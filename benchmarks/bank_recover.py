"""Durability cost of the write-ahead session journal.

Two questions, one benchmark:

  1. **What does journaling cost in steady state?**  Each row streams N
     tenant sessions through a `BankSessionServer` three times — no
     journal, journal with ``fsync=False`` (every record still reaches
     the OS page cache in an unbuffered write, i.e. full ``SIGKILL``
     durability), and journal with ``fsync=True`` (group-commit fsync
     per `step()`, power-loss durability) — and reports aggregate
     delivered samples/s for each.  The CI gate bounds the
     ``fsync=False`` arm's overhead at ``--overhead-gate`` (default
     10%): that arm measures the journal's own cost (record framing,
     CRC, the append syscalls, snapshot cadence), while the fsync arm
     additionally measures the host's storage stack and is reported but
     not ratio-gated (an absolute floor still applies).

  2. **How fast is a restart?**  The journaled server is then abandoned
     mid-flight — queued chunks and undelivered outputs in the log,
     nothing flushed, the `SIGKILL` model — and the row times
     `BankSessionServer.recover(path)` (replay + rebuild + re-serve) to
     the FIRST delivered output sample: ``restart_s`` is the
     restart-to-first-output latency the serving story promises.

Every row verifies one recovered session bit-exactly against the numpy
oracle before its numbers are reported.

The committed ``BENCH_recover.json`` is the smoke baseline CI gates
against: overhead under the gate, restart under the absolute ceiling
and within ``--tolerance`` (a multiple, default 4x) of the committed
row — restart latency re-runs jit warmup on a shared CI host, so the
gate is a loose smoke bound, not a tight regression ratio.

Usage:
  python benchmarks/bank_recover.py                    # full run, writes JSON
  python benchmarks/bank_recover.py --fast --check BENCH_recover.json  # CI
"""
from __future__ import annotations

import argparse
import json
import os
import shutil
import sys
import tempfile
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

TAPS = 31
RESTART_CEILING_S = 60.0  # absolute smoke ceiling per restart
#: (n_sessions, n_slots) grid — the 64-session arm is the acceptance
#: workload: 64 tenants rebuilt from the log after a crash.  The CI
#: (fast) grid runs only that arm: its ~100 ms steps give the
#: interleaved median a stable denominator, where the 16-session arm's
#: short steps measure mostly OS scheduler noise on a shared runner
GRID = ((16, 4), (64, 8))
FAST_GRID = ((64, 8),)
OUT_PATH = os.path.join(os.path.dirname(__file__), "..",
                        "BENCH_recover.json")
ARTIFACT_PATH = os.path.join(
    os.path.dirname(__file__), "out", "bank_recover.json"
)


def _one_step(server, sessions, streams, chunk, k):
    """One push+step+pull round over chunk ``k``; returns (seconds,
    delivered samples)."""
    t0 = time.perf_counter()
    delivered = 0
    for i, s in enumerate(sessions):
        s.push(streams[i][k * chunk:(k + 1) * chunk])
    server.step()
    for s in sessions:
        delivered += s.pull().shape[1]
    return time.perf_counter() - t0, delivered


def _run_row(n_sessions: int, n_slots: int, n_steps: int,
             chunk: int, workdir: str) -> dict:
    from repro.compiler import compile_bank
    from repro.filters import fir_bit_layers_batch, spread_lowpass_qbank
    from repro.serving import BankSessionServer

    bank = max(64, n_sessions)
    qbank = spread_lowpass_qbank(bank, TAPS)
    program = compile_bank(qbank)
    rng = np.random.default_rng(n_sessions)
    sels = [[i % bank, (i * 7 + 3) % bank] for i in range(n_sessions)]
    streams = [
        rng.integers(-128, 128, (n_steps + 1) * chunk).astype(np.int32)
        for _ in range(n_sessions)
    ]

    def make(journal, fsync):
        srv = BankSessionServer(
            program, n_slots=n_slots, auto_step=False,
            journal=journal, journal_fsync=fsync, snapshot_every=4,
        )
        sessions = [srv.open_session(sels[i], session_id=f"t{i}")
                    for i in range(n_sessions)]
        return srv, sessions

    # the three arms run INTERLEAVED, chunk by chunk, and each reports
    # its FASTEST step: host noise only ever adds time to a step, so the
    # min is the arm's true cost, and interleaving keeps load drift from
    # hitting one arm only — the overhead ratio stays honest
    arms = {
        "plain": make(None, True),
        "nosync": make(os.path.join(workdir, f"wal_ns_{n_sessions}"),
                       False),
        "fsync": make(os.path.join(workdir, f"wal_fs_{n_sessions}"),
                      True),
    }
    steps = {name: [] for name in arms}
    order = list(arms)
    for k in range(n_steps):
        # rotate which arm goes first: cache warmth and CPU-boost state
        # carried over from the previous arm's step must not favor a
        # fixed position in the round
        order = order[1:] + order[:1]
        for name in order:
            srv, sessions = arms[name]
            dt, delivered = _one_step(srv, sessions, streams, chunk, k)
            # the first two steps are warmup: no overlap-save tail yet
            # (a different lane shape) then the first steady-state shape
            # — jit compilation bills no arm and no timed step
            if k >= 2:
                steps[name].append(dt)
    sps = {
        name: n_sessions * chunk / float(np.min(ts))
        for name, ts in steps.items()
    }
    plain_sps, nosync_sps, fsync_sps = (
        sps["plain"], sps["nosync"], sps["fsync"]
    )
    arms["plain"][0].close()
    arms["nosync"][0].close()
    srv2, sessions2 = arms["fsync"]

    # crash the fsync arm mid-flight: queued chunks, no close, no flush
    for i, s in enumerate(sessions2):
        s.push(streams[i][n_steps * chunk:])
    journal_stats = srv2.journal.stats()
    d2 = srv2.journal.path
    del srv2

    t0 = time.perf_counter()
    srv3 = BankSessionServer.recover(d2, program)
    recover_s = time.perf_counter() - t0
    first = srv3.sessions["t0"].pull()
    restart_s = time.perf_counter() - t0
    if first.shape[1] == 0:
        raise AssertionError("recovery produced no first output")
    # bit-exactness spot check: the queued post-crash chunk made it
    x = streams[0]
    ref = fir_bit_layers_batch(x[None, :], qbank)[np.asarray(sels[0]), 0]
    n_pre = n_steps * chunk - (TAPS - 1)
    if not np.array_equal(first, ref[:, n_pre:n_pre + first.shape[1]]):
        raise AssertionError("recovered session != oracle")
    srv3.close()

    return {
        "n_sessions": n_sessions,
        "n_slots": n_slots,
        "taps": TAPS,
        "bank_size": bank,
        "n_steps": n_steps,
        "chunk_samples": chunk,
        "plain_samples_per_s": plain_sps,
        "journal_samples_per_s": nosync_sps,
        "journal_fsync_samples_per_s": fsync_sps,
        "overhead_pct": 100.0 * (plain_sps / nosync_sps - 1.0),
        "overhead_fsync_pct": 100.0 * (plain_sps / fsync_sps - 1.0),
        "recover_s": recover_s,
        "restart_s": restart_s,
        "journal_appends": journal_stats["appends"],
        "journal_syncs": journal_stats["syncs"],
        "journal_bytes": journal_stats["segment_bytes"],
    }


def run(grid=GRID, n_steps: int = 14, chunk: int = 1024,
        verbose: bool = True) -> dict:
    import jax

    from repro.kernels.runtime import default_interpret

    workdir = tempfile.mkdtemp(prefix="bank_recover_")
    rows = []
    try:
        for n_sessions, n_slots in grid:
            row = _run_row(n_sessions, n_slots, n_steps, chunk, workdir)
            rows.append(row)
            if verbose:
                print(f"N={n_sessions:3d} slots={n_slots}  plain "
                      f"{row['plain_samples_per_s']:9.0f} sm/s  journal "
                      f"{row['journal_samples_per_s']:9.0f} "
                      f"({row['overhead_pct']:+5.1f}%)  +fsync "
                      f"{row['journal_fsync_samples_per_s']:9.0f} "
                      f"({row['overhead_fsync_pct']:+5.1f}%)  restart "
                      f"{row['restart_s'] * 1e3:7.1f} ms")
    finally:
        shutil.rmtree(workdir, ignore_errors=True)
    return {
        "benchmark": "bank_recover",
        "backend": jax.default_backend(),
        "interpret": default_interpret(),
        "taps": TAPS,
        "restart_ceiling_s": RESTART_CEILING_S,
        "rows": rows,
        "note": (
            "overhead_pct is the fsync=False journal arm vs no journal — "
            "the WAL's own cost (framing, CRC, unbuffered appends, "
            "snapshot cadence) at full SIGKILL durability; "
            "overhead_fsync_pct adds the per-step group-commit fsync and "
            "measures the storage stack, so it is reported but not "
            "ratio-gated; restart_s is recover(path) to the first "
            "delivered output sample for every session rebuilt bit-exactly "
            "from the log"
        ),
    }


def write_artifact(result: dict, path: str = ARTIFACT_PATH) -> None:
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        json.dump(result, f, indent=2)
        f.write("\n")


def check(result: dict, committed_path: str, tolerance: float,
          overhead_gate: float) -> int:
    """Gate: journaling overhead under the gate, restart under the
    absolute ceiling and within ``tolerance`` x the committed row."""
    with open(committed_path) as f:
        committed = json.load(f)
    if not result["rows"]:
        print("check FAILED: no rows ran")
        return 1
    base = {
        (r["n_sessions"], r["n_slots"]): r for r in committed["rows"]
    }
    status = 0
    for row in result["rows"]:
        key = (row["n_sessions"], row["n_slots"])
        ov = row["overhead_pct"]
        flag = "OK" if ov <= 100.0 * overhead_gate else "REGRESSION"
        print(f"check N={key[0]} slots={key[1]} journal overhead "
              f"{ov:+.1f}% <= {100.0 * overhead_gate:.0f}%  {flag}")
        if flag != "OK":
            status = 1
        rs = row["restart_s"]
        flag = "OK" if 0.0 < rs <= RESTART_CEILING_S else "REGRESSION"
        print(f"check N={key[0]} slots={key[1]} restart "
              f"{rs * 1e3:.1f} ms <= ceiling {RESTART_CEILING_S:.0f} s  "
              f"{flag}")
        if flag != "OK":
            status = 1
        if key in base:
            old = base[key]["restart_s"]
            ratio = rs / old if old > 0 else float("inf")
            flag = "OK" if ratio <= 1.0 + tolerance else "REGRESSION"
            print(f"check N={key[0]} slots={key[1]} vs committed "
                  f"{old * 1e3:.1f} ms ({ratio:.2f}x, allowed "
                  f"{1.0 + tolerance:.1f}x)  {flag}")
            if flag != "OK":
                status = 1
    return status


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--fast", action="store_true",
                    help="CI mode: acceptance row only, no JSON rewrite "
                         "(same stream shape as the committed baseline so "
                         "restart latencies compare apples-to-apples)")
    ap.add_argument("--check", metavar="JSON",
                    help="compare against a committed BENCH_recover.json")
    ap.add_argument("--tolerance", type=float, default=4.0,
                    help="allowed restart-latency multiple vs committed")
    ap.add_argument("--overhead-gate", type=float, default=0.10,
                    help="max allowed journaling overhead (fraction)")
    ap.add_argument("--out", default=OUT_PATH)
    args = ap.parse_args()
    if args.check and not os.path.exists(args.check):
        ap.error(f"baseline not found: {args.check}")
    grid = FAST_GRID if args.fast else GRID
    result = run(grid=grid)
    write_artifact(result)
    if args.check:
        return check(result, args.check, args.tolerance,
                     args.overhead_gate)
    if not args.fast:
        with open(args.out, "w") as f:
            json.dump(result, f, indent=2)
            f.write("\n")
        print(f"wrote {os.path.normpath(args.out)}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
