"""Sharded filter-bank scaling: BLMAC bank over 1→N forced host devices.

A B=256 lowpass bank (the BENCH_fir.json workload) is served through
`repro.filters.ShardedFilterBankEngine` at increasing bank-shard counts
on a (n, 1) device mesh.  Every arm is verified bit-exact against the
numpy oracle before any timing.

Methodology (critical-path rule): forced host-platform devices
(``XLA_FLAGS=--xla_force_host_platform_device_count=N``) all share the
host's physical cores, so concurrent wall-clock on them measures core
CONTENTION, not mesh scaling.  The scaling row therefore times each
shard's dispatch IN ISOLATION (`ShardedFilterBankEngine.time_shards`)
and reports the mesh critical path — the slowest shard — which is the
number a real N-device mesh is bounded by, exactly how the paper scales
Msamples/s by replicating independent 110-LUT machines.  The concurrent
wall-clock is also recorded per row (``concurrent_s``) for reference,
but is not the gated metric on a shared-core host.

Because the gated metric is a RATIO of arms, the arms are sampled
interleaved (every repeat touches all arms back-to-back, min per shard
across repeats): a co-tenant slowdown then degrades every arm alike
instead of skewing whichever arm it happened to land on.

The committed ``BENCH_sharded.json`` is the baseline CI regresses
against: the gate compares the SAME-RUN scaling ratio (8-device
aggregate over the 1-device arm), which transfers across runner
hardware, and additionally enforces the absolute acceptance floor
``scaling >= 3.0`` at 8 devices.

Usage:
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      python benchmarks/bank_sharded.py                  # full run, writes JSON
  ... bank_sharded.py --fast --check BENCH_sharded.json  # CI gate
"""
from __future__ import annotations

import argparse
import json
import os
import sys

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

BANK_SIZE = 256
TAPS = 63
DEVICE_ARMS = (1, 2, 4, 8)
SCALING_FLOOR = 3.0  # acceptance: >= 3x aggregate at 8 devices vs 1
OUT_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_sharded.json")
ARTIFACT_PATH = os.path.join(
    os.path.dirname(__file__), "out", "bank_sharded_scaling.json"
)


def _design_qbank(n_filters: int, taps: int) -> np.ndarray:
    from repro.filters import spread_lowpass_qbank

    return spread_lowpass_qbank(n_filters, taps)


def _setup_arm(qbank, x, ndev, devices, n_samples):
    from repro.distributed import bank_mesh
    from repro.filters import ShardedFilterBankEngine, fir_bit_layers_batch

    mesh = bank_mesh(ndev, 1, devices[:ndev])
    eng = ShardedFilterBankEngine(
        qbank, mesh=mesh, n_bank_shards=ndev, chunk_hint=n_samples
    )
    # bit-exact before any timing (the five-way differential runs the
    # full harness in tests; the benchmark still refuses to time a wrong
    # kernel)
    ref = fir_bit_layers_batch(x, qbank)[:, 0, :]
    y = eng.push(x)[:, 0, :]
    if not np.array_equal(y, ref):
        raise AssertionError(f"sharded arm mismatch at {ndev} devices")
    eng.reset()
    return eng


def run(n_samples: int = 8192, repeats: int = 3, arms=DEVICE_ARMS,
        verbose: bool = True) -> dict:
    import time

    import jax

    from repro.kernels.runtime import default_interpret

    devices = jax.devices()
    usable = [n for n in arms if n <= len(devices)]
    dropped = [n for n in arms if n > len(devices)]
    if dropped:
        print(f"NOTE: only {len(devices)} device(s) visible — skipping "
              f"arms {dropped} (run under XLA_FLAGS="
              f"--xla_force_host_platform_device_count={max(arms)})")
    qbank = _design_qbank(BANK_SIZE, TAPS)
    rng = np.random.default_rng(42)
    x = rng.integers(-128, 128, n_samples).astype(np.int32)
    n_out = n_samples - TAPS + 1
    engines = [_setup_arm(qbank, x, n, devices, n_samples) for n in usable]
    # INTERLEAVED timing: the gated metric is a ratio of arms, so every
    # repeat samples all arms back-to-back — a host slowdown then hits
    # every arm alike instead of skewing whichever arm it landed on
    # (sequential arms made the ratio track co-tenant noise, not code)
    shard_s = [None] * len(engines)
    for _ in range(repeats):
        for i, eng in enumerate(engines):
            t = eng.time_shards(x, repeats=1)
            shard_s[i] = t if shard_s[i] is None else np.minimum(shard_s[i], t)
    rows = []
    for eng, ndev, t in zip(engines, usable, shard_s):
        critical = float(t.max())
        # concurrent wall-clock for reference (shared-core contention)
        def run_concurrent():
            p = eng.push_async(x)
            jax.block_until_ready(p._shard_outs)
            eng.reset()

        run_concurrent()
        conc = float("inf")
        for _ in range(repeats):
            t0 = time.perf_counter()
            run_concurrent()
            conc = min(conc, time.perf_counter() - t0)
        rows.append({
            "devices": ndev,
            "n_bank_shards": eng.n_bank_shards,
            "bank_size": qbank.shape[0],
            "n_samples": n_samples,
            "outputs_per_filter": n_out,
            "shard_modes": [p.mode for p in eng.plan.shard_plans],
            "imbalance": round(eng.partition.imbalance, 4),
            "critical_path_s": critical,
            "aggregate_samples_per_s_per_filter": n_out / critical,
            "concurrent_s": conc,
        })
        if verbose:
            print(f"devices={ndev:2d} shards={eng.n_bank_shards:2d} "
                  f"critical {critical * 1e3:8.1f} ms  aggregate "
                  f"{n_out / critical:12.0f} samples/s/filter  "
                  f"(concurrent {conc * 1e3:8.1f} ms, "
                  f"imbalance {eng.partition.imbalance:.2f})")
    base = rows[0]["aggregate_samples_per_s_per_filter"]
    for r in rows:
        r["scaling_vs_1dev"] = r["aggregate_samples_per_s_per_filter"] / base
    return {
        "benchmark": "bank_sharded",
        "backend": jax.default_backend(),
        "interpret": default_interpret(),
        "bank_size": BANK_SIZE,
        "taps": TAPS,
        "n_samples": n_samples,
        "scaling_floor": SCALING_FLOOR,
        "rows": rows,
        "note": (
            "critical-path methodology: forced host devices share cores, so "
            "each shard is timed in isolation and the row reports the mesh "
            "critical path (slowest shard) — the paper's replicated-machine "
            "scaling model; arms are sampled interleaved so the gated "
            "scaling ratio cancels host-speed drift; concurrent_s is the "
            "shared-core wall-clock, reported but not gated; rows are the "
            "conservative floor (lowest scaling) over repeated runs on the "
            "reference machine"
        ),
    }


def write_artifact(result: dict, path: str = ARTIFACT_PATH) -> None:
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        json.dump(result, f, indent=2)
        f.write("\n")


def check(result: dict, committed_path: str, tolerance: float) -> int:
    """Gate: the max-device arm must (a) clear the absolute >= 3x scaling
    floor and (b) not regress > tolerance vs the committed same-run
    scaling ratio.  Ratios are measured within one run, so the gate
    transfers across runner hardware like BENCH_fir.json's speedup gate."""
    with open(committed_path) as f:
        committed = json.load(f)
    rows = {r["devices"]: r for r in result["rows"]}
    top = max(rows)
    if top < max(DEVICE_ARMS):
        print(f"check FAILED: need the {max(DEVICE_ARMS)}-device arm, "
              f"largest measured was {top} (set XLA_FLAGS)")
        return 1
    status = 0
    scaling = rows[top]["scaling_vs_1dev"]
    flag = "OK" if scaling >= SCALING_FLOOR else "REGRESSION"
    print(f"check devices={top} scaling floor: {scaling:.2f}x >= "
          f"{SCALING_FLOOR:.1f}x required  {flag}")
    if flag != "OK":
        status = 1
    base = {r["devices"]: r for r in committed["rows"]}
    for n, row in sorted(rows.items()):
        if n not in base or n == 1:
            continue
        old = base[n]["scaling_vs_1dev"]
        new = row["scaling_vs_1dev"]
        ratio = new / old
        flag = "OK" if ratio >= 1.0 - tolerance else "REGRESSION"
        print(f"check devices={n} scaling: {new:.2f}x vs committed "
              f"{old:.2f}x ({ratio:.2f}x)  {flag}")
        if flag != "OK":
            status = 1
    return status


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--fast", action="store_true",
                    help="short signal + 1-vs-8 arms only (CI; no JSON "
                         "rewrite)")
    ap.add_argument("--check", metavar="JSON",
                    help="compare against a committed BENCH_sharded.json")
    ap.add_argument("--tolerance", type=float, default=0.3)
    ap.add_argument("--out", default=OUT_PATH)
    args = ap.parse_args()
    if args.check and not os.path.exists(args.check):
        ap.error(f"baseline not found: {args.check}")
    n_samples = 4096 if args.fast else 8192
    if args.check:
        # scaling ratios are only comparable at the committed signal
        # length (the autotuner picks different tiles per chunk size)
        with open(args.check) as f:
            n_samples = json.load(f)["n_samples"]
    repeats = 5 if args.fast else 7
    arms = (1, max(DEVICE_ARMS)) if args.fast else DEVICE_ARMS
    result = run(n_samples=n_samples, repeats=repeats, arms=arms)
    write_artifact(result)
    if args.check:
        return check(result, args.check, args.tolerance)
    if not args.fast:
        with open(args.out, "w") as f:
            json.dump(result, f, indent=2)
            f.write("\n")
        print(f"wrote {os.path.normpath(args.out)}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
