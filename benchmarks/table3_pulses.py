"""Paper Tab. 3: average and maximum pulse (non-zero CSD trit) counts for
all integers of 1..24 bits.  Exact combinatorial reproduction — every value
in [0, 2**n) is encoded (chunked; 16.7M values at n=24)."""
from __future__ import annotations

import numpy as np

from repro.core import num_pulses

# Values printed in the paper (Tab. 3), for the comparison column.
PAPER_AVG = [0.5, 1.0, 1.37, 1.75, 2.09, 2.44, 2.77, 3.11, 3.44, 3.77, 4.11,
             4.44, 4.78, 5.11, 5.44, 5.77, 6.11, 6.44, 6.78, 7.11, 7.44,
             7.78, 8.11, 8.44]
PAPER_MAX = [1, 2, 2, 3, 3, 4, 4, 5, 5, 6, 6, 7, 7, 8, 8, 9, 9, 10, 10, 11,
             11, 12, 12, 13]


def compute(max_bits: int = 24, chunk: int = 1 << 20):
    """Returns (avg[n], max[n]) for n = 1..max_bits, exactly."""
    total = 1 << max_bits
    sums = np.zeros(max_bits + 1, np.float64)  # pulse sum over [0, 2**n)
    maxs = np.zeros(max_bits + 1, np.int64)
    done = 0
    # prefix accumulation: values in [2**(n-1), 2**n) belong to all m >= n
    counts_per_pow = np.zeros(max_bits + 1, np.float64)
    max_per_pow = np.zeros(max_bits + 1, np.int64)
    for start in range(0, total, chunk):
        vals = np.arange(start, min(start + chunk, total), dtype=np.int64)
        p = num_pulses(vals)
        # bucket by bit length of the value
        nbits = np.zeros(vals.size, np.int64)
        nz = vals > 0
        nbits[nz] = np.floor(np.log2(vals[nz])).astype(np.int64) + 1
        for b in np.unique(nbits):
            sel = p[nbits == b]
            counts_per_pow[b] += sel.sum()
            max_per_pow[b] = max(max_per_pow[b], int(sel.max()))
        done += vals.size
    for n in range(1, max_bits + 1):
        sums[n] = counts_per_pow[: n + 1].sum()
        maxs[n] = max_per_pow[: n + 1].max()
    avg = {n: sums[n] / float(1 << n) for n in range(1, max_bits + 1)}
    mx = {n: int(maxs[n]) for n in range(1, max_bits + 1)}
    return avg, mx


def run(max_bits: int = 24, verbose: bool = True):
    avg, mx = compute(max_bits)
    rows = []
    ok = True
    for n in range(1, max_bits + 1):
        pa, pm = PAPER_AVG[n - 1], PAPER_MAX[n - 1]
        match = abs(avg[n] - pa) < 0.01 and mx[n] == pm
        ok &= match
        rows.append((n, avg[n], mx[n], pa, pm, match))
        if verbose:
            print(f"  n={n:2d}  avg={avg[n]:5.2f} (paper {pa:5.2f})  "
                  f"max={mx[n]:2d} (paper {pm:2d})  {'OK' if match else 'MISMATCH'}")
    return rows, ok


if __name__ == "__main__":
    _, ok = run()
    print("Table 3 reproduction:", "EXACT" if ok else "MISMATCH")
