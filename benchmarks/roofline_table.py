"""§Roofline: render the per-(arch × shape × mesh) three-term roofline
table from the dry-run JSONs (benchmarks/out/dryrun/*.json)."""
from __future__ import annotations

import glob
import json
import os
import sys

OUT = os.path.join(os.path.dirname(__file__), "out", "dryrun")


def load(tag: str = "baseline") -> list[dict]:
    rows = []
    for f in sorted(glob.glob(os.path.join(OUT, "*.json"))):
        r = json.load(open(f))
        if r.get("tag", "baseline") == tag:
            rows.append(r)
    return rows


def fmt_table(rows: list[dict], pod: str = "pod1") -> str:
    want_mp = pod == "pod2"
    lines = [
        "| arch | shape | compute s | memory s | collective s | dominant | "
        "mem/dev GiB | fits | useful-flops | roofline frac |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if bool(r["multi_pod"]) != want_mp:
            continue
        terms = {"compute": r["compute_term_s"], "memory": r["memory_term_s"],
                 "collective": r["collective_term_s"]}
        bound = max(terms.values())
        # roofline fraction: ideal compute time at peak over the binding term
        ideal = r["model_flops_per_dev"] / 197e12
        frac = ideal / bound if bound > 0 else 0.0
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_term_s']:.4f} | "
            f"{r['memory_term_s']:.4f} | {r['collective_term_s']:.4f} | "
            f"{r['dominant']} | {r['mem_per_device_bytes']/2**30:.2f} | "
            f"{'Y' if r['fits_hbm'] else 'N'} | "
            f"{r['useful_flops_ratio']:.2f} | {frac:.3f} |")
    return "\n".join(lines)


def main() -> None:
    tag = sys.argv[1] if len(sys.argv) > 1 else "baseline"
    rows = load(tag)
    if not rows:
        print("no dry-run artifacts found — run repro.launch.dryrun first")
        return
    print(f"== single-pod (16x16 = 256 chips), tag={tag} ==")
    print(fmt_table(rows, "pod1"))
    print()
    print(f"== multi-pod (2x16x16 = 512 chips), tag={tag} ==")
    print(fmt_table(rows, "pod2"))


if __name__ == "__main__":
    main()
