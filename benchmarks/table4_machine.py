"""Paper §4 + Tab. 4: the 127-tap BLMAC dot-product machine — at sweep scale.

Runs the *vectorized* machine simulator (`repro.core.vmachine`) over the
full 9,900-filter 127-tap Hamming bank: exact outputs and exact per-output
cycle counts for every filter, in seconds of numpy time (the scalar
`FirBlmacMachine` needs minutes per bank; it is retained here as the
spot-check reference on a sample of filters).

Reproduced quantities:
  * average clock cycles per output over the bank (paper: ~231.6; ours is
    the mean over ALL filters — 232.0 at n_div=100, rel. err 0.17%),
  * the fused_last_add variant (§4: last add overlapped with the shift)
    — exactly 16 cycles per output cheaper on fully-populated 16-layer
    programs (bank mean ~217.0),
  * the fraction of filters whose RLE program does NOT fit the 256-entry
    weight memory (paper: ~18%),
  * filtering rates at the paper's post-synthesis clock frequencies
    (LUT counts are quoted, not measured — no synthesis on this host).

Artifacts: ``benchmarks/out/BENCH_machine.json`` every run; the committed
copy at the repo root is the CI baseline (cycle counts are deterministic,
so the regression gate is exact up to ``--tolerance``).

Usage:
  python benchmarks/table4_machine.py                 # full: n_div=100
  python benchmarks/table4_machine.py --fast          # CI smoke: n_div=20
  python benchmarks/table4_machine.py --fast --check BENCH_machine.json
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import (FirBlmacMachine, FirBlmacVMachine, MachineSpec,  # noqa: E402
                        po2_quantize_batch)
from repro.filters import sweep_bank, sweep_specs  # noqa: E402

PAPER_MEAN_CYCLES = 231.6
FAST_N_DIV = 20
BANK_CHUNK = 2048  # filters per vmachine pass — bounds peak numpy memory
OUT_DIR = os.path.join(os.path.dirname(__file__), "out")
ROOT_JSON = os.path.join(os.path.dirname(__file__), "..", "BENCH_machine.json")

# (family, mode, LUTs, fmax MHz) from paper Tab. 4 — LUTs quoted from paper.
PAPER_TABLE4 = [
    ("Artix Ultrascale+", "area", 117, 800.0),
    ("Kintex Ultrascale+", "area", 116, 800.0),
    ("Artix 7", "area", 100, 316.8),
    ("Artix 7", "speed", 134, 416.1),
    ("Kintex 7", "area", 101, 407.3),
    ("Kintex 7", "speed", 134, 628.5),
]


def _direct_reference(x: np.ndarray, qbank: np.ndarray) -> np.ndarray:
    """Classical dot product for the whole bank via one float64 BLAS matmul
    (exact: |Σ w·x| ≤ 127·2^15·2^7 ≈ 5.3e8 ≪ 2^53) — the independent
    check the vmachine outputs are verified against."""
    taps = qbank.shape[1]
    win = np.lib.stride_tricks.sliding_window_view(x, taps)  # (n_out, taps)
    ref = win.astype(np.float64) @ qbank.T.astype(np.float64)
    return np.rint(ref).astype(np.int64).T  # (B, n_out)


def design_quantized_bank(n_div: int, bits: int = 16) -> np.ndarray:
    """The full 127-tap Hamming sweep bank, quantized to ``bits``."""
    bank = sweep_bank(127, n_div, "hamming", sweep_specs(n_div))
    q, _ = po2_quantize_batch(bank, bits=bits)
    return q


def simulate_full_bank(
    n_div: int = 100,
    bits: int = 16,
    n_out: int = 256,
    scalar_checks: int = 3,
    fused: bool = False,
    seed: int = 0,
    qbank: np.ndarray | None = None,
) -> dict:
    """Design → quantize → vectorized machine over the whole bank.

    Returns cycle statistics plus verification counters; every output of
    every filter is checked bit-exactly against the classical dot product,
    and ``scalar_checks`` filters are replayed on the scalar machine
    (outputs AND cycle counts).  Pass ``qbank`` to reuse an
    already-designed bank (skips the design step).
    """
    t_design = time.time()
    q = design_quantized_bank(n_div, bits) if qbank is None else qbank
    t_design = time.time() - t_design

    spec = MachineSpec(taps=127, coeff_bits=bits, fused_last_add=fused)
    rng = np.random.default_rng(seed)
    x = rng.integers(-128, 128, 127 - 1 + n_out)

    t_sim = time.time()
    n_filters = q.shape[0]
    cycles_first = np.empty(n_filters, np.int64)
    code_counts = np.empty(n_filters, np.int64)
    fits = np.empty(n_filters, bool)
    mismatches = 0
    for lo in range(0, n_filters, BANK_CHUNK):
        chunk = q[lo : lo + BANK_CHUNK]
        vm = FirBlmacVMachine(spec)
        fits[lo : lo + len(chunk)] = vm.program_bank(chunk)
        code_counts[lo : lo + len(chunk)] = vm.code_counts
        res = vm.run(x)
        cycles_first[lo : lo + len(chunk)] = res.cycles[:, 0]
        mismatches += int(
            (res.outputs != _direct_reference(x, chunk)).any(axis=1).sum()
        )
    t_sim = time.time() - t_sim

    # scalar spot checks: the slow reference replays a few fitting filters
    scalar_checked = 0
    for b in rng.choice(np.nonzero(fits)[0],
                        size=min(scalar_checks, int(fits.sum())),
                        replace=False):
        m = FirBlmacMachine(spec)
        m.program(q[b])
        sres = m.run(x[: 127 - 1 + min(n_out, 16)])
        vres = FirBlmacVMachine(spec)
        vres.program_bank(q[b : b + 1])
        vv = vres.run(x[: 127 - 1 + min(n_out, 16)])
        assert np.array_equal(sres.outputs, vv.outputs[0]), "scalar mismatch!"
        assert np.array_equal(sres.cycles, vv.cycles[0]), "cycle mismatch!"
        scalar_checked += 1

    return dict(
        n_filters=n_filters,
        n_out=n_out,
        mean_cycles_all=float(cycles_first.mean()),
        mean_cycles_fitting=float(cycles_first[fits].mean()),
        pct_not_fitting=float(100.0 * (~fits).mean()),
        max_codes=int(code_counts.max()),
        bit_exact_mismatches=mismatches,
        scalar_checked=scalar_checked,
        design_s=round(t_design, 3),
        sim_s=round(t_sim, 3),
    )


def run(n_div: int = 100, verbose: bool = True, n_out: int = 256) -> dict:
    t0 = time.time()
    q = design_quantized_bank(n_div)  # design ONCE, share across variants
    t_design = time.time() - t0
    stats = simulate_full_bank(n_div, n_out=n_out, qbank=q)
    stats["design_s"] = round(t_design, 3)
    fused = simulate_full_bank(
        n_div, n_out=16, scalar_checks=1, fused=True, qbank=q
    )
    stats["fused_mean_cycles_all"] = fused["mean_cycles_all"]
    stats["paper_mean_cycles"] = PAPER_MEAN_CYCLES
    stats["paper_rel_err"] = abs(
        stats["mean_cycles_all"] - PAPER_MEAN_CYCLES
    ) / PAPER_MEAN_CYCLES
    if verbose:
        print(
            f"  filters: {stats['n_filters']}  outputs each: {stats['n_out']}  "
            f"simulated in {stats['sim_s']:.1f}s "
            f"(+{stats['design_s']:.1f}s design), "
            f"bit-exact mismatches: {stats['bit_exact_mismatches']}"
        )
        print(
            f"  mean cycles (all): {stats['mean_cycles_all']:.1f} "
            f"(paper ~{PAPER_MEAN_CYCLES}, rel err "
            f"{100 * stats['paper_rel_err']:.2f}%)  "
            f"fused_last_add: {stats['fused_mean_cycles_all']:.1f} "
            f"(−{stats['mean_cycles_all'] - stats['fused_mean_cycles_all']:.1f} "
            f"mean, −16 on fully-populated programs)"
        )
        print(
            f"  mean cycles (fitting subset): "
            f"{stats['mean_cycles_fitting']:.1f}  not fitting 256 codes: "
            f"{stats['pct_not_fitting']:.1f}% (paper ~18%)"
        )
        print(
            f"  scalar machine replayed {stats['scalar_checked']} filters "
            f"(outputs + cycles bit-exact)"
        )
        for fam, mode, luts, fmax in PAPER_TABLE4:
            rate = fmax / stats["mean_cycles_all"]
            print(
                f"  {fam:20s} {mode:5s}  {luts:4d} LUTs (paper)  "
                f"{fmax:6.1f} MHz -> {rate:.2f} Msample/s "
                f"(paper ~{fmax / PAPER_MEAN_CYCLES:.2f})"
            )
    return stats


# ---------------------------------------------------------------------------
# JSON artifacts + CI regression gate
# ---------------------------------------------------------------------------


def _grid_record(stats: dict) -> dict:
    keys = (
        "n_filters", "mean_cycles_all", "mean_cycles_fitting",
        "pct_not_fitting", "fused_mean_cycles_all", "paper_rel_err",
    )
    return {k: stats[k] for k in keys}


def write_json(n_div: int, stats: dict, path: str) -> None:
    data = {}
    if os.path.exists(path):
        with open(path) as f:
            data = json.load(f)
    data.setdefault("meta", {
        "taps": 127, "coeff_bits": 16, "sample_bits": 8,
        "weight_mem_codes": 256, "paper_mean_cycles": PAPER_MEAN_CYCLES,
    })
    data.setdefault("grids", {})[str(n_div)] = _grid_record(stats)
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "w") as f:
        json.dump(data, f, indent=2, sort_keys=True)
        f.write("\n")


def check_against(path: str, n_div: int, stats: dict, tolerance: float) -> int:
    """CI gate: compare against the committed baseline.  Cycle counts are
    deterministic functions of the filter bank, so any drift beyond float
    noise means the cost model or the simulator changed behaviour."""
    with open(path) as f:
        base = json.load(f)
    rec = base.get("grids", {}).get(str(n_div))
    if rec is None:
        print(f"CHECK FAIL: no baseline for n_div={n_div} in {path} "
              f"(add one with --update-baseline, without --check)")
        return 1
    failures = 0
    for key in ("mean_cycles_all", "mean_cycles_fitting",
                "fused_mean_cycles_all", "pct_not_fitting"):
        got, want = stats[key], rec[key]
        rel = abs(got - want) / max(abs(want), 1e-12)
        tag = "OK" if rel <= tolerance else "FAIL"
        if rel > tolerance:
            failures += 1
        print(f"CHECK {tag}: {key} = {got:.4f} vs baseline {want:.4f} "
              f"(rel {rel:.2e}, tol {tolerance:.2e})")
    # the ~231.6 headline is defined over the full 9,900-filter grid; the
    # fast grid is a different (smaller) bank with a different mean
    if n_div == 100 and stats["paper_rel_err"] >= 0.01:
        failures += 1
        print(f"CHECK FAIL: paper rel err {stats['paper_rel_err']:.4f} >= 1%")
    if stats["bit_exact_mismatches"]:
        failures += 1
        print(f"CHECK FAIL: {stats['bit_exact_mismatches']} filters not "
              f"bit-exact vs classical reference")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--n-div", type=int, default=100,
                    help="frequency grid divisions (100 → 9,900 filters)")
    ap.add_argument("--fast", action="store_true",
                    help=f"CI smoke grid (n_div={FAST_N_DIV})")
    ap.add_argument("--n-out", type=int, default=256,
                    help="output samples simulated per filter")
    ap.add_argument("--check", metavar="BASELINE.json",
                    help="compare against a committed baseline; non-zero "
                         "exit on regression")
    ap.add_argument("--tolerance", type=float, default=1e-6,
                    help="relative tolerance for --check (cycle stats are "
                         "deterministic; default is float-noise tight)")
    ap.add_argument("--update-baseline", action="store_true",
                    help=f"also write the repo-root baseline {ROOT_JSON}")
    args = ap.parse_args(argv)
    n_div = FAST_N_DIV if args.fast else args.n_div

    t0 = time.time()
    stats = run(n_div, n_out=args.n_out)
    print(f"  total wall time: {time.time() - t0:.1f}s")

    write_json(n_div, stats, os.path.join(OUT_DIR, "BENCH_machine.json"))
    # gate BEFORE touching the baseline: --check --update-baseline must
    # compare against the committed numbers, not against this very run
    failures = (
        check_against(args.check, n_div, stats, args.tolerance)
        if args.check else 0
    )
    if args.update_baseline:
        write_json(n_div, stats, ROOT_JSON)
    return failures


if __name__ == "__main__":
    raise SystemExit(main())
