"""Paper §4 + Tab. 4: the 127-tap BLMAC dot-product machine.

Reproduces, with the cycle-accurate simulator:
  * average clock cycles per output over the 9,900 127-tap Hamming-window
    filters (paper: ~231.6, measured over the ~82% that fit the 256-entry
    weight memory),
  * the fraction of filters whose RLE program does NOT fit (paper: ~18%),
  * filtering rates at the paper's post-synthesis clock frequencies
    (LUT counts are quoted, not measured — no synthesis on this host).
"""
from __future__ import annotations

import argparse

import numpy as np

from repro.core import csd_digits, code_count, po2_quantize_batch
from repro.core.machine import FirBlmacMachine, MachineSpec
from repro.filters import sweep_bank, sweep_specs

# (family, mode, LUTs, fmax MHz) from paper Tab. 4 — LUTs quoted from paper.
PAPER_TABLE4 = [
    ("Artix Ultrascale+", "area", 117, 800.0),
    ("Kintex Ultrascale+", "area", 116, 800.0),
    ("Artix 7", "area", 100, 316.8),
    ("Artix 7", "speed", 134, 416.1),
    ("Kintex 7", "area", 101, 407.3),
    ("Kintex 7", "speed", 134, 628.5),
]


def cycle_stats(n_div: int = 100, bits: int = 16, mem_codes: int = 256):
    """Code/cycle statistics over the full 127-tap Hamming bank.

    Cycle count per output = #RLE codes (one code, one cycle) — computed
    vectorially here; `tests/test_machine.py` asserts the simulator's
    per-sample cycle counter equals this code count exactly.
    """
    bank = sweep_bank(127, n_div, "hamming", sweep_specs(n_div))
    q, _ = po2_quantize_batch(bank, bits=bits)
    half = q[:, :64]
    digits = csd_digits(half, n_digits=bits)  # (F, 64, 16)
    codes = np.count_nonzero(digits, axis=(1, 2)) + bits  # pulses + EORs
    fits = codes <= mem_codes
    return dict(
        n_filters=len(q),
        mean_cycles_all=float(codes.mean()),
        mean_cycles_fitting=float(codes[fits].mean()),
        pct_not_fitting=float(100.0 * (~fits).mean()),
        max_codes=int(codes.max()),
    )


def demo_machine(n_filters: int = 25, seed: int = 0):
    """Run the actual cycle-accurate machine on a sample of filters and
    verify outputs bit-exactly against the classical algorithm (the
    paper's testbench: 127 warm-up + 256 checked outputs per filter)."""
    from repro.filters import fir_direct

    rng = np.random.default_rng(seed)
    specs = sweep_specs(10)  # 90 specs; take a sample
    bank = sweep_bank(127, 10, "hamming", specs)
    q, _ = po2_quantize_batch(bank, bits=16)
    machine = FirBlmacMachine(MachineSpec())
    checked = 0
    cycles = []
    for row in q[:n_filters]:
        try:
            machine.program(row)
        except ValueError:
            continue  # doesn't fit the 256-code memory
        x = rng.integers(-128, 128, size=127 - 1 + 256)
        res = machine.run(x)
        expect = fir_direct(x, row)
        assert np.array_equal(res.outputs, expect), "machine mismatch!"
        cycles.append(res.mean_cycles)
        checked += 1
    return checked, float(np.mean(cycles)) if cycles else float("nan")


def run(n_div: int = 100, verbose: bool = True):
    stats = cycle_stats(n_div)
    checked, sim_cycles = demo_machine()
    if verbose:
        # the paper's 231.6 matches our mean over ALL filters (232.0) to
        # 0.17%; the subset that fits the 256-code memory averages lower.
        print(f"  filters: {stats['n_filters']}  "
              f"mean cycles (all): {stats['mean_cycles_all']:.1f} (paper ~231.6)")
        print(f"  mean cycles (fitting subset): {stats['mean_cycles_fitting']:.1f}  "
              f"not fitting 256 codes: {stats['pct_not_fitting']:.1f}% (paper ~18%)")
        print(f"  cycle-accurate machine verified bit-exact on {checked} filters "
              f"(sim mean {sim_cycles:.1f} cycles)")
        for fam, mode, luts, fmax in PAPER_TABLE4:
            rate = fmax / stats["mean_cycles_all"]
            print(f"  {fam:20s} {mode:5s}  {luts:4d} LUTs (paper)  "
                  f"{fmax:6.1f} MHz -> {rate:.2f} Msample/s (paper ~{fmax/231.6:.2f})")
    stats["sim_mean_cycles"] = sim_cycles
    stats["sim_checked"] = checked
    return stats


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-div", type=int, default=100)
    run(ap.parse_args().n_div)
