"""Paper Figs. 3–4 + §3.3 analysis: BLMAC additions over the FIR sweep.

For each tap count (55..255 odd) × window (Hamming / Kaiser β=8.0 — β
calibrated against the paper's reported B_N, see EXPERIMENTS.md):
design the N(N−1)-filter bank, quantize to int16 (po2 scale + convergent
rounding), count BLMAC additions (Eq. 3 + ntrits), and report
mean/std/min/max — the quantities plotted in the paper's figures.

Default is the paper's full n_div=100 grid but a thinned tap sweep; pass
``--full`` for all 101 tap counts (≈7 CPU-minutes serially, 1.98M
filters) or ``--fast`` for a n_div=40 grid.  ``--jobs N`` fans the
(window, tap-count) grid across a process pool — each cell designs,
quantizes and counts its bank independently, so this scales to however
many cores the machine has (window vectors are memoized per process).
"""
from __future__ import annotations

import argparse
import csv
import os
import pathlib
import time
from concurrent.futures import ProcessPoolExecutor


from repro.core import (
    adds_per_coeff,
    adds_per_tap,
    classical_equivalent_adds,
    fir_blmac_additions_batch,
    po2_quantize_batch,
)
from repro.filters import sweep_bank, sweep_specs

KAISER_BETA = 8.0  # calibrated: B_55=123.4 vs paper 123.3; B_255=475.3 vs 474.7

# Paper §3.3 reference points for validation.
PAPER = {
    ("hamming", 55): 132.5,
    ("hamming", 255): 513.6,
    ("kaiser", 55): 123.3,
    ("kaiser", 255): 474.7,
}

OUT = pathlib.Path(__file__).resolve().parent / "out"


def _grid_row(args) -> dict:
    """One (window, tap count) cell of the sweep grid — a self-contained
    unit of work, picklable for the ``--jobs`` process pool."""
    window_name, taps, n_div = args
    window = "hamming" if window_name == "hamming" else ("kaiser", KAISER_BETA)
    specs = sweep_specs(n_div)
    bank = sweep_bank(taps, n_div, window, specs)
    q, _ = po2_quantize_batch(bank, bits=16)
    adds = fir_blmac_additions_batch(q)
    return dict(
        window=window_name, taps=taps, n_filters=len(specs),
        mean=float(adds.mean()), std=float(adds.std()),
        min=int(adds.min()), max=int(adds.max()),
        adds_per_coeff=float(adds_per_coeff(adds, taps).mean()),
        adds_per_tap=float(adds_per_tap(adds, taps).mean()),
        classical_equiv=classical_equivalent_adds(taps),
    )


def _print_row(r: dict) -> None:
    print(f"  {r['window']:7s} N={r['taps']:3d}  B_N={r['mean']:6.1f}±{r['std']:5.1f} "
          f"[{r['min']},{r['max']}]  adds/coeff={r['adds_per_coeff']:.2f} "
          f"adds/tap={r['adds_per_tap']:.2f}  vs classical {r['classical_equiv']} "
          f"({r['classical_equiv']/r['mean']:.2f}x)")


def run_window(window_name: str, taps_list, n_div: int, verbose=True):
    rows = []
    for taps in taps_list:
        rows.append(_grid_row((window_name, taps, n_div)))
        if verbose:
            _print_row(rows[-1])
    return rows


def run(mode: str = "default", verbose: bool = True, jobs: int = 1):
    if mode == "full":
        taps_list, n_div = list(range(55, 256, 2)), 100
    elif mode == "fast":
        taps_list, n_div = [55, 127, 255], 40
    else:
        taps_list, n_div = [55, 75, 95, 127, 155, 191, 255], 100
    grid = [(w, t, n_div) for w in ("hamming", "kaiser") for t in taps_list]
    if jobs > 1:
        with ProcessPoolExecutor(max_workers=jobs) as pool:
            all_rows = list(pool.map(_grid_row, grid, chunksize=1))
        if verbose:
            for r in all_rows:
                _print_row(r)
    else:
        all_rows = [r for w in ("hamming", "kaiser")
                    for r in run_window(w, taps_list, n_div, verbose)]
    OUT.mkdir(exist_ok=True)
    with open(OUT / f"fig34_sweep_{mode}.csv", "w", newline="") as f:
        wtr = csv.DictWriter(f, fieldnames=list(all_rows[0].keys()))
        wtr.writeheader()
        wtr.writerows(all_rows)
    # validation against the paper's reported end points — only strict on
    # the paper's own n_div=100 grid (coarser grids sample a different
    # filter population and sit ~2% off; that is grid choice, not error)
    checks = []
    strict = n_div == 100
    for (w, taps), want in PAPER.items():
        got = next((r["mean"] for r in all_rows
                    if r["window"] == w and r["taps"] == taps), None)
        if got is not None:
            rel = abs(got - want) / want
            checks.append((w, taps, got, want, rel, strict))
            if verbose:
                verdict = ("OK" if rel < 0.01 else "MISMATCH") if strict \
                    else f"(informational, n_div={n_div})"
                print(f"  check {w} N={taps}: B_N={got:.1f} paper={want}  "
                      f"rel.err={rel*100:.2f}% {verdict}")
    try:
        _plot(all_rows, mode)
    except Exception as e:  # matplotlib optional at runtime
        print("  (plot skipped:", e, ")")
    return all_rows, checks


def _plot(rows, mode):
    import matplotlib
    matplotlib.use("Agg")
    import matplotlib.pyplot as plt

    fig, axes = plt.subplots(1, 2, figsize=(12, 4.5), sharey=True)
    for ax, w in zip(axes, ("hamming", "kaiser")):
        rs = [r for r in rows if r["window"] == w]
        taps = [r["taps"] for r in rs]
        ax.errorbar(taps, [r["mean"] for r in rs], yerr=[r["std"] for r in rs],
                    fmt="b.-", label="mean ± std")
        ax.plot(taps, [r["max"] for r in rs], "r.", label="max")
        ax.plot(taps, [r["min"] for r in rs], "g.", label="min")
        ax.set_title(f"BLMAC additions, {w} window (paper Fig. {3 if w=='hamming' else 4})")
        ax.set_xlabel("taps"); ax.grid(True); ax.legend()
    axes[0].set_ylabel("additions per filter application")
    fig.tight_layout()
    fig.savefig(OUT / f"fig34_{mode}.png", dpi=110)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="all 101 tap counts, n_div=100")
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--jobs", type=int, default=0, metavar="N",
                    help="process-pool width for the (window, taps) grid; "
                         "0 = all cores, 1 = serial")
    a = ap.parse_args()
    jobs = a.jobs if a.jobs else (os.cpu_count() or 1)
    t0 = time.time()
    run("full" if a.full else "fast" if a.fast else "default", jobs=jobs)
    print(f"done in {time.time()-t0:.1f}s ({jobs} job{'s'[:jobs!=1]})")
