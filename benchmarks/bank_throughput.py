"""Filter-bank throughput: batched BLMAC bank kernel vs per-filter loop.

For each bank size B the benchmark designs B lowpass filters with spread
cutoffs, quantizes them to 16 bits, and measures samples/s/filter for

  * ``batched``  — ONE `pallas_call` via `repro.kernels.blmac_fir_bank`
    (packed-trit operands, one integer matmul per bit layer), and
  * ``per_filter`` — a Python loop issuing one B=1 bank-kernel call per
    filter, trits pre-packed outside the timer (the per-filter serving
    pattern the bank replaces: compiled once, dispatched/framed B times —
    what `blmac_fir_dynamic` does per call, minus its host-side packing,
    so the measured gap is batching, not host overhead).

Outputs are cross-checked bit-exactly against
`repro.filters.fir_bit_layers_batch` before timing.  Results land in
``BENCH_fir.json`` at the repo root — the committed copy is the perf
baseline CI regresses against (>20% drop in batched samples/s/filter
fails the build; see ``--check``).

Usage:
  python benchmarks/bank_throughput.py                 # full: B ∈ {1,16,256}
  python benchmarks/bank_throughput.py --quick         # CI: short signal
  python benchmarks/bank_throughput.py --check BENCH_fir.json --tolerance 0.2
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

BANK_SIZES = (1, 16, 256)
TAPS = 63
TILE = 512
OUT_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_fir.json")


def _design_qbank(n_filters: int, taps: int) -> np.ndarray:
    from repro.core import po2_quantize_batch
    from repro.filters import design_bank

    cuts = 0.05 + 0.9 * (np.arange(n_filters) + 0.5) / n_filters
    q, _ = po2_quantize_batch(
        design_bank(taps, [("lowpass", float(c)) for c in cuts]), 16
    )
    return q


def _time(fn, repeats: int) -> float:
    fn()  # warm-up: compile + cache
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def bench_bank(
    n_filters: int,
    n_samples: int,
    taps: int = TAPS,
    tile: int = TILE,
    repeats: int = 3,
    verbose: bool = True,
    baseline: bool = True,
) -> dict:
    import jax.numpy as jnp

    from repro.filters import fir_bit_layers_batch
    from repro.kernels.blmac_fir import blmac_fir_bank, pack_bank_trits

    qbank = _design_qbank(n_filters, taps)
    rng = np.random.default_rng(42)
    x = rng.integers(-128, 128, n_samples).astype(np.int32)
    xj = jnp.asarray(x)
    n_out = n_samples - taps + 1

    # both arms get trit encoding AND packing hoisted out of the timed region
    packed = pack_bank_trits(qbank)
    packed_single = [packed[b : b + 1] for b in range(n_filters)]

    # bit-exact check before any timing
    ref = fir_bit_layers_batch(x, qbank)[:, 0, :]
    y_bank = np.asarray(blmac_fir_bank(xj, packed, taps, tile=tile))
    if not np.array_equal(y_bank, ref):
        raise AssertionError(f"bank kernel mismatch at B={n_filters}")

    def run_batched():
        blmac_fir_bank(xj, packed, taps, tile=tile).block_until_ready()

    t_batched = _time(run_batched, repeats)
    row = {
        "bank_size": n_filters,
        "n_samples": n_samples,
        "taps": taps,
        "tile": tile,
        "outputs_per_filter": n_out,
        "batched_s": t_batched,
        "batched_samples_per_s_per_filter": n_out / t_batched,
    }
    if baseline:

        def run_per_filter():
            ys = [
                blmac_fir_bank(xj, packed_single[b], taps, tile, bank_tile=1)
                for b in range(n_filters)
            ]
            ys[-1].block_until_ready()

        t_loop = _time(run_per_filter, repeats)
        row["per_filter_s"] = t_loop
        row["per_filter_samples_per_s_per_filter"] = n_out / t_loop
        row["speedup"] = t_loop / t_batched
    if verbose:
        per = (f"  per-filter {row['per_filter_samples_per_s_per_filter']:12.0f}"
               f"  samples/s/filter  speedup {row['speedup']:.2f}x"
               if baseline else "  samples/s/filter")
        print(f"B={n_filters:4d}  batched "
              f"{row['batched_samples_per_s_per_filter']:12.0f}{per}")
    return row


def run(
    bank_sizes=BANK_SIZES,
    n_samples: int = 8192,
    repeats: int = 3,
    verbose: bool = True,
    baseline: bool = True,
) -> dict:
    import jax

    from repro.kernels.runtime import default_interpret

    rows = [
        bench_bank(b, n_samples, repeats=repeats, verbose=verbose,
                   baseline=baseline)
        for b in bank_sizes
    ]
    return {
        "benchmark": "bank_throughput",
        "backend": jax.default_backend(),
        "interpret": default_interpret(),
        "taps": TAPS,
        "tile": TILE,
        "rows": rows,
    }


def check(result: dict, committed_path: str, tolerance: float,
          min_bank: int = 16, gate: str = "throughput") -> int:
    """Fail (non-zero) if the gated metric regressed > tolerance versus
    the committed baseline.

    ``gate="throughput"`` compares absolute batched samples/s/filter —
    only meaningful on hardware comparable to where the baseline was
    recorded.  ``gate="speedup"`` compares the batched-vs-per-filter
    ratio measured within the same run, which transfers across machines
    (this is what CI uses).  Banks below ``min_bank`` are reported but
    not gated: their wall time is a few ms of pure dispatch overhead and
    too noisy for a pass/fail threshold — the batching claim lives in
    the wide-bank rows."""
    key = ("batched_samples_per_s_per_filter" if gate == "throughput"
           else "speedup")
    with open(committed_path) as f:
        committed = json.load(f)
    base = {r["bank_size"]: r for r in committed["rows"]}
    status = 0
    for row in result["rows"]:
        b = row["bank_size"]
        if b not in base:
            continue
        if b < min_bank:
            print(f"check B={b:4d}: skipped (below --min-bank={min_bank})")
            continue
        old = base[b][key]
        new = row[key]
        ratio = new / old
        flag = "OK" if ratio >= 1.0 - tolerance else "REGRESSION"
        print(f"check B={b:4d} {gate}: {new:.0f} vs committed {old:.0f} "
              f"({ratio:.2f}x) {flag}")
        if flag != "OK":
            status = 1
    return status


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="short signal for CI (no JSON rewrite)")
    ap.add_argument("--check", metavar="JSON",
                    help="compare against a committed BENCH_fir.json")
    ap.add_argument("--tolerance", type=float, default=0.2)
    ap.add_argument("--min-bank", type=int, default=16,
                    help="smallest bank size the regression gate applies to")
    ap.add_argument("--gate", choices=("throughput", "speedup"),
                    default="throughput",
                    help="metric to gate on: absolute samples/s/filter "
                         "(same-machine runs) or the machine-normalized "
                         "batched-vs-per-filter speedup (CI)")
    ap.add_argument("--out", default=OUT_PATH)
    args = ap.parse_args()
    if args.check and not os.path.exists(args.check):
        ap.error(f"baseline not found: {args.check}")  # before minutes of timing
    n_samples = 2048 if args.quick else 8192
    repeats = 1 if args.quick else 3
    # --check must measure the same signal length as the committed
    # baseline to be comparable; the throughput gate doesn't need the
    # per-filter arm, the speedup gate does
    result = run(n_samples=8192 if args.check else n_samples,
                 repeats=repeats,
                 baseline=not args.check or args.gate == "speedup")
    if args.check:
        return check(result, args.check, args.tolerance, args.min_bank,
                     args.gate)
    if not args.quick:
        with open(args.out, "w") as f:
            json.dump(result, f, indent=2)
            f.write("\n")
        print(f"wrote {os.path.normpath(args.out)}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
