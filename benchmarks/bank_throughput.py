"""Filter-bank throughput: autotuned BLMAC dispatch vs per-filter loop.

For each bank size B the benchmark designs B lowpass filters with spread
cutoffs, quantizes them to 16 bits, and measures samples/s/filter for

  * ``batched``    — the autotuned dispatch path: `autotune_bank_dispatch`
    picks (mode, tile, bank_tile, merge) per bank — the pulse-specialized
    loop for narrow banks, occupancy-grouped scheduled bank tiles for
    wide ones.  The winning configuration is recorded in the row.
  * ``dense``      — the scheduled kernel forced to merge=1 and one
    default bank tile: one matmul per bit layer, i.e. the PR-1 kernel —
    kept so the schedule's contribution is measurable on its own.
  * ``per_filter`` — a Python loop issuing one dense B=1 bank-kernel call
    per filter, trits packed and schedules planned outside the timer (the
    per-filter serving pattern the bank replaces: compiled once,
    dispatched/framed B times).

All arms are cross-checked bit-exactly against
`repro.filters.fir_bit_layers_batch` before timing.  Results land in
``BENCH_fir.json`` at the repo root — the committed copy is the perf
baseline CI regresses against — and the per-mode breakdown in
``benchmarks/out/bank_throughput_breakdown.json`` (uploaded as a CI
artifact).

Methodology note (committed-floor rule): the committed rows are the
CONSERVATIVE FLOOR — lowest speedup over repeated serial runs on the
reference machine — so the CI gate tolerance absorbs timing noise, not a
lucky fast run.  Since the sparsity-scheduled kernel the floor also
covers the B=1 row: the small-bank fast path must keep ``speedup >= 1.0``
there (the PR-1 kernel committed 0.70× — a framing-overhead regression
this file now gates against).

Usage:
  python benchmarks/bank_throughput.py                 # full: B ∈ {1,16,256}
  python benchmarks/bank_throughput.py --quick         # CI: short signal
  python benchmarks/bank_throughput.py --check BENCH_fir.json --tolerance 0.2
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

BANK_SIZES = (1, 16, 256)
TAPS = 63
TILE = 512  # per-filter baseline tile; the batched arm's tile is autotuned
OUT_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_fir.json")
BREAKDOWN_PATH = os.path.join(
    os.path.dirname(__file__), "out", "bank_throughput_breakdown.json"
)


def _design_qbank(n_filters: int, taps: int) -> np.ndarray:
    from repro.filters import spread_lowpass_qbank

    return spread_lowpass_qbank(n_filters, taps)


def _time(fn, repeats: int) -> float:
    fn()  # warm-up: compile + cache
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def bench_bank(
    n_filters: int,
    n_samples: int,
    taps: int = TAPS,
    tile: int = TILE,
    repeats: int = 3,
    verbose: bool = True,
    baseline: bool = True,
) -> dict:
    import jax.numpy as jnp

    from repro.compiler import compile_bank
    from repro.filters import fir_bit_layers_batch
    from repro.kernels.blmac_fir import (blmac_fir_bank, plan_bank_schedule,
                                         blmac_fir_specialized)
    from repro.kernels.runtime import autotune_bank_dispatch

    qbank = _design_qbank(n_filters, taps)
    rng = np.random.default_rng(42)
    x = rng.integers(-128, 128, n_samples).astype(np.int32)
    xj = jnp.asarray(x)
    n_out = n_samples - taps + 1

    # every arm reads the ONE compiled program — trit encoding, packing
    # AND schedule planning are compile-time work hoisted out of the
    # timed region, like reloading the FPGA weight memory; the autotuner
    # shares the program's schedule memo instead of re-planning
    program = compile_bank(qbank)
    packed = program.packed
    plan, schedule = autotune_bank_dispatch(
        program, channels=1, chunk_hint=n_samples
    )
    dense_schedule = program.schedule(bank_tile=None, merge=1)
    singles = [
        (packed[b : b + 1], plan_bank_schedule(packed[b : b + 1], 1, merge=1))
        for b in range(n_filters)
    ]

    ref = fir_bit_layers_batch(x, qbank)[:, 0, :]

    if plan.mode == "specialized":
        pulses = program.pulse_schedules()

        def run_batched():
            ys = [
                blmac_fir_specialized(xj, p, taps, plan.tile) for p in pulses
            ]
            ys[-1].block_until_ready()

        y_tuned = np.stack(
            [np.asarray(blmac_fir_specialized(xj, p, taps, plan.tile))[:n_out]
             for p in pulses]
        )
    else:

        def run_batched():
            blmac_fir_bank(
                xj, packed, taps, tile=plan.tile, schedule=schedule
            ).block_until_ready()

        y_tuned = np.asarray(
            blmac_fir_bank(xj, packed, taps, tile=plan.tile, schedule=schedule)
        )

    def run_dense():
        blmac_fir_bank(
            xj, packed, taps, tile=tile, schedule=dense_schedule
        ).block_until_ready()

    # bit-exact check of every arm before any timing
    if not np.array_equal(y_tuned, ref):
        raise AssertionError(f"autotuned arm mismatch at B={n_filters}")
    y_dense = np.asarray(
        blmac_fir_bank(xj, packed, taps, tile=tile, schedule=dense_schedule)
    )
    if not np.array_equal(y_dense, ref):
        raise AssertionError(f"dense arm mismatch at B={n_filters}")

    t_batched = _time(run_batched, repeats)
    t_dense = _time(run_dense, repeats)
    row = {
        "bank_size": n_filters,
        "n_samples": n_samples,
        "taps": taps,
        "tile": plan.tile,
        "mode": plan.mode,
        "bank_tile": plan.bank_tile,
        "merge": plan.merge,
        "outputs_per_filter": n_out,
        "batched_s": t_batched,
        "batched_samples_per_s_per_filter": n_out / t_batched,
        "dense_s": t_dense,
        "dense_samples_per_s_per_filter": n_out / t_dense,
        "speedup_vs_dense": t_dense / t_batched,
    }
    if n_filters == 1:
        baseline = True  # the B=1 floor gate always needs the speedup ratio
    if baseline:

        def run_per_filter():
            ys = [
                blmac_fir_bank(
                    xj, p, taps, tile, bank_tile=1, schedule=s, fast_path=False
                )
                for p, s in singles
            ]
            ys[-1].block_until_ready()

        t_loop = _time(run_per_filter, repeats)
        row["per_filter_s"] = t_loop
        row["per_filter_samples_per_s_per_filter"] = n_out / t_loop
        row["speedup"] = t_loop / t_batched
    if verbose:
        per = (f"  per-filter {row['per_filter_samples_per_s_per_filter']:12.0f}"
               f"  samples/s/filter  speedup {row['speedup']:.2f}x"
               if baseline else "  samples/s/filter")
        print(f"B={n_filters:4d} [{row['mode']:11s} tile={row['tile']:4d} "
              f"bank_tile={row['bank_tile']:3d} merge={row['merge']}] batched "
              f"{row['batched_samples_per_s_per_filter']:12.0f}{per} "
              f"(vs dense {row['speedup_vs_dense']:.2f}x)")
    return row


def run(
    bank_sizes=BANK_SIZES,
    n_samples: int = 8192,
    repeats: int = 3,
    verbose: bool = True,
    baseline: bool = True,
) -> dict:
    import jax

    from repro.kernels.runtime import default_interpret

    rows = [
        bench_bank(b, n_samples, repeats=repeats, verbose=verbose,
                   baseline=baseline)
        for b in bank_sizes
    ]
    return {
        "benchmark": "bank_throughput",
        "backend": jax.default_backend(),
        "interpret": default_interpret(),
        "taps": TAPS,
        "tile": TILE,
        "rows": rows,
    }


def write_breakdown(result: dict, path: str = BREAKDOWN_PATH) -> None:
    """Per-mode rows for the CI artifact: one entry per (bank, arm)."""
    rows = []
    for r in result["rows"]:
        for arm in ("batched", "dense", "per_filter"):
            key = f"{arm}_s"
            if key not in r:
                continue
            rows.append({
                "bank_size": r["bank_size"],
                "arm": arm,
                "mode": r["mode"] if arm == "batched" else
                        ("scheduled/merge=1" if arm == "dense"
                         else "dense/bank_tile=1 loop"),
                "seconds": r[key],
                "samples_per_s_per_filter":
                    r["outputs_per_filter"] / r[key],
            })
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        json.dump({"benchmark": "bank_throughput_breakdown",
                   "taps": result["taps"], "rows": rows}, f, indent=2)
        f.write("\n")


def check(result: dict, committed_path: str, tolerance: float,
          min_bank: int = 16, gate: str = "throughput") -> int:
    """Fail (non-zero) if the gated metric regressed > tolerance versus
    the committed baseline.

    ``gate="throughput"`` compares absolute batched samples/s/filter —
    only meaningful on hardware comparable to where the baseline was
    recorded.  ``gate="speedup"`` compares the batched-vs-per-filter
    ratio measured within the same run, which transfers across machines
    (this is what CI uses).  Banks below ``min_bank`` are exempt from
    the *ratio* gate (their wall time is a few ms of dispatch overhead,
    too noisy for a relative threshold) — EXCEPT the B=1 row, which is
    gated on the absolute floor ``speedup >= 1.0``: the small-bank fast
    path must never be slower than the per-filter loop it replaces."""
    key = ("batched_samples_per_s_per_filter" if gate == "throughput"
           else "speedup")
    with open(committed_path) as f:
        committed = json.load(f)
    base = {r["bank_size"]: r for r in committed["rows"]}
    status = 0
    for row in result["rows"]:
        b = row["bank_size"]
        if b == 1 and "speedup" in row:
            flag = "OK" if row["speedup"] >= 1.0 else "REGRESSION"
            print(f"check B={b:4d} fast-path floor: speedup "
                  f"{row['speedup']:.2f}x >= 1.00x required  {flag}")
            if flag != "OK":
                status = 1
        if b not in base:
            continue
        if b < min_bank and b != 1:
            print(f"check B={b:4d}: skipped (below --min-bank={min_bank})")
            continue
        if b < min_bank:
            continue  # B=1 already gated on the absolute floor above
        old = base[b][key]
        new = row[key]
        ratio = new / old
        flag = "OK" if ratio >= 1.0 - tolerance else "REGRESSION"
        print(f"check B={b:4d} {gate}: {new:.0f} vs committed {old:.0f} "
              f"({ratio:.2f}x) {flag}")
        if flag != "OK":
            status = 1
    return status


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="short signal for CI (no JSON rewrite)")
    ap.add_argument("--check", metavar="JSON",
                    help="compare against a committed BENCH_fir.json")
    ap.add_argument("--tolerance", type=float, default=0.2)
    ap.add_argument("--min-bank", type=int, default=16,
                    help="smallest bank size the relative regression gate "
                         "applies to (B=1 is always gated on speedup>=1)")
    ap.add_argument("--gate", choices=("throughput", "speedup"),
                    default="throughput",
                    help="metric to gate on: absolute samples/s/filter "
                         "(same-machine runs) or the machine-normalized "
                         "batched-vs-per-filter speedup (CI)")
    ap.add_argument("--out", default=OUT_PATH)
    args = ap.parse_args()
    if args.check and not os.path.exists(args.check):
        ap.error(f"baseline not found: {args.check}")  # before minutes of timing
    n_samples = 2048 if args.quick else 8192
    repeats = 1 if args.quick else 3
    # --check must measure the same signal length as the committed
    # baseline to be comparable; the throughput gate doesn't need the
    # per-filter arm, the speedup gate (and the B=1 floor) does
    result = run(n_samples=8192 if args.check else n_samples,
                 repeats=repeats,
                 baseline=not args.check or args.gate == "speedup")
    write_breakdown(result)
    if args.check:
        return check(result, args.check, args.tolerance, args.min_bank,
                     args.gate)
    if not args.quick:
        with open(args.out, "w") as f:
            json.dump(result, f, indent=2)
            f.write("\n")
        print(f"wrote {os.path.normpath(args.out)}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
