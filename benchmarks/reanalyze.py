"""Re-derive roofline JSONs from the saved (gzipped) HLO — lets analyzer
improvements update §Roofline without recompiling 68 cells."""
import glob
import gzip
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
from repro.roofline.hlo_analysis import analyze_hlo  # noqa: E402

PEAK, HBM_BW, ICI_BW = 197e12, 819e9, 50e9
OUT = os.path.join(os.path.dirname(__file__), "out", "dryrun")

for jf in sorted(glob.glob(os.path.join(OUT, "*.json"))):
    hf = jf[:-5] + ".hlo.txt.gz"
    if not os.path.exists(hf):
        continue
    r = json.load(open(jf))
    cost = analyze_hlo(gzip.open(hf, "rt").read())
    r["hlo_flops_per_dev"] = cost.flops
    r["hlo_hbm_bytes_per_dev"] = cost.hbm_bytes
    r["collective_bytes_per_dev"] = cost.total_coll_bytes
    r["collectives"] = cost.coll_bytes
    r["collective_counts"] = cost.coll_counts
    r["hbm_by_op"] = dict(sorted(cost.hbm_by_op.items(), key=lambda kv: -kv[1])[:12])
    r["compute_term_s"] = cost.flops / PEAK
    r["memory_term_s"] = cost.hbm_bytes / HBM_BW
    r["collective_term_s"] = cost.total_coll_bytes / ICI_BW
    terms = {"compute": r["compute_term_s"], "memory": r["memory_term_s"],
             "collective": r["collective_term_s"]}
    r["dominant"] = max(terms, key=terms.get)
    r["useful_flops_ratio"] = (r["model_flops_per_dev"] / cost.flops
                               if cost.flops else 0.0)
    json.dump(r, open(jf, "w"), indent=1)
    print(os.path.basename(jf), "->", r["dominant"],
          f"c={r['compute_term_s']:.3f} m={r['memory_term_s']:.3f} "
          f"x={r['collective_term_s']:.3f}")
