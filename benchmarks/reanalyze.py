"""Re-derive analysis columns of committed artifacts without re-measuring.

Two sections:

* **dryrun** — re-derive the §Roofline JSONs from the saved (gzipped)
  HLO — lets analyzer improvements update the table without recompiling
  68 cells.
* **compiled** — re-derive `BENCH_compiled.json`'s roofline columns
  (`roofline_us`, `roofline_utilization`, `compiled_speedup`,
  `best_arm`) from the stored raw values — HLO FLOP/byte counts and
  measured peaks — so a formula change does not require re-timing the
  arms on the reference box.
* **cse** — re-derive `BENCH_cse.json`'s reduction and ratio columns
  (`adds_per_filter_*`, `adds_reduction`, `pulse_reduction`,
  `cycle_reduction`, `throughput_ratio`, `forced_ratio`) from the
  stored raw totals and per-arm seconds (same formulas as
  `bank_cse.derive_sweep` / `derive_throughput`).
"""
import glob
import gzip
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
from repro.roofline.hlo_analysis import analyze_hlo  # noqa: E402

PEAK, HBM_BW, ICI_BW = 197e12, 819e9, 50e9
OUT = os.path.join(os.path.dirname(__file__), "out", "dryrun")
BENCH_COMPILED = os.path.join(
    os.path.dirname(__file__), "..", "BENCH_compiled.json"
)
BENCH_CSE = os.path.join(os.path.dirname(__file__), "..", "BENCH_cse.json")


def reanalyze_dryrun() -> None:
    for jf in sorted(glob.glob(os.path.join(OUT, "*.json"))):
        hf = jf[:-5] + ".hlo.txt.gz"
        if not os.path.exists(hf):
            continue
        r = json.load(open(jf))
        cost = analyze_hlo(gzip.open(hf, "rt").read())
        r["hlo_flops_per_dev"] = cost.flops
        r["hlo_hbm_bytes_per_dev"] = cost.hbm_bytes
        r["collective_bytes_per_dev"] = cost.total_coll_bytes
        r["collectives"] = cost.coll_bytes
        r["collective_counts"] = cost.coll_counts
        r["hbm_by_op"] = dict(
            sorted(cost.hbm_by_op.items(), key=lambda kv: -kv[1])[:12]
        )
        r["compute_term_s"] = cost.flops / PEAK
        r["memory_term_s"] = cost.hbm_bytes / HBM_BW
        r["collective_term_s"] = cost.total_coll_bytes / ICI_BW
        terms = {"compute": r["compute_term_s"], "memory": r["memory_term_s"],
                 "collective": r["collective_term_s"]}
        r["dominant"] = max(terms, key=terms.get)
        r["useful_flops_ratio"] = (r["model_flops_per_dev"] / cost.flops
                                   if cost.flops else 0.0)
        json.dump(r, open(jf, "w"), indent=1)
        print(os.path.basename(jf), "->", r["dominant"],
              f"c={r['compute_term_s']:.3f} m={r['memory_term_s']:.3f} "
              f"x={r['collective_term_s']:.3f}")


def reanalyze_compiled(path: str = BENCH_COMPILED) -> None:
    """Recompute BENCH_compiled.json's derived roofline columns from its
    stored raw measurements (same formula as bank_compiled.run)."""
    if not os.path.exists(path):
        return
    r = json.load(open(path))
    t_interp = next(
        row["seconds"] for row in r["rows"] if row["lane"] == "interpret"
    )
    for row in r["rows"]:
        row["speedup_vs_interpret"] = t_interp / row["seconds"]
        if row.get("hlo_flops") is None:
            row["roofline_us"] = None
            row["roofline_utilization"] = None
            continue
        f32 = row.get("hlo_f32_flops", 0.0)
        int_flops = max(row["hlo_flops"] - f32, 0.0)
        compute_s = (f32 / r["peak_f32_flops"]
                     + int_flops / r["peak_int32_flops"])
        row["roofline_us"] = max(
            compute_s, row["hlo_hbm_bytes"] / r["peak_hbm_bytes_per_s"]
        ) * 1e6
        row["roofline_utilization"] = (
            row["roofline_us"] / (row["seconds"] * 1e6)
        )
    best = max((row for row in r["rows"] if row["lane"] != "interpret"),
               key=lambda row: row["speedup_vs_interpret"])
    r["compiled_speedup"] = best["speedup_vs_interpret"]
    r["best_arm"] = best["arm"]
    with open(path, "w") as f:
        json.dump(r, f, indent=2)
        f.write("\n")
    print(os.path.basename(path), "->",
          f"best={r['best_arm']} {r['compiled_speedup']:.2f}x")


def reanalyze_cse(path: str = BENCH_CSE) -> None:
    """Recompute BENCH_cse.json's derived reduction/ratio columns from
    its stored raw totals (same formulas as bank_cse.run)."""
    if not os.path.exists(path):
        return
    from benchmarks.bank_cse import derive_sweep, derive_throughput

    r = json.load(open(path))
    s = r["sweep"]
    s.update(derive_sweep(
        s["total_adds_parent"], s["total_adds_optimized"], s["n_filters"],
        s["total_pulses_parent"], s["total_pulses_optimized"],
        s["mean_cycles_parent"], s["mean_cycles_optimized"],
    ))
    tp = r["throughput"]
    for row in tp["rows"]:
        row["samples_per_s_per_filter"] = (
            (tp["n_samples"] - tp["taps"] + 1) / row["seconds"]
        )
        row["ratio_vs_baseline"] = (
            tp["rows"][0]["seconds"] / row["seconds"]
        )
    tp.update(derive_throughput(tp["rows"]))
    with open(path, "w") as f:
        json.dump(r, f, indent=2)
        f.write("\n")
    print(os.path.basename(path), "->",
          f"adds_reduction={100 * s['adds_reduction']:.1f}% "
          f"throughput_ratio={tp['throughput_ratio']:.2f}x")


if __name__ == "__main__":
    reanalyze_dryrun()
    reanalyze_compiled()
    reanalyze_cse()
