"""Multi-tenant session serving vs dedicated per-session engines.

Each row serves ``n_sessions`` concurrent tenant streams — every session
selecting its own 4-filter slice of one compiled 256-filter lowpass bank
— two ways:

  * **shared**    — ONE `repro.serving.BankSessionServer` over the bank:
    all sessions' chunks are continuously batched into ``n_slots``
    shared channel lanes, ceil(n_sessions / n_slots) dispatches per
    round instead of one per tenant.
  * **dedicated** — the PR 6 shape scaled naively: one
    `FilterBankEngine` per session over the SAME `BlmacProgram`
    (construction is a content-addressed cache hit), one dispatch per
    tenant per chunk.

Both arms run identical kernel arithmetic per stream (the full bank per
lane, sliced to the session's rows), so the measured gap is pure
dispatch amortization — the thing the session layer exists to buy.
Every session's shared-arm stream is verified bit-exact against its
dedicated-arm stream BEFORE the row is reported: a fast-but-wrong
batcher is an assertion failure, not a good number.

Reported per row: aggregate output samples/s across all sessions, and
p50/p99 per-chunk latency (shared: push-to-resolved queue latency from
`serve_stats()`; dedicated: per-push wall time).

The committed ``BENCH_serve.json`` records the shared/dedicated speedup;
the CI gate (`--fast --check`) enforces BOTH floors on the same-run
ratio — shared must beat dedicated (> ``MIN_SPEEDUP``x), and must stay
within ``--tolerance`` of the committed speedup.  Same-run ratios cancel
host drift, so the gate is meaningful on any runner.

Usage:
  python benchmarks/bank_serve.py                    # full run, writes JSON
  python benchmarks/bank_serve.py --fast --check BENCH_serve.json  # CI gate
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

BANK_SIZE = 256
TAPS = 63
ROWS_PER_SESSION = 4
MIN_SPEEDUP = 1.0  # hard floor: shared-slot serving must beat dedicated
# (n_sessions, n_slots) grid — 64 tenants is the committed headline row
GRID = ((64, 8), (64, 16))
FAST_GRID = ((64, 16),)
OUT_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_serve.json")
ARTIFACT_PATH = os.path.join(
    os.path.dirname(__file__), "out", "bank_serve_sessions.json"
)


def _pct(samples, q) -> float:
    return float(np.percentile(np.asarray(samples), q)) * 1e3


def _run_row(n_sessions: int, n_slots: int, n_chunks: int,
             chunk: int) -> dict:
    from repro.filters import FilterBankEngine, spread_lowpass_qbank
    from repro.compiler import compile_bank
    from repro.serving import BankSessionServer

    program = compile_bank(spread_lowpass_qbank(BANK_SIZE, TAPS))
    rng = np.random.default_rng(n_sessions * 1000 + n_slots)
    sels = [
        np.arange(i * ROWS_PER_SESSION, (i + 1) * ROWS_PER_SESSION)
        % BANK_SIZE
        for i in range(n_sessions)
    ]
    streams = [
        rng.integers(-128, 128, (n_chunks + 1) * chunk).astype(np.int32)
        for _ in range(n_sessions)
    ]

    # -- shared arm: one server, n_slots lanes, batched steps ---------------
    srv = BankSessionServer(
        program, n_slots=n_slots, chunk_hint=chunk, auto_step=False
    )
    sessions = [srv.open_session(sel) for sel in sels]
    shared_out = [[] for _ in range(n_sessions)]

    def shared_round(k: int) -> None:
        for i, s in enumerate(sessions):
            s.push(streams[i][k * chunk: (k + 1) * chunk])
        srv.step()
        for i, s in enumerate(sessions):
            shared_out[i].append(s.pull())

    shared_round(0)  # warm the jit/autotune caches off the clock
    warm_samples = srv.samples_out
    t0 = time.perf_counter()
    for k in range(1, n_chunks + 1):
        shared_round(k)
    shared_s = time.perf_counter() - t0
    shared_samples = srv.samples_out - warm_samples
    stats = srv.serve_stats()

    # -- dedicated arm: one engine per session over the same program --------
    engines = [
        FilterBankEngine(program, channels=1, chunk_hint=chunk)
        for _ in range(n_sessions)
    ]
    ded_out = [[] for _ in range(n_sessions)]
    push_s = []

    def dedicated_round(k: int, timed: bool) -> int:
        produced = 0
        for i, eng in enumerate(engines):
            t = time.perf_counter()
            y = eng.push(streams[i][None, k * chunk: (k + 1) * chunk])
            if timed:
                push_s.append(time.perf_counter() - t)
            out = y[sels[i], 0]
            produced += out.shape[1]
            ded_out[i].append(out)
        return produced

    dedicated_round(0, timed=False)  # same off-the-clock warm-up
    t0 = time.perf_counter()
    ded_samples = 0
    for k in range(1, n_chunks + 1):
        ded_samples += dedicated_round(k, timed=True)
    ded_s = time.perf_counter() - t0

    # -- bit-exactness gate BEFORE any number is reported -------------------
    for i in range(n_sessions):
        got = np.concatenate(shared_out[i], axis=1)
        want = np.concatenate(ded_out[i], axis=1)
        if not np.array_equal(got, want):
            raise AssertionError(
                f"shared session {i} != dedicated engine "
                f"(sessions={n_sessions}, slots={n_slots})"
            )
    if shared_samples != ded_samples:
        raise AssertionError("arms produced different sample counts")

    shared_rate = shared_samples / shared_s
    ded_rate = ded_samples / ded_s
    return {
        "n_sessions": n_sessions,
        "n_slots": n_slots,
        "bank_size": BANK_SIZE,
        "rows_per_session": ROWS_PER_SESSION,
        "taps": TAPS,
        "chunk_samples": chunk,
        "n_chunks": n_chunks,
        "occupancy": stats["occupancy"],
        "shared": {
            "samples_per_s": shared_rate,
            "latency_p50_ms": stats["latency_p50_ms"],
            "latency_p99_ms": stats["latency_p99_ms"],
            "dispatch_rounds": stats["rounds"],
        },
        "dedicated": {
            "samples_per_s": ded_rate,
            "latency_p50_ms": _pct(push_s, 50),
            "latency_p99_ms": _pct(push_s, 99),
            "dispatches": n_sessions * n_chunks,
        },
        "speedup": shared_rate / ded_rate,
    }


def run(grid=GRID, n_chunks: int = 6, chunk: int = 512,
        verbose: bool = True) -> dict:
    import jax

    from repro.kernels.runtime import default_interpret

    rows = []
    for n_sessions, n_slots in grid:
        row = _run_row(n_sessions, n_slots, n_chunks, chunk)
        rows.append(row)
        if verbose:
            print(f"sessions={n_sessions:3d} slots={n_slots:3d}  shared "
                  f"{row['shared']['samples_per_s']:10.0f} samp/s "
                  f"(p50 {row['shared']['latency_p50_ms']:6.1f} ms, p99 "
                  f"{row['shared']['latency_p99_ms']:6.1f} ms)  dedicated "
                  f"{row['dedicated']['samples_per_s']:10.0f} samp/s  "
                  f"speedup {row['speedup']:.2f}x")
    return {
        "benchmark": "bank_serve",
        "backend": jax.default_backend(),
        "interpret": default_interpret(),
        "min_speedup": MIN_SPEEDUP,
        "rows": rows,
        "note": (
            "shared = one BankSessionServer batching all sessions into "
            "n_slots lanes; dedicated = one FilterBankEngine per session "
            "over the same BlmacProgram; both arms run identical kernel "
            "arithmetic and every session is verified bit-exact "
            "shared-vs-dedicated before the row is reported, so speedup "
            "is pure dispatch amortization; the CI gate is same-run "
            "(shared vs dedicated measured in one process), so host "
            "speed cancels"
        ),
    }


def write_artifact(result: dict, path: str = ARTIFACT_PATH) -> None:
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        json.dump(result, f, indent=2)
        f.write("\n")


def check(result: dict, committed_path: str, tolerance: float) -> int:
    """Gate: every measured row's shared arm beats its dedicated arm
    (> MIN_SPEEDUP, the acceptance floor), and stays within ``tolerance``
    of the committed speedup for the same (sessions, slots) row."""
    with open(committed_path) as f:
        committed = json.load(f)
    if not result["rows"]:
        print("check FAILED: no rows ran")
        return 1
    base = {(r["n_sessions"], r["n_slots"]): r for r in committed["rows"]}
    status = 0
    for row in result["rows"]:
        key = (row["n_sessions"], row["n_slots"])
        sp = row["speedup"]
        flag = "OK" if sp > MIN_SPEEDUP else "REGRESSION"
        print(f"check sessions={key[0]} slots={key[1]} speedup {sp:.2f}x "
              f"> floor {MIN_SPEEDUP:.2f}x  {flag}")
        if flag != "OK":
            status = 1
        if key in base:
            old = base[key]["speedup"]
            floor = old / (1.0 + tolerance)
            flag = "OK" if sp >= floor else "REGRESSION"
            print(f"check sessions={key[0]} slots={key[1]} vs committed "
                  f"{old:.2f}x (allowed >= {floor:.2f}x)  {flag}")
            if flag != "OK":
                status = 1
    return status


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--fast", action="store_true",
                    help="reduced grid + shorter streams (CI; no JSON "
                         "rewrite)")
    ap.add_argument("--check", metavar="JSON",
                    help="compare against a committed BENCH_serve.json")
    ap.add_argument("--tolerance", type=float, default=1.0,
                    help="allowed shortfall vs the committed speedup "
                         "(speedup >= committed / (1 + tolerance))")
    ap.add_argument("--out", default=OUT_PATH)
    args = ap.parse_args()
    if args.check and not os.path.exists(args.check):
        ap.error(f"baseline not found: {args.check}")
    grid = FAST_GRID if args.fast else GRID
    n_chunks = 3 if args.fast else 6
    chunk = 256 if args.fast else 512
    result = run(grid=grid, n_chunks=n_chunks, chunk=chunk)
    write_artifact(result)
    if args.check:
        return check(result, args.check, args.tolerance)
    if not args.fast:
        with open(args.out, "w") as f:
            json.dump(result, f, indent=2)
            f.write("\n")
        print(f"wrote {os.path.normpath(args.out)}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
