"""Cross-filter CSE pass: adds-per-filter reduction AND B=256 throughput.

Two arms, one committed artifact (``BENCH_cse.json``):

* **sweep** — the paper's §3.3/Table-4 accounting metric on the full
  9,900-filter 127-tap Hamming sweep grid (`table4_machine`'s bank):
  the grid is compiled once, `repro.compiler.cse_pass`-optimized, and
  the §3.3 adds-per-filter and §4 machine-cycle predictions of parent
  vs optimized program are compared.  Pure accounting — no timing — so
  the reduction is exact, deterministic and machine-independent (the
  optimized cycle column amortizes each shared virtual row once per
  bank and charges one cycle per combine use; it is priced at the
  widened ``coeff_bits = n_layers + 1`` spec the augmented rows need).

* **throughput** — the compiled-lane no-regression gate on the B=256
  reference bank (63 taps, spread lowpass cutoffs, the
  `bank_compiled` geometry).  Three interleaved arms, every one
  verified bit-exact against `fir_bit_layers_batch` before timing:

    - ``baseline``    — the parent's autotuned compiled dispatch,
    - ``cse-auto``    — the autotuned dispatch for the OPTIMIZED
      program: `autotune_bank_dispatch` prices the combine stage
      (`predict_combine_us`) against the parent's own plan and may
      *decline* the shared-row layout (``plan.cse == "declined"``) —
      the honest mechanism behind the no-regression guarantee, since
      a dense superlayer GEMM's cost scales with ROWS and the
      augmented bank has more of them,
    - ``cse-forced``  — the shared-row layout forced onto the compiled
      lane (informational: what declining saved).

The CI gate (``--check``) enforces the acceptance floors: mean
adds-per-filter reduction ``>= --floor-adds`` (default 10%) on the
sweep grid, same-run ``baseline/cse-auto`` throughput ratio
``>= --floor-throughput`` (default 0.90 — no regression beyond runner
noise; when the autotuner declines, both arms run the identical parent
plan), plus a tolerance band against the committed reduction.

Usage:
  python benchmarks/bank_cse.py                  # full run, writes JSON
  python benchmarks/bank_cse.py --fast           # CI smoke sizes
  python benchmarks/bank_cse.py --fast --check BENCH_cse.json
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

BANK_SIZE = 256
TAPS = 63
SWEEP_TAPS = 127
OUT_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_cse.json")
BREAKDOWN_PATH = os.path.join(
    os.path.dirname(__file__), "out", "bank_cse_breakdown.json"
)


def run_sweep(n_div: int = 100, verbose: bool = True) -> dict:
    """The accounting arm: §3.3 adds and §4 cycles, parent vs optimized,
    over the Table-4 sweep grid."""
    from benchmarks.table4_machine import design_quantized_bank
    from repro.compiler import compile_bank, cse_pass
    from repro.core import MachineSpec

    qbank = design_quantized_bank(n_div)
    parent = compile_bank(qbank)
    n_filters = parent.n_filters
    t0 = time.perf_counter()
    opt = cse_pass(parent)
    mine_s = time.perf_counter() - t0

    adds_parent = parent.total_adds()
    adds_opt = opt.total_adds()
    pulses_parent = int(parent.pulse_counts.sum())
    pulses_opt = int(opt.pulse_counts.sum())
    cyc_parent = float(
        parent.machine_cycles(MachineSpec(taps=SWEEP_TAPS)).mean()
    )
    if opt is parent:  # the pass declined entirely (degenerate grid)
        n_shared, cyc_opt = 0, cyc_parent
    else:
        assert np.array_equal(opt.effective_qbank(), parent.qbank), \
            "sweep arm: optimized program is not bit-equivalent"
        n_shared = opt.n_shared
        # real-row cycles (incl. one per combine use) + each shared
        # virtual row amortized ONCE per bank per output sample
        cyc_opt = float(
            (opt.machine_cycles().sum() + opt.shared_cycles().sum())
            / opt.n_real
        )
    sweep = {
        "n_filters": n_filters,
        "taps": SWEEP_TAPS,
        "n_div": n_div,
        "n_shared": n_shared,
        "mine_seconds": mine_s,
        "total_adds_parent": adds_parent,
        "total_adds_optimized": adds_opt,
        "total_pulses_parent": pulses_parent,
        "total_pulses_optimized": pulses_opt,
        "mean_cycles_parent": cyc_parent,
        "mean_cycles_optimized": cyc_opt,
        **derive_sweep(adds_parent, adds_opt, n_filters,
                       pulses_parent, pulses_opt, cyc_parent, cyc_opt),
    }
    if verbose:
        print(f"sweep B={n_filters} taps={SWEEP_TAPS}: "
              f"adds/filter {sweep['adds_per_filter_parent']:.1f} -> "
              f"{sweep['adds_per_filter_optimized']:.1f} "
              f"({100 * sweep['adds_reduction']:.1f}% saved, "
              f"{n_shared} shared rows, mined in {mine_s:.2f}s); "
              f"cycles {cyc_parent:.1f} -> {cyc_opt:.1f} "
              f"({100 * sweep['cycle_reduction']:.1f}%)")
    return sweep


def derive_sweep(adds_parent, adds_opt, n_filters, pulses_parent,
                 pulses_opt, cyc_parent, cyc_opt) -> dict:
    """Derived reduction columns from the raw totals (shared with
    `benchmarks.reanalyze.reanalyze_cse`)."""
    return {
        "adds_per_filter_parent": adds_parent / n_filters,
        "adds_per_filter_optimized": adds_opt / n_filters,
        "adds_reduction": 1.0 - adds_opt / adds_parent,
        "pulse_reduction": 1.0 - pulses_opt / pulses_parent,
        "cycle_reduction": 1.0 - cyc_opt / cyc_parent,
    }


def _interleaved_times(arms: dict, repeats: int) -> dict:
    """Fastest wall time per arm, arms interleaved with rotating start."""
    for fn in arms.values():
        fn()  # warm-up: compile + stage operands
    names = list(arms)
    best = {name: float("inf") for name in names}
    for r in range(repeats):
        for name in names[r % len(names):] + names[: r % len(names)]:
            t0 = time.perf_counter()
            arms[name]()
            best[name] = min(best[name], time.perf_counter() - t0)
    return best


def run_throughput(n_samples: int = 16384, repeats: int = 3,
                   verbose: bool = True, n_filters: int = BANK_SIZE,
                   taps: int = TAPS) -> dict:
    import jax.numpy as jnp

    from repro.compiler import compile_bank, cse_pass
    from repro.filters import fir_bit_layers_batch, spread_lowpass_qbank
    from repro.kernels.blmac_fir import blmac_fir_bank
    from repro.kernels.runtime import autotune_bank_dispatch, resolve_lane

    qbank = spread_lowpass_qbank(n_filters, taps)
    parent = compile_bank(qbank)
    opt = cse_pass(parent)
    rng = np.random.default_rng(42)
    x = rng.integers(-128, 128, n_samples).astype(np.int32)
    xj = jnp.asarray(x)
    n_out = n_samples - taps + 1
    ref = fir_bit_layers_batch(x, qbank)[:, 0, :]

    lane = resolve_lane(True)  # this host's compiled lane
    plan_b, sched_b = autotune_bank_dispatch(
        parent, chunk_hint=n_samples, compiled=lane
    )
    plan_a, sched_a = autotune_bank_dispatch(
        opt, chunk_hint=n_samples, compiled=lane
    )

    def make_arm(prog, schedule, plan, combine, n_real):
        def f():
            blmac_fir_bank(
                xj, prog.packed, taps, tile=plan.tile, schedule=schedule,
                fast_path=False, lane=plan.lane, combine=combine,
                n_real=n_real,
            ).block_until_ready()
        return f

    def verify(prog, schedule, plan, combine, n_real, name):
        y = np.asarray(blmac_fir_bank(
            xj, prog.packed, taps, tile=plan.tile, schedule=schedule,
            fast_path=False, lane=plan.lane, combine=combine, n_real=n_real,
        ))[..., :n_out]
        if not np.array_equal(y, ref):
            raise AssertionError(f"arm {name} is not bit-exact")

    arms, rows = {}, []

    def add_arm(name, prog, schedule, plan, combine=None, n_real=None,
                **extra):
        verify(prog, schedule, plan, combine, n_real, name)
        arms[name] = make_arm(prog, schedule, plan, combine, n_real)
        rows.append({
            "arm": name, "lane": plan.lane, "merge": plan.merge,
            "bank_tile": plan.bank_tile, "tile": plan.tile,
            "rows_executed": prog.n_filters, **extra,
        })

    add_arm("baseline", parent, sched_b, plan_b)
    if opt is parent or plan_a.cse == "declined":
        # the autotuner rejected the shared-row layout: the auto arm
        # executes the PARENT plan (what an engine would actually do)
        add_arm("cse-auto", parent, sched_a, plan_a, cse=plan_a.cse)
    else:
        add_arm("cse-auto", opt, sched_a, plan_a, combine=opt.combine,
                n_real=opt.n_real, cse=plan_a.cse)
    if opt is not parent:
        # the shared-row layout forced onto the compiled lane at the
        # baseline's autotuned merge (informational)
        sched_f = opt.schedule(None, plan_b.merge)
        add_arm("cse-forced", opt, sched_f, plan_b,
                combine=opt.combine, n_real=opt.n_real, cse="forced")

    times = _interleaved_times(arms, repeats)
    t_base = times["baseline"]
    for row in rows:
        t = times[row["arm"]]
        row["seconds"] = t
        row["samples_per_s_per_filter"] = n_out / t
        row["ratio_vs_baseline"] = t_base / t
        if verbose:
            print(f"{row['arm']:12s} {t * 1e3:9.2f} ms  "
                  f"{row['samples_per_s_per_filter']:12.0f} "
                  f"samples/s/filter  "
                  f"({row['ratio_vs_baseline']:.2f}x baseline)"
                  + (f"  [{row['cse']}]" if "cse" in row else ""))

    out = {
        "bank_size": n_filters,
        "taps": taps,
        "n_samples": n_samples,
        "lane": lane,
        "auto_cse": plan_a.cse if opt is not parent else "",
        "n_shared": 0 if opt is parent else opt.n_shared,
        "rows": rows,
        **derive_throughput(rows),
    }
    return out


def derive_throughput(rows) -> dict:
    """Derived ratio columns from the per-arm seconds (shared with
    `benchmarks.reanalyze.reanalyze_cse`)."""
    t = {row["arm"]: row["seconds"] for row in rows}
    out = {"throughput_ratio": t["baseline"] / t["cse-auto"]}
    if "cse-forced" in t:
        out["forced_ratio"] = t["baseline"] / t["cse-forced"]
    return out


def run(n_div: int = 100, n_samples: int = 16384, repeats: int = 3,
        verbose: bool = True) -> dict:
    import jax

    sweep = run_sweep(n_div, verbose=verbose)
    throughput = run_throughput(n_samples, repeats, verbose=verbose)
    return {
        "benchmark": "bank_cse",
        "backend": jax.default_backend(),
        "sweep": sweep,
        "throughput": throughput,
    }


def write_breakdown(result: dict, path: str = BREAKDOWN_PATH) -> None:
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        json.dump(result, f, indent=2)
        f.write("\n")


def check(result: dict, committed_path: str, tolerance: float,
          floor_adds: float, floor_throughput: float) -> int:
    """Fail (non-zero) unless the adds-per-filter reduction clears the
    acceptance floor and stays within ``tolerance`` of the committed
    value, AND the same-run autotuned throughput does not regress."""
    with open(committed_path) as f:
        committed = json.load(f)
    status = 0

    red = result["sweep"]["adds_reduction"]
    flag = "OK" if red >= floor_adds else "REGRESSION"
    print(f"check adds reduction: {100 * red:.1f}% >= "
          f"{100 * floor_adds:.1f}% required  {flag}")
    if flag != "OK":
        status = 1
    old = committed["sweep"]["adds_reduction"]
    flag = "OK" if red >= old - tolerance else "REGRESSION"
    print(f"check adds reduction vs committed: {100 * red:.1f}% vs "
          f"{100 * old:.1f}% (tolerance {100 * tolerance:.1f}pt)  {flag}")
    if flag != "OK":
        status = 1

    ratio = result["throughput"]["throughput_ratio"]
    flag = "OK" if ratio >= floor_throughput else "REGRESSION"
    print(f"check B={result['throughput']['bank_size']} throughput: "
          f"cse-auto at {ratio:.2f}x baseline >= {floor_throughput:.2f}x "
          f"required  {flag}")
    if flag != "OK":
        status = 1
    return status


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--fast", action="store_true",
                    help="CI smoke sizes (no JSON rewrite)")
    ap.add_argument("--check", metavar="JSON",
                    help="compare against a committed BENCH_cse.json")
    ap.add_argument("--tolerance", type=float, default=0.05,
                    help="allowed adds-reduction drop vs committed "
                         "(absolute percentage points / 100)")
    ap.add_argument("--floor-adds", type=float, default=0.10,
                    help="minimum mean adds-per-filter reduction on the "
                         "sweep grid (the PR acceptance bar)")
    ap.add_argument("--floor-throughput", type=float, default=0.90,
                    help="minimum same-run cse-auto/baseline throughput "
                         "ratio at B=256.  When the autotuner declines "
                         "(the common verdict on dense GEMM lanes) both "
                         "arms run the IDENTICAL parent plan, so the true "
                         "ratio is 1.0 and the band only absorbs runner "
                         "noise; a real regression — the autotuner "
                         "wrongly forcing the shared-row layout, or the "
                         "combine epilogue slowing the winning plan — "
                         "lands far below it")
    ap.add_argument("--out", default=OUT_PATH)
    args = ap.parse_args()
    if args.check and not os.path.exists(args.check):
        ap.error(f"baseline not found: {args.check}")
    n_div = 20 if args.fast else 100
    n_samples = 8192 if args.fast else 16384
    # arms are ms-scale: generous repeats cost little and keep the
    # near-1.0 declined-arm ratio out of the noise floor
    repeats = 6 if args.fast else 8
    result = run(n_div=n_div, n_samples=n_samples, repeats=repeats)
    write_breakdown(result)
    if args.check:
        return check(result, args.check, args.tolerance,
                     args.floor_adds, args.floor_throughput)
    if not args.fast:
        with open(args.out, "w") as f:
            json.dump(result, f, indent=2)
            f.write("\n")
        print(f"wrote {os.path.normpath(args.out)}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
