"""Fault-recovery latency of the sharded filter-bank service.

Each row streams a lowpass bank through `ShardedFilterBankEngine` behind
`AsyncBankServer` on an (n, 1) forced-host-device mesh, kills one bank
shard mid-stream with a deterministic
`repro.distributed.faultbank.FaultInjector`, and measures what the
recovery path costs:

  * ``recovery_s``     — detection → recovered-mesh wall time (the
    engine's ``last_recovery_s``: drop the dead row, cost-model the
    re-partition, rebuild the dispatch closures, replay every in-flight
    chunk from its tail snapshot),
  * ``stall_s``        — the worst single ``submit``/``drain`` step of
    the faulted stream (the one that absorbed detection + recovery),
    next to the median step as the no-fault reference,
  * ``replayed_chunks`` / ``replayed_samples`` — the deterministic
    replay volume behind bit-exactness.

Every row is verified bit-exact against the numpy oracle BEFORE its
numbers are reported: a recovery that loses or corrupts samples is an
assertion failure, not a slow row.

The committed ``BENCH_fault.json`` is the smoke baseline CI gates
against.  Wall-clock recovery latency is host-speed dependent (it
re-runs the mesh autotuner), so the gate is deliberately loose: every
row must (a) recover bit-exactly with the expected counters, (b) keep
``recovery_s`` under the absolute smoke ceiling, and (c) stay within
``--tolerance`` (a multiple, default 4x) of the committed latency.

Usage:
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      python benchmarks/bank_fault.py                # full run, writes JSON
  ... bank_fault.py --fast --check BENCH_fault.json  # CI gate
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

TAPS = 63
KILL_SHARD = 1
KILL_CHUNK = 3
RECOVERY_CEILING_S = 30.0  # absolute smoke ceiling per recovery
# (bank_size, n_bank_shards) grid; the 8-shard arm is the BENCH_sharded
# workload losing one of its machines
GRID = ((64, 4), (256, 4), (256, 8))
FAST_GRID = ((64, 4), (256, 8))
OUT_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_fault.json")
ARTIFACT_PATH = os.path.join(
    os.path.dirname(__file__), "out", "bank_fault_recovery.json"
)


def _run_row(bank_size: int, n_shards: int, n_chunks: int,
             chunk: int) -> dict:
    from repro.distributed import bank_mesh
    from repro.distributed.faultbank import FaultInjector
    from repro.filters import (ShardedFilterBankEngine, fir_bit_layers_batch,
                               spread_lowpass_qbank)
    from repro.serving import AsyncBankServer

    qbank = spread_lowpass_qbank(bank_size, TAPS)
    rng = np.random.default_rng(bank_size + n_shards)
    x = rng.integers(-128, 128, n_chunks * chunk).astype(np.int32)
    ref = fir_bit_layers_batch(x, qbank)[:, 0, :]

    injector = FaultInjector().kill_shard(KILL_SHARD, at_chunk=KILL_CHUNK)
    eng = ShardedFilterBankEngine(
        qbank, mesh=bank_mesh(n_shards, 1), n_bank_shards=n_shards,
        chunk_hint=chunk, fault_injector=injector,
    )
    server = AsyncBankServer(eng, depth=2)
    # warm the jit caches so the recovery row does not bill compilation
    # of the HEALTHY mesh to the fault path
    eng.push(x[:chunk])
    eng.reset()

    got, step_s = [], []
    for k in range(n_chunks):
        t0 = time.perf_counter()
        got += server.submit(x[k * chunk:(k + 1) * chunk])
        step_s.append(time.perf_counter() - t0)
    t0 = time.perf_counter()
    got += server.drain()
    step_s.append(time.perf_counter() - t0)

    y = np.concatenate([g for g in got if g.shape[2]], axis=2)[:, 0, :]
    if not np.array_equal(y, ref):
        raise AssertionError(
            f"recovered stream != oracle (B={bank_size}, shards={n_shards})"
        )
    st = eng.fault_stats()
    if not (st["recoveries"] == 1 and st["lost_shards"] == 1
            and server.failed_chunks == 0):
        raise AssertionError(f"unexpected fault counters: {st}")
    return {
        "bank_size": bank_size,
        "n_bank_shards": n_shards,
        "recovered_shards": eng.n_bank_shards,
        "taps": TAPS,
        "n_chunks": n_chunks,
        "chunk_samples": chunk,
        "kill": [KILL_SHARD, KILL_CHUNK],
        "recovery_s": st["last_recovery_s"],
        "stall_s": max(step_s),
        "median_step_s": float(np.median(step_s)),
        "replayed_chunks": st["replayed_chunks"],
        "replayed_samples": st["replayed_samples"],
        "detections": st["detections"],
    }


def run(grid=GRID, n_chunks: int = 8, chunk: int = 4096,
        verbose: bool = True) -> dict:
    import jax

    from repro.kernels.runtime import default_interpret

    n_dev = len(jax.devices())
    rows = []
    for bank_size, n_shards in grid:
        if n_shards > n_dev:
            print(f"NOTE: only {n_dev} device(s) visible — skipping "
                  f"(B={bank_size}, shards={n_shards}) (run under XLA_FLAGS="
                  f"--xla_force_host_platform_device_count={n_shards})")
            continue
        row = _run_row(bank_size, n_shards, n_chunks, chunk)
        rows.append(row)
        if verbose:
            print(f"B={bank_size:4d} shards={n_shards} -> "
                  f"{row['recovered_shards']}  recovery "
                  f"{row['recovery_s'] * 1e3:8.1f} ms  stall "
                  f"{row['stall_s'] * 1e3:8.1f} ms (median step "
                  f"{row['median_step_s'] * 1e3:6.1f} ms)  replayed "
                  f"{row['replayed_chunks']} chunks")
    return {
        "benchmark": "bank_fault",
        "backend": jax.default_backend(),
        "interpret": default_interpret(),
        "taps": TAPS,
        "recovery_ceiling_s": RECOVERY_CEILING_S,
        "rows": rows,
        "note": (
            "recovery_s is detection -> recovered mesh (re-partition via the "
            "cost model, rebuilt dispatch closures, bit-exact replay of every "
            "in-flight chunk from its tail snapshot); every row is verified "
            "bit-exact against the numpy oracle before it is reported; "
            "latency re-runs the mesh autotuner so the CI gate is a loose "
            "smoke bound (absolute ceiling + a generous multiple of the "
            "committed row), not a tight regression ratio"
        ),
    }


def write_artifact(result: dict, path: str = ARTIFACT_PATH) -> None:
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        json.dump(result, f, indent=2)
        f.write("\n")


def check(result: dict, committed_path: str, tolerance: float) -> int:
    """Gate: every measured row recovered (bit-exactness and counters are
    asserted inside the run), under the absolute smoke ceiling, and within
    ``tolerance`` x the committed recovery latency for the same row."""
    with open(committed_path) as f:
        committed = json.load(f)
    if not result["rows"]:
        print("check FAILED: no rows ran (set XLA_FLAGS to force devices)")
        return 1
    base = {
        (r["bank_size"], r["n_bank_shards"]): r for r in committed["rows"]
    }
    status = 0
    for row in result["rows"]:
        key = (row["bank_size"], row["n_bank_shards"])
        rec = row["recovery_s"]
        flag = "OK" if 0.0 < rec <= RECOVERY_CEILING_S else "REGRESSION"
        print(f"check B={key[0]} shards={key[1]} recovery "
              f"{rec * 1e3:.1f} ms <= ceiling "
              f"{RECOVERY_CEILING_S:.0f} s  {flag}")
        if flag != "OK":
            status = 1
        if key in base:
            old = base[key]["recovery_s"]
            ratio = rec / old if old > 0 else float("inf")
            flag = "OK" if ratio <= 1.0 + tolerance else "REGRESSION"
            print(f"check B={key[0]} shards={key[1]} vs committed "
                  f"{old * 1e3:.1f} ms ({ratio:.2f}x, "
                  f"allowed {1.0 + tolerance:.1f}x)  {flag}")
            if flag != "OK":
                status = 1
    return status


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--fast", action="store_true",
                    help="reduced grid + shorter stream (CI; no JSON "
                         "rewrite)")
    ap.add_argument("--check", metavar="JSON",
                    help="compare against a committed BENCH_fault.json")
    ap.add_argument("--tolerance", type=float, default=4.0,
                    help="allowed recovery-latency multiple vs committed")
    ap.add_argument("--out", default=OUT_PATH)
    args = ap.parse_args()
    if args.check and not os.path.exists(args.check):
        ap.error(f"baseline not found: {args.check}")
    grid = FAST_GRID if args.fast else GRID
    n_chunks = 6 if args.fast else 8
    chunk = 2048 if args.fast else 4096
    result = run(grid=grid, n_chunks=n_chunks, chunk=chunk)
    write_artifact(result)
    if args.check:
        return check(result, args.check, args.tolerance)
    if not args.fast:
        with open(args.out, "w") as f:
            json.dump(result, f, indent=2)
            f.write("\n")
        print(f"wrote {os.path.normpath(args.out)}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
