"""Compiled-lane bank kernel throughput with roofline verification.

For the B=256 reference bank (63 taps, spread lowpass cutoffs) this
benchmark measures the scheduled bank kernel on

  * ``interpret``      — the autotuned interpret-lane dispatch (the
    historic CI arm every BENCH_fir number was recorded on), and
  * ``xla@merge=M/bt=N`` — the fused CPU-compiled XLA lowering
    (`repro.kernels.blmac_fir._bank_call_xla`) at each compiled
    ``(merge, bank_tile)`` variant, including the geometry the compiled
    autotuner sweep (`autotune_bank_dispatch(compiled=...)`) picks.

Every arm is checked bit-exact against `fir_bit_layers_batch` before any
timing; arms are interleaved round-robin (rotating which arm goes first
each repeat) so cache warmth never favors a position, and each arm
reports its fastest repeat.

Roofline columns: per compiled variant the benchmark statically analyzes
the variant's own compiled HLO with `repro.roofline.hlo_analysis`
(summed over tile groups) and divides by *measured* host peaks — int32
and f32 matmul probes for FLOP/s (each superlayer priced against the
unit it actually runs on, see `f32_dot_safe`), a large-array copy probe
for bytes/s — giving ``roofline_us`` (the light-speed bound for that
variant) and
``utilization = roofline_us / measured_us``.  The interpret arm has no
compiled HLO, so its roofline columns are null.  `analyze_hlo` is
fusion-optimistic on CPU HLO (see docs/benchmarks.md), so utilizations
are conservative.

Results land in ``BENCH_compiled.json`` at the repo root (the committed
copy is the CI baseline) plus a per-variant breakdown in
``benchmarks/out/bank_compiled_breakdown.json``.

The CI gate (``--check``) enforces the acceptance floor — the best
compiled variant must beat the interpret arm by ``>= --floor`` (default
1.5x) at B=256, measured in the same run so it transfers across runner
hardware — plus a tolerance band against the committed speedup.

Usage:
  python benchmarks/bank_compiled.py                   # full run, writes JSON
  python benchmarks/bank_compiled.py --fast            # CI smoke sizes
  python benchmarks/bank_compiled.py --fast --check BENCH_compiled.json
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

BANK_SIZE = 256
TAPS = 63
OUT_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_compiled.json")
BREAKDOWN_PATH = os.path.join(
    os.path.dirname(__file__), "out", "bank_compiled_breakdown.json"
)


def measure_peaks(repeats: int = 3) -> dict:
    """Measured host peaks the roofline divides by: int32 AND f32 matmul
    FLOP/s (the compiled variants mix both — f32-safe superlayers run on
    the float GEMM units, see `f32_dot_safe`) plus large-copy bytes/s —
    the same units `analyze_hlo` counts."""
    import jax
    import jax.numpy as jnp

    m, k, n = 256, 256, 65536

    def probe(dtype, **dot_kwargs):
        a = jnp.ones((m, k), dtype)
        b = jnp.ones((k, n), dtype)
        dot = jax.jit(lambda a, b: jnp.dot(a, b, **dot_kwargs))
        dot(a, b).block_until_ready()
        best = float("inf")
        for _ in range(repeats):
            t0 = time.perf_counter()
            dot(a, b).block_until_ready()
            best = min(best, time.perf_counter() - t0)
        return 2.0 * m * k * n / best

    peak_int32 = probe(jnp.int32, preferred_element_type=jnp.int32)
    peak_f32 = probe(jnp.float32)

    big = jnp.ones((64 << 20) // 4, jnp.int32)  # 64 MiB, past any LLC
    copy = jax.jit(lambda x: x + 1)
    copy(big).block_until_ready()
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        copy(big).block_until_ready()
        best = min(best, time.perf_counter() - t0)
    peak_bw = 2.0 * big.size * 4 / best  # read + write
    return {"peak_int32_flops": peak_int32, "peak_f32_flops": peak_f32,
            "peak_hbm_bytes_per_s": peak_bw}


def _variant_hlo_cost(program, schedule, taps: int, tile: int, chunk: int):
    """Static FLOPs/bytes of one compiled variant: `analyze_hlo` over the
    lowered `_bank_call_xla` of every tile group, summed.  Also returns
    the portion of the dot FLOPs that runs on the f32 GEMM units (the
    exact-f32 superlayers, `f32_dot_safe`) so the roofline can price each
    contraction against the right measured peak."""
    import jax.numpy as jnp

    from repro.kernels.blmac_fir import (TRITS_PER_WORD, _bank_call_xla,
                                         f32_dot_safe, frame_signal_batch)
    from repro.roofline.hlo_analysis import CompCost, analyze_hlo

    frames, _ = frame_signal_batch(jnp.zeros((1, chunk), jnp.int32), taps,
                                   tile)
    n_chan, n_tiles, _ = frames.shape
    signal = n_chan * n_tiles * tile
    total, f32_flops = CompCost(), 0.0
    for g in schedule.groups:
        if not g.sel_layers:
            continue
        op = jnp.asarray(g.packed.view(np.int32))
        text = _bank_call_xla.lower(
            frames, op, taps=taps, schedule=g.schedule,
            tail_shift=g.tail_shift, tile=tile,
        ).compile().as_text()
        total.add(analyze_hlo(text))
        b_pad, _, n_words = op.shape
        m_pad = n_words * TRITS_PER_WORD
        for _, parts in g.schedule:
            if f32_dot_safe(m_pad, parts):
                f32_flops += 2.0 * b_pad * m_pad * signal
    return total, f32_flops


def _interleaved_times(arms: dict, repeats: int) -> dict:
    """Fastest wall time per arm, arms interleaved with rotating start."""
    for fn in arms.values():
        fn()  # warm-up: compile + stage operands
    names = list(arms)
    best = {name: float("inf") for name in names}
    for r in range(repeats):
        for name in names[r % len(names):] + names[: r % len(names)]:
            t0 = time.perf_counter()
            arms[name]()
            best[name] = min(best[name], time.perf_counter() - t0)
    return best


def run(n_samples: int = 16384, repeats: int = 3, verbose: bool = True,
        n_filters: int = BANK_SIZE, taps: int = TAPS) -> dict:
    import jax
    import jax.numpy as jnp

    from repro.compiler import compile_bank
    from repro.filters import fir_bit_layers_batch, spread_lowpass_qbank
    from repro.kernels.blmac_fir import blmac_fir_bank
    from repro.kernels.runtime import (COMPILED_MERGE_CANDIDATES,
                                       DEFAULT_TILE, autotune_bank_dispatch,
                                       resolve_lane)

    qbank = spread_lowpass_qbank(n_filters, taps)
    program = compile_bank(qbank)
    rng = np.random.default_rng(42)
    x = rng.integers(-128, 128, n_samples).astype(np.int32)
    xj = jnp.asarray(x)
    n_out = n_samples - taps + 1
    ref = fir_bit_layers_batch(x, qbank)[:, 0, :]

    lane = resolve_lane(True)  # this host's compiled lane
    plan_i, sched_i = autotune_bank_dispatch(program, chunk_hint=n_samples)
    plan_c, _ = autotune_bank_dispatch(program, chunk_hint=n_samples,
                                       compiled=lane)

    # variant grid: the compiled merge candidates at the default bank
    # tile, always including whatever geometry the compiled sweep picked
    geoms = [(m, None) for m in COMPILED_MERGE_CANDIDATES]
    if plan_c.lane != "interpret" and (plan_c.merge, None) not in geoms:
        geoms.append((plan_c.merge, plan_c.bank_tile))

    arms, rows = {}, []

    def make_arm(schedule, tile, arm_lane):
        def f():
            blmac_fir_bank(
                xj, program.packed, taps, tile=tile, schedule=schedule,
                fast_path=False, lane=arm_lane,
            ).block_until_ready()
        return f

    def verify(schedule, tile, arm_lane, name):
        y = np.asarray(blmac_fir_bank(
            xj, program.packed, taps, tile=tile, schedule=schedule,
            fast_path=False, lane=arm_lane,
        ))[..., :n_out]
        if not np.array_equal(y, ref):
            raise AssertionError(f"arm {name} is not bit-exact")

    verify(sched_i, plan_i.tile, "interpret", "interpret")
    arms["interpret"] = make_arm(sched_i, plan_i.tile, "interpret")
    rows.append({"arm": "interpret", "lane": "interpret",
                 "merge": plan_i.merge, "bank_tile": plan_i.bank_tile,
                 "tile": plan_i.tile, "autotuned": True})

    peaks = measure_peaks(repeats)
    for merge, bt in geoms:
        schedule = program.schedule(bt, merge)
        name = f"{lane}@merge={merge}/bt={schedule.tile_size}"
        verify(schedule, DEFAULT_TILE, lane, name)
        arms[name] = make_arm(schedule, DEFAULT_TILE, lane)
        cost, f32_flops = _variant_hlo_cost(program, schedule, taps,
                                            DEFAULT_TILE, n_samples)
        int_flops = max(cost.flops - f32_flops, 0.0)
        compute_s = (f32_flops / peaks["peak_f32_flops"]
                     + int_flops / peaks["peak_int32_flops"])
        roofline_us = max(compute_s,
                          cost.hbm_bytes / peaks["peak_hbm_bytes_per_s"]) * 1e6
        rows.append({
            "arm": name, "lane": lane, "merge": merge,
            "bank_tile": schedule.tile_size, "tile": DEFAULT_TILE,
            "autotuned": (merge, bt) == (plan_c.merge, plan_c.bank_tile),
            "hlo_flops": cost.flops, "hlo_f32_flops": f32_flops,
            "hlo_hbm_bytes": cost.hbm_bytes,
            "roofline_us": roofline_us,
        })

    times = _interleaved_times(arms, repeats)
    t_interp = times["interpret"]
    for row in rows:
        t = times[row["arm"]]
        row["seconds"] = t
        row["samples_per_s_per_filter"] = n_out / t
        row["speedup_vs_interpret"] = t_interp / t
        if "roofline_us" in row:
            row["roofline_utilization"] = row["roofline_us"] / (t * 1e6)
        else:
            row["roofline_us"] = None
            row["roofline_utilization"] = None
        if verbose:
            util = (f"  util {row['roofline_utilization']:.3f}"
                    if row["roofline_utilization"] is not None else "")
            print(f"{row['arm']:24s} {t * 1e3:9.2f} ms  "
                  f"{row['samples_per_s_per_filter']:12.0f} samples/s/filter"
                  f"  ({row['speedup_vs_interpret']:.2f}x interpret){util}")

    best = max((r for r in rows if r["lane"] != "interpret"),
               key=lambda r: r["speedup_vs_interpret"])
    return {
        "benchmark": "bank_compiled",
        "backend": jax.default_backend(),
        "lane": lane,
        "bank_size": n_filters,
        "taps": taps,
        "n_samples": n_samples,
        "autotuned_plan": {"lane": plan_c.lane, "merge": plan_c.merge,
                           "bank_tile": plan_c.bank_tile,
                           "tile": plan_c.tile, "mode": plan_c.mode},
        "compiled_speedup": best["speedup_vs_interpret"],
        "best_arm": best["arm"],
        **peaks,
        "rows": rows,
    }


def write_breakdown(result: dict, path: str = BREAKDOWN_PATH) -> None:
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        json.dump(result, f, indent=2)
        f.write("\n")


def check(result: dict, committed_path: str, tolerance: float,
          floor: float) -> int:
    """Fail (non-zero) unless the same-run compiled-vs-interpret speedup
    clears the absolute acceptance floor AND stays within ``tolerance``
    of the committed baseline ratio."""
    with open(committed_path) as f:
        committed = json.load(f)
    status = 0
    sp = result["compiled_speedup"]
    flag = "OK" if sp >= floor else "REGRESSION"
    print(f"check compiled floor: {sp:.2f}x >= {floor:.2f}x required  {flag}")
    if flag != "OK":
        status = 1
    old = committed["compiled_speedup"]
    ratio = sp / old
    flag = "OK" if ratio >= 1.0 - tolerance else "REGRESSION"
    print(f"check compiled speedup: {sp:.2f}x vs committed {old:.2f}x "
          f"({ratio:.2f}x)  {flag}")
    if flag != "OK":
        status = 1
    for row in result["rows"]:
        if row["roofline_utilization"] is None:
            continue
        if not 0.0 < row["roofline_utilization"] <= 2.0:
            # >1 means the fusion-optimistic static model undercounted
            # or the peak probe ran slow (the probe is itself a timed
            # GEMM on a shared box); far above 1 (or <= 0) means the
            # analyzer or probe broke
            print(f"check {row['arm']}: roofline utilization "
                  f"{row['roofline_utilization']:.3f} out of (0, 2.0]  "
                  f"REGRESSION")
            status = 1
    return status


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--fast", action="store_true",
                    help="CI smoke sizes (no JSON rewrite)")
    ap.add_argument("--check", metavar="JSON",
                    help="compare against a committed BENCH_compiled.json")
    ap.add_argument("--tolerance", type=float, default=0.25)
    ap.add_argument("--floor", type=float, default=1.5,
                    help="absolute compiled-vs-interpret speedup floor "
                         "at B=256 (the PR acceptance bar)")
    ap.add_argument("--out", default=OUT_PATH)
    args = ap.parse_args()
    if args.check and not os.path.exists(args.check):
        ap.error(f"baseline not found: {args.check}")
    n_samples = 8192 if args.fast else 16384
    repeats = 2 if args.fast else 5
    result = run(n_samples=n_samples, repeats=repeats)
    write_breakdown(result)
    if args.check:
        return check(result, args.check, args.tolerance, args.floor)
    if not args.fast:
        with open(args.out, "w") as f:
            json.dump(result, f, indent=2)
            f.write("\n")
        print(f"wrote {os.path.normpath(args.out)}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
