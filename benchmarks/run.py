"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows:
  * us_per_call — wall time of the measured operation on this host,
  * derived     — the paper-comparable metric (additions, cycles, rates…).

Full-fidelity modes (paper's exact grids) are available on each module's
CLI (e.g. ``python benchmarks/fig34_fir_sweep.py --full``); this harness
uses reduced grids so the whole suite runs in ~2 minutes.
"""
from __future__ import annotations

import sys
import os
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))


def _row(name: str, us: float, derived: str) -> None:
    print(f"{name},{us:.1f},{derived}")


def bench_table3_pulses() -> None:
    """Tab. 3: avg/max pulse counts (exact, 1..16 bits here; --full: 24)."""
    from benchmarks import table3_pulses

    t0 = time.time()
    rows, ok = table3_pulses.run(max_bits=16, verbose=False)
    us = (time.time() - t0) * 1e6
    n7 = next(r for r in rows if r[0] == 7)
    _row("table3_pulses", us / len(rows),
         f"exact={ok};avg7={n7[1]:.2f};max7={n7[2]}")


def bench_fig34_sweep() -> None:
    """Figs. 3-4: B_N over the sweep (fast grid; --full = 1.98M filters)."""
    from benchmarks import fig34_fir_sweep

    t0 = time.time()
    rows, checks = fig34_fir_sweep.run("fast", verbose=False)
    us = (time.time() - t0) * 1e6
    h255 = next(r for r in rows if r["window"] == "hamming" and r["taps"] == 255)
    _row("fig34_fir_sweep", us / max(len(rows), 1),
         f"B255_hamming={h255['mean']:.1f};adds_per_tap={h255['adds_per_tap']:.2f};"
         f"vs_classical={h255['classical_equiv']/h255['mean']:.2f}x")


def bench_table4_machine() -> None:
    """§4/Tab. 4: vectorized machine over the whole bank — cycles,
    memory-fit rate, Msample/s, full-bank bit-exactness."""
    from benchmarks import table4_machine

    t0 = time.time()
    stats = table4_machine.run(n_div=40, verbose=False)
    us = (time.time() - t0) * 1e6
    _row("table4_machine", us,
         f"mean_cycles={stats['mean_cycles_all']:.1f};"
         f"fused={stats['fused_mean_cycles_all']:.1f};"
         f"pct_overflow={stats['pct_not_fitting']:.1f};"
         f"rate_artix7={316.8/stats['mean_cycles_all']:.2f}Msps;"
         f"bit_exact_bank={stats['n_filters'] - stats['bit_exact_mismatches']}"
         f"/{stats['n_filters']}")


def bench_kernel_blmac_fir() -> None:
    """Pallas FIR kernel (interpret mode on CPU): adds == pulse count."""
    import jax.numpy as jnp

    from repro.core import fir_blmac_additions, po2_quantize
    from repro.filters import design_bank
    from repro.kernels import blmac_fir

    h = design_bank(127, [("lowpass", 0.31)])[0]
    q, _ = po2_quantize(h, 16)
    x = jnp.asarray(np.random.default_rng(0).integers(-128, 128, 8192),
                    jnp.int32)
    y = blmac_fir(x, q)  # compile once
    t0 = time.time()
    for _ in range(3):
        y = blmac_fir(x, q)
    y.block_until_ready()
    us = (time.time() - t0) / 3 * 1e6
    _row("kernel_blmac_fir", us,
         f"outputs={y.shape[0]};adds_per_output={fir_blmac_additions(q)}")


def bench_kernel_bank() -> None:
    """Batched filter-bank kernel: samples/s/filter and speedup vs the
    per-filter loop (full grid + BENCH_fir.json: benchmarks/bank_throughput.py)."""
    from benchmarks import bank_throughput

    rows = bank_throughput.run(bank_sizes=(16,), n_samples=2048,
                               repeats=1, verbose=False)["rows"]
    r = rows[0]
    _row("kernel_bank_fir", r["batched_s"] * 1e6,
         f"B={r['bank_size']};"
         f"samples_per_s_per_filter={r['batched_samples_per_s_per_filter']:.0f};"
         f"vs_per_filter={r['speedup']:.2f}x")


def bench_bank_compiled() -> None:
    """Compiled-lane bank kernel vs interpret, with roofline utilization
    (full grid + BENCH_compiled.json: benchmarks/bank_compiled.py)."""
    from benchmarks import bank_compiled

    result = bank_compiled.run(n_samples=4096, repeats=2, verbose=False)
    best = next(r for r in result["rows"] if r["arm"] == result["best_arm"])
    util = best["roofline_utilization"]
    derived = (f"lane={result['lane']};best={result['best_arm']};"
               f"vs_interpret={result['compiled_speedup']:.2f}x")
    if util is not None:
        derived += f";roofline_util={util:.2f}"
    _row("bank_compiled", best["seconds"] * 1e6, derived)


def bench_bank_cse() -> None:
    """Cross-filter CSE pass: adds-per-filter reduction on the sweep
    grid + autotuned B=256 throughput vs the unoptimized baseline
    (full grid + BENCH_cse.json: benchmarks/bank_cse.py)."""
    from benchmarks import bank_cse

    result = bank_cse.run(n_div=10, n_samples=4096, repeats=2,
                          verbose=False)
    sweep, tp = result["sweep"], result["throughput"]
    auto = next(r for r in tp["rows"] if r["arm"] == "cse-auto")
    derived = (f"adds_reduction={100 * sweep['adds_reduction']:.1f}%;"
               f"cycle_reduction={100 * sweep['cycle_reduction']:.1f}%;"
               f"n_shared={sweep['n_shared']};"
               f"auto={tp['auto_cse'] or 'n/a'};"
               f"throughput_ratio={tp['throughput_ratio']:.2f}x")
    _row("bank_cse", auto["seconds"] * 1e6, derived)


def bench_kernel_pulse_matmul() -> None:
    """CSD-P pulse-code matmul vs quantization error / storage."""
    import jax.numpy as jnp

    from repro.kernels import pulse_dequantize, pulse_matmul_op, pulse_quantize

    rng = np.random.default_rng(0)
    w = rng.standard_normal((512, 256))
    x = rng.standard_normal((16, 512)).astype(np.float32)
    derived = []
    us = 0.0
    for p in (1, 2, 4):
        codes, ge = pulse_quantize(w, p)
        err = np.abs(pulse_dequantize(codes, ge) - w).mean() / np.abs(w).mean()
        t0 = time.time()
        y = pulse_matmul_op(jnp.asarray(x), jnp.asarray(codes),
                            jnp.asarray(ge), p)
        y.block_until_ready()
        us = (time.time() - t0) * 1e6
        derived.append(f"P{p}:relerr={err:.4f}")
    _row("kernel_pulse_matmul", us, ";".join(derived) + ";bits=6P(packed)")


def bench_roofline_summary() -> None:
    """§Roofline headline from the dry-run artifacts (if present)."""
    from benchmarks import roofline_table

    rows = roofline_table.load("baseline")
    if not rows:
        _row("roofline", 0.0, "no dryrun artifacts (run repro.launch.dryrun)")
        return
    n_fit = sum(r["fits_hbm"] for r in rows)
    dom = {}
    for r in rows:
        dom[r["dominant"]] = dom.get(r["dominant"], 0) + 1
    best = max(
        (r for r in rows if r["kind"] == "train"),
        key=lambda r: r["model_flops_per_dev"] / 197e12
        / max(r["compute_term_s"], r["memory_term_s"], r["collective_term_s"]),
    )
    frac = (best["model_flops_per_dev"] / 197e12 /
            max(best["compute_term_s"], best["memory_term_s"],
                best["collective_term_s"]))
    dom_s = ":".join(f"{k}{v}" for k, v in sorted(dom.items()))
    _row("roofline", 0.0,
         f"cells={len(rows)};fits_hbm={n_fit};dominant={dom_s};"
         f"best_train={best['arch']}@{frac:.3f}")


def main() -> None:
    print("name,us_per_call,derived")
    bench_table3_pulses()
    bench_fig34_sweep()
    bench_table4_machine()
    bench_kernel_blmac_fir()
    bench_kernel_bank()
    bench_bank_compiled()
    bench_bank_cse()
    bench_kernel_pulse_matmul()
    bench_roofline_summary()


if __name__ == "__main__":
    main()
