"""Bounded, instrumented caches for the compile pipeline.

One content-addressed program cache replaces the four scattered LRUs that
PRs 1–4 grew (`specialized_program`, `_bank_call`, the two autotuner
caches): a `BlmacProgram` is compiled at most once per distinct bank
content, and every downstream cache keys on the program's digest instead
of re-hashing (or worse, re-deriving) the bank.

`cache_stats()` is the single observability point: hit/miss/size for
every cache in the pipeline plus event counters for the expensive
recomputations the refactor is meant to eliminate (CSD packings,
schedule plans, machine-cycle derivations).  `tests/test_compiler.py`
asserts through it that CSD/occupancy is computed exactly once when one
bank is shared by the engine, the autotuner and the cycle predictor.
"""
from __future__ import annotations

import collections
import importlib
from dataclasses import dataclass

__all__ = ["CacheStat", "ProgramCache", "cache_stats", "clear_caches",
           "PROGRAM_CACHE", "STATS", "COUNTERS"]


@dataclass
class CacheStat:
    """Hit/miss counters for one cache domain."""

    hits: int = 0
    misses: int = 0

    def hit(self) -> None:
        self.hits += 1

    def miss(self) -> None:
        self.misses += 1

    def reset(self) -> None:
        self.hits = self.misses = 0


class ProgramCache:
    """LRU cache of compiled `BlmacProgram`s, content-addressed.

    One program object may be registered under SEVERAL keys (its
    quantized-coefficient digest and its packed-trit digest point at the
    same artifact), so a bank compiled from coefficients is found again
    by a caller holding only the packed operand, and vice versa.
    Bounded: past ``max_entries`` keys the least recently used entry is
    dropped — programs hold the packed bank, so the bound is the memory
    bound.
    """

    def __init__(self, max_entries: int = 64):
        self.max_entries = int(max_entries)
        self._entries: "collections.OrderedDict" = collections.OrderedDict()
        self.stat = CacheStat()

    def get(self, key):
        prog = self._entries.get(key)
        if prog is None:
            self.stat.miss()
            return None
        self._entries.move_to_end(key)
        self.stat.hit()
        return prog

    def put(self, prog, *keys) -> None:
        for key in keys:
            self._entries[key] = prog
            self._entries.move_to_end(key)
        while len(self._entries) > self.max_entries:
            self._entries.popitem(last=False)

    def __len__(self) -> int:
        return len(self._entries)

    def clear(self) -> None:
        self._entries.clear()
        self.stat.reset()


PROGRAM_CACHE = ProgramCache()

# hit/miss stats for caches that live OUTSIDE this module but belong to
# the one pipeline (the autotuners key on program digests; their cache
# object stays in kernels/runtime.py to keep that module a leaf)
STATS: "dict[str, CacheStat]" = {
    "autotune": CacheStat(),
    # the optimized-program memo (compiler/optimize.py `_CSE_MEMO`):
    # hits are cse_pass calls answered without re-mining
    "cse": CacheStat(),
}

# event counters for the expensive derivations `compile_bank` is meant to
# centralize — each key counts actual recomputation events, not lookups
COUNTERS = collections.Counter()


def _bump(event: str, n: int = 1) -> None:
    COUNTERS[event] += n


def cache_stats() -> dict:
    """Hits/misses/size across every compile-pipeline cache.

    Returns a plain dict (JSON-ready)::

        {
          "program":     {"hits": ..., "misses": ..., "size": ...},
          "autotune":    {"hits": ..., "misses": ..., "size": ...},
          "cse":         {"hits": ..., "misses": ..., "size": ...},
          "specialized": {"hits": ..., "misses": ..., "size": ...},
          "bank_call":   {"size": ...},          # jit cache: size only
          "counters":    {"csd_packings": ..., "schedule_plans": ...,
                          "machine_cycle_computes": ..., ...},
        }

    ``counters`` are recomputation EVENTS (how many times the expensive
    step actually ran), the quantity the one-program refactor bounds.
    """
    # the submodule, NOT the same-named function re-exported by the
    # kernels package (`import ... as` would resolve the shadowing attr)
    _bf = importlib.import_module("repro.kernels.blmac_fir")
    _rt = importlib.import_module("repro.kernels.runtime")
    _opt = importlib.import_module("repro.compiler.optimize")

    out: dict = {
        "program": {
            "hits": PROGRAM_CACHE.stat.hits,
            "misses": PROGRAM_CACHE.stat.misses,
            "size": len(PROGRAM_CACHE),
        },
        "autotune": {
            "hits": STATS["autotune"].hits,
            "misses": STATS["autotune"].misses,
            "size": len(_rt._AUTOTUNE_CACHE),
        },
        "cse": {
            "hits": STATS["cse"].hits,
            "misses": STATS["cse"].misses,
            "size": len(_opt._CSE_MEMO),
        },
    }
    info = _bf.specialized_program.cache_info()
    out["specialized"] = {
        "hits": info.hits, "misses": info.misses, "size": info.currsize,
    }
    try:  # jax.jit exposes only a size; absent on very old jax
        bank_size = _bf._bank_call._cache_size()
    except Exception:
        bank_size = None
    out["bank_call"] = {"size": bank_size}
    out["counters"] = dict(COUNTERS)
    return out


def clear_caches() -> None:
    """Empty every compile-pipeline cache and zero the counters.

    Test isolation hook; serving processes never need it (the caches are
    bounded).  The `_bank_call` jit cache is cleared when the running jax
    exposes `clear_cache`, skipped otherwise.
    """
    _bf = importlib.import_module("repro.kernels.blmac_fir")
    _rt = importlib.import_module("repro.kernels.runtime")
    _opt = importlib.import_module("repro.compiler.optimize")

    PROGRAM_CACHE.clear()
    _rt._AUTOTUNE_CACHE.clear()
    STATS["autotune"].reset()
    _opt._CSE_MEMO.clear()
    STATS["cse"].reset()
    _bf.specialized_program.cache_clear()
    try:
        _bf._bank_call.clear_cache()
    except Exception:
        pass
    COUNTERS.clear()
