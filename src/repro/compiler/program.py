"""`compile_bank(coeffs, spec) -> BlmacProgram`: filter compilation as a
first-class, cached, serializable step.

The paper's core object is a *compiled filter*: quantized taps → CSD bit
layers → a pulse/superlayer schedule a tiny machine executes.  PRs 1–4
re-derived that object at five call sites (numpy oracle, pulse-specialized
kernel, scheduled bank kernel, vmachine, sharded engine); `BlmacProgram`
computes it exactly once and every backend reads it off the artifact:

  * quantized coefficients (float input is quantized the paper's way,
    §3.2 power-of-two scaling; int input is taken as already quantized),
  * signed CSD digits and the packed 2-bit trit words
    (`pack_bank_trits` layout — the kernel operand format),
  * per-filter layer occupancy, occupancy signatures and pulse counts,
  * memoized superlayer schedules (`plan_bank_schedule`) per
    ``(bank_tile, merge)``,
  * memoized §4 machine cycle predictions per `MachineSpec`,
  * memoized bank partitions (the sharded engine's plan hook),
  * cost-model estimates (`predict_{specialized,scheduled}_us` read
    their inputs off the program instead of re-unpacking trits).

Programs are content-addressed (`ProgramCache`): compiling the same bank
twice — from coefficients or from an identical packed operand — is a
digest plus a dict hit.  `save()`/`load()` (npz + JSON header) let a
serving process warm-start without recompiling.
"""
from __future__ import annotations

import hashlib
import json
from dataclasses import dataclass

import numpy as np

from ..core.io import atomic_write, check_format_header
from ..core.csd import (assert_int32_bound, csd_decode, csd_digits,
                        layer_occupancy, occupancy_signatures, pack_trits,
                        packed_pulse_counts, require_type1, unpack_trits)
from .cache import PROGRAM_CACHE, _bump
from .schedule import (BankSchedule, MERGE_DEFAULT, default_bank_tile,
                       plan_bank_schedule)

__all__ = [
    "CompileSpec",
    "BlmacProgram",
    "ProgramFormatError",
    "PROGRAM_FORMAT_VERSION",
    "compile_bank",
    "compile_packed",
    "pack_bank_trits",
]

TRITS_PER_WORD = 16

# bump whenever the on-disk layout changes incompatibly; `load` rejects
# files written by a different version instead of mis-parsing them
PROGRAM_FORMAT_VERSION = 1


class ProgramFormatError(ValueError):
    """A saved program file has the wrong version or is corrupted."""


@dataclass(frozen=True)
class CompileSpec:
    """Compilation parameters — part of the program's content address.

    ``coeff_bits`` is the §3.2 quantization width applied to FLOAT
    coefficient input (integer banks are taken as already quantized);
    ``sample_bits`` the input-sample width of the §2.1 int32 accumulator
    bound, asserted once at compile; ``n_layers`` overrides the CSD digit
    count (None = minimal for the bank's magnitude range).
    """

    coeff_bits: int = 16
    sample_bits: int = 8
    n_layers: int | None = None


def _bank_digits(qbank: np.ndarray, n_layers: int | None) -> np.ndarray:
    """(B, taps) symmetric ints → (B, M, L) CSD digits of the folded half.

    The ONE place bank CSD encoding happens (counted in
    `cache_stats()["counters"]["csd_packings"]`).
    """
    _bump("csd_packings")
    half = qbank.shape[-1] // 2
    return csd_digits(qbank[:, : half + 1], n_digits=n_layers)


def pack_bank_trits(
    qbank: np.ndarray,
    n_layers: int | None = None,
    sample_bits: int = 8,
) -> np.ndarray:
    """(B, taps) symmetric int coefficients → (B, n_layers, n_words) uint32
    packed trit words over the folded half-filter (M = taps//2 + 1 rows),
    layer-major so the kernel slices one layer per Horner step.

    The int32 accumulator bound (§2.1) is asserted HERE, once per pack —
    `blmac_fir_bank`, `blmac_fir_dynamic` and `FilterBankEngine` all
    consume packed operands and inherit the guarantee for ``sample_bits``
    inputs (default 8-bit, the paper's operating point).

    Prefer `compile_bank` for anything beyond a one-off pack: it caches
    the result (and everything derived from it) content-addressed.
    """
    qbank = np.asarray(qbank, np.int64)
    if qbank.ndim != 2:
        raise ValueError("qbank must be (n_filters, taps)")
    require_type1(qbank, "bank kernel")
    assert_int32_bound(qbank, sample_bits, "bank kernel")
    digits = _bank_digits(qbank, n_layers)  # (B, M, L)
    return pack_trits(np.swapaxes(digits, 1, 2))  # (B, L, n_words)


def _qbank_key(qbank: np.ndarray, spec: CompileSpec):
    return (
        "q", hashlib.sha256(np.ascontiguousarray(qbank)).digest(),
        qbank.shape, spec.sample_bits, spec.n_layers,
    )


def _packed_key(packed: np.ndarray, taps: int, sample_bits: int):
    # geometry is folded into the digest itself (not just the key tuple):
    # the digest doubles as `BlmacProgram.key`, and identical trit BYTES
    # can arise from different tap counts (zero-padded trailing slots of
    # the last word) — those must not collide in digest-keyed caches
    h = hashlib.sha256(np.ascontiguousarray(packed))
    h.update(repr((packed.shape, int(taps), int(sample_bits))).encode())
    return ("p", h.digest(), packed.shape, int(taps), int(sample_bits))


def _memo_put(memo: dict, key, value, cap: int) -> None:
    """Insert into a bounded FIFO memo (dicts preserve insertion order):
    derived artifacts hold compacted bank copies, so per-program memos
    stay small — an evicted geometry is simply re-planned on demand."""
    memo[key] = value
    while len(memo) > cap:
        del memo[next(iter(memo))]


# per-program memo bounds: schedules/subprograms embed packed-bank copies
# (the quantity the old bounded autotune cache deliberately limited), so
# cap them instead of growing forever.  The schedule cap must cover the
# autotuner's full sweep width (2 bank-tile candidates × 3 interpret
# merge candidates + 2 × 3 compiled merge candidates = 12 geometries)
# or repeated sweeps thrash the memo.
SCHEDULE_MEMO_MAX = 16
SUBPROGRAM_MEMO_MAX = 32


class BlmacProgram:
    """One compiled BLMAC filter bank — the artifact every backend executes.

    Read-only by contract (the arrays are flagged unwritable; programs are
    shared across engines, autotuners and caches).  Construct via
    `compile_bank` / `compile_packed` / `load`, never directly.

    Attributes
    ----------
    key : str
        Hex content digest of the packed trit operand — the program's
        content address (stable across ``save``/``load``).
    qbank : (B, taps) int64
        Quantized coefficients.
    exponents : (B,) int64
        Per-filter §3.2 power-of-two scale exponents (zero when compiled
        from already-quantized integers): float ≈ qbank · 2^−exponent.
    packed : (B, n_layers, n_words) uint32
        Packed 2-bit trit words over the folded half-filter — the bank
        kernel's weight-memory image.
    occupancy : (B, n_layers) bool;  signatures : (B,) uint64
        Which bit layers hold pulses, and the sort key that groups
        schedule-identical filters.
    pulse_counts : (B,) int64
        Non-zero trits per filter — the §3.3 add count less the folds.
    """

    # non-None only on `repro.compiler.optimize.OptimizedProgram`; plain
    # consumers can branch on `program.combine is not None` (or on
    # `parent`) without importing the optimize module
    combine = None
    parent = None

    def __init__(self, *, qbank, exponents, packed, occupancy, signatures,
                 pulse_counts, spec: CompileSpec, key: str):
        self.qbank = qbank
        self.exponents = exponents
        self.packed = packed
        self.occupancy = occupancy
        self.signatures = signatures
        self.pulse_counts = pulse_counts
        self.spec = spec
        self.key = key
        self.n_filters, self.taps = qbank.shape
        _, self.n_layers, self.n_words = packed.shape
        for a in (qbank, exponents, packed, occupancy, signatures,
                  pulse_counts):
            a.setflags(write=False)
        # memoized derived artifacts — the whole point of the program
        self._schedules: dict = {}
        self._cycle_cache: dict = {}
        self._partitions: dict = {}
        self._subprograms: dict = {}
        self._half_digits = None
        self._pulse_schedules = None

    def __repr__(self) -> str:
        return (
            f"BlmacProgram(B={self.n_filters}, taps={self.taps}, "
            f"layers={self.n_layers}, key={self.key[:12]}…)"
        )

    # -- derived views -------------------------------------------------------

    @property
    def mean_pulses(self) -> float:
        """Bank-average BLMAC pulses per filter (the cost model's knob)."""
        return float(self.pulse_counts.mean()) if self.n_filters else 0.0

    @property
    def out_filters(self) -> int:
        """Filters this program serves — equals ``n_filters`` here;
        an `OptimizedProgram` serves fewer than its row count (the
        extra rows are shared partial sums)."""
        return self.n_filters

    def total_adds(self) -> int:
        """§3.3 additions to produce one output sample of the whole
        bank: ``taps//2`` symmetric folds per filter plus one add per
        CSD pulse — the paper's adds-per-filter metric times B, and the
        baseline the CSE pass (`repro.compiler.optimize`) reduces."""
        return self.n_filters * (self.taps // 2) + int(
            self.pulse_counts.sum()
        )

    @property
    def filter_costs(self) -> np.ndarray:
        """(B,) float64 predicted per-filter work: pulses + symmetric
        folds — the quantity `partition_bank` balances (identical to
        `repro.distributed.sharding.bank_filter_costs`)."""
        return self.pulse_counts.astype(np.float64) + self.taps // 2

    def half_digits(self) -> np.ndarray:
        """(B, M, n_layers) int8 signed CSD digits of the folded half,
        LSB-first layers — unpacked from the trit words once, then shared
        (read-only)."""
        if self._half_digits is None:
            half = self.taps // 2
            d = unpack_trits(self.packed, half + 1)  # (B, L, M)
            d = np.ascontiguousarray(np.swapaxes(d, 1, 2))
            d.setflags(write=False)
            self._half_digits = d
        return self._half_digits

    def pulse_schedules(self) -> tuple:
        """Per-filter MSB-first static pulse tuples ``(layer, j, sign)`` —
        the `specialized_program` input, derived once from the digits."""
        if self._pulse_schedules is None:
            digits = self.half_digits()  # (B, M, L)
            out = []
            for b in range(self.n_filters):
                d = digits[b]
                pulses = []
                for layer in range(d.shape[1] - 1, -1, -1):
                    for j in np.nonzero(d[:, layer])[0]:
                        pulses.append((int(layer), int(j), int(d[j, layer])))
                out.append(tuple(pulses))
            self._pulse_schedules = tuple(out)
        return self._pulse_schedules

    def schedule(
        self, bank_tile: int | None = None, merge: int | None = None
    ) -> BankSchedule:
        """The memoized superlayer schedule for one kernel geometry.

        Engine construction, the autotuner grid sweep and benchmarks all
        land here — one `plan_bank_schedule` per distinct
        ``(bank_tile, merge)`` per program, however many clients ask.
        """
        bt = default_bank_tile(self.n_filters) if bank_tile is None \
            else int(bank_tile)
        mg = MERGE_DEFAULT if merge is None else int(merge)
        key = (bt, mg)
        if key not in self._schedules:
            _memo_put(
                self._schedules, key,
                plan_bank_schedule(self.packed, bt, mg), SCHEDULE_MEMO_MAX,
            )
        return self._schedules[key]

    def machine_cycles(self, spec=None) -> np.ndarray:
        """(B,) §4 machine clock cycles per output sample, per filter.

        Derived from the program's OWN digits (no CSD recomputation):
        layers are sliced/padded to ``spec.n_layers`` — exact, because
        NAF digit values are independent of the requested width — and a
        bank whose digits populate layers the spec lacks raises, like
        `machine_cycles_batch` would.  Memoized per spec parameters;
        agrees bit-for-bit with both simulators (`tests/differential.py`).
        """
        from ..core.machine import MachineSpec
        from ..core.rle import code_count_batch

        if spec is None:
            spec = MachineSpec(taps=self.taps)
        if spec.taps != self.taps:
            raise ValueError(
                f"spec is for {spec.taps} taps, bank has {self.taps}"
            )
        key = (spec.n_layers, spec.start_overhead, spec.fused_last_add)
        if key not in self._cycle_cache:
            _bump("machine_cycle_computes")
            digits = self.half_digits()  # (B, M, L) LSB-first
            n = int(spec.n_layers)
            if digits.shape[-1] > n:
                if self.occupancy[:, n:].any():
                    raise ValueError(
                        f"bank populates CSD layer >= {n}; spec has only "
                        f"{n} layers"
                    )
                digits = digits[..., :n]
            elif digits.shape[-1] < n:
                pad = np.zeros(
                    digits.shape[:-1] + (n - digits.shape[-1],), np.int8
                )
                digits = np.concatenate([digits, pad], axis=-1)
            cycles = code_count_batch(digits) + spec.start_overhead
            if spec.fused_last_add:
                cycles = cycles - np.count_nonzero(
                    digits.any(axis=1), axis=-1
                )
            cycles.setflags(write=False)  # shared cache entry: no mutation
            self._cycle_cache[key] = cycles
        return self._cycle_cache[key]

    def partition(self, n_shards: int):
        """Memoized occupancy-balanced `BankPartition` over ``n_shards``
        (the sharded engine's and mesh autotuner's shared plan hook).

        Parameters
        ----------
        n_shards : int
            Number of contiguous (post-occupancy-sort) filter shards.

        Returns
        -------
        repro.distributed.sharding.BankPartition
            ``.assign`` lists each shard's original filter indices;
            ``.imbalance`` is max/mean predicted shard cost.

        Raises
        ------
        ValueError
            ``n_shards < 1`` or more shards than filters.

        Examples
        --------
        >>> import numpy as np
        >>> from repro.compiler import compile_bank
        >>> bank = np.zeros((4, 15), np.int64)
        >>> bank[:, 7] = [64, 96, 160, 224]
        >>> part = compile_bank(bank).partition(2)
        >>> sorted(len(rows) for rows in part.assign)
        [2, 2]
        """
        from ..distributed.sharding import partition_bank

        n_shards = int(n_shards)
        if n_shards not in self._partitions:
            self._partitions[n_shards] = partition_bank(
                self.packed, n_shards, self.taps,
                cost=self.filter_costs, sig=self.signatures,
            )
        return self._partitions[n_shards]

    def select(self, rows) -> "BlmacProgram":
        """The subprogram serving ``rows`` (original filter indices, in
        order) — array slices of this program, no recompilation.  Memoized
        here AND registered content-addressed, so the sharded autotuner
        and the sharded engine asking for the same shard get one object.

        Parameters
        ----------
        rows : sequence of int
            Original filter indices, in the order the subprogram should
            serve them.

        Returns
        -------
        BlmacProgram
            The sliced program (same taps/spec, ``len(rows)`` filters).

        Raises
        ------
        IndexError
            A row index is out of range for this bank.

        Examples
        --------
        >>> import numpy as np
        >>> from repro.compiler import compile_bank
        >>> bank = np.zeros((3, 15), np.int64)
        >>> bank[:, 7] = [64, 96, 160]
        >>> prog = compile_bank(bank)
        >>> sub = prog.select([2, 0])
        >>> sub.n_filters, [int(w) for w in sub.qbank[:, 7]]
        (2, [160, 64])
        >>> prog.select([2, 0]) is sub               # memoized
        True
        """
        rows = np.asarray(rows, np.int64)
        memo = rows.tobytes()
        if memo in self._subprograms:
            return self._subprograms[memo]
        qbank = np.ascontiguousarray(self.qbank[rows])
        packed = np.ascontiguousarray(self.packed[rows])
        qkey = _qbank_key(qbank, self.spec)
        pkey = _packed_key(packed, self.taps, self.spec.sample_bits)
        sub = PROGRAM_CACHE.get(pkey)
        if sub is None:
            sub = BlmacProgram(
                qbank=qbank,
                exponents=np.ascontiguousarray(self.exponents[rows]),
                packed=packed,
                occupancy=np.ascontiguousarray(self.occupancy[rows]),
                signatures=np.ascontiguousarray(self.signatures[rows]),
                pulse_counts=np.ascontiguousarray(self.pulse_counts[rows]),
                spec=self.spec,
                key=pkey[1].hex(),
            )
            PROGRAM_CACHE.put(sub, pkey, qkey)
        _memo_put(self._subprograms, memo, sub, SUBPROGRAM_MEMO_MAX)
        return sub

    # -- cost-model reads ----------------------------------------------------

    def predict_specialized_us(
        self, channels: int, n_tiles: int, cal=None
    ) -> float:
        """Modelled per-dispatch latency of the per-filter specialized
        loop — `repro.core.costmodel.predict_specialized_us` with every
        bank-derived input read off the program.  ``cal`` optionally
        selects a per-lane `BackendCalibration` constant set."""
        from ..core.costmodel import predict_specialized_us

        return predict_specialized_us(
            self.n_filters, channels, n_tiles, self.taps,
            self.mean_pulses, self.n_layers, cal=cal,
        )

    def predict_scheduled_us(
        self,
        channels: int,
        n_tiles: int,
        tile: int,
        bank_tile: int | None = None,
        merge: int | None = None,
        cal=None,
    ) -> float:
        """Modelled per-dispatch latency of the scheduled bank path for
        one geometry, costed on the memoized schedule.  ``cal``
        optionally selects a per-lane `BackendCalibration` constant
        set (default: the interpret reference constants).  The exact
        schedule also decides ``f32_safe`` — whether EVERY superlayer's
        digit bound admits the xla lane's exact-f32 contraction
        (`repro.kernels.blmac_fir.f32_dot_safe`), which prices MACs at
        the lane's f32 GEMM rate."""
        from ..core.costmodel import predict_scheduled_us
        from ..kernels.blmac_fir import f32_dot_safe

        sched = self.schedule(bank_tile, merge)
        m_pad = self.n_words * TRITS_PER_WORD
        f32_safe = all(
            f32_dot_safe(m_pad, parts)
            for g in sched.groups
            for _, parts in g.schedule
        )
        return predict_scheduled_us(
            channels, n_tiles, tile, m_pad,
            sched.group_summaries(), cal=cal, f32_safe=f32_safe,
        )

    # -- serialization -------------------------------------------------------

    def save(self, path) -> None:
        """Write the program to ``path``: one npz holding the arrays plus
        a JSON header (format version, geometry, content key) — a serving
        process `load`s it and warm-starts without recompiling.  The
        write is atomic (tmp file + rename): a killed process leaves the
        previous file intact, never a truncated one.

        Parameters
        ----------
        path : str | os.PathLike
            Destination file (conventionally ``*.npz``); parent
            directory must exist.

        Raises
        ------
        OSError
            The destination is not writable.

        Examples
        --------
        >>> import numpy as np, os, tempfile
        >>> from repro.compiler import BlmacProgram, compile_bank
        >>> bank = np.zeros((2, 15), np.int64)
        >>> bank[:, 7] = [64, 96]
        >>> prog = compile_bank(bank)
        >>> path = os.path.join(tempfile.mkdtemp(), "bank.npz")
        >>> prog.save(path)
        >>> BlmacProgram.load(path) is prog      # content-addressed hit
        True
        """
        header = {
            "format_version": PROGRAM_FORMAT_VERSION,
            "kind": "blmac_program",
            "key": self.key,
            "n_filters": self.n_filters,
            "taps": self.taps,
            "n_layers": self.n_layers,
            "n_words": self.n_words,
            "spec": {
                "coeff_bits": self.spec.coeff_bits,
                "sample_bits": self.spec.sample_bits,
                "n_layers": self.spec.n_layers,
            },
        }
        atomic_write(path, lambda f: np.savez(
            f,
            header=np.array(json.dumps(header)),
            qbank=self.qbank,
            exponents=self.exponents,
            packed=self.packed,
        ))

    @classmethod
    def load(cls, path) -> "BlmacProgram":
        """Read a program written by `save`.

        Every way the file can be bad raises `ProgramFormatError`: a
        different format version, an unreadable/truncated archive, a
        header digest that does not match the packed trits, or stored
        coefficients that do not decode from the trits (the case where
        the oracle backend and the kernels would silently diverge).
        Callers can therefore `except ProgramFormatError` and fall back
        to recompiling.  The loaded program is registered content-
        addressed, so later `compile_bank` calls for the same bank hit
        it instead of recompiling.

        Parameters
        ----------
        path : str | os.PathLike
            A file written by `save`.

        Returns
        -------
        BlmacProgram
            The loaded (or cache-hit) program.

        Raises
        ------
        ProgramFormatError
            Wrong version, unreadable archive, digest mismatch, or
            coefficients that do not decode from the stored trits.

        Examples
        --------
        >>> from repro.compiler import BlmacProgram, ProgramFormatError
        >>> try:
        ...     BlmacProgram.load("/nonexistent/bank.npz")
        ... except ProgramFormatError:
        ...     print("fall back to compile_bank")
        fall back to compile_bank
        """
        try:
            with np.load(path, allow_pickle=False) as z:
                header = json.loads(str(z["header"][()]))
                check_format_header(
                    header, kind="blmac_program",
                    version=PROGRAM_FORMAT_VERSION, path=path,
                    error_cls=ProgramFormatError, label="BLMAC program",
                )
                qbank = np.ascontiguousarray(z["qbank"], np.int64)
                exponents = np.ascontiguousarray(z["exponents"], np.int64)
                packed = np.ascontiguousarray(z["packed"], np.uint32)
                combine = use_counts = None
                if "cse" in header:  # an optimized program (see optimize.py)
                    combine = np.asarray(z["combine"], np.int64)
                    use_counts = np.asarray(z["use_counts"], np.int64)
        except ProgramFormatError:
            raise
        except Exception as e:  # truncated zip, missing array, bad JSON …
            raise ProgramFormatError(f"{path}: unreadable program file: {e}")
        spec = CompileSpec(**header["spec"])
        taps = int(header["taps"])
        pkey = _packed_key(packed, taps, spec.sample_bits)
        # an optimized file's `key` is its CSE content address; the raw
        # trit digest moves to `packed_digest` (same integrity check)
        if pkey[1].hex() != header.get("packed_digest", header.get("key")):
            raise ProgramFormatError(
                f"{path}: content digest mismatch (corrupted file?)"
            )
        # the digest covers the packed trits; cross-check the stored
        # coefficients against them so a corrupted qbank cannot make the
        # oracle backend diverge from the kernels
        half = taps // 2
        halves = csd_decode(np.swapaxes(unpack_trits(packed, half + 1), 1, 2))
        if not np.array_equal(
            qbank, np.concatenate([halves, halves[:, :-1][:, ::-1]], axis=1)
        ):
            raise ProgramFormatError(
                f"{path}: stored coefficients do not decode from the packed "
                f"trits — digest mismatch (corrupted file?)"
            )
        if "cse" in header:
            from .optimize import _load_optimized

            return _load_optimized(
                path, header, qbank, exponents, packed, combine, use_counts
            )
        cached = PROGRAM_CACHE.get(pkey)
        if cached is not None:
            return cached
        prog = _from_arrays(qbank, exponents, packed, spec)
        PROGRAM_CACHE.put(prog, pkey, _qbank_key(qbank, spec))
        return prog


def _from_arrays(
    qbank: np.ndarray,
    exponents: np.ndarray,
    packed: np.ndarray,
    spec: CompileSpec,
) -> BlmacProgram:
    """Assemble a program from its stored arrays — derives only the cheap
    views (occupancy, signatures, pulse counts read off the packed words),
    never re-runs CSD encoding."""
    taps = qbank.shape[-1]
    require_type1(qbank, "compile_bank")
    assert_int32_bound(qbank, spec.sample_bits, "compile_bank")
    occupancy = np.ascontiguousarray(packed.any(axis=-1))
    signatures = occupancy_signatures(occupancy)
    pulse_counts = packed_pulse_counts(packed)
    return BlmacProgram(
        qbank=qbank,
        exponents=np.ascontiguousarray(exponents),
        packed=packed,
        occupancy=occupancy,
        signatures=np.ascontiguousarray(signatures),
        pulse_counts=pulse_counts,
        spec=spec,
        key=_packed_key(packed, taps, spec.sample_bits)[1].hex(),
    )


def compile_bank(coeffs, spec: CompileSpec | None = None) -> BlmacProgram:
    """Compile a filter bank to a `BlmacProgram` — THE entry point of the
    one-program/five-backends pipeline.

    Content-addressed: the same bank compiles once per process (then per
    `save` file across processes) — every engine, autotuner and
    predictor shares the artifact and its memoized schedules, partitions
    and cycle predictions.

    Parameters
    ----------
    coeffs : (B, taps) or (taps,) array
        Odd symmetric type-I coefficients.  Float input is quantized
        per-row the paper's way (§3.2, `po2_quantize_batch` at
        ``spec.coeff_bits``); integer input is taken as already
        quantized.
    spec : CompileSpec | None
        Compilation parameters (quantization width, sample bits, CSD
        layer count); part of the content address.

    Returns
    -------
    BlmacProgram
        The compiled (or cache-hit) program.

    Raises
    ------
    ValueError
        Coefficients are not type-I (even tap count / asymmetric), or
        the §2.1 int32 accumulator bound fails at ``spec.sample_bits``.
    TypeError
        Coefficient dtype is neither float nor integer.

    Examples
    --------
    >>> import numpy as np
    >>> from repro.compiler import compile_bank
    >>> bank = np.zeros((2, 15), np.int64)
    >>> bank[:, 7] = [64, 96]                    # centre-tap scalers
    >>> prog = compile_bank(bank)
    >>> prog.n_filters, prog.taps
    (2, 15)
    >>> compile_bank(bank) is prog               # content-addressed
    True
    """
    spec = spec or CompileSpec()
    coeffs = np.atleast_2d(np.asarray(coeffs))
    if coeffs.ndim != 2:
        raise ValueError("coeffs must be (n_filters, taps)")
    if coeffs.dtype.kind == "f":
        from ..core.quantize import po2_quantize_batch

        qbank, exponents = po2_quantize_batch(coeffs, spec.coeff_bits)
        exponents = np.ascontiguousarray(exponents, np.int64)
    elif coeffs.dtype.kind in "iu":
        qbank = coeffs.astype(np.int64)
        exponents = np.zeros(qbank.shape[0], np.int64)
    else:
        raise TypeError(f"cannot compile coefficients of dtype {coeffs.dtype}")
    qbank = np.ascontiguousarray(qbank)
    qkey = _qbank_key(qbank, spec)
    prog = PROGRAM_CACHE.get(qkey)
    if prog is not None:
        return prog
    require_type1(qbank, "compile_bank")
    assert_int32_bound(qbank, spec.sample_bits, "compile_bank")
    digits = _bank_digits(qbank, spec.n_layers)  # (B, M, L) — ONCE
    packed = pack_trits(np.swapaxes(digits, 1, 2))  # (B, L, n_words)
    pkey = _packed_key(packed, qbank.shape[-1], spec.sample_bits)
    # a bank first seen through `compile_packed` (or a shard `select`) is
    # registered under its packed digest only — adopt that program rather
    # than building a duplicate, and index it under this qbank key too
    existing = PROGRAM_CACHE.get(pkey)
    if existing is not None:
        PROGRAM_CACHE.put(existing, qkey)
        return existing
    _bump("bank_compiles")
    occupancy = np.ascontiguousarray(layer_occupancy(digits))
    prog = BlmacProgram(
        qbank=qbank,
        exponents=exponents,
        packed=packed,
        occupancy=occupancy,
        signatures=np.ascontiguousarray(occupancy_signatures(occupancy)),
        pulse_counts=np.count_nonzero(digits, axis=(1, 2)).astype(np.int64),
        spec=spec,
        key=pkey[1].hex(),
    )
    # digits were just computed — seed the memo instead of re-unpacking
    prog._half_digits = np.ascontiguousarray(digits)
    prog._half_digits.setflags(write=False)
    PROGRAM_CACHE.put(prog, qkey, pkey)
    return prog


def compile_packed(
    packed: np.ndarray, taps: int, sample_bits: int = 8
) -> BlmacProgram:
    """Wrap an existing packed-trit operand (`pack_bank_trits` output) as
    a `BlmacProgram` WITHOUT re-running CSD encoding: the quantized
    coefficients are decoded from the trits (exact — the trit words ARE
    the weights).  Content-addressed like `compile_bank`; a bank packed
    and a bank compiled from the same coefficients at the same layer
    count resolve to one program."""
    packed = np.ascontiguousarray(np.asarray(packed, np.uint32))
    if packed.ndim != 3:
        raise ValueError("packed must be (n_filters, n_layers, n_words)")
    pkey = _packed_key(packed, int(taps), sample_bits)
    prog = PROGRAM_CACHE.get(pkey)
    if prog is not None:
        return prog
    _bump("bank_compiles")
    # a program owns (and freezes) its arrays; copy rather than adopt the
    # caller's buffer — freezing it would be a visible side effect, and a
    # writable alias could mutate cached content under a stale digest
    packed = packed.copy()
    half = int(taps) // 2
    digits = unpack_trits(packed, half + 1)  # (B, L, M)
    halves = csd_decode(np.swapaxes(digits, 1, 2))  # (B, M)
    qbank = np.ascontiguousarray(
        np.concatenate([halves, halves[:, :-1][:, ::-1]], axis=1)
    )
    spec = CompileSpec(sample_bits=sample_bits, n_layers=packed.shape[1])
    prog = _from_arrays(
        qbank, np.zeros(qbank.shape[0], np.int64), packed, spec
    )
    PROGRAM_CACHE.put(prog, pkey, _qbank_key(qbank, spec))
    return prog
