"""Bank-wide sparsity scheduling: the pack-time half of the compile pipeline.

`plan_bank_schedule` turns a packed-trit bank into a `BankSchedule` — the
occupancy-sorted filter permutation plus per-tile-group static *superlayer*
programs that `repro.kernels.blmac_fir._fir_kernel_bank` executes verbatim.
It is pure numpy planning (no jax), which is why it lives in the compiler
package: `BlmacProgram.schedule()` memoizes its output per
``(bank_tile, merge)`` so the engine, the autotuner and any benchmark
asking for the same geometry share ONE plan.

Moved here from ``kernels/blmac_fir.py`` in the one-program refactor; the
kernel module re-exports every name for backward compatibility.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from ..core.csd import occupancy_signatures
from .cache import _bump

__all__ = [
    "MERGE_DEFAULT",
    "MAX_BANK_TILE",
    "TileGroup",
    "BankSchedule",
    "superlayer_schedule",
    "plan_bank_schedule",
    "default_bank_tile",
]

MAX_BANK_TILE = 256  # acc VMEM at tile=1024: 256×1024×4 B = 1 MiB

# CSD layers fused per superlayer matmul (see plan_bank_schedule): the
# measured optimum on the reference machine; 1 recovers the paper-pure
# one-matmul-per-bit-layer kernel.  8 merged layers bound the superlayer
# digit by 2**8 - 1, which keeps the whole contraction inside the exact
# float32 window (`repro.kernels.blmac_fir.f32_dot_safe`: m_pad * bound
# * 2**8 <= 2**24, satisfied for folded windows up to ~257 taps-half) —
# so the compiled lanes run it on the fast f32 GEMM units bit-exactly,
# the effect the compiled-merge autotuner sweep re-measures per plan.
MERGE_DEFAULT = 8


def _pad_to(n: int, m: int) -> int:
    return (n + m - 1) // m * m


def default_bank_tile(n_filters: int) -> int:
    """Bank-tile heuristic: whole bank in one tile up to the VMEM cap;
    above the cap, size the tile so the padded bank tracks n_filters
    (257 filters → 2 tiles of 136, not 2 tiles of 256)."""
    n = max(n_filters, 1)
    if n <= MAX_BANK_TILE:
        return _pad_to(n, 8)
    n_tiles = -(-n // MAX_BANK_TILE)
    return _pad_to(-(-n // n_tiles), 8)


def superlayer_schedule(
    populated: tuple[int, ...], merge: int
) -> tuple[tuple, int, tuple[int, ...]]:
    """Compile a populated-layer set into a static Horner schedule.

    ``populated`` are the bit-layer indices holding ≥1 pulse anywhere in
    the bank tile.  Greedy MSB-first, layers within a span of ``merge``
    positions fuse into one superlayer (digit values then span
    ±(2^merge − 1), still far inside int32 given the pack-time bound).

    Returns ``(schedule, tail_shift, sel_layers)``:
      * ``schedule`` — tuple of ``(shift_in, ((sel_idx, rel_weight), …))``
        entries, MSB first, consumed verbatim by `_fir_kernel_bank`;
      * ``tail_shift`` — final left shift down to layer 0;
      * ``sel_layers`` — the packed-layer indices to gather, MSB first
        (``sel_idx`` indexes this tuple).
    """
    if merge < 1:
        raise ValueError("merge must be >= 1")
    layers = sorted((int(lyr) for lyr in populated), reverse=True)
    if not layers:
        return (), 0, ()
    runs: list[list[int]] = [[layers[0]]]
    for lyr in layers[1:]:
        if runs[-1][0] - lyr < merge:  # span (hi − lo) stays < merge
            runs[-1].append(lyr)
        else:
            runs.append([lyr])
    schedule = []
    sel_layers: list[int] = []
    prev_lo = None
    for run in runs:  # each run: descending layer indices
        lo = run[-1]
        shift_in = 0 if prev_lo is None else prev_lo - lo
        parts = tuple(
            (len(sel_layers) + i, lyr - lo) for i, lyr in enumerate(run)
        )
        sel_layers.extend(run)
        schedule.append((shift_in, parts))
        prev_lo = lo
    return tuple(schedule), prev_lo, tuple(sel_layers)


@dataclass(frozen=True)
class TileGroup:
    """A run of consecutive (post-sort) bank tiles sharing one compiled
    schedule — dispatched as one `pallas_call` with a tile-count grid."""

    schedule: tuple  # static Horner program (see superlayer_schedule)
    tail_shift: int
    sel_layers: tuple[int, ...]  # packed layer indices gathered, MSB first
    packed: np.ndarray  # (n_tiles * bank_tile, n_sel, n_words) uint32
    n_filters: int  # valid (non-pad) rows covered by this group


@dataclass(frozen=True)
class BankSchedule:
    """Pack-time product of `plan_bank_schedule`: occupancy-sorted filter
    permutation + per-group layer-skip schedules."""

    tile_size: int  # bank_tile
    merge: int
    perm: np.ndarray  # (B,) original index of the filter in permuted slot p
    inv: np.ndarray  # (B,) permuted slot of original filter b
    groups: tuple[TileGroup, ...]
    n_filters: int

    @property
    def n_superlayers(self) -> int:
        """Total scheduled matmuls per grid step, summed over groups —
        the quantity the dense kernel fixed at n_layers per tile."""
        return sum(len(g.schedule) for g in self.groups)

    def group_summaries(self) -> "list[tuple[int, int, int, int]]":
        """One ``(n_bank_tiles, bank_tile, n_superlayers, n_sel_layers)``
        tuple per tile group — the shape `predict_scheduled_us` costs."""
        return [
            (
                g.packed.shape[0] // self.tile_size,
                self.tile_size,
                len(g.schedule),
                len(g.sel_layers),
            )
            for g in self.groups
        ]


def plan_bank_schedule(
    packed: np.ndarray,
    bank_tile: int | None = None,
    merge: int = MERGE_DEFAULT,
) -> BankSchedule:
    """Sort a packed bank into occupancy-homogeneous tiles and compile a
    layer-skip schedule per tile group.

    Filters are ordered by their layer-occupancy signature (a bitmask of
    populated layers), partitioned into ``bank_tile`` rows, and each
    tile's schedule is built from the UNION occupancy of its rows — so a
    tile of truncated / low-precision / narrow-band filters never pays
    for layers only its neighbours populate.  Consecutive tiles with an
    identical schedule fuse into one `pallas_call` (one `TileGroup`).
    A tile whose union is empty (all-zero filters) is scheduled as a
    constant zero block — no kernel runs at all.

    Prefer `BlmacProgram.schedule()` when you hold a compiled program:
    it memoizes this call per ``(bank_tile, merge)``.
    """
    _bump("schedule_plans")
    packed = np.asarray(packed)
    n_filters, n_layers, n_words = packed.shape
    if bank_tile is None:
        bank_tile = default_bank_tile(n_filters)
    occ = packed.any(axis=-1)  # (B, L) bool: layer populated in filter b
    sig = occupancy_signatures(occ)
    perm = np.argsort(sig, kind="stable")
    inv = np.empty(n_filters, np.int64)
    inv[perm] = np.arange(n_filters)
    b_pad = _pad_to(n_filters, bank_tile)
    occ_p = np.zeros((b_pad, n_layers), bool)
    occ_p[:n_filters] = occ[perm]
    packed_p = np.zeros((b_pad, n_layers, n_words), packed.dtype)
    packed_p[:n_filters] = packed[perm]

    groups: list[TileGroup] = []
    run_tiles: list[int] = []  # tile indices of the open run
    run_key = None
    n_tiles = b_pad // bank_tile

    def close_run():
        if not run_tiles:
            return
        schedule, tail_shift, sel_layers = run_key
        lo = run_tiles[0] * bank_tile
        hi = (run_tiles[-1] + 1) * bank_tile
        sel = (
            packed_p[lo:hi][:, list(sel_layers), :]
            if sel_layers
            else packed_p[lo:hi, :0, :]
        )
        groups.append(
            TileGroup(
                schedule=schedule,
                tail_shift=tail_shift,
                sel_layers=sel_layers,
                packed=np.ascontiguousarray(sel),
                n_filters=min(hi, n_filters) - min(lo, n_filters),
            )
        )

    for ti in range(n_tiles):
        union = occ_p[ti * bank_tile : (ti + 1) * bank_tile].any(axis=0)
        key = superlayer_schedule(tuple(np.nonzero(union)[0]), merge)
        if key != run_key:
            close_run()
            run_tiles = []
            run_key = key
        run_tiles.append(ti)
    close_run()
    return BankSchedule(
        tile_size=bank_tile,
        merge=merge,
        perm=perm,
        inv=inv,
        groups=tuple(groups),
        n_filters=n_filters,
    )
