"""Cross-filter common-subexpression elimination over a compiled bank.

`cse_pass(program) -> BlmacProgram` rewrites a compiled bank so that the
most frequent signed CSD digit-pair patterns — the 2-term subexpressions
of Kumm/Volkova/Filip, "Design of Optimal Multiplierless FIR Filters"
(arXiv:1912.04210, PAPERS.md) — are computed ONCE as shared partial-sum
rows and reused everywhere they occur:

  * A *pattern* is ``(j, delta, ss)``: two pulses on the same folded tap
    ``j``, ``delta`` bit layers apart, with sign product ``ss``.  NAF
    forbids adjacent non-zero digits, so ``delta >= 2`` always — which
    also makes the canonical 2-pulse prototype ``1 + ss·2^delta`` a valid
    NAF string, i.e. a legal row of a packed trit operand.
  * Each chosen pattern becomes one *virtual filter row* appended to the
    bank (value ``1 + ss·2^delta`` at tap ``j``); every occurrence at
    base layer ``l`` with leading sign ``sigma`` is deleted from its real
    row (−2 pulses) and recorded as the integer coefficient
    ``sigma·2^l`` in a ``(n_real, n_shared)`` *combine* matrix (+1 add
    with shift, applied by downstream consumers as one small GEMM).
  * Because NAF is the unique minimal signed-digit form, deleting a digit
    subset leaves rows that are still the NAF of their decoded value —
    the reduced bank repacks bit-identically and every existing schedule,
    kernel lane, simulator and shard planner executes it unchanged.

Exactness does NOT depend on the augmented rows staying inside the §2.1
int32 bound: int32 adds, shifts and matmuls are ring arithmetic mod 2^32
on every backend, the combine is linear, and the *final* combined value
is the parent's filter output, which the parent's own pack-time bound
guarantees fits int32.  Host-side combines go through int64 and cast
(same residue, no numpy overflow warnings).

The greedy pass picks the highest-count pattern, replaces every
non-overlapping occurrence at once, and re-counts only the changed tap
row; a pattern is only committed when it saves at least one add (count
``m`` replaces ``m`` pairs for +2 virtual pulses, so new patterns need
``m >= 3``).  Removals never create new pairs, so each pattern commits
at most once and the pass terminates after at most ``M·L·2`` commits.

Optimized programs are content-addressed by ``(parent.key, "cse",
level)`` and memoized (`STATS["cse"]` hit/miss counters in
`cache_stats()`), and serialize through the ordinary
`BlmacProgram.save`/`load` path — the parent is reconstructed from the
stored arrays by linearity and verified against its stored key.

``level="ilp"`` is the documented stretch hook: the exact adder-minimal
formulation of Kumm/Volkova/Filip is an integer linear program, not a
greedy scan, and raises `NotImplementedError` here.
"""
from __future__ import annotations

import hashlib

import numpy as np

from ..core.csd import (layer_occupancy, occupancy_signatures, pack_trits,
                        packed_pulse_counts)
from .cache import PROGRAM_CACHE, STATS, _bump
from .program import (BlmacProgram, CompileSpec, ProgramFormatError,
                      _packed_key, _qbank_key, compile_bank)

__all__ = ["OptimizedProgram", "cse_pass", "CSE_MEMO_MAX"]

# the memo holds whole optimized programs (augmented packed banks), so it
# is bounded like the autotune cache; an evicted entry just re-mines
CSE_MEMO_MAX = 16
_CSE_MEMO: dict = {}


def _memo_key(parent_key: str, level, max_shared):
    return (parent_key, "cse", level, max_shared)


class OptimizedProgram(BlmacProgram):
    """A CSE-optimized bank: the parent's filters over a *shared-row*
    operand layout.

    The base-class arrays describe the AUGMENTED bank — ``n_real``
    reduced real rows followed by ``n_shared`` virtual 2-pulse rows — so
    every `BlmacProgram` consumer (schedules, kernels, cost model,
    simulators) executes it unchanged; consumers then apply ``combine``
    (one add + shift per use, as a small GEMM) to fold the shared rows
    back into the real outputs.  Bit-exact vs. the parent on every
    backend lane (`tests/differential.cse_check`).

    Extra attributes
    ----------------
    parent : BlmacProgram
        The unoptimized program; ``effective_qbank() == parent.qbank``.
    n_real, n_shared : int
        Real-filter and virtual-row counts (``n_filters`` is their sum).
    combine : (n_real, n_shared) int64
        Signed power-of-two reuse coefficients; column ``p`` folds shared
        row ``p`` into each real output.
    use_counts : (n_real,) int64
        Combine adds per real filter — the +1-cycle term of the §4 cycle
        model and the +1-add term of the §3.3 adds count.
    """

    def __init__(self, *, parent, combine, use_counts, level, **kw):
        super().__init__(**kw)
        self.parent = parent
        self.combine = combine
        self.use_counts = use_counts
        self.level = level
        self.n_real = int(combine.shape[0])
        self.n_shared = int(combine.shape[1])
        self.parent_key = parent.key
        for a in (combine, use_counts):
            a.setflags(write=False)
        self._bank = None

    def __repr__(self) -> str:
        return (
            f"OptimizedProgram(B={self.n_real}+{self.n_shared} shared, "
            f"taps={self.taps}, layers={self.n_layers}, "
            f"key={self.key[:12]}…)"
        )

    # -- semantics -----------------------------------------------------------

    @property
    def out_filters(self) -> int:
        """Filters this program *serves* (the parent's count) — fewer
        than ``n_filters``, which also counts the virtual rows."""
        return self.n_real

    def effective_qbank(self) -> np.ndarray:
        """The (n_real, taps) coefficients the program implements after
        the combine — equal to ``parent.qbank`` by construction (the
        property the differential leg asserts)."""
        shared = self.qbank[self.n_real:]
        return self.qbank[: self.n_real] + self.combine @ shared

    @property
    def bank(self) -> BlmacProgram:
        """The augmented rows as a PLAIN program — the operand view for
        consumers that partition or shard rows (`lowering`'s sharded
        backend); the caller applies ``combine`` after reassembly.

        Built directly from this program's (frozen) arrays: the
        augmented rows may exceed the parent's §2.1 bound, which is fine
        — see the module docstring's mod-2^32 argument — so the
        re-asserting `compile_packed` path is bypassed.
        """
        if self._bank is None:
            pkey = _packed_key(self.packed, self.taps,
                               self.spec.sample_bits)
            plain = PROGRAM_CACHE.get(pkey)
            if plain is None:
                plain = BlmacProgram(
                    qbank=self.qbank, exponents=self.exponents,
                    packed=self.packed, occupancy=self.occupancy,
                    signatures=self.signatures,
                    pulse_counts=self.pulse_counts,
                    spec=self.spec, key=pkey[1].hex(),
                )
                if self._half_digits is not None:
                    plain._half_digits = self._half_digits
                PROGRAM_CACHE.put(
                    plain, pkey, _qbank_key(self.qbank, self.spec)
                )
            self._bank = plain
        return self._bank

    def total_adds(self) -> int:
        """§3.3 additions to produce one output sample of every *real*
        filter: the symmetric folds, every remaining pulse (including
        the virtual rows' two pulses each, counted once per bank), plus
        one combine add per use."""
        return (
            self.n_real * (self.taps // 2)
            + int(self.pulse_counts.sum())
            + int(self.use_counts.sum())
        )

    def machine_cycles(self, spec=None) -> np.ndarray:
        """(n_real,) §4 cycles per output for each real filter: the
        reduced row's own RLE codes plus one cycle per combine add.
        Shared-row cycles are bank-level (each virtual row runs once for
        all its consumers) — see `shared_cycles`.

        The default spec is widened to ``n_layers + 1`` coefficient
        bits: reduced and virtual rows can exceed the parent's
        magnitude range even though their outputs recombine into it.
        """
        from ..core.machine import MachineSpec

        if spec is None:
            spec = MachineSpec(taps=self.taps,
                               coeff_bits=self.n_layers + 1)
        base = super().machine_cycles(spec)
        cycles = base[: self.n_real] + self.use_counts
        cycles.setflags(write=False)
        return cycles

    def shared_cycles(self, spec=None) -> np.ndarray:
        """(n_shared,) §4 cycles of the virtual rows — amortized once
        per bank per output sample."""
        from ..core.machine import MachineSpec

        if spec is None:
            spec = MachineSpec(taps=self.taps,
                               coeff_bits=self.n_layers + 1)
        return super().machine_cycles(spec)[self.n_real:]

    # -- cost-model reads ----------------------------------------------------

    def predict_scheduled_us(self, channels, n_tiles, tile,
                             bank_tile=None, merge=None, cal=None) -> float:
        """Augmented-schedule latency plus the combine-stage price — the
        number the autotuner compares against the parent's own plan to
        *decline* the pass when sharing loses on a dense-GEMM lane."""
        from ..core.costmodel import predict_combine_us

        base = super().predict_scheduled_us(
            channels, n_tiles, tile, bank_tile, merge, cal=cal
        )
        return base + predict_combine_us(
            self.n_real, self.n_shared, channels, n_tiles, tile, cal=cal
        )

    def predict_specialized_us(self, channels, n_tiles, cal=None) -> float:
        from ..core.costmodel import predict_combine_us

        base = super().predict_specialized_us(channels, n_tiles, cal=cal)
        # the specialized path still pays the combine GEMM per dispatch;
        # tile size only enters via the signal length, folded into
        # n_tiles by the caller's framing, so price one unit tile
        return base + predict_combine_us(
            self.n_real, self.n_shared, channels, n_tiles, 1, cal=cal
        )

    # -- row-structure hooks that do not survive the combine -----------------

    def select(self, rows):
        raise NotImplementedError(
            "OptimizedProgram rows are coupled through the combine "
            "matrix; select() from the parent program, or shard the "
            "augmented rows via .bank and apply .combine afterwards"
        )

    def partition(self, n_shards):
        raise NotImplementedError(
            "partition the augmented rows via .bank (the sharded "
            "lowering does this) and apply .combine after reassembly"
        )

    # -- serialization -------------------------------------------------------

    def save(self, path) -> None:
        """`BlmacProgram.save` plus the sharing structure: the combine
        and use-count arrays and a ``cse`` header section.  `load`
        reconstructs (and key-verifies) the parent by linearity, so a
        warm-started serving process gets the optimized program without
        re-mining."""
        import json

        from ..core.io import atomic_write
        from .program import PROGRAM_FORMAT_VERSION

        header = {
            "format_version": PROGRAM_FORMAT_VERSION,
            "kind": "blmac_program",
            "key": self.key,
            "packed_digest": _packed_key(
                self.packed, self.taps, self.spec.sample_bits
            )[1].hex(),
            "n_filters": self.n_filters,
            "taps": self.taps,
            "n_layers": self.n_layers,
            "n_words": self.n_words,
            "spec": {
                "coeff_bits": self.spec.coeff_bits,
                "sample_bits": self.spec.sample_bits,
                "n_layers": self.spec.n_layers,
            },
            "cse": {
                "level": self.level,
                "n_real": self.n_real,
                "parent_key": self.parent_key,
                "parent_spec": {
                    "coeff_bits": self.parent.spec.coeff_bits,
                    "sample_bits": self.parent.spec.sample_bits,
                    "n_layers": self.parent.spec.n_layers,
                },
            },
        }
        atomic_write(path, lambda f: np.savez(
            f,
            header=np.array(json.dumps(header)),
            qbank=self.qbank,
            exponents=self.exponents,
            packed=self.packed,
            combine=self.combine,
            use_counts=self.use_counts,
        ))


def _cse_content_key(parent_key: str, level, combine: np.ndarray,
                     packed: np.ndarray) -> str:
    """The optimized program's content address: the issue-mandated
    ``(parent.key, pass, level)`` triple, plus digests of the pass
    OUTPUT (deterministic given the triple — included so a corrupted
    file cannot collide with the honest artifact)."""
    h = hashlib.sha256()
    h.update(repr((parent_key, "cse", level)).encode())
    h.update(np.ascontiguousarray(combine))
    h.update(np.ascontiguousarray(packed))
    return h.hexdigest()


def _greedy2(digits: np.ndarray, max_shared: int | None):
    """The greedy weight-level 2-term miner.

    ``digits`` is a writable (B, M, L) int8 copy of the parent's folded
    CSD digits; returns ``(reduced_digits, virtual_digits, combine,
    use_counts, patterns)`` where ``patterns`` maps ``(j, delta, ss)``
    to its virtual-row index.
    """
    n_real, m_taps, n_layers = digits.shape
    deltas = range(2, n_layers)  # NAF: no adjacent pulses

    def pair_counts(rows: np.ndarray) -> np.ndarray:
        """(B, M', L) digits → (M', L, 2) pattern counts; index 0 of the
        last axis counts sign product +1, index 1 counts −1."""
        c = np.zeros((rows.shape[1], n_layers, 2), np.int64)
        r16 = rows.astype(np.int16)
        for delta in deltas:
            prod = r16[:, :, :-delta] * r16[:, :, delta:]
            c[:, delta, 0] = (prod == 1).sum(axis=(0, 2))
            c[:, delta, 1] = (prod == -1).sum(axis=(0, 2))
        return c

    counts = pair_counts(digits)  # (M, L, 2)
    patterns: dict = {}
    columns: list = []
    use_counts = np.zeros(n_real, np.int64)
    dead = np.zeros(counts.shape, bool)  # candidates that failed commit

    while True:
        score = counts - 2  # new pattern: +2 pulses for the virtual row
        score[dead] = 0
        if max_shared is not None and len(patterns) >= max_shared:
            break
        flat = int(np.argmax(score))
        if score.flat[flat] < 1:
            break
        j, delta, s = np.unravel_index(flat, score.shape)
        j, delta, ss = int(j), int(delta), 1 if s == 0 else -1

        # every non-overlapping occurrence, greedily LSB-first: scan base
        # layers ascending, vectorized over filters, skipping pairs that
        # share a pulse with an already-taken pair (NAF chains)
        row = digits[:, j, :]
        prod = row[:, :-delta].astype(np.int16) * row[:, delta:]
        mask = prod == ss
        used = np.zeros((n_real, n_layers), bool)
        occ_b, occ_l = [], []
        for low in range(n_layers - delta):
            take = mask[:, low] & ~used[:, low] & ~used[:, low + delta]
            if take.any():
                bs = np.nonzero(take)[0]
                occ_b.append(bs)
                occ_l.append(np.full(bs.size, low, np.int64))
                used[bs, low] = True
                used[bs, low + delta] = True
        n_occ = sum(len(b) for b in occ_b)
        if n_occ - 2 < 1:  # overlap made the estimate unprofitable
            dead[j, delta, s] = True
            continue

        col = np.zeros(n_real, np.int64)
        bs = np.concatenate(occ_b)
        ls = np.concatenate(occ_l)
        sigma = digits[bs, j, ls].astype(np.int64)
        digits[bs, j, ls] = 0
        digits[bs, j, ls + delta] = 0
        np.add.at(col, bs, sigma << ls)
        np.add.at(use_counts, bs, 1)
        patterns[(j, delta, ss)] = len(columns)
        columns.append(col)
        counts[j] = pair_counts(digits[:, j : j + 1, :])[0]
        dead[j] = False  # the row changed: retry its failed candidates

    n_shared = len(columns)
    virtual = np.zeros((n_shared, m_taps, n_layers), np.int8)
    for (j, delta, ss), p in patterns.items():
        virtual[p, j, 0] = 1
        virtual[p, j, delta] = ss
    combine = (
        np.stack(columns, axis=1)
        if columns else np.zeros((n_real, 0), np.int64)
    )
    return digits, virtual, combine, use_counts, patterns


def cse_pass(program: BlmacProgram, level=2, *,
             max_shared: int | None = None) -> BlmacProgram:
    """Optimize a compiled bank by sharing 2-term partial sums across
    filters.  Returns an `OptimizedProgram` (or ``program`` itself when
    no profitable sharing exists — the pass declines entirely).

    Parameters
    ----------
    program : BlmacProgram
        The parent program (already optimized programs are returned
        unchanged — the pass is idempotent).
    level : int | str
        ``2`` — the committed greedy weight-level 2-term pass.
        ``"ilp"`` — the exact adder-minimal ILP of Kumm/Volkova/Filip,
        "Design of Optimal Multiplierless FIR Filters"
        (arXiv:1912.04210, see PAPERS.md): a documented stretch hook
        that raises `NotImplementedError`.
    max_shared : int | None
        Cap on virtual rows (None = unbounded); part of the memo key.

    Returns
    -------
    BlmacProgram
        Content-addressed and memoized: the same ``(parent.key, level,
        max_shared)`` mines once per process (`STATS["cse"]` counts the
        memo's hits/misses, ``counters["cse_passes"]`` the mines).

    Examples
    --------
    >>> import numpy as np
    >>> from repro.compiler import compile_bank, cse_pass
    >>> bank = np.zeros((3, 15), np.int64)
    >>> bank[:, 7] = [9, 9, 9]                   # 9 = 2^0 + 2^3, shared
    >>> opt = cse_pass(compile_bank(bank))
    >>> int(opt.n_shared), int(opt.pulse_counts.sum())
    (1, 2)
    >>> np.array_equal(opt.effective_qbank(), compile_bank(bank).qbank)
    True
    """
    if level == "ilp":
        raise NotImplementedError(
            "level='ilp' is the stretch formulation — the adder-minimal "
            "integer linear program of Kumm/Volkova/Filip, 'Design of "
            "Optimal Multiplierless FIR Filters' (arXiv:1912.04210, "
            "PAPERS.md); only the greedy level=2 pass is implemented"
        )
    if level != 2:
        raise ValueError(f"unsupported CSE level {level!r} (use 2 or 'ilp')")
    if not isinstance(program, BlmacProgram):
        raise TypeError(f"cse_pass needs a BlmacProgram, got {program!r}")
    if isinstance(program, OptimizedProgram):
        return program

    mkey = _memo_key(program.key, level, max_shared)
    cached = _CSE_MEMO.get(mkey)
    if cached is not None:
        STATS["cse"].hit()
        return cached
    STATS["cse"].miss()
    _bump("cse_passes")

    digits = np.array(program.half_digits(), np.int8)  # writable copy
    reduced, virtual, combine, use_counts, _ = _greedy2(digits, max_shared)
    if combine.shape[1] == 0:
        _memo_register(mkey, program)
        return program

    opt = _assemble(program, reduced, virtual, combine, use_counts, level)
    _memo_register(mkey, opt)
    return opt


def _memo_register(mkey, prog) -> None:
    _CSE_MEMO[mkey] = prog
    while len(_CSE_MEMO) > CSE_MEMO_MAX:
        del _CSE_MEMO[next(iter(_CSE_MEMO))]


def _assemble(parent: BlmacProgram, reduced: np.ndarray,
              virtual: np.ndarray, combine: np.ndarray,
              use_counts: np.ndarray, level) -> OptimizedProgram:
    """Augmented arrays → `OptimizedProgram`, bypassing the §2.1
    re-assert (module docstring) but deriving every view the same way
    `compile_bank` does."""
    aug = np.concatenate([reduced, virtual], axis=0)  # (B+P, M, L)
    packed = pack_trits(np.swapaxes(aug, 1, 2))
    weights = np.int64(1) << np.arange(aug.shape[-1], dtype=np.int64)
    halves = (aug.astype(np.int64) * weights).sum(axis=-1)
    qbank = np.ascontiguousarray(
        np.concatenate([halves, halves[:, :-1][:, ::-1]], axis=1)
    )
    occupancy = np.ascontiguousarray(layer_occupancy(aug))
    exponents = np.concatenate([
        parent.exponents,
        np.zeros(virtual.shape[0], np.int64),
    ])
    spec = CompileSpec(
        coeff_bits=parent.spec.coeff_bits,
        sample_bits=parent.spec.sample_bits,
        n_layers=parent.n_layers,
    )
    combine = np.ascontiguousarray(combine, np.int64)
    opt = OptimizedProgram(
        parent=parent,
        combine=combine,
        use_counts=np.ascontiguousarray(use_counts, np.int64),
        level=level,
        qbank=qbank,
        exponents=np.ascontiguousarray(exponents),
        packed=packed,
        occupancy=occupancy,
        signatures=np.ascontiguousarray(occupancy_signatures(occupancy)),
        pulse_counts=packed_pulse_counts(packed),
        spec=spec,
        key=_cse_content_key(parent.key, level, combine, packed),
    )
    aug = np.ascontiguousarray(aug)
    aug.setflags(write=False)
    opt._half_digits = aug
    return opt


def _load_optimized(path, header, qbank, exponents, packed,
                    combine, use_counts) -> OptimizedProgram:
    """`BlmacProgram.load`'s branch for files with a ``cse`` header
    section (digest + trit-decode checks already done by the caller).
    Reconstructs the parent by linearity and verifies its stored key —
    a corrupted combine matrix cannot produce a program that silently
    serves the wrong filters."""
    cse = header["cse"]
    level = cse["level"]
    n_real = int(cse["n_real"])
    if combine is None or use_counts is None:
        raise ProgramFormatError(
            f"{path}: optimized program is missing combine/use_counts"
        )
    combine = np.ascontiguousarray(combine, np.int64)
    use_counts = np.ascontiguousarray(use_counts, np.int64)
    n_shared = qbank.shape[0] - n_real
    if combine.shape != (n_real, n_shared) or use_counts.shape != (n_real,):
        raise ProgramFormatError(
            f"{path}: combine/use_counts shapes do not match the header"
        )
    if _cse_content_key(cse["parent_key"], level, combine,
                        packed) != header.get("key"):
        raise ProgramFormatError(
            f"{path}: optimized-program content key mismatch "
            f"(corrupted file?)"
        )
    parent_q = qbank[:n_real] + combine @ qbank[n_real:]
    parent = compile_bank(parent_q, CompileSpec(**cse["parent_spec"]))
    if parent.key != cse["parent_key"]:
        raise ProgramFormatError(
            f"{path}: reconstructed parent does not match the stored "
            f"parent key (corrupted file?)"
        )
    mkey = _memo_key(parent.key, level, None)
    cached = _CSE_MEMO.get(mkey)
    if isinstance(cached, OptimizedProgram) and cached.key == header["key"]:
        STATS["cse"].hit()
        return cached
    half = qbank.shape[1] // 2
    from ..core.csd import unpack_trits

    digits = np.ascontiguousarray(
        np.swapaxes(unpack_trits(packed, half + 1), 1, 2)
    )
    opt = _assemble(parent, digits[:n_real], digits[n_real:],
                    combine, use_counts, level)
    _memo_register(mkey, opt)
    return opt
