"""Streaming tail state as a first-class compiler artifact.

Overlap-save streaming keeps exactly one piece of mutable state per
engine: the last ``taps − 1`` input samples of every channel, plus the
stream's sample counters.  `TailSnapshot` freezes that state and keys
it to the **content digest of the compiled program** (`BlmacProgram.key`)
— restoring a snapshot into an engine built from a different bank is a
loud `ValueError`, never a silently wrong stream.

Because the tail is pure host-side numpy, a snapshot is a complete,
deterministic replay point: re-running ``concat(tail, chunk)`` through
ANY backend of the same program reproduces the chunk's outputs bit-
exactly.  That property is what makes the sharded engine's fault
recovery bit-exact — on shard loss it re-partitions the bank over the
surviving mesh and replays every in-flight chunk from its snapshot
(`repro.filters.ShardedFilterBankEngine`), and it is what a serving
process saves beside `BlmacProgram.save()` to resume a stream across a
restart (`save()`/`load()` here use the same atomic npz + JSON-header
layout).
"""
from __future__ import annotations

import dataclasses
import json

import numpy as np

from ..core.io import atomic_write, check_format_header

__all__ = ["STATE_FORMAT_VERSION", "SnapshotFormatError", "TailSnapshot"]

STATE_FORMAT_VERSION = 1


class SnapshotFormatError(ValueError):
    """A saved tail-snapshot file has the wrong kind/version or is
    corrupted — recapture the snapshot (or start a fresh stream)."""


@dataclasses.dataclass(frozen=True)
class TailSnapshot:
    """Frozen overlap-save stream state, content-addressed to a program.

    ``program_key`` is the hex digest of the `BlmacProgram` the stream
    was running; ``tail`` is the (channels, ≤ taps−1) int32 history;
    ``samples_in`` / ``samples_out`` are the stream counters at capture
    time.  Engines validate the key and channel count on restore.

    ``session`` is an optional caller-chosen stream identity: the
    multi-tenant session server (`repro.serving.sessions`) stamps each
    parked/paused session's id here, so a directory of snapshots is
    self-describing — which tenant a frozen stream belongs to rides
    with the artifact, not in a side table.  Engines ignore it; files
    written before the field existed load with ``session=""``.
    """

    program_key: str
    channels: int
    samples_in: int
    samples_out: int
    tail: np.ndarray
    session: str = ""

    def save(self, path) -> None:
        """Atomic npz write (`repro.core.io.atomic_write`), mirroring
        `BlmacProgram.save` — a killed process never leaves a truncated
        snapshot behind."""
        header = {
            "format_version": STATE_FORMAT_VERSION,
            "kind": "blmac_tail_snapshot",
            "program_key": self.program_key,
            "channels": int(self.channels),
            "samples_in": int(self.samples_in),
            "samples_out": int(self.samples_out),
            "session": str(self.session),
        }
        atomic_write(path, lambda f: np.savez(
            f,
            header=np.array(json.dumps(header)),
            tail=np.asarray(self.tail, np.int32),
        ))

    @classmethod
    def load(cls, path) -> "TailSnapshot":
        """Read a snapshot written by `save`; every way the file can be
        bad raises `SnapshotFormatError`."""
        try:
            with np.load(path, allow_pickle=False) as z:
                header = json.loads(str(z["header"][()]))
                check_format_header(
                    header, kind="blmac_tail_snapshot",
                    version=STATE_FORMAT_VERSION, path=path,
                    error_cls=SnapshotFormatError, label="tail-snapshot",
                )
                tail = np.ascontiguousarray(z["tail"], np.int32)
        except SnapshotFormatError:
            raise
        except Exception as e:  # truncated zip, missing array, bad JSON …
            raise SnapshotFormatError(f"{path}: unreadable snapshot: {e}")
        if tail.ndim != 2 or tail.shape[0] != int(header["channels"]):
            raise SnapshotFormatError(
                f"{path}: tail shape {tail.shape} does not match header "
                f"channels={header['channels']}"
            )
        return cls(
            program_key=str(header["program_key"]),
            channels=int(header["channels"]),
            samples_in=int(header["samples_in"]),
            samples_out=int(header["samples_out"]),
            tail=tail,
            session=str(header.get("session", "")),
        )
