"""`lower(program, backend=...)`: one compiled program, five executables.

Every execution engine in this repo is a *backend* of the same compiled
artifact.  `lower` returns a callable ``exe(x) -> (B, C, n_out)`` (``x``
is ``(C, T)`` or ``(T,)`` integer samples) for:

  * ``"oracle"``      — the numpy Eq. 2 reference
    (`fir_bit_layers_batch`).  Deliberately the naive dense bit-layer
    recursion reading only ``program.qbank``: it is the independent
    ground truth the other backends are differentially verified against,
    so it must not share the schedule mechanism under test.  int64.
  * ``"specialized"`` — per-filter pulse-baked Pallas programs
    (`specialized_program` LRU) from ``program.pulse_schedules()``. int32.
  * ``"scheduled"``   — the sparsity-scheduled bank kernel on
    ``program.packed`` with the memoized ``program.schedule()``. int32.
    Takes a ``lane`` argument selecting the execution lane: ``None``
    (legacy pallas_call + ``interpret``), ``"interpret"``, ``"mosaic"``
    (TPU), ``"triton"`` (GPU) or ``"xla"`` — the fused CPU-compiled
    lowering (`repro.kernels.blmac_fir._bank_call_xla`).
  * ``"vmachine"``    — the vectorized §4 machine simulator programmed
    with the bank; the executable exposes ``.vmachine`` and ``.fits``
    (weight-memory verdicts). int64.
  * ``"sharded"``     — a `ShardedFilterBankEngine` built FROM the
    program over a (bank, data) mesh; exposes ``.engine``.  One-shot
    semantics (the engine is reset per call). int32.

All five agree bit-for-bit on integer inputs — `tests/differential.py`
proves it on one shared program.

`OptimizedProgram` (the CSE pass output, `repro.compiler.optimize`)
lowers through the same five backends: the executables run the
augmented shared-row bank and fold the shared partial sums back with
the program's combine matrix, so ``exe(x)`` still returns
``(out_filters, C, n_out)`` — bit-exact against lowering the parent.
The oracle reads ``effective_qbank()`` (weight-level reconstruction),
keeping it independent of the shared-row execution under test; the
vmachine widens ``coeff_bits`` because reduced/virtual row magnitudes
may exceed the parent's coefficient range.
"""
from __future__ import annotations

import numpy as np

from .program import BlmacProgram

__all__ = ["BACKENDS", "Lowered", "lower"]

BACKENDS = ("oracle", "specialized", "scheduled", "vmachine", "sharded")


def _host_combine_i32(y: np.ndarray, combine: np.ndarray, n_real: int):
    """int32 shared-row fold on the host: int64 intermediate, then a
    wrapping cast — the same mod-2**32 residue as the in-kernel GEMM."""
    mixed = y[:n_real].astype(np.int64) + np.tensordot(
        combine, y[n_real:].astype(np.int64), axes=1
    )
    return mixed.astype(np.int32)


class Lowered:
    """An executable lowered from a `BlmacProgram` for one backend.

    Callable ``exe(x) -> np.ndarray (B, C, n_out)``; backend-specific
    handles (``.vmachine``, ``.fits``, ``.engine``) are attached as
    attributes where the backend has them.
    """

    def __init__(self, fn, backend: str, program: BlmacProgram, **extras):
        self._fn = fn
        self.backend = backend
        self.program = program
        for name, value in extras.items():
            setattr(self, name, value)

    def __call__(self, x) -> np.ndarray:
        return self._fn(x)

    def __repr__(self) -> str:
        return f"Lowered({self.backend}, {self.program!r})"


def _as_channels(x) -> np.ndarray:
    x = np.asarray(x)
    return x[None, :] if x.ndim == 1 else x


def lower(
    program: BlmacProgram,
    backend: str = "scheduled",
    *,
    channels: int = 1,
    tile: int | None = None,
    bank_tile: int | None = None,
    merge: int | None = None,
    interpret: bool | None = None,
    machine_spec=None,
    mesh=None,
    lane: str | None = None,
) -> Lowered:
    """Lower ``program`` to an executable for ``backend`` (see module doc).

    Parameters
    ----------
    program : BlmacProgram
        The compiled artifact (from `compile_bank` / `compile_packed` /
        `BlmacProgram.load`).
    backend : str
        One of `BACKENDS`.
    channels, mesh
        Configure the sharded engine (the other backends infer C from
        the input).
    tile, bank_tile, merge
        Pin kernel geometry (None = defaults / memoized heuristics).
    interpret : bool | None
        Pallas interpret override for the kernel backends.
    machine_spec : repro.core.MachineSpec | None
        The vmachine's spec (default: the paper's parameters at this
        tap count).
    lane : str | None
        Execution lane for the ``"scheduled"`` backend (see module doc);
        ignored by the others.

    Returns
    -------
    Lowered
        Callable ``exe(x) -> (B, C, n_out)`` with backend-specific
        attributes (``.schedule``, ``.vmachine``, ``.fits``, ``.engine``).

    Raises
    ------
    TypeError
        ``program`` is not a `BlmacProgram`.
    ValueError
        Unknown ``backend``.

    Examples
    --------
    >>> import numpy as np
    >>> from repro.compiler import compile_bank, lower
    >>> bank = np.zeros((2, 15), np.int64)
    >>> bank[:, 7] = [64, 96]
    >>> prog = compile_bank(bank)
    >>> x = np.arange(30, dtype=np.int64)
    >>> y_oracle = lower(prog, "oracle")(x)
    >>> y_xla = lower(prog, "scheduled", lane="xla", interpret=True)(x)
    >>> bool((y_oracle == y_xla).all())          # bit-exact across lanes
    True
    """
    if not isinstance(program, BlmacProgram):
        raise TypeError("lower() needs a BlmacProgram — call compile_bank")
    combine = program.combine  # None on plain programs
    n_real = program.out_filters if combine is not None else None
    if backend == "oracle":
        from ..filters.apply import fir_bit_layers_batch

        qbank = (
            program.qbank if combine is None else program.effective_qbank()
        )

        def run_oracle(x):
            return fir_bit_layers_batch(_as_channels(x), qbank)

        return Lowered(run_oracle, backend, program)

    if backend == "specialized":
        import jax.numpy as jnp

        from ..kernels.blmac_fir import blmac_fir_specialized

        pulses = program.pulse_schedules()
        taps = program.taps
        tile = tile or 1024

        def run_specialized(x):
            xi = jnp.asarray(_as_channels(x), jnp.int32)
            n_out = xi.shape[-1] - taps + 1
            y = np.stack([
                np.stack([
                    np.asarray(
                        blmac_fir_specialized(xi[c], p, taps, tile, interpret)
                    )[:n_out]
                    for c in range(xi.shape[0])
                ])
                for p in pulses
            ])
            if combine is not None:
                y = _host_combine_i32(y, combine, n_real)
            return y

        return Lowered(run_specialized, backend, program)

    if backend == "scheduled":
        from ..kernels.blmac_fir import blmac_fir_bank

        sched = program.schedule(bank_tile, merge)
        tile = tile or 1024

        def run_scheduled(x):
            return np.asarray(blmac_fir_bank(
                _as_channels(x), program.packed, program.taps, tile,
                interpret=interpret, schedule=sched, fast_path=False,
                lane=lane, combine=combine, n_real=n_real,
            ))

        return Lowered(run_scheduled, backend, program, schedule=sched)

    if backend == "vmachine":
        import dataclasses

        from ..core.machine import MachineSpec
        from ..core.vmachine import FirBlmacVMachine

        spec = machine_spec or MachineSpec(taps=program.taps)
        if combine is not None:
            # reduced/virtual row magnitudes can exceed the parent's
            # coefficient range — widen, as machine_cycles() does
            spec = dataclasses.replace(
                spec, coeff_bits=max(spec.coeff_bits, program.n_layers + 1)
            )
        vm = FirBlmacVMachine(spec)
        fits = vm.program_bank(program.qbank)

        def run_vmachine(x):
            x2 = _as_channels(x)
            y = np.stack(
                [vm.run(x2[c]).outputs for c in range(x2.shape[0])], axis=1
            )
            if combine is not None:
                # the vmachine is exact int64: shared rows fold without
                # wrap, landing on the parent's exact outputs
                y = y[:n_real] + np.tensordot(combine, y[n_real:], axes=1)
            return y

        return Lowered(run_vmachine, backend, program, vmachine=vm, fits=fits)

    if backend == "sharded":
        from ..filters.sharded import ShardedFilterBankEngine

        # the sharded engine partitions rows across the bank mesh; an
        # optimized program shards its augmented bank (shared rows are
        # rows like any other) and folds after the gather
        eng = ShardedFilterBankEngine(
            program.bank if combine is not None else program,
            channels=channels, mesh=mesh, tile=tile, merge=merge,
            interpret=interpret,
        )

        def run_sharded(x):
            eng.reset()
            y = eng.push(_as_channels(x))
            if combine is not None:
                y = _host_combine_i32(np.asarray(y), combine, n_real)
            return y

        return Lowered(run_sharded, backend, program, engine=eng)

    raise ValueError(f"unknown backend {backend!r}; expected one of {BACKENDS}")
