"""One BLMAC program, five backends: the unified compile pipeline.

The paper's pipeline — quantized taps → CSD bit layers → a pulse /
superlayer schedule a tiny machine executes — is compiled ONCE into a
`BlmacProgram` and every execution engine is a *backend* of that
artifact::

             coefficients (float or already-quantized int)
                          │  compile_bank(coeffs, spec)
                          ▼
                    BlmacProgram  ──  save() / load()  (npz + JSON header)
        quantized taps · packed CSD trits · occupancy signatures
        memoized superlayer schedules · partitions · cost estimates
                          │  lower(program, backend=...)
        ┌───────────┬─────┴─────┬───────────┬─────────────┐
        ▼           ▼           ▼           ▼             ▼
     oracle    specialized  scheduled    vmachine      sharded
     (numpy     (Pallas,    (Pallas      (§4 machine   (mesh of
      Eq. 2)    pulse-baked) bank tiles)  simulator)    bank shards)

Public surface:

  * `compile_bank` / `compile_packed` — content-addressed compilation,
  * `BlmacProgram` — the artifact (schedules, partitions, cycle and
    latency predictions all memoized on it),
  * `lower` — executables for the five backends,
  * `cse_pass` / `OptimizedProgram` — the cross-filter CSE optimizing
    pass: shared partial-sum rows mined across the bank, bit-exact on
    every backend, memoized on ``(parent.key, "cse", level)`` (see
    ``docs/architecture.md`` "Optimization passes"),
  * `plan_bank_schedule` / `BankSchedule` / `superlayer_schedule` — the
    pack-time scheduler (moved here from ``kernels/blmac_fir.py``),
  * `cache_stats` / `clear_caches` — one observability point for every
    compile-pipeline cache,
  * `TailSnapshot` — overlap-save stream state frozen as an artifact,
    content-addressed to its program (the replay point behind the
    sharded engine's bit-exact fault recovery).

`repro.filters.FilterBankEngine`, `ShardedFilterBankEngine`,
`repro.serving.AsyncBankServer` and both autotuners are thin clients of
this package.
"""
from .cache import cache_stats, clear_caches
from .lowering import BACKENDS, Lowered, lower
from .optimize import OptimizedProgram, cse_pass
from .program import (BlmacProgram, CompileSpec, PROGRAM_FORMAT_VERSION,
                      ProgramFormatError, compile_bank, compile_packed,
                      pack_bank_trits)
from .schedule import (BankSchedule, MERGE_DEFAULT, TileGroup,
                       default_bank_tile, plan_bank_schedule,
                       superlayer_schedule)
from .state import STATE_FORMAT_VERSION, SnapshotFormatError, TailSnapshot

__all__ = [
    "BACKENDS",
    "BankSchedule",
    "BlmacProgram",
    "CompileSpec",
    "Lowered",
    "MERGE_DEFAULT",
    "OptimizedProgram",
    "PROGRAM_FORMAT_VERSION",
    "ProgramFormatError",
    "STATE_FORMAT_VERSION",
    "SnapshotFormatError",
    "TailSnapshot",
    "TileGroup",
    "cache_stats",
    "clear_caches",
    "compile_bank",
    "compile_packed",
    "cse_pass",
    "default_bank_tile",
    "lower",
    "pack_bank_trits",
    "plan_bank_schedule",
    "superlayer_schedule",
]
