"""Shared durable-file primitives: atomic writes, fsync, format headers.

Every on-disk artifact in the repo follows the same discipline — write
to a sibling temp file, optionally fsync, then `os.replace` so a killed
process leaves either the previous file or the complete new one, never
a truncated hybrid.  `BlmacProgram.save`, `TailSnapshot.save`, the
checkpoint manager and the session write-ahead journal
(`repro.serving.journal`) all route through these helpers instead of
carrying their own copy of the tmp+rename dance.
"""
from __future__ import annotations

import os

__all__ = [
    "atomic_write",
    "fsync_file",
    "fsync_dir",
    "check_format_header",
]


def fsync_file(f) -> None:
    """Flush python buffers and force the file's bytes to stable storage."""
    f.flush()
    os.fsync(f.fileno())


def fsync_dir(path) -> None:
    """Best-effort directory fsync: makes a rename/create in ``path``
    durable against power loss (a no-op where directories cannot be
    opened, e.g. some non-POSIX filesystems)."""
    try:
        fd = os.open(path, os.O_RDONLY)
    except OSError:
        return
    try:
        os.fsync(fd)
    except OSError:
        pass
    finally:
        os.close(fd)


def atomic_write(path, writer, fsync: bool = True) -> None:
    """Atomically (re)place ``path``: ``writer(f)`` fills a binary temp
    file next to it, which is fsynced (unless ``fsync=False``) and then
    renamed over the target.  Readers never observe a partial file."""
    path = os.fspath(path)
    tmp = f"{path}.tmp"
    with open(tmp, "wb") as f:
        writer(f)
        if fsync:
            fsync_file(f)
    os.replace(tmp, path)
    if fsync:
        fsync_dir(os.path.dirname(path) or ".")


def check_format_header(
    header: dict, *, kind: str, version: int, path, error_cls=ValueError,
    label: str | None = None,
) -> None:
    """Validate the ``kind`` / ``format_version`` fields every artifact
    header carries; raises ``error_cls`` with a uniform message on
    mismatch (wrong artifact type, or a version this build cannot read).
    ``label`` is the human name used in messages (defaults to ``kind``)."""
    got_kind = header.get("kind")
    if got_kind != kind:
        raise error_cls(f"{path}: not a {label or kind} file")
    got_version = header.get("format_version")
    if got_version != version:
        raise error_cls(
            f"{path}: format version {got_version} != supported {version}"
        )
