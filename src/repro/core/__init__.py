"""BLMAC core: CSD codec, RLE weight programs, quantizers, cost model, and
the cycle-accurate dot-product machine — scalar reference and vectorized
bank simulator (paper §2, §2.4, §3.2, §3.3, §4)."""
from .csd import (
    csd_digits,
    csd_decode,
    csd_truncate,
    max_pulses,
    ntrits_table,
    num_pulses,
    pack_trits,
    require_type1,
    unpack_trits,
)
from .costmodel import (
    adds_per_coeff,
    adds_per_tap,
    classical_equivalent_adds,
    fir_blmac_additions,
    fir_blmac_additions_batch,
    machine_cycles,
    machine_cycles_batch,
)
from .machine import FirBlmacMachine, MachineResult, MachineSpec
from .vmachine import FirBlmacVMachine, VMachineResult, simulate_bank
from .quantize import (
    PlaneQuantized,
    csd_plane_quantize,
    dequantize,
    plane_dequantize,
    po2_quantize,
    po2_quantize_batch,
)
from .rle import (
    EOR,
    RleBatch,
    RleStream,
    code_count,
    code_count_batch,
    decode_codes,
    encode_digits,
    encode_digits_batch,
)

__all__ = [
    "csd_digits",
    "csd_decode",
    "csd_truncate",
    "max_pulses",
    "ntrits_table",
    "num_pulses",
    "pack_trits",
    "require_type1",
    "unpack_trits",
    "adds_per_coeff",
    "adds_per_tap",
    "classical_equivalent_adds",
    "fir_blmac_additions",
    "fir_blmac_additions_batch",
    "machine_cycles",
    "machine_cycles_batch",
    "FirBlmacMachine",
    "MachineResult",
    "MachineSpec",
    "FirBlmacVMachine",
    "VMachineResult",
    "simulate_bank",
    "PlaneQuantized",
    "csd_plane_quantize",
    "dequantize",
    "plane_dequantize",
    "po2_quantize",
    "EOR",
    "RleBatch",
    "RleStream",
    "code_count",
    "code_count_batch",
    "decode_codes",
    "encode_digits",
    "encode_digits_batch",
]
