"""The paper's cost model (§3.3): additions needed to apply a type-I FIR
filter with a BLMAC, with the symmetric pre-add optimization of Eq. 3.

    tot = N/2                              (pre-adds of symmetric samples)
        + Σ_{j<N/2+1} ntrits[|w_j|]        (BLMAC pulses)

plus the comparison baselines the paper uses.
"""
from __future__ import annotations

import numpy as np

from .csd import csd_digits, num_pulses
from .rle import code_count, code_count_batch

__all__ = [
    "fir_blmac_additions",
    "fir_blmac_additions_batch",
    "adds_per_coeff",
    "adds_per_tap",
    "classical_equivalent_adds",
    "machine_cycles",
    "machine_cycles_batch",
]


def _half(wq: np.ndarray) -> np.ndarray:
    """First N//2 + 1 coefficients of a type-I (odd, symmetric) filter."""
    n = wq.shape[-1]
    if n % 2 == 0:
        raise ValueError("type-I FIR filters have an odd number of taps")
    return wq[..., : n // 2 + 1]


def fir_blmac_additions(wq: np.ndarray) -> int:
    """Total additions to apply one quantized N-tap type-I filter (Eq. 3)."""
    n = wq.shape[-1]
    return int(n // 2 + num_pulses(np.abs(_half(wq))).sum())


def fir_blmac_additions_batch(wq: np.ndarray) -> np.ndarray:
    """Vectorized over a bank: ``wq`` is (n_filters, n_taps) int."""
    n = wq.shape[-1]
    return n // 2 + num_pulses(np.abs(_half(wq))).sum(axis=-1)


def adds_per_coeff(total_adds, n_taps: int):
    """(B_N − N/2) / (N/2 + 1) — comparable to Tab. 3's per-weight averages."""
    return (np.asarray(total_adds, np.float64) - n_taps // 2) / (n_taps // 2 + 1)


def adds_per_tap(total_adds, n_taps: int):
    return np.asarray(total_adds, np.float64) / n_taps


def classical_equivalent_adds(n_taps: int, mult_cost_adds: int = 15) -> int:
    """The paper's apples-to-apples baseline: symmetric classical algorithm
    = (N/2+1) multiplications (@ ``mult_cost_adds`` adds each for 16-bit)
    + N−1 additions."""
    return mult_cost_adds * (n_taps // 2 + 1) + n_taps - 1


def machine_cycles(
    wq: np.ndarray, n_layers: int = 16, overhead: int = 0
) -> int:
    """Clock cycles of the §4 dot-product machine for one output sample:
    one cycle per RLE code (pulse or EOR) + fixed per-sample overhead."""
    digits = csd_digits(_half(wq), n_digits=n_layers)
    return code_count(digits) + overhead


def machine_cycles_batch(
    wq: np.ndarray,
    n_layers: int = 16,
    overhead: int = 0,
    fused_last_add: bool = False,
) -> np.ndarray:
    """Vectorized :func:`machine_cycles` over a (B, taps) bank → (B,) int64.

    ``fused_last_add`` applies the §4 optimization (the last add of each
    non-empty bit layer overlaps the shift: −1 cycle per such layer, −16
    for a fully-populated 16-layer program) — matching both simulators.
    """
    wq2 = np.atleast_2d(np.asarray(wq, np.int64))
    digits = csd_digits(_half(wq2), n_digits=n_layers)  # (B, M, L)
    cycles = code_count_batch(digits) + overhead
    if fused_last_add:
        cycles = cycles - np.count_nonzero(digits.any(axis=1), axis=-1)
    return cycles
