"""The paper's cost model (§3.3): additions needed to apply a type-I FIR
filter with a BLMAC, with the symmetric pre-add optimization of Eq. 3.

    tot = N/2                              (pre-adds of symmetric samples)
        + Σ_{j<N/2+1} ntrits[|w_j|]        (BLMAC pulses)

plus the comparison baselines the paper uses.
"""
from __future__ import annotations

import json
import os
import platform
import time
from dataclasses import asdict, dataclass

import numpy as np

from .csd import csd_digits, num_pulses
from .rle import code_count, code_count_batch

__all__ = [
    "fir_blmac_additions",
    "fir_blmac_additions_batch",
    "adds_per_coeff",
    "adds_per_tap",
    "classical_equivalent_adds",
    "machine_cycles",
    "machine_cycles_batch",
    "BackendCalibration",
    "calibration_path",
    "get_calibration",
    "ensure_calibration",
    "calibrate_backend",
    "BankDispatchPlan",
    "ShardedBankPlan",
    "predict_specialized_us",
    "predict_scheduled_us",
    "predict_sharded_us",
    "predict_recovery_us",
    "predict_session_step_us",
]


def _half(wq: np.ndarray) -> np.ndarray:
    """First N//2 + 1 coefficients of a type-I (odd, symmetric) filter."""
    n = wq.shape[-1]
    if n % 2 == 0:
        raise ValueError("type-I FIR filters have an odd number of taps")
    return wq[..., : n // 2 + 1]


def fir_blmac_additions(wq: np.ndarray) -> int:
    """Total additions to apply one quantized N-tap type-I filter (Eq. 3)."""
    n = wq.shape[-1]
    return int(n // 2 + num_pulses(np.abs(_half(wq))).sum())


def fir_blmac_additions_batch(wq: np.ndarray) -> np.ndarray:
    """Vectorized over a bank: ``wq`` is (n_filters, n_taps) int."""
    n = wq.shape[-1]
    return n // 2 + num_pulses(np.abs(_half(wq))).sum(axis=-1)


def adds_per_coeff(total_adds, n_taps: int):
    """(B_N − N/2) / (N/2 + 1) — comparable to Tab. 3's per-weight averages."""
    return (np.asarray(total_adds, np.float64) - n_taps // 2) / (n_taps // 2 + 1)


def adds_per_tap(total_adds, n_taps: int):
    return np.asarray(total_adds, np.float64) / n_taps


def classical_equivalent_adds(n_taps: int, mult_cost_adds: int = 15) -> int:
    """The paper's apples-to-apples baseline: symmetric classical algorithm
    = (N/2+1) multiplications (@ ``mult_cost_adds`` adds each for 16-bit)
    + N−1 additions."""
    return mult_cost_adds * (n_taps // 2 + 1) + n_taps - 1


def machine_cycles(
    wq: np.ndarray, n_layers: int = 16, overhead: int = 0
) -> int:
    """Clock cycles of the §4 dot-product machine for one output sample:
    one cycle per RLE code (pulse or EOR) + fixed per-sample overhead."""
    digits = csd_digits(_half(wq), n_digits=n_layers)
    return code_count(digits) + overhead


# ---------------------------------------------------------------------------
# bank-dispatch cost model (the autotuner's objective function)
# ---------------------------------------------------------------------------
#
# Coarse per-dispatch latency predictions for the FIR serving paths, in
# microseconds.  Since the compiled-lowering work the constants are a
# PER-BACKEND-LANE calibration table (`BackendCalibration`): each
# execution lane — Pallas interpret, the CPU-compiled XLA path, TPU
# Mosaic, GPU Triton — carries its own set, fitted by
# `calibrate_backend()` at first compiled-sweep use and persisted next
# to the program-cache root (`calibration_path()`), so the autotuner
# ranks candidates with numbers measured on THIS machine instead of a
# reference container's.  The module-level constants below are the
# "interpret" lane's reference values (the machine the original
# BENCH_fir.json was recorded on) and double as the fallback when no
# fitted table exists; on other hardware the absolute numbers are wrong
# but the *rankings* the autotuner needs (specialized for narrow banks,
# wide-merge scheduled tiles for wide banks) are driven by the same
# op-count asymmetries.  Accuracy is ±30% on the calibration grid —
# good enough to pick a dispatch, not to replace measurement.

SPEC_CALL_US = 140.0  # per specialized-program dispatch (B=1 pallas_call)
SPEC_OP_US = 0.014  # per pulse/fold/shift op, per signal tile
PALLAS_CALL_US = 500.0  # per scheduled-bank pallas_call dispatch
STEP_US = 300.0  # per grid step: frame gather + interpret plumbing
MAC_US = 7e-5  # per int32 multiply-accumulate in a superlayer matmul
UNPACK_US = 2e-3  # per packed trit unpacked, per grid step


@dataclass(frozen=True)
class BackendCalibration:
    """Per-lane cost-model constants (all microseconds).

    ``lane`` names the execution path the constants describe:
    ``"interpret"`` (Pallas interpreter), ``"xla"`` (the CPU-compiled
    XLA lowering), ``"mosaic"`` (TPU) or ``"triton"`` (GPU).
    ``source`` records provenance: ``"reference"`` (shipped defaults)
    or ``"fitted"`` (measured on this host by `calibrate_backend`,
    ``cpu_model`` stamps which one).
    """

    lane: str
    spec_call_us: float  # per specialized-program dispatch
    spec_op_us: float  # per pulse/fold/shift op, per signal tile
    call_us: float  # per scheduled-bank kernel/jit dispatch
    step_us: float  # per grid step: frame gather + plumbing
    mac_us: float  # per int32 MAC in a superlayer contraction
    unpack_us: float  # per packed trit unpacked, per grid step
    # per MAC when the contraction runs on the f32 GEMM units — the xla
    # lane's exact-f32 superlayer dot (see `_bank_call_xla`): CPU XLA
    # vectorizes float GEMMs ~an order of magnitude harder than int32
    # loops.  0.0 = lane has no separate f32 path (falls back to mac_us).
    mac_f32_us: float = 0.0
    source: str = "reference"
    cpu_model: str = ""


# Reference calibrations per lane.  The "interpret" row IS the historic
# constant set; the compiled rows are order-of-magnitude priors that a
# `calibrate_backend()` fit replaces at first use — they only need to
# keep compiled candidates comparable amongst themselves until then.
REFERENCE_CALIBRATIONS: "dict[str, BackendCalibration]" = {
    "interpret": BackendCalibration(
        "interpret", SPEC_CALL_US, SPEC_OP_US, PALLAS_CALL_US, STEP_US,
        MAC_US, UNPACK_US,
    ),
    "xla": BackendCalibration(
        "xla", spec_call_us=60.0, spec_op_us=1e-3, call_us=80.0,
        step_us=8.0, mac_us=1.5e-4, unpack_us=4e-5, mac_f32_us=2e-5,
    ),
    "mosaic": BackendCalibration(
        "mosaic", spec_call_us=30.0, spec_op_us=2e-4, call_us=40.0,
        step_us=2.0, mac_us=2e-8, unpack_us=1e-6,
    ),
    "triton": BackendCalibration(
        "triton", spec_call_us=30.0, spec_op_us=2e-4, call_us=40.0,
        step_us=2.0, mac_us=5e-8, unpack_us=2e-6,
    ),
}


def calibration_path() -> str:
    """Where the fitted per-lane table persists: ``calibration.json``
    under ``$REPRO_CACHE_DIR`` (default ``~/.cache/repro-blmac`` — the
    same cache root serving processes use for saved programs), so a
    process calibrates once per machine, not once per run.  CI caches
    this file keyed on the runner's CPU model."""
    root = os.environ.get(
        "REPRO_CACHE_DIR",
        os.path.join(os.path.expanduser("~"), ".cache", "repro-blmac"),
    )
    return os.path.join(root, "calibration.json")


def _cpu_model() -> str:
    try:
        with open("/proc/cpuinfo") as f:
            for line in f:
                if line.startswith("model name"):
                    return line.split(":", 1)[1].strip()
    except OSError:
        pass
    return platform.processor() or platform.machine()


def _load_table() -> dict:
    try:
        with open(calibration_path()) as f:
            return json.load(f)
    except (OSError, ValueError):
        return {}


def get_calibration(lane: str) -> BackendCalibration:
    """The active `BackendCalibration` for ``lane``: the fitted entry
    from `calibration_path()` when one exists for this CPU model, else
    the reference defaults.  Pure read — never runs probes; use
    `ensure_calibration` on paths allowed to measure."""
    entry = _load_table().get(lane)
    if entry and entry.get("cpu_model") == _cpu_model():
        try:
            return BackendCalibration(**entry)
        except TypeError:  # older/foreign file layout: fall through
            pass
    if lane not in REFERENCE_CALIBRATIONS:
        raise ValueError(
            f"unknown lane {lane!r}; expected one of "
            f"{sorted(REFERENCE_CALIBRATIONS)}"
        )
    return REFERENCE_CALIBRATIONS[lane]


def ensure_calibration(lane: str) -> BackendCalibration:
    """`get_calibration`, but fit-at-first-use: when no fitted entry for
    this host exists yet, run `calibrate_backend` (seconds of probe
    measurements), persist it, and return the fit.  Any probe failure
    falls back to the reference constants — calibration is a ranking
    aid, never a hard dependency."""
    cal = get_calibration(lane)
    if cal.source == "fitted":
        return cal
    try:
        return calibrate_backend(lane)
    except Exception:
        return cal


def calibrate_backend(lane: str, repeats: int = 3) -> BackendCalibration:
    """Fit the ``lane``'s cost-model constants on THIS machine and
    persist them to `calibration_path()`.

    Probes (µs-scale, a few seconds total):

    * dispatch overhead — wall time of a warm tiny dispatch on the lane
      (a jitted no-op-sized program for ``"xla"``, a 1-step scheduled
      kernel for the Pallas lanes),
    * MAC rate — a warm ``(128, 64) @ (64, 65536)`` int32 contraction,
      the superlayer matmul's shape family,
    * unpack rate — the shift/mask trit decode over a packed operand,
    * step overhead — a framed gather per grid step.

    The specialized-path constants are scaled from the dispatch probe
    (per-pulse work shares the lane's op rate).  Lanes other than
    ``"xla"`` and ``"interpret"`` reuse the probe harness only where the
    backend is actually present; fitting a TPU lane on a CPU host
    raises.
    """
    import jax
    import jax.numpy as jnp

    if lane not in REFERENCE_CALIBRATIONS:
        raise ValueError(f"unknown lane {lane!r}")
    if lane == "mosaic" and jax.default_backend() != "tpu":
        raise RuntimeError("cannot fit the mosaic lane without a TPU")
    if lane == "triton" and jax.default_backend() != "gpu":
        raise RuntimeError("cannot fit the triton lane without a GPU")
    ref = REFERENCE_CALIBRATIONS[lane]

    def best(fn, *args):
        fn(*args)  # warm: compile + cache
        t = float("inf")
        for _ in range(repeats):
            t0 = time.perf_counter()
            fn(*args)
            t = min(t, time.perf_counter() - t0)
        return t * 1e6  # µs

    if lane in ("xla", "mosaic", "triton"):
        # dispatch: the smallest useful jitted program on the lane
        tiny = jnp.zeros((8, 8), jnp.int32)
        call_us = best(lambda a: jax.jit(jnp.sum)(a).block_until_ready(), tiny)
        # MAC rate: one superlayer-family contraction
        b, m, n = 128, 64, 65536
        d = jnp.ones((b, m), jnp.int32)
        u = jnp.ones((m, n), jnp.int32)
        dot = jax.jit(
            lambda d, u: jnp.dot(d, u, preferred_element_type=jnp.int32)
        )
        mac_us = max(
            best(lambda d, u: dot(d, u).block_until_ready(), d, u) - call_us,
            1e-3,
        ) / (b * m * n)
        # f32 GEMM rate: the same contraction on the float units (the
        # exact-f32 superlayer dot of the xla lane)
        df, uf = d.astype(jnp.float32), u.astype(jnp.float32)
        dotf = jax.jit(jnp.dot)
        mac_f32_us = max(
            best(lambda d, u: dotf(d, u).block_until_ready(), df, uf)
            - call_us,
            1e-3,
        ) / (b * m * n)
        # unpack rate: shift/mask decode of a packed operand
        words = jnp.ones((b, 16, m // 16), jnp.int32)
        shifts = 2 * jnp.arange(16, dtype=jnp.int32)

        def unpack(w):
            codes = (w[..., None] >> shifts) & 3
            return (
                (codes == 1).astype(jnp.int32)
                - (codes == 3).astype(jnp.int32)
            ).sum()

        unpack_us = max(
            best(lambda w: jax.jit(unpack)(w).block_until_ready(), words)
            - call_us,
            1e-3,
        ) / (b * 16 * m)
        # step overhead: a framed gather, the per-grid-step fixed cost
        frame = jnp.arange(4096, dtype=jnp.int32)
        idx = jnp.arange(64)[:, None] + jnp.arange(512)[None, :]
        gather = jax.jit(lambda f: f[idx].sum())
        step_us = max(
            best(lambda f: gather(f).block_until_ready(), frame) - call_us,
            0.5,
        )
        cal = BackendCalibration(
            lane=lane,
            spec_call_us=call_us,
            spec_op_us=max(mac_us * 512, 1e-5),  # per vector op per tile
            call_us=call_us,
            step_us=step_us,
            mac_us=mac_us,
            unpack_us=unpack_us,
            mac_f32_us=mac_f32_us,
            source="fitted",
            cpu_model=_cpu_model(),
        )
    else:  # "interpret": fit the dominant dispatch/step terms via a
        # real (tiny) interpreted kernel; keep reference per-op rates
        from ..kernels.blmac_fir import blmac_fir_bank
        from ..compiler.program import pack_bank_trits

        q = np.zeros((2, 15), np.int64)
        q[:, 7] = [64, 96]
        packed = pack_bank_trits(q)
        x = jnp.asarray(
            np.random.default_rng(0).integers(-128, 128, 600), jnp.int32
        )

        def run(x):
            blmac_fir_bank(
                x, packed, 15, tile=512, interpret=True, fast_path=False
            ).block_until_ready()

        one = best(run, x)  # ~1 call + 2 steps of pure overhead
        call_us = max(one * 0.4, 50.0)
        cal = BackendCalibration(
            lane=lane,
            spec_call_us=call_us * ref.spec_call_us / ref.call_us,
            spec_op_us=ref.spec_op_us,
            call_us=call_us,
            step_us=max((one - call_us) / 2, 10.0),
            mac_us=ref.mac_us,
            unpack_us=ref.unpack_us,
            source="fitted",
            cpu_model=_cpu_model(),
        )

    table = _load_table()
    table[lane] = asdict(cal)
    path = calibration_path()
    os.makedirs(os.path.dirname(path), exist_ok=True)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(table, f, indent=1)
    os.replace(tmp, path)
    return cal


@dataclass(frozen=True)
class BankDispatchPlan:
    """Autotuner verdict: how to run a (B, taps) bank over C channels.

    ``mode`` is ``"specialized"`` (per-filter pulse-baked programs) or
    ``"scheduled"`` (occupancy-grouped bank tiles).  ``lane`` is the
    execution lane the plan was costed for (``"interpret"`` — the
    historic default — or a compiled lane: ``"xla"``, ``"mosaic"``,
    ``"triton"``).  ``merge`` is the CSD-layers-per-superlayer fusion
    factor of the scheduled kernel (1 = paper-pure one matmul per bit
    layer); ``predicted_us`` is the modelled per-dispatch latency the
    plan won with.
    """

    mode: str
    tile: int
    bank_tile: int
    merge: int
    predicted_us: float
    lane: str = "interpret"
    # CSE verdict for plans tuned on an `OptimizedProgram`: "" (plain
    # program), "optimized" (the shared-row layout won), or "declined"
    # (the parent's own best plan was cheaper once the combine stage was
    # priced in — the engine then executes the parent, bit-identically)
    cse: str = ""


def predict_specialized_us(
    n_filters: int,
    channels: int,
    n_tiles: int,
    taps: int,
    mean_pulses: float,
    n_layers: int = 16,
    cal: BackendCalibration | None = None,
) -> float:
    """Modelled latency of the per-filter specialized-program loop: one
    dispatch per (filter, channel), each executing ~(folds + pulses +
    layer shifts) vector ops per signal tile.  ``cal`` selects the
    lane's constants (default: the "interpret" reference set)."""
    c = cal or REFERENCE_CALIBRATIONS["interpret"]
    ops = taps // 2 + mean_pulses + n_layers
    return n_filters * channels * (
        c.spec_call_us + n_tiles * ops * c.spec_op_us
    )


def predict_scheduled_us(
    channels: int,
    n_tiles: int,
    tile: int,
    m_pad: int,
    groups: "list[tuple[int, int, int, int]]",
    cal: BackendCalibration | None = None,
    f32_safe: bool = False,
) -> float:
    """Modelled latency of the scheduled bank path.

    ``groups`` summarizes a `BankSchedule`: one ``(n_bank_tiles,
    bank_tile, n_superlayers, n_sel_layers)`` tuple per tile group.  Cost
    per grid step = fixed step overhead + one matmul per superlayer +
    the unpack of the tile's selected trit layers.  ``cal`` selects the
    lane's constants (default: the "interpret" reference set) — the
    SAME formula ranks compiled-lane candidates, only the constants
    change.  ``f32_safe`` marks schedules whose superlayer digit bound
    admits the exact-f32 contraction (see `_bank_call_xla`): MACs are
    then priced at the lane's ``mac_f32_us`` GEMM rate when it has one.
    """
    c = cal or REFERENCE_CALIBRATIONS["interpret"]
    mac = (c.mac_f32_us or c.mac_us) if f32_safe else c.mac_us
    total = 0.0
    for n_bank_tiles, bank_tile, n_super, n_sel in groups:
        if n_sel == 0:
            continue  # zero-fill group: no kernel dispatched
        step = (
            c.step_us
            + n_super * bank_tile * m_pad * tile * mac
            + n_sel * bank_tile * m_pad * c.unpack_us
        )
        total += c.call_us + n_bank_tiles * channels * n_tiles * step
    return total


def predict_combine_us(
    n_real: int,
    n_shared: int,
    channels: int,
    n_tiles: int,
    tile: int,
    cal: BackendCalibration | None = None,
) -> float:
    """Modelled latency of the CSE combine stage: one extra dispatch
    applying the ``(n_real, n_shared)`` reuse matrix to the shared-row
    outputs — a small GEMM plus the elementwise add back into the real
    rows.  Priced at the lane's *int32* MAC rate even when the schedule
    itself is f32-safe: combined outputs reach the full int32 range, so
    the exact-f32 trick never applies to this contraction.  Zero when
    the program has no shared rows."""
    if n_shared == 0:
        return 0.0
    c = cal or REFERENCE_CALIBRATIONS["interpret"]
    signal = channels * n_tiles * tile
    return c.call_us + n_real * (n_shared + 1) * signal * c.mac_us


# ---------------------------------------------------------------------------
# mesh-aware sharded-bank cost model
# ---------------------------------------------------------------------------
#
# The sharded engine replicates per-shard bank programs over a (bank, data)
# device mesh the way the paper replicates 110-LUT machines.  Its latency
# model is CRITICAL-PATH over TWO resources: the slowest shard's device
# time (shards run concurrently on disjoint devices), and the HOST's
# total dispatch time (one python thread feeds the whole mesh, so
# per-shard dispatch work is serial and SUMS across shards — a
# specialized-mode shard costs one host dispatch per filter, which is
# why a mesh full of specialized shards is host-bound while the same
# shards as single-group scheduled programs are not).  Plus the fixed
# costs sharding adds: one queue hop per shard, and (for time-sharded
# single-channel streams) one halo ppermute per push.  The same fitted
# single-device constants feed the per-shard terms, so "does sharding
# pay at all?" is answered by the SAME model that ranks single-device
# modes: a narrow bank where dispatch overhead dominates comes back
# with n_bank_shards == 1 (the autotuner declines).

SHARD_DISPATCH_US = 250.0  # per bank-shard program dispatch, per push
HALO_EXCHANGE_US = 180.0  # per time-shard halo ppermute, per push


@dataclass(frozen=True)
class ShardedBankPlan:
    """Mesh-aware autotuner verdict for one bank on one (bank, data) mesh.

    ``n_bank_shards`` of 1 means the autotuner DECLINED to shard the
    filter axis (single-device program wins).  ``data_mode`` is how the
    second mesh axis is used: ``"none"`` (axis size 1), ``"channels"``
    (C divisible over the axis, no communication) or ``"time"`` (signal
    chunks with an overlap-save halo exchange).  ``shard_plans`` holds
    one single-device `BankDispatchPlan` per bank shard — per-shard
    mode/tile picks, exactly what the issue's heterogeneous-occupancy
    banks need.  ``predicted_us`` is the modelled critical path.
    """

    n_bank_shards: int
    n_data: int
    data_mode: str
    shard_plans: tuple
    predicted_us: float
    # CSE verdict when tuned on an `OptimizedProgram` — same convention
    # as `BankDispatchPlan.cse` ("", "optimized", or "declined")
    cse: str = ""

    @property
    def sharded(self) -> bool:
        return self.n_bank_shards > 1 or self.n_data > 1


def predict_sharded_us(
    shard_us,
    n_data: int = 1,
    data_mode: str = "none",
    host_us=None,
) -> float:
    """Critical-path latency of a sharded dispatch.

    ``shard_us`` are per-shard single-device predictions (each shard's
    slice of the data axis already folded in by the caller); shards run
    concurrently, so their DEVICE contribution is the max.  ``host_us``
    are per-shard host dispatch costs; the host is one serial resource,
    so they SUM — whichever resource saturates first is the critical
    path.  Per-shard queue overhead and the time-shard halo exchange
    are added on top."""
    shard_us = list(shard_us)
    if not shard_us:
        raise ValueError("predict_sharded_us needs at least one shard")
    n_shards = len(shard_us)
    us = max(shard_us)
    if host_us is not None:
        us = max(us, float(sum(host_us)))
    us += n_shards * SHARD_DISPATCH_US
    if data_mode == "time" and n_data > 1:
        us += HALO_EXCHANGE_US * n_data
    return us


# ---------------------------------------------------------------------------
# recovery cost model (fault-tolerant re-partition target choice)
# ---------------------------------------------------------------------------
#
# When `ShardedFilterBankEngine` loses a shard it must pick the shard
# count of the re-partitioned survivor mesh.  The choice trades a
# ONE-TIME bill (compiling the candidate's per-shard schedules, and
# replaying the in-flight chunks through the new mesh) against the
# candidate's STEADY-STATE per-push latency over however long the
# recovered mesh is expected to serve.  The constants are coarse, in
# the same fitted-on-the-reference-container spirit as the dispatch
# constants above: they only need to rank candidates (e.g. "7 fresh
# shard schedules + slightly better steady state" vs "4 likely-memoized
# shards"), not predict wall time.

RECOVERY_REPLAN_US = 2500.0  # per fresh shard subprogram: select + schedule
REPLAY_US_PER_SAMPLE = 0.5  # per in-flight output sample replayed
RECOVERY_HORIZON_PUSHES = 50.0  # pushes the recovered mesh amortizes over


def predict_recovery_us(
    steady_us: float,
    n_replanned_shards: int,
    replay_samples: int,
) -> float:
    """Modelled total cost of adopting one recovery target: the re-plan
    bill for its ``n_replanned_shards`` shard schedules, the replay of
    ``replay_samples`` in-flight output samples, and its steady-state
    per-push latency (``steady_us``, from `predict_sharded_us`) over
    the amortization horizon.  Lower is better; used by
    `ShardedFilterBankEngine` to choose the re-partition shard count."""
    return (
        n_replanned_shards * RECOVERY_REPLAN_US
        + replay_samples * REPLAY_US_PER_SAMPLE
        + RECOVERY_HORIZON_PUSHES * float(steady_us)
    )


# ---------------------------------------------------------------------------
# multi-tenant session-step cost model (admission control)
# ---------------------------------------------------------------------------
#
# `BankSessionServer` packs active sessions into the C channel lanes of
# ONE shared engine and dispatches once per step; when more sessions are
# active than the engine has lanes, a step takes several rounds.  The
# kernel computes every lane of every round whether or not it carries a
# real tenant (idle lanes are zero-padded), so a round costs the full
# dispatch plus n_slots lane-fills regardless of occupancy — which is
# exactly the asymmetry admission control needs: adding a session is
# nearly free until it spills a new round.  Same fitted-on-the-reference-
# container spirit as the constants above: ranks "admit vs reject", does
# not predict wall time.

SESSION_LANE_US = 45.0  # per channel lane staged + sliced, per round
JOURNAL_APPEND_US = 15.0  # per chunk/pull WAL record framed + written
JOURNAL_SYNC_US = 400.0  # per group-commit fsync at the end of a step


def predict_session_step_us(
    dispatch_us: float,
    n_active: int,
    n_slots: int,
    journal_us: float = 0.0,
) -> float:
    """Modelled latency of one session-server batching step with
    ``n_active`` sessions packed into ``n_slots`` shared lanes:
    ceil(n_active / n_slots) rounds, each a full ``dispatch_us`` bank
    dispatch (from `predict_specialized_us` / `predict_scheduled_us`)
    plus the per-lane staging cost of every slot in the round, plus the
    step's flat write-ahead-journal bill (``journal_us``, built by the
    server from `JOURNAL_APPEND_US` / `JOURNAL_SYNC_US` when a journal
    is attached).  ``dispatch_us`` is the CURRENT engine plan's
    prediction — on a sharded engine that plan is rebuilt by every
    fault recovery, so admission is automatically priced against the
    degraded mesh.  The server admits a session only while the
    predicted step stays inside its latency budget."""
    if n_slots < 1:
        raise ValueError("n_slots must be >= 1")
    if n_active <= 0:
        return 0.0
    rounds = -(-int(n_active) // int(n_slots))
    return rounds * (float(dispatch_us) + n_slots * SESSION_LANE_US) \
        + float(journal_us)


def machine_cycles_batch(
    wq: np.ndarray,
    n_layers: int = 16,
    overhead: int = 0,
    fused_last_add: bool = False,
) -> np.ndarray:
    """Vectorized :func:`machine_cycles` over a (B, taps) bank → (B,) int64.

    ``fused_last_add`` applies the §4 optimization (the last add of each
    non-empty bit layer overlaps the shift: −1 cycle per such layer, −16
    for a fully-populated 16-layer program) — matching both simulators.
    """
    wq2 = np.atleast_2d(np.asarray(wq, np.int64))
    digits = csd_digits(_half(wq2), n_digits=n_layers)  # (B, M, L)
    cycles = code_count_batch(digits) + overhead
    if fused_last_add:
        cycles = cycles - np.count_nonzero(digits.any(axis=1), axis=-1)
    return cycles
