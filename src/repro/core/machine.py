"""Cycle-accurate simulator of the paper's §4 BLMAC dot-product machine
(Fig. 5): right-shift BLMAC + RLE weight memory + symmetric pre-adder,
specialised for odd-tap type-I FIR filters.

We cannot synthesize LUTs in this container, so the FPGA resource numbers
of Tab. 4 are quoted from the paper; everything *behavioural* is simulated
exactly: the 8-bit RLE weight memory (256 codes), the per-code cycle count,
the right-shift accumulator with its streamed-out result bits, and the
bit-exactness of the result against the classical dot product — this is the
paper's testbench, reproduced (`tests/test_machine.py`).
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from .csd import csd_digits
from .rle import EOR, RleStream, encode_digits

__all__ = ["MachineSpec", "MachineResult", "FirBlmacMachine"]


@dataclass(frozen=True)
class MachineSpec:
    """Hardware parameters of the dot-product machine."""

    taps: int = 127
    sample_bits: int = 8
    coeff_bits: int = 16
    weight_mem_codes: int = 256
    zrun_bits: int = 6
    # §4: "perform the last addition at the end of a bit layer at the same
    # time as the shift ... would reduce the number of clock cycles by 16".
    fused_last_add: bool = False
    # fixed cycles per output sample (start/clear); the paper's ~231.6 avg
    # is consistent with 0–2 cycles of overhead on top of the code count.
    start_overhead: int = 0

    @property
    def n_half(self) -> int:
        return self.taps // 2 + 1

    @property
    def n_layers(self) -> int:
        return self.coeff_bits


@dataclass
class MachineResult:
    outputs: np.ndarray  # int64 (n_out,) exact filter outputs
    cycles: np.ndarray  # int64 (n_out,) clock cycles per output
    stream: RleStream = field(repr=False)

    @property
    def mean_cycles(self) -> float:
        return float(self.cycles.mean())


class FirBlmacMachine:
    """Behavioural + cycle model.  Program once per filter, then stream."""

    def __init__(self, spec: MachineSpec | None = None):
        # None default (not `spec=MachineSpec()`): a mutable-looking default
        # would be evaluated once at import and shared by every machine
        self.spec = spec if spec is not None else MachineSpec()
        self._stream: RleStream | None = None
        self._coeffs: np.ndarray | None = None

    # -- programming --------------------------------------------------------

    def program(self, coeffs: np.ndarray) -> RleStream:
        """Load a quantized type-I filter into the weight memory.

        Raises ``ValueError`` when the RLE program does not fit the weight
        memory — the condition that excluded ~18% of the paper's 9,900
        127-tap Hamming filters.
        """
        spec = self.spec
        coeffs = np.asarray(coeffs, np.int64)
        if coeffs.shape != (spec.taps,):
            raise ValueError(f"expected {spec.taps} taps, got {coeffs.shape}")
        if not np.array_equal(coeffs, coeffs[::-1]):
            raise ValueError("type-I FIR coefficients must be symmetric")
        lim = 1 << (spec.coeff_bits - 1)
        if coeffs.max() >= lim or coeffs.min() < -lim:
            raise ValueError(f"coefficients exceed {spec.coeff_bits} bits")
        half = coeffs[: spec.n_half]
        digits = csd_digits(half, n_digits=spec.n_layers)
        stream = encode_digits(digits, zrun_bits=spec.zrun_bits)
        if not stream.fits(spec.weight_mem_codes):
            raise ValueError(
                f"RLE program needs {stream.n_codes} codes > "
                f"{spec.weight_mem_codes}-entry weight memory"
            )
        self._stream, self._coeffs = stream, coeffs
        return stream

    # -- execution ----------------------------------------------------------

    def run(self, samples: np.ndarray) -> MachineResult:
        """Stream ``samples`` through the programmed filter.

        Produces ``len(samples) - taps + 1`` outputs, exactly like the
        paper's testbench (127 warm-up samples + one output per new
        sample), each with its cycle count.
        """
        spec = self.spec
        if self._stream is None or self._coeffs is None:
            raise RuntimeError("machine not programmed")
        x = np.asarray(samples, np.int64)
        lim = 1 << (spec.sample_bits - 1)
        if x.max() >= lim or x.min() < -lim:
            raise ValueError(f"samples exceed {spec.sample_bits} bits")
        n_out = x.size - spec.taps + 1
        if n_out <= 0:
            raise ValueError("need at least `taps` samples")
        outputs = np.empty(n_out, np.int64)
        cycles = np.empty(n_out, np.int64)
        codes = self._stream.codes
        for t in range(n_out):
            window = x[t : t + spec.taps]
            outputs[t], cycles[t] = self._apply_once(codes, window)
        return MachineResult(outputs, cycles, self._stream)

    def _apply_once(self, codes: np.ndarray, window: np.ndarray):
        """One dot product, right-shift BLMAC semantics, exact integers.

        The sample memory is addressed j (ascending) and taps-1-j
        (descending); the pre-adder folds the symmetric pair.  The centre
        tap reads the same cell on both ports, so the machine suppresses
        the second port's contribution there.  Each RLE code (pulse or
        EOR) costs one clock cycle; each EOR arithmetic-right-shifts the
        accumulator, streaming one fully-determined result bit (§2.1) into
        the output shift register.
        """
        spec = self.spec
        centre = spec.n_half - 1
        acc = 0
        low_bits = 0
        shift_count = 0
        n_cycles = spec.start_overhead
        j = 0
        layer_pulses = 0
        for c in codes:
            c = int(c)
            if c & EOR:
                low_bits |= (acc & 1) << shift_count
                shift_count += 1
                n_cycles += 1
                if spec.fused_last_add and layer_pulses:
                    n_cycles -= 1  # last add fused with the shift
                acc >>= 1  # arithmetic shift; exact two's complement
                j = 0
                layer_pulses = 0
                continue
            j += c & 0x3F  # ZRUN expansion
            pre = int(window[j])
            if j != centre:
                pre += int(window[spec.taps - 1 - j])
            acc = acc - pre if (c & 0x40) else acc + pre
            n_cycles += 1
            layer_pulses += 1
            j += 1
        # acc holds the high bits, the shift register the low n_layers bits
        return (acc << spec.n_layers) | low_bits, n_cycles
