"""Coefficient quantization, exactly as the paper does it (§3.2) — plus the
CSD-plane quantizer used by the LM serving path (DESIGN.md §2.2).

The paper: scale the float coefficients by the *largest power of two* such
that the largest coefficient still fits a signed 16-bit word, then apply
convergent rounding (round-half-to-even; numpy's ``rint``).  This fills the
full int16 range so the 16-bit pulse statistics are honest.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from .csd import csd_digits, csd_truncate, pack_trits

__all__ = [
    "po2_quantize",
    "dequantize",
    "PlaneQuantized",
    "csd_plane_quantize",
    "plane_dequantize",
]


def po2_quantize(h: np.ndarray, bits: int = 16) -> tuple[np.ndarray, int]:
    """Quantize float coefficients to ``bits``-bit signed integers.

    Returns ``(q, k)`` with ``q = rint(h * 2**k)`` and ``k`` the largest
    exponent for which every value fits ``[-(2**(bits-1)), 2**(bits-1)-1]``.
    """
    h = np.asarray(h, np.float64)
    maxabs = float(np.max(np.abs(h))) if h.size else 0.0
    if maxabs == 0.0:
        return np.zeros(h.shape, np.int32), 0
    top = float(2 ** (bits - 1) - 1)
    k = int(np.floor(np.log2(top / maxabs)))
    lo, hi = -(2 ** (bits - 1)), 2 ** (bits - 1) - 1
    # convergent rounding can tip the largest value over; back off if so
    for _ in range(4):
        q = np.rint(h * float(2.0**k))
        if q.max() <= hi and q.min() >= lo:
            break
        k -= 1
    else:  # pragma: no cover - mathematically unreachable
        raise RuntimeError("po2_quantize failed to converge")
    return q.astype(np.int64), k


def dequantize(q: np.ndarray, k: int) -> np.ndarray:
    return np.asarray(q, np.float64) * float(2.0**-k)


def po2_quantize_batch(
    bank: np.ndarray, bits: int = 16
) -> tuple[np.ndarray, np.ndarray]:
    """Row-wise :func:`po2_quantize` for a (n_filters, n_taps) bank.

    Returns ``(q, k)`` with per-row exponents; vectorized (the 1.98M-filter
    sweep calls this 202 times on 9,900-row banks).
    """
    bank = np.asarray(bank, np.float64)
    maxabs = np.abs(bank).max(axis=-1)
    maxabs = np.where(maxabs == 0.0, 1.0, maxabs)
    top = float(2 ** (bits - 1) - 1)
    lo, hi = -(2 ** (bits - 1)), 2 ** (bits - 1) - 1
    k = np.floor(np.log2(top / maxabs)).astype(np.int64)
    for _ in range(4):
        q = np.rint(bank * np.exp2(k.astype(np.float64))[..., None])
        over = (q.max(axis=-1) > hi) | (q.min(axis=-1) < lo)
        if not over.any():
            break
        k = np.where(over, k - 1, k)
    else:  # pragma: no cover
        raise RuntimeError("po2_quantize_batch failed to converge")
    return q.astype(np.int64), k


# ---------------------------------------------------------------------------
# CSD-P plane quantization: keep only the P most-significant pulses of each
# weight.  This is the paper's "naturally variable precision" observation
# (§2) used as a *quantizer*: storage is P × 2-bit planes instead of 16 bits,
# which is what a memory-bound decode roofline wants.
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class PlaneQuantized:
    """A weight matrix stored as packed CSD trit planes.

    ``planes_packed``: uint32, shape ``W.shape[:-1] + (n_digits, ceil(last/16))``
    — plane ``i`` holds the digit of weight ``2**i`` for each entry, packed
    16 trits/word along the (contracted) last axis.
    """

    planes_packed: np.ndarray
    n_digits: int
    n: int  # unpacked size of the packed axis
    exponent: int  # dequant scale is 2**-exponent
    keep_planes: int

    @property
    def bits_per_weight(self) -> float:
        """Storage cost of the packed representation."""
        return 2.0 * self.n_digits


def csd_plane_quantize(
    w: np.ndarray, bits: int = 16, keep_pulses: int | None = None
) -> PlaneQuantized:
    """Quantize float weights to int, CSD-encode, optionally truncate to the
    ``keep_pulses`` most significant pulses, and pack 2-bit trit planes.

    The packed axis is the *last* axis of ``w`` (the contraction axis of
    ``x @ W`` should be moved there by the caller).
    """
    q, k = po2_quantize(w, bits)
    if keep_pulses is not None:
        q = csd_truncate(q, keep_pulses, n_digits=bits + 1)
    digits = csd_digits(q, n_digits=bits + 1)  # (..., n, n_digits)
    # drop empty leading planes (cheap static compression)
    nz = np.nonzero(np.any(digits != 0, axis=tuple(range(digits.ndim - 1))))[0]
    n_digits = int(nz.max()) + 1 if nz.size else 1
    digits = digits[..., :n_digits]
    planes = np.moveaxis(digits, -1, -2)  # (..., n_digits, n)
    return PlaneQuantized(
        planes_packed=pack_trits(planes),
        n_digits=n_digits,
        n=w.shape[-1],
        exponent=k,
        keep_planes=keep_pulses if keep_pulses is not None else bits + 1,
    )


def plane_dequantize(pq: PlaneQuantized) -> np.ndarray:
    """Reconstruct float weights from packed planes (the numpy oracle)."""
    from .csd import unpack_trits

    planes = unpack_trits(pq.planes_packed, pq.n).astype(np.int64)
    scale = (np.int64(1) << np.arange(pq.n_digits, dtype=np.int64))
    q = np.tensordot(
        np.moveaxis(planes, -2, -1), scale, axes=([-1], [0])
    )  # (..., n)
    return q.astype(np.float64) * float(2.0**-pq.exponent)
