"""Canonical signed-digit (ternary) weight codec — the heart of BLMAC.

The paper (§2) represents each integer weight as ``w = Σ_i d_i 2^i`` with
``d_i ∈ {-1, 0, +1}`` ("trits"); every non-zero trit is a *pulse* and costs
exactly one add/sub cycle in a BLMAC.  We use the non-adjacent form (NAF),
the canonical signed-digit recoding, which provably minimizes the number of
non-zero digits and reproduces the paper's Tab. 3 statistics exactly
(avg ~2.77 pulses for 7-bit, max ⌈(n+1)/2⌉ pulses for n-bit).

Everything here is vectorized numpy; LSB-first digit order throughout
(digit ``[..., i]`` weighs ``2**i``) — the right-shift BLMAC processes
layers in exactly this order.
"""
from __future__ import annotations

import numpy as np

__all__ = [
    "csd_digits",
    "csd_decode",
    "num_pulses",
    "ntrits_table",
    "max_pulses",
    "csd_truncate",
    "pack_trits",
    "unpack_trits",
    "packed_pulse_counts",
    "require_type1",
    "assert_int32_bound",
    "layer_occupancy",
    "layer_pulse_counts",
    "occupancy_signatures",
]


def require_type1(w, what: str = "filter") -> int:
    """Validate odd symmetric (type-I) coefficients — the precondition of
    the BLMAC symmetric fold (Eq. 3).  Accepts one filter (taps,) or a
    bank (B, taps); returns the tap count."""
    w2 = np.atleast_2d(np.asarray(w))
    taps = int(w2.shape[-1])
    if taps % 2 == 0 or not np.array_equal(w2, w2[..., ::-1]):
        raise ValueError(f"{what} needs odd symmetric (type-I) coefficients")
    return taps


def assert_int32_bound(w, sample_bits: int = 8, what: str = "filter bank") -> int:
    """Assert the BLMAC accumulator fits int32 — checked ONCE at pack time.

    This is the §2.1 claim ("16-bit coeffs × 8-bit samples × ≤255 taps fits
    32 bits") made load-bearing: every BLMAC accumulator in this repo —
    the Pallas kernels, `blmac_fir_dynamic`, `FilterBankEngine` — carries
    int32, so this single pack-time check covers every call site.

    The checked quantity is the final-sum bound Σ|w_j|·max|x| plus a
    partial-Horner slack of 2·M·max|x|: after processing layers ≥ lo the
    accumulator holds (w_prefix/2^lo)·u, and a signed-CSD prefix can
    exceed |w| by the discarded NAF tail (< 2^lo per coefficient) — e.g.
    NAF(7) = +8−1, whose prefix is 8.  That slack is ≤ 2·max|x| per
    folded row, taps·max|x| total, far below the headroom at the paper's
    operating point (255·2^15·2^7 ≈ 2^30).  Returns the final-sum bound.
    """
    w2 = np.atleast_2d(np.asarray(w, np.int64))
    taps = w2.shape[-1]
    xmax = np.int64(1) << (sample_bits - 1)
    bound = int(np.abs(w2).sum(axis=-1).max(initial=0) * xmax)
    slack = (taps // 2 + 1) * int(xmax) * 2  # NAF-prefix excess, see above
    if bound + slack >= 1 << 31:
        raise OverflowError(
            f"{what}: worst-case accumulator Σ|w|·2^{sample_bits - 1} "
            f"(+{slack} partial-sum slack) = {bound + slack} overflows "
            f"int32 — reduce coeff bits, taps, or sample_bits"
        )
    return bound


def layer_occupancy(digits: np.ndarray) -> np.ndarray:
    """(…, M, L) CSD digits → bool (…, L): which bit layers hold ≥1 pulse.

    The layer-skip schedule of the bank kernel is built from this: a layer
    empty across a whole bank tile costs zero kernel iterations.
    """
    return np.any(np.asarray(digits) != 0, axis=-2)


def layer_pulse_counts(digits: np.ndarray) -> np.ndarray:
    """(…, M, L) CSD digits → int64 (…, L) pulses per bit layer (the
    autotuner's per-layer work predictor)."""
    return np.count_nonzero(np.asarray(digits), axis=-2).astype(np.int64)


def occupancy_signatures(occ: np.ndarray) -> np.ndarray:
    """Bool (…, L) occupancy → uint64 (…,) bitmask (bit i = layer i
    populated).  Filters sharing a signature schedule identically, so
    sorting on it groups bank tiles into occupancy-homogeneous runs."""
    occ = np.asarray(occ, bool)
    if occ.shape[-1] > 64:
        raise ValueError("occupancy signatures support at most 64 layers")
    weights = np.uint64(1) << np.arange(occ.shape[-1], dtype=np.uint64)
    return (occ * weights).sum(axis=-1, dtype=np.uint64)


def _as_int64(w) -> np.ndarray:
    a = np.asarray(w)
    if a.dtype.kind not in "iu":
        raise TypeError(f"CSD encoding requires integer input, got {a.dtype}")
    return a.astype(np.int64)


def csd_digits(w, n_digits: int | None = None) -> np.ndarray:
    """NAF/CSD digits of integer array ``w``.

    Returns int8 array of shape ``w.shape + (n_digits,)``, LSB first, each
    digit in {-1, 0, +1}, satisfying ``Σ_i d[..., i] * 2**i == w``.

    ``n_digits`` defaults to the minimum that can represent ``max |w|``
    (NAF of an n-bit magnitude may need n+1 digit positions).
    """
    w = _as_int64(w)
    if n_digits is None:
        maxabs = int(np.max(np.abs(w))) if w.size else 0
        n_digits = max(1, maxabs.bit_length() + 1)
    digits = np.zeros(w.shape + (n_digits,), dtype=np.int8)
    rem = w.copy()
    for i in range(n_digits):
        odd = (rem & 1).astype(bool)
        # For odd rem, pick d = ±1 so that rem - d ≡ 0 (mod 4)  →  NAF.
        mod4 = rem & 3
        d = np.where(odd, np.where(mod4 == 1, 1, -1), 0).astype(np.int64)
        digits[..., i] = d
        rem = (rem - d) >> 1
    if np.any(rem != 0):
        bad = int(np.max(np.abs(w)))
        raise ValueError(
            f"n_digits={n_digits} too small for values up to |{bad}|"
        )
    return digits


def csd_decode(digits: np.ndarray) -> np.ndarray:
    """Inverse of :func:`csd_digits` (works for any {-1,0,1} digit tensor)."""
    d = np.asarray(digits, dtype=np.int64)
    weights = np.int64(1) << np.arange(d.shape[-1], dtype=np.int64)
    return (d * weights).sum(axis=-1)


def num_pulses(w) -> np.ndarray:
    """Number of BLMAC additions (non-zero NAF trits) for each weight.

    Sign-independent (paper §2.3: a negative number costs the same).
    """
    d = csd_digits(np.abs(_as_int64(w)))
    return np.count_nonzero(d, axis=-1)


_NTRITS_CACHE: dict[int, np.ndarray] = {}


def ntrits_table(bits: int = 15) -> np.ndarray:
    """The paper's precomputed ``ntrits[]`` array (§3.3): pulse count for
    every magnitude in ``[0, 2**bits)``.  Cached; ~32k uint8 for bits=15."""
    if bits not in _NTRITS_CACHE:
        values = np.arange(1 << bits, dtype=np.int64)
        _NTRITS_CACHE[bits] = num_pulses(values).astype(np.uint8)
    return _NTRITS_CACHE[bits]


def max_pulses(bits: int) -> int:
    """Worst-case pulses for a ``bits``-bit magnitude: ⌈(bits+1)/2⌉ (Tab. 3)."""
    return (bits + 2) // 2


def csd_truncate(w, planes: int, n_digits: int | None = None) -> np.ndarray:
    """Keep only the ``planes`` most-significant *pulses* of each weight.

    This is the "variable precision" property of §2 turned into a
    quantizer: a weight rounded to ≤ ``planes`` signed powers of two.
    Greedy MSB-first on the NAF digits; exact when the weight already has
    ≤ ``planes`` pulses.  Returns the truncated integer values.
    """
    d = csd_digits(w, n_digits).astype(np.int64)
    nz = d != 0
    # rank pulses MSB→LSB: cumulative count of non-zeros from the top
    rank = np.cumsum(nz[..., ::-1], axis=-1)[..., ::-1]
    keep = nz & (rank <= planes)
    return csd_decode(np.where(keep, d, 0))


# ---------------------------------------------------------------------------
# 2-bit trit packing — the TPU-side storage format (DESIGN.md §2.2).
# Code: 0b00 = 0, 0b01 = +1, 0b11 = -1 (0b10 unused).  16 trits / int32.
# ---------------------------------------------------------------------------

def pack_trits(digits: np.ndarray) -> np.ndarray:
    """Pack a {-1,0,1} int8 tensor into uint32 along the last axis
    (16 trits per word, little-endian trit order).  Pads with zeros."""
    d = np.asarray(digits)
    n = d.shape[-1]
    n_words = (n + 15) // 16
    pad = n_words * 16 - n
    if pad:
        d = np.concatenate([d, np.zeros(d.shape[:-1] + (pad,), d.dtype)], -1)
    codes = np.where(d == 0, 0, np.where(d > 0, 1, 3)).astype(np.uint32)
    codes = codes.reshape(d.shape[:-1] + (n_words, 16))
    shifts = (2 * np.arange(16, dtype=np.uint32))[None]
    return (codes << shifts).sum(axis=-1, dtype=np.uint32)


def unpack_trits(words: np.ndarray, n: int) -> np.ndarray:
    """Inverse of :func:`pack_trits`; returns int8 of last-dim size ``n``."""
    w = np.asarray(words, dtype=np.uint32)
    shifts = (2 * np.arange(16, dtype=np.uint32))[None]
    codes = (w[..., None] >> shifts) & np.uint32(3)
    trits = np.where(codes == 1, 1, np.where(codes == 3, -1, 0)).astype(np.int8)
    out = trits.reshape(w.shape[:-1] + (w.shape[-1] * 16,))
    return out[..., :n]


def packed_pulse_counts(packed: np.ndarray) -> np.ndarray:
    """(B, n_layers, n_words) packed trit words → (B,) int64 non-zero trit
    (= BLMAC pulse, §3.3) counts per filter, read straight off the 2-bit
    codes without unpacking.  The single popcount shared by
    `repro.compiler.BlmacProgram` and the shard balancer
    (`repro.distributed.sharding.bank_filter_costs`)."""
    w = np.asarray(packed, dtype=np.uint32)
    shifts = 2 * np.arange(16, dtype=np.uint32)
    codes = (w[..., None] >> shifts) & np.uint32(3)
    return (codes != 0).sum(axis=(1, 2, 3)).astype(np.int64)
