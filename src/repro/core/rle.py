"""Run-length coding of bit layers — the BLMAC weight memory format (§2.4).

Each bit layer of the CSD digit matrix is a stream of (S, ZRUN) pairs —
``S`` the ±1 pulse sign, ``ZRUN`` the number of zero coefficients skipped
before it — terminated by an End-Of-Run (EOR) code; an empty layer is a
bare EOR.  The paper's 127-tap machine stores these in a 256×8 distributed
memory; our concrete 8-bit code packing (which fits that memory exactly):

    bit 7      EOR flag (1 ⇒ end of layer; other bits ignored)
    bit 6      S: 0 ⇒ +1, 1 ⇒ −1
    bits 5..0  ZRUN (0..63) — enough for the 64 unique coefficients of a
               symmetric 127-tap filter

Layers are emitted LSB-first, matching the right-shift BLMAC.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

EOR = 0x80
_SIGN = 0x40

__all__ = [
    "EOR",
    "RleStream",
    "RleBatch",
    "encode_digits",
    "encode_digits_batch",
    "decode_codes",
    "code_count",
    "code_count_batch",
    "max_zrun_batch",
]


@dataclass(frozen=True)
class RleStream:
    """A packed BLMAC weight program."""

    codes: np.ndarray  # uint8 (n_codes,)
    n_coeffs: int
    n_layers: int

    @property
    def n_codes(self) -> int:
        return int(self.codes.size)

    @property
    def n_pulses(self) -> int:
        return int(np.count_nonzero((self.codes & EOR) == 0))

    def fits(self, mem_codes: int = 256) -> bool:
        """Does the program fit the machine's weight memory?  The paper's
        256-entry memory rejects ~18% of the 127-tap Hamming filters."""
        return self.n_codes <= mem_codes


def encode_digits(digits: np.ndarray, zrun_bits: int = 6) -> RleStream:
    """Encode a CSD digit matrix (n_coeffs, n_layers), LSB-first layers.

    Raises ``ValueError`` if any zero-run exceeds the ZRUN field — the
    hardware analogue of a mis-sized run-length field.
    """
    d = np.asarray(digits)
    if d.ndim != 2:
        raise ValueError(f"digits must be (n_coeffs, n_layers), got {d.shape}")
    n_coeffs, n_layers = d.shape
    max_run = (1 << zrun_bits) - 1
    codes: list[int] = []
    for layer in range(n_layers):  # LSB first
        run = 0
        col = d[:, layer]
        for j in range(n_coeffs):
            t = int(col[j])
            if t == 0:
                run += 1
                continue
            if run > max_run:
                raise ValueError(
                    f"zero-run {run} exceeds {zrun_bits}-bit ZRUN field"
                )
            codes.append((_SIGN if t < 0 else 0) | run)
            run = 0
        codes.append(EOR)
    return RleStream(np.asarray(codes, np.uint8), n_coeffs, n_layers)


def decode_codes(stream: RleStream) -> np.ndarray:
    """Inverse of :func:`encode_digits`: codes → (n_coeffs, n_layers) int8."""
    d = np.zeros((stream.n_coeffs, stream.n_layers), np.int8)
    layer = 0
    j = 0
    for c in stream.codes:
        c = int(c)
        if c & EOR:
            layer += 1
            j = 0
            continue
        j += c & 0x3F
        d[j, layer] = -1 if (c & _SIGN) else 1
        j += 1
    if layer != stream.n_layers:
        raise ValueError(f"expected {stream.n_layers} EORs, saw {layer}")
    return d


def code_count(digits: np.ndarray) -> int:
    """#codes = #pulses + #layers — the machine's weight-memory footprint
    and (bar fixed overhead) its cycle count per output sample."""
    d = np.asarray(digits)
    return int(np.count_nonzero(d)) + d.shape[-1]


# ---------------------------------------------------------------------------
# bank-level (vectorized) encoding — the weight programs of a whole filter
# bank in numpy array ops, no per-code Python loop
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class RleBatch:
    """Weight programs for a whole bank, one padded row per filter.

    ``codes[b, :n_codes[b]]`` is exactly ``encode_digits(digits[b]).codes``;
    entries past ``n_codes[b]`` are zero padding and carry no meaning.
    """

    codes: np.ndarray  # uint8 (B, max_codes), rows zero-padded
    n_codes: np.ndarray  # int64 (B,)
    n_coeffs: int
    n_layers: int

    def __len__(self) -> int:
        return int(self.codes.shape[0])

    def stream(self, b: int) -> RleStream:
        """The b-th filter's program as a scalar :class:`RleStream`."""
        return RleStream(
            self.codes[b, : self.n_codes[b]].copy(),
            self.n_coeffs,
            self.n_layers,
        )

    @property
    def n_pulses(self) -> np.ndarray:
        return self.n_codes - self.n_layers

    def fits(self, mem_codes: int = 256) -> np.ndarray:
        """(B,) bool — which programs fit a ``mem_codes``-entry memory."""
        return self.n_codes <= mem_codes


def encode_digits_batch(digits: np.ndarray, zrun_bits: int = 6) -> RleBatch:
    """Vectorized :func:`encode_digits` over a bank.

    ``digits`` is (B, n_coeffs, n_layers) in {-1, 0, 1}.  The whole bank is
    encoded with a handful of numpy passes (nonzero + bincount + two
    scatters); per-row results are bit-identical to the scalar encoder.
    Raises ``ValueError`` if any zero-run in any filter overflows the ZRUN
    field, like the scalar path.
    """
    d = np.asarray(digits)
    if d.ndim != 3:
        raise ValueError(f"digits must be (B, n_coeffs, n_layers), got {d.shape}")
    n_bank, n_coeffs, n_layers = d.shape
    max_run = (1 << zrun_bits) - 1
    dT = d.transpose(0, 2, 1)  # (B, L, C): layer-major, LSB first
    b_idx, l_idx, j_idx = np.nonzero(dT)  # lexicographic (b, l, j) order
    signs = dT[b_idx, l_idx, j_idx]
    # zero-run preceding each pulse: distance to the previous pulse in the
    # same (filter, layer), or to the start of the layer
    same = np.zeros(b_idx.size, bool)
    same[1:] = (b_idx[1:] == b_idx[:-1]) & (l_idx[1:] == l_idx[:-1])
    prev_end = np.concatenate([[0], j_idx[:-1] + 1])
    runs = j_idx - np.where(same, prev_end, 0)
    if runs.size and runs.max() > max_run:
        bad = int(runs.max())
        raise ValueError(f"zero-run {bad} exceeds {zrun_bits}-bit ZRUN field")
    pulse_codes = (np.where(signs < 0, _SIGN, 0) | runs).astype(np.uint8)
    # stream position of each pulse: pulses before it in its row + one EOR
    # per earlier layer (l_idx)
    pulses_per_row = np.bincount(b_idx, minlength=n_bank)
    row_start = np.concatenate([[0], np.cumsum(pulses_per_row)])[:-1]
    pulse_pos = np.arange(b_idx.size) - row_start[b_idx] + l_idx
    # EOR of (b, l) sits after every pulse of layers <= l and l earlier EORs
    pulses_per_bl = np.bincount(
        b_idx * n_layers + l_idx, minlength=n_bank * n_layers
    ).reshape(n_bank, n_layers)
    eor_pos = np.cumsum(pulses_per_bl, axis=1) + np.arange(n_layers)
    n_codes = pulses_per_row + n_layers
    max_codes = int(n_codes.max()) if n_bank else 0  # B=0: empty batch
    codes = np.zeros((n_bank, max_codes), np.uint8)
    codes[np.repeat(np.arange(n_bank), n_layers), eor_pos.ravel()] = EOR
    codes[b_idx, pulse_pos] = pulse_codes
    return RleBatch(codes, n_codes.astype(np.int64), n_coeffs, n_layers)


def code_count_batch(digits: np.ndarray) -> np.ndarray:
    """Vectorized :func:`code_count`: (..., n_coeffs, n_layers) digit
    tensors → (...,) int64 code counts (pulses + one EOR per layer)."""
    d = np.asarray(digits)
    if d.ndim < 2:
        raise ValueError("digits need at least (n_coeffs, n_layers) axes")
    return (
        np.count_nonzero(d, axis=(-2, -1)).astype(np.int64) + d.shape[-1]
    )


def max_zrun_batch(digits: np.ndarray) -> np.ndarray:
    """(B, n_coeffs, n_layers) → (B,) longest zero-run *preceding a pulse*
    in any layer — the quantity the ZRUN field must hold.  Trailing zeros
    of a layer are never encoded and do not count (a filter fits iff
    ``max_zrun_batch(d) <= 2**zrun_bits - 1``, matching exactly where the
    encoders raise)."""
    d = np.asarray(digits)
    if d.ndim != 3:
        raise ValueError(f"digits must be (B, n_coeffs, n_layers), got {d.shape}")
    nz = d != 0
    j = np.arange(d.shape[1])[None, :, None]
    prev_end = np.maximum.accumulate(np.where(nz, j + 1, 0), axis=1)
    shifted = np.concatenate(
        [np.zeros_like(prev_end[:, :1]), prev_end[:, :-1]], axis=1
    )
    runs = np.where(nz, j - shifted, 0)
    if not runs.size:
        return np.zeros(d.shape[0], np.int64)
    return runs.max(axis=(1, 2)).astype(np.int64)
