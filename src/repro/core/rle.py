"""Run-length coding of bit layers — the BLMAC weight memory format (§2.4).

Each bit layer of the CSD digit matrix is a stream of (S, ZRUN) pairs —
``S`` the ±1 pulse sign, ``ZRUN`` the number of zero coefficients skipped
before it — terminated by an End-Of-Run (EOR) code; an empty layer is a
bare EOR.  The paper's 127-tap machine stores these in a 256×8 distributed
memory; our concrete 8-bit code packing (which fits that memory exactly):

    bit 7      EOR flag (1 ⇒ end of layer; other bits ignored)
    bit 6      S: 0 ⇒ +1, 1 ⇒ −1
    bits 5..0  ZRUN (0..63) — enough for the 64 unique coefficients of a
               symmetric 127-tap filter

Layers are emitted LSB-first, matching the right-shift BLMAC.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

EOR = 0x80
_SIGN = 0x40

__all__ = ["EOR", "RleStream", "encode_digits", "decode_codes", "code_count"]


@dataclass(frozen=True)
class RleStream:
    """A packed BLMAC weight program."""

    codes: np.ndarray  # uint8 (n_codes,)
    n_coeffs: int
    n_layers: int

    @property
    def n_codes(self) -> int:
        return int(self.codes.size)

    @property
    def n_pulses(self) -> int:
        return int(np.count_nonzero((self.codes & EOR) == 0))

    def fits(self, mem_codes: int = 256) -> bool:
        """Does the program fit the machine's weight memory?  The paper's
        256-entry memory rejects ~18% of the 127-tap Hamming filters."""
        return self.n_codes <= mem_codes


def encode_digits(digits: np.ndarray, zrun_bits: int = 6) -> RleStream:
    """Encode a CSD digit matrix (n_coeffs, n_layers), LSB-first layers.

    Raises ``ValueError`` if any zero-run exceeds the ZRUN field — the
    hardware analogue of a mis-sized run-length field.
    """
    d = np.asarray(digits)
    if d.ndim != 2:
        raise ValueError(f"digits must be (n_coeffs, n_layers), got {d.shape}")
    n_coeffs, n_layers = d.shape
    max_run = (1 << zrun_bits) - 1
    codes: list[int] = []
    for layer in range(n_layers):  # LSB first
        run = 0
        col = d[:, layer]
        for j in range(n_coeffs):
            t = int(col[j])
            if t == 0:
                run += 1
                continue
            if run > max_run:
                raise ValueError(
                    f"zero-run {run} exceeds {zrun_bits}-bit ZRUN field"
                )
            codes.append((_SIGN if t < 0 else 0) | run)
            run = 0
        codes.append(EOR)
    return RleStream(np.asarray(codes, np.uint8), n_coeffs, n_layers)


def decode_codes(stream: RleStream) -> np.ndarray:
    """Inverse of :func:`encode_digits`: codes → (n_coeffs, n_layers) int8."""
    d = np.zeros((stream.n_coeffs, stream.n_layers), np.int8)
    layer = 0
    j = 0
    for c in stream.codes:
        c = int(c)
        if c & EOR:
            layer += 1
            j = 0
            continue
        j += c & 0x3F
        d[j, layer] = -1 if (c & _SIGN) else 1
        j += 1
    if layer != stream.n_layers:
        raise ValueError(f"expected {stream.n_layers} EORs, saw {layer}")
    return d


def code_count(digits: np.ndarray) -> int:
    """#codes = #pulses + #layers — the machine's weight-memory footprint
    and (bar fixed overhead) its cycle count per output sample."""
    d = np.asarray(digits)
    return int(np.count_nonzero(d)) + d.shape[-1]
