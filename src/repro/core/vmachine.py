"""Vectorized, bank-batched simulator of the §4 right-shift BLMAC machine.

`FirBlmacMachine` (`core/machine.py`) walks the RLE weight program one code
at a time for every output sample — faithful to the hardware, but minutes
of interpreter time for the paper's 9,900-filter Table 4 sweep.  This
module simulates the *same* datapath for a whole ``(B, taps)`` bank against
a whole signal in numpy array ops:

  * the per-layer partial sums Σ_j d[b,j,l]·u[j,t] (what the machine's
    pulse adds accumulate between two EORs) are ONE matrix product per
    bank — the (B·L, M) digit matrix times the (M, n_out) symmetric-folded
    window matrix, evaluated in float64 BLAS (exact: every addend is an
    integer of magnitude ≤ M·2^sample_bits ≪ 2^53) and cast back to int64;
  * the right-shift accumulator is then replayed layer-by-layer (one pass
    per bit layer, vectorized over every filter and every output sample):
    add the layer sum, stream the accumulator LSB into the output shift
    register, arithmetic-shift right — bit-for-bit what `_apply_once` does
    per code, including the final ``(acc << n_layers) | low_bits`` splice;
  * cycle counts are data-independent (one cycle per RLE code, §4), so the
    per-output cycle matrix is the bank's code-count vector broadcast over
    outputs — with the ``fused_last_add`` −1-per-non-empty-layer rebate
    and ``start_overhead`` applied exactly as in the scalar machine.

Weight-memory behaviour is also reproduced bank-wide: `program_bank`
returns a boolean *fit* mask instead of raising per filter, flagging the
~18% of 127-tap Hamming filters whose RLE program overflows the 256-entry
memory (and any filter whose zero-run overflows the ZRUN field).  The
scalar machine stays the trusted reference; `tests/differential.py` proves
outputs, cycles, and overflow decisions identical on every tested bank.

Cycle → paper mapping (Tab. 4): mean cycles per output over the full
9,900-filter 127-tap Hamming bank ≈ 231.6; `benchmarks/table4_machine.py`
reproduces that figure with this simulator in seconds.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from .csd import csd_digits
from .machine import MachineSpec
from .rle import (RleBatch, code_count_batch, encode_digits_batch,
                  max_zrun_batch)

__all__ = ["VMachineResult", "FirBlmacVMachine", "simulate_bank"]


@dataclass
class VMachineResult:
    """Bank-level analogue of `MachineResult`.

    ``outputs[b]`` / ``cycles[b]`` are defined for every filter, including
    the ones that do NOT fit the weight memory (the arithmetic is the same
    dot product either way); ``fits`` says which rows a real machine could
    actually be programmed with.
    """

    outputs: np.ndarray  # int64 (B, n_out) exact filter outputs
    cycles: np.ndarray  # int64 (B, n_out) clock cycles per output
    fits: np.ndarray = field(repr=False)  # bool (B,)

    @property
    def mean_cycles(self) -> float:
        """Mean cycles per output over the whole bank (all filters)."""
        return float(self.cycles.mean())

    @property
    def mean_cycles_fitting(self) -> float:
        """Mean cycles over the filters that fit the weight memory."""
        if not self.fits.any():
            return float("nan")
        return float(self.cycles[self.fits].mean())


class FirBlmacVMachine:
    """Program a bank once, then stream signals through every filter at
    once.  Mirrors `FirBlmacMachine`'s two-phase API (program → run)."""

    def __init__(self, spec: MachineSpec | None = None):
        self.spec = spec if spec is not None else MachineSpec()
        self._digits: np.ndarray | None = None  # (B, M, L) int8
        self._fits: np.ndarray | None = None  # (B,) bool
        self._n_codes: np.ndarray | None = None  # (B,) int64
        self._cycles: np.ndarray | None = None  # (B,) int64

    # -- programming --------------------------------------------------------

    def program_bank(self, qbank: np.ndarray) -> np.ndarray:
        """Load a quantized type-I filter bank; returns the (B,) fit mask.

        Validation errors that a designer must fix (wrong tap count,
        asymmetry, out-of-range coefficients) raise, exactly like the
        scalar `program`; the *data-dependent* rejections (RLE program
        longer than the weight memory, zero-run overflowing the ZRUN
        field) come back as ``False`` entries of the mask so a sweep can
        tally them — the paper's ~18% figure.
        """
        spec = self.spec
        qbank = np.atleast_2d(np.asarray(qbank, np.int64))
        if qbank.ndim != 2 or qbank.shape[1] != spec.taps:
            raise ValueError(
                f"expected (B, {spec.taps}) coefficients, got {qbank.shape}"
            )
        if not np.array_equal(qbank, qbank[:, ::-1]):
            raise ValueError("type-I FIR coefficients must be symmetric")
        lim = 1 << (spec.coeff_bits - 1)
        if qbank.max() >= lim or qbank.min() < -lim:
            raise ValueError(f"coefficients exceed {spec.coeff_bits} bits")
        digits = csd_digits(qbank[:, : spec.n_half], n_digits=spec.n_layers)
        n_codes = code_count_batch(digits)
        zrun_ok = max_zrun_batch(digits) <= (1 << spec.zrun_bits) - 1
        fits = (n_codes <= spec.weight_mem_codes) & zrun_ok
        self._digits = digits
        self._fits = fits
        self._n_codes = n_codes
        self._cycles = n_codes + spec.start_overhead
        if spec.fused_last_add:
            # §4: the last add of a non-empty layer happens during the shift
            nonempty = np.count_nonzero(
                digits.any(axis=1), axis=-1
            ).astype(np.int64)
            self._cycles = self._cycles - nonempty
        return fits

    @property
    def code_counts(self) -> np.ndarray:
        """(B,) RLE codes per programmed filter (pulses + one EOR per
        layer) — the weight-memory footprint, independent of spec
        variants like ``fused_last_add``."""
        if self._n_codes is None:
            raise RuntimeError("machine not programmed")
        return self._n_codes

    def programs(self) -> RleBatch:
        """The programmed bank's RLE weight programs (vectorized encode).

        Raises on ZRUN overflow like the scalar encoder — call only when
        every filter passed the fit mask, or slice the bank first.
        """
        if self._digits is None:
            raise RuntimeError("machine not programmed")
        return encode_digits_batch(self._digits, zrun_bits=self.spec.zrun_bits)

    # -- execution ----------------------------------------------------------

    def run(self, samples: np.ndarray) -> VMachineResult:
        """Stream ``samples`` (T,) through every programmed filter.

        Returns outputs and per-output cycle counts of shape
        ``(B, T - taps + 1)``, bit-exact against running the scalar
        machine once per filter.
        """
        spec = self.spec
        if self._digits is None:
            raise RuntimeError("machine not programmed")
        x = np.asarray(samples, np.int64)
        if x.ndim != 1:
            raise ValueError(f"samples must be 1-D, got shape {x.shape}")
        lim = 1 << (spec.sample_bits - 1)
        if x.size and (x.max() >= lim or x.min() < -lim):
            raise ValueError(f"samples exceed {spec.sample_bits} bits")
        n_out = x.size - spec.taps + 1
        if n_out <= 0:
            raise ValueError("need at least `taps` samples")
        u = _folded_windows(x, spec.taps)  # (M, n_out)
        layer_sums = _layer_sums(self._digits, u, spec.sample_bits)
        outputs = _right_shift_accumulate(layer_sums)
        # cycles are data-independent (§4: one clock per RLE code), so the
        # per-output matrix is a zero-copy read-only broadcast of the
        # per-filter vector
        cycles = np.broadcast_to(self._cycles[:, None], outputs.shape)
        return VMachineResult(outputs, cycles, self._fits.copy())


def _folded_windows(x: np.ndarray, taps: int) -> np.ndarray:
    """(T,) → (M, n_out) symmetric pre-adder outputs: row j is
    x[t+j] + x[t+taps−1−j] for j < centre, the bare centre tap at j=centre
    — the machine's two sample-memory ports plus the Eq. 3 fold."""
    half = taps // 2
    win = np.lib.stride_tricks.sliding_window_view(x, taps)  # (n_out, taps)
    folded = win[:, :half] + win[:, taps - 1 : half : -1]
    return np.concatenate([folded, win[:, half : half + 1]], axis=1).T


def _layer_sums(
    digits: np.ndarray, u: np.ndarray, sample_bits: int
) -> np.ndarray:
    """(B, M, L) digits × (M, n_out) windows → (B, L, n_out) int64 layer
    partial sums, via one float64 BLAS matmul (exact, see module doc)."""
    n_bank, m, n_layers = digits.shape
    # every addend is an integer; the sum magnitude is < M · 2^(bits+1),
    # far inside float64's 2^53 exact-integer range for any real spec —
    # a real raise (not assert) so `python -O` can't silently lose bits
    if m * 2.0 ** (sample_bits + 1) >= 2.0**52:
        raise ValueError(
            f"float64 layer-sum path not exact for {m} coefficients at "
            f"{sample_bits} sample bits"
        )
    d2 = digits.transpose(0, 2, 1).reshape(n_bank * n_layers, m)
    p = d2.astype(np.float64) @ u.astype(np.float64)
    return np.rint(p).astype(np.int64).reshape(n_bank, n_layers, -1)


def _right_shift_accumulate(layer_sums: np.ndarray) -> np.ndarray:
    """Replay the right-shift BLMAC accumulator over bit layers, LSB first,
    vectorized over (B, n_out): each EOR streams the accumulator LSB into
    the output shift register and arithmetic-shifts the accumulator."""
    n_bank, n_layers, n_out = layer_sums.shape
    acc = np.zeros((n_bank, n_out), np.int64)
    low_bits = np.zeros((n_bank, n_out), np.int64)
    for layer in range(n_layers):
        acc += layer_sums[:, layer, :]
        low_bits |= (acc & 1) << layer
        acc >>= 1  # numpy int64 >> is arithmetic: exact two's complement
    return (acc << n_layers) | low_bits


def simulate_bank(
    qbank: np.ndarray,
    samples: np.ndarray,
    spec: MachineSpec | None = None,
) -> VMachineResult:
    """One-shot convenience: program ``qbank`` and run ``samples``."""
    vm = FirBlmacVMachine(spec)
    vm.program_bank(qbank)
    return vm.run(samples)
