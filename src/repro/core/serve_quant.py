"""Checkpoint-level BLMAC quantization for serving.

Walks a parameter tree and replaces every ≥2-D linear weight with its
CSD-P pulse-code reconstruction (`kernels/blmac_matmul.pulse_quantize`).
On TPU the packed codes feed the `pulse_matmul` Pallas kernel directly
(weights stream from HBM at P bytes — 6P bits achievable — per weight);
on this CPU host we fake-quantize (quantize → decode → float) so every
downstream path exercises the exact serving numerics.

Norm scales, biases and 1-D params are left untouched (negligible bytes,
disproportionate quality impact — same policy as int8/int4 LLM quant).
"""
from __future__ import annotations

from typing import Any

import jax
import numpy as np

from ..kernels.blmac_matmul import GROUP, pulse_dequantize, pulse_quantize

__all__ = ["quantize_param_tree"]


def _quantize_leaf(x: np.ndarray, planes: int):
    """Quantize along the last-but-one axis (contraction axis of x @ W)."""
    w = np.asarray(x, np.float64)
    orig_shape = w.shape
    k = orig_shape[-2]
    if k % GROUP:
        return None  # leave oddly-shaped weights alone
    w2 = w.reshape(-1, k, orig_shape[-1])
    outs = []
    rel_errs = []
    for i in range(w2.shape[0]):
        codes, ge = pulse_quantize(w2[i], planes)
        deq = pulse_dequantize(codes, ge)
        denom = np.abs(w2[i]).mean() + 1e-12
        rel_errs.append(float(np.abs(deq - w2[i]).mean() / denom))
        outs.append(deq)
    return (np.stack(outs).reshape(orig_shape).astype(x.dtype),
            float(np.mean(rel_errs)))


def quantize_param_tree(params: Any, planes: int,
                        min_size: int = 4096) -> tuple[Any, dict]:
    """Returns (quantized tree, stats).  Quantizes float leaves with ≥2
    dims and ≥ `min_size` elements."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    out = []
    n_q = 0
    errs = []
    for path, leaf in flat:
        arr = np.asarray(jax.device_get(leaf))
        key = "/".join(str(getattr(p, "key", p)) for p in path)
        eligible = (arr.ndim >= 2 and arr.size >= min_size
                    and arr.dtype.kind == "f" and "norm" not in key.lower())
        if eligible:
            res = _quantize_leaf(arr, planes)
            if res is not None:
                q, err = res
                out.append(jax.numpy.asarray(q))
                n_q += 1
                errs.append(err)
                continue
        out.append(leaf)
    stats = {
        "n_quantized": n_q,
        "mean_rel_err": float(np.mean(errs)) if errs else 0.0,
        # implemented packing: 8 bits/pulse + group exponent overhead;
        # 6 bits/pulse achievable with bit packing (DESIGN.md §2.2)
        "bits_per_weight": 8.0 * planes + 8.0 / GROUP,
        "bits_per_weight_achievable": 6.0 * planes + 8.0 / GROUP,
    }
    return jax.tree_util.tree_unflatten(treedef, out), stats
