"""Public jit'd entry points for the Pallas kernels.

On non-TPU backends (this container) the kernels run under
``interpret=True`` — the kernel body executes as traced jnp on CPU, which
is the validation mode demanded by the deliverables.  On TPU the same
`pallas_call` lowers to Mosaic.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .blmac_fir import (
    blmac_fir_dynamic,
    blmac_fir_specialized,
    pulses_msb_first,
)
from .blmac_matmul import (
    GROUP,
    pulse_dequantize,
    pulse_matmul,
    pulse_quantize,
)
from ..core.csd import csd_digits

__all__ = [
    "blmac_fir",
    "pulse_quantize",
    "pulse_dequantize",
    "pulse_matmul_op",
    "default_interpret",
]


def default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def blmac_fir(
    x: jnp.ndarray,
    qcoeffs: np.ndarray,
    specialize: bool = True,
    tile: int = 1024,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Apply a quantized symmetric type-I FIR filter with the BLMAC kernel.

    ``qcoeffs`` is host-side (static) int data — reprogramming the filter
    recompiles, exactly as the FPGA machine reloads its weight memory.
    Returns int32 (len(x) - taps + 1,).
    """
    qcoeffs = np.asarray(qcoeffs, np.int64)
    taps = int(qcoeffs.shape[0])
    if taps % 2 == 0 or not np.array_equal(qcoeffs, qcoeffs[::-1]):
        raise ValueError("blmac_fir needs an odd symmetric (type-I) filter")
    if interpret is None:
        interpret = default_interpret()
    if specialize:
        pulses = pulses_msb_first(qcoeffs)
        return blmac_fir_specialized(x, pulses, taps, tile, interpret)
    half = taps // 2 + 1
    digits = csd_digits(qcoeffs[:half], n_digits=17)  # (M, L)
    m_pad = -(-half // 128) * 128
    trits = np.zeros((digits.shape[1], m_pad), np.int8)
    trits[:, :half] = digits.T
    return blmac_fir_dynamic(
        x, jnp.asarray(trits), taps, digits.shape[1], tile, interpret
    )


def pulse_matmul_op(
    x: jnp.ndarray,
    codes: jnp.ndarray,
    group_exp: jnp.ndarray,
    planes: int,
    group: int = GROUP,
    interpret: bool | None = None,
    **block_kw,
) -> jnp.ndarray:
    """CSD-P pulse-code matmul (see `blmac_matmul.py`)."""
    if interpret is None:
        interpret = default_interpret()
    return pulse_matmul(
        x, codes, group_exp, planes, group, interpret=interpret, **block_kw
    )
