"""Public jit'd entry points for the Pallas kernels.

Backend selection lives in `runtime.default_interpret` (re-exported
here): on non-TPU backends (this container) the kernels run under
``interpret=True`` — the kernel body executes as traced jnp on CPU, which
is the validation mode demanded by the deliverables.  On TPU the same
`pallas_call` lowers to Mosaic.  Every entry point takes
``interpret=None`` meaning "whatever the backend needs".
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from ..compiler import compile_bank
from ..core.csd import require_type1
from .blmac_fir import (
    FAST_PATH_MAX,
    MERGE_DEFAULT,
    blmac_fir_bank as _bank_kernel,
    blmac_fir_specialized,
)
from .blmac_matmul import (
    GROUP,
    pulse_dequantize,
    pulse_matmul,
    pulse_quantize,
)
from .runtime import default_interpret, resolve_interpret

__all__ = [
    "blmac_fir",
    "blmac_fir_bank",
    "pulse_quantize",
    "pulse_dequantize",
    "pulse_matmul_op",
    "default_interpret",
]


def blmac_fir(
    x: jnp.ndarray,
    qcoeffs: np.ndarray,
    specialize: bool = True,
    tile: int = 1024,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Apply a quantized symmetric type-I FIR filter with the BLMAC kernel.

    ``qcoeffs`` is host-side (static) int data — reprogramming the filter
    recompiles, exactly as the FPGA machine reloads its weight memory
    (`specialize=True` hits the LRU program cache; `specialize=False`
    ships packed trits as a runtime operand instead).  Both routes read a
    content-addressed `repro.compiler.BlmacProgram` — the pulse schedule
    and packed trits are derived once per distinct filter.
    Returns int32 (len(x) - taps + 1,).
    """
    qcoeffs = np.asarray(qcoeffs, np.int64)
    taps = require_type1(qcoeffs, "blmac_fir")
    interpret = resolve_interpret(interpret)
    prog = compile_bank(qcoeffs[None, :])
    if specialize:
        return blmac_fir_specialized(
            x, prog.pulse_schedules()[0], taps, tile, interpret
        )
    return _bank_kernel(
        x, prog.packed, taps, tile, interpret=interpret,
        fast_path=False, schedule=prog.schedule(bank_tile=1),
    )[0]


def blmac_fir_bank(
    x: jnp.ndarray,
    qbank: np.ndarray,
    tile: int = 1024,
    bank_tile: int | None = None,
    interpret: bool | None = None,
    merge: int | None = None,
) -> jnp.ndarray:
    """Apply a whole (B, taps) filter bank to a (C, T) or (T,) signal with
    the sparsity-scheduled bank kernel — packed-trit operands, filters
    grouped into occupancy-homogeneous bank tiles, one integer matmul per
    populated *superlayer* (``merge`` adjacent CSD layers; see
    `repro.compiler.plan_bank_schedule`), window matrix amortized over
    the bank tile.  B=1 dispatches to the pulse-specialized fast path.

    The bank is compiled once (`repro.compiler.compile_bank`, content-
    addressed) and its memoized superlayer schedule reused, so repeated
    calls — and other clients of the same bank, like `FilterBankEngine`
    — share one artifact.

    Returns int32 (B, C, T - taps + 1), or (B, T - taps + 1) for 1-D ``x``.
    """
    prog = compile_bank(qbank)
    if prog.n_filters <= FAST_PATH_MAX:
        return _bank_kernel(
            x, prog.packed, prog.taps, tile, bank_tile, interpret,
            merge=MERGE_DEFAULT if merge is None else merge,
        )
    return _bank_kernel(
        x, prog.packed, prog.taps, tile, interpret=interpret,
        fast_path=False, schedule=prog.schedule(bank_tile, merge),
    )


def pulse_matmul_op(
    x: jnp.ndarray,
    codes: jnp.ndarray,
    group_exp: jnp.ndarray,
    planes: int,
    group: int = GROUP,
    interpret: bool | None = None,
    **block_kw,
) -> jnp.ndarray:
    """CSD-P pulse-code matmul (see `blmac_matmul.py`)."""
    return pulse_matmul(
        x, codes, group_exp, planes, group,
        interpret=resolve_interpret(interpret), **block_kw,
    )
