"""Pallas TPU kernels: BLMAC FIR filtering, single filters and whole banks.

TPU adaptation of the paper's machine (DESIGN.md §2): the FPGA executes one
add per pulse per *sample*; these kernels execute one VPU vector add per
pulse per *tile of output samples* (lane-parallel, pulse-serial).  The
symmetric pre-add (Eq. 3) is fused.  All arithmetic is exact int32 — the
§2.1 bound (16-bit coeffs × 8-bit samples × ≤255 taps fits 32 bits) is
asserted ONCE at pack time (`core.csd.assert_int32_bound`), not per call.

Three modes:

  * **specialized** — the CSD pulse list of ONE filter is baked into the
    kernel at trace time: the emitted program is literally `acc ±= u_j`
    per pulse plus one shift per bit layer — the paper's add-count cost
    model *is* the instruction count.  One (cheap) recompile per distinct
    pulse schedule, held in an LRU cache (`specialized_program`), exactly
    like reprogramming the FPGA weight memory.
  * **bank** — the workhorse for filter *banks*: a `pallas_call` over a
    3-D grid `(bank_tile, channel, signal_tile)` applies B filters to C
    channels.  Trits travel as **packed uint32 words** (16 two-bit trit
    codes per word, `core.csd.pack_trits` layout: 0b00=0, 0b01=+1,
    0b11=−1, signed CSD end-to-end — ~2× fewer pulses than binary
    layers, paper Tab. 3) and are unpacked in-kernel with shifts and
    masks.  Each grid step builds the framed `(M, tile)` window matrix
    ONCE with a single gather and reuses it for every surviving layer
    and every filter in the bank tile.

    The Horner loop is **schedule-driven**, not fixed-length: at pack
    time `plan_bank_schedule` sorts the filters by layer-occupancy
    signature, partitions them into occupancy-homogeneous bank tiles,
    and emits per-tile-group schedules of *superlayers* — runs of
    ``merge`` adjacent CSD layers contracted in one
    ``(bank_tile, M) @ (M, tile)`` integer matmul, with one
    ``acc << shift`` per populated superlayer.  Bit layers empty across
    the whole tile cost **zero** kernel work (layer-skip); the schedule
    is static per compiled signature and jit-cached exactly like
    `specialized_program`.
  * **dynamic** — legacy single-filter runtime-trit entry point: a B=1
    scheduled bank call whose compile cache is keyed on layer occupancy,
    not the pulse list (trits stay a runtime operand).  `blmac_fir_bank`
    itself fast-paths B≤1 *packed* banks to the specialized program —
    the route that erased the PR-1 B=1 framing regression.

Input layout: the host frames each channel into overlapping tiles
(n_tiles, tile + taps − 1 padded to a lane multiple); BlockSpec then maps
one frame per grid step into VMEM.  The ~taps/tile halo duplication
(≈12% at tile=1024, taps=127) is the price of clean non-overlapping
BlockSpecs and is counted in the roofline maths.

Since the one-program refactor this module is pure *execution*: the
pack-time half of the pipeline (trit packing, occupancy sorting,
superlayer scheduling) lives in `repro.compiler` — `pack_bank_trits`,
`plan_bank_schedule`, `BankSchedule` and friends are re-exported here
for backward compatibility.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from ..compiler.program import compile_packed, pack_bank_trits  # noqa: F401
from ..compiler.schedule import (  # noqa: F401 — re-exported, moved in PR 5
    BankSchedule, MAX_BANK_TILE, MERGE_DEFAULT, TileGroup, default_bank_tile,
    plan_bank_schedule, superlayer_schedule)
from ..core.csd import csd_digits, pack_trits, unpack_trits
from .runtime import resolve_interpret

LANE = 128
TRITS_PER_WORD = 16


def _pad_to(n: int, m: int) -> int:
    return (n + m - 1) // m * m


# ---------------------------------------------------------------------------
# host-side framing (overlap-save layout)
# ---------------------------------------------------------------------------

def frame_signal_batch(
    x: jnp.ndarray, taps: int, tile: int
) -> tuple[jnp.ndarray, int]:
    """(C, T) → (C, n_tiles, frame_len) overlapping frames per channel;
    returns padded frames and the number of valid output samples."""
    t = x.shape[-1]
    n_out = t - taps + 1
    if n_out <= 0:
        raise ValueError("signal shorter than the filter")
    n_tiles = -(-n_out // tile)
    frame_len = _pad_to(tile + taps - 1, LANE)
    pad = (n_tiles - 1) * tile + frame_len - t
    xp = jnp.pad(x, ((0, 0), (0, max(0, pad))))
    idx = jnp.arange(n_tiles)[:, None] * tile + jnp.arange(frame_len)[None, :]
    return xp[:, idx], n_out


def frame_signal(x: jnp.ndarray, taps: int, tile: int) -> tuple[jnp.ndarray, int]:
    """(T,) → (n_tiles, frame_len) overlapping frames; returns padded frames
    and the number of valid output samples."""
    frames, n_out = frame_signal_batch(x[None, :], taps, tile)
    return frames[0], n_out


# ---------------------------------------------------------------------------
# specialized single-filter kernel (pulse schedule baked in at trace time)
# ---------------------------------------------------------------------------

def _fir_kernel_specialized(frame_ref, out_ref, *, pulses, taps, tile):
    """One grid step = one output tile.  `pulses` is a static tuple of
    (layer, j, sign) triples, MSB layer first."""
    fx = frame_ref[0, :].astype(jnp.int32)
    half = taps // 2
    # symmetric fold, built lazily: only the taps that carry pulses
    needed = sorted({j for (_, j, _) in pulses})
    u = {}
    for j in needed:
        if j == half:
            u[j] = jax.lax.dynamic_slice(fx, (half,), (tile,))
        else:
            a = jax.lax.dynamic_slice(fx, (j,), (tile,))
            b = jax.lax.dynamic_slice(fx, (taps - 1 - j,), (tile,))
            u[j] = a + b
    acc = jnp.zeros((tile,), jnp.int32)
    layer_of = None
    for layer, j, sign in pulses:  # MSB layer first, grouped by layer
        if layer_of is None:
            layer_of = layer
        while layer_of > layer:  # Horner: one shift per layer boundary
            acc = acc << 1
            layer_of -= 1
        acc = acc + u[j] if sign > 0 else acc - u[j]
    if layer_of is not None and layer_of > 0:
        acc = acc << layer_of
    out_ref[0, :] = acc


def pulses_msb_first(qcoeffs: np.ndarray) -> tuple[tuple[int, int, int], ...]:
    """Static pulse schedule from quantized symmetric coefficients."""
    taps = qcoeffs.shape[0]
    digits = csd_digits(np.asarray(qcoeffs[: taps // 2 + 1], np.int64))
    out = []
    for layer in range(digits.shape[1] - 1, -1, -1):
        for j in np.nonzero(digits[:, layer])[0]:
            out.append((int(layer), int(j), int(digits[j, layer])))
    return tuple(out)


@functools.lru_cache(maxsize=1024)
def specialized_program(pulses, taps: int, tile: int, interpret: bool):
    """Compiled BLMAC program for one pulse schedule.

    LRU-cached on the pulse tuple: reprogramming a filter that was seen
    before is a dict hit, a new schedule costs one (cheap) trace — the
    software analogue of reloading the FPGA weight memory.  The returned
    callable is additionally jit-cached per input length.
    """
    kern = functools.partial(
        _fir_kernel_specialized, pulses=pulses, taps=taps, tile=tile
    )

    @jax.jit
    def run(x: jnp.ndarray) -> jnp.ndarray:
        frames, n_out = frame_signal(x.astype(jnp.int32), taps, tile)
        n_tiles, frame_len = frames.shape
        y = pl.pallas_call(
            kern,
            grid=(n_tiles,),
            in_specs=[pl.BlockSpec((1, frame_len), lambda i: (i, 0))],
            out_specs=pl.BlockSpec((1, tile), lambda i: (i, 0)),
            out_shape=jax.ShapeDtypeStruct((n_tiles, tile), jnp.int32),
            interpret=interpret,
        )(frames)
        return y.reshape(-1)[:n_out]

    return run


def blmac_fir_specialized(
    x: jnp.ndarray,
    pulses,
    taps: int,
    tile: int = 1024,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Apply one pulse-specialized filter; compiles at most once per
    distinct (pulse schedule, taps, tile, backend)."""
    return specialized_program(
        tuple(pulses), taps, tile, resolve_interpret(interpret)
    )(x)


# ---------------------------------------------------------------------------
# batched bank kernel (packed-trit operands, 3-D grid, layer-skip schedule)
# ---------------------------------------------------------------------------

def _fir_kernel_bank(
    frame_ref, packed_ref, out_ref, *, taps, tile, schedule, tail_shift,
    bank_tile, n_words
):
    """One grid step = one (bank tile × signal tile) block of one channel.

    `packed_ref` holds 2-bit trit codes, 16 per uint32 word (viewed as
    int32 — the `& 3` mask makes arithmetic vs logical shift moot), laid
    out (bank_tile, n_sel, n_words) over the folded half-filter, where
    the n_sel slices are ONLY the bit layers populated somewhere in this
    bank tile (MSB first — see `plan_bank_schedule`).

    `schedule` drives the Horner recursion: a static tuple of superlayer
    entries ``(shift_in, ((sel_idx, rel_weight), ...))``, MSB first.  Each
    entry shifts the accumulator left by the layer gap to the previous
    superlayer, sums its ``merge``-adjacent trit layers into one small-
    integer digit matrix, and contracts it against the shared window
    matrix in ONE ``(bank_tile, M) @ (M, tile)`` integer matmul.  Layers
    (and whole superlayers) empty across the tile appear nowhere: the
    emitted program length tracks the occupancy, not the worst case.
    """
    fx = frame_ref[0, 0, :].astype(jnp.int32)
    frame_len = fx.shape[0]
    half = taps // 2
    m_pad = n_words * TRITS_PER_WORD
    # The framed (M, tile) window matrix: one gather, built once per grid
    # step, shared by every superlayer and every filter in the bank tile.
    # Row j holds the symmetric fold u_j[t] = x[t+j] + x[t+taps-1-j]
    # (centre row: no fold); rows past the centre are zero and meet only
    # zero trits.
    j = jax.lax.broadcasted_iota(jnp.int32, (m_pad, tile), 0)
    t = jax.lax.broadcasted_iota(jnp.int32, (m_pad, tile), 1)
    fwd = fx[jnp.minimum(j + t, frame_len - 1)]
    rev = fx[jnp.clip(taps - 1 - j + t, 0, frame_len - 1)]
    u = jnp.where(j < half, fwd + rev, jnp.where(j == half, fwd, 0))

    words = packed_ref[...]  # (bank_tile, n_sel, n_words) int32
    shifts = 2 * jax.lax.broadcasted_iota(
        jnp.int32, (n_words, TRITS_PER_WORD), 1
    )

    def trit_layer(sel_idx):
        codes = (words[:, sel_idx, :, None] >> shifts[None]) & 3
        d = (codes == 1).astype(jnp.int32) - (codes == 3).astype(jnp.int32)
        return d.reshape(bank_tile, m_pad)

    acc = jnp.zeros((bank_tile, tile), jnp.int32)
    for shift_in, parts in schedule:  # MSB → LSB over populated superlayers
        if shift_in:
            acc = acc << shift_in
        d = None
        for sel_idx, rel in parts:
            dl = trit_layer(sel_idx)
            if rel:
                dl = dl << rel
            d = dl if d is None else d + dl
        # one integer matmul per populated superlayer: every pulse in the
        # tile is one lane-parallel add inside this contraction
        acc = acc + jnp.dot(d, u, preferred_element_type=jnp.int32)
    if tail_shift:
        acc = acc << tail_shift
    out_ref[...] = acc[:, None, None, :]


@functools.partial(
    jax.jit,
    static_argnames=(
        "taps", "schedule", "tail_shift", "tile", "bank_tile", "interpret"
    ),
)
def _bank_call(
    frames: jnp.ndarray,  # (C, n_tiles, frame_len) int32
    packed: jnp.ndarray,  # (B_pad, n_sel, n_words) int32, selected layers
    taps: int,
    schedule: tuple,
    tail_shift: int,
    tile: int,
    bank_tile: int,
    interpret: bool,
) -> jnp.ndarray:
    """Scheduled bank call.  jit's static-argument cache makes this the
    bank analogue of `specialized_program`: one compile per distinct
    (schedule, geometry) signature, every later dispatch a cache hit."""
    n_chan, n_tiles, frame_len = frames.shape
    b_pad, n_sel, n_words = packed.shape
    kern = functools.partial(
        _fir_kernel_bank,
        taps=taps,
        tile=tile,
        schedule=schedule,
        tail_shift=tail_shift,
        bank_tile=bank_tile,
        n_words=n_words,
    )
    return pl.pallas_call(
        kern,
        grid=(b_pad // bank_tile, n_chan, n_tiles),
        in_specs=[
            pl.BlockSpec((1, 1, frame_len), lambda b, c, s: (c, s, 0)),
            pl.BlockSpec((bank_tile, n_sel, n_words), lambda b, c, s: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec(
            (bank_tile, 1, 1, tile), lambda b, c, s: (b, c, s, 0)
        ),
        out_shape=jax.ShapeDtypeStruct((b_pad, n_chan, n_tiles, tile), jnp.int32),
        interpret=interpret,
    )(frames, packed)


# ---------------------------------------------------------------------------
# compiled lowering lanes
# ---------------------------------------------------------------------------
#
# The scheduled kernel above runs on four execution lanes:
#
#   "interpret" — the Pallas interpreter (pure python; the historic CI
#       target every BENCH_fir number was recorded on),
#   "mosaic"    — pallas_call compiled for TPU,
#   "triton"    — pallas_call compiled for GPU,
#   "xla"       — the SAME superlayer schedule lowered as a plain jitted
#       XLA program (no Pallas): the always-available compiled CI target,
#       since the Pallas interpreter is the only Pallas mode a CPU host
#       can run.
#
# The XLA lane keeps the two properties that make the Pallas kernel fast:
# the packed trit words are the *operand* (the 2-bit→{-1,0,+1} decode
# happens inside the jitted program, so XLA fuses it into the dot's LHS
# and trits never round-trip through memory as unpacked int8), and each
# populated superlayer is ONE integer contraction — here against the
# window matrix of EVERY (channel, signal-tile) grid cell at once,
# ``(B_pad, M) @ (M, C·n_tiles·tile)``, which is exactly the
# wide-matmul-unit regime where the compiled autotuner sweep
# re-evaluates the merge heuristic: superlayers whose digit bound stays
# below the f32 mantissa limit run bit-exactly on the float GEMM units
# (`f32_dot_safe`), which caps the winning merge near the f32-safe span
# instead of "fuse everything".
# The cost is materializing that im2col-style window matrix
# (``m_pad × signal`` int32, ~`m_pad`× the signal bytes) instead of one
# (M, tile) block per grid step — the right trade below VMEM-scale
# signals, and the reason the Pallas lanes keep the blocked layout.

LANES = ("interpret", "mosaic", "triton", "xla")

# float32 mantissa: integers of magnitude < 2**24 are exactly
# representable, and sums/products that stay under the bound are exact
F32_EXACT_BOUND = 1 << 24


def f32_dot_safe(m_pad: int, parts) -> bool:
    """Whether one superlayer's contraction is EXACT in float32.

    Under the §2.1 regime every int32 path already assumes (8-bit
    samples — the same precondition the pack-time accumulator bound is
    stated for), the symmetric-fold window entries obey ``|u_j| <= 2**8``
    and the superlayer digit is bounded by its trit shifts,
    ``|d_j| <= sum(2**rel)``.  When ``m_pad * bound(d) * 2**8 < 2**24``
    every partial sum of the dot is an integer below the f32 mantissa
    limit, so running it on the float GEMM units is bit-exact — and on
    CPU XLA those units are ~an order of magnitude faster than the int32
    matmul loop (the wide-matmul-unit effect the compiled merge
    heuristic re-evaluates; see `repro.core.costmodel`).
    """
    bound = sum(1 << rel for _, rel in parts)
    return m_pad * bound * 256 <= F32_EXACT_BOUND


def _lane_interpret(lane: str, interpret: bool) -> bool:
    """Pallas ``interpret`` flag for a lane (the "xla" lane never reaches
    a pallas_call)."""
    if lane == "interpret":
        return True
    if lane in ("mosaic", "triton", "xla"):
        return False
    raise ValueError(f"unknown lane {lane!r}; expected one of {LANES}")


@functools.partial(
    jax.jit,
    static_argnames=("taps", "schedule", "tail_shift", "tile", "n_real"),
)
def _bank_call_xla(
    frames: jnp.ndarray,  # (C, n_tiles, frame_len) int32
    packed: jnp.ndarray,  # (B_pad, n_sel, n_words) int32, selected layers
    taps: int,
    schedule: tuple,
    tail_shift: int,
    tile: int,
    combine: jnp.ndarray | None = None,  # (n_real, n_shared) int32
    n_real: int | None = None,
) -> jnp.ndarray:
    """The scheduled bank computation as ONE fused XLA program — same
    schedule semantics as `_fir_kernel_bank`, same (B_pad, C, n_tiles,
    tile) result, bit-exact.

    ``combine`` (CSE-optimized programs, `repro.compiler.optimize`) adds
    a second small GEMM to the fused program: rows past ``n_real`` are
    shared partial-sum rows, folded back as ``y[:n_real] + combine @
    y[n_real:]`` — int32 ring arithmetic, so the result equals the
    parent program's output bit-for-bit even if a shared row wraps."""
    n_chan, n_tiles, frame_len = frames.shape
    b_pad, n_sel, n_words = packed.shape
    m_pad = n_words * TRITS_PER_WORD
    half = taps // 2
    # window matrix for EVERY grid cell at once: row j of cell (c, s)
    # holds the symmetric fold u_j[t] = x[t+j] + x[t+taps-1-j]
    j = jnp.arange(m_pad, dtype=jnp.int32)[:, None]
    t = jnp.arange(tile, dtype=jnp.int32)[None, :]
    fwd = frames[..., jnp.minimum(j + t, frame_len - 1)]
    rev = frames[..., jnp.clip(taps - 1 - j + t, 0, frame_len - 1)]
    u = jnp.where(j < half, fwd + rev, jnp.where(j == half, fwd, 0))
    # (C, n_tiles, m_pad, tile) → (m_pad, C·n_tiles·tile): the RHS every
    # superlayer contraction shares
    u = jnp.moveaxis(u, 2, 0).reshape(m_pad, n_chan * n_tiles * tile)

    shifts = 2 * jnp.arange(TRITS_PER_WORD, dtype=jnp.int32)

    def trit_layer(sel_idx):
        # fused unpack: packed words are the operand; the 2-bit decode is
        # part of the jitted program, feeding the dot LHS directly
        codes = (packed[:, sel_idx, :, None] >> shifts) & 3
        d = (codes == 1).astype(jnp.int32) - (codes == 3).astype(jnp.int32)
        return d.reshape(b_pad, m_pad)

    # superlayers whose digit bound admits the exact-f32 contraction run
    # on the float GEMM units (see `f32_dot_safe`); the window matrix is
    # converted once (|u_j| <= 2**8: exact)
    u_f32 = (
        u.astype(jnp.float32)
        if any(f32_dot_safe(m_pad, parts) for _, parts in schedule)
        else None
    )
    acc = jnp.zeros((b_pad, u.shape[1]), jnp.int32)
    for shift_in, parts in schedule:  # MSB → LSB over populated superlayers
        if shift_in:
            acc = acc << shift_in
        d = None
        for sel_idx, rel in parts:
            dl = trit_layer(sel_idx)
            if rel:
                dl = dl << rel
            d = dl if d is None else d + dl
        if f32_dot_safe(m_pad, parts):
            # every partial sum is an integer < 2**24: the f32 dot is
            # bit-exact, and the f32->s32 convert of exact integers is too
            y = jnp.dot(d.astype(jnp.float32), u_f32).astype(jnp.int32)
        else:
            y = jnp.dot(d, u, preferred_element_type=jnp.int32)
        acc = acc + y
    if tail_shift:
        acc = acc << tail_shift
    if combine is not None:
        acc = acc[:n_real] + jnp.dot(
            combine, acc[n_real:], preferred_element_type=jnp.int32
        )
    return acc.reshape(acc.shape[0], n_chan, n_tiles, tile)


def pulses_from_packed(packed_row: np.ndarray, taps: int):
    """(n_layers, n_words) packed trits → MSB-first static pulse tuple
    (the `specialized_program` input) — the small-bank fast path's bridge
    from the bank operand format to the pulse-baked kernel."""
    half = taps // 2
    digits = unpack_trits(packed_row, half + 1)  # (L, M) int8
    out = []
    for layer in range(digits.shape[0] - 1, -1, -1):
        for j in np.nonzero(digits[layer])[0]:
            out.append((int(layer), int(j), int(digits[layer, j])))
    return tuple(out)


FAST_PATH_MAX = 1  # banks up to this size dispatch to specialized programs


def blmac_fir_bank(
    x: jnp.ndarray,  # (C, T) or (T,)
    packed: np.ndarray,  # (B, n_layers, n_words) uint32 from pack_bank_trits
    taps: int,
    tile: int = 1024,
    bank_tile: int | None = None,
    interpret: bool | None = None,
    merge: int = MERGE_DEFAULT,
    schedule: BankSchedule | None = None,
    fast_path: bool = True,
    lane: str | None = None,
    combine: np.ndarray | None = None,
    n_real: int | None = None,
) -> jnp.ndarray:
    """Apply a B-filter bank to a C-channel signal with the scheduled
    bank kernel (one `pallas_call` per occupancy tile group).

    Returns int32 (B, C, T - taps + 1).  Bit-exact against
    `repro.filters.fir_bit_layers_batch` on integer inputs, whatever the
    schedule: grouping permutes filters internally and restores the
    caller's order on the way out.

    ``fast_path`` routes banks of ≤ `FAST_PATH_MAX` filters to the
    pulse-specialized kernel — a B=1 "bank" paid 0.70× the per-filter
    baseline in PR 1 purely in framing/padding overhead; now it costs
    exactly its pulse count.  Pass a precomputed ``schedule`` (from
    `plan_bank_schedule`) to skip planning on the hot path — the
    `FilterBankEngine` does this once at construction.  ``lane``
    selects the execution lane (see `LANES`; compiled lanes skip the
    fast path — specialized programs are an interpret-era optimization).
    ``combine``/``n_real`` execute a CSE-optimized shared-row bank (see
    `bank_schedule_apply`); the result then has ``n_real`` rows.
    """
    x = jnp.asarray(x)
    squeeze = x.ndim == 1
    if squeeze:
        x = x[None, :]
    packed = np.asarray(packed)
    n_filters = packed.shape[0]
    interpret = resolve_interpret(interpret)

    if (
        fast_path
        and schedule is None
        and combine is None
        and n_filters <= FAST_PATH_MAX
        and lane in (None, "interpret")
    ):
        xi = x.astype(jnp.int32)
        n_out = xi.shape[-1] - taps + 1
        ys = [
            jnp.stack(
                [
                    blmac_fir_specialized(
                        xi[c], pulses_from_packed(packed[b], taps), taps,
                        tile, interpret,
                    )
                    for c in range(xi.shape[0])
                ]
            )
            for b in range(n_filters)
        ]
        y = jnp.stack(ys)[:, :, :n_out]
        return y[:, 0, :] if squeeze else y

    if schedule is None:
        schedule = plan_bank_schedule(packed, bank_tile, merge)
    frames, n_out = frame_signal_batch(x.astype(jnp.int32), taps, tile)
    y = bank_schedule_apply(frames, schedule, taps, tile, interpret, lane=lane,
                            combine=combine, n_real=n_real)
    # one combined slice: separate [:, :, :n_out] then [:, 0, :] would copy
    # the full (B, C, signal) buffer twice on the host
    return y[:, 0, :n_out] if squeeze else y[:, :, :n_out]


def bank_schedule_apply(
    frames: jnp.ndarray,  # (C, n_tiles, frame_len) int32 framed signal
    schedule: BankSchedule,
    taps: int,
    tile: int,
    interpret: bool,
    device_groups: list | None = None,
    lane: str | None = None,
    combine: jnp.ndarray | None = None,
    n_real: int | None = None,
) -> jnp.ndarray:
    """Run every tile group of a `BankSchedule` over pre-framed signal and
    reassemble rows in the caller's filter order → (B, C, n_tiles*tile).

    ``device_groups`` optionally supplies pre-uploaded packed operands
    (one per group, int32 view) so streaming callers don't re-stage the
    bank every chunk.  ``lane`` selects the execution lane (see `LANES`);
    None keeps the legacy behaviour — a pallas_call honouring the
    ``interpret`` flag — while ``"xla"`` routes to the fused compiled
    lowering `_bank_call_xla` (bit-exact against every other lane).

    ``combine``/``n_real`` execute a CSE-optimized program's shared-row
    layout (`repro.compiler.optimize`): rows past ``n_real`` are shared
    partial sums, folded back after reassembly as one small int32 GEMM
    plus an add — on the single-group xla path the GEMM fuses into the
    lowered program itself.  The result then has ``n_real`` rows."""
    n_chan, n_tiles, _ = frames.shape
    if combine is not None:
        combine = jnp.asarray(np.asarray(combine, np.int32))
    if lane is not None and lane != "xla":
        interpret = _lane_interpret(lane, interpret)
    if len(schedule.groups) == 1 and lane == "xla":
        # Single tile group (the common autotuned shape): fold the
        # caller-order restore into the dot's LHS instead of gathering
        # the (B, C, signal) result — permuting the tiny packed operand's
        # rows permutes the output rows for free, where `y[inv]` is a
        # full-output-size copy (~6 ms of the ~40 ms xla arm at the
        # BENCH_compiled geometry).  Pad rows drop out with the same
        # indexing.  Pallas lanes keep the gather: their grid needs the
        # padded, occupancy-sorted row layout.
        g = schedule.groups[0]
        if not g.sel_layers:
            rows = len(schedule.inv) if combine is None else n_real
            return jnp.zeros((rows, n_chan, n_tiles * tile), jnp.int32)
        op = (
            device_groups[0]
            if device_groups is not None
            else jnp.asarray(g.packed.view(np.int32))
        )[schedule.inv]
        y = _bank_call_xla(
            frames, op, taps, g.schedule, g.tail_shift, tile,
            combine=combine, n_real=n_real,
        )
        return y.reshape(y.shape[0], n_chan, -1)
    parts = []
    for gi, g in enumerate(schedule.groups):
        rows = g.packed.shape[0]
        if not g.sel_layers:  # all-zero tile group: no kernel at all
            parts.append(
                jnp.zeros((rows, n_chan, n_tiles * tile), jnp.int32)
            )
            continue
        op = (
            device_groups[gi]
            if device_groups is not None
            else jnp.asarray(g.packed.view(np.int32))
        )
        if lane == "xla":
            y = _bank_call_xla(
                frames, op, taps, g.schedule, g.tail_shift, tile
            )
        else:
            y = _bank_call(
                frames, op, taps, g.schedule, g.tail_shift, tile,
                schedule.tile_size, interpret,
            )  # (rows, C, n_tiles, tile)
        parts.append(y.reshape(rows, n_chan, -1))
    y = jnp.concatenate(parts, axis=0) if len(parts) > 1 else parts[0]
    y = y[schedule.inv]  # drop pad rows, restore caller's filter order
    if combine is not None:
        y = _combine_shared(y, combine, n_real)
    return y


@functools.partial(jax.jit, static_argnames=("n_real",))
def _combine_shared(y: jnp.ndarray, combine: jnp.ndarray, n_real: int):
    """Fold shared partial-sum rows (``y[n_real:]``) back into their
    consumers: one (n_real, n_shared) int32 GEMM plus an add.  Exact in
    the mod-2**32 ring on every lane; the combined values are the parent
    program's outputs, which fit int32 by the pack-time §2.1 bound."""
    return y[:n_real] + jnp.tensordot(
        combine, y[n_real:], axes=1, preferred_element_type=jnp.int32
    )


def blmac_fir_dynamic(
    x: jnp.ndarray,
    trits: np.ndarray,  # (n_layers, M_pad) int8, layer-major, {-1,0,1}
    taps: int,
    n_layers: int,
    tile: int = 1024,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Single-filter runtime-trit entry point: a B=1 scheduled bank call.

    The trits stay a runtime operand — the kernel compile cache is keyed
    on the filter's layer-OCCUPANCY schedule, not its pulse list, so
    streaming many distinct filters through this path re-traces only when
    the set of populated layers changes (dense same-width filters share
    one program).  Use `blmac_fir_bank`'s fast path /
    `blmac_fir_specialized` when per-filter compilation is acceptable.
    The trits are wrapped as a content-addressed `BlmacProgram`
    (`repro.compiler.compile_packed`), which asserts the §2.1 int32
    accumulator bound and memoizes the B=1 superlayer schedule.
    """
    trits = np.asarray(trits)
    half = taps // 2
    packed = pack_trits(trits[None, :n_layers, : half + 1])  # (1, L, W)
    prog = compile_packed(packed, taps)  # decodes weights, asserts §2.1
    return blmac_fir_bank(
        x, prog.packed, taps, tile, interpret=interpret,
        fast_path=False, schedule=prog.schedule(bank_tile=1),
    )[0]
