"""Pallas TPU kernels: BLMAC FIR filtering, single filters and whole banks.

TPU adaptation of the paper's machine (DESIGN.md §2): the FPGA executes one
add per pulse per *sample*; these kernels execute one VPU vector add per
pulse per *tile of output samples* (lane-parallel, pulse-serial).  The
symmetric pre-add (Eq. 3) is fused.  All arithmetic is exact int32 — the
§2.1 bound (16-bit coeffs × 8-bit samples × ≤255 taps fits 32 bits) is
asserted ONCE at pack time (`core.csd.assert_int32_bound`), not per call.

Three modes:

  * **specialized** — the CSD pulse list of ONE filter is baked into the
    kernel at trace time: the emitted program is literally `acc ±= u_j`
    per pulse plus one shift per bit layer — the paper's add-count cost
    model *is* the instruction count.  One (cheap) recompile per distinct
    pulse schedule, held in an LRU cache (`specialized_program`), exactly
    like reprogramming the FPGA weight memory.
  * **bank** — the workhorse for filter *banks*: a `pallas_call` over a
    3-D grid `(bank_tile, channel, signal_tile)` applies B filters to C
    channels.  Trits travel as **packed uint32 words** (16 two-bit trit
    codes per word, `core.csd.pack_trits` layout: 0b00=0, 0b01=+1,
    0b11=−1, signed CSD end-to-end — ~2× fewer pulses than binary
    layers, paper Tab. 3) and are unpacked in-kernel with shifts and
    masks.  Each grid step builds the framed `(M, tile)` window matrix
    ONCE with a single gather and reuses it for every surviving layer
    and every filter in the bank tile.

    The Horner loop is **schedule-driven**, not fixed-length: at pack
    time `plan_bank_schedule` sorts the filters by layer-occupancy
    signature, partitions them into occupancy-homogeneous bank tiles,
    and emits per-tile-group schedules of *superlayers* — runs of
    ``merge`` adjacent CSD layers contracted in one
    ``(bank_tile, M) @ (M, tile)`` integer matmul, with one
    ``acc << shift`` per populated superlayer.  Bit layers empty across
    the whole tile cost **zero** kernel work (layer-skip); the schedule
    is static per compiled signature and jit-cached exactly like
    `specialized_program`.
  * **dynamic** — legacy single-filter runtime-trit entry point: a B=1
    scheduled bank call whose compile cache is keyed on layer occupancy,
    not the pulse list (trits stay a runtime operand).  `blmac_fir_bank`
    itself fast-paths B≤1 *packed* banks to the specialized program —
    the route that erased the PR-1 B=1 framing regression.

Input layout: the host frames each channel into overlapping tiles
(n_tiles, tile + taps − 1 padded to a lane multiple); BlockSpec then maps
one frame per grid step into VMEM.  The ~taps/tile halo duplication
(≈12% at tile=1024, taps=127) is the price of clean non-overlapping
BlockSpecs and is counted in the roofline maths.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from ..core.csd import (assert_int32_bound, csd_decode, csd_digits,
                        occupancy_signatures, pack_trits, require_type1,
                        unpack_trits)
from .runtime import resolve_interpret

LANE = 128
TRITS_PER_WORD = 16
MAX_BANK_TILE = 256  # acc VMEM at tile=1024: 256×1024×4 B = 1 MiB


def _pad_to(n: int, m: int) -> int:
    return (n + m - 1) // m * m


# ---------------------------------------------------------------------------
# host-side framing (overlap-save layout)
# ---------------------------------------------------------------------------

def frame_signal_batch(
    x: jnp.ndarray, taps: int, tile: int
) -> tuple[jnp.ndarray, int]:
    """(C, T) → (C, n_tiles, frame_len) overlapping frames per channel;
    returns padded frames and the number of valid output samples."""
    t = x.shape[-1]
    n_out = t - taps + 1
    if n_out <= 0:
        raise ValueError("signal shorter than the filter")
    n_tiles = -(-n_out // tile)
    frame_len = _pad_to(tile + taps - 1, LANE)
    pad = (n_tiles - 1) * tile + frame_len - t
    xp = jnp.pad(x, ((0, 0), (0, max(0, pad))))
    idx = jnp.arange(n_tiles)[:, None] * tile + jnp.arange(frame_len)[None, :]
    return xp[:, idx], n_out


def frame_signal(x: jnp.ndarray, taps: int, tile: int) -> tuple[jnp.ndarray, int]:
    """(T,) → (n_tiles, frame_len) overlapping frames; returns padded frames
    and the number of valid output samples."""
    frames, n_out = frame_signal_batch(x[None, :], taps, tile)
    return frames[0], n_out


# ---------------------------------------------------------------------------
# specialized single-filter kernel (pulse schedule baked in at trace time)
# ---------------------------------------------------------------------------

def _fir_kernel_specialized(frame_ref, out_ref, *, pulses, taps, tile):
    """One grid step = one output tile.  `pulses` is a static tuple of
    (layer, j, sign) triples, MSB layer first."""
    fx = frame_ref[0, :].astype(jnp.int32)
    half = taps // 2
    # symmetric fold, built lazily: only the taps that carry pulses
    needed = sorted({j for (_, j, _) in pulses})
    u = {}
    for j in needed:
        if j == half:
            u[j] = jax.lax.dynamic_slice(fx, (half,), (tile,))
        else:
            a = jax.lax.dynamic_slice(fx, (j,), (tile,))
            b = jax.lax.dynamic_slice(fx, (taps - 1 - j,), (tile,))
            u[j] = a + b
    acc = jnp.zeros((tile,), jnp.int32)
    layer_of = None
    for layer, j, sign in pulses:  # MSB layer first, grouped by layer
        if layer_of is None:
            layer_of = layer
        while layer_of > layer:  # Horner: one shift per layer boundary
            acc = acc << 1
            layer_of -= 1
        acc = acc + u[j] if sign > 0 else acc - u[j]
    if layer_of is not None and layer_of > 0:
        acc = acc << layer_of
    out_ref[0, :] = acc


def pulses_msb_first(qcoeffs: np.ndarray) -> tuple[tuple[int, int, int], ...]:
    """Static pulse schedule from quantized symmetric coefficients."""
    taps = qcoeffs.shape[0]
    digits = csd_digits(np.asarray(qcoeffs[: taps // 2 + 1], np.int64))
    out = []
    for layer in range(digits.shape[1] - 1, -1, -1):
        for j in np.nonzero(digits[:, layer])[0]:
            out.append((int(layer), int(j), int(digits[j, layer])))
    return tuple(out)


@functools.lru_cache(maxsize=1024)
def specialized_program(pulses, taps: int, tile: int, interpret: bool):
    """Compiled BLMAC program for one pulse schedule.

    LRU-cached on the pulse tuple: reprogramming a filter that was seen
    before is a dict hit, a new schedule costs one (cheap) trace — the
    software analogue of reloading the FPGA weight memory.  The returned
    callable is additionally jit-cached per input length.
    """
    kern = functools.partial(
        _fir_kernel_specialized, pulses=pulses, taps=taps, tile=tile
    )

    @jax.jit
    def run(x: jnp.ndarray) -> jnp.ndarray:
        frames, n_out = frame_signal(x.astype(jnp.int32), taps, tile)
        n_tiles, frame_len = frames.shape
        y = pl.pallas_call(
            kern,
            grid=(n_tiles,),
            in_specs=[pl.BlockSpec((1, frame_len), lambda i: (i, 0))],
            out_specs=pl.BlockSpec((1, tile), lambda i: (i, 0)),
            out_shape=jax.ShapeDtypeStruct((n_tiles, tile), jnp.int32),
            interpret=interpret,
        )(frames)
        return y.reshape(-1)[:n_out]

    return run


def blmac_fir_specialized(
    x: jnp.ndarray,
    pulses,
    taps: int,
    tile: int = 1024,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Apply one pulse-specialized filter; compiles at most once per
    distinct (pulse schedule, taps, tile, backend)."""
    return specialized_program(
        tuple(pulses), taps, tile, resolve_interpret(interpret)
    )(x)


# ---------------------------------------------------------------------------
# batched bank kernel (packed-trit operands, 3-D grid, layer-skip schedule)
# ---------------------------------------------------------------------------

def _fir_kernel_bank(
    frame_ref, packed_ref, out_ref, *, taps, tile, schedule, tail_shift,
    bank_tile, n_words
):
    """One grid step = one (bank tile × signal tile) block of one channel.

    `packed_ref` holds 2-bit trit codes, 16 per uint32 word (viewed as
    int32 — the `& 3` mask makes arithmetic vs logical shift moot), laid
    out (bank_tile, n_sel, n_words) over the folded half-filter, where
    the n_sel slices are ONLY the bit layers populated somewhere in this
    bank tile (MSB first — see `plan_bank_schedule`).

    `schedule` drives the Horner recursion: a static tuple of superlayer
    entries ``(shift_in, ((sel_idx, rel_weight), ...))``, MSB first.  Each
    entry shifts the accumulator left by the layer gap to the previous
    superlayer, sums its ``merge``-adjacent trit layers into one small-
    integer digit matrix, and contracts it against the shared window
    matrix in ONE ``(bank_tile, M) @ (M, tile)`` integer matmul.  Layers
    (and whole superlayers) empty across the tile appear nowhere: the
    emitted program length tracks the occupancy, not the worst case.
    """
    fx = frame_ref[0, 0, :].astype(jnp.int32)
    frame_len = fx.shape[0]
    half = taps // 2
    m_pad = n_words * TRITS_PER_WORD
    # The framed (M, tile) window matrix: one gather, built once per grid
    # step, shared by every superlayer and every filter in the bank tile.
    # Row j holds the symmetric fold u_j[t] = x[t+j] + x[t+taps-1-j]
    # (centre row: no fold); rows past the centre are zero and meet only
    # zero trits.
    j = jax.lax.broadcasted_iota(jnp.int32, (m_pad, tile), 0)
    t = jax.lax.broadcasted_iota(jnp.int32, (m_pad, tile), 1)
    fwd = fx[jnp.minimum(j + t, frame_len - 1)]
    rev = fx[jnp.clip(taps - 1 - j + t, 0, frame_len - 1)]
    u = jnp.where(j < half, fwd + rev, jnp.where(j == half, fwd, 0))

    words = packed_ref[...]  # (bank_tile, n_sel, n_words) int32
    shifts = 2 * jax.lax.broadcasted_iota(
        jnp.int32, (n_words, TRITS_PER_WORD), 1
    )

    def trit_layer(sel_idx):
        codes = (words[:, sel_idx, :, None] >> shifts[None]) & 3
        d = (codes == 1).astype(jnp.int32) - (codes == 3).astype(jnp.int32)
        return d.reshape(bank_tile, m_pad)

    acc = jnp.zeros((bank_tile, tile), jnp.int32)
    for shift_in, parts in schedule:  # MSB → LSB over populated superlayers
        if shift_in:
            acc = acc << shift_in
        d = None
        for sel_idx, rel in parts:
            dl = trit_layer(sel_idx)
            if rel:
                dl = dl << rel
            d = dl if d is None else d + dl
        # one integer matmul per populated superlayer: every pulse in the
        # tile is one lane-parallel add inside this contraction
        acc = acc + jnp.dot(d, u, preferred_element_type=jnp.int32)
    if tail_shift:
        acc = acc << tail_shift
    out_ref[...] = acc[:, None, None, :]


@functools.partial(
    jax.jit,
    static_argnames=(
        "taps", "schedule", "tail_shift", "tile", "bank_tile", "interpret"
    ),
)
def _bank_call(
    frames: jnp.ndarray,  # (C, n_tiles, frame_len) int32
    packed: jnp.ndarray,  # (B_pad, n_sel, n_words) int32, selected layers
    taps: int,
    schedule: tuple,
    tail_shift: int,
    tile: int,
    bank_tile: int,
    interpret: bool,
) -> jnp.ndarray:
    """Scheduled bank call.  jit's static-argument cache makes this the
    bank analogue of `specialized_program`: one compile per distinct
    (schedule, geometry) signature, every later dispatch a cache hit."""
    n_chan, n_tiles, frame_len = frames.shape
    b_pad, n_sel, n_words = packed.shape
    kern = functools.partial(
        _fir_kernel_bank,
        taps=taps,
        tile=tile,
        schedule=schedule,
        tail_shift=tail_shift,
        bank_tile=bank_tile,
        n_words=n_words,
    )
    return pl.pallas_call(
        kern,
        grid=(b_pad // bank_tile, n_chan, n_tiles),
        in_specs=[
            pl.BlockSpec((1, 1, frame_len), lambda b, c, s: (c, s, 0)),
            pl.BlockSpec((bank_tile, n_sel, n_words), lambda b, c, s: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec(
            (bank_tile, 1, 1, tile), lambda b, c, s: (b, c, s, 0)
        ),
        out_shape=jax.ShapeDtypeStruct((b_pad, n_chan, n_tiles, tile), jnp.int32),
        interpret=interpret,
    )(frames, packed)


def pack_bank_trits(
    qbank: np.ndarray,
    n_layers: int | None = None,
    sample_bits: int = 8,
) -> np.ndarray:
    """(B, taps) symmetric int coefficients → (B, n_layers, n_words) uint32
    packed trit words over the folded half-filter (M = taps//2 + 1 rows),
    layer-major so the kernel slices one layer per Horner step.

    The int32 accumulator bound (§2.1) is asserted HERE, once per pack —
    `blmac_fir_bank`, `blmac_fir_dynamic` and `FilterBankEngine` all
    consume packed operands and inherit the guarantee for ``sample_bits``
    inputs (default 8-bit, the paper's operating point)."""
    qbank = np.asarray(qbank, np.int64)
    if qbank.ndim != 2:
        raise ValueError("qbank must be (n_filters, taps)")
    taps = require_type1(qbank, "bank kernel")
    assert_int32_bound(qbank, sample_bits, "bank kernel")
    half = taps // 2
    digits = csd_digits(qbank[:, : half + 1], n_digits=n_layers)  # (B, M, L)
    return pack_trits(np.swapaxes(digits, 1, 2))  # (B, L, n_words)


def default_bank_tile(n_filters: int) -> int:
    """Bank-tile heuristic: whole bank in one tile up to the VMEM cap;
    above the cap, size the tile so the padded bank tracks n_filters
    (257 filters → 2 tiles of 136, not 2 tiles of 256)."""
    n = max(n_filters, 1)
    if n <= MAX_BANK_TILE:
        return _pad_to(n, 8)
    n_tiles = -(-n // MAX_BANK_TILE)
    return _pad_to(-(-n // n_tiles), 8)


# ---------------------------------------------------------------------------
# bank-wide sparsity schedule (pack-time planning)
# ---------------------------------------------------------------------------

# CSD layers fused per superlayer matmul (see plan_bank_schedule): the
# measured optimum on the reference machine; 1 recovers the paper-pure
# one-matmul-per-bit-layer kernel, 7 keeps superlayer digits in int8
# range for MXU operand packing.
MERGE_DEFAULT = 8


def superlayer_schedule(
    populated: tuple[int, ...], merge: int
) -> tuple[tuple, int, tuple[int, ...]]:
    """Compile a populated-layer set into a static Horner schedule.

    ``populated`` are the bit-layer indices holding ≥1 pulse anywhere in
    the bank tile.  Greedy MSB-first, layers within a span of ``merge``
    positions fuse into one superlayer (digit values then span
    ±(2^merge − 1), still far inside int32 given the pack-time bound).

    Returns ``(schedule, tail_shift, sel_layers)``:
      * ``schedule`` — tuple of ``(shift_in, ((sel_idx, rel_weight), …))``
        entries, MSB first, consumed verbatim by `_fir_kernel_bank`;
      * ``tail_shift`` — final left shift down to layer 0;
      * ``sel_layers`` — the packed-layer indices to gather, MSB first
        (``sel_idx`` indexes this tuple).
    """
    if merge < 1:
        raise ValueError("merge must be >= 1")
    layers = sorted((int(lyr) for lyr in populated), reverse=True)
    if not layers:
        return (), 0, ()
    runs: list[list[int]] = [[layers[0]]]
    for lyr in layers[1:]:
        if runs[-1][0] - lyr < merge:  # span (hi − lo) stays < merge
            runs[-1].append(lyr)
        else:
            runs.append([lyr])
    schedule = []
    sel_layers: list[int] = []
    prev_lo = None
    for run in runs:  # each run: descending layer indices
        lo = run[-1]
        shift_in = 0 if prev_lo is None else prev_lo - lo
        parts = tuple(
            (len(sel_layers) + i, lyr - lo) for i, lyr in enumerate(run)
        )
        sel_layers.extend(run)
        schedule.append((shift_in, parts))
        prev_lo = lo
    return tuple(schedule), prev_lo, tuple(sel_layers)


@dataclass(frozen=True)
class TileGroup:
    """A run of consecutive (post-sort) bank tiles sharing one compiled
    schedule — dispatched as one `pallas_call` with a tile-count grid."""

    schedule: tuple  # static Horner program (see superlayer_schedule)
    tail_shift: int
    sel_layers: tuple[int, ...]  # packed layer indices gathered, MSB first
    packed: np.ndarray  # (n_tiles * bank_tile, n_sel, n_words) uint32
    n_filters: int  # valid (non-pad) rows covered by this group


@dataclass(frozen=True)
class BankSchedule:
    """Pack-time product of `plan_bank_schedule`: occupancy-sorted filter
    permutation + per-group layer-skip schedules."""

    tile_size: int  # bank_tile
    merge: int
    perm: np.ndarray  # (B,) original index of the filter in permuted slot p
    inv: np.ndarray  # (B,) permuted slot of original filter b
    groups: tuple[TileGroup, ...]
    n_filters: int

    @property
    def n_superlayers(self) -> int:
        """Total scheduled matmuls per grid step, summed over groups —
        the quantity the dense kernel fixed at n_layers per tile."""
        return sum(len(g.schedule) for g in self.groups)


def plan_bank_schedule(
    packed: np.ndarray,
    bank_tile: int | None = None,
    merge: int = MERGE_DEFAULT,
) -> BankSchedule:
    """Sort a packed bank into occupancy-homogeneous tiles and compile a
    layer-skip schedule per tile group.

    Filters are ordered by their layer-occupancy signature (a bitmask of
    populated layers), partitioned into ``bank_tile`` rows, and each
    tile's schedule is built from the UNION occupancy of its rows — so a
    tile of truncated / low-precision / narrow-band filters never pays
    for layers only its neighbours populate.  Consecutive tiles with an
    identical schedule fuse into one `pallas_call` (one `TileGroup`).
    A tile whose union is empty (all-zero filters) is scheduled as a
    constant zero block — no kernel runs at all.
    """
    packed = np.asarray(packed)
    n_filters, n_layers, n_words = packed.shape
    if bank_tile is None:
        bank_tile = default_bank_tile(n_filters)
    occ = packed.any(axis=-1)  # (B, L) bool: layer populated in filter b
    sig = occupancy_signatures(occ)
    perm = np.argsort(sig, kind="stable")
    inv = np.empty(n_filters, np.int64)
    inv[perm] = np.arange(n_filters)
    b_pad = _pad_to(n_filters, bank_tile)
    occ_p = np.zeros((b_pad, n_layers), bool)
    occ_p[:n_filters] = occ[perm]
    packed_p = np.zeros((b_pad, n_layers, n_words), packed.dtype)
    packed_p[:n_filters] = packed[perm]

    groups: list[TileGroup] = []
    run_tiles: list[int] = []  # tile indices of the open run
    run_key = None
    n_tiles = b_pad // bank_tile

    def close_run():
        if not run_tiles:
            return
        schedule, tail_shift, sel_layers = run_key
        lo = run_tiles[0] * bank_tile
        hi = (run_tiles[-1] + 1) * bank_tile
        sel = (
            packed_p[lo:hi][:, list(sel_layers), :]
            if sel_layers
            else packed_p[lo:hi, :0, :]
        )
        groups.append(
            TileGroup(
                schedule=schedule,
                tail_shift=tail_shift,
                sel_layers=sel_layers,
                packed=np.ascontiguousarray(sel),
                n_filters=min(hi, n_filters) - min(lo, n_filters),
            )
        )

    for ti in range(n_tiles):
        union = occ_p[ti * bank_tile : (ti + 1) * bank_tile].any(axis=0)
        key = superlayer_schedule(tuple(np.nonzero(union)[0]), merge)
        if key != run_key:
            close_run()
            run_tiles = []
            run_key = key
        run_tiles.append(ti)
    close_run()
    return BankSchedule(
        tile_size=bank_tile,
        merge=merge,
        perm=perm,
        inv=inv,
        groups=tuple(groups),
        n_filters=n_filters,
    )


def pulses_from_packed(packed_row: np.ndarray, taps: int):
    """(n_layers, n_words) packed trits → MSB-first static pulse tuple
    (the `specialized_program` input) — the small-bank fast path's bridge
    from the bank operand format to the pulse-baked kernel."""
    half = taps // 2
    digits = unpack_trits(packed_row, half + 1)  # (L, M) int8
    out = []
    for layer in range(digits.shape[0] - 1, -1, -1):
        for j in np.nonzero(digits[layer])[0]:
            out.append((int(layer), int(j), int(digits[layer, j])))
    return tuple(out)


FAST_PATH_MAX = 1  # banks up to this size dispatch to specialized programs


def blmac_fir_bank(
    x: jnp.ndarray,  # (C, T) or (T,)
    packed: np.ndarray,  # (B, n_layers, n_words) uint32 from pack_bank_trits
    taps: int,
    tile: int = 1024,
    bank_tile: int | None = None,
    interpret: bool | None = None,
    merge: int = MERGE_DEFAULT,
    schedule: BankSchedule | None = None,
    fast_path: bool = True,
) -> jnp.ndarray:
    """Apply a B-filter bank to a C-channel signal with the scheduled
    bank kernel (one `pallas_call` per occupancy tile group).

    Returns int32 (B, C, T - taps + 1).  Bit-exact against
    `repro.filters.fir_bit_layers_batch` on integer inputs, whatever the
    schedule: grouping permutes filters internally and restores the
    caller's order on the way out.

    ``fast_path`` routes banks of ≤ `FAST_PATH_MAX` filters to the
    pulse-specialized kernel — a B=1 "bank" paid 0.70× the per-filter
    baseline in PR 1 purely in framing/padding overhead; now it costs
    exactly its pulse count.  Pass a precomputed ``schedule`` (from
    `plan_bank_schedule`) to skip planning on the hot path — the
    `FilterBankEngine` does this once at construction.
    """
    x = jnp.asarray(x)
    squeeze = x.ndim == 1
    if squeeze:
        x = x[None, :]
    packed = np.asarray(packed)
    n_filters = packed.shape[0]
    interpret = resolve_interpret(interpret)

    if fast_path and schedule is None and n_filters <= FAST_PATH_MAX:
        xi = x.astype(jnp.int32)
        n_out = xi.shape[-1] - taps + 1
        ys = [
            jnp.stack(
                [
                    blmac_fir_specialized(
                        xi[c], pulses_from_packed(packed[b], taps), taps,
                        tile, interpret,
                    )
                    for c in range(xi.shape[0])
                ]
            )
            for b in range(n_filters)
        ]
        y = jnp.stack(ys)[:, :, :n_out]
        return y[:, 0, :] if squeeze else y

    if schedule is None:
        schedule = plan_bank_schedule(packed, bank_tile, merge)
    frames, n_out = frame_signal_batch(x.astype(jnp.int32), taps, tile)
    y = bank_schedule_apply(frames, schedule, taps, tile, interpret)
    y = y[:, :, :n_out]
    return y[:, 0, :] if squeeze else y


def bank_schedule_apply(
    frames: jnp.ndarray,  # (C, n_tiles, frame_len) int32 framed signal
    schedule: BankSchedule,
    taps: int,
    tile: int,
    interpret: bool,
    device_groups: list | None = None,
) -> jnp.ndarray:
    """Run every tile group of a `BankSchedule` over pre-framed signal and
    reassemble rows in the caller's filter order → (B, C, n_tiles*tile).

    ``device_groups`` optionally supplies pre-uploaded packed operands
    (one per group, int32 view) so streaming callers don't re-stage the
    bank every chunk."""
    n_chan, n_tiles, _ = frames.shape
    parts = []
    for gi, g in enumerate(schedule.groups):
        rows = g.packed.shape[0]
        if not g.sel_layers:  # all-zero tile group: no kernel at all
            parts.append(
                jnp.zeros((rows, n_chan, n_tiles * tile), jnp.int32)
            )
            continue
        op = (
            device_groups[gi]
            if device_groups is not None
            else jnp.asarray(g.packed.view(np.int32))
        )
        y = _bank_call(
            frames, op, taps, g.schedule, g.tail_shift, tile,
            schedule.tile_size, interpret,
        )  # (rows, C, n_tiles, tile)
        parts.append(y.reshape(rows, n_chan, -1))
    y = jnp.concatenate(parts, axis=0) if len(parts) > 1 else parts[0]
    return y[schedule.inv]  # drop pad rows, restore caller's filter order


def blmac_fir_dynamic(
    x: jnp.ndarray,
    trits: np.ndarray,  # (n_layers, M_pad) int8, layer-major, {-1,0,1}
    taps: int,
    n_layers: int,
    tile: int = 1024,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Single-filter runtime-trit entry point: a B=1 scheduled bank call.

    The trits stay a runtime operand — the compile cache is keyed on the
    filter's layer-OCCUPANCY schedule, not its pulse list, so streaming
    many distinct filters through this path re-traces only when the set
    of populated layers changes (dense same-width filters share one
    program).  Use `blmac_fir_bank`'s fast path / `blmac_fir_specialized`
    when per-filter compilation is acceptable.  Accumulator width: int32,
    guaranteed by the pack-time `assert_int32_bound` for 16-bit coeffs ×
    8-bit samples at ≤255 taps (§2.1) — the same single check
    `FilterBankEngine` relies on.
    """
    trits = np.asarray(trits)
    half = taps // 2
    w_half = csd_decode(trits[:n_layers, : half + 1].T)  # (M,) int64
    assert_int32_bound(
        np.concatenate([w_half, w_half[:-1][::-1]]), 8, "blmac_fir_dynamic"
    )
    packed = pack_trits(trits[None, :n_layers, : half + 1])  # (1, L, W)
    return blmac_fir_bank(
        x, packed, taps, tile, bank_tile=1, interpret=interpret, fast_path=False
    )[0]
