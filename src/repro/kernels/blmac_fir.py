"""Pallas TPU kernels: BLMAC FIR filtering, single filters and whole banks.

TPU adaptation of the paper's machine (DESIGN.md §2): the FPGA executes one
add per pulse per *sample*; these kernels execute one VPU vector add per
pulse per *tile of output samples* (lane-parallel, pulse-serial).  The
symmetric pre-add (Eq. 3) is fused.  All arithmetic is exact int32
(§2.1: 16-bit coeffs × 8-bit samples × ≤255 taps fits 32 bits).

Three modes:

  * **specialized** — the CSD pulse list of ONE filter is baked into the
    kernel at trace time: the emitted program is literally `acc ±= u_j`
    per pulse plus one shift per bit layer — the paper's add-count cost
    model *is* the instruction count.  One (cheap) recompile per distinct
    pulse schedule, held in an LRU cache (`specialized_program`), exactly
    like reprogramming the FPGA weight memory.
  * **bank** — the workhorse for filter *banks*: one `pallas_call` over a
    3-D grid `(bank_tile, channel, signal_tile)` applies B filters to C
    channels.  Trits travel as **packed uint32 words** (16 two-bit trit
    codes per word, `core.csd.pack_trits` layout: 0b00=0, 0b01=+1,
    0b11=−1) and are unpacked in-kernel with shifts and masks.  Each grid
    step builds the framed `(M, tile)` window matrix ONCE with a single
    gather and reuses it for every filter in the bank tile; each bit
    layer is then one `(bank_tile, M) @ (M, tile)` integer matmul —
    Horner over layers, matmul over the bank.
  * **dynamic** — legacy single-filter runtime-trit entry point, now a
    B=1 bank call (kept for API compatibility and as the per-filter
    baseline in `benchmarks/bank_throughput.py`).

Input layout: the host frames each channel into overlapping tiles
(n_tiles, tile + taps − 1 padded to a lane multiple); BlockSpec then maps
one frame per grid step into VMEM.  The ~taps/tile halo duplication
(≈12% at tile=1024, taps=127) is the price of clean non-overlapping
BlockSpecs and is counted in the roofline maths.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from ..core.csd import csd_digits, pack_trits, require_type1
from .runtime import resolve_interpret

LANE = 128
TRITS_PER_WORD = 16
MAX_BANK_TILE = 256  # acc VMEM at tile=1024: 256×1024×4 B = 1 MiB


def _pad_to(n: int, m: int) -> int:
    return (n + m - 1) // m * m


# ---------------------------------------------------------------------------
# host-side framing (overlap-save layout)
# ---------------------------------------------------------------------------

def frame_signal_batch(
    x: jnp.ndarray, taps: int, tile: int
) -> tuple[jnp.ndarray, int]:
    """(C, T) → (C, n_tiles, frame_len) overlapping frames per channel;
    returns padded frames and the number of valid output samples."""
    t = x.shape[-1]
    n_out = t - taps + 1
    if n_out <= 0:
        raise ValueError("signal shorter than the filter")
    n_tiles = -(-n_out // tile)
    frame_len = _pad_to(tile + taps - 1, LANE)
    pad = (n_tiles - 1) * tile + frame_len - t
    xp = jnp.pad(x, ((0, 0), (0, max(0, pad))))
    idx = jnp.arange(n_tiles)[:, None] * tile + jnp.arange(frame_len)[None, :]
    return xp[:, idx], n_out


def frame_signal(x: jnp.ndarray, taps: int, tile: int) -> tuple[jnp.ndarray, int]:
    """(T,) → (n_tiles, frame_len) overlapping frames; returns padded frames
    and the number of valid output samples."""
    frames, n_out = frame_signal_batch(x[None, :], taps, tile)
    return frames[0], n_out


# ---------------------------------------------------------------------------
# specialized single-filter kernel (pulse schedule baked in at trace time)
# ---------------------------------------------------------------------------

def _fir_kernel_specialized(frame_ref, out_ref, *, pulses, taps, tile):
    """One grid step = one output tile.  `pulses` is a static tuple of
    (layer, j, sign) triples, MSB layer first."""
    fx = frame_ref[0, :].astype(jnp.int32)
    half = taps // 2
    # symmetric fold, built lazily: only the taps that carry pulses
    needed = sorted({j for (_, j, _) in pulses})
    u = {}
    for j in needed:
        if j == half:
            u[j] = jax.lax.dynamic_slice(fx, (half,), (tile,))
        else:
            a = jax.lax.dynamic_slice(fx, (j,), (tile,))
            b = jax.lax.dynamic_slice(fx, (taps - 1 - j,), (tile,))
            u[j] = a + b
    acc = jnp.zeros((tile,), jnp.int32)
    layer_of = None
    for layer, j, sign in pulses:  # MSB layer first, grouped by layer
        if layer_of is None:
            layer_of = layer
        while layer_of > layer:  # Horner: one shift per layer boundary
            acc = acc << 1
            layer_of -= 1
        acc = acc + u[j] if sign > 0 else acc - u[j]
    if layer_of is not None and layer_of > 0:
        acc = acc << layer_of
    out_ref[0, :] = acc


def pulses_msb_first(qcoeffs: np.ndarray) -> tuple[tuple[int, int, int], ...]:
    """Static pulse schedule from quantized symmetric coefficients."""
    taps = qcoeffs.shape[0]
    digits = csd_digits(np.asarray(qcoeffs[: taps // 2 + 1], np.int64))
    out = []
    for layer in range(digits.shape[1] - 1, -1, -1):
        for j in np.nonzero(digits[:, layer])[0]:
            out.append((int(layer), int(j), int(digits[j, layer])))
    return tuple(out)


@functools.lru_cache(maxsize=1024)
def specialized_program(pulses, taps: int, tile: int, interpret: bool):
    """Compiled BLMAC program for one pulse schedule.

    LRU-cached on the pulse tuple: reprogramming a filter that was seen
    before is a dict hit, a new schedule costs one (cheap) trace — the
    software analogue of reloading the FPGA weight memory.  The returned
    callable is additionally jit-cached per input length.
    """
    kern = functools.partial(
        _fir_kernel_specialized, pulses=pulses, taps=taps, tile=tile
    )

    @jax.jit
    def run(x: jnp.ndarray) -> jnp.ndarray:
        frames, n_out = frame_signal(x.astype(jnp.int32), taps, tile)
        n_tiles, frame_len = frames.shape
        y = pl.pallas_call(
            kern,
            grid=(n_tiles,),
            in_specs=[pl.BlockSpec((1, frame_len), lambda i: (i, 0))],
            out_specs=pl.BlockSpec((1, tile), lambda i: (i, 0)),
            out_shape=jax.ShapeDtypeStruct((n_tiles, tile), jnp.int32),
            interpret=interpret,
        )(frames)
        return y.reshape(-1)[:n_out]

    return run


def blmac_fir_specialized(
    x: jnp.ndarray,
    pulses,
    taps: int,
    tile: int = 1024,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Apply one pulse-specialized filter; compiles at most once per
    distinct (pulse schedule, taps, tile, backend)."""
    return specialized_program(
        tuple(pulses), taps, tile, resolve_interpret(interpret)
    )(x)


# ---------------------------------------------------------------------------
# batched bank kernel (packed-trit operands, 3-D grid)
# ---------------------------------------------------------------------------

def _fir_kernel_bank(
    frame_ref, packed_ref, out_ref, *, taps, tile, n_layers, bank_tile, n_words
):
    """One grid step = one (bank tile × signal tile) block of one channel.

    `packed_ref` holds 2-bit trit codes, 16 per uint32 word (viewed as
    int32 — the `& 3` mask makes arithmetic vs logical shift moot), laid
    out (bank_tile, n_layers, n_words) over the folded half-filter.
    """
    fx = frame_ref[0, 0, :].astype(jnp.int32)
    frame_len = fx.shape[0]
    half = taps // 2
    m_pad = n_words * TRITS_PER_WORD
    # The framed (M, tile) window matrix: one gather, built once per grid
    # step, shared by every filter in the bank tile.  Row j holds the
    # symmetric fold u_j[t] = x[t+j] + x[t+taps-1-j] (centre row: no fold);
    # rows past the centre are zero and meet only zero trits.
    j = jax.lax.broadcasted_iota(jnp.int32, (m_pad, tile), 0)
    t = jax.lax.broadcasted_iota(jnp.int32, (m_pad, tile), 1)
    fwd = fx[jnp.minimum(j + t, frame_len - 1)]
    rev = fx[jnp.clip(taps - 1 - j + t, 0, frame_len - 1)]
    u = jnp.where(j < half, fwd + rev, jnp.where(j == half, fwd, 0))

    words = packed_ref[...]  # (bank_tile, n_layers, n_words) int32
    shifts = 2 * jax.lax.broadcasted_iota(
        jnp.int32, (n_words, TRITS_PER_WORD), 1
    )
    acc = jnp.zeros((bank_tile, tile), jnp.int32)
    for layer in range(n_layers - 1, -1, -1):  # MSB → LSB Horner
        codes = (words[:, layer, :, None] >> shifts[None]) & 3
        d = (codes == 1).astype(jnp.int32) - (codes == 3).astype(jnp.int32)
        d = d.reshape(bank_tile, m_pad)
        # one integer matmul per bit layer: every pulse in the tile is one
        # lane-parallel add inside this contraction
        acc = (acc << 1) + jnp.dot(d, u, preferred_element_type=jnp.int32)
    out_ref[...] = acc[:, None, None, :]


@functools.partial(
    jax.jit,
    static_argnames=("taps", "n_layers", "tile", "bank_tile", "interpret"),
)
def _bank_call(
    frames: jnp.ndarray,  # (C, n_tiles, frame_len) int32
    packed: jnp.ndarray,  # (B_pad, n_layers, n_words) int32
    taps: int,
    n_layers: int,
    tile: int,
    bank_tile: int,
    interpret: bool,
) -> jnp.ndarray:
    n_chan, n_tiles, frame_len = frames.shape
    b_pad, _, n_words = packed.shape
    kern = functools.partial(
        _fir_kernel_bank,
        taps=taps,
        tile=tile,
        n_layers=n_layers,
        bank_tile=bank_tile,
        n_words=n_words,
    )
    return pl.pallas_call(
        kern,
        grid=(b_pad // bank_tile, n_chan, n_tiles),
        in_specs=[
            pl.BlockSpec((1, 1, frame_len), lambda b, c, s: (c, s, 0)),
            pl.BlockSpec((bank_tile, n_layers, n_words), lambda b, c, s: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec(
            (bank_tile, 1, 1, tile), lambda b, c, s: (b, c, s, 0)
        ),
        out_shape=jax.ShapeDtypeStruct((b_pad, n_chan, n_tiles, tile), jnp.int32),
        interpret=interpret,
    )(frames, packed)


def pack_bank_trits(qbank: np.ndarray, n_layers: int | None = None) -> np.ndarray:
    """(B, taps) symmetric int coefficients → (B, n_layers, n_words) uint32
    packed trit words over the folded half-filter (M = taps//2 + 1 rows),
    layer-major so the kernel slices one layer per Horner step."""
    qbank = np.asarray(qbank, np.int64)
    if qbank.ndim != 2:
        raise ValueError("qbank must be (n_filters, taps)")
    taps = require_type1(qbank, "bank kernel")
    half = taps // 2
    digits = csd_digits(qbank[:, : half + 1], n_digits=n_layers)  # (B, M, L)
    return pack_trits(np.swapaxes(digits, 1, 2))  # (B, L, n_words)


def default_bank_tile(n_filters: int) -> int:
    """Bank-tile heuristic: whole bank in one tile up to the VMEM cap;
    above the cap, size the tile so the padded bank tracks n_filters
    (257 filters → 2 tiles of 136, not 2 tiles of 256)."""
    n = max(n_filters, 1)
    if n <= MAX_BANK_TILE:
        return _pad_to(n, 8)
    n_tiles = -(-n // MAX_BANK_TILE)
    return _pad_to(-(-n // n_tiles), 8)


def blmac_fir_bank(
    x: jnp.ndarray,  # (C, T) or (T,)
    packed: np.ndarray,  # (B, n_layers, n_words) uint32 from pack_bank_trits
    taps: int,
    tile: int = 1024,
    bank_tile: int | None = None,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Apply a B-filter bank to a C-channel signal in ONE `pallas_call`.

    Returns int32 (B, C, T - taps + 1).  Bit-exact against
    `repro.filters.fir_bit_layers_batch` on integer inputs.
    """
    x = jnp.asarray(x)
    squeeze = x.ndim == 1
    if squeeze:
        x = x[None, :]
    packed = np.asarray(packed)
    n_filters, n_layers, n_words = packed.shape
    if bank_tile is None:
        bank_tile = default_bank_tile(n_filters)
    b_pad = _pad_to(n_filters, bank_tile)
    if b_pad != n_filters:
        packed = np.concatenate(
            [packed, np.zeros((b_pad - n_filters, n_layers, n_words), packed.dtype)]
        )
    frames, n_out = frame_signal_batch(x.astype(jnp.int32), taps, tile)
    y = _bank_call(
        frames,
        jnp.asarray(packed.view(np.int32)),
        taps,
        n_layers,
        tile,
        bank_tile,
        resolve_interpret(interpret),
    )  # (B_pad, C, n_tiles, tile)
    y = y.reshape(b_pad, y.shape[1], -1)[:n_filters, :, :n_out]
    return y[:, 0, :] if squeeze else y


def blmac_fir_dynamic(
    x: jnp.ndarray,
    trits: np.ndarray,  # (n_layers, M_pad) int8, layer-major, {-1,0,1}
    taps: int,
    n_layers: int,
    tile: int = 1024,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Single-filter runtime-trit path: a B=1 bank call on packed words.

    Kept for API compatibility; `benchmarks/bank_throughput.py` uses it as
    the per-filter baseline the batched kernel is measured against.
    """
    trits = np.asarray(trits)
    half = taps // 2
    packed = pack_trits(trits[None, :n_layers, : half + 1])  # (1, L, W)
    return blmac_fir_bank(x, packed, taps, tile, bank_tile=1, interpret=interpret)[0]
