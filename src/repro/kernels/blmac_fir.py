"""Pallas TPU kernel: BLMAC FIR filtering.

TPU adaptation of the paper's machine (DESIGN.md §2): the FPGA executes one
add per pulse per *sample*; this kernel executes one VPU vector add per
pulse per *tile of output samples* (lane-parallel, pulse-serial).  The
symmetric pre-add (Eq. 3) is fused.  All arithmetic is exact int32
(§2.1: 16-bit coeffs × 8-bit samples × ≤255 taps fits 32 bits).

Two modes:
  * specialized=True  — the CSD pulse list is baked into the kernel at
    trace time: the emitted program is literally `acc ±= u_j` per pulse
    plus one shift per bit layer — the paper's add-count cost model *is*
    the instruction count.  One (cheap) recompile per filter, amortized
    over the stream, exactly like reprogramming the FPGA weight memory.
  * specialized=False — trits are a runtime operand and each bit layer is
    a dense ternary masked reduction; no recompilation per filter, ~N_b×
    more vector work (still multiplication-free).

Input layout: the host frames the signal into overlapping tiles
(n_tiles, tile + taps − 1 padded to a lane multiple); BlockSpec then maps
one frame per grid step into VMEM.  The ~taps/tile halo duplication
(≈12% at tile=1024, taps=127) is the price of clean non-overlapping
BlockSpecs and is counted in the roofline maths.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from ..core.csd import csd_digits

LANE = 128


def _pad_to(n: int, m: int) -> int:
    return (n + m - 1) // m * m


def frame_signal(x: jnp.ndarray, taps: int, tile: int) -> tuple[jnp.ndarray, int]:
    """(T,) → (n_tiles, frame_len) overlapping frames; returns padded frames
    and the number of valid output samples."""
    t = x.shape[0]
    n_out = t - taps + 1
    if n_out <= 0:
        raise ValueError("signal shorter than the filter")
    n_tiles = -(-n_out // tile)
    frame_len = _pad_to(tile + taps - 1, LANE)
    pad = (n_tiles - 1) * tile + frame_len - t
    xp = jnp.pad(x, (0, max(0, pad)))
    idx = jnp.arange(n_tiles)[:, None] * tile + jnp.arange(frame_len)[None, :]
    return xp[idx], n_out


def _fir_kernel_specialized(frame_ref, out_ref, *, pulses, taps, tile):
    """One grid step = one output tile.  `pulses` is a static tuple of
    (layer, j, sign) triples, MSB layer first."""
    fx = frame_ref[0, :].astype(jnp.int32)
    half = taps // 2
    # symmetric fold, built lazily: only the taps that carry pulses
    needed = sorted({j for (_, j, _) in pulses})
    u = {}
    for j in needed:
        if j == half:
            u[j] = jax.lax.dynamic_slice(fx, (half,), (tile,))
        else:
            a = jax.lax.dynamic_slice(fx, (j,), (tile,))
            b = jax.lax.dynamic_slice(fx, (taps - 1 - j,), (tile,))
            u[j] = a + b
    acc = jnp.zeros((tile,), jnp.int32)
    layer_of = None
    for layer, j, sign in pulses:  # MSB layer first, grouped by layer
        if layer_of is None:
            layer_of = layer
        while layer_of > layer:  # Horner: one shift per layer boundary
            acc = acc << 1
            layer_of -= 1
        acc = acc + u[j] if sign > 0 else acc - u[j]
    if layer_of is not None and layer_of > 0:
        acc = acc << layer_of
    out_ref[0, :] = acc


def _fir_kernel_dynamic(frame_ref, trits_ref, out_ref, *, taps, tile, n_layers):
    """Runtime-trit mode: dense ternary reduction per bit layer."""
    fx = frame_ref[0, :].astype(jnp.int32)
    half = taps // 2
    m = half + 1
    u_rows = []
    for j in range(m):
        a = jax.lax.dynamic_slice(fx, (j,), (tile,))
        if j != half:
            a = a + jax.lax.dynamic_slice(fx, (taps - 1 - j,), (tile,))
        u_rows.append(a)
    u = jnp.stack(u_rows)  # (M, tile) int32
    acc = jnp.zeros((tile,), jnp.int32)
    for layer in range(n_layers - 1, -1, -1):  # MSB → LSB
        d = trits_ref[layer, :m].astype(jnp.int32)  # (M,) in {-1,0,1}
        layer_sum = jnp.sum(jnp.where(d[:, None] == 0, 0,
                                      jnp.where(d[:, None] > 0, u, -u)), axis=0)
        acc = (acc << 1) + layer_sum
    out_ref[0, :] = acc


def pulses_msb_first(qcoeffs: np.ndarray) -> tuple[tuple[int, int, int], ...]:
    """Static pulse schedule from quantized symmetric coefficients."""
    taps = qcoeffs.shape[0]
    digits = csd_digits(np.asarray(qcoeffs[: taps // 2 + 1], np.int64))
    out = []
    for layer in range(digits.shape[1] - 1, -1, -1):
        for j in np.nonzero(digits[:, layer])[0]:
            out.append((int(layer), int(j), int(digits[j, layer])))
    return tuple(out)


@functools.partial(
    jax.jit, static_argnames=("pulses", "taps", "tile", "interpret")
)
def blmac_fir_specialized(
    x: jnp.ndarray, pulses, taps: int, tile: int = 1024, interpret: bool = True
) -> jnp.ndarray:
    frames, n_out = frame_signal(x.astype(jnp.int32), taps, tile)
    n_tiles, frame_len = frames.shape
    kern = functools.partial(
        _fir_kernel_specialized, pulses=pulses, taps=taps, tile=tile
    )
    y = pl.pallas_call(
        kern,
        grid=(n_tiles,),
        in_specs=[pl.BlockSpec((1, frame_len), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, tile), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_tiles, tile), jnp.int32),
        interpret=interpret,
    )(frames)
    return y.reshape(-1)[:n_out]


@functools.partial(
    jax.jit, static_argnames=("taps", "n_layers", "tile", "interpret")
)
def blmac_fir_dynamic(
    x: jnp.ndarray,
    trits: jnp.ndarray,  # (n_layers, M_pad) int8
    taps: int,
    n_layers: int,
    tile: int = 1024,
    interpret: bool = True,
) -> jnp.ndarray:
    frames, n_out = frame_signal(x.astype(jnp.int32), taps, tile)
    n_tiles, frame_len = frames.shape
    m_pad = trits.shape[1]
    kern = functools.partial(
        _fir_kernel_dynamic, taps=taps, tile=tile, n_layers=n_layers
    )
    y = pl.pallas_call(
        kern,
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec((1, frame_len), lambda i: (i, 0)),
            pl.BlockSpec((n_layers, m_pad), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, tile), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_tiles, tile), jnp.int32),
        interpret=interpret,
    )(frames, trits)
    return y.reshape(-1)[:n_out]
