"""Backend selection and autotuned dispatch shared by the Pallas kernels.

Kernels take ``interpret: bool | None`` and resolve ``None`` through
:func:`default_interpret` at trace time: on a TPU backend the
``pallas_call`` lowers to Mosaic; everywhere else (this container is
CPU-only) the kernel body runs under the Pallas interpreter, which is the
bit-exact validation mode the tests rely on.

`autotune_bank_dispatch` is the FIR bank dispatch planner: it sweeps a
small candidate grid of ``(mode, tile, bank_tile, merge)`` configurations
through the cost model in `repro.core.costmodel` (constants fitted on the
reference machine) and returns the winner together with its compiled
`BankSchedule`.  Since the one-program refactor both autotuners are thin
clients of `repro.compiler`: the bank argument may be a `BlmacProgram`
(preferred — the engines pass theirs) or a raw packed operand (wrapped
via `compile_packed`), every candidate schedule comes from the program's
memoized `schedule()` and every cost estimate from its
``predict_*_us`` readers — nothing here re-derives CSD, occupancy or
trit statistics.  The dispatch cache keys on the program's content
digest; hits/misses are reported by `repro.compiler.cache_stats()`.

Lives in its own leaf module so both ``ops.py`` (the public entry points)
and the kernel modules it imports can share it without a cycle (the
planner imports ``blmac_fir`` lazily for the same reason).
"""
from __future__ import annotations

import collections

import jax
import numpy as np

from ..compiler.cache import STATS as _COMPILER_STATS

__all__ = [
    "default_interpret",
    "resolve_interpret",
    "default_lane",
    "resolve_lane",
    "autotune_bank_dispatch",
    "autotune_sharded_dispatch",
    "SPECIALIZE_BANK_MAX",
    "MERGE_CANDIDATES",
    "COMPILED_MERGE_CANDIDATES",
]

# Specialized programs compile once per filter (~0.3 s each under the
# interpreter): banks wider than this never dispatch per-filter, whatever
# the steady-state model says, so the compile bill stays bounded.
SPECIALIZE_BANK_MAX = 32
MERGE_CANDIDATES = (1, 4, 8)
# Compiled lanes re-open the merge question: a superlayer matmul on a
# wide vector/matrix unit amortizes its pass over the window matrix far
# better than the interpreter did, so FEWER, FATTER superlayers win —
# 32 exceeds any 16-bit bank's layer count, i.e. full fusion into one
# dense (bank_tile, M) @ (M, signal) contraction.  Measured on the
# reference container (B=256, taps=63): full merge on the XLA lane is
# ~2× merge=8 on the same lane, inverting the interpret-era heuristic.
COMPILED_MERGE_CANDIDATES = (8, 16, 32)
DEFAULT_TILE = 512
# Tile is a measured lookup, not a model output: the analytic cost model
# is linear in tile and cannot capture the cache-residency cliff that
# actually decides it (a (bank_tile, tile) int32 accumulator past ~256 KiB
# goes memory-bound on the reference machine).  Measured optimum: 512
# everywhere except wide scheduled tiles, where 256 wins ~15%.  The
# cliff is a property of the interpreter's blocked accumulate; compiled
# lanes keep DEFAULT_TILE.
WIDE_BANK_TILE = 128


def _default_tile(mode: str, bank_tile: int) -> int:
    return 256 if mode == "scheduled" and bank_tile >= WIDE_BANK_TILE \
        else DEFAULT_TILE


def default_interpret() -> bool:
    """True when no TPU backend is present (interpret mode required)."""
    return jax.default_backend() != "tpu"


def resolve_interpret(interpret: bool | None) -> bool:
    """Resolve an ``interpret=None`` kernel argument to the backend default."""
    return default_interpret() if interpret is None else bool(interpret)


def default_lane() -> str:
    """The compiled execution lane this host can actually run: Mosaic on
    a TPU backend, Triton on a GPU backend, the plain-XLA lowering
    everywhere else (the CPU-compiled CI target)."""
    backend = jax.default_backend()
    if backend == "tpu":
        return "mosaic"
    if backend == "gpu":
        return "triton"
    return "xla"


def resolve_lane(lane: "str | bool | None") -> str:
    """Resolve a ``compiled=`` argument to a lane name: ``True``/``None``
    → this host's `default_lane`, a string → itself (validated)."""
    from .blmac_fir import LANES

    if lane is None or lane is True:
        return default_lane()
    if lane in LANES:
        return str(lane)
    raise ValueError(f"unknown lane {lane!r}; expected one of {LANES}")


def _resolve_program(bank, taps):
    """Accept a `BlmacProgram` (preferred) or a packed operand + taps."""
    from ..compiler import BlmacProgram, compile_packed

    if isinstance(bank, BlmacProgram):
        if taps is not None and int(taps) != bank.taps:
            raise ValueError(f"program is {bank.taps}-tap, got taps={taps}")
        return bank
    if taps is None:
        raise ValueError("taps is required with a packed-operand bank")
    return compile_packed(np.ascontiguousarray(bank), int(taps))


def autotune_bank_dispatch(
    bank,  # BlmacProgram, or (B, n_layers, n_words) uint32 packed operand
    taps: int | None = None,
    channels: int = 1,
    tile: int | None = None,
    chunk_hint: int = 2048,
    interpret: bool | None = None,
    compiled: "bool | str" = False,
):
    """Pick ``(mode, tile, bank_tile, merge)`` for a compiled bank.

    Evaluates the cost model over the candidate grid — the specialized
    per-filter loop (narrow banks only, see `SPECIALIZE_BANK_MAX`) versus
    occupancy-grouped scheduled tiles at each ``(bank_tile, merge)`` —
    and returns ``(plan, schedule)``: the winning
    `repro.core.costmodel.BankDispatchPlan` plus, for scheduled mode, the
    `BankSchedule` it was costed with (so callers never re-plan).

    ``bank`` is a `repro.compiler.BlmacProgram` or a raw `pack_bank_trits`
    operand (then ``taps`` is required; the operand is wrapped content-
    addressed via `compile_packed`).  Candidate schedules come from the
    program's memo, so an engine autotuning then serving the same bank
    plans each geometry once.  ``chunk_hint`` is the expected samples per
    dispatch, the autotuner's amortization knob (streaming engines push
    small chunks → dispatch overhead matters more; one-shot batch jobs
    amortize it).  ``tile`` defaults to the measured per-mode lookup
    (see `_default_tile`).

    ``compiled`` opts the sweep into the compiled execution lanes:
    ``True`` adds this host's `default_lane` (a lane name string pins
    one explicitly), costed at the wider `COMPILED_MERGE_CANDIDATES`
    with that lane's `BackendCalibration` — fitted at first use via
    `repro.core.costmodel.ensure_calibration`.  The interpret candidates
    stay in the sweep, so the winning ``plan.lane`` answers "does the
    compiled lowering pay here?".  The default (``False``) keeps the
    historic interpret-only sweep byte-for-byte.

    An `repro.compiler.OptimizedProgram` (CSE pass output) is swept over
    its shared-row layout — `predict_scheduled_us` prices the combine
    stage — AND compared against autotuning its parent: when the parent
    wins, the returned plan carries ``cse="declined"`` with the PARENT's
    schedule, and the engine executes the parent (bit-identical
    outputs); otherwise ``cse="optimized"``.
    """
    program = _resolve_program(bank, taps)
    lanes: "tuple[str, ...]" = ("interpret",)
    if compiled:
        lanes = ("interpret", resolve_lane(compiled))
    key = (
        program.key, channels, tile, chunk_hint, resolve_interpret(interpret),
        lanes,
    )
    if key in _AUTOTUNE_CACHE:
        _AUTOTUNE_CACHE.move_to_end(key)
        _COMPILER_STATS["autotune"].hit()
        return _AUTOTUNE_CACHE[key]
    _COMPILER_STATS["autotune"].miss()
    result = _autotune(program, channels, tile, chunk_hint, lanes=lanes)
    if program.combine is not None:
        import dataclasses

        parent_plan, parent_sched = autotune_bank_dispatch(
            program.parent, channels=channels, tile=tile,
            chunk_hint=chunk_hint, interpret=interpret, compiled=compiled,
        )
        opt_plan, opt_sched = result
        if parent_plan.predicted_us < opt_plan.predicted_us:
            result = (
                dataclasses.replace(parent_plan, cse="declined"),
                parent_sched,
            )
        else:
            result = (
                dataclasses.replace(opt_plan, cse="optimized"), opt_sched
            )
    _AUTOTUNE_CACHE[key] = result
    while len(_AUTOTUNE_CACHE) > _AUTOTUNE_CACHE_MAX:
        _AUTOTUNE_CACHE.popitem(last=False)
    return result


_AUTOTUNE_CACHE: "collections.OrderedDict" = collections.OrderedDict()
_AUTOTUNE_CACHE_MAX = 16  # schedules hold compacted bank copies: keep few


def _autotune(program, channels, tile, chunk_hint, allow_specialized=True,
              lanes=("interpret",)):
    from ..compiler import default_bank_tile
    from ..core.costmodel import BankDispatchPlan, ensure_calibration

    n_filters = program.n_filters

    def n_tiles(t):
        return max(1, -(-chunk_hint // t))

    best = None  # (plan, schedule)
    if allow_specialized and n_filters <= SPECIALIZE_BANK_MAX:
        t = tile or _default_tile("specialized", 1)
        us = program.predict_specialized_us(channels, n_tiles(t))
        best = (BankDispatchPlan("specialized", t, 1, 1, us), None)
    bank_tiles = {default_bank_tile(n_filters)}
    if n_filters > 8:
        bank_tiles.add(min(default_bank_tile(n_filters), 32))
    for lane in lanes:
        if lane == "interpret":
            # the historic sweep: reference constants, blocked-tile lookup
            cal, merges = None, MERGE_CANDIDATES
        else:
            cal = ensure_calibration(lane)  # fit-at-first-use, persisted
            merges = COMPILED_MERGE_CANDIDATES
        for bt in sorted(bank_tiles):
            for merge in merges:
                schedule = program.schedule(bt, merge)
                t = tile or (
                    _default_tile("scheduled", bt)
                    if lane == "interpret" else DEFAULT_TILE
                )
                us = program.predict_scheduled_us(
                    channels, n_tiles(t), t, bt, merge, cal=cal
                )
                plan = BankDispatchPlan("scheduled", t, bt, merge, us, lane)
                if best is None or us < best[0].predicted_us:
                    best = (plan, schedule)
    return best


# ---------------------------------------------------------------------------
# mesh-aware sharded dispatch planning
# ---------------------------------------------------------------------------


def autotune_sharded_dispatch(
    bank,  # BlmacProgram, or (B, n_layers, n_words) uint32 packed operand
    taps: int | None = None,
    channels: int = 1,
    mesh_shape: "tuple[int, int]" = (1, 1),
    tile: int | None = None,
    chunk_hint: int = 2048,
    interpret: bool | None = None,
    force_shards: int | None = None,
    force_data: str | None = None,
    compiled: "bool | str" = False,
):
    """Plan a bank dispatch over an (n_bank, n_data) device mesh.

    Sweeps the bank-shard count over {1, 2, 4, …, n_bank} (occupancy-
    balanced contiguous partitions from
    `repro.distributed.sharding.partition_bank`), runs the single-device
    autotuner on EVERY candidate shard (per-shard mode/tile/merge picks,
    with the data-axis slice of the chunk folded into its amortization
    knob), and scores candidates with the critical-path model
    `repro.core.costmodel.predict_sharded_us`.  The unsharded plan
    competes in the same sweep, so the winner answers "does sharding pay
    at all?" — `ShardedBankPlan.n_bank_shards == 1` means it does not.

    Returns ``(plan, partition, schedules)``: the winning
    `ShardedBankPlan`, its `BankPartition`, and one `BankSchedule` (or
    ``None`` for specialized shards) per bank shard, so callers never
    re-plan.  ``bank`` is a `BlmacProgram` or a raw packed operand (then
    ``taps`` is required); per-shard candidates are the program's
    memoized `select()` subprograms — the exact objects the sharded
    engine then executes, so autotuning and serving share one compiled
    artifact per shard.  LRU-cached on the program digest like
    `autotune_bank_dispatch`.  ``force_shards`` pins the bank-shard count
    (the sweep collapses to that single candidate — mode/tile per shard
    are still autotuned); ``force_data`` pins the data-axis usage to
    ``"none"``, ``"channels"`` or ``"time"`` instead of letting the
    sweep decline the axis.  ``compiled`` adds the compiled execution
    lanes to every per-shard sweep, exactly as in
    `autotune_bank_dispatch` — per-shard plans then carry the winning
    ``lane`` and the host-dispatch costs are priced with that lane's
    calibration.

    An `OptimizedProgram` plans its augmented shared-row bank (via
    ``.bank``; the host folds ``combine`` after the gather, priced with
    `predict_combine_us`) and competes against planning its parent —
    the winner's plan carries ``cse="optimized"`` or ``cse="declined"``
    so callers know which program's rows the partition/schedules
    describe.
    """
    program = _resolve_program(bank, taps)
    n_bank, n_data = int(mesh_shape[0]), int(mesh_shape[1])
    lanes: "tuple[str, ...]" = ("interpret",)
    if compiled:
        lanes = ("interpret", resolve_lane(compiled))
    key = (
        "sharded", program.key, channels, n_bank, n_data, tile, chunk_hint,
        resolve_interpret(interpret), force_shards, force_data, lanes,
    )
    if key in _AUTOTUNE_CACHE:
        _AUTOTUNE_CACHE.move_to_end(key)
        _COMPILER_STATS["autotune"].hit()
        return _AUTOTUNE_CACHE[key]
    _COMPILER_STATS["autotune"].miss()
    if program.combine is not None:
        result = _sharded_cse_compare(
            program, channels, n_bank, n_data, tile, chunk_hint,
            force_shards, force_data, lanes,
        )
    else:
        result = _autotune_sharded(
            program, channels, n_bank, n_data, tile, chunk_hint,
            force_shards, force_data, lanes=lanes,
        )
    _AUTOTUNE_CACHE[key] = result
    while len(_AUTOTUNE_CACHE) > _AUTOTUNE_CACHE_MAX:
        _AUTOTUNE_CACHE.popitem(last=False)
    return result


def _sharded_cse_compare(program, channels, n_bank, n_data, tile,
                         chunk_hint, force_shards, force_data, lanes):
    """Sharded plan for an `OptimizedProgram`: plan the augmented bank
    (+ the host-side combine fold after the gather) against planning
    the parent outright, and tag the winner's ``cse`` field."""
    import dataclasses

    from ..core.costmodel import predict_combine_us

    opt_plan, opt_part, opt_scheds = _autotune_sharded(
        program.bank, channels, n_bank, n_data, tile, chunk_hint,
        force_shards, force_data, lanes=lanes,
    )
    # the fold is host numpy on the gathered result — reference-constant
    # pricing, like the host dispatch terms above
    t = opt_plan.shard_plans[0].tile
    combine_us = predict_combine_us(
        program.n_real, program.n_shared, channels,
        max(1, -(-chunk_hint // t)), t,
    )
    opt_plan = dataclasses.replace(
        opt_plan, predicted_us=opt_plan.predicted_us + combine_us,
        cse="optimized",
    )
    parent_plan, parent_part, parent_scheds = _autotune_sharded(
        program.parent, channels, n_bank, n_data, tile, chunk_hint,
        force_shards, force_data, lanes=lanes,
    )
    if parent_plan.predicted_us < opt_plan.predicted_us:
        return (
            dataclasses.replace(parent_plan, cse="declined"),
            parent_part, parent_scheds,
        )
    return opt_plan, opt_part, opt_scheds


def _shard_candidates(n_bank: int, n_filters: int) -> "list[int]":
    """Bank-shard counts to sweep: powers of two up to the axis, the axis
    itself, all clamped to the bank size."""
    cands = {1}
    c = 2
    while c < n_bank:
        cands.add(c)
        c *= 2
    cands.add(n_bank)
    return sorted({min(c, n_filters) for c in cands})


def _autotune_sharded(program, channels, n_bank, n_data, tile,
                      chunk_hint, force_shards=None, force_data=None,
                      lanes=("interpret",)):
    from ..core.costmodel import (PALLAS_CALL_US, SPEC_CALL_US,
                                  ShardedBankPlan, get_calibration,
                                  predict_sharded_us)

    taps = program.taps
    n_filters = program.n_filters
    # data-axis candidates: using the axis (channels when divisible, else
    # time chunks with a halo exchange) AND leaving it idle — the sweep
    # may decline EITHER mesh axis; the engine degrades per-shard to a
    # single-device row when nd == 1 wins
    data_cands = [(1, "none", channels, chunk_hint)]
    if n_data > 1:
        if channels % n_data == 0:
            data_cands.append(
                (n_data, "channels", channels // n_data, chunk_hint)
            )
        else:
            data_cands.append(
                (n_data, "time", channels,
                 max(taps, -(-chunk_hint // n_data)))
            )
    if force_data is not None:
        data_cands = [c for c in data_cands if c[1] == force_data]
        if not data_cands:
            raise ValueError(
                f"data mode {force_data!r} is not available on a "
                f"({n_bank}, {n_data}) mesh with {channels} channel(s)"
            )

    if force_shards is not None:
        candidates = [max(1, min(int(force_shards), n_bank, n_filters))]
    else:
        candidates = _shard_candidates(n_bank, n_filters)
    best = None  # (ShardedBankPlan, partition, schedules)
    for nd, data_mode, chan_local, chunk_local in data_cands:
        for n_shards in candidates:
            part = program.partition(n_shards)
            # two mode policies per shard count: each shard's free pick,
            # and all-scheduled — the per-shard optimum is chosen in
            # isolation, but specialized shards pay one HOST dispatch
            # per filter, and the host is serial across the mesh; only
            # the sharded objective can see that, so it must get both
            # variants to rank
            policies = (
                (True, False) if data_mode == "none" else (False,)
            )
            for allow_spec in policies:
                plans, schedules, costs, host = [], [], [], []
                for rows in part.assign:
                    sub = program.select(rows)  # memoized shard subprogram
                    plan, schedule = _autotune(
                        sub, chan_local, tile, chunk_local,
                        allow_specialized=allow_spec, lanes=lanes,
                    )
                    plans.append(plan)
                    schedules.append(schedule)
                    costs.append(plan.predicted_us)
                    # host dispatch is priced with the winning lane's
                    # constants (interpret keeps the reference values)
                    if plan.lane == "interpret":
                        call_us, spec_us = PALLAS_CALL_US, SPEC_CALL_US
                    else:
                        c = get_calibration(plan.lane)
                        call_us, spec_us = c.call_us, c.spec_call_us
                    if plan.mode == "specialized":
                        host.append(len(rows) * chan_local * spec_us)
                    else:
                        host.append(
                            sum(1 for g in schedule.groups if g.sel_layers)
                            * call_us
                        )
                if allow_spec and not any(
                    p.mode == "specialized" for p in plans
                ):
                    continue  # identical to the all-scheduled variant
                us = predict_sharded_us(costs, nd, data_mode, host_us=host)
                if n_shards == 1 and nd == 1:
                    us = plans[0].predicted_us  # true unsharded baseline
                cand = (
                    ShardedBankPlan(
                        n_bank_shards=n_shards,
                        n_data=nd,
                        data_mode=data_mode,
                        shard_plans=tuple(plans),
                        predicted_us=us,
                    ),
                    part,
                    tuple(schedules),
                )
                if best is None or us < best[0].predicted_us:
                    best = cand
    return best
