"""Backend selection and autotuned dispatch shared by the Pallas kernels.

Kernels take ``interpret: bool | None`` and resolve ``None`` through
:func:`default_interpret` at trace time: on a TPU backend the
``pallas_call`` lowers to Mosaic; everywhere else (this container is
CPU-only) the kernel body runs under the Pallas interpreter, which is the
bit-exact validation mode the tests rely on.

`autotune_bank_dispatch` is the FIR bank dispatch planner: it sweeps a
small candidate grid of ``(mode, tile, bank_tile, merge)`` configurations
through the cost model in `repro.core.costmodel` (constants fitted on the
reference machine) and returns the winner together with its compiled
`BankSchedule`.  LRU-cached on a content digest of the packed bank,
exactly like `specialized_program` caches pulse schedules — re-dispatching
a bank that was seen before costs a hash plus a dict hit.

Lives in its own leaf module so both ``ops.py`` (the public entry points)
and the kernel modules it imports can share it without a cycle (the
planner imports ``blmac_fir`` lazily for the same reason).
"""
from __future__ import annotations

import collections
import hashlib

import jax
import numpy as np

__all__ = [
    "default_interpret",
    "resolve_interpret",
    "autotune_bank_dispatch",
    "SPECIALIZE_BANK_MAX",
    "MERGE_CANDIDATES",
]

# Specialized programs compile once per filter (~0.3 s each under the
# interpreter): banks wider than this never dispatch per-filter, whatever
# the steady-state model says, so the compile bill stays bounded.
SPECIALIZE_BANK_MAX = 32
MERGE_CANDIDATES = (1, 4, 8)
DEFAULT_TILE = 512
# Tile is a measured lookup, not a model output: the analytic cost model
# is linear in tile and cannot capture the cache-residency cliff that
# actually decides it (a (bank_tile, tile) int32 accumulator past ~256 KiB
# goes memory-bound on the reference machine).  Measured optimum: 512
# everywhere except wide scheduled tiles, where 256 wins ~15%.
WIDE_BANK_TILE = 128


def _default_tile(mode: str, bank_tile: int) -> int:
    return 256 if mode == "scheduled" and bank_tile >= WIDE_BANK_TILE \
        else DEFAULT_TILE


def default_interpret() -> bool:
    """True when no TPU backend is present (interpret mode required)."""
    return jax.default_backend() != "tpu"


def resolve_interpret(interpret: bool | None) -> bool:
    """Resolve an ``interpret=None`` kernel argument to the backend default."""
    return default_interpret() if interpret is None else bool(interpret)


def autotune_bank_dispatch(
    packed: np.ndarray,  # (B, n_layers, n_words) uint32 from pack_bank_trits
    taps: int,
    channels: int = 1,
    tile: int | None = None,
    chunk_hint: int = 2048,
    interpret: bool | None = None,
):
    """Pick ``(mode, tile, bank_tile, merge)`` for a packed bank.

    Evaluates the cost model over the candidate grid — the specialized
    per-filter loop (narrow banks only, see `SPECIALIZE_BANK_MAX`) versus
    occupancy-grouped scheduled tiles at each ``(bank_tile, merge)`` —
    and returns ``(plan, schedule)``: the winning
    `repro.core.costmodel.BankDispatchPlan` plus, for scheduled mode, the
    `BankSchedule` it was costed with (so callers never re-plan).

    ``chunk_hint`` is the expected samples per dispatch, the autotuner's
    amortization knob (streaming engines push small chunks → dispatch
    overhead matters more; one-shot batch jobs amortize it).  ``tile``
    defaults to the measured per-mode lookup (see `_default_tile`).
    """
    packed = np.ascontiguousarray(packed)
    # key on a content digest, not the bytes themselves: hashing reads the
    # buffer in place (no copy) and the cache retains 32 bytes per bank
    # instead of pinning whole packed banks for the process lifetime
    key = (
        hashlib.sha256(packed).digest(), packed.shape, taps, channels,
        tile, chunk_hint, resolve_interpret(interpret),
    )
    if key in _AUTOTUNE_CACHE:
        _AUTOTUNE_CACHE.move_to_end(key)
        return _AUTOTUNE_CACHE[key]
    result = _autotune(packed, taps, channels, tile, chunk_hint)
    _AUTOTUNE_CACHE[key] = result
    while len(_AUTOTUNE_CACHE) > _AUTOTUNE_CACHE_MAX:
        _AUTOTUNE_CACHE.popitem(last=False)
    return result


_AUTOTUNE_CACHE: "collections.OrderedDict" = collections.OrderedDict()
_AUTOTUNE_CACHE_MAX = 16  # schedules hold compacted bank copies: keep few


def _autotune(packed, taps, channels, tile, chunk_hint):
    from ..core.costmodel import (BankDispatchPlan, predict_scheduled_us,
                                  predict_specialized_us)
    from ..core.csd import unpack_trits
    from .blmac_fir import TRITS_PER_WORD, default_bank_tile, plan_bank_schedule

    n_filters, n_layers, n_words = packed.shape
    m_pad = n_words * TRITS_PER_WORD

    def n_tiles(t):
        return max(1, -(-chunk_hint // t))

    best = None  # (plan, schedule)
    if n_filters <= SPECIALIZE_BANK_MAX:
        trits = unpack_trits(packed, m_pad)  # (B, L, m_pad)
        mean_pulses = float(np.count_nonzero(trits) / max(n_filters, 1))
        t = tile or _default_tile("specialized", 1)
        us = predict_specialized_us(
            n_filters, channels, n_tiles(t), taps, mean_pulses, n_layers
        )
        best = (BankDispatchPlan("specialized", t, 1, 1, us), None)
    bank_tiles = {default_bank_tile(n_filters)}
    if n_filters > 8:
        bank_tiles.add(min(default_bank_tile(n_filters), 32))
    for bt in sorted(bank_tiles):
        for merge in MERGE_CANDIDATES:
            schedule = plan_bank_schedule(packed, bt, merge)
            groups = [
                (
                    g.packed.shape[0] // bt,
                    bt,
                    len(g.schedule),
                    len(g.sel_layers),
                )
                for g in schedule.groups
            ]
            t = tile or _default_tile("scheduled", bt)
            us = predict_scheduled_us(channels, n_tiles(t), t, m_pad, groups)
            plan = BankDispatchPlan("scheduled", t, bt, merge, us)
            if best is None or us < best[0].predicted_us:
                best = (plan, schedule)
    return best
