"""Backend selection shared by every Pallas kernel in this package.

Kernels take ``interpret: bool | None`` and resolve ``None`` through
:func:`default_interpret` at trace time: on a TPU backend the
``pallas_call`` lowers to Mosaic; everywhere else (this container is
CPU-only) the kernel body runs under the Pallas interpreter, which is the
bit-exact validation mode the tests rely on.

Lives in its own leaf module so both ``ops.py`` (the public entry points)
and the kernel modules it imports can share it without a cycle.
"""
from __future__ import annotations

import jax

__all__ = ["default_interpret", "resolve_interpret"]


def default_interpret() -> bool:
    """True when no TPU backend is present (interpret mode required)."""
    return jax.default_backend() != "tpu"


def resolve_interpret(interpret: bool | None) -> bool:
    """Resolve an ``interpret=None`` kernel argument to the backend default."""
    return default_interpret() if interpret is None else bool(interpret)
