"""Pure-jnp oracles for every Pallas kernel (the `ref.py` contract).

These are the ground truth the kernels are swept against in
`tests/test_kernels.py`; they are also the fallback implementation on
backends without Pallas support.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def blmac_fir_ref(x: jnp.ndarray, qcoeffs: np.ndarray) -> jnp.ndarray:
    """Exact type-I FIR via CSD bit layers (Eq. 2 + Eq. 3), jnp.

    ``x``: (T,) integer samples; ``qcoeffs``: (taps,) host-side int64
    quantized symmetric coefficients (static).  Returns (T - taps + 1,)
    int32 — identical to ``filters.apply.fir_bit_layers``.

    The CSD digits are read off the content-addressed compiled program
    (`repro.compiler.compile_bank`) — the same artifact the kernels
    execute — so this oracle cannot drift from the bank semantics; only
    the jnp Horner recursion below is independent.
    """
    from ..compiler import compile_bank

    taps = qcoeffs.shape[0]
    half = taps // 2
    x = x.astype(jnp.int32)
    n_out = x.shape[0] - taps + 1
    # symmetric fold (Eq. 3)
    folded = [
        x[j : j + n_out] + x[taps - 1 - j : taps - 1 - j + n_out]
        for j in range(half)
    ]
    folded.append(x[half : half + n_out])
    prog = compile_bank(np.asarray(qcoeffs, np.int64)[None, :])
    digits = prog.half_digits()[0]  # static (M, L)
    acc = jnp.zeros((n_out,), jnp.int32)
    for layer in range(digits.shape[1] - 1, -1, -1):
        acc = acc << 1
        for j in np.nonzero(digits[:, layer])[0]:
            acc = acc + folded[j] if digits[j, layer] > 0 else acc - folded[j]
    return acc


def fir_direct_ref(x: jnp.ndarray, qcoeffs: np.ndarray) -> jnp.ndarray:
    """Classical dot-product FIR (int32), the independent oracle."""
    taps = qcoeffs.shape[0]
    n_out = x.shape[0] - taps + 1
    w = jnp.asarray(np.asarray(qcoeffs), jnp.int32)
    windows = jnp.stack([x[j : j + n_out].astype(jnp.int32) for j in range(taps)], 1)
    return windows @ w


def pulse_matmul_ref(
    x: jnp.ndarray,
    codes: jnp.ndarray,
    group_exp: jnp.ndarray,
    out_dtype=jnp.float32,
) -> jnp.ndarray:
    """Oracle for the pulse-code (CSD-P) quantized matmul.

    ``codes``: (P, K, N) uint8, bit7=valid, bit6=sign, bits3..0=pos.
    ``group_exp``: (K // group, N) int8 — weight = Σ_p ±2**(e_g − 14 + pos).
    Reconstructs the float weight matrix then does a plain matmul.
    """
    w = pulse_decode_ref(codes, group_exp, x.shape[-1] and None)
    return jnp.dot(x.astype(jnp.float32), w).astype(out_dtype)


def pulse_decode_ref(codes: jnp.ndarray, group_exp: jnp.ndarray, _=None) -> jnp.ndarray:
    """Decode pulse codes to the float32 weight matrix (K, N)."""
    P, K, N = codes.shape
    G = group_exp.shape[0]
    group = K // G
    valid = (codes >> 7) & 1
    sign = jnp.where((codes >> 6) & 1 == 1, -1.0, 1.0)
    pos = (codes & 0x0F).astype(jnp.int32)
    e = jnp.repeat(group_exp.astype(jnp.int32), group, axis=0)  # (K, N)
    mag = jnp.exp2((e[None] - 14 + pos).astype(jnp.float32))
    return (valid * sign * mag).sum(axis=0)
