"""Pallas TPU kernels for the BLMAC hot spots, with jnp oracles.

  blmac_fir       — pulse-specialized bit-layer FIR (the paper's machine,
                    lane-parallelized; exact int32), LRU program cache
  blmac_fir_bank  — sparsity-scheduled bank kernel: occupancy-grouped
                    bank tiles, layer-skip superlayer schedules, one
                    integer matmul per populated superlayer (the
                    1.98M-filter sweep path); B=1 fast-paths to the
                    specialized program
  autotune_bank_dispatch — cost-model dispatch planner for the above
  blmac_matmul    — CSD-P pulse-code quantized matmul (serving-side weight
                    decompression; attacks the decode memory roofline)
"""
from .ops import (
    blmac_fir,
    blmac_fir_bank,
    default_interpret,
    pulse_dequantize,
    pulse_matmul_op,
    pulse_quantize,
)
from .blmac_fir import (BankSchedule, pack_bank_trits, plan_bank_schedule,
                        superlayer_schedule)
from .runtime import autotune_bank_dispatch
from . import ref

__all__ = [
    "blmac_fir",
    "blmac_fir_bank",
    "BankSchedule",
    "pack_bank_trits",
    "plan_bank_schedule",
    "superlayer_schedule",
    "autotune_bank_dispatch",
    "default_interpret",
    "pulse_dequantize",
    "pulse_matmul_op",
    "pulse_quantize",
    "ref",
]
