"""Pallas TPU kernels for the BLMAC hot spots, with jnp oracles.

  blmac_fir     — pulse-specialized bit-layer FIR (the paper's machine,
                  lane-parallelized; exact int32)
  blmac_matmul  — CSD-P pulse-code quantized matmul (serving-side weight
                  decompression; attacks the decode memory roofline)
"""
from .ops import (
    blmac_fir,
    default_interpret,
    pulse_dequantize,
    pulse_matmul_op,
    pulse_quantize,
)
from . import ref

__all__ = [
    "blmac_fir",
    "default_interpret",
    "pulse_dequantize",
    "pulse_matmul_op",
    "pulse_quantize",
    "ref",
]
