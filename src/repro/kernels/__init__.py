"""Pallas TPU kernels for the BLMAC hot spots, with jnp oracles.

  blmac_fir       — pulse-specialized bit-layer FIR (the paper's machine,
                    lane-parallelized; exact int32), LRU program cache
  blmac_fir_bank  — ONE pallas_call applying a B-filter bank to a
                    C-channel signal: packed-trit operands, one integer
                    matmul per bit layer (the 1.98M-filter sweep path)
  blmac_matmul    — CSD-P pulse-code quantized matmul (serving-side weight
                    decompression; attacks the decode memory roofline)
"""
from .ops import (
    blmac_fir,
    blmac_fir_bank,
    default_interpret,
    pulse_dequantize,
    pulse_matmul_op,
    pulse_quantize,
)
from .blmac_fir import pack_bank_trits
from . import ref

__all__ = [
    "blmac_fir",
    "blmac_fir_bank",
    "pack_bank_trits",
    "default_interpret",
    "pulse_dequantize",
    "pulse_matmul_op",
    "pulse_quantize",
    "ref",
]
