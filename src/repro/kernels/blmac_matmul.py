"""Pallas TPU kernel: pulse-code (CSD-P) quantized matmul.

The serving-side adaptation of BLMAC (DESIGN.md §2.2): each weight is
stored as its P most-significant CSD pulses — `w ≈ Σ_p s_p·2^(e_g−14+r_p)`
with a per-group (32 along K) exponent `e_g`.  The kernel streams the
packed pulse codes from HBM, reconstructs the bf16/f32 weight tile in
VMEM with shifts and selects (no multiplier needed for the reconstruction)
and runs one MXU matmul per tile.  HBM weight traffic is `P` bytes/weight
as implemented (byte-aligned codes; 6P bits achievable with bit packing —
both numbers are carried in the roofline analysis) versus 2 bytes for
bf16 — the lever used on the memory-bound decode cells in §Perf.

Quantization quality versus plain round-to-nearest int is benchmarked in
`benchmarks/pulse_quant.py`; P=1 is exact power-of-two weights (the
paper's shift-only limit), P≥4 is ≈ lossless for FIR banks (avg 3.0–3.8
pulses per coefficient, Figs. 3–4).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from ..core.csd import csd_digits, csd_truncate
from .runtime import resolve_interpret

GROUP = 32
NULL_POS = 15


# ---------------------------------------------------------------------------
# host-side quantizer
# ---------------------------------------------------------------------------

def pulse_quantize(
    w: np.ndarray, planes: int, group: int = GROUP
) -> tuple[np.ndarray, np.ndarray]:
    """Quantize float (K, N) weights to P pulse codes + group exponents.

    Returns ``codes`` uint8 (P, K, N) [bit7 valid, bit6 sign, bits3..0
    pos] and ``group_exp`` int8 (K // group, N).
    """
    w = np.asarray(w, np.float64)
    k_dim, n_dim = w.shape
    if k_dim % group:
        raise ValueError(f"K={k_dim} not a multiple of group={group}")
    gmax = np.abs(w).reshape(k_dim // group, group, n_dim).max(axis=1)
    safe = np.where(gmax == 0.0, 1.0, gmax)
    e = np.ceil(np.log2(safe)).astype(np.int64)  # maxabs ≤ 2**e
    e = np.where(gmax == 0.0, -128, e)
    scale = np.exp2((e - 14).astype(np.float64))  # q ≤ 2**14
    q = np.rint(w / np.repeat(scale, group, axis=0)).astype(np.int64)
    q = np.where(np.repeat(gmax, group, axis=0) == 0.0, 0, q)
    q = csd_truncate(q, planes, n_digits=16)
    digits = csd_digits(q, n_digits=16)  # (K, N, 16)
    codes = np.zeros((planes, k_dim, n_dim), np.uint8)
    # assign pulses MSB-first into the P slots
    slot = np.zeros((k_dim, n_dim), np.int64)
    for pos in range(15, -1, -1):
        d = digits[:, :, pos]
        sel = d != 0
        if not sel.any():
            continue
        p_idx = slot[sel]
        assert (p_idx < planes).all(), "csd_truncate must bound pulse count"
        codes[(p_idx,) + np.nonzero(sel)] = (
            0x80 | (np.where(d[sel] < 0, 0x40, 0)) | pos
        ).astype(np.uint8)
        slot[sel] += 1
    # unused slots: valid=0, pos=NULL
    empty = codes == 0
    codes[empty] = NULL_POS
    return codes, np.clip(e, -127, 127).astype(np.int8)


def pulse_dequantize(codes: np.ndarray, group_exp: np.ndarray,
                     group: int = GROUP) -> np.ndarray:
    """Numpy decode (host oracle)."""
    planes, k_dim, n_dim = codes.shape
    valid = (codes >> 7) & 1
    sign = np.where((codes >> 6) & 1 == 1, -1.0, 1.0)
    pos = (codes & 0x0F).astype(np.int64)
    e = np.repeat(group_exp.astype(np.int64), group, axis=0)
    mag = np.exp2((e[None] - 14 + pos).astype(np.float64))
    return (valid * sign * mag).sum(axis=0)


# ---------------------------------------------------------------------------
# kernel
# ---------------------------------------------------------------------------

def _pulse_matmul_kernel(x_ref, codes_ref, exp_ref, out_ref, *,
                         planes: int, group: int, bk: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    codes = codes_ref[...]  # (P, BK, BN) uint8
    e = exp_ref[...].astype(jnp.int32)  # (BK//group, BN)
    e = jnp.repeat(e, group, axis=0)  # (BK, BN)
    w = jnp.zeros(codes.shape[1:], jnp.float32)
    for p in range(planes):  # VMEM reconstruction: select + exp2, no mults
        c = codes[p].astype(jnp.int32)
        valid = (c >> 7) & 1
        sgn = jnp.where((c >> 6) & 1 == 1, -1.0, 1.0)
        pos = c & 0x0F
        mag = jnp.exp2((e - 14 + pos).astype(jnp.float32))
        w = w + jnp.where(valid == 1, sgn * mag, 0.0)
    x = x_ref[...].astype(jnp.float32)
    out_ref[...] += jnp.dot(x, w, preferred_element_type=jnp.float32)


@functools.partial(
    jax.jit,
    static_argnames=("planes", "group", "bm", "bk", "bn", "interpret"),
)
def pulse_matmul(
    x: jnp.ndarray,  # (M, K)
    codes: jnp.ndarray,  # (P, K, N) uint8
    group_exp: jnp.ndarray,  # (K//group, N) int8
    planes: int,
    group: int = GROUP,
    bm: int = 128,
    bk: int = 512,
    bn: int = 256,
    interpret: bool | None = None,
) -> jnp.ndarray:
    interpret = resolve_interpret(interpret)  # static arg: trace-time resolve
    m, k_dim = x.shape
    _, _, n_dim = codes.shape
    bm = min(bm, m)
    bk = min(bk, k_dim)
    bn = min(bn, n_dim)
    if m % bm or k_dim % bk or n_dim % bn or bk % group:
        raise ValueError(f"blocks must tile the operands: {(m, k_dim, n_dim)}"
                         f" vs {(bm, bk, bn)}, group={group}")
    kern = functools.partial(
        _pulse_matmul_kernel, planes=planes, group=group, bk=bk
    )
    return pl.pallas_call(
        kern,
        grid=(m // bm, n_dim // bn, k_dim // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((planes, bk, bn), lambda i, j, k: (0, k, j)),
            pl.BlockSpec((bk // group, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n_dim), jnp.float32),
        interpret=interpret,
    )(x, codes, group_exp)
