"""Write-ahead session journal: crash durability for the session server.

`repro.serving.BankSessionServer` keeps every tenant's stream state
(overlap-save tail, counters, queued chunks) in host memory; a process
crash would lose all of it.  `SessionJournal` is the write-ahead log
that makes the server rebuildable: every state transition is appended
as a CRC-framed record BEFORE the caller observes its effect, so
`BankSessionServer.recover(path)` can replay the log and resume every
session bit-exactly after a `SIGKILL`.

Format
------
A journal is a directory of segment files ``wal.NNNNNN.log``.  Each
segment is a sequence of records::

    <u32 payload_len> <u32 crc32(payload)> <payload: compact JSON>

and BEGINS with a ``journal`` header record carrying the format version
and the **program content digest** — recovering under a different
program is a loud error, never a silently wrong stream.  Numpy arrays
ride in payloads as ``{dtype, shape, b64}``.  Record types:

  * ``journal`` — segment header: format version, program key, geometry.
  * ``open`` / ``close`` / ``select`` — session registry: a session
    exists, died, or was retargeted (`swap_filters`) to new rows.
  * ``chunk``  — one pushed input chunk, with its per-session sequence
    number.  Appended at ``push`` time, before the samples can reach a
    kernel.
  * ``snap``   — a per-session `TailSnapshot`-equivalent (tail +
    counters), written only at **quiescent** points (nothing queued,
    everything computed has been delivered) at a configurable cadence;
    chunks at or below its ``seq`` become dead weight and are dropped
    from the next rotation.
  * ``pull``   — the session's cumulative delivered-sample watermark,
    appended before `pull` returns data; recovery trims regenerated
    output below the watermark so a client sees no duplicates and no
    gaps.

Durability: segment files are opened **unbuffered**, so every appended
record reaches the OS page cache in the `write` — that alone makes the
log complete under `SIGKILL` (the crash the serving story cares about).
`sync()` adds an `fsync` for power-loss durability; the server group-
commits one at the end of every `step()` and forces one on registry
changes and snapshots.

Rotation is atomic: when the live segment outgrows ``segment_bytes``
the server condenses the full live state into checkpoint records and
`rotate()` writes header + checkpoint into a NEW segment via
`repro.core.io.atomic_write` (tmp + fsync + rename), then deletes the
older segments — a crash at any point leaves either the old segments
or a complete new one.

Recovery reads the NEWEST segment (older ones are superseded
checkpoints awaiting deletion).  A torn tail record — the process died
mid-append — truncates the log at the last valid record; a record that
fails its CRC is rejected the same way (nothing after a bad frame can
be trusted, because framing is sequential).  A segment whose header is
unreadable raises `JournalFormatError`.
"""
from __future__ import annotations

import base64
import json
import os
import re
import struct
import zlib

import numpy as np

from ..core.io import atomic_write, check_format_header, fsync_dir

__all__ = ["JOURNAL_FORMAT_VERSION", "JournalFormatError", "SessionJournal"]

JOURNAL_FORMAT_VERSION = 1
_KIND = "blmac_session_journal"
_SEG_RE = re.compile(r"^wal\.(\d{6})\.log$")
_FRAME = struct.Struct("<II")
#: framing sanity bound — a "length" beyond this is corruption, not data
_MAX_RECORD = 1 << 26


class JournalFormatError(ValueError):
    """The journal directory is unusable: no segments, an unreadable
    segment header, a format version this build cannot read, or a
    program-digest mismatch.  (A torn TAIL record is NOT this error —
    that is expected crash damage and is truncated away.)"""


def encode_array(a: np.ndarray) -> dict:
    """Numpy array → JSON-able payload fragment (dtype, shape, base64)."""
    a = np.ascontiguousarray(a)
    return {
        "dtype": str(a.dtype),
        "shape": list(a.shape),
        "b64": base64.b64encode(a.tobytes()).decode("ascii"),
    }


def decode_array(d: dict) -> np.ndarray:
    return (
        np.frombuffer(base64.b64decode(d["b64"]), dtype=d["dtype"])
        .reshape(d["shape"])
        .copy()
    )


def _frame(rec: dict) -> bytes:
    payload = json.dumps(rec, separators=(",", ":")).encode("utf-8")
    return _FRAME.pack(len(payload), zlib.crc32(payload)) + payload


def _segments(path) -> list:
    """Sorted (index, filename) of every committed segment in ``path``."""
    out = []
    for name in os.listdir(path):
        m = _SEG_RE.match(name)
        if m:
            out.append((int(m.group(1)), name))
    return sorted(out)


def _read_records(seg_path):
    """Parse one segment → (records, valid_bytes).  Stops at the first
    bad frame (short header, implausible length, CRC mismatch, broken
    JSON): everything before it is valid, everything from it on is a
    torn tail.  ``valid_bytes`` is the offset a repair should truncate
    the file to."""
    records = []
    with open(seg_path, "rb") as f:
        data = f.read()
    off = 0
    while off + _FRAME.size <= len(data):
        length, crc = _FRAME.unpack_from(data, off)
        end = off + _FRAME.size + length
        if length > _MAX_RECORD or end > len(data):
            break
        payload = data[off + _FRAME.size: end]
        if zlib.crc32(payload) != crc:
            break
        try:
            records.append(json.loads(payload.decode("utf-8")))
        except (UnicodeDecodeError, json.JSONDecodeError):
            break
        off = end
    return records, off


class SessionJournal:
    """One server's write-ahead log, rooted at a directory.

    Construction only prepares the root; the server calls
    `start_segment(records)` (also the rotation primitive) to commit a
    checkpoint and open the live segment for appends.  ``fsync=False``
    keeps `SIGKILL` durability (unbuffered writes) but skips the
    power-loss fsyncs — the benchmark's knob.
    """

    def __init__(
        self,
        path,
        *,
        program_key: str,
        taps: int,
        n_filters: int,
        segment_bytes: int = 4 << 20,
        fsync: bool = True,
    ):
        self.path = os.fspath(path)
        os.makedirs(self.path, exist_ok=True)
        self.program_key = str(program_key)
        self.taps = int(taps)
        self.n_filters = int(n_filters)
        self.segment_bytes = int(segment_bytes)
        self.fsync = bool(fsync)
        segs = _segments(self.path)
        self._seg_index = segs[-1][0] if segs else -1
        self._f = None
        self._size = 0
        self._dirty = False
        # observability counters (surface through server fault_stats)
        self.appends = 0
        self.syncs = 0
        self.rotations = 0

    # -- segment lifecycle ---------------------------------------------------

    def _header(self, index: int) -> dict:
        return {
            "t": "journal",
            "kind": _KIND,
            "format_version": JOURNAL_FORMAT_VERSION,
            "segment": index,
            "program_key": self.program_key,
            "taps": self.taps,
            "n_filters": self.n_filters,
        }

    @property
    def segment_path(self) -> str:
        return os.path.join(self.path, f"wal.{self._seg_index:06d}.log")

    def start_segment(self, records=()) -> None:
        """Atomically commit a NEW segment holding the header plus the
        ``records`` checkpoint, point appends at it, and delete every
        older segment.  Called once at attach time and again on every
        rotation; a crash anywhere leaves a recoverable directory."""
        index = self._seg_index + 1
        blob = _frame(self._header(index))
        for rec in records:
            blob += _frame(rec)
        name = f"wal.{index:06d}.log"
        final = os.path.join(self.path, name)
        atomic_write(final, lambda f: f.write(blob), fsync=self.fsync)
        if self._f is not None:
            self._f.close()
        # buffering=0: every append is a syscall straight into the OS
        # page cache — SIGKILL cannot lose an acknowledged record
        self._f = open(final, "ab", buffering=0)
        old, self._seg_index = self._seg_index, index
        self._size = len(blob)
        self._dirty = False
        if old >= 0:
            self.rotations += 1
        for i, seg_name in _segments(self.path):
            if i < index:
                try:
                    os.unlink(os.path.join(self.path, seg_name))
                except OSError:
                    pass

    # -- appends -------------------------------------------------------------

    def append(self, rec: dict, sync: bool = False) -> None:
        """Frame + CRC + write one record to the live segment.  The
        unbuffered write makes it `SIGKILL`-durable on return; pass
        ``sync=True`` (registry changes, snapshots) to fsync too."""
        if self._f is None:
            raise RuntimeError(
                "journal has no live segment — call start_segment() first"
            )
        blob = _frame(rec)
        self._f.write(blob)
        self._size += len(blob)
        self._dirty = True
        self.appends += 1
        if sync:
            self.sync()

    def sync(self) -> None:
        """Group-commit fsync of everything appended since the last sync
        (no-op when clean or when the journal was opened fsync=False)."""
        if self._f is None or not self._dirty:
            return
        if self.fsync:
            os.fsync(self._f.fileno())
        self._dirty = False
        self.syncs += 1

    @property
    def needs_rotation(self) -> bool:
        return self._size > self.segment_bytes

    def close(self) -> None:
        if self._f is not None:
            self.sync()
            self._f.close()
            self._f = None

    def stats(self) -> dict:
        return {
            "path": self.path,
            "segment": self._seg_index,
            "segment_bytes": self._size,
            "appends": self.appends,
            "syncs": self.syncs,
            "rotations": self.rotations,
            "fsync": self.fsync,
        }

    # -- replay --------------------------------------------------------------

    @staticmethod
    def replay(path, repair: bool = True):
        """Read a journal directory → ``(header, records)``.

        Only the NEWEST segment is replayed — every segment starts with
        a complete checkpoint of the state at its birth, so older
        segments are superseded (rotation deletes them; a crash between
        rename and unlink leaves them behind harmlessly).  A torn tail
        truncates the log at the last valid record (physically, when
        ``repair`` and the file is writable).  An unusable directory or
        header raises `JournalFormatError`."""
        path = os.fspath(path)
        if not os.path.isdir(path):
            raise JournalFormatError(f"{path}: not a journal directory")
        segs = _segments(path)
        if not segs:
            raise JournalFormatError(f"{path}: no journal segments")
        index, name = segs[-1]
        seg_path = os.path.join(path, name)
        records, valid = _read_records(seg_path)
        if not records:
            raise JournalFormatError(
                f"{seg_path}: no readable header record"
            )
        header = records[0]
        check_format_header(
            header, kind=_KIND, version=JOURNAL_FORMAT_VERSION,
            path=seg_path, error_cls=JournalFormatError,
            label="session journal",
        )
        if repair and valid < os.path.getsize(seg_path):
            try:
                with open(seg_path, "r+b") as f:
                    f.truncate(valid)
                fsync_dir(path)
            except OSError:
                pass  # read-only media: logical truncation is enough
        return header, records[1:]
