from .engine import (
    AsyncBankServer,
    ServeEngine,
    abstract_caches,
    cache_pspecs,
    make_decode_fn,
    make_prefill_fn,
)

__all__ = [
    "AsyncBankServer",
    "ServeEngine",
    "abstract_caches",
    "cache_pspecs",
    "make_decode_fn",
    "make_prefill_fn",
]
