from .engine import (
    AsyncBankServer,
    ServeEngine,
    abstract_caches,
    cache_pspecs,
    make_decode_fn,
    make_prefill_fn,
)
from .journal import JournalFormatError, SessionJournal
from .sessions import AdmissionRejected, BankSession, BankSessionServer

__all__ = [
    "AdmissionRejected",
    "AsyncBankServer",
    "BankSession",
    "BankSessionServer",
    "JournalFormatError",
    "SessionJournal",
    "ServeEngine",
    "abstract_caches",
    "cache_pspecs",
    "make_decode_fn",
    "make_prefill_fn",
]
