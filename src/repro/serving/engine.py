"""Serving: prefill + decode steps, a batched greedy LM engine, and the
async double-buffered request path for sharded BLMAC filter banks
(`AsyncBankServer`).

Caches are the per-stage stacked trees produced by the scanned prefill;
decode scans over (stage params, stage cache) in lock-step.  Variable
prompt lengths are supported for attention archs by voiding the cache
positions past each prompt (pos = −1 ⇒ masked); recurrent archs (ssd /
rglru) require equal-length prompts — their state cannot be position-
masked after the fact (documented limitation; continuous batching is the
production fix).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..nn.common import ShardCtx
from ..nn.model import decode_step as _decode_step
from ..nn.model import forward


def make_prefill_fn(cfg, cache_len: int, mesh=None, rules=None):
    recurrent = any(k in ("ssd", "rglru") for k in cfg.block_pattern)

    def prefill(params, batch):
        leaf = batch.get("tokens", batch.get("embeds"))
        b, s = leaf.shape[0], leaf.shape[1]
        lengths = batch.get("lengths")
        pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
        if lengths is not None and not recurrent:
            pos = jnp.where(pos < lengths[:, None], pos, -1)
            next_pos = lengths.astype(jnp.int32)
        else:
            next_pos = jnp.full((b,), s, jnp.int32)
        ctx = ShardCtx(
            rules=rules, mesh=mesh, positions=pos,
            compute_dtype=jnp.dtype(cfg.compute_dtype),
            make_cache=True, cache_len=cache_len,
        )
        logits, _, caches = forward(params, batch, cfg, ctx)
        return logits, {"caches": caches, "pos": next_pos}

    return prefill


def make_decode_fn(cfg, mesh=None, rules=None):
    def decode(params, batch, state):
        pos = state["pos"]  # (B,)
        ctx = ShardCtx(
            rules=rules, mesh=mesh, positions=pos[:, None],
            compute_dtype=jnp.dtype(cfg.compute_dtype),
        )
        logits, caches = _decode_step(params, batch, state["caches"], ctx, cfg)
        return logits, {"caches": caches, "pos": pos + 1}

    return decode


def abstract_caches(cfg, batch: int, cache_len: int):
    """ShapeDtypeStruct cache tree matching `forward(make_cache=True)` —
    the dry-run's decode state, never allocated."""
    from ..nn.attention import cache_size
    from ..nn.model import stage_plan

    dt = jnp.dtype(cfg.compute_dtype)
    sds = jax.ShapeDtypeStruct

    def slot_cache(meta, repeat):
        b = batch
        if meta.mixer == "attn":
            w = cache_size(cache_len, meta.window)
            hkv, dh = cfg.n_kv_heads, cfg.head_dim_
            # (B, Hkv, W, Dh): decode-optimized layout (§Perf A4)
            return {
                "k": sds((repeat, b, hkv, w, dh), dt),
                "v": sds((repeat, b, hkv, w, dh), dt),
                "pos": sds((repeat, b, w), jnp.int32),
            }
        if meta.mixer == "mla":
            return {
                "c_kv": sds((repeat, b, cache_len, cfg.kv_lora_rank), dt),
                "k_rope": sds((repeat, b, cache_len, cfg.qk_rope_dim), dt),
                "pos": sds((repeat, b, cache_len), jnp.int32),
            }
        if meta.mixer == "ssd":
            ch = cfg.ssm_heads * cfg.ssm_head_dim + 2 * cfg.ssm_state
            return {
                "state": sds((repeat, b, cfg.ssm_heads, cfg.ssm_state,
                              cfg.ssm_head_dim), dt),
                "conv_tail": sds((repeat, b, cfg.conv_width - 1, ch), dt),
            }
        # rglru
        return {
            "h": sds((repeat, b, cfg.rglru_width), jnp.float32),
            "conv_tail": sds((repeat, b, cfg.conv_width - 1,
                              cfg.rglru_width), dt),
        }

    return [
        tuple(slot_cache(m, st.repeat) for m in st.metas)
        for st in stage_plan(cfg)
    ]


def cache_pspecs(cfg, rules):
    """PartitionSpecs mirroring `abstract_caches`."""
    from jax.sharding import PartitionSpec as P

    from ..nn.model import stage_plan

    b = rules.get("batch")
    cs = rules.get("cache_seq")
    # a mesh axis may appear once per spec: when the cache sequence is
    # sharded over `model` (SP decode), the kv-head dim must stay replicated
    cs_axes = set(cs) if isinstance(cs, tuple) else {cs}
    kvh = rules.get("kv_heads")
    if kvh in cs_axes:
        kvh = None

    def slot_spec(meta):
        if meta.mixer == "attn":
            return {
                "k": P(None, b, kvh, cs, None),
                "v": P(None, b, kvh, cs, None),
                "pos": P(None, b, cs),
            }
        if meta.mixer == "mla":
            return {
                "c_kv": P(None, b, cs, None),
                "k_rope": P(None, b, cs, None),
                "pos": P(None, b, cs),
            }
        if meta.mixer == "ssd":
            return {
                "state": P(None, b, rules.get("heads"), None, None),
                "conv_tail": P(None, b, None, rules.get("heads_flat")),
            }
        return {
            "h": P(None, b, rules.get("ff")),
            "conv_tail": P(None, b, None, rules.get("ff")),
        }

    return [
        tuple(slot_spec(m) for m in st.metas) for st in stage_plan(cfg)
    ]


class AsyncBankServer:
    """Double-buffered request path over a sharded BLMAC filter bank.

    Wraps `repro.filters.ShardedFilterBankEngine` (or anything with its
    ``push_async → PendingChunk`` contract) behind a bounded in-flight
    queue: ``submit(chunk)`` dispatches the chunk's kernels onto the
    mesh and returns immediately, so the host frames and enqueues chunk
    ``k+1`` while the devices are still filtering chunk ``k`` — the
    classic serve-side latency hide.  ``depth`` bounds the outstanding
    chunks (2 = double buffering); when the queue is full, ``submit``
    resolves the OLDEST chunk first and returns its outputs, giving a
    strict-ordered stream with no unbounded device-memory growth.

    Failure semantics (see `repro.distributed.faultbank`): permanent
    shard loss is the ENGINE's job — it re-partitions and replays, and
    the server never sees it unless no device survived.  What the
    server owns is the bounded-liveness contract on top:

      * `TransientShardError` from a chunk's ``result()`` is retried up
        to ``max_retries`` times with exponential backoff (the engine
        re-arms the chunk before re-raising, so each retry is a fresh
        dispatch); the budget exhausting raises `RetriesExhausted`,
      * ``deadline_s`` bounds one chunk's total resolve time across all
        its attempts; expiry raises `DeadlineExceeded`,
      * each backoff sleep is capped at ``max_backoff_s`` AND clamped to
        the remaining deadline budget, so an exponential backoff can
        never sleep past ``deadline_s`` before re-checking,
      * a failed chunk is dropped from the stream (its pending is
        invalidated so a late ``result()`` cannot resurrect stale
        outputs) and the error PROPAGATES to the caller — never a hang,
      * chunks that already RESOLVED inside the same ``submit``/``drain``
        call are never discarded by a later chunk's terminal failure:
        they are buffered and delivered (oldest first) by the next
        ``submit``/``drain`` call, so the surviving stream stays gapless
        around the dropped chunk,
      * strict output order is preserved across failures and mid-flight
        recoveries: chunks resolve oldest-first, and a recovery replay
        happens inside the oldest chunk's ``result()`` before any newer
        chunk is touched.

    ``fault_stats()`` surfaces the server's retry/failure counters next
    to the engine's detection/recovery counters.

    Typical loop::

        server = AsyncBankServer(engine)
        for chunk in stream:
            for done in server.submit(chunk):
                consume(done)          # (B, C, n_out) int32
        for done in server.drain():
            consume(done)
    """

    def __init__(self, engine, depth: int = 2, max_retries: int = 3,
                 backoff_s: float = 0.01, deadline_s: float | None = None,
                 max_backoff_s: float = 1.0):
        if depth < 1:
            raise ValueError("depth must be >= 1")
        if max_retries < 0:
            raise ValueError("max_retries must be >= 0")
        if max_backoff_s <= 0:
            raise ValueError("max_backoff_s must be > 0")
        self.engine = engine
        self.depth = int(depth)
        self.max_retries = int(max_retries)
        self.backoff_s = float(backoff_s)
        self.max_backoff_s = float(max_backoff_s)
        self.deadline_s = deadline_s
        self._inflight: list = []
        self._ready: list = []  # resolved outputs not yet delivered
        self.chunks_in = 0
        self.chunks_out = 0
        self.retries = 0
        self.retries_exhausted = 0
        self.deadline_expired = 0
        self.failed_chunks = 0

    @property
    def program(self):
        """The engine's compiled `repro.compiler.BlmacProgram` (None for
        engines that predate the compile pipeline) — `save()` it so the
        next serving process warm-starts without recompiling."""
        return getattr(self.engine, "program", None)

    def _resolve(self, pending):
        """Resolve ONE pending chunk under the retry/deadline budget.

        Transient errors sleep an exponentially growing backoff and
        retry (the engine re-armed the chunk before raising, so each
        ``result()`` attempt is a fresh dispatch).  Each sleep is capped
        at ``max_backoff_s`` and clamped to the remaining ``deadline_s``
        budget — the doubling can never overshoot the deadline, so a
        tight deadline expires on time instead of after a stray
        multi-second sleep.  On a terminal failure — budget exhausted,
        deadline elapsed, or a permanent error — the pending is
        invalidated (dropped from the stream and from the engine's
        replay set) and the error propagates."""
        import time

        from ..distributed.faultbank import (DeadlineExceeded,
                                             RetriesExhausted,
                                             TransientShardError)

        t0 = time.monotonic()
        delay = min(self.backoff_s, self.max_backoff_s)
        failures = 0
        while True:
            try:
                return pending.result()
            except TransientShardError as e:
                failures += 1
                elapsed = time.monotonic() - t0
                if self.deadline_s is not None and elapsed >= self.deadline_s:
                    self.deadline_expired += 1
                    self._drop(pending)
                    raise DeadlineExceeded(
                        e.shard,
                        f"chunk missed its {self.deadline_s:.3f}s deadline "
                        f"after {failures} attempt(s) ({elapsed:.3f}s "
                        f"elapsed)",
                    ) from e
                if failures > self.max_retries:
                    self.retries_exhausted += 1
                    self._drop(pending)
                    raise RetriesExhausted(
                        e.shard,
                        f"chunk failed {failures} attempt(s) "
                        f"(max_retries={self.max_retries}): {e}",
                    ) from e
                self.retries += 1
                sleep_s = delay
                if self.deadline_s is not None:
                    # never sleep past the deadline: wake exactly at it,
                    # give the chunk one final attempt, and let the check
                    # above expire it
                    sleep_s = min(
                        sleep_s, self.deadline_s - (time.monotonic() - t0)
                    )
                if sleep_s > 0:
                    time.sleep(sleep_s)
                delay = min(delay * 2, self.max_backoff_s)
            except Exception:
                # permanent: unrecoverable loss, invalidated pending, …
                self._drop(pending)
                raise

    def _drop(self, pending) -> None:
        """Remove a terminally failed chunk from the stream: out of the
        server queue (so the NEXT submit/drain resolves the next-oldest
        chunk, not the dead one again) and invalidated on the engine
        side (so a late ``result()`` raises instead of resurrecting
        stale outputs, and recovery replays stop tracking it)."""
        self.failed_chunks += 1
        if pending in self._inflight:
            self._inflight.remove(pending)
        invalidate = getattr(pending, "invalidate", None)
        if callable(invalidate):
            invalidate()

    def _take_ready(self) -> list:
        """Outputs that resolved during a previous call whose drain loop
        then failed terminally — delivered (oldest first) ahead of this
        call's own resolves, so a dropped chunk never takes its already-
        resolved elders down with it."""
        done, self._ready = self._ready, []
        return done

    def submit(self, chunk) -> list:
        """Dispatch one chunk; returns the list of chunk outputs that
        RESOLVED to make room (possibly empty, never more than one under
        steady state).  Raises on a terminally failed chunk (see class
        docstring) — the failed chunk is dropped, the rest of the
        stream's order is unaffected, and any outputs that resolved
        before the failure are buffered for the next ``submit``/
        ``drain`` call (never discarded)."""
        import numpy as np

        done = self._take_ready()
        try:
            while len(self._inflight) >= self.depth:
                pending = self._inflight[0]
                out = self._resolve(pending)  # raises AFTER dropping
                self._inflight.pop(0)
                done.append(out)
                self.chunks_out += 1
        except Exception:
            self._ready = done  # deliver with the next call
            raise
        pending = self.engine.push_async(np.asarray(chunk))
        self._inflight.append(pending)
        self.chunks_in += 1
        return done

    def drain(self) -> list:
        """Resolve every in-flight chunk, oldest first.  On a terminal
        failure the outputs resolved so far are buffered and delivered
        by the next ``submit``/``drain`` call."""
        done = self._take_ready()
        try:
            while self._inflight:
                out = self._resolve(self._inflight[0])
                self._inflight.pop(0)
                done.append(out)
                self.chunks_out += 1
        except Exception:
            self._ready = done
            raise
        return done

    @property
    def inflight(self) -> int:
        return len(self._inflight)

    def fault_stats(self) -> dict:
        """Server retry/failure counters merged with the engine's
        detection/recovery counters (``engine`` key; ``None`` for
        engines without a ``fault_stats`` surface)."""
        eng_stats = getattr(self.engine, "fault_stats", None)
        return {
            "retries": self.retries,
            "retries_exhausted": self.retries_exhausted,
            "deadline_expired": self.deadline_expired,
            "failed_chunks": self.failed_chunks,
            "chunks_in": self.chunks_in,
            "chunks_out": self.chunks_out,
            "inflight": len(self._inflight),
            "buffered": len(self._ready),
            "engine": eng_stats() if callable(eng_stats) else None,
        }


class ServeEngine:
    """Minimal batched greedy engine over the prefill/decode steps."""

    def __init__(self, cfg, params, cache_len: int = 4096,
                 mesh=None, rules=None):
        self.cfg = cfg
        self.params = params
        self.cache_len = cache_len
        self._prefill = jax.jit(make_prefill_fn(cfg, cache_len, mesh, rules))
        self._decode = jax.jit(make_decode_fn(cfg, mesh, rules))

    def generate(self, prompts, max_new_tokens: int = 16):
        """prompts: (B, S) int tokens (equal length).  Greedy argmax.
        ``max_new_tokens=0`` returns an empty (B, 0) array — the prefill
        argmax is NOT an emitted token."""
        prompts = jnp.asarray(prompts, jnp.int32)
        if max_new_tokens <= 0:
            return jnp.zeros((prompts.shape[0], 0), jnp.int32)
        logits, state = self._prefill(self.params, {"tokens": prompts})
        tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        out = [tok]
        for _ in range(max_new_tokens - 1):
            logits, state = self._decode(
                self.params, {"token": tok[:, None]}, state)
            tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
            out.append(tok)
        return jnp.stack(out, axis=1)  # (B, max_new_tokens)
