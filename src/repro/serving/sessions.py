"""Multi-tenant session serving: many user streams over ONE compiled bank.

`AsyncBankServer` double-buffers a single caller; this module is the
production layer above it — a `BankSessionServer` that serves MANY
concurrent user streams over one `BlmacProgram`:

  * **Per-tenant filter selection.**  Each session opens on a subset of
    the bank's filters.  `program.select(rows)` makes the slice cheap
    (memoized array views registered content-addressed in the
    `ProgramCache`) and gives every selection a stable content key — the
    key a paused session's `TailSnapshot` is addressed to.
  * **Continuous batching into shared slots.**  The server owns one
    `FilterBankEngine` with ``n_slots`` channel lanes.  Sessions push
    independently-paced chunks into per-session queues; each `step()`
    packs every ready session's ``tail + queued`` buffer into the lanes
    of ONE batched dispatch (several rounds when more sessions are ready
    than there are lanes) and slices each tenant's rows / valid sample
    range out of the result.  Bit-exactness versus a dedicated
    per-session engine is structural: a lane is exactly the overlap-save
    buffer `FilterBankEngine.push` would have built, lanes are
    arithmetically independent, and everything is int32 — property-
    tested across arbitrary interleavings in ``tests/test_sessions.py``.
  * **Pause / resume.**  `session.pause()` flushes the session and
    freezes its stream as a `TailSnapshot` keyed to the session's
    *selection* subprogram (and stamped with the session id —
    the compiler-side ``session`` field); `resume_session()` re-admits
    it bit-exactly, in this process or after a restart.
  * **Zero-downtime hot-swap.**  `session.swap_filters(rows)` retargets
    one session (its queue is flushed under the old selection first, so
    a swap never mixes output shapes); `server.swap_program(coeffs)`
    recompiles through the content-addressed `ProgramCache`, builds and
    warms the NEW engine while the OLD program keeps serving, then
    drains and flips atomically — per-session tails carry over because
    they are raw input history, not program state.
  * **Admission control and eviction.**  `open_session` is gated by
    `core.costmodel.predict_session_step_us`: a session is admitted only
    while the predicted batching step stays inside ``step_budget_us``.
    When over budget the server first parks idle sessions (LRU) —
    parking is an internal snapshot, and a push to a parked session
    transparently re-admits it — and only then rejects with
    `AdmissionRejected`.
  * **Sessions × shards.**  The shared lanes can run on a
    `repro.filters.ShardedFilterBankEngine` of the same program (pass
    ``engine=``): `apply_lanes` dispatches through the sharded engine's
    `select()` subprograms, so a shard lost / timed out / corrupted
    mid-`step()` triggers the PR 6 machinery — re-partition over the
    survivors, bit-exact replay — **inside the call**, with per-tenant
    fault isolation: only the sessions packed into the failed dispatch
    round ride the replay (no other session's output is reordered or
    dropped), transient shard errors get a bounded in-step retry, and
    `fault_stats()` attributes faults per session.  Admission control
    reads the ENGINE'S LIVE PLAN, which every recovery rebuilds, so
    after a shard loss the server prices steps against the degraded
    mesh (and `serve_stats()['degraded']` flips once the engine has
    fallen back to the 1×1 plain lowering).
  * **Durability.**  Attach a `repro.serving.journal.SessionJournal`
    (``journal=`` path) and every state transition — session registry,
    pushed chunks, delivered-sample watermarks, cadenced quiescent-point
    snapshots — is written ahead to a CRC-framed segment log.
    `BankSessionServer.recover(path, program)` rebuilds every session
    bit-exactly after a `SIGKILL`: torn tail records are truncated,
    journaled chunks replay from the last snapshot, and regenerated
    output below each session's delivered watermark is trimmed so
    clients see no duplicates and no gaps.
  * **Observability.**  `serve_stats()` (per-session p50/p99 latency,
    batch occupancy, queue depth, admission rejections, swap/eviction
    counters, degraded flag, journal counters) lands next to the
    compiler's `cache_stats()` and the fault layer's `fault_stats()`.

The server is host-side and single-threaded by design (like
`AsyncBankServer`): callers interleave ``push`` / ``step`` / ``pull``
from one thread, and determinism of the batching schedule is part of
the bit-exactness contract.
"""
from __future__ import annotations

import itertools
import os
import time
from collections import deque

import numpy as np

__all__ = ["AdmissionRejected", "BankSession", "BankSessionServer"]

#: per-session latency samples kept for the p50/p99 estimators
LATENCY_WINDOW = 256


class AdmissionRejected(RuntimeError):
    """`open_session` (or re-admission of a parked session) would push the
    predicted batching step past the server's ``step_budget_us`` — or past
    ``max_sessions`` — and no idle session could be evicted to make room.

    Carries ``predicted_us`` (the step latency the admission would have
    cost) and ``budget_us`` so callers can implement backpressure.
    """

    def __init__(self, msg: str, predicted_us: float, budget_us: float):
        super().__init__(msg)
        self.predicted_us = float(predicted_us)
        self.budget_us = float(budget_us)


class BankSession:
    """One tenant stream: a filter selection plus overlap-save state.

    Handles are created by `BankSessionServer.open_session` /
    `resume_session`; all methods delegate to the server (which owns the
    shared engine and the batching schedule).
    """

    def __init__(self, server: "BankSessionServer", session_id: str, rows):
        self._server = server
        self.session_id = session_id
        self.rows = np.asarray(rows, np.int64)
        self.subkey = server.program.select(self.rows).key
        # overlap-save state (one lane): last ≤ taps−1 input samples
        self.tail = np.zeros((1, 0), np.int32)
        self.samples_in = 0
        self.samples_out = 0
        # independently-paced input: (chunk, enqueue_monotonic) pairs
        self.queue: list = []
        self.queued_samples = 0
        # outputs computed but not yet pulled, each (len(rows), n_i)
        self.outbox: list = []
        self.latencies = deque(maxlen=LATENCY_WINDOW)
        self.last_active = 0  # server step-sequence of last activity
        self.parked = False
        self.closed = False
        # durability / fault-attribution state
        self.seq = 0  # chunks pushed over the session lifetime
        self.delivered = 0  # samples handed to the caller (pull watermark)
        self.faults = 0  # dispatch-round faults this session rode through
        self.serves_since_snap = 0
        # rotation material: the last quiescent-point snapshot plus every
        # chunk pushed after it (pruned at each new snapshot, so memory is
        # bounded by the snapshot cadence)
        self._wal_snap: dict | None = None
        self._wal_chunks: list = []

    # -- conveniences that delegate to the server ---------------------------

    def push(self, chunk) -> None:
        self._server.push(self, chunk)

    def pull(self) -> np.ndarray:
        return self._server.pull(self)

    def pause(self):
        return self._server.pause_session(self)

    def swap_filters(self, rows) -> np.ndarray:
        return self._server.swap_filters(self, rows)

    def close(self) -> None:
        self._server.close_session(self)

    @property
    def pending(self) -> int:
        """Samples queued or tail-buffered but not yet served."""
        return self.queued_samples + self.tail.shape[1]


class BankSessionServer:
    """Serve many concurrent filter-selection streams over one program.

    Parameters
    ----------
    program : `repro.compiler.BlmacProgram` or (B, taps) int array
        The compiled bank every session selects from (arrays are
        compiled via the content-addressed `compile_bank`).
    n_slots : int
        Channel lanes of the shared engine — sessions batched per
        dispatch round.  More ready sessions than slots simply take
        ceil(ready / n_slots) rounds per step.
    step_budget_us : float | None
        Admission budget: a session is admitted only while
        `predict_session_step_us(dispatch_us, active + 1, n_slots)`
        stays ≤ this.  None disables cost-model admission control.
    max_sessions : int | None
        Hard cap on concurrently *active* (non-parked) sessions.
    auto_step : bool
        When True (default) every `push` runs a batching step, so a
        single-caller loop behaves like `FilterBankEngine.push`.  Set
        False to drive `step()` yourself and batch many sessions' pushes
        into shared rounds (what the benchmark and a real event loop do).
    engine : engine instance | None
        A prebuilt lane engine to serve on instead of the default
        single-device `FilterBankEngine` — in practice a
        `repro.filters.ShardedFilterBankEngine` of the SAME program with
        ``channels == n_slots`` (sessions × shards).  Faults inside its
        `apply_lanes` recover per the engine's own machinery; the server
        adds bounded transient retry, per-session fault attribution and
        post-recovery load shedding.  `swap_program` is a loud error
        with an injected engine (the server cannot rebuild a mesh it
        does not own).
    journal : str | os.PathLike | SessionJournal | None
        Write-ahead journal directory (see `repro.serving.journal`).
        The directory must not already hold a journal — recover an
        existing one with `BankSessionServer.recover`.
    journal_fsync : bool
        False keeps SIGKILL durability (unbuffered appends) but skips
        the power-loss fsyncs.
    snapshot_every : int
        Quiescent-point snapshot cadence: a session's tail+counters are
        re-journaled after this many served rounds (shorter replays,
        more snapshot bytes).
    segment_bytes : int
        Journal segment size that triggers an atomic checkpoint
        rotation.
    max_step_retries : int
        Transient shard errors absorbed per dispatch round before the
        error propagates to the `step()` caller.
    mode, tile, interpret, chunk_hint
        Forwarded to the shared `FilterBankEngine` (ignored when
        ``engine`` is injected).
    """

    def __init__(
        self,
        program,
        n_slots: int = 8,
        step_budget_us: float | None = None,
        max_sessions: int | None = None,
        auto_step: bool = True,
        mode: str = "auto",
        tile: int | None = None,
        interpret: bool | None = None,
        chunk_hint: int = 2048,
        engine=None,
        journal=None,
        journal_fsync: bool = True,
        snapshot_every: int = 8,
        segment_bytes: int = 4 << 20,
        max_step_retries: int = 2,
    ):
        from ..compiler import BlmacProgram, compile_bank
        from ..filters import FilterBankEngine

        if n_slots < 1:
            raise ValueError("n_slots must be >= 1")
        if not isinstance(program, BlmacProgram):
            program = compile_bank(np.atleast_2d(np.asarray(program)))
        self.program = program
        self.n_slots = int(n_slots)
        self.step_budget_us = step_budget_us
        self.max_sessions = max_sessions
        self.auto_step = bool(auto_step)
        self._engine_kw = dict(
            mode=mode, tile=tile, interpret=interpret, chunk_hint=chunk_hint
        )
        if engine is not None:
            eng_prog = getattr(engine, "program", None)
            if eng_prog is None or eng_prog.key != program.key:
                raise ValueError(
                    "injected engine runs a different program than the "
                    "server (content keys differ) — sessions would select "
                    "rows of the wrong bank"
                )
            if int(engine.channels) != self.n_slots:
                raise ValueError(
                    f"injected engine has {engine.channels} channel lanes, "
                    f"server needs n_slots={self.n_slots}"
                )
            self.engine = engine
            self._engine_injected = True
        else:
            self.engine = FilterBankEngine(
                program, channels=self.n_slots, **self._engine_kw
            )
            self._engine_injected = False
        self.sessions: dict = {}  # session_id -> BankSession (incl. parked)
        self._ids = itertools.count()
        self._seq = 0  # monotone activity clock for LRU decisions
        self.snapshot_every = int(snapshot_every)
        self.max_step_retries = int(max_step_retries)
        # counters for serve_stats()
        self.steps = 0
        self.rounds = 0
        self.chunks_in = 0
        self.chunks_out = 0
        self.samples_in = 0
        self.samples_out = 0
        self.admission_rejections = 0
        self.evictions = 0
        self.filter_swaps = 0
        self.program_swaps = 0
        self.step_retries = 0  # transient faults absorbed inside step()
        self.session_faults = 0  # dispatch-round faults attributed to tenants
        self._lane_fill = 0  # lanes carrying a session, across all rounds
        self.journal = None
        if journal is not None:
            from .journal import SessionJournal

            if not isinstance(journal, SessionJournal):
                journal = SessionJournal(
                    os.fspath(journal),
                    program_key=program.key,
                    taps=program.taps,
                    n_filters=program.n_filters,
                    segment_bytes=segment_bytes,
                    fsync=journal_fsync,
                )
            if journal._seg_index >= 0:
                raise ValueError(
                    f"{journal.path} already holds a journal — a fresh "
                    f"server would supersede it; rebuild the crashed one "
                    f"with BankSessionServer.recover() instead"
                )
            self.journal = journal
            self._journal_rotate()  # commit the (empty) birth checkpoint

    # -- admission / eviction -----------------------------------------------

    def _dispatch_us(self) -> float:
        """Per-round dispatch latency estimate feeding admission control.
        Reads the engine's LIVE plan first — on a sharded engine that is
        `ShardedBankPlan`, rebuilt by every fault recovery, so admission
        automatically re-prices against a degraded mesh (the 1×1
        fallback's plan may carry a NaN prediction, which falls through
        to the coarse fixed-overhead floor)."""
        from ..core.costmodel import PALLAS_CALL_US, STEP_US

        plan = getattr(self.engine, "plan", None)  # sharded: live mesh plan
        if plan is None:
            plan = getattr(self.engine, "dispatch_plan", None)
        if plan is not None:
            us = float(plan.predicted_us)
            if np.isfinite(us):
                return us
        return PALLAS_CALL_US + STEP_US

    def _degraded(self) -> bool:
        """True once the (sharded) engine has fallen back to the 1×1
        plain lowering — the last rung of graceful degradation."""
        fault = getattr(self.engine, "fault", None)
        return bool(
            fault is not None
            and getattr(fault, "degraded_since", None) is not None
        )

    def _active(self) -> int:
        return sum(
            1 for s in self.sessions.values() if not s.parked and not s.closed
        )

    def _journal_us(self, n_active: int) -> float:
        """Flat per-step WAL bill for the cost model: one chunk append
        per active session plus the group-commit fsync."""
        if self.journal is None:
            return 0.0
        from ..core.costmodel import JOURNAL_APPEND_US, JOURNAL_SYNC_US

        return JOURNAL_APPEND_US * n_active + (
            JOURNAL_SYNC_US if self.journal.fsync else 0.0
        )

    def predicted_step_us(self, extra_sessions: int = 0) -> float:
        """Modelled latency of one batching step with the current active
        population plus ``extra_sessions`` hypothetical admissions,
        priced against the engine's CURRENT (possibly degraded) plan and
        the journal's per-step overhead."""
        from ..core.costmodel import predict_session_step_us

        n = self._active() + extra_sessions
        return predict_session_step_us(
            self._dispatch_us(), n, self.n_slots,
            journal_us=self._journal_us(n),
        )

    def _park_idle_lru(self) -> bool:
        """Park the least-recently-active idle session to make room.
        Parking is internal state only (the lane model has no per-session
        device residency), so a parked session's handle stays valid and
        its next `push` re-admits it transparently."""
        idle = [
            s for s in self.sessions.values()
            if not s.parked and not s.closed and s.queued_samples == 0
        ]
        if not idle:
            return False
        victim = min(idle, key=lambda s: s.last_active)
        victim.parked = True
        self.evictions += 1
        return True

    def _shed_to_budget(self) -> int:
        """Post-recovery load shedding: after the engine re-plans onto a
        smaller (or degraded) mesh, the SAME active population may no
        longer fit the step budget — park idle LRU sessions until the
        predicted step fits again (or nothing idle remains).  Returns
        the number of sessions parked."""
        shed = 0
        if self.step_budget_us is None:
            return shed
        while (
            self.predicted_step_us() > self.step_budget_us
            and self._park_idle_lru()
        ):
            shed += 1
        return shed

    def _admit(self, what: str) -> None:
        """Gate one admission (open / resume / un-park) on the cost model,
        parking idle LRU sessions until the predicted step fits."""
        while True:
            over_cap = (
                self.max_sessions is not None
                and self._active() + 1 > self.max_sessions
            )
            predicted = self.predicted_step_us(extra_sessions=1)
            over_budget = (
                self.step_budget_us is not None
                and predicted > self.step_budget_us
            )
            if not over_cap and not over_budget:
                return
            if self._park_idle_lru():
                continue
            self.admission_rejections += 1
            budget = (
                float(self.step_budget_us)
                if self.step_budget_us is not None
                else float("inf")
            )
            raise AdmissionRejected(
                f"{what}: predicted step {predicted:.0f}us exceeds budget "
                f"{budget:.0f}us (active={self._active()}, "
                f"slots={self.n_slots}) and no idle session to evict",
                predicted_us=predicted,
                budget_us=budget,
            )

    def _readmit(self, session: BankSession) -> None:
        self._admit(f"re-admit session {session.session_id}")
        session.parked = False

    # -- write-ahead journal plumbing ---------------------------------------

    def _journal_append(self, rec: dict, sync: bool = False) -> None:
        if self.journal is not None:
            self.journal.append(rec, sync=sync)

    @staticmethod
    def _snap_record(session: BankSession, w: dict) -> dict:
        from .journal import encode_array

        return {
            "t": "snap",
            "sid": session.session_id,
            "seq": int(w["seq"]),
            "samples_in": int(w["samples_in"]),
            "samples_out": int(w["samples_out"]),
            "delivered": int(w["delivered"]),
            "tail": encode_array(w["tail"]),
        }

    def _maybe_snapshot(self, session: BankSession, force: bool = False):
        """Record a quiescent-point snapshot — nothing queued, everything
        computed delivered — at the configured cadence.  Tracked in
        memory unconditionally (it is also rotation material) and
        journaled when a journal is attached."""
        if (
            session.queued_samples
            or session.outbox
            or session.delivered != session.samples_out
        ):
            return  # not quiescent: a snapshot here could lose samples
        if not force and session.serves_since_snap < self.snapshot_every:
            return
        w = session._wal_snap
        if w is not None and w["seq"] == session.seq \
                and w["delivered"] == session.delivered:
            return  # nothing advanced since the last snapshot
        session._wal_snap = {
            "seq": session.seq,
            "samples_in": session.samples_in,
            "samples_out": session.samples_out,
            "delivered": session.delivered,
            "tail": session.tail.copy(),
        }
        session._wal_chunks = [
            (q, c) for q, c in session._wal_chunks if q > session.seq
        ]
        session.serves_since_snap = 0
        if self.journal is not None:
            self.journal.append(
                self._snap_record(session, session._wal_snap), sync=True
            )

    def _journal_checkpoint_records(self) -> list:
        """Condense the full live state into the record list a rotation
        (or a post-recovery re-attach) seeds its fresh segment with:
        per session, the registry entry, the last quiescent snapshot,
        every chunk pushed since it, and the delivered watermark."""
        from .journal import encode_array

        recs = []
        for s in self.sessions.values():
            recs.append({
                "t": "open",
                "sid": s.session_id,
                "rows": [int(r) for r in s.rows],
            })
            w = s._wal_snap
            if w is not None:
                recs.append(self._snap_record(s, w))
            for q, c in s._wal_chunks:
                recs.append({
                    "t": "chunk", "sid": s.session_id,
                    "seq": int(q), "data": encode_array(c),
                })
            if s.delivered > (int(w["delivered"]) if w else 0):
                recs.append({
                    "t": "pull", "sid": s.session_id,
                    "delivered": int(s.delivered),
                })
        return recs

    def _journal_rotate(self) -> None:
        self.journal.start_segment(self._journal_checkpoint_records())

    # -- session lifecycle ---------------------------------------------------

    def open_session(self, rows, session_id: str | None = None) -> BankSession:
        """Open a stream serving ``rows`` of the bank (original filter
        indices).  Warms the selection subprogram through the
        `ProgramCache` and runs admission control before the session can
        occupy a lane."""
        rows = np.asarray(rows, np.int64).ravel()
        if rows.size == 0:
            raise ValueError("a session must select at least one filter")
        if rows.min() < 0 or rows.max() >= self.program.n_filters:
            raise ValueError(
                f"filter rows out of range for a {self.program.n_filters}-"
                f"filter bank: {rows}"
            )
        if session_id is None:
            session_id = f"s{next(self._ids)}"
        if session_id in self.sessions:
            raise ValueError(f"session id {session_id!r} already open")
        self._admit(f"open session {session_id}")
        s = BankSession(self, session_id, rows)
        self._seq += 1
        s.last_active = self._seq
        self.sessions[session_id] = s
        self._journal_append(
            {"t": "open", "sid": session_id, "rows": [int(r) for r in s.rows]},
            sync=True,
        )
        return s

    def close_session(self, session: BankSession) -> None:
        session.closed = True
        if self.sessions.pop(session.session_id, None) is not None:
            self._journal_append(
                {"t": "close", "sid": session.session_id}, sync=True
            )

    def pause_session(self, session: BankSession):
        """Flush the session, freeze its stream as a `TailSnapshot`
        addressed to its *selection* subprogram and stamped with the
        session id, and close it (freeing its admission slot).  The
        snapshot (plus the same ``rows``) is everything
        `resume_session` needs — here or in another process.  Outputs
        computed by the flush stay in the handle's outbox: `pull` works
        on a closed session, so nothing is lost if the caller pauses
        before draining."""
        from ..compiler.state import TailSnapshot

        self._check_open(session)
        if session.queued_samples:
            self.step()
        snap = TailSnapshot(
            program_key=session.subkey,
            channels=1,
            samples_in=session.samples_in,
            samples_out=session.samples_out,
            tail=session.tail.copy(),
            session=session.session_id,
        )
        self.close_session(session)
        return snap

    def resume_session(
        self, snapshot, rows, session_id: str | None = None
    ) -> BankSession:
        """Re-admit a paused stream bit-exactly.  The snapshot must be
        addressed to `program.select(rows)` — resuming under a different
        selection (or a different program) is a loud ValueError."""
        rows = np.asarray(rows, np.int64).ravel()
        expect = self.program.select(rows).key
        if snapshot.program_key != expect:
            raise ValueError(
                f"snapshot belongs to selection {snapshot.program_key[:12]}…,"
                f" rows {rows.tolist()} of this program are {expect[:12]}…"
            )
        if int(snapshot.channels) != 1:
            raise ValueError(
                f"session snapshots are single-lane, got "
                f"{snapshot.channels} channels"
            )
        s = self.open_session(
            rows, session_id=session_id or snapshot.session or None
        )
        s.tail = np.asarray(snapshot.tail, np.int32).copy()
        s.samples_in = int(snapshot.samples_in)
        s.samples_out = int(snapshot.samples_out)
        # a resumed stream starts quiescent: everything computed before
        # the pause was delivered (or rode away in the pause snapshot)
        s.delivered = s.samples_out
        self._maybe_snapshot(s, force=True)
        return s

    # -- hot swap ------------------------------------------------------------

    def swap_filters(self, session: BankSession, rows) -> np.ndarray:
        """Retarget one session to a new filter selection.  Queued input
        is flushed under the OLD selection first (a swap never mixes
        output shapes in the outbox); returns those final old-selection
        outputs.  The overlap-save tail carries over — it is raw input
        history, selection-independent — so the new selection's stream
        continues gaplessly."""
        self._check_open(session)
        if session.queued_samples:
            self.step()
        out = self.pull(session)
        rows = np.asarray(rows, np.int64).ravel()
        if rows.size == 0:
            raise ValueError("a session must select at least one filter")
        if rows.min() < 0 or rows.max() >= self.program.n_filters:
            raise ValueError(
                f"filter rows out of range for a {self.program.n_filters}-"
                f"filter bank: {rows}"
            )
        session.rows = rows
        session.subkey = self.program.select(rows).key  # warm via cache
        self.filter_swaps += 1
        self._journal_append(
            {
                "t": "select",
                "sid": session.session_id,
                "rows": [int(r) for r in rows],
            },
            sync=True,
        )
        # the flush above delivered everything: snapshot the swap point so
        # a crash never replays pre-swap chunks under the new selection
        self._maybe_snapshot(session, force=True)
        return out

    def swap_program(self, coeffs, spec=None) -> None:
        """Zero-downtime server-wide program swap.  The replacement is
        compiled through the content-addressed `ProgramCache`
        (recompiling identical content is a cache hit) and its engine is
        built and warmed while the OLD program keeps serving; only then
        are all sessions drained under the old program and the engine
        flipped atomically.  Tap count must match — per-session tails
        are taps−1 samples of raw input history and carry over unchanged,
        which is what makes the swap seamless mid-stream."""
        from ..compiler import BlmacProgram, compile_bank
        from ..filters import FilterBankEngine

        if self._engine_injected:
            raise ValueError(
                "swap_program is not supported on an injected engine — "
                "the server cannot rebuild a sharded mesh it does not "
                "own; build the new engine yourself and start a new "
                "server (or construct the server without engine=)"
            )
        if isinstance(coeffs, BlmacProgram):
            new_prog = coeffs
        else:
            new_prog = compile_bank(np.atleast_2d(np.asarray(coeffs)), spec)
        if new_prog.taps != self.program.taps:
            raise ValueError(
                f"cannot hot-swap a {new_prog.taps}-tap program into a "
                f"{self.program.taps}-tap stream (tails would be invalid)"
            )
        for s in self.sessions.values():
            if s.rows.max() >= new_prog.n_filters:
                raise ValueError(
                    f"session {s.session_id} selects row {int(s.rows.max())}"
                    f" but the new program has {new_prog.n_filters} filters"
                )
        # build + warm the new engine while the old one still serves
        new_engine = FilterBankEngine(
            new_prog, channels=self.n_slots, **self._engine_kw
        )
        # drain every queued chunk under the OLD program, then flip
        self.step()
        self.program = new_prog
        self.engine = new_engine
        for s in self.sessions.values():
            s.subkey = new_prog.select(s.rows).key
        self.program_swaps += 1
        if self.journal is not None:
            # the journal is content-addressed to ONE program: re-key it
            # and rotate so the fresh segment's checkpoint belongs to the
            # new digest.  Caveat (documented): outputs computed under
            # the OLD program but not yet pulled at a crash regenerate
            # under the NEW program after recovery.
            self.journal.program_key = new_prog.key
            self._journal_rotate()

    # -- streaming -----------------------------------------------------------

    def _check_open(self, session: BankSession) -> None:
        if session.closed or session.session_id not in self.sessions:
            raise ValueError(f"session {session.session_id!r} is closed")

    def push(self, session: BankSession, chunk) -> None:
        """Enqueue (n,) samples on one session's independently-paced
        stream.  Pushing to a parked session re-admits it (possibly
        parking another idle session).  With ``auto_step`` the push also
        runs a batching step, so outputs land in the outbox immediately."""
        self._check_open(session)
        if session.parked:
            self._readmit(session)
        chunk = np.asarray(chunk)
        if chunk.ndim == 2 and chunk.shape[0] == 1:
            chunk = chunk[0]
        if chunk.ndim != 1:
            raise ValueError(
                f"session chunks are 1-D sample vectors, got {chunk.shape}"
            )
        chunk = chunk.astype(np.int32, copy=False)
        self._seq += 1
        session.last_active = self._seq
        if chunk.shape[0]:
            # write-ahead: the chunk is journaled (and SIGKILL-durable)
            # before any queue or counter can observe it
            session.seq += 1
            session._wal_chunks.append((session.seq, chunk))
            if self.journal is not None:
                from .journal import encode_array

                self.journal.append({
                    "t": "chunk",
                    "sid": session.session_id,
                    "seq": session.seq,
                    "data": encode_array(chunk),
                })
            session.queue.append((chunk, time.monotonic()))
            session.queued_samples += int(chunk.shape[0])
            session.samples_in += int(chunk.shape[0])
            self.chunks_in += 1
            self.samples_in += int(chunk.shape[0])
        if self.auto_step:
            self.step()

    def pull(self, session: BankSession) -> np.ndarray:
        """Drain a session's computed outputs as one gapless
        (len(rows), n) int32 array (n may be 0).  The delivered-sample
        watermark is journaled BEFORE the data is returned, so recovery
        never re-delivers samples the caller already has."""
        if not session.outbox:
            self._maybe_snapshot(session)
            return np.zeros((session.rows.size, 0), np.int32)
        out, session.outbox = session.outbox, []
        out = np.concatenate(out, axis=1) if len(out) > 1 else out[0]
        if out.shape[1]:
            session.delivered += int(out.shape[1])
            self._journal_append({
                "t": "pull",
                "sid": session.session_id,
                "delivered": session.delivered,
            })
        self._maybe_snapshot(session)
        return out

    def _ready_sessions(self) -> list:
        """Consume priming-only queues into tails (no kernel work) and
        return the sessions that can produce ≥ 1 output, oldest queued
        chunk first (deterministic batching order)."""
        ready = []
        for s in self.sessions.values():
            if s.parked or s.closed or not s.queue:
                continue
            total = s.tail.shape[1] + s.queued_samples
            if total < self.program.taps:  # still priming: absorb, no lane
                data = np.concatenate([c for c, _ in s.queue])
                now = time.monotonic()
                for _, ts in s.queue:
                    s.latencies.append(now - ts)
                self.chunks_out += len(s.queue)
                s.queue = []
                s.queued_samples = 0
                s.tail = np.concatenate([s.tail, data[None, :]], axis=1)
                continue
            ready.append(s)
        ready.sort(key=lambda s: s.queue[0][1])
        return ready

    def _dispatch_lanes(self, buf, batch) -> np.ndarray:
        """One dispatch round through the shared engine, with the fault
        contract the sharded engine needs: transient shard errors get a
        bounded retry (the call is stateless, so a retry is a clean
        re-dispatch), any detection the engine's recovery machinery
        handled DURING the call is attributed to exactly the sessions in
        this round, and a recovery re-plan immediately re-prices the
        budget (shedding idle load if the degraded mesh no longer fits).
        Per-tenant isolation is structural: sessions outside ``batch``
        have no samples in ``buf``, so neither the fault nor the replay
        can touch their streams."""
        fault = getattr(self.engine, "fault", None)
        d0 = fault.detections if fault is not None else 0
        attempts = 0
        try:
            while True:
                try:
                    return self.engine.apply_lanes(buf)
                except Exception as e:
                    from ..distributed.faultbank import TransientShardError

                    if not isinstance(e, TransientShardError):
                        raise
                    attempts += 1
                    self.step_retries += 1
                    if attempts > self.max_step_retries:
                        raise
        finally:
            d1 = fault.detections if fault is not None else 0
            if d1 > d0:
                self.session_faults += d1 - d0
                for s in batch:
                    s.faults += 1
                self._shed_to_budget()

    def step(self) -> int:
        """Run one batching step: serve EVERY ready session, packing up
        to ``n_slots`` of them per dispatch round.  Returns the number of
        sessions served.  Idempotent when nothing is queued.

        Fault isolation: a round that raises (transient retries
        exhausted, or a terminal shard loss) leaves ITS sessions' queues
        intact — nothing is consumed until the round's outputs exist —
        while rounds already completed in this step keep their outputs.
        With a journal attached the step ends with one group-commit
        fsync covering every chunk/pull record appended since the last."""
        ready = self._ready_sessions()
        if not ready:
            return 0
        self.steps += 1
        taps = self.program.taps
        served = 0
        try:
            for r0 in range(0, len(ready), self.n_slots):
                batch = ready[r0:r0 + self.n_slots]
                lane_bufs = []
                for s in batch:
                    data = np.concatenate([c for c, _ in s.queue])
                    lane_bufs.append(
                        np.concatenate([s.tail[0], data])
                    )
                lane_len = max(b.shape[0] for b in lane_bufs)
                buf = np.zeros((self.n_slots, lane_len), np.int32)
                for lane, b in enumerate(lane_bufs):
                    buf[lane, : b.shape[0]] = b
                y = self._dispatch_lanes(buf, batch)
                # y: (B_full, n_slots, lane_len - taps + 1)
                self.rounds += 1
                self._lane_fill += len(batch)
                now = time.monotonic()
                for lane, s in enumerate(batch):
                    valid = lane_bufs[lane].shape[0]
                    n_out = valid - taps + 1
                    s.outbox.append(
                        np.ascontiguousarray(y[s.rows, lane, :n_out])
                    )
                    s.tail = lane_bufs[lane][None, valid - (taps - 1):] \
                        if taps > 1 else np.zeros((1, 0), np.int32)
                    s.samples_out += n_out
                    self.samples_out += n_out
                    for _, ts in s.queue:
                        s.latencies.append(now - ts)
                    self.chunks_out += len(s.queue)
                    s.queue = []
                    s.queued_samples = 0
                    s.serves_since_snap += 1
                    self._seq += 1
                    s.last_active = self._seq
                    served += 1
        finally:
            if self.journal is not None:
                self.journal.sync()  # group commit
                if self.journal.needs_rotation:
                    self._journal_rotate()
        return served

    def flush(self) -> int:
        """Serve everything currently queued (alias for one `step`)."""
        return self.step()

    # -- observability -------------------------------------------------------

    def serve_stats(self) -> dict:
        """Serving-layer observability, one JSON-able dict — the session
        analogue of the compiler's `cache_stats()` and the fault layer's
        `fault_stats()`."""

        def _pct(samples, q):
            # None, not an IndexError, for a fresh server / all-parked
            # population with no latency samples yet
            if samples is None or len(samples) == 0:
                return None
            return float(np.percentile(np.asarray(samples), q)) * 1e3

        all_lat = []
        per_session = {}
        for s in self.sessions.values():
            lat = list(s.latencies)
            all_lat.extend(lat)
            per_session[s.session_id] = {
                "rows": int(s.rows.size),
                "parked": bool(s.parked),
                "queue_depth": len(s.queue),
                "queued_samples": int(s.queued_samples),
                "samples_in": int(s.samples_in),
                "samples_out": int(s.samples_out),
                "delivered": int(s.delivered),
                "faults": int(s.faults),
                "latency_p50_ms": _pct(lat, 50),
                "latency_p99_ms": _pct(lat, 99),
            }
        return {
            "sessions": len(self.sessions),
            "active": self._active(),
            "parked": sum(1 for s in self.sessions.values() if s.parked),
            "slots": self.n_slots,
            "steps": self.steps,
            "rounds": self.rounds,
            "occupancy": (
                self._lane_fill / (self.rounds * self.n_slots)
                if self.rounds else 0.0
            ),
            "queue_depth": sum(
                len(s.queue) for s in self.sessions.values()
            ),
            "chunks_in": self.chunks_in,
            "chunks_out": self.chunks_out,
            "samples_in": self.samples_in,
            "samples_out": self.samples_out,
            "admission_rejections": self.admission_rejections,
            "evictions": self.evictions,
            "filter_swaps": self.filter_swaps,
            "program_swaps": self.program_swaps,
            "step_retries": self.step_retries,
            "session_faults": self.session_faults,
            "degraded": self._degraded(),
            "predicted_step_us": self.predicted_step_us(),
            "step_budget_us": self.step_budget_us,
            "latency_p50_ms": _pct(all_lat, 50),
            "latency_p99_ms": _pct(all_lat, 99),
            "journal": (
                self.journal.stats() if self.journal is not None else None
            ),
            "per_session": per_session,
        }

    def fault_stats(self) -> dict:
        """Fault observability through the serving layer: the engine's
        own counters (mesh shape, detections, recoveries, injected
        faults…) when it has any, plus the server's per-tenant
        attribution — which sessions rode through a faulted dispatch
        round, and how often."""
        eng_stats = getattr(self.engine, "fault_stats", None)
        d = dict(eng_stats()) if callable(eng_stats) else {}
        d["step_retries"] = self.step_retries
        d["session_faults"] = self.session_faults
        d["per_session"] = {
            sid: int(s.faults) for sid, s in self.sessions.items()
        }
        return d

    # -- crash recovery ------------------------------------------------------

    def close(self) -> None:
        """Flush and close the journal (if any) — the clean-shutdown
        twin of `recover`; the server object stays usable journal-less."""
        if self.journal is not None:
            self.journal.close()
            self.journal = None

    @classmethod
    def recover(
        cls,
        path,
        program,
        *,
        engine=None,
        journal_fsync: bool = True,
        segment_bytes: int = 4 << 20,
        **kwargs,
    ):
        """Rebuild a crashed server from its write-ahead journal.

        ``path`` is the journal directory of the dead process;
        ``program`` is the same bank (coefficients or a compiled
        `BlmacProgram`) — validated against the journal's program
        digest, so recovering under the wrong bank is a loud
        `JournalFormatError`, never a silently wrong stream.

        The rebuild is bit-exact and exactly-once: a torn tail record
        (the process died mid-append) is truncated at the last valid
        record; each session is restored from its last quiescent
        snapshot; journaled chunks after the snapshot are re-pushed and
        re-served through the engine; and the regenerated output below
        the session's journaled delivered-watermark is trimmed, so the
        first post-recovery `pull` continues the stream with no
        duplicates and no gaps.  Admission control is suspended during
        the rebuild (the journal already admitted these sessions once)
        and the server re-attaches to ``path`` with one atomic
        checkpoint rotation.  Extra ``kwargs`` (``n_slots``,
        ``step_budget_us``, ``engine`` …) configure the new server as
        usual."""
        from ..compiler import BlmacProgram, compile_bank
        from .journal import (JournalFormatError, SessionJournal,
                              decode_array)

        if not isinstance(program, BlmacProgram):
            program = compile_bank(np.atleast_2d(np.asarray(program)))
        header, records = SessionJournal.replay(path)
        if header.get("program_key") != program.key:
            raise JournalFormatError(
                f"{os.fspath(path)}: journal belongs to program "
                f"{str(header.get('program_key', '?'))[:12]}…, recovery "
                f"was offered {program.key[:12]}…"
            )
        server = cls(program, engine=engine, journal=None, **kwargs)
        # fold the log into per-session material: registry, last
        # snapshot, undigested chunks, delivered watermark
        reg: dict = {}
        for rec in records:
            t = rec.get("t")
            sid = rec.get("sid")
            if t == "open":
                reg[sid] = {
                    "rows": rec["rows"], "snap": None,
                    "chunks": [], "delivered": 0,
                }
            elif t == "close":
                reg.pop(sid, None)
            elif sid not in reg:
                continue  # record for a session closed later in the log
            elif t == "select":
                reg[sid]["rows"] = rec["rows"]
            elif t == "chunk":
                reg[sid]["chunks"].append(
                    (int(rec["seq"]), decode_array(rec["data"]))
                )
            elif t == "snap":
                r = reg[sid]
                r["snap"] = rec
                r["chunks"] = [
                    (q, c) for q, c in r["chunks"] if q > int(rec["seq"])
                ]
                r["delivered"] = max(r["delivered"], int(rec["delivered"]))
            elif t == "pull":
                reg[sid]["delivered"] = max(
                    reg[sid]["delivered"], int(rec["delivered"])
                )
        saved = (server.step_budget_us, server.max_sessions, server.auto_step)
        server.step_budget_us = None
        server.max_sessions = None
        server.auto_step = False
        try:
            for sid, r in reg.items():
                s = server.open_session(
                    np.asarray(r["rows"], np.int64), session_id=sid
                )
                snap = r["snap"]
                if snap is not None:
                    s.tail = np.atleast_2d(
                        decode_array(snap["tail"]).astype(np.int32)
                    )
                    s.samples_in = int(snap["samples_in"])
                    s.samples_out = int(snap["samples_out"])
                    s.seq = int(snap["seq"])
                    s._wal_snap = {
                        "seq": s.seq,
                        "samples_in": s.samples_in,
                        "samples_out": s.samples_out,
                        "delivered": int(snap["delivered"]),
                        "tail": s.tail.copy(),
                    }
                s.delivered = max(
                    int(r["delivered"]),
                    int(snap["delivered"]) if snap is not None else 0,
                )
                for _, chunk in sorted(r["chunks"], key=lambda t_: t_[0]):
                    server.push(s, chunk)
            server.step()  # regenerate every session's post-snapshot output
            for sid, r in reg.items():
                s = server.sessions[sid]
                base = (
                    int(r["snap"]["samples_out"])
                    if r["snap"] is not None else 0
                )
                drop = s.delivered - base
                if drop <= 0:
                    continue
                out = (
                    np.concatenate(s.outbox, axis=1)
                    if len(s.outbox) > 1
                    else (s.outbox[0] if s.outbox
                          else np.zeros((s.rows.size, 0), np.int32))
                )
                if drop > out.shape[1]:
                    raise JournalFormatError(
                        f"{os.fspath(path)}: session {sid} journaled a "
                        f"delivered watermark {s.delivered} beyond its "
                        f"replayable output {base + out.shape[1]} — "
                        f"chunk records are missing"
                    )
                trimmed = np.ascontiguousarray(out[:, drop:])
                s.outbox = [trimmed] if trimmed.shape[1] else []
        finally:
            (server.step_budget_us, server.max_sessions,
             server.auto_step) = saved
        # re-attach at the same path: one atomic checkpoint rotation
        # supersedes (and deletes) the crashed process's segments
        server.journal = SessionJournal(
            path,
            program_key=program.key,
            taps=program.taps,
            n_filters=program.n_filters,
            segment_bytes=segment_bytes,
            fsync=journal_fsync,
        )
        server._journal_rotate()
        return server
