"""Multi-tenant session serving: many user streams over ONE compiled bank.

`AsyncBankServer` double-buffers a single caller; this module is the
production layer above it — a `BankSessionServer` that serves MANY
concurrent user streams over one `BlmacProgram`:

  * **Per-tenant filter selection.**  Each session opens on a subset of
    the bank's filters.  `program.select(rows)` makes the slice cheap
    (memoized array views registered content-addressed in the
    `ProgramCache`) and gives every selection a stable content key — the
    key a paused session's `TailSnapshot` is addressed to.
  * **Continuous batching into shared slots.**  The server owns one
    `FilterBankEngine` with ``n_slots`` channel lanes.  Sessions push
    independently-paced chunks into per-session queues; each `step()`
    packs every ready session's ``tail + queued`` buffer into the lanes
    of ONE batched dispatch (several rounds when more sessions are ready
    than there are lanes) and slices each tenant's rows / valid sample
    range out of the result.  Bit-exactness versus a dedicated
    per-session engine is structural: a lane is exactly the overlap-save
    buffer `FilterBankEngine.push` would have built, lanes are
    arithmetically independent, and everything is int32 — property-
    tested across arbitrary interleavings in ``tests/test_sessions.py``.
  * **Pause / resume.**  `session.pause()` flushes the session and
    freezes its stream as a `TailSnapshot` keyed to the session's
    *selection* subprogram (and stamped with the session id —
    the compiler-side ``session`` field); `resume_session()` re-admits
    it bit-exactly, in this process or after a restart.
  * **Zero-downtime hot-swap.**  `session.swap_filters(rows)` retargets
    one session (its queue is flushed under the old selection first, so
    a swap never mixes output shapes); `server.swap_program(coeffs)`
    recompiles through the content-addressed `ProgramCache`, builds and
    warms the NEW engine while the OLD program keeps serving, then
    drains and flips atomically — per-session tails carry over because
    they are raw input history, not program state.
  * **Admission control and eviction.**  `open_session` is gated by
    `core.costmodel.predict_session_step_us`: a session is admitted only
    while the predicted batching step stays inside ``step_budget_us``.
    When over budget the server first parks idle sessions (LRU) —
    parking is an internal snapshot, and a push to a parked session
    transparently re-admits it — and only then rejects with
    `AdmissionRejected`.
  * **Observability.**  `serve_stats()` (per-session p50/p99 latency,
    batch occupancy, queue depth, admission rejections, swap/eviction
    counters) lands next to the compiler's `cache_stats()` and the
    fault layer's `fault_stats()`.

The server is host-side and single-threaded by design (like
`AsyncBankServer`): callers interleave ``push`` / ``step`` / ``pull``
from one thread, and determinism of the batching schedule is part of
the bit-exactness contract.
"""
from __future__ import annotations

import itertools
import time
from collections import deque

import numpy as np

__all__ = ["AdmissionRejected", "BankSession", "BankSessionServer"]

#: per-session latency samples kept for the p50/p99 estimators
LATENCY_WINDOW = 256


class AdmissionRejected(RuntimeError):
    """`open_session` (or re-admission of a parked session) would push the
    predicted batching step past the server's ``step_budget_us`` — or past
    ``max_sessions`` — and no idle session could be evicted to make room.

    Carries ``predicted_us`` (the step latency the admission would have
    cost) and ``budget_us`` so callers can implement backpressure.
    """

    def __init__(self, msg: str, predicted_us: float, budget_us: float):
        super().__init__(msg)
        self.predicted_us = float(predicted_us)
        self.budget_us = float(budget_us)


class BankSession:
    """One tenant stream: a filter selection plus overlap-save state.

    Handles are created by `BankSessionServer.open_session` /
    `resume_session`; all methods delegate to the server (which owns the
    shared engine and the batching schedule).
    """

    def __init__(self, server: "BankSessionServer", session_id: str, rows):
        self._server = server
        self.session_id = session_id
        self.rows = np.asarray(rows, np.int64)
        self.subkey = server.program.select(self.rows).key
        # overlap-save state (one lane): last ≤ taps−1 input samples
        self.tail = np.zeros((1, 0), np.int32)
        self.samples_in = 0
        self.samples_out = 0
        # independently-paced input: (chunk, enqueue_monotonic) pairs
        self.queue: list = []
        self.queued_samples = 0
        # outputs computed but not yet pulled, each (len(rows), n_i)
        self.outbox: list = []
        self.latencies = deque(maxlen=LATENCY_WINDOW)
        self.last_active = 0  # server step-sequence of last activity
        self.parked = False
        self.closed = False

    # -- conveniences that delegate to the server ---------------------------

    def push(self, chunk) -> None:
        self._server.push(self, chunk)

    def pull(self) -> np.ndarray:
        return self._server.pull(self)

    def pause(self):
        return self._server.pause_session(self)

    def swap_filters(self, rows) -> np.ndarray:
        return self._server.swap_filters(self, rows)

    def close(self) -> None:
        self._server.close_session(self)

    @property
    def pending(self) -> int:
        """Samples queued or tail-buffered but not yet served."""
        return self.queued_samples + self.tail.shape[1]


class BankSessionServer:
    """Serve many concurrent filter-selection streams over one program.

    Parameters
    ----------
    program : `repro.compiler.BlmacProgram` or (B, taps) int array
        The compiled bank every session selects from (arrays are
        compiled via the content-addressed `compile_bank`).
    n_slots : int
        Channel lanes of the shared engine — sessions batched per
        dispatch round.  More ready sessions than slots simply take
        ceil(ready / n_slots) rounds per step.
    step_budget_us : float | None
        Admission budget: a session is admitted only while
        `predict_session_step_us(dispatch_us, active + 1, n_slots)`
        stays ≤ this.  None disables cost-model admission control.
    max_sessions : int | None
        Hard cap on concurrently *active* (non-parked) sessions.
    auto_step : bool
        When True (default) every `push` runs a batching step, so a
        single-caller loop behaves like `FilterBankEngine.push`.  Set
        False to drive `step()` yourself and batch many sessions' pushes
        into shared rounds (what the benchmark and a real event loop do).
    mode, tile, interpret, chunk_hint
        Forwarded to the shared `FilterBankEngine`.
    """

    def __init__(
        self,
        program,
        n_slots: int = 8,
        step_budget_us: float | None = None,
        max_sessions: int | None = None,
        auto_step: bool = True,
        mode: str = "auto",
        tile: int | None = None,
        interpret: bool | None = None,
        chunk_hint: int = 2048,
    ):
        from ..compiler import BlmacProgram, compile_bank
        from ..filters import FilterBankEngine

        if n_slots < 1:
            raise ValueError("n_slots must be >= 1")
        if not isinstance(program, BlmacProgram):
            program = compile_bank(np.atleast_2d(np.asarray(program)))
        self.program = program
        self.n_slots = int(n_slots)
        self.step_budget_us = step_budget_us
        self.max_sessions = max_sessions
        self.auto_step = bool(auto_step)
        self._engine_kw = dict(
            mode=mode, tile=tile, interpret=interpret, chunk_hint=chunk_hint
        )
        self.engine = FilterBankEngine(
            program, channels=self.n_slots, **self._engine_kw
        )
        self.sessions: dict = {}  # session_id -> BankSession (incl. parked)
        self._ids = itertools.count()
        self._seq = 0  # monotone activity clock for LRU decisions
        # counters for serve_stats()
        self.steps = 0
        self.rounds = 0
        self.chunks_in = 0
        self.chunks_out = 0
        self.samples_in = 0
        self.samples_out = 0
        self.admission_rejections = 0
        self.evictions = 0
        self.filter_swaps = 0
        self.program_swaps = 0
        self._lane_fill = 0  # lanes carrying a session, across all rounds

    # -- admission / eviction -----------------------------------------------

    def _dispatch_us(self) -> float:
        """Per-round dispatch latency estimate feeding admission control:
        the shared engine's autotuner verdict when there is one, else the
        coarse fixed-overhead floor of a forced-mode scheduled dispatch."""
        from ..core.costmodel import PALLAS_CALL_US, STEP_US

        plan = getattr(self.engine, "dispatch_plan", None)
        if plan is not None:
            return float(plan.predicted_us)
        return PALLAS_CALL_US + STEP_US

    def _active(self) -> int:
        return sum(
            1 for s in self.sessions.values() if not s.parked and not s.closed
        )

    def predicted_step_us(self, extra_sessions: int = 0) -> float:
        """Modelled latency of one batching step with the current active
        population plus ``extra_sessions`` hypothetical admissions."""
        from ..core.costmodel import predict_session_step_us

        return predict_session_step_us(
            self._dispatch_us(), self._active() + extra_sessions, self.n_slots
        )

    def _park_idle_lru(self) -> bool:
        """Park the least-recently-active idle session to make room.
        Parking is internal state only (the lane model has no per-session
        device residency), so a parked session's handle stays valid and
        its next `push` re-admits it transparently."""
        idle = [
            s for s in self.sessions.values()
            if not s.parked and not s.closed and s.queued_samples == 0
        ]
        if not idle:
            return False
        victim = min(idle, key=lambda s: s.last_active)
        victim.parked = True
        self.evictions += 1
        return True

    def _admit(self, what: str) -> None:
        """Gate one admission (open / resume / un-park) on the cost model,
        parking idle LRU sessions until the predicted step fits."""
        while True:
            over_cap = (
                self.max_sessions is not None
                and self._active() + 1 > self.max_sessions
            )
            predicted = self.predicted_step_us(extra_sessions=1)
            over_budget = (
                self.step_budget_us is not None
                and predicted > self.step_budget_us
            )
            if not over_cap and not over_budget:
                return
            if self._park_idle_lru():
                continue
            self.admission_rejections += 1
            budget = (
                float(self.step_budget_us)
                if self.step_budget_us is not None
                else float("inf")
            )
            raise AdmissionRejected(
                f"{what}: predicted step {predicted:.0f}us exceeds budget "
                f"{budget:.0f}us (active={self._active()}, "
                f"slots={self.n_slots}) and no idle session to evict",
                predicted_us=predicted,
                budget_us=budget,
            )

    def _readmit(self, session: BankSession) -> None:
        self._admit(f"re-admit session {session.session_id}")
        session.parked = False

    # -- session lifecycle ---------------------------------------------------

    def open_session(self, rows, session_id: str | None = None) -> BankSession:
        """Open a stream serving ``rows`` of the bank (original filter
        indices).  Warms the selection subprogram through the
        `ProgramCache` and runs admission control before the session can
        occupy a lane."""
        rows = np.asarray(rows, np.int64).ravel()
        if rows.size == 0:
            raise ValueError("a session must select at least one filter")
        if rows.min() < 0 or rows.max() >= self.program.n_filters:
            raise ValueError(
                f"filter rows out of range for a {self.program.n_filters}-"
                f"filter bank: {rows}"
            )
        if session_id is None:
            session_id = f"s{next(self._ids)}"
        if session_id in self.sessions:
            raise ValueError(f"session id {session_id!r} already open")
        self._admit(f"open session {session_id}")
        s = BankSession(self, session_id, rows)
        self._seq += 1
        s.last_active = self._seq
        self.sessions[session_id] = s
        return s

    def close_session(self, session: BankSession) -> None:
        session.closed = True
        self.sessions.pop(session.session_id, None)

    def pause_session(self, session: BankSession):
        """Flush the session, freeze its stream as a `TailSnapshot`
        addressed to its *selection* subprogram and stamped with the
        session id, and close it (freeing its admission slot).  The
        snapshot (plus the same ``rows``) is everything
        `resume_session` needs — here or in another process.  Outputs
        computed by the flush stay in the handle's outbox: `pull` works
        on a closed session, so nothing is lost if the caller pauses
        before draining."""
        from ..compiler.state import TailSnapshot

        self._check_open(session)
        if session.queued_samples:
            self.step()
        snap = TailSnapshot(
            program_key=session.subkey,
            channels=1,
            samples_in=session.samples_in,
            samples_out=session.samples_out,
            tail=session.tail.copy(),
            session=session.session_id,
        )
        self.close_session(session)
        return snap

    def resume_session(
        self, snapshot, rows, session_id: str | None = None
    ) -> BankSession:
        """Re-admit a paused stream bit-exactly.  The snapshot must be
        addressed to `program.select(rows)` — resuming under a different
        selection (or a different program) is a loud ValueError."""
        rows = np.asarray(rows, np.int64).ravel()
        expect = self.program.select(rows).key
        if snapshot.program_key != expect:
            raise ValueError(
                f"snapshot belongs to selection {snapshot.program_key[:12]}…,"
                f" rows {rows.tolist()} of this program are {expect[:12]}…"
            )
        if int(snapshot.channels) != 1:
            raise ValueError(
                f"session snapshots are single-lane, got "
                f"{snapshot.channels} channels"
            )
        s = self.open_session(
            rows, session_id=session_id or snapshot.session or None
        )
        s.tail = np.asarray(snapshot.tail, np.int32).copy()
        s.samples_in = int(snapshot.samples_in)
        s.samples_out = int(snapshot.samples_out)
        return s

    # -- hot swap ------------------------------------------------------------

    def swap_filters(self, session: BankSession, rows) -> np.ndarray:
        """Retarget one session to a new filter selection.  Queued input
        is flushed under the OLD selection first (a swap never mixes
        output shapes in the outbox); returns those final old-selection
        outputs.  The overlap-save tail carries over — it is raw input
        history, selection-independent — so the new selection's stream
        continues gaplessly."""
        self._check_open(session)
        if session.queued_samples:
            self.step()
        out = self.pull(session)
        rows = np.asarray(rows, np.int64).ravel()
        if rows.size == 0:
            raise ValueError("a session must select at least one filter")
        if rows.min() < 0 or rows.max() >= self.program.n_filters:
            raise ValueError(
                f"filter rows out of range for a {self.program.n_filters}-"
                f"filter bank: {rows}"
            )
        session.rows = rows
        session.subkey = self.program.select(rows).key  # warm via cache
        self.filter_swaps += 1
        return out

    def swap_program(self, coeffs, spec=None) -> None:
        """Zero-downtime server-wide program swap.  The replacement is
        compiled through the content-addressed `ProgramCache`
        (recompiling identical content is a cache hit) and its engine is
        built and warmed while the OLD program keeps serving; only then
        are all sessions drained under the old program and the engine
        flipped atomically.  Tap count must match — per-session tails
        are taps−1 samples of raw input history and carry over unchanged,
        which is what makes the swap seamless mid-stream."""
        from ..compiler import BlmacProgram, compile_bank
        from ..filters import FilterBankEngine

        if isinstance(coeffs, BlmacProgram):
            new_prog = coeffs
        else:
            new_prog = compile_bank(np.atleast_2d(np.asarray(coeffs)), spec)
        if new_prog.taps != self.program.taps:
            raise ValueError(
                f"cannot hot-swap a {new_prog.taps}-tap program into a "
                f"{self.program.taps}-tap stream (tails would be invalid)"
            )
        for s in self.sessions.values():
            if s.rows.max() >= new_prog.n_filters:
                raise ValueError(
                    f"session {s.session_id} selects row {int(s.rows.max())}"
                    f" but the new program has {new_prog.n_filters} filters"
                )
        # build + warm the new engine while the old one still serves
        new_engine = FilterBankEngine(
            new_prog, channels=self.n_slots, **self._engine_kw
        )
        # drain every queued chunk under the OLD program, then flip
        self.step()
        self.program = new_prog
        self.engine = new_engine
        for s in self.sessions.values():
            s.subkey = new_prog.select(s.rows).key
        self.program_swaps += 1

    # -- streaming -----------------------------------------------------------

    def _check_open(self, session: BankSession) -> None:
        if session.closed or session.session_id not in self.sessions:
            raise ValueError(f"session {session.session_id!r} is closed")

    def push(self, session: BankSession, chunk) -> None:
        """Enqueue (n,) samples on one session's independently-paced
        stream.  Pushing to a parked session re-admits it (possibly
        parking another idle session).  With ``auto_step`` the push also
        runs a batching step, so outputs land in the outbox immediately."""
        self._check_open(session)
        if session.parked:
            self._readmit(session)
        chunk = np.asarray(chunk)
        if chunk.ndim == 2 and chunk.shape[0] == 1:
            chunk = chunk[0]
        if chunk.ndim != 1:
            raise ValueError(
                f"session chunks are 1-D sample vectors, got {chunk.shape}"
            )
        chunk = chunk.astype(np.int32, copy=False)
        self._seq += 1
        session.last_active = self._seq
        if chunk.shape[0]:
            session.queue.append((chunk, time.monotonic()))
            session.queued_samples += int(chunk.shape[0])
            session.samples_in += int(chunk.shape[0])
            self.chunks_in += 1
            self.samples_in += int(chunk.shape[0])
        if self.auto_step:
            self.step()

    def pull(self, session: BankSession) -> np.ndarray:
        """Drain a session's computed outputs as one gapless
        (len(rows), n) int32 array (n may be 0)."""
        if not session.outbox:
            return np.zeros((session.rows.size, 0), np.int32)
        out, session.outbox = session.outbox, []
        return np.concatenate(out, axis=1) if len(out) > 1 else out[0]

    def _ready_sessions(self) -> list:
        """Consume priming-only queues into tails (no kernel work) and
        return the sessions that can produce ≥ 1 output, oldest queued
        chunk first (deterministic batching order)."""
        ready = []
        for s in self.sessions.values():
            if s.parked or s.closed or not s.queue:
                continue
            total = s.tail.shape[1] + s.queued_samples
            if total < self.program.taps:  # still priming: absorb, no lane
                data = np.concatenate([c for c, _ in s.queue])
                now = time.monotonic()
                for _, ts in s.queue:
                    s.latencies.append(now - ts)
                self.chunks_out += len(s.queue)
                s.queue = []
                s.queued_samples = 0
                s.tail = np.concatenate([s.tail, data[None, :]], axis=1)
                continue
            ready.append(s)
        ready.sort(key=lambda s: s.queue[0][1])
        return ready

    def step(self) -> int:
        """Run one batching step: serve EVERY ready session, packing up
        to ``n_slots`` of them per dispatch round.  Returns the number of
        sessions served.  Idempotent when nothing is queued."""
        ready = self._ready_sessions()
        if not ready:
            return 0
        self.steps += 1
        taps = self.program.taps
        served = 0
        for r0 in range(0, len(ready), self.n_slots):
            batch = ready[r0:r0 + self.n_slots]
            lane_bufs = []
            for s in batch:
                data = np.concatenate([c for c, _ in s.queue])
                lane_bufs.append(
                    np.concatenate([s.tail[0], data])
                )
            lane_len = max(b.shape[0] for b in lane_bufs)
            buf = np.zeros((self.n_slots, lane_len), np.int32)
            for lane, b in enumerate(lane_bufs):
                buf[lane, : b.shape[0]] = b
            y = self.engine.apply_lanes(buf)  # (B_full, n_slots, lane_len-taps+1)
            self.rounds += 1
            self._lane_fill += len(batch)
            now = time.monotonic()
            for lane, s in enumerate(batch):
                valid = lane_bufs[lane].shape[0]
                n_out = valid - taps + 1
                s.outbox.append(
                    np.ascontiguousarray(y[s.rows, lane, :n_out])
                )
                s.tail = lane_bufs[lane][None, valid - (taps - 1):] \
                    if taps > 1 else np.zeros((1, 0), np.int32)
                s.samples_out += n_out
                self.samples_out += n_out
                for _, ts in s.queue:
                    s.latencies.append(now - ts)
                self.chunks_out += len(s.queue)
                s.queue = []
                s.queued_samples = 0
                self._seq += 1
                s.last_active = self._seq
                served += 1
        return served

    def flush(self) -> int:
        """Serve everything currently queued (alias for one `step`)."""
        return self.step()

    # -- observability -------------------------------------------------------

    def serve_stats(self) -> dict:
        """Serving-layer observability, one JSON-able dict — the session
        analogue of the compiler's `cache_stats()` and the fault layer's
        `fault_stats()`."""

        def _pct(samples, q):
            return float(np.percentile(np.asarray(samples), q)) * 1e3

        all_lat = []
        per_session = {}
        for s in self.sessions.values():
            lat = list(s.latencies)
            all_lat.extend(lat)
            per_session[s.session_id] = {
                "rows": int(s.rows.size),
                "parked": bool(s.parked),
                "queue_depth": len(s.queue),
                "queued_samples": int(s.queued_samples),
                "samples_in": int(s.samples_in),
                "samples_out": int(s.samples_out),
                "latency_p50_ms": _pct(lat, 50) if lat else None,
                "latency_p99_ms": _pct(lat, 99) if lat else None,
            }
        return {
            "sessions": len(self.sessions),
            "active": self._active(),
            "parked": sum(1 for s in self.sessions.values() if s.parked),
            "slots": self.n_slots,
            "steps": self.steps,
            "rounds": self.rounds,
            "occupancy": (
                self._lane_fill / (self.rounds * self.n_slots)
                if self.rounds else 0.0
            ),
            "queue_depth": sum(
                len(s.queue) for s in self.sessions.values()
            ),
            "chunks_in": self.chunks_in,
            "chunks_out": self.chunks_out,
            "samples_in": self.samples_in,
            "samples_out": self.samples_out,
            "admission_rejections": self.admission_rejections,
            "evictions": self.evictions,
            "filter_swaps": self.filter_swaps,
            "program_swaps": self.program_swaps,
            "predicted_step_us": self.predicted_step_us(),
            "step_budget_us": self.step_budget_us,
            "latency_p50_ms": _pct(all_lat, 50) if all_lat else None,
            "latency_p99_ms": _pct(all_lat, 99) if all_lat else None,
            "per_session": per_session,
        }
