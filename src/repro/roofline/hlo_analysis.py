"""Static analyzer for SPMD-partitioned HLO text.

`compiled.cost_analysis()` visits while bodies ONCE, so for scan-over-
layers programs (every model here) its FLOP/byte counts are ~depth×
too low, and it reports nothing about collectives.  This module re-derives
the three roofline inputs directly from `compiled.as_text()`:

  * flops      — 2·|result|·|contraction| per `dot`, × loop trip counts
                 (trip counts read from the while op's backend_config
                 `known_trip_count`, falling back to the condition's
                 comparison constant),
  * hbm_bytes  — Σ (operand + result sizes) over *top-level* ops — i.e.
                 fusion boundaries, which is exactly XLA's definition of
                 what goes to HBM; zero-cost ops (gte/tuple/parameter/
                 bitcast/constant) excluded, × trip counts,
  * collective_bytes — per collective kind, with ring-model link-byte
                 factors and replica-group sizes parsed per op.

All shapes in the partitioned module are per-device, so every number this
produces is per-chip — matching the roofline denominators.

Validated in `tests/test_roofline.py`: a scanned and an unrolled version
of the same network produce identical FLOP counts, and hand-computable
matmuls match exactly.
"""
from __future__ import annotations

import dataclasses
import math
import re
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_NAME_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*")
_OP_RE = re.compile(r"\s*([\w\-]+)\(")
_CALLS_RE = re.compile(r"(?:calls|to_apply)=%?([\w\.\-]+)")
_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_WHILE_RE = re.compile(r"condition=%?([\w\.\-]+), body=%?([\w\.\-]+)")
_TRIP_RE = re.compile(r'known_trip_count["\s:{]+n["\s:]+\"?(\d+)')
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

_ZERO_COST = {
    "parameter", "get-tuple-element", "tuple", "bitcast", "constant",
    "after-all", "iota", "partition-id", "replica-id", "reshape",
    "broadcast",
}

# Ops the TPU backend fuses into neighbours (CPU HLO leaves them top-level,
# which would overstate HBM traffic ~3-5×).  Excluding them makes hbm_bytes
# a *fusion-optimistic* model — the methodology caveat documented in
# docs/benchmarks.md (roofline utilization columns of BENCH_compiled.json
# inherit it).
_FUSED_ON_TPU = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "abs",
    "negate", "exponential", "exponential-minus-one", "log", "log-plus-one",
    "tanh", "logistic", "rsqrt", "sqrt", "power", "select", "compare",
    "and", "or", "not", "xor", "convert", "clamp", "floor", "ceil",
    "round-nearest-even", "round-nearest-afz", "sign", "is-finite", "copy",
    "reverse", "slice", "concatenate", "pad", "transpose", "cosine", "sine",
    "shift-left", "shift-right-logical", "shift-right-arithmetic", "expm1",
    "remainder", "atan2", "cbrt", "erf", "real", "imag", "stochastic-convert",
}

COLLECTIVE_KINDS = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)


def shape_bytes(type_str: str) -> int:
    """Bytes of an HLO type string; tuples summed (layout braces ignored)."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def shape_dims(type_str: str) -> list[int]:
    m = _SHAPE_RE.search(type_str)
    if not m or not m.group(2):
        return []
    return [int(d) for d in m.group(2).split(",")]


def _matching_paren(s: str, i: int) -> int:
    depth = 0
    for j in range(i, len(s)):
        if s[j] == "(":
            depth += 1
        elif s[j] == ")":
            depth -= 1
            if depth == 0:
                return j
    return len(s) - 1


@dataclasses.dataclass
class OpDef:
    name: str
    type_str: str
    opcode: str
    args: str
    line: str


def parse_def(line: str) -> OpDef | None:
    m = _NAME_RE.match(line)
    if not m:
        return None
    i = m.end()
    if i >= len(line):
        return None
    if line[i] == "(":  # tuple result type
        j = _matching_paren(line, i)
        type_str = line[i : j + 1]
        k = j + 1
    else:
        sp = line.find(" ", i)
        if sp < 0:
            return None
        type_str = line[i:sp]
        k = sp
    om = _OP_RE.match(line[k:])
    if not om:
        return None
    opcode = om.group(1)
    astart = k + om.end() - 1
    aend = _matching_paren(line, astart)
    return OpDef(m.group(1), type_str, opcode, line[astart + 1 : aend], line)


@dataclasses.dataclass
class CompCost:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    coll_bytes: dict[str, float] = dataclasses.field(default_factory=dict)
    coll_counts: dict[str, float] = dataclasses.field(default_factory=dict)
    hbm_by_op: dict[str, float] = dataclasses.field(default_factory=dict)

    def add(self, other: "CompCost", mult: float = 1.0) -> None:
        self.flops += other.flops * mult
        self.hbm_bytes += other.hbm_bytes * mult
        for k, v in other.coll_bytes.items():
            self.coll_bytes[k] = self.coll_bytes.get(k, 0.0) + v * mult
        for k, v in other.coll_counts.items():
            self.coll_counts[k] = self.coll_counts.get(k, 0) + v * mult
        for k, v in other.hbm_by_op.items():
            self.hbm_by_op[k] = self.hbm_by_op.get(k, 0.0) + v * mult

    def _hbm(self, op: str, nbytes: float) -> None:
        self.hbm_bytes += nbytes
        self.hbm_by_op[op] = self.hbm_by_op.get(op, 0.0) + nbytes

    @property
    def total_coll_bytes(self) -> float:
        return sum(self.coll_bytes.values())


def split_computations(text: str) -> tuple[dict[str, list[str]], str]:
    """→ ({name: body lines}, entry_name)."""
    comps: dict[str, list[str]] = {}
    entry = ""
    cur: list[str] | None = None
    cur_name = ""
    for line in text.splitlines():
        if cur is None:
            m = re.match(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\{\s*$", line)
            if m and ("->" in line or m.group(1)):
                cur_name = m.group(2)
                cur = []
                if m.group(1):
                    entry = cur_name
        else:
            if line.startswith("}"):
                comps[cur_name] = cur
                cur = None
            else:
                cur.append(line)
    return comps, entry


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return default


def _collective_link_bytes(op: str, result_bytes: int,
                           operand_bytes: int, n: int) -> float:
    """Ring-model bytes crossing a link per device."""
    frac = (n - 1) / max(n, 1)
    if op == "all-reduce":
        return 2.0 * result_bytes * frac
    if op == "all-gather":
        return result_bytes * frac
    if op == "reduce-scatter":
        return operand_bytes * frac
    if op == "all-to-all":
        return result_bytes * frac
    if op == "collective-permute":
        return float(result_bytes)
    return 0.0


def analyze_hlo(text: str, default_group: int = 1) -> CompCost:
    comps, entry = split_computations(text)
    if not entry:
        raise ValueError("no ENTRY computation found")
    memo: dict[str, CompCost] = {}

    def trip_count(line: str, cond_name: str) -> int:
        m = _TRIP_RE.search(line)
        if m:
            return int(m.group(1))
        for ln in comps.get(cond_name, []):
            m = _CONST_RE.search(ln)
            if m:
                return int(m.group(1))
        return 1

    def operand_bytes(args: str, types: dict[str, str],
                      producers: dict[str, "OpDef"] | None = None) -> int:
        """Sum operand sizes, looking *through* bf16→f32 legalization:
        the CPU backend has no native bf16, so it inserts convert
        fusions that a TPU build would not have — the true HBM read is
        the convert's INPUT, not its f32 output."""
        total = 0
        for om in re.finditer(r"%([\w\.\-]+)", args):
            name2 = om.group(1)
            if producers:
                for _ in range(3):  # look through convert chains
                    d2 = producers.get(name2)
                    if d2 is None:
                        break
                    if d2.opcode == "convert" or (
                            d2.opcode == "fusion" and "convert" in d2.name):
                        m2 = re.match(r"\s*%([\w\.\-]+)", d2.args)
                        if m2 and shape_dims(d2.type_str) == shape_dims(
                                types.get(m2.group(1), "")):
                            name2 = m2.group(1)
                            continue
                    break
            t = types.get(name2)
            if t:
                total += shape_bytes(t)
        return total

    def cost_of(name: str) -> CompCost:
        if name in memo:
            return memo[name]
        memo[name] = CompCost()  # break cycles defensively
        c = CompCost()
        lines = comps.get(name, [])
        types: dict[str, str] = {}
        producers: dict[str, OpDef] = {}
        defs: list[OpDef] = []
        for ln in lines:
            d = parse_def(ln)
            if d:
                defs.append(d)
                types[d.name] = d.type_str
                producers[d.name] = d
        # computation parameters also carry types (parameter(0) defs) —
        # already included via parse_def above.
        for d in defs:
            op = d.opcode
            if op in _ZERO_COST:
                continue
            if op == "while":
                wm = _WHILE_RE.search(d.line)
                if wm:
                    t = trip_count(d.line, wm.group(1))
                    c.add(cost_of(wm.group(2)), t)
                    c.add(cost_of(wm.group(1)), t)
                continue
            if op == "scatter":
                # in-place aliased: traffic = updates (read) + touched rows
                # (read-modify-write) + indices; NOT the whole buffer
                parts = [pm.group(1) for pm in
                         re.finditer(r"%([\w\.\-]+)", d.args)]
                upd = shape_bytes(types.get(parts[-1], "")) if parts else 0
                idx = shape_bytes(types.get(parts[1], "")) if len(parts) > 2 else 0
                c._hbm(op, 3 * upd + idx)
                continue
            if op in ("call", "conditional", "map", "sort", "reduce",
                      "reduce-window", "select-and-scatter",
                      "custom-call", "async-start"):
                cm = _CALLS_RE.search(d.line)
                if cm:
                    c.add(cost_of(cm.group(1)))
                bm = _BRANCH_RE.search(d.line)
                if bm:
                    for cn in re.split(r",\s*", bm.group(1)):
                        c.add(cost_of(cn.strip().lstrip("%")))
                c._hbm(op, operand_bytes(d.args, types) + shape_bytes(d.type_str))
                continue
            if op == "fusion":
                if "convert" in d.name:
                    continue  # CPU bf16→f32 legalization; absent on TPU
                # one HBM round trip; internals are on-chip by definition
                c._hbm(op, operand_bytes(d.args, types, producers)
                       + shape_bytes(d.type_str))
                continue
            base = op.removesuffix("-start").removesuffix("-done")
            if base in COLLECTIVE_KINDS:
                if op.endswith("-done"):
                    continue  # counted at -start
                n = _group_size(d.line, default_group)
                ob = operand_bytes(d.args, types, producers)
                rb = shape_bytes(d.type_str)
                b = _collective_link_bytes(base, rb, ob, n)
                c.coll_bytes[base] = c.coll_bytes.get(base, 0.0) + b
                c.coll_counts[base] = c.coll_counts.get(base, 0) + 1
                c._hbm(op, ob + rb)
                continue
            if op == "dynamic-update-slice":
                # aliased in-place update: only the update slice moves
                # (read-modify-write), not the full buffer
                um = re.match(r"\s*%[\w\.\-]+,\s*%([\w\.\-]+)", d.args)
                ub = shape_bytes(types.get(um.group(1), "")) if um else 0
                c._hbm(op, 2 * ub)
                continue
            if op in ("dynamic-slice", "gather"):
                # reads only the addressed window, not the whole operand
                c._hbm(op, 2 * shape_bytes(d.type_str))
                continue
            if op == "dot":
                dims = shape_dims(d.type_str)
                # operands may be printed bare (%lhs, %rhs) or typed
                # (f32[16,32]{1,0} %lhs, ...) depending on the XLA version,
                # so locate the first operand name rather than anchoring
                lm = re.search(r"%([\w\.\-]+)", d.args)
                contract = 1
                cm = _CONTRACT_RE.search(d.line)
                if lm and cm and lm.group(1) in types and cm.group(1):
                    ldims = shape_dims(types[lm.group(1)])
                    for ci in cm.group(1).split(","):
                        ci = int(ci)
                        if ci < len(ldims):
                            contract *= ldims[ci]
                c.flops += 2.0 * math.prod(dims) * contract
            elif op == "convolution":
                c.flops += 2.0 * math.prod(shape_dims(d.type_str))
            if op not in _FUSED_ON_TPU:
                c._hbm(op, operand_bytes(d.args, types, producers)
                       + shape_bytes(d.type_str))
        memo[name] = c
        return c

    total = CompCost()
    total.add(cost_of(entry))
    return total
