"""Deterministic, sharded, resumable synthetic token pipeline.

Every batch is a pure function of (seed, step) via counter-based Philox
RNG — a restart at step k replays exactly the stream a non-failing run
would have seen (the property the fault-tolerance test asserts).  Shards
slice the global batch so each data-parallel group loads only its rows.

Two distributions:
  * ``uniform`` — i.i.d. tokens (throughput benchmarking),
  * ``markov``  — x_{t+1} = (a·x_t + c) mod V with ε-noise: a learnable
    next-token structure, so integration tests can assert loss ↓.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    global_batch: int
    seq_len: int
    seed: int = 0
    kind: str = "markov"  # uniform | markov
    noise: float = 0.1


class TokenPipeline:
    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        # fixed affine map per seed for the markov structure
        r = np.random.Generator(np.random.Philox(key=cfg.seed))
        self._a = int(r.integers(1, cfg.vocab_size - 1)) | 1  # odd ⇒ bijective mod 2^k-ish
        self._c = int(r.integers(0, cfg.vocab_size))

    def _rng(self, step: int) -> np.random.Generator:
        return np.random.Generator(
            np.random.Philox(key=self.cfg.seed, counter=step)
        )

    def global_batch_at(self, step: int) -> dict[str, np.ndarray]:
        cfg = self.cfg
        r = self._rng(step)
        b, s, v = cfg.global_batch, cfg.seq_len, cfg.vocab_size
        if cfg.kind == "uniform":
            toks = r.integers(0, v, size=(b, s + 1), dtype=np.int32)
        else:
            toks = np.empty((b, s + 1), np.int32)
            toks[:, 0] = r.integers(0, v, size=b)
            noise = r.random((b, s)) < cfg.noise
            rand = r.integers(0, v, size=(b, s), dtype=np.int32)
            for t in range(s):
                nxt = (toks[:, t].astype(np.int64) * self._a + self._c) % v
                toks[:, t + 1] = np.where(noise[:, t], rand[:, t], nxt)
        return {
            "tokens": toks[:, :-1],
            "labels": toks[:, 1:].copy(),
            "mask": np.ones((b, s), np.float32),
        }

    def shard_batch_at(self, step: int, shard: int, n_shards: int):
        """Rows owned by data shard `shard` — deterministic slicing."""
        g = self.global_batch_at(step)
        b = self.cfg.global_batch
        if b % n_shards:
            raise ValueError(f"batch {b} not divisible by {n_shards} shards")
        lo = shard * (b // n_shards)
        hi = lo + b // n_shards
        return {k: v[lo:hi] for k, v in g.items()}
