from .optimizer import OptHParams, make_optimizer, schedule, global_norm
from .train_step import TrainHParams, make_train_step, train_state_init, make_positions

__all__ = ["OptHParams", "make_optimizer", "schedule", "global_norm",
           "TrainHParams", "make_train_step", "train_state_init",
           "make_positions"]
