"""Optimizers, built in-tree (no optax in this environment): AdamW with
fp32 moments, and factored Adafactor for the huge MoE archs where full
second moments don't fit HBM (DESIGN.md §5; deepseek-v3 uses it).
States are pytrees mirroring the params, so they shard with the params.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptHParams:
    learning_rate: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    grad_clip: float = 1.0
    min_lr_ratio: float = 0.1


def schedule(hp: OptHParams, step: jax.Array) -> jax.Array:
    """Linear warmup → cosine decay to min_lr_ratio."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(hp.warmup_steps, 1), 1.0)
    prog = jnp.clip(
        (step - hp.warmup_steps) / jnp.maximum(hp.total_steps - hp.warmup_steps, 1),
        0.0, 1.0,
    )
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    decay = hp.min_lr_ratio + (1 - hp.min_lr_ratio) * cos
    return hp.learning_rate * warm * decay


def global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def clip_by_global_norm(tree, max_norm: float):
    g = global_norm(tree)
    factor = jnp.minimum(1.0, max_norm / (g + 1e-9))
    return jax.tree_util.tree_map(
        lambda x: (x.astype(jnp.float32) * factor).astype(x.dtype), tree
    ), g


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------


def adamw_init(params):
    def zeros(p):
        return jnp.zeros(p.shape, jnp.float32)

    return {
        "m": jax.tree_util.tree_map(zeros, params),
        "v": jax.tree_util.tree_map(zeros, params),
    }


def adamw_update(grads, state, params, step, hp: OptHParams):
    lr = schedule(hp, step)
    t = step.astype(jnp.float32) + 1.0
    bc1 = 1 - hp.b1 ** t
    bc2 = 1 - hp.b2 ** t

    def upd(g, m, v, p):
        g = g.astype(jnp.float32)
        m = hp.b1 * m + (1 - hp.b1) * g
        v = hp.b2 * v + (1 - hp.b2) * g * g
        u = (m / bc1) / (jnp.sqrt(v / bc2) + hp.eps)
        u = u + hp.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype), m, v

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v}


# ---------------------------------------------------------------------------
# Adafactor (factored second moment; no first moment) — memory ~0 extra
# ---------------------------------------------------------------------------


def adafactor_init(params):
    def fac(p):
        if p.ndim >= 2:
            return {
                "vr": jnp.zeros(p.shape[:-1], jnp.float32),
                "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
            }
        return {"v": jnp.zeros(p.shape, jnp.float32)}

    return {"f": jax.tree_util.tree_map(fac, params,
                                        is_leaf=lambda x: hasattr(x, "ndim"))}


def adafactor_update(grads, state, params, step, hp: OptHParams):
    lr = schedule(hp, step)
    decay = 1.0 - (step.astype(jnp.float32) + 1.0) ** -0.8

    def upd(g, f, p):
        g = g.astype(jnp.float32)
        g2 = g * g + 1e-30
        if p.ndim >= 2:
            vr = decay * f["vr"] + (1 - decay) * g2.mean(axis=-1)
            vc = decay * f["vc"] + (1 - decay) * g2.mean(axis=-2)
            denom = (
                vr[..., None] * vc[..., None, :]
                / jnp.maximum(vr.mean(axis=-1)[..., None, None], 1e-30)
            )
            u = g / jnp.sqrt(denom + 1e-30)
            nf = {"vr": vr, "vc": vc}
        else:
            v = decay * f["v"] + (1 - decay) * g2
            u = g / jnp.sqrt(v + 1e-30)
            nf = {"v": v}
        # update clipping (RMS ≤ 1), as in the Adafactor paper
        rms = jnp.sqrt(jnp.mean(u * u) + 1e-30)
        u = u / jnp.maximum(1.0, rms)
        u = u + hp.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype), nf

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_f = treedef.flatten_up_to(state["f"])
    out = [upd(g, f, p) for g, f, p in zip(flat_g, flat_f, flat_p)]
    return treedef.unflatten([o[0] for o in out]), {
        "f": treedef.unflatten([o[1] for o in out])
    }


def make_optimizer(name: str) -> tuple[Callable, Callable]:
    if name == "adamw":
        return adamw_init, adamw_update
    if name == "adafactor":
        return adafactor_init, adafactor_update
    raise ValueError(f"unknown optimizer {name!r}")
