"""The jitted train step: microbatched grads → clip → optimizer update.

Built for the pjit path: params/opt-state carry NamedShardings derived
from the declaration tree; activations are constrained inside the model;
XLA SPMD places the DP/FSDP/TP/EP collectives.  Gradient accumulation is
a `lax.scan` over microbatches (sequential, checkpointed).
"""
from __future__ import annotations

import dataclasses
import jax
import jax.numpy as jnp

from ..nn.common import ShardCtx
from ..nn.model import loss_fn
from .optimizer import OptHParams, clip_by_global_norm, make_optimizer


@dataclasses.dataclass(frozen=True)
class TrainHParams:
    opt: OptHParams = OptHParams()
    grad_accum: int = 1
    z_loss: float = 1e-4


def train_state_init(params, cfg):
    opt_init, _ = make_optimizer(cfg.optimizer)
    return {"params": params, "opt": opt_init(params),
            "step": jnp.zeros((), jnp.int32)}


def abstract_train_state(cfg, decls):
    """ShapeDtypeStruct train state — feeds jit(...).lower() without ever
    allocating the (possibly 671B-param) model."""
    from ..nn.common import abstract_params

    aparams = abstract_params(decls, jnp.dtype(cfg.param_dtype))
    def sds(shape):
        return jax.ShapeDtypeStruct(shape, jnp.float32)

    if cfg.optimizer == "adamw":
        moments = jax.tree_util.tree_map(lambda p: sds(p.shape), aparams)
        opt = {"m": moments, "v": moments}
    else:  # adafactor
        def fac(p):
            if len(p.shape) >= 2:
                return {"vr": sds(p.shape[:-1]),
                        "vc": sds(p.shape[:-2] + p.shape[-1:])}
            return {"v": sds(p.shape)}

        opt = {"f": jax.tree_util.tree_map(fac, aparams)}
    return {"params": aparams, "opt": opt,
            "step": jax.ShapeDtypeStruct((), jnp.int32)}


def train_state_pspecs(cfg, decls, rules):
    """PartitionSpec tree mirroring `abstract_train_state`."""
    from jax.sharding import PartitionSpec
    from ..nn.common import param_pspecs

    pspecs = param_pspecs(decls, rules)
    def is_spec(x):
        return isinstance(x, PartitionSpec)

    if cfg.optimizer == "adamw":
        opt = {"m": pspecs, "v": pspecs}
    else:
        def fac(s):
            entries = list(s)
            if len(entries) >= 2:
                return {"vr": PartitionSpec(*entries[:-1]),
                        "vc": PartitionSpec(*entries[:-2], entries[-1])}
            return {"v": s}

        opt = {"f": jax.tree_util.tree_map(fac, pspecs, is_leaf=is_spec)}
    return {"params": pspecs, "opt": opt, "step": PartitionSpec()}


def make_positions(batch) -> jax.Array:
    leaf = batch.get("tokens", batch.get("embeds"))
    b, s = leaf.shape[0], leaf.shape[1]
    return jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))


def make_train_step(cfg, hp: TrainHParams, mesh=None, rules=None):
    _, opt_update = make_optimizer(cfg.optimizer)

    def compute_loss(params, batch):
        ctx = ShardCtx(
            rules=rules, mesh=mesh, positions=make_positions(batch),
            compute_dtype=jnp.dtype(cfg.compute_dtype),
        )
        return loss_fn(params, batch, cfg, ctx)

    grad_fn = jax.value_and_grad(compute_loss, has_aux=True)

    def train_step(state, batch):
        params = state["params"]
        if hp.grad_accum > 1:
            def micro(carry, mb):
                (loss_a, metrics_a, grads_a) = carry
                (loss, metrics), grads = grad_fn(params, mb)
                grads_a = jax.tree_util.tree_map(jnp.add, grads_a, grads)
                metrics_a = jax.tree_util.tree_map(jnp.add, metrics_a, metrics)
                return (loss_a + loss, metrics_a, grads_a), None

            mbs = jax.tree_util.tree_map(
                lambda x: x.reshape((hp.grad_accum, x.shape[0] // hp.grad_accum)
                                    + x.shape[1:]),
                batch,
            )
            zeros_g = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            zeros_m = {"xent": 0.0, "zloss": 0.0, "aux": 0.0}
            (loss, metrics, grads), _ = jax.lax.scan(
                micro, (jnp.float32(0.0), zeros_m, zeros_g), mbs)
            inv = 1.0 / hp.grad_accum
            loss = loss * inv
            grads = jax.tree_util.tree_map(lambda g: g * inv, grads)
            metrics = jax.tree_util.tree_map(lambda m: m * inv, metrics)
        else:
            (loss, metrics), grads = grad_fn(params, batch)
        grads, gnorm = clip_by_global_norm(grads, hp.opt.grad_clip)
        new_params, new_opt = opt_update(
            grads, state["opt"], params, state["step"], hp.opt)
        metrics = dict(metrics, loss=loss, grad_norm=gnorm)
        return {"params": new_params, "opt": new_opt,
                "step": state["step"] + 1}, metrics

    return train_step
