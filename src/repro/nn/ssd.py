"""Mamba-2 mixer: State Space Duality (SSD), chunked algorithm.

Faithful to the paper's reference recurrence
    h_t = exp(dt_t·A) h_{t-1} + dt_t · B_t ⊗ x_t,   y_t = C_t·h_t + D·x_t
evaluated chunk-wise (quadratic within a Q-token chunk via the decay
matrix L, linear across chunks via a scanned state), which is the
arrangement that maps onto MXU matmuls.  Includes the depthwise causal
conv1d (width 4) over the xBC stream — a literal FIR filter bank, with an
optional BLMAC bit-layer evaluation path for quantized serving
(DESIGN.md §Arch-applicability).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import ParamDecl, ShardCtx, cast


def ssd_decls(cfg) -> dict:
    d_in = cfg.ssm_heads * cfg.ssm_head_dim
    n, g = cfg.ssm_state, 1  # single B/C group
    conv_ch = d_in + 2 * g * n
    return {
        "in_proj": ParamDecl(
            (cfg.d_model, 2 * d_in + 2 * g * n + cfg.ssm_heads), jnp.float32,
            ("d_model", "heads_flat"), "fan_in"),
        "conv_w": ParamDecl((cfg.conv_width, conv_ch), jnp.float32,
                            (None, "heads_flat"), "fan_in"),
        "conv_b": ParamDecl((conv_ch,), jnp.float32, ("heads_flat",), "zeros"),
        "a_log": ParamDecl((cfg.ssm_heads,), jnp.float32, ("heads",), "zeros"),
        "dt_bias": ParamDecl((cfg.ssm_heads,), jnp.float32, ("heads",), "zeros"),
        "d_skip": ParamDecl((cfg.ssm_heads,), jnp.float32, ("heads",), "ones"),
        "norm_scale": ParamDecl((d_in,), jnp.float32, ("heads_flat",), "ones"),
        "out_proj": ParamDecl((d_in, cfg.d_model), jnp.float32,
                              ("heads_flat", "d_model"), "fan_in"),
    }


def causal_conv1d(x, w, b, tail=None):
    """Depthwise causal conv.  x: (B, S, Ch), w: (W, Ch).  ``tail`` is the
    (B, W-1, Ch) history for decode continuity; zeros when None."""
    width = w.shape[0]
    if tail is None:
        tail = jnp.zeros((x.shape[0], width - 1, x.shape[-1]), x.dtype)
    xp = jnp.concatenate([tail, x], axis=1)
    y = sum(
        xp[:, i : i + x.shape[1]] * cast(w[i], x.dtype) for i in range(width)
    )
    return jax.nn.silu(y + cast(b, x.dtype)), xp[:, -(width - 1):]


def blmac_conv1d(x, trits, exponent, b, tail=None):
    """BLMAC bit-layer evaluation of the same conv: weights are CSD trit
    planes (L, W, Ch) in {-1,0,+1}; one masked add per plane·tap — no
    weight multiplies (serving path for quantized checkpoints)."""
    n_layers, width, ch = trits.shape
    if tail is None:
        tail = jnp.zeros((x.shape[0], width - 1, x.shape[-1]), x.dtype)
    xp = jnp.concatenate([tail, x], axis=1)
    s = x.shape[1]
    acc = jnp.zeros_like(x, dtype=jnp.float32)
    for layer in range(n_layers - 1, -1, -1):  # MSB → LSB (Eq. 2)
        acc = acc * 2.0
        for i in range(width):
            t = trits[layer, i]  # (Ch,) in {-1,0,1}
            contrib = jnp.where(t == 0, 0.0,
                                jnp.where(t > 0, 1.0, -1.0)) * xp[:, i : i + s].astype(jnp.float32)
            acc = acc + contrib
    y = acc * (2.0 ** float(-exponent)) + cast(b, jnp.float32)
    return jax.nn.silu(y).astype(x.dtype), xp[:, -(width - 1):]


def _split(p, x, cfg):
    d_in = cfg.ssm_heads * cfg.ssm_head_dim
    n = cfg.ssm_state
    zxbcdt = jnp.einsum("bsd,de->bse", x, cast(p["in_proj"], x.dtype))
    z = zxbcdt[..., :d_in]
    xbc = zxbcdt[..., d_in : 2 * d_in + 2 * n]
    dt = zxbcdt[..., 2 * d_in + 2 * n :]
    return z, xbc, dt


def _gated_norm(p, y, z, eps=1e-6):
    g = y * jax.nn.silu(z)
    gf = g.astype(jnp.float32)
    var = jnp.mean(gf * gf, axis=-1, keepdims=True)
    return (gf * jax.lax.rsqrt(var + eps) * p["norm_scale"]).astype(y.dtype)


def ssd_apply(p, x, ctx: ShardCtx, cfg, meta, chunk: int | None = None):
    """Full-sequence SSD.  Returns (y, cache|None) where cache carries the
    final SSM state and conv tail for decode continuation."""
    bsz, s, _ = x.shape
    if chunk is None:
        chunk = cfg.ssm_chunk
    h, pdim, n = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    d_in = h * pdim
    z, xbc, dt = _split(p, x, cfg)
    xbc, conv_tail = causal_conv1d(xbc, p["conv_w"], p["conv_b"])
    xs = xbc[..., :d_in].reshape(bsz, s, h, pdim)
    bmat = xbc[..., d_in : d_in + n][:, :, None, :]  # (B,S,1,N) group=1
    cmat = xbc[..., d_in + n :][:, :, None, :]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,S,H)
    a = -jnp.exp(p["a_log"])  # (H,)
    da = dt * a  # (B,S,H) ≤ 0

    q = min(chunk, s)
    while s % q:
        q -= 1
    nc = s // q
    causal = jnp.tril(jnp.ones((q, q), bool))[None, :, :, None]
    bmat2 = bmat[:, :, 0, :]
    cmat2 = cmat[:, :, 0, :]

    def chunk_body(state, i):
        # slice chunks IN PLACE (§Perf C3): scan-major xs (swapaxes) would
        # materialize a transposed copy of every activation per step
        def sl(a):
            return jax.lax.dynamic_slice_in_dim(a, i * q, q, axis=1)

        xc, dtc, dac, bc, cc = sl(xs), sl(dt), sl(da), sl(bmat2), sl(cmat2)
        cs = jnp.cumsum(dac, axis=1)  # (B,Q,H) f32, ≤ 0
        # intra-chunk: L[i,j] = exp(cs_i − cs_j) for i ≥ j
        li = cs[:, :, None, :] - cs[:, None, :, :]  # (B,Qi,Qj,H)
        decay = jnp.where(causal, jnp.exp(li), 0.0).astype(xc.dtype)
        cb = jnp.einsum("bin,bjn->bij", cc, bc)[..., None]
        w_ij = cb * decay * dtc.astype(xc.dtype)[:, None, :, :]
        y_diag = jnp.einsum("bijh,bjhp->bihp", w_ij, xc)
        # contribution of the state entering the chunk
        y_off = jnp.einsum("bqn,bqh,bhnp->bqhp",
                           cc, jnp.exp(cs).astype(xc.dtype), state)
        # chunk-final state
        decay_end = jnp.exp(cs[:, -1:, :] - cs)  # (B,Q,H)
        sb = jnp.einsum("bqh,bqn,bqhp->bhnp",
                        (dtc * decay_end).astype(xc.dtype), bc, xc)
        chunk_decay = jnp.exp(cs[:, -1, :]).astype(state.dtype)  # (B,H)
        new_state = state * chunk_decay[:, :, None, None] + sb
        return new_state, y_diag + y_off  # (B,Q,H,P)

    init = jnp.zeros((bsz, h, n, pdim), x.dtype)
    final_state, y_chunks = jax.lax.scan(chunk_body, init, jnp.arange(nc))
    y = y_chunks.swapaxes(0, 1).reshape(bsz, s, h, pdim)
    y = y + xs * p["d_skip"][None, None, :, None].astype(x.dtype)
    y = _gated_norm(p, y.reshape(bsz, s, d_in), z)
    out = jnp.einsum("bse,ed->bsd", y, cast(p["out_proj"], x.dtype))
    out = ctx.shard(out, ("batch", "seq", None))
    cache = None
    if ctx.make_cache:
        cache = {"state": final_state, "conv_tail": conv_tail}
    return out, cache


def ssd_decode(p, x, cache, ctx: ShardCtx, cfg, meta):
    """Single-step recurrence.  x: (B, 1, d)."""
    bsz = x.shape[0]
    h, pdim, n = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    d_in = h * pdim
    z, xbc, dt = _split(p, x, cfg)
    xbc, conv_tail = causal_conv1d(xbc, p["conv_w"], p["conv_b"],
                                   tail=cache["conv_tail"])
    xs = xbc[:, 0, :d_in].reshape(bsz, h, pdim)
    bvec = xbc[:, 0, d_in : d_in + n]
    cvec = xbc[:, 0, d_in + n :]
    dt = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])  # (B,H)
    a = -jnp.exp(p["a_log"])
    decay = jnp.exp(dt * a).astype(x.dtype)  # (B,H)
    state = cache["state"] * decay[:, :, None, None]
    state = state + jnp.einsum(
        "bh,bn,bhp->bhnp", dt.astype(x.dtype), bvec, xs
    )
    y = jnp.einsum("bn,bhnp->bhp", cvec, state)
    y = y + xs * p["d_skip"][None, :, None].astype(x.dtype)
    y = _gated_norm(p, y.reshape(bsz, 1, d_in), z)
    out = jnp.einsum("bse,ed->bsd", y, cast(p["out_proj"], x.dtype))
    return out, {"state": state, "conv_tail": conv_tail}
