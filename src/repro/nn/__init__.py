"""Pure-JAX model zoo with declaration-based params and staged scans."""
from .common import (
    ParamDecl,
    ShardCtx,
    abstract_params,
    count_params,
    init_params,
    param_pspecs,
)
from .model import decode_step, forward, loss_fn, model_decls, stage_plan

__all__ = [
    "ParamDecl", "ShardCtx", "abstract_params", "count_params",
    "init_params", "param_pspecs", "decode_step", "forward", "loss_fn",
    "model_decls", "stage_plan",
]
