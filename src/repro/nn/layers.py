"""Shared layers: norms, embeddings, position encodings, MLPs."""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from .common import ParamDecl, ShardCtx, cast

# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------


def norm_decls(d: int, kind: str) -> dict:
    if kind == "rmsnorm":
        return {"scale": ParamDecl((d,), jnp.float32, ("d_model",), "zeros")}
    if kind == "rmsnorm_unit":  # plain 1.0-centred scale
        return {"scale": ParamDecl((d,), jnp.float32, ("d_model",), "ones")}
    if kind == "layernorm":
        return {
            "scale": ParamDecl((d,), jnp.float32, ("d_model",), "ones"),
            "bias": ParamDecl((d,), jnp.float32, ("d_model",), "zeros"),
        }
    raise ValueError(kind)


def apply_norm(p: dict, x: jax.Array, kind: str, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    if kind in ("rmsnorm", "rmsnorm_unit"):
        var = jnp.mean(xf * xf, axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + eps)
        # gemma-style (1 + w) for "rmsnorm" (zero-init scale); unit for others
        w = p["scale"] + 1.0 if kind == "rmsnorm" else p["scale"]
        return (y * w).astype(x.dtype)
    mean = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mean) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"] + p["bias"]).astype(x.dtype)


# ---------------------------------------------------------------------------
# embeddings & unembedding
# ---------------------------------------------------------------------------


def embed_decls(vocab: int, d: int) -> dict:
    # fan-in (1/sqrt d) init keeps tied-head logits O(1); archs that feed
    # the table straight into the stack (gemma family) set embed_scale to
    # recover unit-variance activations.
    return {
        "table": ParamDecl((vocab, d), jnp.float32, ("vocab", "d_model"),
                           "fan_in", fan_axis=1)
    }


def embed_lookup(p: dict, tokens: jax.Array, ctx: ShardCtx,
                 scale_by_sqrt_d: bool = False) -> jax.Array:
    table = cast(p["table"], ctx.compute_dtype)
    x = table[tokens]  # gather; vocab-sharded tables gather fine under SPMD
    if scale_by_sqrt_d:
        x = x * math.sqrt(table.shape[-1])
    return ctx.shard(x, ("batch", "seq", None))


def unembed_decls(d: int, vocab: int) -> dict:
    return {
        "kernel": ParamDecl((d, vocab), jnp.float32, ("d_model", "vocab"),
                            "fan_in")
    }


def unembed(p: dict, x: jax.Array, ctx: ShardCtx,
            tied_table: jax.Array | None = None,
            softcap: float | None = None) -> jax.Array:
    if tied_table is not None:
        logits = jnp.einsum("bsd,vd->bsv", x, cast(tied_table, x.dtype))
    else:
        logits = jnp.einsum("bsd,dv->bsv", x, cast(p["kernel"], x.dtype))
    logits = logits.astype(jnp.float32)
    if softcap:
        logits = softcap * jnp.tanh(logits / softcap)
    return ctx.shard(logits, ("batch", "seq", "vocab"))


# ---------------------------------------------------------------------------
# rotary & sinusoidal position encodings
# ---------------------------------------------------------------------------


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, S, H, D) with D even; positions: (B, S) int32."""
    d = x.shape[-1]
    half = d // 2
    freq = jnp.exp(
        -math.log(theta) * jnp.arange(0, half, dtype=jnp.float32) / half
    )  # (half,)
    ang = positions[..., None].astype(jnp.float32) * freq  # (B, S, half)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


def sinusoidal(positions: jax.Array, d: int) -> jax.Array:
    """(B, S) → (B, S, d) classic transformer sinusoids."""
    half = d // 2
    freq = jnp.exp(
        -math.log(10000.0) * jnp.arange(half, dtype=jnp.float32) / half
    )
    ang = positions[..., None].astype(jnp.float32) * freq
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


def mlp_decls(d: int, ff: int, kind: str, bias: bool = False) -> dict:
    decls: dict[str, Any] = {}
    if kind in ("swiglu", "geglu"):
        decls["gate"] = ParamDecl((d, ff), jnp.float32, ("d_model", "ff"), "fan_in")
        decls["up"] = ParamDecl((d, ff), jnp.float32, ("d_model", "ff"), "fan_in")
    else:  # gelu
        decls["up"] = ParamDecl((d, ff), jnp.float32, ("d_model", "ff"), "fan_in")
        if bias:
            decls["up_b"] = ParamDecl((ff,), jnp.float32, ("ff",), "zeros")
    decls["down"] = ParamDecl((ff, d), jnp.float32, ("ff", "d_model"), "fan_in")
    if bias:
        decls["down_b"] = ParamDecl((d,), jnp.float32, ("d_model",), "zeros")
    return decls


def apply_mlp(p: dict, x: jax.Array, kind: str, ctx: ShardCtx) -> jax.Array:
    dt = x.dtype
    if kind in ("swiglu", "geglu"):
        g = jnp.einsum("bsd,df->bsf", x, cast(p["gate"], dt))
        u = jnp.einsum("bsd,df->bsf", x, cast(p["up"], dt))
        act = jax.nn.silu(g) if kind == "swiglu" else jax.nn.gelu(g)
        h = act * u
    else:
        h = jnp.einsum("bsd,df->bsf", x, cast(p["up"], dt))
        if "up_b" in p:
            h = h + cast(p["up_b"], dt)
        h = jax.nn.gelu(h)
    h = ctx.shard(h, ("batch", "seq", "ff"))
    y = jnp.einsum("bsf,fd->bsd", h, cast(p["down"], dt))
    if "down_b" in p:
        y = y + cast(p["down_b"], dt)
    return ctx.shard(y, ("batch", "seq", None))
