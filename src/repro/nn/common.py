"""Parameter-declaration system and sharding plumbing.

A model is declared once as a tree of :class:`ParamDecl` (shape, dtype,
logical axes, initializer).  From the declarations we derive, without
duplication:

  * ``init_params``      — materialized, initialized parameters,
  * ``abstract_params``  — ShapeDtypeStructs for ``jit(...).lower()``
                           (the multi-pod dry-run never allocates weights),
  * ``param_pspecs``     — PartitionSpecs via logical→mesh axis rules.

Logical axis vocabulary (mapped by `distributed/sharding.py` rules):
``batch seq d_model d_model2 vocab heads kv_heads head_dim ff experts
state layers`` — `None` for replicated dims.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec

Pytree = Any


@dataclasses.dataclass(frozen=True)
class ParamDecl:
    shape: tuple[int, ...]
    dtype: Any = jnp.float32
    axes: tuple[str | None, ...] = ()
    init: str = "fan_in"  # fan_in | normal | zeros | ones | embed
    scale: float = 1.0
    fan_axis: int = 0  # which axis is fan-in for "fan_in" init

    def __post_init__(self):
        if self.axes and len(self.axes) != len(self.shape):
            raise ValueError(f"axes {self.axes} vs shape {self.shape}")


def _leaves_with_path(tree: Pytree):
    return jax.tree_util.tree_flatten_with_path(
        tree, is_leaf=lambda x: isinstance(x, ParamDecl)
    )


def init_params(decls: Pytree, key: jax.Array, param_dtype=None) -> Pytree:
    """Materialize parameters from declarations (deterministic per path)."""
    flat, treedef = _leaves_with_path(decls)
    keys = jax.random.split(key, max(1, len(flat)))
    out = []
    for (path, d), k in zip(flat, keys):
        dtype = param_dtype or d.dtype
        if d.init == "zeros":
            v = jnp.zeros(d.shape, dtype)
        elif d.init == "ones":
            v = jnp.ones(d.shape, dtype)
        elif d.init == "normal":
            v = jax.random.normal(k, d.shape, dtype) * d.scale
        elif d.init == "embed":
            v = jax.random.normal(k, d.shape, dtype) * d.scale
        elif d.init == "fan_in":
            fan = d.shape[d.fan_axis] if d.shape else 1
            v = jax.random.normal(k, d.shape, dtype) * (d.scale / math.sqrt(fan))
        else:
            raise ValueError(f"unknown init {d.init!r}")
        out.append(v)
    return jax.tree_util.tree_unflatten(treedef, out)


def abstract_params(decls: Pytree, param_dtype=None) -> Pytree:
    """ShapeDtypeStructs — no allocation; feeds jit(...).lower()."""
    return jax.tree_util.tree_map(
        lambda d: jax.ShapeDtypeStruct(d.shape, param_dtype or d.dtype),
        decls,
        is_leaf=lambda x: isinstance(x, ParamDecl),
    )


def param_pspecs(decls: Pytree, rules: dict[str, Any]) -> Pytree:
    """PartitionSpec tree from the logical→mesh axis rules."""

    def spec(d: ParamDecl) -> PartitionSpec:
        axes = d.axes or (None,) * len(d.shape)
        return PartitionSpec(*(rules.get(a) if a else None for a in axes))

    return jax.tree_util.tree_map(
        spec, decls, is_leaf=lambda x: isinstance(x, ParamDecl)
    )


def count_params(decls: Pytree) -> int:
    flat, _ = _leaves_with_path(decls)
    return sum(math.prod(d.shape) for _, d in flat)


def count_active_params(decls: Pytree, experts_per_token: int = 0,
                        n_experts: int = 0) -> int:
    """Active parameters per token: expert-stacked weights (logical axis
    'experts') count at k/E — the MoE MODEL_FLOPS convention (6·N_active·D)."""
    flat, _ = _leaves_with_path(decls)
    total = 0.0
    for _, d in flat:
        n = math.prod(d.shape)
        if n_experts and d.axes and "experts" in d.axes:
            n = n * experts_per_token / n_experts
        total += n
    return int(total)


# ---------------------------------------------------------------------------
# activation sharding
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ShardCtx:
    """Threaded through every apply(); carries the activation-sharding rules
    and step context.  ``rules`` is None in unsharded (test) mode."""

    rules: dict[str, Any] | None = None
    mesh: Any = None  # jax.sharding.Mesh when sharded
    positions: jax.Array | None = None  # (B, S) int32 absolute positions
    deterministic: bool = True
    compute_dtype: Any = jnp.bfloat16
    make_cache: bool = False
    cache_len: int = 0

    def shard(self, x: jax.Array, axes: tuple[str | None, ...]) -> jax.Array:
        if self.rules is None or self.mesh is None:
            return x
        # a mesh axis may appear at most once per spec: first logical axis
        # wins (e.g. SP decode maps cache_seq to (data, model); kv_heads
        # must then stay replicated)
        used: set[str] = set()
        entries = []
        for a in axes:
            e = self.rules.get(a) if a else None
            names = e if isinstance(e, tuple) else (e,) if e else ()
            if any(n in used for n in names):
                e = None
                names = ()
            used.update(names)
            entries.append(e)
        sh = jax.sharding.NamedSharding(self.mesh, PartitionSpec(*entries))
        return jax.lax.with_sharding_constraint(x, sh)


def cast(x: jax.Array, dtype) -> jax.Array:
    return x.astype(dtype) if x.dtype != dtype else x
