"""The language model: embed → staged, scanned residual blocks → head.

Heterogeneous layer stacks (gemma2's local/global alternation, Griffin's
R-R-A pattern, DeepSeek's dense-then-MoE split) are grouped into *stages*:
maximal runs of a repeating layer unit.  Each stage's params are stacked
along a leading `layers` axis and the unit is `lax.scan`ned (optionally
rematerialized), so HLO size is O(#stages), not O(depth) — which is also
what keeps the 80-layer dry-run compiles tractable.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from .blocks import BlockMeta, block_apply, block_decode, block_decls
from .common import ParamDecl, ShardCtx
from .layers import (
    apply_norm,
    embed_decls,
    embed_lookup,
    norm_decls,
    sinusoidal,
    unembed,
    unembed_decls,
)


@dataclasses.dataclass(frozen=True)
class Stage:
    metas: tuple[BlockMeta, ...]
    repeat: int


def _layer_meta(cfg, idx: int) -> BlockMeta:
    mixer = cfg.block_pattern[idx % len(cfg.block_pattern)]
    if mixer == "attn" and cfg.attn_kind == "mla":
        mixer = "mla"
    window = 0
    if mixer in ("attn", "mla"):
        window = cfg.window_pattern[idx % len(cfg.window_pattern)]
    if cfg.ffn_pattern == "none":
        ffn = "none"
    elif cfg.n_experts and idx >= cfg.first_dense_layers:
        ffn = "moe"
    else:
        ffn = "mlp"
    return BlockMeta(mixer=mixer, window=window, ffn=ffn, d_ff=cfg.d_ff)


def stage_plan(cfg) -> tuple[Stage, ...]:
    metas = [_layer_meta(cfg, i) for i in range(cfg.n_layers)]
    stages: list[Stage] = []
    i = 0
    n = len(metas)
    while i < n:
        best_u, best_r = 1, 1
        for u in (1, 2, 3, 4, 6):
            if i + u > n:
                break
            r = 1
            while i + (r + 1) * u <= n and metas[i + r * u : i + (r + 1) * u] == metas[i : i + u]:
                r += 1
            if r >= 2 and u * r > best_u * best_r:
                best_u, best_r = u, r
        stages.append(Stage(tuple(metas[i : i + best_u]), best_r))
        i += best_u * best_r
    return tuple(stages)


def _stack_decl(d: ParamDecl, repeat: int) -> ParamDecl:
    axes = d.axes or (None,) * len(d.shape)
    return ParamDecl((repeat,) + d.shape, d.dtype, ("layers",) + tuple(axes),
                     d.init, d.scale, d.fan_axis + 1)


def model_decls(cfg) -> dict:
    decls: dict[str, Any] = {}
    if cfg.input_kind == "tokens":
        decls["embed"] = embed_decls(cfg.vocab_size, cfg.d_model)
    for si, st in enumerate(stage_plan(cfg)):
        unit = {f"slot{j}": block_decls(cfg, m) for j, m in enumerate(st.metas)}
        decls[f"stage{si}"] = jax.tree_util.tree_map(
            lambda d: _stack_decl(d, st.repeat), unit,
            is_leaf=lambda x: isinstance(x, ParamDecl),
        )
    decls["final_norm"] = norm_decls(cfg.d_model, cfg.norm)
    if not (cfg.tie_embeddings and cfg.input_kind == "tokens"):
        decls["lm_head"] = unembed_decls(cfg.d_model, cfg.vocab_size)
    return decls


def _embed_in(params, batch, cfg, ctx: ShardCtx):
    dt = jnp.dtype(cfg.compute_dtype)
    if cfg.input_kind == "embeds":
        x = batch.get("embeds", batch.get("embed")).astype(dt)
    else:
        tokens = batch.get("tokens", batch.get("token"))
        x = embed_lookup(params["embed"], tokens, ctx,
                         scale_by_sqrt_d=cfg.embed_scale)
        x = x.astype(dt)
    if cfg.pos_emb == "sinusoidal":
        x = x + sinusoidal(ctx.positions, cfg.d_model).astype(dt)
    return x


def _head(params, x, cfg, ctx: ShardCtx):
    x = apply_norm(params["final_norm"], x, cfg.norm)
    tied = params["embed"]["table"] if (
        cfg.tie_embeddings and cfg.input_kind == "tokens") else None
    return unembed(params.get("lm_head"), x, ctx, tied_table=tied,
                   softcap=cfg.logit_softcap or None)


def forward(params, batch, cfg, ctx: ShardCtx):
    """Full-sequence pass.  Returns (logits, aux_loss, caches|None)."""
    x = _embed_in(params, batch, cfg, ctx)
    plan = stage_plan(cfg)
    caches = [] if ctx.make_cache else None
    aux_total = jnp.float32(0.0)
    for si, st in enumerate(plan):
        sp = params[f"stage{si}"]

        def unit_fn(x, unit_params, _metas=st.metas):
            cs, aux = [], jnp.float32(0.0)
            for j, meta in enumerate(_metas):
                x, c, a = block_apply(unit_params[f"slot{j}"], x, ctx, cfg, meta)
                cs.append(c)
                aux = aux + a
            return x, tuple(cs), aux

        if cfg.scan_layers:
            def body(carry, unit_params, _fn=unit_fn):
                x, cs, aux = _fn(carry, unit_params)
                return x, (cs, aux)

            if cfg.remat == "full":
                body = jax.checkpoint(
                    body, policy=jax.checkpoint_policies.nothing_saveable
                )
            x, (cs_stack, aux_stack) = jax.lax.scan(body, x, sp)
            aux_total = aux_total + aux_stack.sum()
            if caches is not None:
                caches.append(cs_stack)
        else:
            for r in range(st.repeat):
                unit_params = jax.tree_util.tree_map(lambda a: a[r], sp)
                x, cs, aux = unit_fn(x, unit_params)
                aux_total = aux_total + aux
                if caches is not None:
                    caches.append(cs)
    logits = _head(params, x, cfg, ctx)
    return logits, aux_total, caches


def decode_step(params, batch, caches, ctx: ShardCtx, cfg):
    """One-token step against the cache.  Returns (logits, new_caches)."""
    x = _embed_in(params, batch, cfg, ctx)
    plan = stage_plan(cfg)
    new_caches = []
    for si, st in enumerate(plan):
        sp = params[f"stage{si}"]
        cache_si = caches[si]

        def unit_fn(x, unit_params, unit_cache, _metas=st.metas):
            new_cs = []
            for j, meta in enumerate(_metas):
                x, c = block_decode(unit_params[f"slot{j}"], x,
                                    unit_cache[j], ctx, cfg, meta)
                new_cs.append(c)
            return x, tuple(new_cs)

        if cfg.scan_layers:
            def body(carry, xs, _fn=unit_fn):
                unit_params, unit_cache = xs
                x, new_cs = _fn(carry, unit_params, unit_cache)
                return x, new_cs

            x, ncache = jax.lax.scan(body, x, (sp, cache_si))
            new_caches.append(ncache)
        else:
            ncs = []
            for r in range(st.repeat):
                unit_params = jax.tree_util.tree_map(lambda a: a[r], sp)
                x, cs = unit_fn(x, unit_params, cache_si[r])
                ncs.append(cs)
            new_caches.append(ncs)
    logits = _head(params, x, cfg, ctx)
    return logits, new_caches


def loss_fn(params, batch, cfg, ctx: ShardCtx):
    """Masked token cross-entropy (+ MoE aux, + z-loss)."""
    logits, aux, _ = forward(params, batch, cfg, ctx)
    labels = batch["labels"]
    mask = batch.get("mask")
    if mask is None:
        mask = jnp.ones(labels.shape, jnp.float32)
    logits = logits.astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    xent = (logz - ll) * mask
    denom = jnp.maximum(mask.sum(), 1.0)
    loss = xent.sum() / denom
    zloss = 1e-4 * ((logz * mask) ** 2).sum() / denom
    total = loss + zloss + cfg.aux_loss_coef * aux
    metrics = {"xent": loss, "zloss": zloss, "aux": aux}
    return total, metrics
