"""GQA/MQA attention with chunked online-softmax (flash-style) evaluation.

One code path serves every attention variant in the zoo: grouped KV heads,
RoPE, QKV bias (qwen), attention-logit softcap (gemma2), sliding windows
(mixtral / gemma2-local / recurrentgemma), and ring-buffer KV caches whose
masks are driven purely by *absolute positions* stored next to the cache —
so a rotated ring never needs un-rotation.

The chunked evaluation never materializes an (Sq × Skv) score matrix:
memory is O(Sq × kv_chunk) per head group, which is what lets the 32k
prefill and 500k decode cells compile at sane per-chip footprints.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp

from .common import ParamDecl, ShardCtx, cast
from .layers import rope

NEG = -1e30


@dataclasses.dataclass(frozen=True)
class AttnMeta:
    """Static per-instance attention settings (one per block-pattern slot)."""

    window: int = 0  # 0 = global causal; >0 = sliding window
    kv_chunk: int = 1024
    triangular: bool = True  # skip fully-masked kv chunks (train/prefill)


# ---------------------------------------------------------------------------
# functional chunked attention
# ---------------------------------------------------------------------------


def _mask(q_pos, kv_pos, window):
    """(B, Sq), (B, C) → (B, 1, 1, Sq, C) validity."""
    qp = q_pos[:, None, None, :, None]
    kp = kv_pos[:, None, None, None, :]
    ok = (kp <= qp) & (kp >= 0)
    if window > 0:
        ok &= qp - kp < window
    return ok


def _chunk_scores(q, k_c, scale, softcap, kv_layout="bshd"):
    # q: (B, Sq, Hkv, G, D) → scores (B, Hkv, G, Sq, C)
    eq = "bqhgd,bhcd->bhgqc" if kv_layout == "bhsd" else "bqhgd,bchd->bhgqc"
    s = jnp.einsum(eq, q, k_c).astype(jnp.float32) * scale
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    return s


def _combine(carry, qg, q_pos, kc, vc, pc, scale, softcap, window,
             kv_layout="bshd"):
    """Online-softmax merge of one kv chunk into the running (m, l, acc)."""
    m, l, acc = carry
    s = _chunk_scores(qg, kc, scale, softcap, kv_layout)  # (B,Hkv,G,Sq,C)
    ok = _mask(q_pos, pc, window)
    s = jnp.where(ok, s, NEG)
    m_new = jnp.maximum(m, s.max(axis=-1))
    alpha = jnp.exp(m - m_new)
    p = jnp.exp(s - m_new[..., None])
    p = jnp.where(ok, p, 0.0)
    l = l * alpha + p.sum(axis=-1)
    ev = "bhgqc,bhcv->bhgqv" if kv_layout == "bhsd" else "bhgqc,bchv->bhgqv"
    pv = jnp.einsum(ev, p.astype(vc.dtype), vc)
    acc = acc * alpha[..., None] + pv.astype(jnp.float32)
    return m_new, l, acc


def chunked_attention(
    q: jax.Array,  # (B, Sq, H, D)
    k: jax.Array,  # (B, Skv, Hkv, D)
    v: jax.Array,  # (B, Skv, Hkv, Dv)
    q_pos: jax.Array,  # (B, Sq)
    kv_pos: jax.Array,  # (B, Skv), -1 ⇒ invalid slot
    *,
    scale: float,
    window: int = 0,
    softcap: float | None = None,
    kv_chunk: int = 1024,
    triangular: bool = False,
    kv_layout: str = "bshd",  # decode caches use "bhsd" (no per-chunk
                              # transposes — §Perf iteration A4)
) -> jax.Array:
    b, sq, h, d = q.shape
    if kv_layout == "bhsd":
        _, hkv, skv, dv = v.shape
    else:
        _, skv, hkv, dv = v.shape
    g = h // hkv
    qg = q.reshape(b, sq, hkv, g, d)
    c = min(kv_chunk, skv)
    if skv % c:
        raise ValueError(f"Skv={skv} not a multiple of kv_chunk={c}")
    n_chunks = skv // c


    if triangular and sq == skv and n_chunks > 1:
        # Causal (optionally windowed) self-attention: process q in chunks
        # and give each q chunk only the kv chunks its mask can reach —
        # *statically*.  The compiled FLOPs drop ~2× for global causal and
        # ~S/window× for sliding-window layers; this is real work removed,
        # not masking (see EXPERIMENTS §Perf).
        out_chunks = []
        for qi in range(n_chunks):
            qc = qg[:, qi * c : (qi + 1) * c]
            qp = q_pos[:, qi * c : (qi + 1) * c]
            carry = (
                jnp.full((b, hkv, g, c), NEG, jnp.float32),
                jnp.zeros((b, hkv, g, c), jnp.float32),
                jnp.zeros((b, hkv, g, c, dv), jnp.float32),
            )
            for ki in range(qi + 1):
                if window > 0 and qi * c - ((ki + 1) * c - 1) >= window:
                    continue  # statically unreachable through the window
                kc = k[:, ki * c : (ki + 1) * c]
                vc = v[:, ki * c : (ki + 1) * c]
                pc = kv_pos[:, ki * c : (ki + 1) * c]
                carry = _combine(carry, qc, qp, kc, vc, pc,
                                 scale, softcap, window, kv_layout)
            m, l, acc = carry
            out_chunks.append(acc / jnp.maximum(l, 1e-30)[..., None])
        out = jnp.concatenate(out_chunks, axis=3)  # (B,Hkv,G,Sq,Dv)
    else:
        init = (
            jnp.full((b, hkv, g, sq), NEG, jnp.float32),
            jnp.zeros((b, hkv, g, sq), jnp.float32),
            jnp.zeros((b, hkv, g, sq, dv), jnp.float32),
        )

        # Read K/V chunks IN PLACE with dynamic_slice — reshaping the cache
        # into scan xs (swapaxes) materializes a transposed copy of the
        # whole cache every step (§Perf iteration A3: 4.2 TiB/step → GBs
        # on deepseek-coder-33b decode_32k).
        s_axis = 2 if kv_layout == "bhsd" else 1
        def body(carry, i):
            kc = jax.lax.dynamic_slice_in_dim(k, i * c, c, axis=s_axis)
            vc = jax.lax.dynamic_slice_in_dim(v, i * c, c, axis=s_axis)
            pc = jax.lax.dynamic_slice_in_dim(kv_pos, i * c, c, axis=1)
            return _combine(carry, qg, q_pos, kc, vc, pc,
                            scale, softcap, window, kv_layout), None

        (m, l, acc), _ = jax.lax.scan(body, init, jnp.arange(n_chunks))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 3, 1, 2, 4).reshape(b, sq, h, dv).astype(q.dtype)


# ---------------------------------------------------------------------------
# the attention mixer block
# ---------------------------------------------------------------------------


def attn_decls(cfg) -> dict:
    d, h, hkv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    decls: dict[str, Any] = {
        "wq": ParamDecl((d, h, dh), jnp.float32, ("d_model", "heads", "head_dim"), "fan_in"),
        "wk": ParamDecl((d, hkv, dh), jnp.float32, ("d_model", "kv_heads", "head_dim"), "fan_in"),
        "wv": ParamDecl((d, hkv, dh), jnp.float32, ("d_model", "kv_heads", "head_dim"), "fan_in"),
        "wo": ParamDecl((h, dh, d), jnp.float32, ("heads", "head_dim", "d_model"), "fan_in", fan_axis=1),
    }
    if cfg.attn_bias:
        decls["bq"] = ParamDecl((h, dh), jnp.float32, ("heads", "head_dim"), "zeros")
        decls["bk"] = ParamDecl((hkv, dh), jnp.float32, ("kv_heads", "head_dim"), "zeros")
        decls["bv"] = ParamDecl((hkv, dh), jnp.float32, ("kv_heads", "head_dim"), "zeros")
    return decls


def _qkv(p, x, cfg, positions):
    dt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, cast(p["wq"], dt))
    k = jnp.einsum("bsd,dhk->bshk", x, cast(p["wk"], dt))
    v = jnp.einsum("bsd,dhk->bshk", x, cast(p["wv"], dt))
    if "bq" in p:
        q = q + cast(p["bq"], dt)
        k = k + cast(p["bk"], dt)
        v = v + cast(p["bv"], dt)
    if cfg.pos_emb == "rope":
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    return q, k, v


def _scale(cfg) -> float:
    s = cfg.query_scale if cfg.query_scale else cfg.head_dim
    return 1.0 / math.sqrt(s)


def _maybe_repeat_kv(k, v, cfg, ctx: ShardCtx):
    """Under TP, grouped-query attention with few KV heads would force the
    partitioner to reshard around the (hkv, g) reshape every kv chunk — a
    collective storm.  Megatron-style practice: replicate KV heads up to
    the query head count so the `heads` axis shards uniformly end-to-end.
    Unsharded (test) mode keeps the memory-lean grouped form."""
    if ctx.rules is None or cfg.n_heads == cfg.n_kv_heads:
        return k, v
    g = cfg.n_heads // cfg.n_kv_heads
    return jnp.repeat(k, g, axis=2), jnp.repeat(v, g, axis=2)


def attn_apply(p, x, ctx: ShardCtx, cfg, meta: AttnMeta):
    """Full-sequence path (train & prefill).  Returns (y, cache | None)."""
    b, s, _ = x.shape
    pos = ctx.positions
    q, k, v = _qkv(p, x, cfg, pos)
    kr, vr = _maybe_repeat_kv(k, v, cfg, ctx)
    q = ctx.shard(q, ("batch", "seq", "heads", None))
    kr = ctx.shard(kr, ("batch", "seq", "heads", None))
    vr = ctx.shard(vr, ("batch", "seq", "heads", None))
    # adaptive chunk: cap the triangular unroll at ~16 chunks per side so
    # the HLO stays compact inside scanned layers
    kvc = min(meta.kv_chunk, s) if s <= meta.kv_chunk else max(meta.kv_chunk, s // 16)
    if s % kvc:
        kvc = s
    out = chunked_attention(
        q, kr, vr, pos, pos,
        scale=_scale(cfg), window=meta.window, softcap=cfg.attn_softcap,
        kv_chunk=kvc, triangular=meta.triangular,
    )
    y = jnp.einsum("bshk,hkd->bsd", out, cast(p["wo"], x.dtype))
    y = ctx.shard(y, ("batch", "seq", None))
    cache = None
    if ctx.make_cache:
        cache = build_kv_cache(k, v, pos, ctx.cache_len, meta.window)
    return y, cache


def cache_size(cache_len: int, window: int) -> int:
    return min(cache_len, window) if window > 0 else cache_len


def build_kv_cache(k, v, pos, cache_len: int, window: int) -> dict:
    """Build a (ring) cache from prefilled K/V (rope already applied).

    Layout is (B, Hkv, W, Dh) — decode-optimized: the attention einsums
    read it without per-chunk transposes (§Perf A4); the one transpose
    here is amortized over the whole generation."""
    b, s, hkv, dh = k.shape
    w = cache_size(cache_len, window)
    ck = jnp.zeros((b, hkv, w, dh), k.dtype)
    cv = jnp.zeros((b, hkv, w, v.shape[-1]), v.dtype)
    cp = jnp.full((b, w), -1, jnp.int32)
    take = min(s, w)
    ks = k[:, s - take :].swapaxes(1, 2)  # (B, Hkv, take, Dh)
    vs = v[:, s - take :].swapaxes(1, 2)
    ps = pos[:, s - take :]
    slots = ps % w  # unique because positions are consecutive, take <= w
    bidx = jnp.arange(b)[:, None, None]
    hidx = jnp.arange(hkv)[None, :, None]
    ck = ck.at[bidx, hidx, slots[:, None, :]].set(ks)
    cv = cv.at[bidx, hidx, slots[:, None, :]].set(vs)
    cp = cp.at[jnp.arange(b)[:, None], ps % w].set(ps)
    return {"k": ck, "v": cv, "pos": cp}


def attn_decode(p, x, cache: dict, ctx: ShardCtx, cfg, meta: AttnMeta):
    """Single-token decode: x (B, 1, d); cache slots addressed pos % W."""
    b = x.shape[0]
    pos = ctx.positions  # (B, 1) current absolute position
    q, k, v = _qkv(p, x, cfg, pos)
    hkv = cache["k"].shape[1]
    w = cache["k"].shape[2]
    slot = (pos[:, 0] % w).astype(jnp.int32)
    bidx = jnp.arange(b)[:, None]
    hidx = jnp.arange(hkv)[None, :]
    ck = cache["k"].at[bidx, hidx, slot[:, None]].set(k[:, 0])
    cv = cache["v"].at[bidx, hidx, slot[:, None]].set(v[:, 0])
    cp = cache["pos"].at[jnp.arange(b), slot].set(pos[:, 0])
    ck = ctx.shard(ck, ("batch", "kv_heads", "cache_seq", None))
    cv = ctx.shard(cv, ("batch", "kv_heads", "cache_seq", None))
    kvc = min(meta.kv_chunk, w) if w <= meta.kv_chunk else max(meta.kv_chunk, w // 64)
    if w % kvc:
        kvc = w
    out = chunked_attention(
        q, ck, cv, pos, cp,
        scale=_scale(cfg), window=meta.window, softcap=cfg.attn_softcap,
        kv_chunk=kvc, triangular=False, kv_layout="bhsd",
    )
    y = jnp.einsum("bshk,hkd->bsd", out, cast(p["wo"], x.dtype))
    return y, {"k": ck, "v": cv, "pos": cp}
