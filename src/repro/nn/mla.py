"""Multi-head Latent Attention (DeepSeek-V2/V3).

Train/prefill: queries go through a low-rank bottleneck (q_lora), K/V are
generated from a shared compressed latent c_kv (kv_lora_rank) plus a
decoupled shared RoPE key.  Decode: the *latent* is cached (kv_lora +
qk_rope_dim per token — 9× smaller than full GQA KV) and the up-projections
are **absorbed** into the query/output paths, so attention runs directly
against the latent cache:

    score(t, s) = q_nopeᵀ·(W_uk c_s) + q_ropeᵀ·k_rope_s
                = (W_ukᵀ q_nope)ᵀ·c_s + q_ropeᵀ·k_rope_s
    out_h       = W_uv Σ_s a_s c_s

The latent cache carries absolute positions; for long contexts it is
sequence-sharded and XLA inserts the partial-softmax all-reduces
(flash-decoding on the compiler side — see EXPERIMENTS §Roofline).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .common import ParamDecl, ShardCtx, cast
from .layers import apply_norm, norm_decls, rope

NEG = -1e30


def mla_decls(cfg) -> dict:
    d = cfg.d_model
    h = cfg.n_heads
    qr, kr = cfg.q_lora_rank, cfg.kv_lora_rank
    dn, dr, dvh = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    return {
        "wq_a": ParamDecl((d, qr), jnp.float32, ("d_model", None), "fan_in"),
        "q_norm": norm_decls(qr, "rmsnorm_unit"),
        "wq_b": ParamDecl((qr, h, dn + dr), jnp.float32, (None, "heads", "head_dim"), "fan_in"),
        "wkv_a": ParamDecl((d, kr + dr), jnp.float32, ("d_model", None), "fan_in"),
        "kv_norm": norm_decls(kr, "rmsnorm_unit"),
        "wk_b": ParamDecl((kr, h, dn), jnp.float32, (None, "heads", "head_dim"), "fan_in"),
        "wv_b": ParamDecl((kr, h, dvh), jnp.float32, (None, "heads", "head_dim"), "fan_in"),
        "wo": ParamDecl((h, dvh, d), jnp.float32, ("heads", "head_dim", "d_model"), "fan_in", fan_axis=1),
    }


def _latent(p, x, cfg, positions):
    """x → (c_kv normed, k_rope rotated, q_nope, q_rope)."""
    dt = x.dtype
    kr, dr = cfg.kv_lora_rank, cfg.qk_rope_dim
    qa = jnp.einsum("bsd,dr->bsr", x, cast(p["wq_a"], dt))
    qa = apply_norm(p["q_norm"], qa, "rmsnorm_unit")
    q = jnp.einsum("bsr,rhk->bshk", qa, cast(p["wq_b"], dt))
    q_nope, q_rope = q[..., : cfg.qk_nope_dim], q[..., cfg.qk_nope_dim :]
    q_rope = rope(q_rope, positions, cfg.rope_theta)
    kv = jnp.einsum("bsd,dr->bsr", x, cast(p["wkv_a"], dt))
    c_kv, k_rope = kv[..., :kr], kv[..., kr:]
    c_kv = apply_norm(p["kv_norm"], c_kv, "rmsnorm_unit")
    k_rope = rope(k_rope[:, :, None, :], positions, cfg.rope_theta)[:, :, 0, :]
    return c_kv, k_rope, q_nope, q_rope


def _scale(cfg) -> float:
    return 1.0 / math.sqrt(cfg.qk_nope_dim + cfg.qk_rope_dim)


def mla_apply(p, x, ctx: ShardCtx, cfg, meta):
    """Full-sequence path: expand K/V per head (standard formulation)."""
    b, s, _ = x.shape
    pos = ctx.positions
    c_kv, k_rope, q_nope, q_rope = _latent(p, x, cfg, pos)
    k_nope = jnp.einsum("bsr,rhk->bshk", c_kv, cast(p["wk_b"], x.dtype))
    v = jnp.einsum("bsr,rhk->bshk", c_kv, cast(p["wv_b"], x.dtype))
    q = jnp.concatenate([q_nope, q_rope], -1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                  (b, s, cfg.n_heads, cfg.qk_rope_dim))], -1
    )
    q = ctx.shard(q, ("batch", "seq", "heads", None))
    k = ctx.shard(k, ("batch", "seq", "heads", None))
    v = ctx.shard(v, ("batch", "seq", "heads", None))
    from .attention import chunked_attention

    kvc = min(1024, s) if s <= 1024 else max(1024, s // 16)
    if s % kvc:
        kvc = s
    out = chunked_attention(
        q, k, v, pos, pos, scale=_scale(cfg), window=0, softcap=None,
        kv_chunk=kvc, triangular=True,
    )
    y = jnp.einsum("bshk,hkd->bsd", out, cast(p["wo"], x.dtype))
    y = ctx.shard(y, ("batch", "seq", None))
    cache = None
    if ctx.make_cache:
        pad = ctx.cache_len - s
        cache = {
            "c_kv": jnp.pad(c_kv, ((0, 0), (0, pad), (0, 0))),
            "k_rope": jnp.pad(k_rope, ((0, 0), (0, pad), (0, 0))),
            "pos": jnp.pad(pos, ((0, 0), (0, pad)), constant_values=-1),
        }
    return y, cache


def mla_decode(p, x, cache, ctx: ShardCtx, cfg, meta):
    """Absorbed decode against the latent cache.  x: (B, 1, d)."""
    b = x.shape[0]
    pos = ctx.positions  # (B, 1)
    dt = x.dtype
    c_new, kr_new, q_nope, q_rope = _latent(p, x, cfg, pos)
    slot = pos[:, 0]
    bidx = jnp.arange(b)
    c = cache["c_kv"].at[bidx, slot].set(c_new[:, 0])
    krope = cache["k_rope"].at[bidx, slot].set(kr_new[:, 0])
    cpos = cache["pos"].at[bidx, slot].set(pos[:, 0])
    c = ctx.shard(c, ("batch", "cache_seq", None))
    krope = ctx.shard(krope, ("batch", "cache_seq", None))
    # absorb W_uk into q:  (B,1,H,dn) × (kr,H,dn) → (B,H,kr); fp32
    # accumulation keeps the absorbed path within ~1e-2 of the expanded one
    q_abs = jnp.einsum("bohk,rhk->bhr", q_nope, cast(p["wk_b"], dt),
                       preferred_element_type=jnp.float32)
    s_lat = jnp.einsum("bhr,bsr->bhs", q_abs, c.astype(jnp.float32))
    s_rope = jnp.einsum("bohk,bsk->bhs", q_rope, krope,
                        preferred_element_type=jnp.float32)
    s = (s_lat + s_rope) * _scale(cfg)
    valid = (cpos[:, None, :] <= pos[:, :1][:, None, :]) & (cpos[:, None, :] >= 0)
    s = jnp.where(valid, s, NEG)
    a = jax.nn.softmax(s, axis=-1).astype(dt)  # (B,H,S)
    out_lat = jnp.einsum("bhs,bsr->bhr", a, c)  # (B,H,kr)
    out = jnp.einsum("bhr,rhk->bhk", out_lat, cast(p["wv_b"], dt))
    y = jnp.einsum("bhk,hkd->bd", out, cast(p["wo"], dt))[:, None, :]
    return y, {"c_kv": c, "k_rope": krope, "pos": cpos}
