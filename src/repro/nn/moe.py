"""Mixture-of-Experts FFN: top-k routing, group-local capacity dispatch.

Dispatch is GShard-style but sort-free and *group-local*: tokens are split
into G groups aligned with the data shards, each group scatters into its
own (E, C_g, d) buffer — so the scatter itself needs no cross-device
traffic; the cross-device all-to-all appears where it belongs, in the
expert einsum whose expert axis is sharded over `model` (EP).  Capacity
overflow drops (counted in aux stats); router styles: `softmax` (Mixtral)
and `sigmoid_norm` (DeepSeek-V3).

Shared experts (DeepSeek) are a plain dense MLP added to the routed path.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from .common import ParamDecl, ShardCtx, cast
from .layers import apply_mlp, mlp_decls


def moe_decls(cfg) -> dict:
    d, e, ffe = cfg.d_model, cfg.n_experts, cfg.moe_d_ff
    decls: dict[str, Any] = {
        "router": ParamDecl((d, e), jnp.float32, ("d_model", None), "fan_in"),
        "gate": ParamDecl((e, d, ffe), jnp.float32,
                          ("experts", "d_model", "expert_ff"), "fan_in", fan_axis=1),
        "up": ParamDecl((e, d, ffe), jnp.float32,
                        ("experts", "d_model", "expert_ff"), "fan_in", fan_axis=1),
        "down": ParamDecl((e, ffe, d), jnp.float32,
                          ("experts", "expert_ff", "d_model"), "fan_in", fan_axis=1),
    }
    if cfg.n_shared_experts:
        decls["shared"] = mlp_decls(
            d, cfg.moe_d_ff * cfg.n_shared_experts, "swiglu"
        )
    return decls


def _positions_in_expert(e_idx: jax.Array, n_experts: int) -> jax.Array:
    """Rank of each routed slot within its expert (stable, sort-based).

    ``e_idx``: (M,) expert ids.  Returns (M,) positions 0..count_e-1.
    """
    m = e_idx.shape[0]
    order = jnp.argsort(e_idx, stable=True)
    sorted_e = e_idx[order]
    counts = jnp.bincount(sorted_e, length=n_experts)
    starts = jnp.cumsum(counts) - counts
    rank_sorted = jnp.arange(m) - starts[sorted_e]
    return jnp.zeros((m,), jnp.int32).at[order].set(rank_sorted.astype(jnp.int32))


def moe_apply(p, x: jax.Array, ctx: ShardCtx, cfg):
    """x: (B, S, d) → (y, aux_loss).  Groups = cfg.moe_groups (align with
    the number of data shards so dispatch stays shard-local)."""
    b, s, d = x.shape
    t = b * s
    g = max(1, min(cfg.moe_groups, t))
    while t % g:
        g -= 1
    tg = t // g
    e, k = cfg.n_experts, cfg.experts_per_token
    cap = max(k, int(cfg.capacity_factor * tg * k / e))
    xt = x.reshape(g, tg, d)
    xt = ctx.shard(xt, ("batch", None, None))

    logits = jnp.einsum("gtd,de->gte", xt, cast(p["router"], x.dtype))
    logits = logits.astype(jnp.float32)
    if cfg.router == "sigmoid_norm":
        scores = jax.nn.sigmoid(logits)
        w, idx = jax.lax.top_k(scores, k)
        w = w / (w.sum(-1, keepdims=True) + 1e-9)
        probs = scores / (scores.sum(-1, keepdims=True) + 1e-9)
    else:
        probs = jax.nn.softmax(logits, axis=-1)
        w, idx = jax.lax.top_k(probs, k)
        w = w / (w.sum(-1, keepdims=True) + 1e-9)

    def dispatch_group(xg, idxg, wg):
        # xg: (Tg, d), idxg/wg: (Tg, k)
        e_flat = idxg.reshape(-1)  # (Tg*k,)
        pos = _positions_in_expert(e_flat, e)
        keep = pos < cap
        p_idx = jnp.where(keep, pos, cap)  # OOB ⇒ dropped by scatter mode
        x_rep = jnp.repeat(xg, k, axis=0)  # (Tg*k, d)
        buf = jnp.zeros((e, cap, d), xg.dtype)
        buf = buf.at[e_flat, p_idx].add(
            x_rep * keep[:, None].astype(xg.dtype), mode="drop"
        )
        return buf, (e_flat, jnp.minimum(p_idx, cap - 1), keep)

    buf, addr = jax.vmap(dispatch_group)(xt, idx, w)  # buf: (G, E, C, d)
    buf = ctx.shard(buf, ("batch", "experts", None, None))
    h_g = jnp.einsum("gecd,edf->gecf", buf, cast(p["gate"], x.dtype))
    h_u = jnp.einsum("gecd,edf->gecf", buf, cast(p["up"], x.dtype))
    h = jax.nn.silu(h_g) * h_u
    h = ctx.shard(h, ("batch", "experts", None, None))
    yb = jnp.einsum("gecf,efd->gecd", h, cast(p["down"], x.dtype))
    yb = ctx.shard(yb, ("batch", "experts", None, None))

    def gather_group(ybg, addrg, wg):
        e_flat, p_idx, keep = addrg
        y_sel = ybg[e_flat, p_idx] * keep[:, None].astype(ybg.dtype)
        y_sel = y_sel.reshape(-1, k, d) * wg[..., None].astype(ybg.dtype)
        return y_sel.sum(axis=1)

    y = jax.vmap(gather_group)(yb, addr, w).reshape(b, s, d)

    # load-balance aux (switch-style) + drop fraction for monitoring
    me = probs.mean(axis=(0, 1))  # (E,)
    ce = jnp.zeros((e,), jnp.float32).at[idx.reshape(-1)].add(1.0) / (t * k)
    aux = e * jnp.sum(me * ce)
    if cfg.n_shared_experts:
        y = y + apply_mlp(p["shared"], x, "swiglu", ctx)
    return ctx.shard(y, ("batch", "seq", None)), aux
