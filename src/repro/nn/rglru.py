"""RG-LRU recurrent block (Griffin / RecurrentGemma).

    r_t = σ(W_a x_t + b_a)          recurrence gate
    i_t = σ(W_x x_t + b_x)          input gate
    a_t = exp(−c · softplus(Λ) · r_t),   c = 8
    h_t = a_t h_{t−1} + sqrt(1 − a_t²) · (i_t ⊙ x_t)

evaluated with an associative scan over the sequence (log-depth, shardable)
— plus the surrounding temporal block: linear → causal conv1d(4) → RG-LRU,
gated by a GeLU branch, as in the Griffin recurrent block.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import ParamDecl, ShardCtx, cast
from .ssd import causal_conv1d

_C = 8.0


def rglru_decls(cfg) -> dict:
    d, dr = cfg.d_model, cfg.rglru_width
    return {
        "gate_proj": ParamDecl((d, dr), jnp.float32, ("d_model", "ff"), "fan_in"),
        "rec_proj": ParamDecl((d, dr), jnp.float32, ("d_model", "ff"), "fan_in"),
        "conv_w": ParamDecl((cfg.conv_width, dr), jnp.float32, (None, "ff"), "fan_in"),
        "conv_b": ParamDecl((dr,), jnp.float32, ("ff",), "zeros"),
        "w_a": ParamDecl((dr, dr), jnp.float32, ("ff", None), "fan_in"),
        "b_a": ParamDecl((dr,), jnp.float32, (None,), "zeros"),
        "w_x": ParamDecl((dr, dr), jnp.float32, ("ff", None), "fan_in"),
        "b_x": ParamDecl((dr,), jnp.float32, (None,), "zeros"),
        "lambda_p": ParamDecl((dr,), jnp.float32, (None,), "ones"),
        "out_proj": ParamDecl((dr, d), jnp.float32, ("ff", "d_model"), "fan_in"),
    }


def _gates(p, x):
    """x: (..., dr) → (a, gated_in) in f32."""
    r = jax.nn.sigmoid(jnp.einsum("...d,de->...e", x, p["w_a"].astype(x.dtype))
                       .astype(jnp.float32) + p["b_a"])
    i = jax.nn.sigmoid(jnp.einsum("...d,de->...e", x, p["w_x"].astype(x.dtype))
                       .astype(jnp.float32) + p["b_x"])
    log_a = -_C * jax.nn.softplus(p["lambda_p"]) * r
    a = jnp.exp(log_a)
    mult = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12))
    return a, mult * i * x.astype(jnp.float32)


def rglru_apply(p, x, ctx: ShardCtx, cfg, meta):
    """x: (B, S, d) → (y, cache|None)."""
    gate = jax.nn.gelu(
        jnp.einsum("bsd,de->bse", x, cast(p["gate_proj"], x.dtype))
    )
    u = jnp.einsum("bsd,de->bse", x, cast(p["rec_proj"], x.dtype))
    u = ctx.shard(u, ("batch", "seq", "ff"))
    u, conv_tail = causal_conv1d(u, p["conv_w"], p["conv_b"])
    a, b = _gates(p, u)

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, ar * bl + br

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    h = h.astype(x.dtype)
    y = jnp.einsum("bse,ed->bsd", h * gate, cast(p["out_proj"], x.dtype))
    y = ctx.shard(y, ("batch", "seq", None))
    cache = None
    if ctx.make_cache:
        cache = {"h": h[:, -1].astype(jnp.float32), "conv_tail": conv_tail}
    return y, cache


def rglru_decode(p, x, cache, ctx: ShardCtx, cfg, meta):
    """Single step: x (B, 1, d)."""
    gate = jax.nn.gelu(
        jnp.einsum("bsd,de->bse", x, cast(p["gate_proj"], x.dtype))
    )
    u = jnp.einsum("bsd,de->bse", x, cast(p["rec_proj"], x.dtype))
    u, conv_tail = causal_conv1d(u, p["conv_w"], p["conv_b"],
                                 tail=cache["conv_tail"])
    a, b = _gates(p, u)  # (B,1,dr)
    h = a[:, 0] * cache["h"] + b[:, 0]
    y = jnp.einsum("bse,ed->bsd",
                   h[:, None].astype(x.dtype) * gate,
                   cast(p["out_proj"], x.dtype))
    return y, {"h": h, "conv_tail": conv_tail}
