"""Residual blocks: (norm → mixer → residual) (+ norm → FFN → residual).

A `BlockMeta` fixes the *static* identity of one slot in a stage's layer
pattern (mixer kind, attention window, FFN kind); params for that slot are
stacked across the stage's repeats and scanned.
"""
from __future__ import annotations

import dataclasses
from typing import Any

from . import attention, mla, moe, rglru, ssd
from .attention import AttnMeta
from .common import ShardCtx
from .layers import apply_mlp, apply_norm, mlp_decls, norm_decls


@dataclasses.dataclass(frozen=True)
class BlockMeta:
    mixer: str  # attn | mla | ssd | rglru
    window: int = 0
    ffn: str = "mlp"  # mlp | moe | none
    d_ff: int = 0  # dense ffn width for this slot (moe uses cfg.moe_d_ff)


def block_decls(cfg, meta: BlockMeta) -> dict:
    d = cfg.d_model
    decls: dict[str, Any] = {"norm1": norm_decls(d, cfg.norm)}
    if meta.mixer == "attn":
        decls["mixer"] = attention.attn_decls(cfg)
    elif meta.mixer == "mla":
        decls["mixer"] = mla.mla_decls(cfg)
    elif meta.mixer == "ssd":
        decls["mixer"] = ssd.ssd_decls(cfg)
    elif meta.mixer == "rglru":
        decls["mixer"] = rglru.rglru_decls(cfg)
    else:
        raise ValueError(meta.mixer)
    if cfg.post_norm:
        decls["post_norm1"] = norm_decls(d, cfg.norm)
    if meta.ffn != "none":
        decls["norm2"] = norm_decls(d, cfg.norm)
        if meta.ffn == "moe":
            decls["ffn"] = moe.moe_decls(cfg)
        else:
            decls["ffn"] = mlp_decls(d, meta.d_ff or cfg.d_ff, cfg.mlp,
                                     cfg.mlp_bias)
        if cfg.post_norm:
            decls["post_norm2"] = norm_decls(d, cfg.norm)
    return decls


def _attn_meta(cfg, meta: BlockMeta) -> AttnMeta:
    return AttnMeta(window=meta.window)


def block_apply(p, x, ctx: ShardCtx, cfg, meta: BlockMeta):
    """Full-sequence (train/prefill).  Returns (x, cache, aux)."""
    h = apply_norm(p["norm1"], x, cfg.norm)
    if meta.mixer == "attn":
        y, cache = attention.attn_apply(p["mixer"], h, ctx, cfg, _attn_meta(cfg, meta))
    elif meta.mixer == "mla":
        y, cache = mla.mla_apply(p["mixer"], h, ctx, cfg, meta)
    elif meta.mixer == "ssd":
        y, cache = ssd.ssd_apply(p["mixer"], h, ctx, cfg, meta)
    else:
        y, cache = rglru.rglru_apply(p["mixer"], h, ctx, cfg, meta)
    if cfg.post_norm:
        y = apply_norm(p["post_norm1"], y, cfg.norm)
    x = x + y
    aux = 0.0
    if meta.ffn != "none":
        h = apply_norm(p["norm2"], x, cfg.norm)
        if meta.ffn == "moe":
            y, aux = moe.moe_apply(p["ffn"], h, ctx, cfg)
        else:
            y = apply_mlp(p["ffn"], h, cfg.mlp, ctx)
        if cfg.post_norm:
            y = apply_norm(p["post_norm2"], y, cfg.norm)
        x = x + y
    return x, cache, aux


def block_decode(p, x, cache, ctx: ShardCtx, cfg, meta: BlockMeta):
    """Single-token decode.  Returns (x, new_cache)."""
    h = apply_norm(p["norm1"], x, cfg.norm)
    if meta.mixer == "attn":
        y, cache = attention.attn_decode(p["mixer"], h, cache, ctx, cfg,
                                         _attn_meta(cfg, meta))
    elif meta.mixer == "mla":
        y, cache = mla.mla_decode(p["mixer"], h, cache, ctx, cfg, meta)
    elif meta.mixer == "ssd":
        y, cache = ssd.ssd_decode(p["mixer"], h, cache, ctx, cfg, meta)
    else:
        y, cache = rglru.rglru_decode(p["mixer"], h, cache, ctx, cfg, meta)
    if cfg.post_norm:
        y = apply_norm(p["post_norm1"], y, cfg.norm)
    x = x + y
    if meta.ffn != "none":
        h = apply_norm(p["norm2"], x, cfg.norm)
        if meta.ffn == "moe":
            y, _ = moe.moe_apply(p["ffn"], h, ctx, cfg)
        else:
            y = apply_mlp(p["ffn"], h, cfg.mlp, ctx)
        if cfg.post_norm:
            y = apply_norm(p["post_norm2"], y, cfg.norm)
        x = x + y
    return x, cache
