"""Sharded, atomic, keep-k checkpointing with elastic re-mesh restore.

Layout (one directory per step):

    <root>/step_000042.tmp/...      (written, fsynced)
    <root>/step_000042/             (atomic rename = commit)
        manifest.json               {step, leaf paths, shapes, dtypes}
        <leaf>.npy                  (gathered layout), or
        <leaf>.shard<k>.npy         (per-addressable-shard layout)

Restore can target a *different* mesh/sharding than the save (elastic
scaling): leaves are assembled on host and re-placed with `jax.device_put`
under the new sharding — `tests/test_checkpoint.py` exercises 8→4→8
device re-meshes and bit-exact resume.
"""
from __future__ import annotations

import json
import os
import re
import shutil
from typing import Any

import jax
import numpy as np

from ..core.io import fsync_dir, fsync_file

_STEP_RE = re.compile(r"^step_(\d{9})$")


def _path_str(path) -> str:
    out = []
    for p in path:
        if isinstance(p, jax.tree_util.DictKey):
            out.append(str(p.key))
        elif isinstance(p, jax.tree_util.SequenceKey):
            out.append(str(p.idx))
        else:
            out.append(str(p))
    return "__".join(out)


def save_checkpoint(root: str, step: int, tree: Any, keep: int = 3,
                    sharded: bool = False) -> str:
    """Write atomically; returns the committed directory."""
    os.makedirs(root, exist_ok=True)
    name = f"step_{step:09d}"
    tmp = os.path.join(root, name + ".tmp")
    final = os.path.join(root, name)
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    manifest = {"step": step, "sharded": sharded, "leaves": {}}
    for path, leaf in flat:
        key = _path_str(path)
        arr_meta: dict[str, Any] = {}
        if sharded and isinstance(leaf, jax.Array) and len(leaf.sharding.device_set) > 1:
            idx = 0
            for sh in leaf.addressable_shards:
                np.save(os.path.join(tmp, f"{key}.shard{idx}.npy"),
                        np.asarray(sh.data))
                arr_meta.setdefault("shards", []).append(
                    {"i": idx, "index": _index_to_json(sh.index)})
                idx += 1
            arr_meta["shape"] = list(leaf.shape)
            arr_meta["dtype"] = str(leaf.dtype)
        else:
            np.save(os.path.join(tmp, f"{key}.npy"),
                    np.asarray(jax.device_get(leaf)))
        manifest["leaves"][key] = arr_meta
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        fsync_file(f)
    os.rename(tmp, final)  # atomic commit
    fsync_dir(root)
    _gc(root, keep)
    return final


def _index_to_json(index) -> list:
    return [[s.start, s.stop] for s in index]


def _gc(root: str, keep: int) -> None:
    steps = sorted(all_steps(root))
    for s in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(root, f"step_{s:09d}"), ignore_errors=True)


def all_steps(root: str) -> list[int]:
    if not os.path.isdir(root):
        return []
    out = []
    for n in os.listdir(root):
        m = _STEP_RE.match(n)
        if m and os.path.exists(os.path.join(root, n, "manifest.json")):
            out.append(int(m.group(1)))
    return sorted(out)


def latest_step(root: str) -> int | None:
    steps = all_steps(root)
    return steps[-1] if steps else None


def restore_checkpoint(root: str, tree_like: Any, step: int | None = None,
                       shardings: Any = None) -> tuple[Any, int]:
    """Restore into the structure of `tree_like`; optionally re-place under
    `shardings` (same treedef) — this is the elastic re-mesh path."""
    if step is None:
        step = latest_step(root)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {root}")
    d = os.path.join(root, f"step_{step:09d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree_like)
    sflat = (jax.tree_util.tree_leaves(shardings) if shardings is not None
             else [None] * len(flat))
    leaves = []
    for (path, like), sh in zip(flat, sflat):
        key = _path_str(path)
        meta = manifest["leaves"][key]
        if meta.get("shards"):
            arr = np.zeros(meta["shape"], dtype=meta["dtype"])
            for shard in meta["shards"]:
                piece = np.load(os.path.join(d, f"{key}.shard{shard['i']}.npy"))
                idx = tuple(slice(a, b) for a, b in shard["index"])
                arr[idx] = piece
        else:
            arr = np.load(os.path.join(d, f"{key}.npy"))
        if sh is not None:
            leaves.append(jax.device_put(arr, sh))
        else:
            leaves.append(jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, leaves), step
