import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture × input-shape ×
mesh) cell against the production mesh, prove the sharding config is
coherent, and extract the roofline inputs from the compiled artifact.

MUST be run as its own process (the XLA flag above is applied before any
other import initializes jax).  One JSON per cell lands in
benchmarks/out/dryrun/; `benchmarks/roofline_table.py` renders §Roofline.
"""
import argparse  # noqa: E402
import dataclasses  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec  # noqa: E402

from repro.configs import SHAPES, cells_for, get_config, input_specs  # noqa: E402
from repro.distributed.sharding import (  # noqa: E402
    data_size,
    make_rules,
    sanitize_spec,
    sanitized_shardings,
)
from repro.launch.mesh import (  # noqa: E402
    HBM_BW,
    HBM_BYTES,
    ICI_BW,
    PEAK_FLOPS_BF16,
    make_production_mesh,
)
from repro.nn.common import (  # noqa: E402
    abstract_params,
    count_active_params,
    count_params,
    param_pspecs,
)
from repro.nn.model import model_decls  # noqa: E402
from repro.roofline.hlo_analysis import analyze_hlo  # noqa: E402
from repro.serving.engine import (  # noqa: E402
    abstract_caches,
    cache_pspecs,
    make_decode_fn,
    make_prefill_fn,
)
from repro.training.train_step import (  # noqa: E402
    TrainHParams,
    abstract_train_state,
    make_train_step,
    train_state_pspecs,
)

OUT_DEFAULT = os.path.join(
    os.path.dirname(os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))), "benchmarks", "out", "dryrun")


def _batch_shardings(mesh, rules, abstract_batch):
    out = {}
    for k, v in abstract_batch.items():
        spec = PartitionSpec(rules.get("batch"), *([None] * (len(v.shape) - 1)))
        out[k] = NamedSharding(mesh, sanitize_spec(mesh, spec, tuple(v.shape)))
    return out


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               cfg_overrides: dict | None = None):
    """Build and lower one cell; returns (lowered, meta)."""
    mesh = make_production_mesh(multi_pod=multi_pod)
    cfg = get_config(arch)
    if cfg.n_experts:
        cfg = dataclasses.replace(cfg, moe_groups=data_size(mesh))
    if cfg_overrides:
        cfg = dataclasses.replace(cfg, **cfg_overrides)
    shape = SHAPES[shape_name]
    rules = make_rules(mesh, shape.kind, shape.global_batch)
    decls = model_decls(cfg)
    abatch = input_specs(cfg, shape)
    bsh = _batch_shardings(mesh, rules, abatch)

    if shape.kind == "train":
        hp = TrainHParams()
        step = make_train_step(cfg, hp, mesh, rules)
        astate = abstract_train_state(cfg, decls)
        ssh = sanitized_shardings(
            mesh, train_state_pspecs(cfg, decls, rules), astate)
        f = jax.jit(step, in_shardings=(ssh, bsh),
                    out_shardings=(ssh, None), donate_argnums=0)
        lowered = f.lower(astate, abatch)
    else:
        aparams = abstract_params(decls, jnp.dtype(cfg.param_dtype))
        psh = sanitized_shardings(mesh, param_pspecs(decls, rules), aparams,
                                  tp_fallback_axis="model")
        if shape.kind == "prefill":
            fn = make_prefill_fn(cfg, cache_len=shape.seq_len,
                                 mesh=mesh, rules=rules)
            lowered = jax.jit(fn, in_shardings=(psh, bsh)).lower(
                aparams, abatch)
        else:  # decode
            fn = make_decode_fn(cfg, mesh=mesh, rules=rules)
            acaches = abstract_caches(cfg, shape.global_batch, shape.seq_len)
            csh = sanitized_shardings(mesh, cache_pspecs(cfg, rules), acaches)
            pos = jax.ShapeDtypeStruct((shape.global_batch,), jnp.int32)
            pos_sh = NamedSharding(mesh, sanitize_spec(
                mesh, PartitionSpec(rules.get("batch")), pos.shape))
            astate = {"caches": acaches, "pos": pos}
            state_sh = {"caches": csh, "pos": pos_sh}
            # pin the OUTPUT cache sharding to the input's: without it the
            # compiler picks its own layout — the state round-trips through
            # resharding collectives every step and donation can't alias
            # (§Perf iteration A5)
            f = jax.jit(fn, in_shardings=(psh, bsh, state_sh),
                        out_shardings=(None, state_sh), donate_argnums=2)
            lowered = f.lower(aparams, abatch, astate)

    meta = dict(arch=arch, shape=shape_name, kind=shape.kind,
                multi_pod=multi_pod, n_devices=mesh.size,
                seq_len=shape.seq_len, global_batch=shape.global_batch)
    return lowered, meta, cfg, decls


def model_flops(cfg, decls, shape) -> float:
    """6·N·D (train) / 2·N·D (forward), N = active params."""
    n_act = count_active_params(decls, cfg.experts_per_token, cfg.n_experts)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_act * tokens
    if shape.kind == "prefill":
        return 2.0 * n_act * shape.global_batch * shape.seq_len
    return 2.0 * n_act * shape.global_batch  # decode: one token per row


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: str,
             save_hlo: bool = False, tag: str = "baseline",
             cfg_overrides: dict | None = None) -> dict:
    t0 = time.time()
    lowered, meta, cfg, decls = lower_cell(arch, shape_name, multi_pod,
                                           cfg_overrides)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    hlo = compiled.as_text()
    cost = analyze_hlo(hlo)

    shape = SHAPES[shape_name]
    n_dev = meta["n_devices"]
    mf = model_flops(cfg, decls, shape)
    per_dev_bytes = (mem.argument_size_in_bytes + mem.temp_size_in_bytes
                     + mem.output_size_in_bytes - mem.alias_size_in_bytes)
    compute_s = cost.flops / PEAK_FLOPS_BF16
    memory_s = cost.hbm_bytes / HBM_BW
    coll_s = cost.total_coll_bytes / ICI_BW
    dominant = max(
        (("compute", compute_s), ("memory", memory_s), ("collective", coll_s)),
        key=lambda kv: kv[1])[0]
    result = dict(
        meta,
        tag=tag,
        lower_s=round(t_lower, 2),
        compile_s=round(t_compile, 2),
        n_params=count_params(decls),
        n_active_params=count_active_params(
            decls, cfg.experts_per_token, cfg.n_experts),
        model_flops_total=mf,
        model_flops_per_dev=mf / n_dev,
        xla_flops_per_dev=float(ca.get("flops", -1.0)),
        hlo_flops_per_dev=cost.flops,
        hlo_hbm_bytes_per_dev=cost.hbm_bytes,
        collective_bytes_per_dev=cost.total_coll_bytes,
        collectives=cost.coll_bytes,
        collective_counts=cost.coll_counts,
        hbm_by_op=dict(sorted(cost.hbm_by_op.items(),
                              key=lambda kv: -kv[1])[:12]),
        mem_argument_bytes=mem.argument_size_in_bytes,
        mem_output_bytes=mem.output_size_in_bytes,
        mem_temp_bytes=mem.temp_size_in_bytes,
        mem_alias_bytes=mem.alias_size_in_bytes,
        mem_per_device_bytes=per_dev_bytes,
        fits_hbm=bool(per_dev_bytes <= HBM_BYTES),
        compute_term_s=compute_s,
        memory_term_s=memory_s,
        collective_term_s=coll_s,
        dominant=dominant,
        useful_flops_ratio=(mf / n_dev) / cost.flops if cost.flops else 0.0,
    )
    os.makedirs(out_dir, exist_ok=True)
    stem = f"{arch}__{shape_name}__{'pod2' if multi_pod else 'pod1'}"
    if tag != "baseline":
        stem += f"__{tag}"
    with open(os.path.join(out_dir, stem + ".json"), "w") as f:
        json.dump(result, f, indent=1)
    # always keep the partitioned HLO (gzipped) so analyzer improvements
    # can re-derive the roofline without recompiling
    import gzip

    with gzip.open(os.path.join(out_dir, stem + ".hlo.txt.gz"), "wt") as f:
        f.write(hlo)
    if save_hlo:
        with open(os.path.join(out_dir, stem + ".hlo.txt"), "w") as f:
            f.write(hlo)
    return result


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", help="architecture id (see repro.configs)")
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="every (arch × shape × mesh) cell")
    ap.add_argument("--out", default=OUT_DEFAULT)
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--tag", default="baseline")
    ap.add_argument("--set", action="append", default=[],
                    help="config override key=int, e.g. --set ssm_chunk=128")
    args = ap.parse_args()
    overrides = {}
    for kv in args.set:
        k, v = kv.split("=")
        overrides[k] = int(v)

    cells: list[tuple[str, str, bool]] = []
    if args.all:
        from repro.configs import all_configs

        for arch in all_configs():
            for shape in cells_for(arch):
                cells.append((arch, shape, False))
                cells.append((arch, shape, True))
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape (or --all) required")
        cells.append((args.arch, args.shape, args.multi_pod))

    failures = 0
    for arch, shape, mp in cells:
        label = f"{arch} × {shape} × {'2-pod(512)' if mp else '1-pod(256)'}"
        try:
            r = run_cell(arch, shape, mp, args.out, args.save_hlo, args.tag,
                         cfg_overrides=overrides or None)
            print(f"[dryrun] OK   {label}: compile {r['compile_s']}s, "
                  f"mem/dev {r['mem_per_device_bytes']/2**30:.2f} GiB "
                  f"(fits={r['fits_hbm']}), dominant={r['dominant']}")
            print(f"         terms: compute {r['compute_term_s']:.4f}s | "
                  f"memory {r['memory_term_s']:.4f}s | "
                  f"collective {r['collective_term_s']:.4f}s | "
                  f"useful-flops {r['useful_flops_ratio']:.2f}")
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"[dryrun] FAIL {label}: {type(e).__name__}: {e}")
            traceback.print_exc()
    if failures:
        raise SystemExit(f"{failures} cell(s) failed")


if __name__ == "__main__":
    main()
