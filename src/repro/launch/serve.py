"""Serving launcher: batched greedy LM generation, or a device-sharded
BLMAC filter-bank stream.

LM serving (prefill + decode steps)::

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-3b \
        --batch 4 --prompt-len 32 --new-tokens 16

Optionally applies BLMAC CSD-P pulse-code quantization to the checkpoint
before serving (`--quant-planes P`) — the paper's variable-precision dot
product as a deployment feature (weights stored/streamed at P pulses).

FIR bank serving (the paper's workload, sharded over every visible XLA
device and double-buffered through `repro.serving.AsyncBankServer`)::

    PYTHONPATH=src python -m repro.launch.serve --fir-bank 256 \
        --taps 63 --channels 1 --chunk 4096 --chunks 32

Run it under ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` to
exercise the mesh path on a CPU host.  ``--program-path bank.npz``
round-trips the compiled `repro.compiler.BlmacProgram` through disk:
the first run compiles and saves, every later run warm-starts from the
file (no re-quantization, CSD encoding or trit packing at startup).
"""
from __future__ import annotations

import argparse
import time


def serve_fir_bank(args) -> None:
    import os
    import numpy as np

    from repro.compiler import BlmacProgram, ProgramFormatError, compile_bank
    from repro.filters import (ShardedFilterBankEngine, fir_bit_layers_batch,
                               spread_lowpass_qbank)
    from repro.serving import AsyncBankServer

    n = args.fir_bank
    qbank = spread_lowpass_qbank(n, args.taps)
    # warm-start: load the compiled program if a previous serving process
    # saved one for this bank; otherwise compile once and save it
    program = None
    if args.program_path and os.path.exists(args.program_path):
        try:
            cand = BlmacProgram.load(args.program_path)
            if np.array_equal(cand.qbank, qbank):
                program = cand
                print(f"[serve] warm-start: loaded compiled program "
                      f"{program.key[:12]}… from {args.program_path}")
            else:
                print(f"[serve] {args.program_path} is for a different "
                      f"bank; recompiling")
        except ProgramFormatError as e:
            print(f"[serve] ignoring stale program file: {e}")
    if program is None:
        program = compile_bank(qbank)
        if args.program_path:
            program.save(args.program_path)
            print(f"[serve] saved compiled program to {args.program_path}")
    engine = ShardedFilterBankEngine(
        program, channels=args.channels, chunk_hint=args.chunk
    )
    print(f"[serve] {engine.describe()}")
    server = AsyncBankServer(engine, depth=args.depth)
    rng = np.random.default_rng(0)
    stream = rng.integers(
        -128, 128, (args.channels, args.chunk * args.chunks)
    ).astype(np.int32)
    done = 0
    t0 = time.time()
    for k in range(args.chunks):
        chunk = stream[:, k * args.chunk: (k + 1) * args.chunk]
        for out in server.submit(chunk):
            done += out.shape[2]
    outs = server.drain()
    done += sum(o.shape[2] for o in outs)
    dt = time.time() - t0
    print(f"[serve] fir-bank: {done} samples/filter/channel in {dt:.2f}s "
          f"({done / dt:.0f} samples/s/filter, "
          f"{done * n * args.channels / dt:.3e} filter-samples/s aggregate)")
    # spot-check the tail chunk against the exact oracle
    if outs and outs[-1].shape[2]:
        t = args.taps
        tail_in = stream[:, -(outs[-1].shape[2] + t - 1):]
        ref = fir_bit_layers_batch(tail_in, qbank)
        assert np.array_equal(outs[-1], ref), "sharded serve output mismatch"
        print("[serve] tail chunk bit-exact vs numpy oracle")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", help="LM architecture (omit with --fir-bank)")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--cache-len", type=int, default=256)
    ap.add_argument("--quant-planes", type=int, default=0,
                    help="CSD-P pulse-code weight quantization (0 = off)")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--fir-bank", type=int, default=0, metavar="B",
                    help="serve a B-filter BLMAC bank instead of an LM")
    ap.add_argument("--taps", type=int, default=63)
    ap.add_argument("--channels", type=int, default=1)
    ap.add_argument("--chunk", type=int, default=4096,
                    help="samples per request chunk (fir-bank mode)")
    ap.add_argument("--chunks", type=int, default=32)
    ap.add_argument("--depth", type=int, default=2,
                    help="async double-buffer depth (fir-bank mode)")
    ap.add_argument("--program-path", default="",
                    help="compiled-program cache file (fir-bank mode): "
                         "load it to warm-start, write it after compiling")
    args = ap.parse_args()

    if args.fir_bank:
        serve_fir_bank(args)
        return
    if not args.arch:
        ap.error("--arch is required unless --fir-bank is given")

    import jax
    import numpy as np

    from repro.configs import get_config
    from repro.nn import init_params, model_decls
    from repro.serving import ServeEngine

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if cfg.input_kind == "embeds":
        import dataclasses

        cfg = dataclasses.replace(cfg, input_kind="tokens")
    params = init_params(model_decls(cfg), jax.random.key(0))
    if args.quant_planes:
        from repro.core.serve_quant import quantize_param_tree

        params, stats = quantize_param_tree(params, args.quant_planes)
        print(f"[serve] CSD-{args.quant_planes} quantized "
              f"{stats['n_quantized']} matrices, mean rel err "
              f"{stats['mean_rel_err']:.4f}, stored bits/weight "
              f"{stats['bits_per_weight']:.1f}")
    eng = ServeEngine(cfg, params, cache_len=args.cache_len)
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size,
                           (args.batch, args.prompt_len)).astype(np.int32)
    t0 = time.time()
    out = eng.generate(prompts, max_new_tokens=args.new_tokens)
    dt = time.time() - t0
    print(f"[serve] {args.arch}: generated {out.shape} in {dt:.2f}s "
          f"({args.batch * args.new_tokens / dt:.1f} tok/s)")
    print(np.asarray(out)[:2])


if __name__ == "__main__":
    main()
