"""Serving launcher: batched greedy LM generation, or a device-sharded
BLMAC filter-bank stream.

LM serving (prefill + decode steps)::

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-3b \
        --batch 4 --prompt-len 32 --new-tokens 16

Optionally applies BLMAC CSD-P pulse-code quantization to the checkpoint
before serving (`--quant-planes P`) — the paper's variable-precision dot
product as a deployment feature (weights stored/streamed at P pulses).

FIR bank serving (the paper's workload, sharded over every visible XLA
device and double-buffered through `repro.serving.AsyncBankServer`)::

    PYTHONPATH=src python -m repro.launch.serve --fir-bank 256 \
        --taps 63 --channels 1 --chunk 4096 --chunks 32

Run it under ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` to
exercise the mesh path on a CPU host.  ``--program-path bank.npz``
round-trips the compiled `repro.compiler.BlmacProgram` through disk:
the first run compiles and saves, every later run warm-starts from the
file (no re-quantization, CSD encoding or trit packing at startup).

Multi-tenant session serving (N user streams, each on its own filter
selection, continuously batched into the shared lanes of ONE
`repro.serving.BankSessionServer`)::

    PYTHONPATH=src python -m repro.launch.serve --fir-bank 256 \
        --taps 63 --sessions 64 --slots 8 --chunk 512 --chunks 16

Exercises one mid-run `swap_filters` hot-swap and one pause/resume,
spot-checks a session against the numpy oracle, and prints the
`serve_stats()` surface (occupancy, queue depth, p50/p99 latency).

``--journal-path wal/`` makes the session server crash-safe: every
push/pull/registry change is written ahead to a CRC-framed journal and
`BankSessionServer.recover(path)` rebuilds every tenant bit-exactly
after a SIGKILL (see ``examples/session_recovery.py`` for the
kill-and-resume demo).  ``--bank-shards K`` runs the same session layer
ON TOP of a K-way `ShardedFilterBankEngine` (sessions × shards): lane
dispatches go through the sharded mesh and inherit its shard-loss
recovery.
"""
from __future__ import annotations

import argparse
import time


def serve_sessions(args) -> None:
    """--sessions path: N tenant streams over one compiled bank."""
    import numpy as np

    from repro.compiler import compile_bank
    from repro.filters import fir_bit_layers_batch, spread_lowpass_qbank
    from repro.serving import BankSessionServer

    n, n_sessions = args.fir_bank, args.sessions
    program = compile_bank(spread_lowpass_qbank(n, args.taps))
    engine = None
    if args.bank_shards:
        from repro.filters import ShardedFilterBankEngine

        engine = ShardedFilterBankEngine(
            program,
            channels=args.slots,
            n_bank_shards=args.bank_shards,
            chunk_hint=args.chunk,
        )
        print(f"[serve] sessions × shards: {engine.describe()}")
    server = BankSessionServer(
        program,
        n_slots=args.slots,
        chunk_hint=args.chunk,
        auto_step=False,
        engine=engine,
        journal=args.journal_path or None,
    )
    if args.journal_path:
        print(f"[serve] journaling session state to {args.journal_path}")
    rng = np.random.default_rng(0)
    # each session selects a distinct contiguous row slice of the bank
    per = max(1, n // n_sessions)
    selections = [
        np.arange((i * per) % n, (i * per) % n + per) for i in range(n_sessions)
    ]
    sessions = [server.open_session(sel) for sel in selections]
    streams = [
        rng.integers(-128, 128, args.chunk * args.chunks).astype(np.int32)
        for _ in range(n_sessions)
    ]
    outs = [[] for _ in range(n_sessions)]
    paused = None
    t0 = time.time()
    for k in range(args.chunks):
        if k == args.chunks // 3 and n_sessions > 1:
            # mid-run zero-downtime selection hot-swap on session 1
            outs[1].append(sessions[1].swap_filters(selections[1]))
        if k == args.chunks // 2 and n_sessions > 2:
            paused = (2, sessions[2].pause())  # park tenant 2 mid-stream
        for i, s in enumerate(sessions):
            if paused and i == paused[0]:
                continue
            s.push(streams[i][k * args.chunk: (k + 1) * args.chunk])
        server.step()
        if paused and k == args.chunks // 2:
            # …and resume it immediately: bit-exact continuation
            sessions[paused[0]] = server.resume_session(
                paused[1], selections[paused[0]]
            )
        for i, s in enumerate(sessions):
            out = s.pull()
            if out.shape[1]:
                outs[i].append(out)
    # feed the paused session the chunks it missed, then drain everyone
    if paused:
        i = paused[0]
        missed = streams[i][(args.chunks // 2) * args.chunk:]
        sessions[i].push(missed)
    server.step()
    for i, s in enumerate(sessions):
        out = s.pull()
        if out.shape[1]:
            outs[i].append(out)
    dt = time.time() - t0
    stats = server.serve_stats()
    agg = stats["samples_out"]
    print(f"[serve] sessions: {n_sessions} tenants × {per} filters over a "
          f"{n}-filter bank, {args.slots} shared lanes")
    print(f"[serve] {agg} output samples in {dt:.2f}s "
          f"({agg / dt:.0f} samples/s aggregate), "
          f"occupancy {stats['occupancy']:.2f}, "
          f"rounds {stats['rounds']}, "
          f"p50 {stats['latency_p50_ms']:.1f}ms / "
          f"p99 {stats['latency_p99_ms']:.1f}ms")
    # spot-check one full session stream against the exact numpy oracle
    check = 0
    got = np.concatenate(outs[check], axis=1)
    ref = fir_bit_layers_batch(
        streams[check][None, :], program.qbank
    )[selections[check], 0]
    assert np.array_equal(got, ref), "session stream mismatch vs oracle"
    print(f"[serve] session {check} bit-exact vs numpy oracle "
          f"({got.shape[1]} samples × {got.shape[0]} filters)")
    if stats.get("journal"):
        j = stats["journal"]
        print(f"[serve] journal: {j['appends']} appends, {j['syncs']} "
              f"fsyncs, {j['rotations']} rotations, live segment "
              f"{j['segment_bytes']} bytes at {j['path']}")
    server.close()


def serve_fir_bank(args) -> None:
    import os
    import numpy as np

    from repro.compiler import BlmacProgram, ProgramFormatError, compile_bank
    from repro.filters import (ShardedFilterBankEngine, fir_bit_layers_batch,
                               spread_lowpass_qbank)
    from repro.serving import AsyncBankServer

    n = args.fir_bank
    qbank = spread_lowpass_qbank(n, args.taps)
    # warm-start: load the compiled program if a previous serving process
    # saved one for this bank; otherwise compile once and save it
    program = None
    if args.program_path and os.path.exists(args.program_path):
        try:
            cand = BlmacProgram.load(args.program_path)
            if np.array_equal(cand.qbank, qbank):
                program = cand
                print(f"[serve] warm-start: loaded compiled program "
                      f"{program.key[:12]}… from {args.program_path}")
            else:
                print(f"[serve] {args.program_path} is for a different "
                      f"bank; recompiling")
        except ProgramFormatError as e:
            print(f"[serve] ignoring stale program file: {e}")
    if program is None:
        program = compile_bank(qbank)
        if args.program_path:
            program.save(args.program_path)
            print(f"[serve] saved compiled program to {args.program_path}")
    engine = ShardedFilterBankEngine(
        program, channels=args.channels, chunk_hint=args.chunk
    )
    print(f"[serve] {engine.describe()}")
    server = AsyncBankServer(engine, depth=args.depth)
    rng = np.random.default_rng(0)
    stream = rng.integers(
        -128, 128, (args.channels, args.chunk * args.chunks)
    ).astype(np.int32)
    done = 0
    t0 = time.time()
    for k in range(args.chunks):
        chunk = stream[:, k * args.chunk: (k + 1) * args.chunk]
        for out in server.submit(chunk):
            done += out.shape[2]
    outs = server.drain()
    done += sum(o.shape[2] for o in outs)
    dt = time.time() - t0
    print(f"[serve] fir-bank: {done} samples/filter/channel in {dt:.2f}s "
          f"({done / dt:.0f} samples/s/filter, "
          f"{done * n * args.channels / dt:.3e} filter-samples/s aggregate)")
    # spot-check the tail chunk against the exact oracle
    if outs and outs[-1].shape[2]:
        t = args.taps
        tail_in = stream[:, -(outs[-1].shape[2] + t - 1):]
        ref = fir_bit_layers_batch(tail_in, qbank)
        assert np.array_equal(outs[-1], ref), "sharded serve output mismatch"
        print("[serve] tail chunk bit-exact vs numpy oracle")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", help="LM architecture (omit with --fir-bank)")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--cache-len", type=int, default=256)
    ap.add_argument("--quant-planes", type=int, default=0,
                    help="CSD-P pulse-code weight quantization (0 = off)")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--fir-bank", type=int, default=0, metavar="B",
                    help="serve a B-filter BLMAC bank instead of an LM")
    ap.add_argument("--taps", type=int, default=63)
    ap.add_argument("--channels", type=int, default=1)
    ap.add_argument("--chunk", type=int, default=4096,
                    help="samples per request chunk (fir-bank mode)")
    ap.add_argument("--chunks", type=int, default=32)
    ap.add_argument("--depth", type=int, default=2,
                    help="async double-buffer depth (fir-bank mode)")
    ap.add_argument("--sessions", type=int, default=0, metavar="N",
                    help="serve N multi-tenant session streams over the "
                         "bank (fir-bank mode) instead of one sharded "
                         "stream")
    ap.add_argument("--slots", type=int, default=8,
                    help="shared batching lanes of the session server")
    ap.add_argument("--journal-path", default="",
                    help="write-ahead session journal directory (sessions "
                         "mode): makes the server crash-safe via "
                         "BankSessionServer.recover()")
    ap.add_argument("--bank-shards", type=int, default=0, metavar="K",
                    help="run the session lanes on a K-way sharded filter "
                         "bank engine (sessions mode, 0 = plain engine)")
    ap.add_argument("--program-path", default="",
                    help="compiled-program cache file (fir-bank mode): "
                         "load it to warm-start, write it after compiling")
    args = ap.parse_args()

    if args.fir_bank and args.sessions:
        serve_sessions(args)
        return
    if args.fir_bank:
        serve_fir_bank(args)
        return
    if not args.arch:
        ap.error("--arch is required unless --fir-bank is given")

    import jax
    import numpy as np

    from repro.configs import get_config
    from repro.nn import init_params, model_decls
    from repro.serving import ServeEngine

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if cfg.input_kind == "embeds":
        import dataclasses

        cfg = dataclasses.replace(cfg, input_kind="tokens")
    params = init_params(model_decls(cfg), jax.random.key(0))
    if args.quant_planes:
        from repro.core.serve_quant import quantize_param_tree

        params, stats = quantize_param_tree(params, args.quant_planes)
        print(f"[serve] CSD-{args.quant_planes} quantized "
              f"{stats['n_quantized']} matrices, mean rel err "
              f"{stats['mean_rel_err']:.4f}, stored bits/weight "
              f"{stats['bits_per_weight']:.1f}")
    eng = ServeEngine(cfg, params, cache_len=args.cache_len)
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size,
                           (args.batch, args.prompt_len)).astype(np.int32)
    t0 = time.time()
    out = eng.generate(prompts, max_new_tokens=args.new_tokens)
    dt = time.time() - t0
    print(f"[serve] {args.arch}: generated {out.shape} in {dt:.2f}s "
          f"({args.batch * args.new_tokens / dt:.1f} tok/s)")
    print(np.asarray(out)[:2])


if __name__ == "__main__":
    main()
