"""Serving launcher: batched greedy generation with prefill + decode steps.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-3b \
        --batch 4 --prompt-len 32 --new-tokens 16

Optionally applies BLMAC CSD-P pulse-code quantization to the checkpoint
before serving (`--quant-planes P`) — the paper's variable-precision dot
product as a deployment feature (weights stored/streamed at P pulses).
"""
from __future__ import annotations

import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--cache-len", type=int, default=256)
    ap.add_argument("--quant-planes", type=int, default=0,
                    help="CSD-P pulse-code weight quantization (0 = off)")
    ap.add_argument("--reduced", action="store_true", default=True)
    args = ap.parse_args()

    import jax
    import numpy as np

    from repro.configs import get_config
    from repro.nn import init_params, model_decls
    from repro.serving import ServeEngine

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if cfg.input_kind == "embeds":
        import dataclasses

        cfg = dataclasses.replace(cfg, input_kind="tokens")
    params = init_params(model_decls(cfg), jax.random.key(0))
    if args.quant_planes:
        from repro.core.serve_quant import quantize_param_tree

        params, stats = quantize_param_tree(params, args.quant_planes)
        print(f"[serve] CSD-{args.quant_planes} quantized "
              f"{stats['n_quantized']} matrices, mean rel err "
              f"{stats['mean_rel_err']:.4f}, stored bits/weight "
              f"{stats['bits_per_weight']:.1f}")
    eng = ServeEngine(cfg, params, cache_len=args.cache_len)
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size,
                           (args.batch, args.prompt_len)).astype(np.int32)
    t0 = time.time()
    out = eng.generate(prompts, max_new_tokens=args.new_tokens)
    dt = time.time() - t0
    print(f"[serve] {args.arch}: generated {out.shape} in {dt:.2f}s "
          f"({args.batch * args.new_tokens / dt:.1f} tok/s)")
    print(np.asarray(out)[:2])


if __name__ == "__main__":
    main()
