"""Production meshes.

TPU v5e pod = 256 chips, arranged here as (data=16, model=16); the
multi-pod deployment stacks pods on a leading `pod` axis that folds into
data parallelism (DCN between pods carries only DP gradient reductions).
A FUNCTION, not a module constant: importing this module must never touch
jax device state.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(n_data: int = 2, n_model: int = 2):
    """Small mesh over however many (fake) devices the test session has."""
    return jax.make_mesh((n_data, n_model), ("data", "model"))


# TPU v5e hardware constants used by the roofline analysis
PEAK_FLOPS_BF16 = 197e12  # per chip
HBM_BW = 819e9  # bytes/s per chip
ICI_BW = 50e9  # bytes/s per link (≈ per-direction, per chip)
HBM_BYTES = 16 * 2**30  # 16 GiB per chip
