"""Production training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-3b \
        --steps 200 --batch 32 --seq 512 --ckpt-dir /tmp/run1

On real hardware this process is started once per host (jax.distributed
initializes from the TPU pod environment); here a reduced config trains on
CPU to demonstrate the full loop: sharded data, fault-tolerant checkpoints,
auto-resume, straggler watchdog.  `--production-mesh` lowers against the
16×16 pod mesh instead of executing (the executable-on-this-host path
needs ≤ CPU device count).

Launcher TPU flags (applied on TPU backends only): async collectives +
latency-hiding scheduler — the compute/communication overlap knobs.
"""
from __future__ import annotations

import argparse
import dataclasses
import os


TPU_PERF_FLAGS = (
    "--xla_enable_async_all_gather=true "
    "--xla_enable_async_collective_permute=true "
    "--xla_tpu_enable_latency_hiding_scheduler=true "
    "--xla_tpu_overlap_compute_collective_tc=true"
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--reduced", action="store_true", default=True,
                    help="use the smoke-scale config (CPU demo)")
    ap.add_argument("--full-config", dest="reduced", action="store_false")
    ap.add_argument("--data", choices=("markov", "uniform"), default="markov")
    args = ap.parse_args()

    import jax

    if jax.default_backend() == "tpu":  # pragma: no cover - TPU only
        os.environ["LIBTPU_INIT_ARGS"] = TPU_PERF_FLAGS

    from repro.configs import get_config
    from repro.data import DataConfig, TokenPipeline
    from repro.distributed.fault import TrainLoop
    from repro.training import OptHParams, TrainHParams

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if cfg.input_kind == "embeds":
        cfg = dataclasses.replace(cfg, input_kind="tokens")  # text-only demo

    pipe = TokenPipeline(DataConfig(
        vocab_size=cfg.vocab_size, global_batch=args.batch,
        seq_len=args.seq, kind=args.data))
    hp = TrainHParams(
        opt=OptHParams(learning_rate=args.lr, warmup_steps=10,
                       total_steps=args.steps),
        grad_accum=args.grad_accum)
    loop = TrainLoop(cfg, hp, pipe, args.ckpt_dir,
                     ckpt_every=args.ckpt_every)
    hist = loop.run(args.steps)
    print(f"[train] {args.arch}: step {hist[0]['step']} loss "
          f"{hist[0]['loss']:.3f} -> step {hist[-1]['step']} loss "
          f"{hist[-1]['loss']:.3f}; stragglers={loop.stragglers.slow_steps}")


if __name__ == "__main__":
    main()
