"""Fault-tolerant TRAIN loop: checkpoint/resume + watchdog, as a client
of the shared fault machinery in `repro.distributed.faultbank`.

This module is the train-side face of fault tolerance only:

  * atomic keep-k checkpoints every `ckpt_every` steps,
  * auto-resume from the latest committed checkpoint,
  * deterministic data replay (the pipeline is a pure function of step),
  * straggler watchdog: per-step wall times vs a running median
    (`faultbank.StragglerStats`); slow steps are counted and reported
    (on a real fleet this feeds the preemption/rescheduling controller
    — here it is observability),
  * failure injection for tests (`fail_at`), proving crash → restart →
    bit-exact convergence with the uninterrupted run.

The SERVING-side fault tolerance — shard-loss detection, re-partition
recovery and chaos injection for the sharded filter-bank mesh — lives
in `faultbank` (shared taxonomy/watchdog/injector) and
`repro.filters.ShardedFilterBankEngine` / `repro.serving.AsyncBankServer`
(the recovery and retry paths).  `StragglerStats` and `SimulatedFailure`
moved to `faultbank` and are re-exported here for compatibility.
"""
from __future__ import annotations

import time
import jax

from ..checkpoint.manager import latest_step, restore_checkpoint, save_checkpoint
from ..data.pipeline import TokenPipeline
from ..training.train_step import TrainHParams, make_train_step, train_state_init
from .faultbank import SimulatedFailure, StragglerStats

__all__ = ["SimulatedFailure", "StragglerStats", "TrainLoop"]


class TrainLoop:
    def __init__(self, cfg, hp: TrainHParams, pipeline: TokenPipeline,
                 ckpt_dir: str, *, ckpt_every: int = 10, keep: int = 3,
                 mesh=None, rules=None, batch_shardings=None,
                 init_key: int = 0):
        self.cfg = cfg
        self.hp = hp
        self.pipeline = pipeline
        self.ckpt_dir = ckpt_dir
        self.ckpt_every = ckpt_every
        self.keep = keep
        self.mesh = mesh
        self.batch_shardings = batch_shardings
        self.stragglers = StragglerStats()
        self._step_fn = jax.jit(make_train_step(cfg, hp, mesh, rules))
        from ..nn import init_params, model_decls

        params = init_params(model_decls(cfg), jax.random.key(init_key))
        self.state = train_state_init(params, cfg)
        self.metrics_history: list[dict] = []
        self._maybe_resume()

    def _maybe_resume(self) -> None:
        if latest_step(self.ckpt_dir) is not None:
            self.state, step = restore_checkpoint(self.ckpt_dir, self.state)
            print(f"[fault] resumed from checkpoint at step {step}")

    @property
    def step(self) -> int:
        return int(self.state["step"])

    def _put(self, batch):
        batch = {k: jax.numpy.asarray(v) for k, v in batch.items()}
        if self.batch_shardings is not None:
            batch = {k: jax.device_put(v, self.batch_shardings[k])
                     for k, v in batch.items()}
        return batch

    def run(self, until_step: int,
            fail_at: int | None = None) -> list[dict]:
        """Run to `until_step`; raises SimulatedFailure at `fail_at`
        (before that step commits) when requested by a test."""
        while self.step < until_step:
            step = self.step
            if fail_at is not None and step == fail_at:
                raise SimulatedFailure(f"injected failure at step {step}")
            batch = self._put(self.pipeline.global_batch_at(step))
            t0 = time.time()
            self.state, metrics = self._step_fn(self.state, batch)
            jax.block_until_ready(metrics["loss"])
            slow = self.stragglers.record(time.time() - t0)
            if slow:
                print(f"[fault] straggling step {step}: "
                      f"{self.stragglers.times[-1]:.3f}s")
            m = {k: float(v) for k, v in metrics.items()}
            m["step"] = step
            self.metrics_history.append(m)
            new_step = self.step
            if new_step % self.ckpt_every == 0 or new_step == until_step:
                save_checkpoint(self.ckpt_dir, new_step, self.state,
                                keep=self.keep)
        return self.metrics_history
