from .sharding import (batch_shardings, data_axes, data_size, make_rules,
                       tree_shardings)
from .collectives import compressed_psum, compressed_psum_tree

__all__ = ["batch_shardings", "data_axes", "data_size", "make_rules",
           "tree_shardings", "compressed_psum", "compressed_psum_tree"]
