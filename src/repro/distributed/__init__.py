from .collectives import (
    compressed_psum,
    compressed_psum_tree,
    get_shard_map,
    halo_exchange_left,
    shard_map_no_check_kwargs,
)
from .sharding import (
    BankPartition,
    bank_filter_costs,
    bank_mesh,
    batch_shardings,
    data_axes,
    data_size,
    make_rules,
    mesh_bank_shape,
    partition_bank,
    tree_shardings,
)

__all__ = [
    "BankPartition",
    "bank_filter_costs",
    "bank_mesh",
    "batch_shardings",
    "compressed_psum",
    "compressed_psum_tree",
    "data_axes",
    "data_size",
    "get_shard_map",
    "halo_exchange_left",
    "make_rules",
    "mesh_bank_shape",
    "partition_bank",
    "shard_map_no_check_kwargs",
    "tree_shardings",
]
