"""Logical-axis → mesh-axis rules: the single place the parallelism layout
is decided.

Parallelism map (DESIGN.md §5):
  DP    batch over (pod, data)
  FSDP  the d_model side of every weight over data  (ZeRO-3-style; XLA
        inserts the per-layer all-gathers inside the scan)
  TP    heads / ff / vocab / experts over model (Megatron-style)
  EP    experts over model
  SP    decode KV/latent caches over model (flash-decoding style), and
        over (data, model) when the decode batch cannot fill the data axis
"""
from __future__ import annotations

import math
from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec


def data_axes(mesh: Mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def data_size(mesh: Mesh) -> int:
    return math.prod(mesh.shape[a] for a in data_axes(mesh))


def make_rules(mesh: Mesh, kind: str = "train",
               global_batch: int | None = None) -> dict[str, Any]:
    """Logical-axis rules for one execution cell."""
    daxes: Any = data_axes(mesh)
    if len(daxes) == 1:
        daxes = daxes[0]
    rules: dict[str, Any] = {
        "batch": daxes,
        "seq": None,
        "vocab": "model",
        "heads": "model",
        "kv_heads": "model",
        "heads_flat": "model",
        "head_dim": None,
        "ff": "model",
        "experts": "model",
        "expert_ff": None,
        "d_model": "data",  # FSDP
        "state": None,
        "layers": None,
        "cache_seq": None,
    }
    if kind == "decode":
        rules["cache_seq"] = "model"
        if global_batch is not None and global_batch < data_size(mesh):
            # batch can't fill the data axis (long-context, batch=1):
            # shard the cache sequence across everything instead
            rules["batch"] = None
            rules["cache_seq"] = (
                ("pod", "data", "model") if "pod" in mesh.axis_names
                else ("data", "model")
            )
    if kind in ("prefill", "decode"):
        # FSDP is a *training* memory trick: at inference, weights are
        # read-only — replicating them over `data` removes a full-model
        # all-gather per step (§Perf iteration A1: 133 GiB/step on
        # deepseek-coder-33b decode_32k)
        rules["d_model"] = None
    return rules


def named_sharding(mesh: Mesh, spec: PartitionSpec) -> NamedSharding:
    return NamedSharding(mesh, spec)


def _axis_size(mesh: Mesh, entry) -> int:
    if entry is None:
        return 1
    if isinstance(entry, (tuple, list)):
        return math.prod(mesh.shape[a] for a in entry)
    return mesh.shape[entry]


def sanitize_spec(mesh: Mesh, spec: PartitionSpec, shape: tuple[int, ...]) -> PartitionSpec:
    """Drop mesh axes that do not divide their dim: jit *input* shardings
    must be even (GSPMD pads only intermediates).  E.g. kv_heads=2 cannot
    shard over model=16 → replicated (the realistic TP choice anyway)."""
    entries = list(spec) + [None] * (len(shape) - len(spec))
    out = []
    for dim, entry in zip(shape, entries):
        if entry is not None and dim % _axis_size(mesh, entry) != 0:
            entry = None
        out.append(entry)
    return PartitionSpec(*out)


def sanitized_shardings(mesh: Mesh, pspec_tree, shape_tree,
                        tp_fallback_axis: str | None = None):
    """NamedShardings with divisibility enforcement, leaf-wise.

    ``tp_fallback_axis``: when a weight ends up with NO use of that mesh
    axis (its TP dim wasn't divisible — e.g. 56 heads on a 16-way axis),
    shard its largest divisible dim instead.  For inference this is the
    row-parallel layout: the contraction dim is sharded, each device reads
    1/TP of the weight and contributes a partial sum (§Perf iteration A2).
    """
    flat_s, treedef = jax.tree_util.tree_flatten(
        pspec_tree, is_leaf=lambda x: isinstance(x, PartitionSpec))
    flat_shapes = treedef.flatten_up_to(shape_tree)
    out = []
    for s, sh in zip(flat_s, flat_shapes):
        shape = tuple(sh.shape)
        spec = sanitize_spec(mesh, s, shape)
        if tp_fallback_axis is not None:
            used = {a for e in spec if e
                    for a in (e if isinstance(e, tuple) else (e,))}
            if tp_fallback_axis not in used and len(shape) >= 2:
                size = mesh.shape[tp_fallback_axis]
                cands = [(dim, i) for i, (dim, e) in
                         enumerate(zip(shape, spec))
                         if e is None and dim % size == 0 and dim >= size]
                if cands:
                    _, idx = max(cands)
                    entries = list(spec)
                    entries[idx] = tp_fallback_axis
                    spec = PartitionSpec(*entries)
        out.append(NamedSharding(mesh, spec))
    return jax.tree_util.tree_unflatten(treedef, out)


def tree_shardings(mesh: Mesh, pspecs) -> Any:
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), pspecs,
        is_leaf=lambda x: isinstance(x, PartitionSpec),
    )


def batch_pspec(mesh: Mesh, rules: dict, ndim: int) -> PartitionSpec:
    """Sharding for a (B, S, ...) input batch leaf."""
    return PartitionSpec(rules.get("batch"), *([None] * (ndim - 1)))


def batch_shardings(mesh: Mesh, rules: dict, batch_tree) -> Any:
    return jax.tree_util.tree_map(
        lambda leaf: NamedSharding(
            mesh, batch_pspec(mesh, rules, len(leaf.shape))
        ),
        batch_tree,
    )
