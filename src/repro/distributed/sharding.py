"""Logical-axis → mesh-axis rules: the single place the parallelism layout
is decided.

Parallelism map (DESIGN.md §5):
  DP    batch over (pod, data)
  FSDP  the d_model side of every weight over data  (ZeRO-3-style; XLA
        inserts the per-layer all-gathers inside the scan)
  TP    heads / ff / vocab / experts over model (Megatron-style)
  EP    experts over model
  SP    decode KV/latent caches over model (flash-decoding style), and
        over (data, model) when the decode batch cannot fill the data axis

FIR bank partitioning (the BLMAC serving side):
  BANK  filters over the `bank` mesh axis — `partition_bank` assigns
        filters to shards occupancy-sorted AND cost-balanced, so one
        dense shard does not straggle the mesh (the paper scales by
        replicating 110-LUT machines; we scale by replicating per-shard
        bank programs)
  DATA  channels (or, for single-channel streams, signal time chunks
        with an overlap-save halo exchange) over the `data` mesh axis
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec


def data_axes(mesh: Mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def data_size(mesh: Mesh) -> int:
    return math.prod(mesh.shape[a] for a in data_axes(mesh))


def make_rules(mesh: Mesh, kind: str = "train",
               global_batch: int | None = None) -> dict[str, Any]:
    """Logical-axis rules for one execution cell."""
    daxes: Any = data_axes(mesh)
    if len(daxes) == 1:
        daxes = daxes[0]
    rules: dict[str, Any] = {
        "batch": daxes,
        "seq": None,
        "vocab": "model",
        "heads": "model",
        "kv_heads": "model",
        "heads_flat": "model",
        "head_dim": None,
        "ff": "model",
        "experts": "model",
        "expert_ff": None,
        "d_model": "data",  # FSDP
        "state": None,
        "layers": None,
        "cache_seq": None,
    }
    if kind == "decode":
        rules["cache_seq"] = "model"
        if global_batch is not None and global_batch < data_size(mesh):
            # batch can't fill the data axis (long-context, batch=1):
            # shard the cache sequence across everything instead
            rules["batch"] = None
            rules["cache_seq"] = (
                ("pod", "data", "model") if "pod" in mesh.axis_names
                else ("data", "model")
            )
    if kind in ("prefill", "decode"):
        # FSDP is a *training* memory trick: at inference, weights are
        # read-only — replicating them over `data` removes a full-model
        # all-gather per step (§Perf iteration A1: 133 GiB/step on
        # deepseek-coder-33b decode_32k)
        rules["d_model"] = None
    return rules


def named_sharding(mesh: Mesh, spec: PartitionSpec) -> NamedSharding:
    return NamedSharding(mesh, spec)


def _axis_size(mesh: Mesh, entry) -> int:
    if entry is None:
        return 1
    if isinstance(entry, (tuple, list)):
        return math.prod(mesh.shape[a] for a in entry)
    return mesh.shape[entry]


def sanitize_spec(mesh: Mesh, spec: PartitionSpec, shape: tuple[int, ...]) -> PartitionSpec:
    """Drop mesh axes that do not divide their dim: jit *input* shardings
    must be even (GSPMD pads only intermediates).  E.g. kv_heads=2 cannot
    shard over model=16 → replicated (the realistic TP choice anyway)."""
    entries = list(spec) + [None] * (len(shape) - len(spec))
    out = []
    for dim, entry in zip(shape, entries):
        if entry is not None and dim % _axis_size(mesh, entry) != 0:
            entry = None
        out.append(entry)
    return PartitionSpec(*out)


def sanitized_shardings(mesh: Mesh, pspec_tree, shape_tree,
                        tp_fallback_axis: str | None = None):
    """NamedShardings with divisibility enforcement, leaf-wise.

    ``tp_fallback_axis``: when a weight ends up with NO use of that mesh
    axis (its TP dim wasn't divisible — e.g. 56 heads on a 16-way axis),
    shard its largest divisible dim instead.  For inference this is the
    row-parallel layout: the contraction dim is sharded, each device reads
    1/TP of the weight and contributes a partial sum (§Perf iteration A2).
    """
    flat_s, treedef = jax.tree_util.tree_flatten(
        pspec_tree, is_leaf=lambda x: isinstance(x, PartitionSpec))
    flat_shapes = treedef.flatten_up_to(shape_tree)
    out = []
    for s, sh in zip(flat_s, flat_shapes):
        shape = tuple(sh.shape)
        spec = sanitize_spec(mesh, s, shape)
        if tp_fallback_axis is not None:
            used = {a for e in spec if e
                    for a in (e if isinstance(e, tuple) else (e,))}
            if tp_fallback_axis not in used and len(shape) >= 2:
                size = mesh.shape[tp_fallback_axis]
                cands = [(dim, i) for i, (dim, e) in
                         enumerate(zip(shape, spec))
                         if e is None and dim % size == 0 and dim >= size]
                if cands:
                    _, idx = max(cands)
                    entries = list(spec)
                    entries[idx] = tp_fallback_axis
                    spec = PartitionSpec(*entries)
        out.append(NamedSharding(mesh, spec))
    return jax.tree_util.tree_unflatten(treedef, out)


def tree_shardings(mesh: Mesh, pspecs) -> Any:
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), pspecs,
        is_leaf=lambda x: isinstance(x, PartitionSpec),
    )


def batch_pspec(mesh: Mesh, rules: dict, ndim: int) -> PartitionSpec:
    """Sharding for a (B, S, ...) input batch leaf."""
    return PartitionSpec(rules.get("batch"), *([None] * (ndim - 1)))


def batch_shardings(mesh: Mesh, rules: dict, batch_tree) -> Any:
    return jax.tree_util.tree_map(
        lambda leaf: NamedSharding(
            mesh, batch_pspec(mesh, rules, len(leaf.shape))
        ),
        batch_tree,
    )


# ---------------------------------------------------------------------------
# FIR filter-bank partition specs (the BLMAC serving mesh)
# ---------------------------------------------------------------------------

BANK_AXIS = "bank"
DATA_AXIS = "data"


def bank_mesh(
    n_bank: int | None = None,
    n_data: int = 1,
    devices=None,
) -> Mesh:
    """(bank, data) device mesh for sharded filter-bank serving.

    ``n_bank`` defaults to every available device divided by ``n_data``.
    A 1×1 mesh is valid — `ShardedFilterBankEngine` degrades to the
    single-device scheduled path on it.
    """
    if devices is None:
        devices = jax.devices()
    devices = list(devices)
    if n_bank is None:
        n_bank = max(1, len(devices) // n_data)
    need = n_bank * n_data
    if need > len(devices):
        raise ValueError(
            f"bank_mesh needs {need} devices ({n_bank}×{n_data}), "
            f"have {len(devices)}"
        )
    return jax.make_mesh(
        (n_bank, n_data), (BANK_AXIS, DATA_AXIS), devices=devices[:need]
    )


def mesh_bank_shape(mesh: Mesh) -> tuple[int, int]:
    """(n_bank, n_data) of a bank mesh; axes it lacks count as size 1."""
    return (
        mesh.shape.get(BANK_AXIS, 1),
        mesh.shape.get(DATA_AXIS, 1),
    )


@dataclass(frozen=True)
class BankPartition:
    """Filter → bank-shard assignment with caller-order restoration baked in.

    ``assign[s]`` holds the ORIGINAL indices of the filters served by
    shard ``s`` (occupancy-sorted within the shard, so each shard's
    `plan_bank_schedule` sees a homogeneous run).  ``inv`` maps an
    original filter index to its row in the shard-major concatenation of
    per-shard outputs — reassembly is one host-side index permutation,
    never a cross-device gather.  ``cost[s]`` is the predicted per-shard
    work the balancer equalized.
    """

    assign: tuple
    inv: np.ndarray
    cost: np.ndarray

    @property
    def n_shards(self) -> int:
        return len(self.assign)

    @property
    def imbalance(self) -> float:
        """max/mean per-shard predicted cost — 1.0 is a perfect balance."""
        mean = float(self.cost.mean())
        return float(self.cost.max()) / mean if mean > 0 else 1.0


def bank_filter_costs(packed: np.ndarray, taps: int) -> np.ndarray:
    """(B,) predicted per-filter work: BLMAC pulses + the symmetric folds.

    The pulse count is exactly the paper's §3.3 add count, read straight
    off the packed trit words (each populated 2-bit code is one add in
    every kernel mode, `core.csd.packed_pulse_counts` — the same popcount
    `BlmacProgram.pulse_counts` stores), so the balancer and the cost
    model agree on what "one filter's work" means.
    """
    from ..core.csd import packed_pulse_counts

    return packed_pulse_counts(packed).astype(np.float64) + taps // 2


def partition_bank(
    packed: np.ndarray,
    n_shards: int,
    taps: int,
    cost: np.ndarray | None = None,
    sig: np.ndarray | None = None,
) -> BankPartition:
    """Occupancy-balanced contiguous partition of a packed bank.

    Filters are first sorted by layer-occupancy signature (the same
    ordering `plan_bank_schedule` uses), then the sorted run is cut into
    ``n_shards`` CONTIGUOUS spans with balanced cumulative cost.
    Contiguity in signature order keeps every shard occupancy-
    homogeneous (its tile schedules stay short); the weighted cut keeps
    a dense shard from straggling the mesh.  Shards may carry unequal
    filter counts — per-shard programs are compiled per shard, so no
    SPMD padding is needed.  ``n_shards`` is clamped to the bank size.

    ``cost``/``sig`` let a `repro.compiler.BlmacProgram` supply its
    precomputed per-filter costs and occupancy signatures (the
    `BlmacProgram.partition` hook does) instead of re-deriving them from
    the packed words here.
    """
    from ..core.csd import occupancy_signatures

    packed = np.asarray(packed)
    n_filters = packed.shape[0]
    if n_filters == 0:
        raise ValueError("cannot partition an empty bank")
    n_shards = max(1, min(int(n_shards), n_filters))
    if cost is None:
        cost = bank_filter_costs(packed, taps)
    cost = np.asarray(cost, np.float64)
    if sig is None:
        sig = occupancy_signatures(packed.any(axis=-1))
    order = np.argsort(sig, kind="stable")
    csum = np.cumsum(cost[order])
    total = csum[-1]
    if total <= 0:  # all-zero bank: fall back to equal counts
        bounds = [round(n_filters * s / n_shards) for s in range(n_shards + 1)]
    else:
        bounds = [0]
        for s in range(1, n_shards):
            target = total * s / n_shards
            cut = int(np.searchsorted(csum, target))
            # every shard keeps >= 1 filter and cuts stay monotonic
            cut = min(max(cut, bounds[-1] + 1), n_filters - (n_shards - s))
            bounds.append(cut)
        bounds.append(n_filters)
    assign = tuple(
        order[bounds[s]: bounds[s + 1]] for s in range(n_shards)
    )
    inv = np.empty(n_filters, np.int64)
    inv[np.concatenate(assign)] = np.arange(n_filters)
    shard_cost = np.array([cost[a].sum() for a in assign])
    return BankPartition(assign=assign, inv=inv, cost=shard_cost)
