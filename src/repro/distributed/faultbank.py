"""Fault machinery for the sharded BLMAC filter-bank service.

The paper scales throughput by replicating small BLMAC machines; a
replicated fleet only earns its keep if losing one machine does not
lose the stream.  This module is the shared substrate for that:

  * the **error taxonomy** every layer speaks — `ShardLost` (permanent,
    the engine must re-partition), `TransientShardError` (the server
    retries with backoff), `ShardCorruption` (the engine replays the
    chunk), `ShardTimeout` (a watchdog escalation of loss), plus the
    caller-facing `PendingInvalidated` / `RetriesExhausted` /
    `DeadlineExceeded`,
  * a deterministic, test-driven `FaultInjector` — kill shard k at
    chunk n, delay shard k, fail a push transiently, corrupt a shard's
    output block — everything the chaos harness and the recovery
    benchmark inject,
  * a `ShardHealth` watchdog: per-shard heartbeat wall-times through
    `StragglerStats` (generalized out of the train-only
    `repro.distributed.fault`) and an optional hard per-shard timeout
    that `ShardedFilterBankEngine` escalates to `ShardTimeout`,
  * `FaultStats` — the counter surface behind the engines' and server's
    ``fault_stats()``, next to the compiler's ``cache_stats()``.

`StragglerStats` and `SimulatedFailure` moved here from
`repro.distributed.fault` (which re-exports them for compatibility):
they were never train-specific, and the serving watchdog reuses them
unchanged.
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

__all__ = [
    "DeadlineExceeded",
    "FaultInjector",
    "FaultStats",
    "PendingInvalidated",
    "RetriesExhausted",
    "ShardCorruption",
    "ShardError",
    "ShardHealth",
    "ShardLost",
    "ShardTimeout",
    "SimulatedFailure",
    "StragglerStats",
    "TransientShardError",
]


# ---------------------------------------------------------------------------
# error taxonomy
# ---------------------------------------------------------------------------


class ShardError(RuntimeError):
    """Base of the shard-level fault taxonomy; carries the bank-shard
    index the fault was detected on (``None`` when no single shard is
    responsible, e.g. a server-side deadline)."""

    def __init__(self, shard: int | None = None, msg: str | None = None):
        super().__init__(msg or f"shard {shard} failed")
        self.shard = shard


class ShardLost(ShardError):
    """Permanent loss of a bank shard (dead device / repeated corruption
    escalation).  The engine's recovery path re-partitions the bank over
    the surviving mesh rows; re-raised only when no survivors remain."""


class ShardTimeout(ShardLost):
    """The `ShardHealth` hard timeout expired while materializing a
    shard's block — treated as a loss (the watchdog's escalation)."""


class TransientShardError(ShardError):
    """A retriable shard failure (queue hiccup, injected transient).
    The engine re-dispatches the chunk and re-raises; bounded
    retry/backoff is `AsyncBankServer`'s job."""


class ShardCorruption(ShardError):
    """A shard's output block failed the boundary integrity probe.  The
    engine replays the chunk from its tail snapshot; repeated corruption
    on the same chunk escalates to `ShardLost`."""


class PendingInvalidated(RuntimeError):
    """`PendingChunk.result()` after the engine's stream state moved on
    (``reset()`` while the push was in flight, or a terminal server
    failure already consumed it) — the shard outputs would reassemble a
    stale stream, so the error is loud instead."""


class RetriesExhausted(ShardError):
    """`AsyncBankServer` exceeded ``max_retries`` on one chunk; the
    chunk is dropped from the stream and the error propagates — never a
    hang."""


class DeadlineExceeded(ShardError):
    """`AsyncBankServer`'s per-push deadline elapsed before the chunk
    resolved."""


class SimulatedFailure(RuntimeError):
    """Injected process-level failure (train-loop ``fail_at`` and any
    other crash-the-world test hook)."""


# ---------------------------------------------------------------------------
# watchdog
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class StragglerStats:
    """Wall-time watchdog: records per-step (or per-shard-materialize)
    durations and flags steps slower than ``factor`` × the running
    median of the last 50.  Needs ≥ 5 samples before it will flag."""

    times: list[float] = dataclasses.field(default_factory=list)
    slow_steps: int = 0
    factor: float = 2.0

    def record(self, dt: float) -> bool:
        self.times.append(dt)
        if len(self.times) >= 5:
            med = float(np.median(self.times[-50:]))
            if dt > self.factor * med:
                self.slow_steps += 1
                return True
        return False


class ShardHealth:
    """Per-shard heartbeat watchdog for a sharded bank engine.

    One `StragglerStats` per bank shard records every materialize
    wall-time; ``timeout`` (seconds, ``None`` = disabled) is the hard
    per-shard deadline the engine enforces around materialization and
    escalates to `ShardTimeout` → `ShardLost`.  ``reset(n)`` rebuilds
    the per-shard series after a recovery re-partition (cumulative
    counters live in `FaultStats`, which survives resets)."""

    def __init__(self, n_shards: int, timeout: float | None = None,
                 straggler_factor: float = 3.0):
        self.timeout = timeout
        self.factor = straggler_factor
        self.reset(n_shards)

    def reset(self, n_shards: int) -> None:
        self.stats = [
            StragglerStats(factor=self.factor) for _ in range(n_shards)
        ]

    @property
    def n_shards(self) -> int:
        return len(self.stats)

    def record(self, shard: int, dt: float) -> bool:
        """Record one materialize wall-time; True if it straggled."""
        return self.stats[shard].record(dt)

    def summary(self) -> dict:
        """JSON-ready per-shard heartbeat summary."""
        return {
            "n_shards": len(self.stats),
            "timeout_s": self.timeout,
            "heartbeats": [len(s.times) for s in self.stats],
            "wall_s": [round(float(sum(s.times)), 6) for s in self.stats],
            "slow_steps": [s.slow_steps for s in self.stats],
        }


# ---------------------------------------------------------------------------
# counters
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class FaultStats:
    """Cumulative fault counters for one engine (survives recovery
    re-partitions and `ShardHealth` resets).  ``as_dict()`` is the
    JSON-ready surface behind ``fault_stats()``:

      * ``detections`` — faults detected (losses + timeouts +
        corruptions + transients), each detection event once,
      * ``recoveries`` — successful re-partitions onto surviving rows
        (including the final degradation to the unsharded engine),
      * ``lost_shards`` / ``timeouts`` / ``corruptions`` /
        ``transients`` — per-kind detection counts,
      * ``replayed_chunks`` / ``replayed_samples`` — deterministic
        replays from tail snapshots (recovery and corruption heals),
      * ``stragglers`` — materializations flagged slow by `ShardHealth`,
      * ``degraded`` / ``degraded_s`` — whether the engine fell back to
        the single-device `FilterBankEngine`, and for how long.
    """

    detections: int = 0
    recoveries: int = 0
    lost_shards: int = 0
    timeouts: int = 0
    corruptions: int = 0
    transients: int = 0
    replayed_chunks: int = 0
    replayed_samples: int = 0
    stragglers: int = 0
    last_recovery_s: float = 0.0
    degraded_since: float | None = None

    def as_dict(self) -> dict:
        degraded = self.degraded_since is not None
        return {
            "detections": self.detections,
            "recoveries": self.recoveries,
            "lost_shards": self.lost_shards,
            "timeouts": self.timeouts,
            "corruptions": self.corruptions,
            "transients": self.transients,
            "replayed_chunks": self.replayed_chunks,
            "replayed_samples": self.replayed_samples,
            "stragglers": self.stragglers,
            "last_recovery_s": self.last_recovery_s,
            "degraded": degraded,
            "degraded_s": (
                time.perf_counter() - self.degraded_since if degraded else 0.0
            ),
        }


# ---------------------------------------------------------------------------
# deterministic fault injection
# ---------------------------------------------------------------------------


class FaultInjector:
    """Deterministic fault injection for `ShardedFilterBankEngine`.

    Faults are armed against **(bank-shard slot, chunk index)**:
    ``chunk`` counts `push_async` calls since engine construction (or
    the last ``reset()``), and ``shard`` means "whatever machine is
    serving bank-shard slot k when chunk n is dispatched" — after a
    recovery re-partition the surviving shards renumber from 0, and
    armed faults keep targeting the renumbered slots.  That makes a
    pre-armed kill grid read exactly like the test reasons about it:
    ``kill(1, 2); kill(1, 5)`` kills slot 1 of the original mesh at
    chunk 2 and slot 1 of the RECOVERED mesh at chunk 5.  When the
    engine removes a lost shard it calls `on_shard_removed`, which
    retires the fired kill so the slot's next occupant is not killed by
    the same corpse.

    All four fault kinds are pure functions of (shard, chunk) and the
    armed state — no randomness, no wall-clock reads — so a chaos grid
    replays identically every run:

      * `kill_shard(k, at_chunk=n)` — every dispatch of shard k for
        chunk ≥ n raises `ShardLost` until the engine removes the shard
        (a dead machine stays dead; chunks already in flight on it get
        replayed through the recovered mesh),
      * `delay_shard(k, at_chunk=n, seconds=t)` — shard k's materialize
        for chunk n sleeps t seconds first (drives the `ShardHealth`
        timeout / straggler paths),
      * `fail_push(k, at_chunk=n, times=m)` — the next m dispatch
        attempts of (k, n) raise `TransientShardError` (drives the
        server's retry/backoff),
      * `corrupt_output(k, at_chunk=n, times=m)` — shard k's
        materialized block for chunk n comes back element-wise damaged
        m times (drives the integrity probe + replay path).
    """

    def __init__(self):
        self._kills: list[dict] = []  # {shard, chunk, fired}
        self._delays: dict[tuple[int, int], float] = {}
        self._transients: dict[tuple[int, int], int] = {}
        self._corruptions: dict[tuple[int, int], int] = {}
        self._injected = {
            "kills": 0, "delays": 0, "transients": 0, "corruptions": 0,
        }

    # -- arming --------------------------------------------------------------

    def kill_shard(self, shard: int, at_chunk: int) -> "FaultInjector":
        self._kills.append(
            {"shard": int(shard), "chunk": int(at_chunk), "fired": False}
        )
        return self

    def delay_shard(self, shard: int, at_chunk: int,
                    seconds: float) -> "FaultInjector":
        self._delays[(int(shard), int(at_chunk))] = float(seconds)
        return self

    def fail_push(self, shard: int, at_chunk: int,
                  times: int = 1) -> "FaultInjector":
        self._transients[(int(shard), int(at_chunk))] = int(times)
        return self

    def corrupt_output(self, shard: int, at_chunk: int,
                       times: int = 1) -> "FaultInjector":
        self._corruptions[(int(shard), int(at_chunk))] = int(times)
        return self

    # -- engine-facing hooks -------------------------------------------------

    def on_dispatch(self, shard: int, chunk: int) -> None:
        """Called before each shard dispatch; raises the armed fault."""
        left = self._transients.get((shard, chunk), 0)
        if left > 0:
            self._transients[(shard, chunk)] = left - 1
            self._injected["transients"] += 1
            raise TransientShardError(
                shard, f"injected transient failure: shard {shard} "
                       f"chunk {chunk} ({left - 1} left)"
            )
        for kill in self._kills:
            if kill["shard"] == shard and chunk >= kill["chunk"]:
                if not kill["fired"]:
                    kill["fired"] = True
                    self._injected["kills"] += 1
                raise ShardLost(
                    shard, f"injected shard loss: shard {shard} died at "
                           f"chunk {kill['chunk']} (dispatching "
                           f"chunk {chunk})"
                )

    def on_materialize(self, shard: int, chunk: int) -> None:
        """Called inside each shard materialize (under the watchdog
        timeout, so an armed delay can trip `ShardTimeout`)."""
        seconds = self._delays.pop((shard, chunk), None)
        if seconds:
            self._injected["delays"] += 1
            time.sleep(seconds)

    def corrupt(self, shard: int, chunk: int, arr: np.ndarray) -> np.ndarray:
        """Called on each shard's materialized block; returns it damaged
        when a corruption is armed for (shard, chunk)."""
        left = self._corruptions.get((shard, chunk), 0)
        if left > 0:
            self._corruptions[(shard, chunk)] = left - 1
            self._injected["corruptions"] += 1
            return arr + 1
        return arr

    def on_shard_removed(self, shard: int) -> None:
        """The engine removed ``shard`` from the mesh: retire the FIRED
        kill targeting it, so the renumbered slot's next occupant is
        not re-killed by the same corpse.  Unfired faults keep their
        slot indices (slot-at-fire-time semantics, see class doc)."""
        self._kills = [
            k for k in self._kills
            if not (k["fired"] and k["shard"] == shard)
        ]

    # -- observability -------------------------------------------------------

    def faults_injected(self) -> dict:
        """Copy of the per-kind injected-fault counters."""
        return dict(self._injected)
