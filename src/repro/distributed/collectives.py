"""Distributed collectives: int8-compressed gradient all-reduce and the
overlap-save halo exchange for time-sharded FIR streams.

Standard pjit training lets XLA place the data-parallel grad reductions.
For bandwidth-constrained inter-pod links, `compressed_psum_tree` offers an
explicit shard_map path: per-tensor-scaled int8 quantization → integer
psum → dequantize.  Error is unbiased-ish (stochastic rounding optional)
and bounded by scale/254; `tests/test_collectives.py` checks numerics and
`train_step(..., grad_compression="int8")` wires it into the loop for the
pure-DP case.

`halo_exchange_left` is the FIR serving collective: when a signal chunk is
split along time over a mesh axis, every shard needs the last ``taps − 1``
samples of its LEFT neighbour to compute its own first outputs (classical
overlap-save, but across devices instead of across pushes).  One
`ppermute` moves exactly the halo — no all-gather of the stream.
"""
from __future__ import annotations

import functools
import inspect
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P


def get_shard_map():
    """`shard_map` across jax versions (>=0.5 top level, 0.4.x experimental)."""
    try:
        from jax import shard_map
    except ImportError:
        from jax.experimental.shard_map import shard_map
    return shard_map


def shard_map_no_check_kwargs() -> dict:
    """The "skip replication check" kwarg for this jax's `shard_map`
    (renamed check_rep → check_vma); keyed off the actual signature."""
    params = inspect.signature(get_shard_map()).parameters
    if "check_vma" in params:
        return {"check_vma": False}
    if "check_rep" in params:
        return {"check_rep": False}
    return {}


def halo_exchange_left(
    x: jax.Array, axis_name: Any, axis_size: int, halo: int
) -> jax.Array:
    """Inside shard_map: prepend the last ``halo`` samples of the LEFT
    neighbour's time slice to this shard's ``(..., T_local)`` slice.

    Shard 0 has no left neighbour and receives zeros (ppermute's
    out-of-range default) — its first ``halo`` outputs are the invalid
    warm-up region the caller trims, exactly like the zero-primed tail
    of a fresh overlap-save stream.  ``axis_size`` must be the static
    mesh-axis size (the permutation is built at trace time).
    """
    if halo <= 0:
        return x
    if x.shape[-1] < halo:
        raise ValueError(
            f"halo {halo} exceeds the local slice ({x.shape[-1]} samples)"
        )
    perm = [(i, i + 1) for i in range(axis_size - 1)]
    left = jax.lax.ppermute(x[..., -halo:], axis_name, perm=perm)
    return jnp.concatenate([left, x], axis=-1)


def _quantize_int8(x: jax.Array, key: jax.Array | None = None):
    scale = jnp.max(jnp.abs(x)) / 127.0
    scale = jnp.where(scale == 0, 1.0, scale)
    y = x / scale
    if key is not None:  # stochastic rounding
        y = jnp.floor(y + jax.random.uniform(key, y.shape))
    else:
        y = jnp.round(y)
    return jnp.clip(y, -127, 127).astype(jnp.int8), scale


def compressed_psum(x: jax.Array, axis_name: Any, key=None) -> jax.Array:
    """Inside shard_map: all-reduce-mean x over `axis_name` in int8.

    Two-phase: a scalar pmax agrees on a *shared* scale (so the integer
    sum decodes exactly to Σ sᵍqᵢ), then the tensor moves as int8.
    Traffic: 1 byte/element + one f32 scalar per tensor, vs 4 bytes/element
    for fp32 ring all-reduce — a 4× inter-pod bandwidth saving.
    """
    gmax = jax.lax.pmax(jnp.max(jnp.abs(x)), axis_name)
    scale = jnp.where(gmax == 0, 1.0, gmax / 127.0)
    y = x / scale
    if key is not None:  # stochastic rounding
        y = jnp.floor(y + jax.random.uniform(key, y.shape))
    else:
        y = jnp.round(y)
    q = jnp.clip(y, -127, 127).astype(jnp.int8)
    total = jax.lax.psum(q.astype(jnp.int32), axis_name)
    n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
    return total.astype(jnp.float32) * scale / n


def compressed_psum_tree(tree, axis_name: Any):
    return jax.tree_util.tree_map(
        lambda g: compressed_psum(g, axis_name), tree
    )


def make_compressed_dp_grad_fn(loss_fn, mesh: Mesh, axis: str = "data"):
    """Data-parallel grads with int8 all-reduce, via shard_map.

    Params replicated; batch sharded on `axis`.  Returns a function
    (params, batch) → (loss, grads) with grads reduced in int8.
    """
    shard_map = get_shard_map()
    _no_check = shard_map_no_check_kwargs()

    def local_grads(params, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        loss = jax.lax.pmean(loss, axis)
        grads = compressed_psum_tree(grads, axis)
        return loss, grads

    @functools.wraps(local_grads)
    def wrapped(params, batch):
        pspec = jax.tree_util.tree_map(lambda _: P(), params)
        bspec = jax.tree_util.tree_map(lambda _: P(axis), batch)
        f = shard_map(
            local_grads, mesh=mesh,
            in_specs=(pspec, bspec),
            out_specs=(P(), jax.tree_util.tree_map(lambda _: P(), params)),
            **_no_check,
        )
        return f(params, batch)

    return wrapped
