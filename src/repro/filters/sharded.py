"""Device-sharded filter-bank serving: BLMAC banks over a (bank, data) mesh.

The paper scales throughput by replicating 110-LUT BLMAC machines; this
module scales the jax_pallas reproduction the same way across XLA devices.
`ShardedFilterBankEngine` partitions a (B filters × C channels) bank over
a two-axis device mesh:

  * **bank axis** — filters, assigned by
    `repro.distributed.sharding.partition_bank`: occupancy-sorted so each
    shard's `plan_bank_schedule` sees a homogeneous run (short superlayer
    programs), cost-balanced so one dense shard never straggles the mesh.
    Every shard compiles its OWN schedule and runs as its own program on
    its own mesh row — replicated machines, not one padded SPMD body.
  * **data axis** — channels when ``C`` divides the axis (no
    communication), otherwise signal time chunks with an overlap-save
    halo exchange (`repro.distributed.collectives.halo_exchange_left`,
    one `ppermute` of ``taps − 1`` samples per push) inside `shard_map`.

Whether sharding pays at all is the mesh-aware autotuner's call
(`repro.kernels.runtime.autotune_sharded_dispatch`): the unsharded plan
competes in the same critical-path sweep, and a narrow bank or a short
chunk comes back with ``n_bank_shards == 1`` — the engine then degrades
to the single-device scheduled path bit-for-bit.

Output reassembly is gather-free: per-shard outputs land on their own
devices, the host reads each shard's block, and ONE precomputed index
permutation (`BankPartition.inv`) restores the caller's filter order —
no cross-device collective touches the results.

Bit-exactness: every mesh shape agrees with
`repro.filters.fir_bit_layers_batch` to the last bit on integer inputs
(the fifth leg of `tests/differential.py`).
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..distributed.collectives import (get_shard_map, halo_exchange_left,
                                       shard_map_no_check_kwargs)
from ..distributed.sharding import DATA_AXIS, bank_mesh, mesh_bank_shape

__all__ = ["ShardedFilterBankEngine", "PendingChunk"]


class PendingChunk:
    """In-flight outputs of one `push_async`: per-shard device arrays plus
    the reassembly recipe.  `result()` materializes on the host — each
    shard's block is read off its own devices and rows are restored to
    caller order with one index permutation (no device-side gather)."""

    def __init__(self, shard_outs, inv, n_out, offsets, n_filters, channels):
        self._shard_outs = shard_outs
        self._inv = inv
        self._offsets = offsets
        self.n_out = int(n_out)
        self._shape = (n_filters, channels)
        self._resolved = None

    def result(self) -> np.ndarray:
        """Block until the chunk's outputs are ready → int32 (B, C, n_out)."""
        if self._resolved is not None:
            return self._resolved
        b, c = self._shape
        if self.n_out <= 0:
            self._resolved = np.zeros((b, c, 0), np.int32)
            return self._resolved
        parts = []
        for y, off in zip(self._shard_outs, self._offsets):
            if isinstance(y, list):  # specialized shard: per-filter arrays
                rows = [
                    np.stack([np.asarray(a)[: self.n_out] for a in chans])
                    for chans in y
                ]
                parts.append(np.stack(rows))
            else:
                parts.append(np.asarray(y)[:, :, off: off + self.n_out])
        out = np.concatenate(parts, axis=0)[self._inv]
        self._shard_outs = None  # free device references
        self._resolved = np.ascontiguousarray(out)
        return self._resolved


class ShardedFilterBankEngine:
    """Overlap-save streaming FIR bank sharded over a (bank, data) mesh.

    Parameters
    ----------
    qbank : (B, taps) or (taps,) int array, or `repro.compiler.BlmacProgram`
        Quantized odd symmetric (type-I) coefficients, one row per filter
        — compiled once via `compile_bank` (content-addressed); passing a
        prebuilt / `load()`ed program warm-starts without recompiling.
        Shard subprograms are the program's memoized `select()` slices,
        shared with the mesh autotuner.
    channels : int
        Independent input channels C (all filtered by every filter).
    mesh : jax.sharding.Mesh | None
        A mesh with a ``bank`` axis and optionally a ``data`` axis
        (see `repro.distributed.sharding.bank_mesh`).  ``None`` builds a
        (n_devices, 1) mesh over every visible device.  A 1×1 mesh is
        valid and degrades to the single-device scheduled engine.
    n_bank_shards : int | None
        Force the filter-shard count (clamped to the mesh's bank axis);
        ``None`` lets the mesh-aware autotuner pick — including picking
        1 when sharding does not pay.
    data_mode : {"none", "channels", "time"} | None
        Force how the data axis is used; ``None`` lets the autotuner
        pick — including leaving the axis idle when the halo/split
        overhead loses to a single device per shard.
    tile, merge, chunk_hint, interpret
        As `repro.filters.FilterBankEngine`; per-shard tiles/modes are
        autotuned per shard unless ``tile`` pins them.
    """

    def __init__(
        self,
        qbank: np.ndarray,
        channels: int = 1,
        mesh: Mesh | None = None,
        n_bank_shards: int | None = None,
        data_mode: str | None = None,
        tile: int | None = None,
        merge: int | None = None,
        chunk_hint: int = 2048,
        interpret: bool | None = None,
    ):
        from ..compiler import BlmacProgram, compile_bank
        from ..kernels.runtime import (autotune_sharded_dispatch,
                                       resolve_interpret)

        if isinstance(qbank, BlmacProgram):
            program = qbank
        else:
            # CSD, packing and the §2.1 int32 bound — once, content-
            # addressed, shared with every other client.  int64 cast as
            # in `FilterBankEngine`: float input keeps its historical
            # truncation semantics; quantize via `compile_bank` directly.
            program = compile_bank(
                np.atleast_2d(np.asarray(qbank, np.int64))
            )
        if channels < 1:
            raise ValueError("channels must be >= 1")
        if mesh is None:
            mesh = bank_mesh()
        self.mesh = mesh
        self.program = program
        self.qbank = program.qbank
        self.n_filters = program.n_filters
        self.taps = program.taps
        self.channels = int(channels)
        self.interpret = resolve_interpret(interpret)
        n_bank, n_data = mesh_bank_shape(mesh)
        if n_bank * n_data != mesh.size:
            raise ValueError(
                f"mesh must be ({'bank'}, {'data'})-shaped, got {mesh.shape}"
            )
        force = None
        if n_bank_shards is not None:
            force = max(1, min(int(n_bank_shards), n_bank, self.n_filters))
        self.plan, self.partition, schedules = autotune_sharded_dispatch(
            program, channels=self.channels, mesh_shape=(n_bank, n_data),
            tile=tile, chunk_hint=chunk_hint, interpret=interpret,
            force_shards=force, force_data=data_mode,
        )
        if merge is not None:
            # re-plan only the scheduled shards whose merge differs,
            # KEEPING each shard's autotuned bank tile, and stamp the
            # override into the shard plans; the re-plan goes through the
            # shard subprogram's schedule memo, and predicted_us
            # intentionally keeps the autotuner's estimate for ITS
            # schedules — the cost model is not re-run for a forced merge
            import dataclasses

            schedules = tuple(
                program.select(rows).schedule(sched.tile_size, merge)
                if sched is not None and sched.merge != merge else sched
                for rows, sched in zip(self.partition.assign, schedules)
            )
            self.plan = dataclasses.replace(
                self.plan,
                shard_plans=tuple(
                    dataclasses.replace(p, merge=merge)
                    if p.mode == "scheduled" else p
                    for p in self.plan.shard_plans
                ),
            )
        self.n_bank_shards = self.plan.n_bank_shards
        self.n_data = self.plan.n_data
        self.data_mode = self.plan.data_mode
        self._halo = self.taps - 1
        # chunk lengths are quantized to a multiple of every shard's tile
        # so ragged pushes hit a handful of jit-cache entries; only TIME
        # sharding additionally needs the ×n_data factor (each device's
        # slice must itself be tile-aligned and cover the halo it sends
        # rightwards) — channel sharding splits C, not time
        self._quantum = max(p.tile for p in self.plan.shard_plans)
        if self.data_mode == "time":
            self._quantum *= self.n_data
            while self._quantum // self.n_data < self._halo:
                self._quantum *= 2

        devices = np.asarray(mesh.devices).reshape(n_bank, n_data)
        self._shards = []
        for s, (rows, plan) in enumerate(
            zip(self.partition.assign, self.plan.shard_plans)
        ):
            self._shards.append(
                self._build_shard(
                    program.select(rows),  # the autotuner's exact subprogram
                    plan, schedules[s], devices[s % n_bank],
                )
            )
        # overlap-save state: the last taps-1 samples of every channel
        self._tail = np.zeros((channels, 0), np.int32)
        self.samples_in = 0
        self.samples_out = 0

    # -- construction helpers ----------------------------------------------

    def _build_shard(self, subprogram, plan, schedule, dev_row):
        """One bank shard = (dispatch closure, device row).  Returns a
        callable ``fn(buf_np, n) -> device output`` where ``buf_np`` is
        the padded (C, n_pad) int32 buffer and ``n`` the valid length.
        ``subprogram`` is the shard's `BlmacProgram` slice — its pulse
        schedules and packed operands are the memoized artifacts the
        autotuner already costed."""
        if plan.mode == "specialized":  # n_data == 1 by construction
            pulses = subprogram.pulse_schedules()
            dev = dev_row[0]

            def run_specialized(buf, n):
                from ..kernels.blmac_fir import blmac_fir_specialized

                x = jax.device_put(jnp.asarray(buf, jnp.int32), dev)
                chans = [x[c] for c in range(self.channels)]
                return [
                    [
                        blmac_fir_specialized(
                            xc, p, self.taps, plan.tile, self.interpret
                        )
                        for xc in chans
                    ]
                    for p in pulses
                ]

            return run_specialized, 0

        fn = self._make_scheduled_fn(schedule, plan.tile)
        if self.n_data == 1:
            dev = dev_row[0]
            ops = tuple(
                jax.device_put(jnp.asarray(g.packed.view(np.int32)), dev)
                for g in schedule.groups if g.sel_layers
            )

            def run_single(buf, n):
                x = jax.device_put(jnp.asarray(buf, jnp.int32), dev)
                return fn(x, *ops)

            return run_single, 0

        row_mesh = Mesh(dev_row, (DATA_AXIS,))
        repl = NamedSharding(row_mesh, P())
        ops = tuple(
            jax.device_put(jnp.asarray(g.packed.view(np.int32)), repl)
            for g in schedule.groups if g.sel_layers
        )
        shard_map = get_shard_map()
        nc = shard_map_no_check_kwargs()
        if self.data_mode == "channels":
            in_specs = (P(DATA_AXIS, None),) + (P(),) * len(ops)
            out_specs = P(None, DATA_AXIS, None)

            def body(buf, *op):
                return fn(buf, *op)

            offset = 0
        else:  # time: halo exchange, then each slice is self-contained
            in_specs = (P(None, DATA_AXIS),) + (P(),) * len(ops)
            out_specs = P(None, None, DATA_AXIS)
            n_data, halo = self.n_data, self._halo

            def body(buf, *op):
                chunk_local = buf.shape[-1]
                xl = halo_exchange_left(buf, DATA_AXIS, n_data, halo)
                return fn(xl, *op)[:, :, :chunk_local]

            # shard 0's halo is ppermute zero-fill: the first taps-1
            # concatenated outputs are warm-up, trimmed at reassembly
            offset = self._halo

        mapped = shard_map(
            body, mesh=row_mesh, in_specs=in_specs, out_specs=out_specs, **nc
        )
        jitted = jax.jit(mapped)
        x_sharding = NamedSharding(row_mesh, in_specs[0])

        def run_mapped(buf, n):
            x = jax.device_put(jnp.asarray(buf, jnp.int32), x_sharding)
            return jitted(x, *ops)

        return run_mapped, offset

    def _make_scheduled_fn(self, schedule, tile):
        """Jitted scheduled-bank program for one shard: frame, then the
        shared `bank_schedule_apply` group loop (zeros for empty groups,
        one `_bank_call` per tile group, shard-order restoration).  The
        schedule is static (closed over); jit caches per input shape ×
        device.  ``ops`` carries only the NON-empty groups' operands
        (shard_map in_specs must match real arrays), re-slotted to the
        full per-group list here."""
        from ..kernels.blmac_fir import bank_schedule_apply, frame_signal_batch

        taps, interpret = self.taps, self.interpret
        has_layers = [bool(g.sel_layers) for g in schedule.groups]

        @jax.jit
        def fn(x, *ops):
            frames, _ = frame_signal_batch(x, taps, tile)
            it = iter(ops)
            full = [next(it) if h else None for h in has_layers]
            return bank_schedule_apply(
                frames, schedule, taps, tile, interpret, device_groups=full
            )

        return fn

    # -- streaming API ------------------------------------------------------

    def push_async(self, chunk) -> PendingChunk:
        """Feed (C, n) samples (or (n,) when C == 1); dispatches every
        bank shard onto its mesh row and returns WITHOUT blocking on the
        device work — the double-buffered serving path overlaps the next
        chunk's host framing with this chunk's kernels."""
        chunk = np.asarray(chunk)
        if chunk.ndim == 1:
            chunk = chunk[None, :]
        if chunk.shape[0] != self.channels:
            raise ValueError(
                f"expected {self.channels} channels, got {chunk.shape[0]}"
            )
        self.samples_in += chunk.shape[1]
        buf = np.concatenate([self._tail, chunk.astype(np.int32)], axis=1)
        n = buf.shape[1]
        if n < self.taps:  # still priming
            self._tail = buf
            return PendingChunk(
                [], self.partition.inv, 0, [], self.n_filters, self.channels
            )
        self._tail = (
            buf[:, n - self._halo:] if self._halo else buf[:, :0]
        )
        n_out = n - self.taps + 1
        n_pad = -(-n // self._quantum) * self._quantum
        if n_pad != n:
            buf = np.pad(buf, ((0, 0), (0, n_pad - n)))
        outs, offsets = [], []
        for fn, offset in self._shards:
            outs.append(fn(buf, n))
            offsets.append(offset)
        self.samples_out += n_out
        return PendingChunk(
            outs, self.partition.inv, n_out, offsets,
            self.n_filters, self.channels,
        )

    def push(self, chunk) -> np.ndarray:
        """Synchronous `push_async` → int32 (B, C, n_out)."""
        return self.push_async(chunk).result()

    def __call__(self, chunk) -> np.ndarray:
        return self.push(chunk)

    def reset(self) -> None:
        """Drop all buffered history (start a new stream)."""
        self._tail = np.zeros((self.channels, 0), np.int32)
        self.samples_in = 0
        self.samples_out = 0

    @property
    def pending(self) -> int:
        """Samples buffered but not yet old enough to finish a window."""
        return self._tail.shape[1]

    def time_shards(self, chunk, repeats: int = 3) -> np.ndarray:
        """(n_shards,) best-of-``repeats`` isolated wall seconds per bank
        shard for one ``chunk``, without disturbing the stream state.

        Forced host-platform devices share the host's cores, so timing
        shards CONCURRENTLY measures core contention, not mesh scaling;
        this probe times each shard's dispatch alone (dispatch → block),
        which is the per-machine number the paper's replicated-instance
        throughput model aggregates.  `benchmarks/bank_sharded.py` builds
        its critical-path scaling row from exactly this.
        """
        import time

        chunk = np.atleast_2d(np.asarray(chunk)).astype(np.int32)
        n = chunk.shape[1]
        if n < self.taps:
            raise ValueError("chunk shorter than the filter")
        n_pad = -(-n // self._quantum) * self._quantum
        buf = np.pad(chunk, ((0, 0), (0, n_pad - n)))
        for fn, _ in self._shards:  # warm-up: compile
            jax.block_until_ready(fn(buf, n))
        # round-robin the repeats so one transient host hiccup cannot
        # poison every sample of a single shard (min-per-shard is only
        # robust when a shard's samples are spread over the run)
        times = np.full(len(self._shards), np.inf)
        for _ in range(repeats):
            for s, (fn, _) in enumerate(self._shards):
                t0 = time.perf_counter()
                jax.block_until_ready(fn(buf, n))
                times[s] = min(times[s], time.perf_counter() - t0)
        return times

    # -- introspection ------------------------------------------------------

    def describe(self) -> str:
        """One line for logs: mesh, shard modes, balance, predicted cost."""
        modes = ",".join(p.mode[:4] for p in self.plan.shard_plans)
        return (
            f"sharded-bank B={self.n_filters} C={self.channels} "
            f"mesh=({self.n_bank_shards}x{self.n_data}) "
            f"data={self.data_mode} modes=[{modes}] "
            f"imbalance={self.partition.imbalance:.2f} "
            f"predicted={self.plan.predicted_us:.0f}us"
        )
