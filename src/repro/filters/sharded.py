"""Device-sharded filter-bank serving: BLMAC banks over a (bank, data) mesh.

The paper scales throughput by replicating 110-LUT BLMAC machines; this
module scales the jax_pallas reproduction the same way across XLA devices.
`ShardedFilterBankEngine` partitions a (B filters × C channels) bank over
a two-axis device mesh:

  * **bank axis** — filters, assigned by
    `repro.distributed.sharding.partition_bank`: occupancy-sorted so each
    shard's `plan_bank_schedule` sees a homogeneous run (short superlayer
    programs), cost-balanced so one dense shard never straggles the mesh.
    Every shard compiles its OWN schedule and runs as its own program on
    its own mesh row — replicated machines, not one padded SPMD body.
  * **data axis** — channels when ``C`` divides the axis (no
    communication), otherwise signal time chunks with an overlap-save
    halo exchange (`repro.distributed.collectives.halo_exchange_left`,
    one `ppermute` of ``taps − 1`` samples per push) inside `shard_map`.

Whether sharding pays at all is the mesh-aware autotuner's call
(`repro.kernels.runtime.autotune_sharded_dispatch`): the unsharded plan
competes in the same critical-path sweep, and a narrow bank or a short
chunk comes back with ``n_bank_shards == 1`` — the engine then degrades
to the single-device scheduled path bit-for-bit.

Output reassembly is gather-free: per-shard outputs land on their own
devices, the host reads each shard's block, and ONE precomputed index
permutation (`BankPartition.inv`) restores the caller's filter order —
no cross-device collective touches the results.

**Fault tolerance** (see `repro.distributed.faultbank` for the shared
taxonomy/injector/watchdog): every `push_async` captures a
`repro.compiler.TailSnapshot` — the pure-host overlap-save state that
makes the chunk deterministically replayable on ANY backend of the same
program.  When a shard is detected dead (a raised `ShardLost`, or the
`ShardHealth` watchdog timeout), the engine removes that mesh row,
re-partitions the bank over the survivors via the program's memoized
`partition`/`select` slices (recovery shard count chosen by
`repro.core.costmodel.predict_recovery_us`), and replays every
in-flight chunk from its snapshot — so the resumed stream is bit-exact
with an uninterrupted run.  When the mesh degrades to a single device
the engine falls back to the plain `FilterBankEngine` lowering of the
SAME `BlmacProgram`.  Corrupted shard blocks (caught by the optional
boundary integrity probe) are replayed in place and escalate to loss if
they persist; transient errors re-arm the chunk and propagate for
`repro.serving.AsyncBankServer`'s bounded retry/backoff.  Counters for
all of it surface through ``fault_stats()``.

Bit-exactness: every mesh shape agrees with
`repro.filters.fir_bit_layers_batch` to the last bit on integer inputs
(the fifth leg of `tests/differential.py`, including its chaos grid).
"""
from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..distributed.collectives import (get_shard_map, halo_exchange_left,
                                       shard_map_no_check_kwargs)
from ..distributed.faultbank import (FaultStats, PendingInvalidated,
                                     ShardCorruption, ShardError, ShardHealth,
                                     ShardLost, ShardTimeout,
                                     TransientShardError)
from ..distributed.sharding import (DATA_AXIS, BankPartition, bank_mesh,
                                    mesh_bank_shape)

__all__ = ["ShardedFilterBankEngine", "PendingChunk"]


class PendingChunk:
    """In-flight outputs of one `push_async`: per-shard device arrays plus
    the reassembly recipe and the chunk's replay material (tail snapshot
    + raw samples).  `result()` materializes on the host — each shard's
    block is read off its own devices and rows are restored to caller
    order with one index permutation (no device-side gather) — and is
    where faults are detected and recovered: a lost shard triggers the
    engine's re-partition + replay, a corrupted block is replayed in
    place, a transient error re-arms the chunk and propagates for the
    server's retry loop."""

    def __init__(self, engine, shard_outs, inv, n_out, offsets,
                 n_filters, channels, snapshot=None, chunk=None,
                 chunk_idx=0):
        self._engine = engine
        self._shard_outs = shard_outs
        self._inv = inv
        self._offsets = offsets
        self.n_out = int(n_out)
        self._shape = (n_filters, channels)
        self._resolved = None
        self._invalid = False
        self.snapshot = snapshot
        self.chunk = chunk
        self.chunk_idx = int(chunk_idx)
        self._heals = 0  # corruption replays consumed on this chunk

    def _rearm(self, shard_outs, offsets, inv) -> None:
        """Swap in a replay's fresh dispatch (possibly from a different
        partition after a recovery re-partition)."""
        self._shard_outs = shard_outs
        self._offsets = offsets
        self._inv = inv

    def invalidate(self) -> None:
        """Mark the chunk unusable (engine reset / terminal failure):
        `result()` will raise `PendingInvalidated`, and the engine stops
        tracking it for replay."""
        self._invalid = True
        self._shard_outs = None
        self.snapshot = None
        self.chunk = None
        eng = self._engine
        if eng is not None and self in eng._inflight:
            eng._inflight.remove(self)

    def result(self) -> np.ndarray:
        """Block until the chunk's outputs are ready → int32 (B, C, n_out).

        Raises `PendingInvalidated` if the engine's stream state moved
        on (``reset()`` while this push was outstanding), re-raises
        `TransientShardError` after re-arming the chunk (the server
        retries), and raises `ShardLost` only when recovery found no
        surviving devices."""
        if self._resolved is not None:
            return self._resolved
        if self._invalid:
            raise PendingInvalidated(
                "engine stream state moved on before this chunk resolved "
                "(reset() or a terminal failure) — its shard outputs are "
                "stale and will not be reassembled"
            )
        b, c = self._shape
        if self.n_out <= 0:
            self._resolved = np.zeros((b, c, 0), np.int32)
            return self._resolved
        eng = self._engine
        while True:
            try:
                out = eng._materialize(self)
                break
            except ShardCorruption as e:
                eng.fault.detections += 1
                eng.fault.corruptions += 1
                self._heals += 1
                if self._heals > eng.max_heals:
                    # persistent corruption == a lying shard: treat as lost
                    eng._recover(ShardLost(
                        e.shard,
                        f"shard {e.shard}: corruption persisted after "
                        f"{eng.max_heals} replays",
                    ))
                else:
                    eng._replay_one(self)
            except TransientShardError:
                eng.fault.detections += 1
                eng.fault.transients += 1
                eng._replay_one(self)  # re-arm so the next attempt is fresh
                raise
            except ShardLost as e:
                eng._recover(e)  # re-partitions + replays, or re-raises
        self._resolved = np.ascontiguousarray(out)
        self._shard_outs = None  # free device references + replay material
        self.snapshot = None
        self.chunk = None
        if eng is not None and self in eng._inflight:
            eng._inflight.remove(self)
        return self._resolved


class ShardedFilterBankEngine:
    """Overlap-save streaming FIR bank sharded over a (bank, data) mesh.

    Parameters
    ----------
    qbank : (B, taps) or (taps,) int array, or `repro.compiler.BlmacProgram`
        Quantized odd symmetric (type-I) coefficients, one row per filter
        — compiled once via `compile_bank` (content-addressed); passing a
        prebuilt / `load()`ed program warm-starts without recompiling.
        Shard subprograms are the program's memoized `select()` slices,
        shared with the mesh autotuner.
    channels : int
        Independent input channels C (all filtered by every filter).
    mesh : jax.sharding.Mesh | None
        A mesh with a ``bank`` axis and optionally a ``data`` axis
        (see `repro.distributed.sharding.bank_mesh`).  ``None`` builds a
        (n_devices, 1) mesh over every visible device.  A 1×1 mesh is
        valid and degrades to the single-device scheduled engine.
    n_bank_shards : int | None
        Force the filter-shard count (clamped to the mesh's bank axis);
        ``None`` lets the mesh-aware autotuner pick — including picking
        1 when sharding does not pay.
    data_mode : {"none", "channels", "time"} | None
        Force how the data axis is used; ``None`` lets the autotuner
        pick — including leaving the axis idle when the halo/split
        overhead loses to a single device per shard.
    tile, merge, chunk_hint, interpret, compiled
        As `repro.filters.FilterBankEngine`; per-shard tiles/modes are
        autotuned per shard unless ``tile`` pins them.  ``compiled``
        opts every per-shard sweep into the compiled execution lanes;
        each shard then runs the lane its winning plan names.
    fault_injector : repro.distributed.faultbank.FaultInjector | None
        Deterministic chaos hooks (tests/benchmarks only): consulted on
        every shard dispatch and materialize.
    shard_timeout : float | None
        Hard per-shard materialize deadline in seconds; expiry is
        escalated to `ShardTimeout` → shard loss.  ``None`` disables
        the watchdog timeout (heartbeats are still recorded).
    integrity_check : bool
        Recompute boundary output positions of every shard block on the
        host and raise `ShardCorruption` on mismatch (cost: a handful
        of taps-length dot products per shard per push).
    straggler_factor : float
        `ShardHealth` slow-shard multiple over the running median.
    """

    def __init__(
        self,
        qbank: np.ndarray,
        channels: int = 1,
        mesh: Mesh | None = None,
        n_bank_shards: int | None = None,
        data_mode: str | None = None,
        tile: int | None = None,
        merge: int | None = None,
        chunk_hint: int = 2048,
        interpret: bool | None = None,
        compiled: "bool | str" = False,
        fault_injector=None,
        shard_timeout: float | None = None,
        integrity_check: bool = False,
        straggler_factor: float = 3.0,
    ):
        from ..compiler import BlmacProgram, compile_bank
        from ..kernels.runtime import resolve_interpret

        if isinstance(qbank, BlmacProgram):
            program = qbank
        else:
            # CSD, packing and the §2.1 int32 bound — once, content-
            # addressed, shared with every other client.  int64 cast as
            # in `FilterBankEngine`: float input keeps its historical
            # truncation semantics; quantize via `compile_bank` directly.
            program = compile_bank(
                np.atleast_2d(np.asarray(qbank, np.int64))
            )
        if channels < 1:
            raise ValueError("channels must be >= 1")
        if mesh is None:
            mesh = bank_mesh()
        self.program = program
        self.qbank = program.qbank
        self.n_filters = program.n_filters
        self.taps = program.taps
        self.channels = int(channels)
        self.interpret = resolve_interpret(interpret)
        self._halo = self.taps - 1
        # construction preferences, reused verbatim by every recovery
        # re-configure so a rebuilt mesh honors the caller's pins
        self._force_bank = n_bank_shards
        self._force_data = data_mode
        self._tile_arg = tile
        self._merge_arg = merge
        self._chunk_hint = chunk_hint
        self._interpret_arg = interpret
        self._compiled_arg = compiled
        self.injector = fault_injector
        self.shard_timeout = shard_timeout
        self.integrity_check = bool(integrity_check)
        self._straggler_factor = float(straggler_factor)
        self.max_heals = 2  # corruption replays per chunk before loss
        self.fault = FaultStats()
        self._plain = None  # set when degraded to the unsharded engine
        self._inflight: list[PendingChunk] = []
        self._chunk_idx = 0
        self._configure(mesh)
        # overlap-save state: the last taps-1 samples of every channel
        self._tail = np.zeros((channels, 0), np.int32)
        self.samples_in = 0
        self.samples_out = 0

    # -- construction helpers ----------------------------------------------

    def _configure(self, mesh: Mesh, force_shards: int | None = None) -> None:
        """(Re)build the mesh-dependent half of the engine: autotuned
        plan, partition, per-shard dispatch closures, chunk quantum and
        the `ShardHealth` watchdog.  Called at construction and again by
        `_recover` with the surviving-device mesh."""
        from ..kernels.runtime import autotune_sharded_dispatch

        n_bank, n_data = mesh_bank_shape(mesh)
        if n_bank * n_data != mesh.size:
            raise ValueError(
                f"mesh must be ({'bank'}, {'data'})-shaped, got {mesh.shape}"
            )
        force = force_shards if force_shards is not None else self._force_bank
        if force is not None:
            force = max(1, min(int(force), n_bank, self.n_filters))
        self.plan, self.partition, schedules = autotune_sharded_dispatch(
            self.program, channels=self.channels, mesh_shape=(n_bank, n_data),
            tile=self._tile_arg, chunk_hint=self._chunk_hint,
            interpret=self._interpret_arg,
            force_shards=force, force_data=self._force_data,
            compiled=self._compiled_arg,
        )
        if self._merge_arg is not None:
            # re-plan only the scheduled shards whose merge differs,
            # KEEPING each shard's autotuned bank tile, and stamp the
            # override into the shard plans; the re-plan goes through the
            # shard subprogram's schedule memo, and predicted_us
            # intentionally keeps the autotuner's estimate for ITS
            # schedules — the cost model is not re-run for a forced merge
            import dataclasses

            merge = self._merge_arg
            schedules = tuple(
                self.program.select(rows).schedule(sched.tile_size, merge)
                if sched is not None and sched.merge != merge else sched
                for rows, sched in zip(self.partition.assign, schedules)
            )
            self.plan = dataclasses.replace(
                self.plan,
                shard_plans=tuple(
                    dataclasses.replace(p, merge=merge)
                    if p.mode == "scheduled" else p
                    for p in self.plan.shard_plans
                ),
            )
        self.mesh = mesh
        self.n_bank_shards = self.plan.n_bank_shards
        self.n_data = self.plan.n_data
        self.data_mode = self.plan.data_mode
        # chunk lengths are quantized to a multiple of every shard's tile
        # so ragged pushes hit a handful of jit-cache entries; only TIME
        # sharding additionally needs the ×n_data factor (each device's
        # slice must itself be tile-aligned and cover the halo it sends
        # rightwards) — channel sharding splits C, not time
        self._quantum = max(p.tile for p in self.plan.shard_plans)
        if self.data_mode == "time":
            self._quantum *= self.n_data
            while self._quantum // self.n_data < self._halo:
                self._quantum *= 2

        devices = np.asarray(mesh.devices).reshape(n_bank, n_data)
        self._device_rows = [devices[r] for r in range(n_bank)]
        self._shards = []
        for s, (rows, plan) in enumerate(
            zip(self.partition.assign, self.plan.shard_plans)
        ):
            self._shards.append(
                self._build_shard(
                    self.program.select(rows),  # the autotuner's subprogram
                    plan, schedules[s], devices[s % n_bank],
                )
            )
        self.health = ShardHealth(
            len(self._shards), timeout=self.shard_timeout,
            straggler_factor=self._straggler_factor,
        )

    def _configure_degraded(self, device) -> None:
        """Last-resort recovery target: one surviving device.  The SAME
        `BlmacProgram` is lowered through the plain single-device
        `FilterBankEngine` (its autotuned packed/specialized path), and
        the shard list collapses to one host-side closure.  ``device``
        is the survivor; on the forced-host-platform meshes the tests
        use, every "device" shares the host, so the plain engine's
        default placement is the survivor's compute either way."""
        from ..core.costmodel import BankDispatchPlan, ShardedBankPlan
        from .bank import FilterBankEngine

        del device  # simulated-loss placement note above
        plain = FilterBankEngine(
            self.program, channels=self.channels, tile=self._tile_arg,
            merge=self._merge_arg, chunk_hint=self._chunk_hint,
            interpret=self._interpret_arg, compiled=self._compiled_arg,
        )
        self._plain = plain
        plan1 = plain.dispatch_plan
        if plan1 is None:
            plan1 = BankDispatchPlan(
                mode=plain.mode, tile=plain.tile,
                bank_tile=plain.bank_tile or 0, merge=plain.merge,
                predicted_us=float("nan"),
            )
        self.plan = ShardedBankPlan(1, 1, "none", (plan1,),
                                    plan1.predicted_us)
        self.n_bank_shards, self.n_data, self.data_mode = 1, 1, "none"
        b = self.n_filters
        self.partition = BankPartition(
            assign=(np.arange(b),), inv=np.arange(b),
            cost=np.asarray([float(self.program.filter_costs.sum())]),
        )
        self._quantum = plain.tile
        self._device_rows = None
        self.mesh = None

        def run_plain(buf, n):
            return plain._apply(buf[:, :n])

        self._shards = [(run_plain, 0)]
        self.health = ShardHealth(
            1, timeout=self.shard_timeout,
            straggler_factor=self._straggler_factor,
        )
        self.fault.degraded_since = time.perf_counter()

    def _build_shard(self, subprogram, plan, schedule, dev_row):
        """One bank shard = (dispatch closure, device row).  Returns a
        callable ``fn(buf_np, n) -> device output`` where ``buf_np`` is
        the padded (C, n_pad) int32 buffer and ``n`` the valid length.
        ``subprogram`` is the shard's `BlmacProgram` slice — its pulse
        schedules and packed operands are the memoized artifacts the
        autotuner already costed."""
        if plan.mode == "specialized":  # n_data == 1 by construction
            pulses = subprogram.pulse_schedules()
            dev = dev_row[0]

            def run_specialized(buf, n):
                from ..kernels.blmac_fir import blmac_fir_specialized

                x = jax.device_put(jnp.asarray(buf, jnp.int32), dev)
                chans = [x[c] for c in range(self.channels)]
                return [
                    [
                        blmac_fir_specialized(
                            xc, p, self.taps, plan.tile, self.interpret
                        )
                        for xc in chans
                    ]
                    for p in pulses
                ]

            return run_specialized, 0

        fn = self._make_scheduled_fn(schedule, plan.tile, lane=plan.lane)
        if self.n_data == 1:
            dev = dev_row[0]
            ops = tuple(
                jax.device_put(jnp.asarray(g.packed.view(np.int32)), dev)
                for g in schedule.groups if g.sel_layers
            )

            def run_single(buf, n):
                x = jax.device_put(jnp.asarray(buf, jnp.int32), dev)
                return fn(x, *ops)

            return run_single, 0

        row_mesh = Mesh(dev_row, (DATA_AXIS,))
        repl = NamedSharding(row_mesh, P())
        ops = tuple(
            jax.device_put(jnp.asarray(g.packed.view(np.int32)), repl)
            for g in schedule.groups if g.sel_layers
        )
        shard_map = get_shard_map()
        nc = shard_map_no_check_kwargs()
        if self.data_mode == "channels":
            in_specs = (P(DATA_AXIS, None),) + (P(),) * len(ops)
            out_specs = P(None, DATA_AXIS, None)

            def body(buf, *op):
                return fn(buf, *op)

            offset = 0
        else:  # time: halo exchange, then each slice is self-contained
            in_specs = (P(None, DATA_AXIS),) + (P(),) * len(ops)
            out_specs = P(None, None, DATA_AXIS)
            n_data, halo = self.n_data, self._halo

            def body(buf, *op):
                chunk_local = buf.shape[-1]
                xl = halo_exchange_left(buf, DATA_AXIS, n_data, halo)
                return fn(xl, *op)[:, :, :chunk_local]

            # shard 0's halo is ppermute zero-fill: the first taps-1
            # concatenated outputs are warm-up, trimmed at reassembly
            offset = self._halo

        mapped = shard_map(
            body, mesh=row_mesh, in_specs=in_specs, out_specs=out_specs, **nc
        )
        jitted = jax.jit(mapped)
        x_sharding = NamedSharding(row_mesh, in_specs[0])

        def run_mapped(buf, n):
            x = jax.device_put(jnp.asarray(buf, jnp.int32), x_sharding)
            return jitted(x, *ops)

        return run_mapped, offset

    def _make_scheduled_fn(self, schedule, tile, lane=None):
        """Jitted scheduled-bank program for one shard: frame, then the
        shared `bank_schedule_apply` group loop (zeros for empty groups,
        one `_bank_call` per tile group, shard-order restoration).  The
        schedule is static (closed over); jit caches per input shape ×
        device.  ``ops`` carries only the NON-empty groups' operands
        (shard_map in_specs must match real arrays), re-slotted to the
        full per-group list here.  ``lane`` is the shard plan's execution
        lane ("interpret" → the legacy pallas_call + interpret flag)."""
        from ..kernels.blmac_fir import bank_schedule_apply, frame_signal_batch

        taps, interpret = self.taps, self.interpret
        if lane == "interpret":
            lane = None  # legacy path: honour the interpret flag
        has_layers = [bool(g.sel_layers) for g in schedule.groups]

        @jax.jit
        def fn(x, *ops):
            frames, _ = frame_signal_batch(x, taps, tile)
            it = iter(ops)
            full = [next(it) if h else None for h in has_layers]
            return bank_schedule_apply(
                frames, schedule, taps, tile, interpret,
                device_groups=full, lane=lane,
            )

        return fn

    # -- streaming API ------------------------------------------------------

    def push_async(self, chunk) -> PendingChunk:
        """Feed (C, n) samples (or (n,) when C == 1); dispatches every
        bank shard onto its mesh row and returns WITHOUT blocking on the
        device work — the double-buffered serving path overlaps the next
        chunk's host framing with this chunk's kernels.  The returned
        `PendingChunk` carries a `TailSnapshot` of the pre-push stream
        state, so the chunk can be replayed bit-exactly through a
        recovered mesh if a shard dies before it resolves."""
        chunk = np.asarray(chunk)
        if chunk.ndim == 1:
            chunk = chunk[None, :]
        if chunk.shape[0] != self.channels:
            raise ValueError(
                f"expected {self.channels} channels, got {chunk.shape[0]}"
            )
        idx = self._chunk_idx
        self._chunk_idx += 1
        snap = self.snapshot_tail()
        chunk_i = chunk.astype(np.int32)
        self.samples_in += chunk.shape[1]
        buf = np.concatenate([self._tail, chunk_i], axis=1)
        n = buf.shape[1]
        if n < self.taps:  # still priming
            self._tail = buf
            return PendingChunk(
                self, [], self.partition.inv, 0, [],
                self.n_filters, self.channels,
                snapshot=snap, chunk=chunk_i, chunk_idx=idx,
            )
        self._tail = (
            buf[:, n - self._halo:] if self._halo else buf[:, :0]
        )
        n_out = n - self.taps + 1
        outs, offsets = self._dispatch_shards(buf, n, idx)
        self.samples_out += n_out
        p = PendingChunk(
            self, outs, self.partition.inv, n_out, offsets,
            self.n_filters, self.channels,
            snapshot=snap, chunk=chunk_i, chunk_idx=idx,
        )
        self._inflight.append(p)
        return p

    def _dispatch_shards(self, buf, n, chunk_idx):
        """Pad ``buf`` to the chunk quantum and dispatch every shard.
        A dispatch-time `ShardError` (injected or real) is STORED in the
        shard's output slot instead of raised — detection and recovery
        happen at `result()`, preserving push_async's non-blocking
        contract."""
        n_pad = -(-n // self._quantum) * self._quantum
        if n_pad != buf.shape[1]:
            buf = np.pad(buf, ((0, 0), (0, n_pad - buf.shape[1])))
        outs, offsets = [], []
        for s, (fn, offset) in enumerate(self._shards):
            try:
                if self.injector is not None:
                    self.injector.on_dispatch(s, chunk_idx)
                y = fn(buf, n)
            except ShardError as e:
                if e.shard is None:
                    e.shard = s
                y = e
            outs.append(y)
            offsets.append(offset)
        return outs, offsets

    def push(self, chunk) -> np.ndarray:
        """Synchronous `push_async` → int32 (B, C, n_out)."""
        return self.push_async(chunk).result()

    def __call__(self, chunk) -> np.ndarray:
        return self.push(chunk)

    def apply_lanes(self, buf) -> np.ndarray:
        """Stateless one-shot bank application over ``channels`` lanes —
        the sharded twin of `FilterBankEngine.apply_lanes`, which is the
        dispatch surface `repro.serving.BankSessionServer` batches
        tenants through.  ``buf`` is (C, n) int samples with
        ``n >= taps``; returns the full (B, C, n − taps + 1) output
        without touching the engine's overlap-save tail or stream
        counters.

        The dispatch goes through the SAME fault path as `push`: each
        lane buffer rides a `PendingChunk` whose replay material is the
        buffer itself (an empty tail snapshot — the call is stateless),
        so a shard lost / timed out / corrupted mid-call triggers the
        normal re-partition + bit-exact replay and the call returns the
        recovered result.  A `TransientShardError` propagates to the
        caller (the session server's bounded retry), after invalidating
        the pending so no stale dispatch leaks into ``_inflight``."""
        from ..compiler.state import TailSnapshot

        buf = np.asarray(buf, np.int32)
        if buf.ndim != 2 or buf.shape[0] != self.channels:
            raise ValueError(
                f"expected ({self.channels}, n) lane buffer, "
                f"got shape {buf.shape}"
            )
        if buf.shape[1] < self.taps:
            raise ValueError(
                f"lane buffer has {buf.shape[1]} samples, "
                f"need >= taps ({self.taps})"
            )
        idx = self._chunk_idx
        self._chunk_idx += 1
        # empty-tail snapshot + the raw buffer == complete replay
        # material: `_replay_one` rebuilds concat(tail, chunk) == buf
        snap = TailSnapshot(
            program_key=self.program.key, channels=self.channels,
            samples_in=0, samples_out=0,
            tail=np.zeros((self.channels, 0), np.int32),
        )
        n = buf.shape[1]
        n_out = n - self.taps + 1
        outs, offsets = self._dispatch_shards(buf, n, idx)
        p = PendingChunk(
            self, outs, self.partition.inv, n_out, offsets,
            self.n_filters, self.channels,
            snapshot=snap, chunk=buf, chunk_idx=idx,
        )
        self._inflight.append(p)
        try:
            return p.result()
        except Exception:
            p.invalidate()
            raise

    def reset(self) -> None:
        """Drop all buffered history (start a new stream).  Outstanding
        `PendingChunk`s are INVALIDATED — their ``result()`` raises
        `PendingInvalidated` instead of silently reassembling shard
        outputs that belong to the abandoned stream."""
        for p in list(self._inflight):
            p.invalidate()
        self._inflight = []
        self._tail = np.zeros((self.channels, 0), np.int32)
        self.samples_in = 0
        self.samples_out = 0
        self._chunk_idx = 0

    @property
    def pending(self) -> int:
        """Samples buffered but not yet old enough to finish a window."""
        return self._tail.shape[1]

    # -- tail snapshot / restore (content-addressed stream state) -----------

    def snapshot_tail(self):
        """Freeze the overlap-save stream state as a
        `repro.compiler.TailSnapshot` keyed to this engine's program
        digest — the deterministic replay point behind fault recovery,
        and `save()`-able next to `BlmacProgram.save()` for cross-
        process stream resume."""
        from ..compiler.state import TailSnapshot

        return TailSnapshot(
            program_key=self.program.key, channels=self.channels,
            samples_in=self.samples_in, samples_out=self.samples_out,
            tail=self._tail.copy(),
        )

    def restore_tail(self, snapshot) -> None:
        """Adopt a `TailSnapshot` captured on THIS program (validated by
        content key — restoring another bank's stream is a loud error).
        Outstanding pendings are invalidated first (`reset` semantics)."""
        if snapshot.program_key != self.program.key:
            raise ValueError(
                f"snapshot belongs to program {snapshot.program_key[:12]}…, "
                f"this engine runs {self.program.key[:12]}…"
            )
        if int(snapshot.channels) != self.channels:
            raise ValueError(
                f"snapshot has {snapshot.channels} channels, "
                f"engine has {self.channels}"
            )
        self.reset()
        self._tail = np.asarray(snapshot.tail, np.int32).copy()
        self.samples_in = int(snapshot.samples_in)
        self.samples_out = int(snapshot.samples_out)

    # -- fault detection / recovery -----------------------------------------

    def _materialize(self, p: PendingChunk) -> np.ndarray:
        """Assemble one pending chunk on the host; raises the first
        shard fault it detects (stored dispatch errors, watchdog
        timeout, integrity-probe corruption)."""
        parts = []
        for s, (y, off) in enumerate(zip(p._shard_outs, p._offsets)):
            if isinstance(y, ShardError):
                raise y
            parts.append(self._materialize_shard(s, p, y, off))
        return np.concatenate(parts, axis=0)[p._inv]

    def _materialize_shard(self, s, p, y, off):
        inj = self.injector
        n_out = p.n_out

        def read():
            if inj is not None:
                inj.on_materialize(s, p.chunk_idx)
            if isinstance(y, list):  # specialized shard: per-filter arrays
                rows = [
                    np.stack([np.asarray(a)[:n_out] for a in chans])
                    for chans in y
                ]
                return np.stack(rows)
            return np.asarray(y)[:, :, off: off + n_out]

        t0 = time.perf_counter()
        if self.health.timeout is not None:
            part = self._with_timeout(read, s)
        else:
            part = read()
        if self.health.record(s, time.perf_counter() - t0):
            self.fault.stragglers += 1
        if inj is not None:
            part = inj.corrupt(s, p.chunk_idx, part)
        if self.integrity_check:
            self._verify_part(s, part, p)
        return part

    def _with_timeout(self, fn, s):
        """Run one shard materialize under the `ShardHealth` hard
        deadline; expiry escalates to `ShardTimeout` (→ loss).  The
        worker thread is abandoned, not joined — a wedged device read
        must not wedge the recovery path too."""
        from concurrent.futures import ThreadPoolExecutor
        from concurrent.futures import TimeoutError as FuturesTimeout

        ex = ThreadPoolExecutor(max_workers=1)
        try:
            fut = ex.submit(fn)
            try:
                return fut.result(timeout=self.health.timeout)
            except FuturesTimeout:
                raise ShardTimeout(
                    s, f"shard {s} exceeded the {self.health.timeout:.3f}s "
                       f"watchdog timeout"
                ) from None
        finally:
            ex.shutdown(wait=False)

    def _verify_part(self, s, part, p):
        """Boundary integrity probe: recompute a handful of this shard's
        output positions on the host (int64 dot products over the
        snapshot tail + raw chunk) and compare bit-for-bit.  Probed
        positions are t = 0, the final output, and every data-axis
        slice boundary — where halo-exchange or reassembly corruption
        shows up first."""
        rows = self.partition.assign[s]
        full = np.concatenate(
            [np.asarray(p.snapshot.tail, np.int64),
             np.asarray(p.chunk, np.int64)], axis=1,
        )
        n_out = p.n_out
        pos = {0, n_out - 1}
        for j in range(1, self.n_data):
            pos.add(min(max(j * n_out // self.n_data, 0), n_out - 1))
        pos = sorted(pos)
        wins = np.stack([full[:, t: t + self.taps] for t in pos])  # (P,C,taps)
        expect = np.einsum("rj,pcj->rpc", self.qbank[rows], wins)
        got = np.asarray(part, np.int64)[:, :, pos].transpose(0, 2, 1)
        if not np.array_equal(got, expect):
            raise ShardCorruption(
                s, f"shard {s} failed the boundary integrity probe on "
                   f"chunk {p.chunk_idx}"
            )

    def _recover(self, err: ShardLost) -> None:
        """Handle a detected shard loss: drop the dead mesh row,
        re-partition the bank over the survivors (recovery shard count
        chosen by modelled cost), rebuild the dispatch closures, and
        replay every in-flight chunk from its tail snapshot.  Raises
        `ShardLost` when no surviving device remains."""
        self.fault.detections += 1
        if isinstance(err, ShardTimeout):
            self.fault.timeouts += 1
        s = err.shard
        rows = self._device_rows
        if self._plain is not None or rows is None or len(rows) <= 1:
            raise ShardLost(
                s, f"shard {s} lost with no surviving devices to "
                   f"re-partition onto: {err}"
            ) from err
        t0 = time.perf_counter()
        self.fault.lost_shards += 1
        if self.injector is not None:
            self.injector.on_shard_removed(s)
        del rows[s]
        n_bank = len(rows)
        n_data = int(np.asarray(rows[0]).size)
        if n_bank == 1 and n_data == 1:
            self._configure_degraded(np.asarray(rows[0]).reshape(-1)[0])
        else:
            devices = [d for row in rows
                       for d in np.asarray(row).reshape(-1)]
            target = self._choose_recovery_shards(n_bank, n_data)
            self._configure(bank_mesh(n_bank, n_data, devices=devices),
                            force_shards=target)
        self._replay_inflight()
        self.fault.recoveries += 1
        self.fault.last_recovery_s = time.perf_counter() - t0

    def _choose_recovery_shards(self, n_bank: int, n_data: int) -> int:
        """Pick the recovery target's bank-shard count by modelled cost
        (`repro.core.costmodel.predict_recovery_us`): each candidate
        pays for its fresh per-shard schedules and the in-flight replay,
        then its steady-state latency over the amortization horizon.
        Candidates are the full surviving row count and the power of two
        below it (partitions the program has likely already memoized).
        A caller-forced shard count short-circuits the sweep."""
        from ..core.costmodel import predict_recovery_us
        from ..kernels.runtime import autotune_sharded_dispatch

        if self._force_bank is not None:
            return max(1, min(int(self._force_bank), n_bank, self.n_filters))
        replay = sum(p.n_out for p in self._inflight)
        pow2 = 1
        while pow2 * 2 <= n_bank:
            pow2 *= 2
        best, best_us = None, float("inf")
        for cand in sorted({min(n_bank, self.n_filters),
                            min(pow2, self.n_filters)}):
            plan, _, schedules = autotune_sharded_dispatch(
                self.program, channels=self.channels,
                mesh_shape=(n_bank, n_data), tile=self._tile_arg,
                chunk_hint=self._chunk_hint, interpret=self._interpret_arg,
                force_shards=cand, force_data=self._force_data,
            )
            n_scheduled = sum(1 for sc in schedules if sc is not None)
            us = predict_recovery_us(plan.predicted_us, n_scheduled, replay)
            if us < best_us:
                best, best_us = cand, us
        return best

    def _replay_inflight(self) -> None:
        """Re-dispatch every unresolved chunk through the recovered
        mesh, oldest first — each from its own tail snapshot, so the
        replayed stream is bit-exact with the uninterrupted one."""
        for p in list(self._inflight):
            self._replay_one(p)

    def _replay_one(self, p: PendingChunk) -> None:
        """Re-dispatch ONE pending chunk from its tail snapshot and
        swap the fresh shard outputs (and the current partition's
        reassembly recipe) into the pending."""
        buf = np.concatenate(
            [np.asarray(p.snapshot.tail, np.int32), p.chunk], axis=1
        )
        outs, offsets = self._dispatch_shards(buf, buf.shape[1], p.chunk_idx)
        p._rearm(outs, offsets, self.partition.inv)
        self.fault.replayed_chunks += 1
        self.fault.replayed_samples += p.n_out

    # -- introspection ------------------------------------------------------

    def fault_stats(self) -> dict:
        """JSON-ready fault/recovery counters (see
        `repro.distributed.faultbank.FaultStats`) plus the live mesh
        shape, in-flight depth, injected-fault counts (when a
        `FaultInjector` is attached) and the `ShardHealth` heartbeat
        summary — the observability surface next to
        `repro.compiler.cache_stats()`."""
        d = self.fault.as_dict()
        d.update(
            n_bank_shards=self.n_bank_shards,
            n_data=self.n_data,
            data_mode=self.data_mode,
            inflight=len(self._inflight),
            injected=(
                self.injector.faults_injected()
                if self.injector is not None else None
            ),
            health=self.health.summary(),
        )
        return d

    def time_shards(self, chunk, repeats: int = 3) -> np.ndarray:
        """(n_shards,) best-of-``repeats`` isolated wall seconds per bank
        shard for one ``chunk``, without disturbing the stream state.

        Forced host-platform devices share the host's cores, so timing
        shards CONCURRENTLY measures core contention, not mesh scaling;
        this probe times each shard's dispatch alone (dispatch → block),
        which is the per-machine number the paper's replicated-instance
        throughput model aggregates.  `benchmarks/bank_sharded.py` builds
        its critical-path scaling row from exactly this.
        """
        chunk = np.atleast_2d(np.asarray(chunk)).astype(np.int32)
        n = chunk.shape[1]
        if n < self.taps:
            raise ValueError("chunk shorter than the filter")
        n_pad = -(-n // self._quantum) * self._quantum
        buf = np.pad(chunk, ((0, 0), (0, n_pad - n)))
        for fn, _ in self._shards:  # warm-up: compile
            jax.block_until_ready(fn(buf, n))
        # round-robin the repeats so one transient host hiccup cannot
        # poison every sample of a single shard (min-per-shard is only
        # robust when a shard's samples are spread over the run)
        times = np.full(len(self._shards), np.inf)
        for _ in range(repeats):
            for s, (fn, _) in enumerate(self._shards):
                t0 = time.perf_counter()
                jax.block_until_ready(fn(buf, n))
                times[s] = min(times[s], time.perf_counter() - t0)
        return times

    def describe(self) -> str:
        """One line for logs: mesh, shard modes, balance, predicted cost."""
        modes = ",".join(p.mode[:4] for p in self.plan.shard_plans)
        degraded = " DEGRADED" if self._plain is not None else ""
        return (
            f"sharded-bank B={self.n_filters} C={self.channels} "
            f"mesh=({self.n_bank_shards}x{self.n_data}){degraded} "
            f"data={self.data_mode} modes=[{modes}] "
            f"imbalance={self.partition.imbalance:.2f} "
            f"predicted={self.plan.predicted_us:.0f}us"
        )
