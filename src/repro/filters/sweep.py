"""The paper's §3.1 filter-space sweep, exactly.

With the frequency axis divided into N parts (paper: N = 100):
  lowpass  : cutoffs  i/N, i = 1..N-1                    → N−1 filters
  highpass : same                                         → N−1 filters
  bandpass : pairs (i/N, j/N), 1 ≤ i < j ≤ N−1            → (N−1)(N−2)/2
  bandstop : same pairs                                   → (N−1)(N−2)/2
total N(N−1) per tap count (9,900 at N=100); taps sweep 55..255 odd
(101 values) × {Hamming, Kaiser} ⇒ 1,980,000 filters.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator, Sequence

import numpy as np

from .fir import FilterKind, bands_for, firwin_batch

__all__ = ["SweepSpec", "sweep_specs", "sweep_bank", "TAPS_RANGE"]

TAPS_RANGE = tuple(range(55, 256, 2))  # odd only: type I


@dataclass(frozen=True)
class SweepSpec:
    kind: FilterKind
    cutoff: float | tuple[float, float]


def sweep_specs(n_div: int = 100) -> list[SweepSpec]:
    """All N(N−1) (kind, cutoff) specs for one tap count."""
    fs = [i / n_div for i in range(1, n_div)]
    specs: list[SweepSpec] = []
    specs += [SweepSpec("lowpass", f) for f in fs]
    specs += [SweepSpec("highpass", f) for f in fs]
    pairs = [(f1, f2) for i, f1 in enumerate(fs) for f2 in fs[i + 1 :]]
    specs += [SweepSpec("bandpass", p) for p in pairs]
    specs += [SweepSpec("bandstop", p) for p in pairs]
    assert len(specs) == n_div * (n_div - 1)
    return specs


def sweep_bank(
    numtaps: int,
    n_div: int = 100,
    window: str | tuple = "hamming",
    specs: Sequence[SweepSpec] | None = None,
    workers: int | None = None,
) -> np.ndarray:
    """Design the full (n_div*(n_div-1), numtaps) bank for one tap count.

    ``workers`` fans the design across a process pool (see
    `firwin_batch`); the window vector itself is memoized, so repeat
    visits of a tap count reuse it."""
    if specs is None:
        specs = sweep_specs(n_div)
    return firwin_batch(
        numtaps, [bands_for(s.kind, s.cutoff) for s in specs], window,
        workers=workers,
    )


def iter_sweep(
    n_div: int = 100,
    taps: Sequence[int] = TAPS_RANGE,
    window: str | tuple = "hamming",
    workers: int | None = None,
) -> Iterator[tuple[int, np.ndarray]]:
    """Yield (numtaps, bank) across the tap sweep."""
    specs = sweep_specs(n_div)
    for t in taps:
        yield t, sweep_bank(t, n_div, window, specs, workers=workers)
