"""Windowed-sinc FIR design, vectorized over whole filter banks.

``firwin_batch`` reproduces ``scipy.signal.firwin`` (windowed-sinc with
passband-centre scaling) but designs thousands of filters in one numpy
pass — the paper's sweep is 1,980,000 filters (§3.1) and scipy's one-at-a-
time loop would take ~30 CPU-minutes; this takes seconds.  Cross-validated
against scipy to 1e-12 in ``tests/test_filters.py``.

Normalized frequencies follow scipy's convention: Nyquist = 1.0.
"""
from __future__ import annotations

import functools
from concurrent.futures import ProcessPoolExecutor
from typing import Literal, Sequence

import numpy as np

FilterKind = Literal["lowpass", "highpass", "bandpass", "bandstop"]

__all__ = ["FilterKind", "bands_for", "window_values", "firwin_batch",
           "design_bank", "spread_lowpass_qbank"]


def bands_for(kind: FilterKind, cutoff: float | tuple[float, float]) -> np.ndarray:
    """Passband edges [(left, right), ...] for one filter, scipy-style."""
    if kind == "lowpass":
        return np.array([[0.0, float(cutoff)]])
    if kind == "highpass":
        return np.array([[float(cutoff), 1.0]])
    f1, f2 = cutoff  # type: ignore[misc]
    if kind == "bandpass":
        return np.array([[float(f1), float(f2)]])
    if kind == "bandstop":
        return np.array([[0.0, float(f1)], [float(f2), 1.0]])
    raise ValueError(f"unknown filter kind {kind!r}")


@functools.lru_cache(maxsize=256)
def _window_cached(numtaps: int, key) -> np.ndarray:
    w = np.hamming(numtaps) if key == "hamming" else np.kaiser(numtaps, key[1])
    w.setflags(write=False)  # memoized: callers share one read-only array
    return w


def window_values(numtaps: int, window: str | tuple = "hamming") -> np.ndarray:
    """Symmetric window samples; supports the paper's two windows.

    Memoized per (numtaps, window): the §3.1 sweep designs 9,900 filters
    per tap count and the window vector is identical for all of them —
    and for every repeat visit of that tap count.  Returns a READ-ONLY
    array; copy before mutating.
    """
    if window == "hamming":
        key = "hamming"
    elif isinstance(window, tuple) and window[0] == "kaiser":
        key = ("kaiser", float(window[1]))
    else:
        raise ValueError(f"unsupported window {window!r}")
    return _window_cached(numtaps, key)


def firwin_batch(
    numtaps: int,
    bands: Sequence[np.ndarray],
    window: str | tuple = "hamming",
    scale: bool = True,
    workers: int | None = None,
) -> np.ndarray:
    """Design ``len(bands)`` filters of ``numtaps`` taps at once.

    ``bands[i]`` is an (n_bands_i, 2) array of passband edges.  Returns
    float64 (n_filters, numtaps).  Matches scipy.signal.firwin bit-for-bit
    up to float roundoff (same summed-sinc construction, same passband-
    centre scaling rule).

    ``workers`` > 1 splits the bank across a process pool — every filter
    is designed independently (the passband-centre scaling is per-filter),
    so chunked results concatenate exactly.  Worth it from ~10⁵ (filter ×
    tap) products; the §3.1 sweep is ~10⁶ per tap count.
    """
    if numtaps % 2 == 0:
        raise ValueError("type-I FIR filters need an odd tap count")
    if workers and workers > 1 and len(bands) >= 4 * workers:
        chunks = np.array_split(np.arange(len(bands)), workers)
        with ProcessPoolExecutor(max_workers=workers) as pool:
            parts = pool.map(
                _firwin_chunk,
                [(numtaps, [bands[i] for i in c], window, scale) for c in chunks],
            )
        return np.concatenate(list(parts), axis=0)
    nf = len(bands)
    m = np.arange(numtaps, dtype=np.float64) - (numtaps - 1) / 2.0  # (T,)
    # Flatten all bands with an owner index so one vector pass handles
    # filters with different band counts (bandstop has two).
    owners = np.concatenate(
        [np.full(len(b), i, dtype=np.int64) for i, b in enumerate(bands)]
    )
    edges = np.concatenate([np.asarray(b, np.float64) for b in bands], axis=0)
    if np.any(edges[:, 0] >= edges[:, 1]) or np.any(edges < 0) or np.any(edges > 1):
        raise ValueError("band edges must satisfy 0 <= left < right <= 1")
    left, right = edges[:, 0:1], edges[:, 1:2]  # (B, 1)
    contrib = right * np.sinc(right * m) - left * np.sinc(left * m)  # (B, T)
    h = np.zeros((nf, numtaps), np.float64)
    np.add.at(h, owners, contrib)
    h *= window_values(numtaps, window)
    if scale:
        # scipy: normalize unit gain at the centre of the *first* band
        first = np.searchsorted(owners, np.arange(nf))
        l0, r0 = edges[first, 0], edges[first, 1]
        scale_f = np.where(l0 == 0.0, 0.0, np.where(r0 == 1.0, 1.0, (l0 + r0) / 2))
        c = np.cos(np.pi * m[None, :] * scale_f[:, None])  # (F, T)
        s = np.einsum("ft,ft->f", h, c)
        h /= s[:, None]
    return h


def _firwin_chunk(args) -> np.ndarray:
    """Process-pool worker: design one contiguous slice of a bank."""
    numtaps, bands, window, scale = args
    return firwin_batch(numtaps, bands, window, scale)


def design_bank(
    numtaps: int,
    specs: Sequence[tuple[FilterKind, float | tuple[float, float]]],
    window: str | tuple = "hamming",
) -> np.ndarray:
    """Convenience: design a heterogeneous bank from (kind, cutoff) specs."""
    return firwin_batch(numtaps, [bands_for(k, c) for k, c in specs], window)


def spread_lowpass_qbank(
    n_filters: int, taps: int, coeff_bits: int = 16
) -> np.ndarray:
    """Quantized lowpass bank with evenly spread cutoffs in (0.05, 0.95) —
    the shared demo/benchmark workload (BENCH_fir.json, BENCH_sharded.json,
    the --fir-bank serving demo, and the sharded tests all use this one
    construction so their banks cannot silently diverge)."""
    from ..core.quantize import po2_quantize_batch

    cuts = 0.05 + 0.9 * (np.arange(n_filters) + 0.5) / n_filters
    q, _ = po2_quantize_batch(
        design_bank(taps, [("lowpass", float(c)) for c in cuts]), coeff_bits
    )
    return q
