"""Reference FIR application paths (numpy, exact integer arithmetic).

Three algorithms, all computing y[t] = Σ_j w[j] · x[t+j] for a length-N
window (the machine's orientation; flip w for convolution):

  * ``fir_direct``      — classical MACs,
  * ``fir_symmetric``   — Eq. 3 pre-add + half-length dot,
  * ``fir_bit_layers``  — Eq. 2: Horner over CSD bit layers, no multiplies
                          (the algorithm the Pallas kernel implements); the
                          type-I path delegates to ``fir_bit_layers_batch``
                          so single-filter and bank semantics are one code
                          path.

All three must agree bit-for-bit on integer inputs (property-tested).

``fir_bit_layers_batch`` is the repo's independent ground truth: the
``"oracle"`` backend of `repro.compiler.lower` reads only the compiled
program's quantized coefficients and runs the naive dense Eq. 2 loop
below — deliberately sharing NO schedule machinery with the kernels it
verifies.
"""
from __future__ import annotations

import numpy as np

from ..core.csd import csd_digits, require_type1

__all__ = [
    "sliding_windows",
    "fir_direct",
    "fir_symmetric",
    "fir_bit_layers",
    "fir_bit_layers_batch",
]


def sliding_windows(x: np.ndarray, n: int) -> np.ndarray:
    """(T,) → (T-n+1, n) view of ascending windows."""
    return np.lib.stride_tricks.sliding_window_view(x, n)


def fir_direct(x: np.ndarray, w: np.ndarray) -> np.ndarray:
    x = np.asarray(x, np.int64)
    w = np.asarray(w, np.int64)
    return sliding_windows(x, w.size) @ w


def fir_symmetric(x: np.ndarray, w: np.ndarray) -> np.ndarray:
    """Eq. 3: fold the symmetric window pairs first (N/2 adds), then an
    (N/2+1)-point dot product."""
    x = np.asarray(x, np.int64)
    w = np.asarray(w, np.int64)
    n = w.size
    if n % 2 == 0 or not np.array_equal(w, w[::-1]):
        raise ValueError("fir_symmetric needs an odd symmetric (type-I) filter")
    half = n // 2
    win = sliding_windows(x, n)
    folded = win[:, :half] + win[:, n - 1 : half:-1]  # (T', N/2)
    centre = win[:, half]
    return folded @ w[:half] + centre * w[half]


def fir_bit_layers(x: np.ndarray, w: np.ndarray, symmetric: bool = True) -> np.ndarray:
    """Eq. 2, MSB-first Horner over CSD bit layers: acc ← 2·acc + Σ ±x.

    One vectorized add per *pulse* across all outputs — the numpy analogue
    of both the FPGA machine (pulse-serial over one sample) and the Pallas
    kernel (pulse-serial over a 128-lane tile).

    The symmetric (type-I) path is a thin shim over the batched bank
    oracle `fir_bit_layers_batch` — a B=1, C=1 bank — so the pre-bank
    single-filter code path cannot drift from the bank semantics every
    kernel is verified against.  Only the ``symmetric=False`` variant
    (which has no bank equivalent: banks require type-I filters) keeps
    its own pulse-serial loop.
    """
    x = np.asarray(x, np.int64)
    w = np.asarray(w, np.int64)
    n = w.size
    if symmetric:
        if n % 2 == 0 or not np.array_equal(w, w[::-1]):
            raise ValueError("symmetric path needs a type-I filter")
        return fir_bit_layers_batch(x, w)[0, 0, :]
    data = sliding_windows(x, n)
    digits = csd_digits(w)  # (M, L) LSB-first
    acc = np.zeros(data.shape[0], np.int64)
    for layer in range(digits.shape[1] - 1, -1, -1):  # MSB → LSB
        acc <<= 1
        d = digits[:, layer]
        for j in np.nonzero(d)[0]:  # one vector add per pulse
            if d[j] > 0:
                acc += data[:, j]
            else:
                acc -= data[:, j]
    return acc


def fir_bit_layers_batch(x: np.ndarray, w: np.ndarray) -> np.ndarray:
    """Batched Eq. 2 oracle: B symmetric filters × C channels at once.

    ``x`` is (C, T) (or (T,), treated as one channel); ``w`` is (B, taps)
    (or (taps,)) odd symmetric integer coefficients sharing one tap count.
    Returns int64 (B, C, T - taps + 1) — the bit-exact reference for
    `repro.kernels.blmac_fir_bank`.  One einsum contraction per bit layer:
    the pulse count of the whole bank is the number of scalar adds.
    """
    x2 = np.atleast_2d(np.asarray(x, np.int64))
    w2 = np.atleast_2d(np.asarray(w, np.int64))
    n = require_type1(w2, "batched path")
    half = n // 2
    win = np.lib.stride_tricks.sliding_window_view(x2, n, axis=-1)  # (C,T',n)
    data = np.concatenate(
        [win[..., :half] + win[..., n - 1 : half : -1], win[..., half : half + 1]],
        axis=-1,
    )  # (C, T', M)
    digits = csd_digits(w2[:, : half + 1])  # (B, M, L) LSB-first
    acc = np.zeros((w2.shape[0], data.shape[0], data.shape[1]), np.int64)
    # Deliberately the naive dense Eq. 2 recursion — NO layer-skip or
    # superlayer merging: this is the independent oracle the scheduled
    # Pallas kernel is differentially verified against, so it must not
    # share the schedule mechanism under test.
    for layer in range(digits.shape[2] - 1, -1, -1):  # MSB → LSB
        acc <<= 1
        acc += np.einsum("bm,ctm->bct", digits[:, :, layer], data)
    return acc
