"""FIR filter substrate: vectorized windowed-sinc design (scipy-compatible),
the paper's 1.98M-filter sweep, exact reference application paths, and the
streaming overlap-save filter-bank engine."""
from .apply import (fir_bit_layers, fir_bit_layers_batch, fir_direct,
                    fir_symmetric, sliding_windows)
from .bank import SPECIALIZE_THRESHOLD, FilterBankEngine
from .fir import (FilterKind, bands_for, design_bank, firwin_batch,
                  spread_lowpass_qbank, window_values)
from .sharded import ShardedFilterBankEngine
from .sweep import TAPS_RANGE, SweepSpec, iter_sweep, sweep_bank, sweep_specs

__all__ = [
    "FilterBankEngine",
    "ShardedFilterBankEngine",
    "SPECIALIZE_THRESHOLD",
    "fir_bit_layers",
    "fir_bit_layers_batch",
    "fir_direct",
    "fir_symmetric",
    "sliding_windows",
    "FilterKind",
    "bands_for",
    "design_bank",
    "firwin_batch",
    "spread_lowpass_qbank",
    "window_values",
    "TAPS_RANGE",
    "SweepSpec",
    "iter_sweep",
    "sweep_bank",
    "sweep_specs",
]
