"""FIR filter substrate: vectorized windowed-sinc design (scipy-compatible),
the paper's 1.98M-filter sweep, and exact reference application paths."""
from .apply import fir_bit_layers, fir_direct, fir_symmetric, sliding_windows
from .fir import FilterKind, bands_for, design_bank, firwin_batch, window_values
from .sweep import TAPS_RANGE, SweepSpec, iter_sweep, sweep_bank, sweep_specs

__all__ = [
    "fir_bit_layers",
    "fir_direct",
    "fir_symmetric",
    "sliding_windows",
    "FilterKind",
    "bands_for",
    "design_bank",
    "firwin_batch",
    "window_values",
    "TAPS_RANGE",
    "SweepSpec",
    "iter_sweep",
    "sweep_bank",
    "sweep_specs",
]
