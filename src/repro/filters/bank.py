"""Streaming filter-bank engine: overlap-save BLMAC over B filters × C channels.

`FilterBankEngine` is the serving-side face of the batched bank kernel
(`repro.kernels.blmac_fir_bank`): feed it arbitrary-length chunks of a
multi-channel sample stream and it returns, for every filter in the bank,
the output samples that became computable — carrying the ``taps − 1``
sample tail between chunks (classical overlap-save) so consecutive pushes
produce one gapless output stream per (filter, channel) pair.

Mode selection mirrors the hardware trade-off:

  * ``"specialized"`` — per-filter pulse-baked programs from the LRU
    program cache; wins for small banks where per-call overhead is
    amortized and the add count is exactly the pulse count.
  * ``"packed"``      — ONE `pallas_call` for the whole bank on packed
    uint32 trit words; wins as soon as the bank is wide enough that
    batching beats per-filter dispatch (default crossover: 8 filters).
  * ``"auto"``        — pick by bank size (the default).

Bit-exactness: both modes agree with `repro.filters.fir_bit_layers_batch`
to the last bit on integer inputs (property-tested in `tests/test_bank.py`).
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from ..core.csd import require_type1

SPECIALIZE_THRESHOLD = 8

__all__ = ["FilterBankEngine", "SPECIALIZE_THRESHOLD"]


class FilterBankEngine:
    """Overlap-save streaming application of a quantized FIR filter bank.

    Parameters
    ----------
    qbank : (B, taps) or (taps,) int array
        Quantized odd symmetric (type-I) coefficients, one row per filter.
    channels : int
        Number of independent input channels C (all filtered by every filter).
    tile : int
        Output samples per kernel grid step (lane-parallel width).
    mode : {"auto", "packed", "specialized"}
    interpret : bool | None
        Pallas interpret override; None = backend default.
    """

    def __init__(
        self,
        qbank: np.ndarray,
        channels: int = 1,
        tile: int = 512,
        mode: str = "auto",
        bank_tile: int | None = None,
        interpret: bool | None = None,
    ):
        from ..kernels.blmac_fir import (_pad_to, default_bank_tile,
                                         pack_bank_trits, pulses_msb_first)

        qbank = np.atleast_2d(np.asarray(qbank, np.int64))
        if qbank.ndim != 2:
            raise ValueError("qbank must be (n_filters, taps)")
        taps = require_type1(qbank, "FilterBankEngine")
        if channels < 1:
            raise ValueError("channels must be >= 1")
        if mode not in ("auto", "packed", "specialized"):
            raise ValueError(f"unknown mode {mode!r}")
        if mode == "auto":
            mode = (
                "specialized"
                if qbank.shape[0] < SPECIALIZE_THRESHOLD
                else "packed"
            )
        self.qbank = qbank
        self.n_filters = int(qbank.shape[0])
        self.taps = int(taps)
        self.channels = int(channels)
        self.tile = int(tile)
        self.mode = mode
        self.bank_tile = bank_tile
        self.interpret = interpret
        if mode == "packed":
            # pad + int32-view + upload the packed bank ONCE; push() then
            # feeds a device-resident operand instead of re-staging the
            # whole bank every chunk
            packed = pack_bank_trits(qbank)  # (B, L, W) uint32
            self.bank_tile = bank_tile or default_bank_tile(self.n_filters)
            b_pad = _pad_to(self.n_filters, self.bank_tile)
            if b_pad != self.n_filters:
                packed = np.concatenate([
                    packed,
                    np.zeros((b_pad - self.n_filters,) + packed.shape[1:],
                             packed.dtype),
                ])
            self._packed = jnp.asarray(packed.view(np.int32))
            self._schedules = None
        else:
            self._packed = None
            self._schedules = [pulses_msb_first(row) for row in qbank]
        # overlap-save state: the last taps-1 samples of every channel
        self._tail = np.zeros((channels, 0), np.int32)
        self.samples_in = 0
        self.samples_out = 0
        self._cycle_cache: dict[tuple, np.ndarray] = {}

    # -- cost model ---------------------------------------------------------

    def predicted_machine_cycles(self, spec=None) -> np.ndarray:
        """(B,) clock cycles per output each filter would cost on the §4
        FPGA dot-product machine (one cycle per RLE code + overhead).

        ``spec`` is a `repro.core.MachineSpec` (default: the paper's
        127-tap spec parameters applied to this bank's tap count); results
        are cached per spec.  Agrees exactly with both simulators —
        `FirBlmacVMachine` asserts this in `tests/differential.py`.
        """
        from ..core.costmodel import machine_cycles_batch
        from ..core.machine import MachineSpec

        if spec is None:
            spec = MachineSpec(taps=self.taps)
        if spec.taps != self.taps:
            raise ValueError(
                f"spec is for {spec.taps} taps, bank has {self.taps}"
            )
        key = (spec.n_layers, spec.start_overhead, spec.fused_last_add)
        if key not in self._cycle_cache:
            cycles = machine_cycles_batch(
                self.qbank,
                n_layers=spec.n_layers,
                overhead=spec.start_overhead,
                fused_last_add=spec.fused_last_add,
            )
            cycles.setflags(write=False)  # shared cache entry: no mutation
            self._cycle_cache[key] = cycles
        return self._cycle_cache[key]

    def predicted_mean_cycles(self, spec=None) -> float:
        """Bank-average §4 machine cycles per output sample."""
        return float(self.predicted_machine_cycles(spec).mean())

    # -- streaming API ------------------------------------------------------

    def push(self, chunk) -> np.ndarray:
        """Feed (C, n) samples (or (n,) when C == 1); returns the newly
        computable outputs as int32 (B, C, n_out) — n_out may be 0 while
        the engine is still priming its taps−1 history."""
        chunk = np.asarray(chunk)
        if chunk.ndim == 1:
            chunk = chunk[None, :]
        if chunk.shape[0] != self.channels:
            raise ValueError(
                f"expected {self.channels} channels, got {chunk.shape[0]}"
            )
        self.samples_in += chunk.shape[1]
        buf = np.concatenate([self._tail, chunk.astype(np.int32)], axis=1)
        n = buf.shape[1]
        if n < self.taps:  # still priming
            self._tail = buf
            return np.zeros((self.n_filters, self.channels, 0), np.int32)
        self._tail = buf[:, n - (self.taps - 1):] if self.taps > 1 else buf[:, :0]
        y = self._apply(buf)
        self.samples_out += y.shape[2]
        return y

    def __call__(self, chunk) -> np.ndarray:
        return self.push(chunk)

    def reset(self) -> None:
        """Drop all buffered history (start a new stream)."""
        self._tail = np.zeros((self.channels, 0), np.int32)
        self.samples_in = 0
        self.samples_out = 0

    @property
    def pending(self) -> int:
        """Samples buffered but not yet old enough to finish a window."""
        return self._tail.shape[1]

    # -- one-shot application ----------------------------------------------

    def _apply(self, buf: np.ndarray) -> np.ndarray:
        from ..kernels.blmac_fir import blmac_fir_bank, blmac_fir_specialized

        n = buf.shape[1]
        n_out = n - self.taps + 1
        # Quantize the jit shape: pad the buffer to a tile multiple so a
        # stream of ragged chunk sizes hits a handful of compile-cache
        # entries instead of retracing every push; windows that reach
        # into the padding are dropped below.
        n_pad = -(-n // self.tile) * self.tile
        if n_pad != n:
            buf = np.pad(buf, ((0, 0), (0, n_pad - n)))
        x = jnp.asarray(buf, jnp.int32)
        if self.mode == "packed":
            from ..kernels.blmac_fir import _bank_call, frame_signal_batch
            from ..kernels.runtime import resolve_interpret

            frames, _ = frame_signal_batch(x, self.taps, self.tile)
            y = _bank_call(
                frames,
                self._packed,
                self.taps,
                int(self._packed.shape[1]),
                self.tile,
                self.bank_tile,
                resolve_interpret(self.interpret),
            )  # (B_pad, C, n_tiles, tile)
            y = y.reshape(y.shape[0], self.channels, -1)
            return np.asarray(y[: self.n_filters, :, :n_out])
        out = np.empty((self.n_filters, self.channels, n_out), np.int32)
        for b, pulses in enumerate(self._schedules):
            for c in range(self.channels):
                out[b, c] = np.asarray(
                    blmac_fir_specialized(
                        x[c], pulses, self.taps, self.tile, self.interpret
                    )
                )[:n_out]
        return out
