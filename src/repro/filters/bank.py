"""Streaming filter-bank engine: overlap-save BLMAC over B filters × C channels.

`FilterBankEngine` is the serving-side face of the scheduled bank kernel
(`repro.kernels.blmac_fir_bank`): feed it arbitrary-length chunks of a
multi-channel sample stream and it returns, for every filter in the bank,
the output samples that became computable — carrying the ``taps − 1``
sample tail between chunks (classical overlap-save) so consecutive pushes
produce one gapless output stream per (filter, channel) pair.

Mode selection mirrors the hardware trade-off:

  * ``"specialized"`` — per-filter pulse-baked programs from the LRU
    program cache; wins for narrow banks where per-call overhead is
    amortized and the add count is exactly the pulse count.
  * ``"packed"``      — the scheduled bank path: filters sorted into
    occupancy-homogeneous bank tiles at construction time
    (`plan_bank_schedule`), each tile group one `pallas_call` iterating
    ONLY its populated superlayers, packed uint32 trit operands resident
    on device across pushes.
  * ``"auto"``        — the default: `autotune_bank_dispatch` runs both
    candidates (and the scheduled tile/merge grid) through the
    calibrated cost model in `repro.core.costmodel` and keeps the
    winner's plan — no threshold guessing.

Since the one-program refactor the engine is a thin client of
`repro.compiler`: construction compiles (or is handed) ONE
`BlmacProgram` and reads everything off it — the packed trit operands,
the memoized superlayer schedule, the per-filter pulse schedules of
specialized mode, and the §4 cycle predictions.  Two engines built on
the same bank share one program (content-addressed), and an engine built
from a `BlmacProgram.load()`ed file starts without recompiling anything.

Arithmetic contract: int32 throughout.  The §2.1 bound (16-bit coeffs ×
8-bit samples × ≤255 taps) is asserted ONCE, inside `compile_bank` —
neither `push` nor the kernels re-check it, and `blmac_fir_dynamic`
documents the identical guarantee.

Bit-exactness: all modes agree with `repro.filters.fir_bit_layers_batch`
to the last bit on integer inputs (property-tested in `tests/test_bank.py`
and `tests/differential.py`).
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from ..kernels.runtime import DEFAULT_TILE

# Legacy crossover (filters below → specialized) — superseded by the
# autotuner for mode="auto"; kept because external callers used it to
# pre-decide a forced mode.
SPECIALIZE_THRESHOLD = 8

__all__ = ["FilterBankEngine", "SPECIALIZE_THRESHOLD", "DEFAULT_TILE"]


class FilterBankEngine:
    """Overlap-save streaming application of a quantized FIR filter bank.

    Parameters
    ----------
    qbank : (B, taps) or (taps,) int array, or `repro.compiler.BlmacProgram`
        Quantized odd symmetric (type-I) coefficients, one row per filter
        — compiled via `compile_bank` (content-addressed, so repeated
        constructions of the same bank share one artifact).  Passing a
        prebuilt / `load()`ed program skips compilation entirely.  A
        CSE-`OptimizedProgram` serves its PARENT's filters: the engine
        runs the shared-row layout and folds the combine matrix inside
        `_apply` (``mode="auto"`` lets the autotuner *decline* the
        optimized layout — ``dispatch_plan.cse`` records the verdict).
    channels : int
        Number of independent input channels C (all filtered by every filter).
    tile : int | None
        Output samples per kernel grid step (lane-parallel width).
        ``None`` lets the autotuner pick (falls back to ``DEFAULT_TILE``
        for forced modes).
    mode : {"auto", "packed", "scheduled", "specialized"}
        ``"scheduled"`` is an alias for ``"packed"``.
    bank_tile : int | None
        Filters per bank tile of the scheduled kernel (None = heuristic).
    merge : int | None
        CSD layers fused per superlayer matmul (None = kernel default;
        1 = paper-pure one matmul per bit layer).
    interpret : bool | None
        Pallas interpret override; None = backend default.
    chunk_hint : int
        Expected samples per push, the autotuner's amortization knob
        (streaming chunks are short; batch jobs long).
    compiled : bool | str
        Opt the ``"auto"`` sweep into the compiled execution lanes
        (``True`` = this host's `default_lane`, or a lane name);
        the engine then executes whatever lane the winning plan names.
        Default ``False`` keeps the historic interpret-only behaviour.
    lane : str | None
        Pin the execution lane for a forced (non-auto) packed mode —
        e.g. ``"xla"`` runs the schedule through the fused compiled
        lowering.  ``None`` = the legacy pallas_call + ``interpret``.

    Raises
    ------
    ValueError
        Unknown ``mode``, ``channels < 1``, or non-type-I/overflowing
        coefficients (via `compile_bank`'s §2.1 bound check).

    Examples
    --------
    >>> import numpy as np
    >>> from repro.filters import FilterBankEngine
    >>> bank = np.zeros((4, 15), np.int64)
    >>> bank[:, 7] = [64, 96, 160, 224]          # centre-tap scalers
    >>> eng = FilterBankEngine(bank, channels=1, interpret=True)
    >>> x = np.arange(40, dtype=np.int32)[None, :]
    >>> y = eng.push(x)                          # (B, C, n_out)
    >>> y.shape
    (4, 1, 26)
    >>> bool((y[1] == 96 * np.arange(7, 33)).all())
    True
    """

    def __init__(
        self,
        qbank: np.ndarray,
        channels: int = 1,
        tile: int | None = None,
        mode: str = "auto",
        bank_tile: int | None = None,
        interpret: bool | None = None,
        merge: int | None = None,
        chunk_hint: int = 2048,
        compiled: "bool | str" = False,
        lane: str | None = None,
    ):
        from ..compiler import BlmacProgram, MERGE_DEFAULT, compile_bank
        from ..kernels.runtime import autotune_bank_dispatch

        if isinstance(qbank, BlmacProgram):
            program = qbank
        else:
            # CSD encoding, trit packing and the §2.1 int32 bound all
            # happen in here — exactly once per distinct bank content,
            # however many engines are built.  The int64 cast preserves
            # this constructor's historical contract (float input is
            # truncated, not quantized — pass the bank through
            # `compile_bank` yourself for §3.2 po2 quantization).
            program = compile_bank(
                np.atleast_2d(np.asarray(qbank, np.int64))
            )
        if channels < 1:
            raise ValueError("channels must be >= 1")
        if mode == "scheduled":
            mode = "packed"
        if mode not in ("auto", "packed", "specialized"):
            raise ValueError(f"unknown mode {mode!r}")
        self.taps = program.taps
        self.channels = int(channels)
        self.interpret = interpret
        self.dispatch_plan = None
        self.lane = lane
        schedule = None
        if mode == "auto":
            self.dispatch_plan, schedule = autotune_bank_dispatch(
                program, channels=self.channels, tile=tile,
                chunk_hint=chunk_hint, interpret=interpret,
                compiled=compiled,
            )
            if self.dispatch_plan.cse == "declined":
                # a CSE-optimized program whose shared-row layout the
                # cost model rejects here: the plan (and schedule) are
                # the PARENT's — execute it, bit-identical outputs
                program = program.parent
            mode = (
                "specialized"
                if self.dispatch_plan.mode == "specialized"
                else "packed"
            )
            if self.lane is None and self.dispatch_plan.lane != "interpret":
                self.lane = self.dispatch_plan.lane
            if tile is None:
                tile = self.dispatch_plan.tile
            if bank_tile is None and schedule is not None:
                bank_tile = schedule.tile_size
            if merge is None and schedule is not None:
                merge = schedule.merge
        self.program = program
        # external face: a CSE-optimized program still SERVES the
        # parent's filters — qbank/n_filters describe the combined
        # outputs, the augmented shared-row layout stays internal
        self._combine = program.combine
        self.qbank = (
            program.qbank if program.combine is None
            else program.effective_qbank()
        )
        self.n_filters = program.out_filters
        self.tile = int(tile) if tile is not None else DEFAULT_TILE
        self.mode = mode
        self.merge = merge if merge is not None else MERGE_DEFAULT
        if mode == "packed":
            # the program memoizes one plan per (bank_tile, merge) — the
            # autotuned schedule and an explicit-override re-plan resolve
            # through the same memo; upload each tile group's packed
            # operand ONCE so push() feeds device-resident operands
            # instead of re-staging the bank every chunk
            if (
                schedule is None
                or (bank_tile is not None and bank_tile != schedule.tile_size)
                or schedule.merge != self.merge
            ):
                schedule = program.schedule(bank_tile, self.merge)
            self.bank_schedule = schedule
            self.bank_tile = schedule.tile_size
            self._group_ops = [
                jnp.asarray(g.packed.view(np.int32)) if g.sel_layers else None
                for g in schedule.groups
            ]
            self._schedules = None
        else:
            self.bank_schedule = None
            self.bank_tile = bank_tile
            self._group_ops = None
            self._schedules = program.pulse_schedules()
        # overlap-save state: the last taps-1 samples of every channel
        self._tail = np.zeros((channels, 0), np.int32)
        self.samples_in = 0
        self.samples_out = 0

    # -- cost model ---------------------------------------------------------

    def predicted_machine_cycles(self, spec=None) -> np.ndarray:
        """(B,) clock cycles per output each filter would cost on the §4
        FPGA dot-product machine (one cycle per RLE code + overhead).

        ``spec`` is a `repro.core.MachineSpec` (default: the paper's
        127-tap spec parameters applied to this bank's tap count).  Reads
        `BlmacProgram.machine_cycles` — derived from the program's own
        CSD digits and memoized per spec ON THE PROGRAM, so every engine,
        benchmark and test sharing this bank shares one computation.
        Agrees exactly with both simulators — `FirBlmacVMachine` asserts
        this in `tests/differential.py`.
        """
        return self.program.machine_cycles(spec)

    def predicted_mean_cycles(self, spec=None) -> float:
        """Bank-average §4 machine cycles per output sample."""
        return float(self.predicted_machine_cycles(spec).mean())

    # -- streaming API ------------------------------------------------------

    def push(self, chunk) -> np.ndarray:
        """Feed (C, n) samples (or (n,) when C == 1); returns the newly
        computable outputs as int32 (B, C, n_out) — n_out may be 0 while
        the engine is still priming its taps−1 history."""
        chunk = np.asarray(chunk)
        if chunk.ndim == 1:
            chunk = chunk[None, :]
        if chunk.shape[0] != self.channels:
            raise ValueError(
                f"expected {self.channels} channels, got {chunk.shape[0]}"
            )
        self.samples_in += chunk.shape[1]
        buf = np.concatenate([self._tail, chunk.astype(np.int32)], axis=1)
        n = buf.shape[1]
        if n < self.taps:  # still priming
            self._tail = buf
            return np.zeros((self.n_filters, self.channels, 0), np.int32)
        self._tail = buf[:, n - (self.taps - 1):] if self.taps > 1 else buf[:, :0]
        y = self._apply(buf)
        self.samples_out += y.shape[2]
        return y

    def __call__(self, chunk) -> np.ndarray:
        return self.push(chunk)

    def reset(self) -> None:
        """Drop all buffered history (start a new stream)."""
        self._tail = np.zeros((self.channels, 0), np.int32)
        self.samples_in = 0
        self.samples_out = 0

    @property
    def pending(self) -> int:
        """Samples buffered but not yet old enough to finish a window."""
        return self._tail.shape[1]

    # -- tail snapshot / restore (content-addressed stream state) -----------

    def snapshot_tail(self, session: str = ""):
        """Freeze the overlap-save stream state as a
        `repro.compiler.TailSnapshot` keyed to this engine's program
        digest — `save()`-able next to `BlmacProgram.save()` so a
        restarted serving process resumes the stream bit-exactly, and
        the replay point the sharded engine's fault recovery builds on.
        ``session`` stamps an optional stream identity into the snapshot
        (the multi-tenant server labels parked sessions this way)."""
        from ..compiler.state import TailSnapshot

        return TailSnapshot(
            program_key=self.program.key, channels=self.channels,
            samples_in=self.samples_in, samples_out=self.samples_out,
            tail=self._tail.copy(), session=str(session),
        )

    def restore_tail(self, snapshot) -> None:
        """Adopt a `TailSnapshot` captured on THIS program (validated by
        content key — restoring another bank's stream is a loud error,
        never a silently wrong output)."""
        if snapshot.program_key != self.program.key:
            raise ValueError(
                f"snapshot belongs to program {snapshot.program_key[:12]}…, "
                f"this engine runs {self.program.key[:12]}…"
            )
        if int(snapshot.channels) != self.channels:
            raise ValueError(
                f"snapshot has {snapshot.channels} channels, "
                f"engine has {self.channels}"
            )
        self._tail = np.asarray(snapshot.tail, np.int32).copy()
        self.samples_in = int(snapshot.samples_in)
        self.samples_out = int(snapshot.samples_out)

    # -- one-shot application ----------------------------------------------

    def apply_lanes(self, buf) -> np.ndarray:
        """Stateless one-shot bank application over ``channels`` lanes.

        ``buf`` is (C, n) int samples with ``n >= taps``; returns the full
        (B, C, n − taps + 1) output without touching the engine's
        overlap-save tail or stream counters.  This is the batched
        multi-select dispatch surface the session server builds on: it
        packs many tenants' ``tail + queued`` buffers into the C lanes of
        ONE shared engine, fires a single dispatch, and slices each
        tenant's `program.select()` rows / valid sample range out of the
        result — bit-exactness per lane follows from `push` and
        `apply_lanes` sharing the same `_apply` path.
        """
        buf = np.asarray(buf, np.int32)
        if buf.ndim != 2 or buf.shape[0] != self.channels:
            raise ValueError(
                f"expected ({self.channels}, n) lane buffer, "
                f"got shape {buf.shape}"
            )
        if buf.shape[1] < self.taps:
            raise ValueError(
                f"lane buffer has {buf.shape[1]} samples, "
                f"need >= taps ({self.taps})"
            )
        return self._apply(buf)

    def _apply(self, buf: np.ndarray) -> np.ndarray:
        from ..kernels.blmac_fir import (bank_schedule_apply, blmac_fir_specialized,
                                         frame_signal_batch)
        from ..kernels.runtime import resolve_interpret

        n = buf.shape[1]
        n_out = n - self.taps + 1
        # Quantize the jit shape: pad the buffer to a tile multiple so a
        # stream of ragged chunk sizes hits a handful of compile-cache
        # entries instead of retracing every push; windows that reach
        # into the padding are dropped below.
        n_pad = -(-n // self.tile) * self.tile
        if n_pad != n:
            buf = np.pad(buf, ((0, 0), (0, n_pad - n)))
        x = jnp.asarray(buf, jnp.int32)
        if self.mode == "packed":
            frames, _ = frame_signal_batch(x, self.taps, self.tile)
            y = bank_schedule_apply(
                frames,
                self.bank_schedule,
                self.taps,
                self.tile,
                resolve_interpret(self.interpret),
                device_groups=self._group_ops,
                lane=self.lane,
                combine=self._combine,
                n_real=self.n_filters if self._combine is not None else None,
            )  # (B, C, n_tiles * tile), caller order restored + combined
            return np.asarray(y[:, :, :n_out])
        out = np.empty((len(self._schedules), self.channels, n_out), np.int32)
        for b, pulses in enumerate(self._schedules):
            for c in range(self.channels):
                out[b, c] = np.asarray(
                    blmac_fir_specialized(
                        x[c], pulses, self.taps, self.tile, self.interpret
                    )
                )[:n_out]
        if self._combine is not None:
            from ..compiler.lowering import _host_combine_i32

            out = _host_combine_i32(out, self._combine, self.n_filters)
        return out
