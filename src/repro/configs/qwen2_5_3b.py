"""qwen2.5-3b [dense]: GQA with QKV bias, tied embeddings.
[hf:Qwen/Qwen2.5-3B]"""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen2.5-3b",
    family="dense",
    n_layers=36,
    d_model=2048,
    n_heads=16,
    n_kv_heads=2,
    head_dim=128,
    d_ff=11_008,
    vocab_size=151_936,
    attn_bias=True,
    rope_theta=1_000_000.0,
    norm="rmsnorm_unit",
    mlp="swiglu",
    tie_embeddings=True,
))
