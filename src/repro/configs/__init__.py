"""Architecture registry: one module per assigned architecture."""
from . import (
    deepseek_coder_33b,
    deepseek_v3_671b,
    gemma2_27b,
    internvl2_76b,
    mamba2_370m,
    mixtral_8x22b,
    musicgen_large,
    qwen2_5_3b,
    recurrentgemma_2b,
    starcoder2_3b,
)
from .base import (
    LONG_CONTEXT_ARCHS,
    SHAPES,
    ModelConfig,
    ShapeSpec,
    all_configs,
    cells_for,
    get_config,
    input_specs,
)
from .fir127 import FirConfig

ALL = list(all_configs())

__all__ = [
    "ALL", "LONG_CONTEXT_ARCHS", "SHAPES", "ModelConfig", "ShapeSpec",
    "FirConfig", "all_configs", "cells_for", "get_config", "input_specs",
]
