"""Architecture registry: one module per assigned architecture."""
# side-effect imports: each module registers its config at import time
from . import deepseek_coder_33b  # noqa: F401
from . import deepseek_v3_671b  # noqa: F401
from . import gemma2_27b  # noqa: F401
from . import internvl2_76b  # noqa: F401
from . import mamba2_370m  # noqa: F401
from . import mixtral_8x22b  # noqa: F401
from . import musicgen_large  # noqa: F401
from . import qwen2_5_3b  # noqa: F401
from . import recurrentgemma_2b  # noqa: F401
from . import starcoder2_3b  # noqa: F401
from .base import (
    LONG_CONTEXT_ARCHS,
    SHAPES,
    ModelConfig,
    ShapeSpec,
    all_configs,
    cells_for,
    get_config,
    input_specs,
)
from .fir127 import FirConfig

ALL = list(all_configs())

__all__ = [
    "ALL", "LONG_CONTEXT_ARCHS", "SHAPES", "ModelConfig", "ShapeSpec",
    "FirConfig", "all_configs", "cells_for", "get_config", "input_specs",
]
