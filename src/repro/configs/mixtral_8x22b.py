"""mixtral-8x22b [moe]: 8 experts top-2, sliding-window attention.
[arXiv:2401.04088]"""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="mixtral-8x22b",
    family="moe",
    n_layers=56,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=16_384,
    vocab_size=32_768,
    window_pattern=(4096,),
    n_experts=8,
    experts_per_token=2,
    moe_d_ff=16_384,
    router="softmax",
    norm="rmsnorm_unit",
    mlp="swiglu",
    rope_theta=1_000_000.0,
    param_dtype="bfloat16",
    moe_groups=16,
))
