"""recurrentgemma-2b [hybrid]: RG-LRU + local attention, pattern (R,R,A).
[arXiv:2402.19427; hf]"""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,          # MQA
    head_dim=256,
    d_ff=7680,
    vocab_size=256_000,
    block_pattern=("rglru", "rglru", "attn"),
    window_pattern=(2048,),
    rglru_width=2560,
    conv_width=4,
    mlp="geglu",
    norm="rmsnorm",        # gemma-style (1 + w)
    embed_scale=True,
    tie_embeddings=True,
    rope_theta=10_000.0,
))
