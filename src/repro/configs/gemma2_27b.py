"""gemma2-27b [dense]: alternating local(4096)/global attention, logit
softcaps, sandwich norms.  [arXiv:2408.00118]"""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="gemma2-27b",
    family="dense",
    n_layers=46,
    d_model=4608,
    n_heads=32,
    n_kv_heads=16,
    head_dim=128,
    d_ff=36_864,
    vocab_size=256_000,
    window_pattern=(4096, 0),
    attn_softcap=50.0,
    logit_softcap=30.0,
    query_scale=144.0,       # query_pre_attn_scalar = d_model / n_heads
    norm="rmsnorm",          # gemma (1 + w)
    post_norm=True,
    mlp="geglu",
    embed_scale=True,
    tie_embeddings=True,
    param_dtype="bfloat16",
))
