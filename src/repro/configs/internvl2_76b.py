"""internvl2-76b [vlm]: InternLM2-76B decoder backbone; the InternViT
front-end is a stub — input_specs() hands the backbone precomputed patch
embeddings.  [arXiv:2404.16821]"""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="internvl2-76b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=28_672,
    vocab_size=128_256,
    norm="rmsnorm_unit",
    mlp="swiglu",
    rope_theta=1_000_000.0,
    input_kind="embeds",
    param_dtype="bfloat16",
))
