"""Model/config schema and the architecture registry.

Every assigned architecture is a `ModelConfig` in its own module; reduced
("smoke") variants are derived mechanically for CPU tests.  Input shapes
(the 4 assigned cells) are `ShapeSpec`s; `input_specs()` turns a
(config × shape) cell into ShapeDtypeStructs for the dry-run.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 ⇒ d_model // n_heads
    # --- attention ---
    attn_kind: str = "gqa"  # gqa | mla
    attn_bias: bool = False
    pos_emb: str = "rope"  # rope | sinusoidal | none
    rope_theta: float = 10_000.0
    logit_softcap: float = 0.0
    attn_softcap: float = 0.0
    query_scale: float = 0.0  # 0 ⇒ head_dim (gemma2 uses d_model/n_heads)
    window_pattern: tuple[int, ...] = (0,)  # cycled per attn layer; 0=global
    # --- MLA ---
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0
    # --- norms / mlp ---
    norm: str = "rmsnorm"  # rmsnorm (gemma +1) | rmsnorm_unit | layernorm
    post_norm: bool = False  # gemma2 sandwich norms
    mlp: str = "swiglu"  # swiglu | geglu | gelu
    mlp_bias: bool = False
    tie_embeddings: bool = False
    embed_scale: bool = False  # gemma-style sqrt(d) embedding scaling
    # --- MoE ---
    n_experts: int = 0
    experts_per_token: int = 0
    n_shared_experts: int = 0
    moe_d_ff: int = 0
    first_dense_layers: int = 0
    capacity_factor: float = 1.25
    router: str = "softmax"  # softmax | sigmoid_norm
    moe_groups: int = 1
    aux_loss_coef: float = 0.01
    # --- SSM / recurrent ---
    block_pattern: tuple[str, ...] = ("attn",)  # cycled: attn | ssd | rglru
    ffn_pattern: str = "mlp"  # mlp | moe | none
    ssm_state: int = 0
    ssm_heads: int = 0
    ssm_head_dim: int = 64
    ssm_chunk: int = 256  # SSD intra-chunk quadratic extent (§Perf C)
    conv_width: int = 4
    rglru_width: int = 0
    # --- modality front-end (stub) ---
    input_kind: str = "tokens"  # tokens | embeds (vlm/audio backbones)
    # --- numerics / execution ---
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    scan_layers: bool = True
    remat: str = "full"  # none | full
    optimizer: str = "adamw"  # adamw | adafactor
    # --- BLMAC integration ---
    quant_planes: int = 0  # >0 ⇒ CSD-P pulse-code serving quantization

    @property
    def head_dim_(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def reduced(self, **overrides) -> "ModelConfig":
        """Mechanically shrunken config for CPU smoke tests."""
        pat = len(self.block_pattern)
        small = dict(
            n_layers=max(pat + 1, 2) if pat > 1 else 2,
            d_model=128,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads else 0,
            head_dim=32,
            d_ff=256,
            vocab_size=512,
            moe_d_ff=64 if self.n_experts else 0,
            n_experts=min(self.n_experts, 8),
            experts_per_token=min(self.experts_per_token, 2),
            q_lora_rank=64 if self.q_lora_rank else 0,
            kv_lora_rank=32 if self.kv_lora_rank else 0,
            qk_nope_dim=32 if self.qk_nope_dim else 0,
            qk_rope_dim=16 if self.qk_rope_dim else 0,
            v_head_dim=32 if self.v_head_dim else 0,
            ssm_state=32 if self.ssm_state else 0,
            ssm_heads=4 if self.ssm_heads else 0,
            ssm_head_dim=16 if self.ssm_heads else 64,
            rglru_width=128 if self.rglru_width else 0,
            window_pattern=tuple(min(w, 64) if w else 0 for w in self.window_pattern),
            first_dense_layers=min(self.first_dense_layers, 1),
            moe_groups=1,
            # no capacity drops in smoke tests: keeps decode/forward parity
            capacity_factor=8.0 if self.n_experts else self.capacity_factor,
            scan_layers=True,
            remat="none",
            name=self.name + "-smoke",
        )
        small.update(overrides)
        return dataclasses.replace(self, **small)


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}

# archs for which long_500k is skipped (pure full attention — DESIGN.md)
LONG_CONTEXT_ARCHS = {
    "recurrentgemma-2b", "mamba2-370m", "mixtral-8x22b", "gemma2-27b",
}

_REGISTRY: dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    if not _REGISTRY:
        from . import ALL  # noqa: F401  (populates the registry)
    return _REGISTRY[name]


def all_configs() -> dict[str, ModelConfig]:
    if not _REGISTRY:
        from . import ALL  # noqa: F401
    return dict(_REGISTRY)


def cells_for(name: str) -> list[str]:
    """The shape cells this arch runs (40 total across the pool, minus
    documented long_500k skips)."""
    get_config(name)  # validate the arch name (raises on unknown)
    cells = ["train_4k", "prefill_32k", "decode_32k"]
    if name in LONG_CONTEXT_ARCHS:
        cells.append("long_500k")
    return cells


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input of the cell —
    weak-type-correct, shardable, no device allocation."""
    b, s = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        if cfg.input_kind == "embeds":
            return {
                "embeds": jax.ShapeDtypeStruct((b, s, cfg.d_model), jnp.bfloat16),
                "labels": jax.ShapeDtypeStruct((b, s), jnp.int32),
                "mask": jax.ShapeDtypeStruct((b, s), jnp.float32),
            }
        return {
            "tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
            "labels": jax.ShapeDtypeStruct((b, s), jnp.int32),
            "mask": jax.ShapeDtypeStruct((b, s), jnp.float32),
        }
    if shape.kind == "prefill":
        if cfg.input_kind == "embeds":
            return {"embeds": jax.ShapeDtypeStruct((b, s, cfg.d_model), jnp.bfloat16)}
        return {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32)}
    # decode: one new token against a cache of seq_len
    if cfg.input_kind == "embeds":
        return {"embed": jax.ShapeDtypeStruct((b, 1, cfg.d_model), jnp.bfloat16)}
    return {"token": jax.ShapeDtypeStruct((b, 1), jnp.int32)}
