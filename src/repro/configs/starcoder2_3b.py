"""starcoder2-3b [dense]: GQA, RoPE, LayerNorm + bias MLP.
[arXiv:2402.19173]"""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="starcoder2-3b",
    family="dense",
    n_layers=30,
    d_model=3072,
    n_heads=24,
    n_kv_heads=2,
    head_dim=128,
    d_ff=12_288,
    vocab_size=49_152,
    attn_bias=True,
    norm="layernorm",
    mlp="gelu",
    mlp_bias=True,
    rope_theta=999_999.0,
))
