"""musicgen-large [audio]: decoder-only over EnCodec tokens; the EnCodec
front-end is a stub — input_specs() hands precomputed frame embeddings.
Sinusoidal positions, full MHA (kv=32).  [arXiv:2306.05284]"""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="musicgen-large",
    family="audio",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab_size=2048,
    pos_emb="sinusoidal",
    norm="layernorm",
    mlp="gelu",
    input_kind="embeds",
))
