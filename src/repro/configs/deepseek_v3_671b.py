"""deepseek-v3-671b [moe]: MLA, 1 shared + 256 routed experts top-8,
sigmoid routing, first 3 layers dense.  MTP (multi-token prediction) is a
training-objective add-on and is NOT implemented — DESIGN.md records the
simplification.  [arXiv:2412.19437]"""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="deepseek-v3-671b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,
    head_dim=192,            # qk_nope + qk_rope (bookkeeping)
    d_ff=18_432,             # dense layers (first 3)
    vocab_size=129_280,
    attn_kind="mla",
    q_lora_rank=1536,
    kv_lora_rank=512,
    qk_nope_dim=128,
    qk_rope_dim=64,
    v_head_dim=128,
    n_experts=256,
    experts_per_token=8,
    n_shared_experts=1,
    moe_d_ff=2048,
    first_dense_layers=3,
    router="sigmoid_norm",
    capacity_factor=1.25,
    norm="rmsnorm_unit",
    mlp="swiglu",
    param_dtype="bfloat16",
    optimizer="adafactor",
    moe_groups=16,
))
