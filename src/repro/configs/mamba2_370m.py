"""mamba2-370m [ssm]: pure SSD mixer stack, attention-free.
[arXiv:2405.21060]"""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="mamba2-370m",
    family="ssm",
    n_layers=48,
    d_model=1024,
    n_heads=1,             # unused (attention-free)
    n_kv_heads=0,
    d_ff=0,
    vocab_size=50_280,
    block_pattern=("ssd",),
    ffn_pattern="none",
    ssm_state=128,
    ssm_heads=32,          # expand=2 ⇒ d_inner=2048, head_dim 64
    ssm_head_dim=64,
    conv_width=4,
    norm="rmsnorm_unit",
    pos_emb="none",
    tie_embeddings=True,
))
