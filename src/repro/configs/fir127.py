"""The paper's own configuration (§4): the 127-tap BLMAC dot-product
machine and its filter workload."""
from dataclasses import dataclass


@dataclass(frozen=True)
class FirConfig:
    taps: int = 127
    coeff_bits: int = 16
    sample_bits: int = 8
    weight_mem_codes: int = 256
    n_div: int = 100          # frequency grid of the §3.1 sweep
    window: str = "hamming"
    kaiser_beta: float = 8.0  # calibrated against the paper's B_N
    kernel_tile: int = 1024   # Pallas output tile (lanes)


CONFIG = FirConfig()
