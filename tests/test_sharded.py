"""Sharded-vs-unsharded numerical equivalence on a small mesh, and the
production-mesh helpers."""
from tests._subproc import run_py


def test_sharded_train_step_matches_unsharded():
    out = run_py("""
import dataclasses, jax, jax.numpy as np_unused
import jax.numpy as jnp, numpy as np
from repro.configs import get_config
from repro.distributed.sharding import make_rules, sanitized_shardings, batch_shardings
from repro.nn import init_params, model_decls
from repro.nn.common import param_pspecs
from repro.training import TrainHParams, OptHParams, make_train_step, train_state_init
from repro.training.train_step import train_state_pspecs

cfg = get_config("qwen2.5-3b").reduced(n_layers=2, vocab_size=256, d_model=128, d_ff=256)
cfg = dataclasses.replace(cfg, compute_dtype="float32")
mesh = jax.make_mesh((2, 4), ("data", "model"))
rules = make_rules(mesh, "train")
hp = TrainHParams(opt=OptHParams(learning_rate=1e-3))
params = init_params(model_decls(cfg), jax.random.key(0))
state = train_state_init(params, cfg)
rng = np.random.default_rng(0)
batch = {"tokens": jnp.asarray(rng.integers(0, 256, (8, 32)), jnp.int32),
         "labels": jnp.asarray(rng.integers(0, 256, (8, 32)), jnp.int32),
         "mask": jnp.ones((8, 32), jnp.float32)}
# unsharded
s1, m1 = jax.jit(make_train_step(cfg, hp))(state, batch)
# sharded
decls = model_decls(cfg)
ssh = sanitized_shardings(mesh, train_state_pspecs(cfg, decls, rules),
                          jax.tree_util.tree_map(lambda x: x, state))
bsh = batch_shardings(mesh, rules, batch)
state_s = jax.device_put(state, ssh)
batch_s = {k: jax.device_put(v, bsh[k]) for k, v in batch.items()}
step = jax.jit(make_train_step(cfg, hp, mesh, rules),
               in_shardings=(ssh, bsh), out_shardings=(ssh, None))
s2, m2 = step(state_s, batch_s)
d = float(jnp.abs(m1["loss"] - m2["loss"]))
assert d < 1e-4, d
pa = jax.tree_util.tree_leaves(s1["params"])
pb = jax.tree_util.tree_leaves(s2["params"])
rel = max(float(jnp.abs(a - b).max()) for a, b in zip(pa, pb))
assert rel < 1e-4, rel
print("SHARDED_MATCH", d, rel)
""", devices=8)
    assert "SHARDED_MATCH" in out


def test_production_mesh_shapes():
    out = run_py("""
from repro.launch.mesh import make_production_mesh
m1 = make_production_mesh()
m2 = make_production_mesh(multi_pod=True)
assert m1.shape == {"data": 16, "model": 16}
assert m2.shape == {"pod": 2, "data": 16, "model": 16}
assert m1.size == 256 and m2.size == 512
print("MESH_OK")
""", devices=512)
    assert "MESH_OK" in out
