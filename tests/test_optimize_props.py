"""Hypothesis property tests for the CSE pass (`repro.compiler.optimize`).

Separate module so the deterministic tests in `test_optimize.py` still
run where hypothesis is not installed (the `test_csd.py` idiom — CI
installs it via requirements-dev.txt).
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis "
                    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from tests.test_optimize import roundtrip_properties  # noqa: E402


@given(
    st.lists(
        st.lists(st.integers(-2**15 + 1, 2**15 - 1),
                 min_size=8, max_size=8),
        min_size=1, max_size=8,
    )
)
@settings(max_examples=40, deadline=None)
def test_cse_property_decode_roundtrip_and_pulse_budget(halves):
    """cse_pass output decodes to the identical quantized bank
    (csd_decode round-trip through the packed augmented trits plus the
    combine fold) and never increases the total pulse count, over
    random type-I banks."""
    h = np.asarray(halves, np.int64)
    roundtrip_properties(np.concatenate([h, h[:, :-1][:, ::-1]], axis=1))


@given(st.integers(0, 2**31 - 1), st.integers(1, 6))
@settings(max_examples=25, deadline=None)
def test_cse_property_on_seeded_random_banks(seed, n_filters):
    from tests.differential import random_type1_bank

    roundtrip_properties(random_type1_bank(n_filters, 31, seed=seed))
