"""Write-ahead session journal: `repro.serving.journal` + crash recovery.

Durability contract under test: the journal is CRC-framed and
unbuffered, so after ANY prefix of the process's writes reaches disk —
torn tail records included — `BankSessionServer.recover(path)` rebuilds
every session bit-exactly and `pull` resumes with no duplicated and no
missing samples.
"""
import json
import os
import signal
import struct

import numpy as np
import pytest

from repro.compiler import SnapshotFormatError, TailSnapshot, compile_bank
from repro.filters import (FilterBankEngine, fir_bit_layers_batch,
                           spread_lowpass_qbank)
from repro.serving import BankSessionServer, JournalFormatError, SessionJournal
from repro.serving.journal import decode_array, encode_array, _read_records
from tests._subproc import run_py_raw

TAPS = 31


def _program(n_filters: int = 16, taps: int = TAPS):
    return compile_bank(spread_lowpass_qbank(n_filters, taps))


def _journal(path, prog, **kw):
    return SessionJournal(path, program_key=prog.key, taps=prog.taps,
                          n_filters=prog.n_filters, **kw)


def _seg(path):
    names = sorted(n for n in os.listdir(path) if n.startswith("wal."))
    return os.path.join(str(path), names[-1])


# ---------------------------------------------------------------------------
# record framing: CRC rejection, torn tails, format gating
# ---------------------------------------------------------------------------


def test_array_payload_round_trip():
    a = np.arange(-6, 6, dtype=np.int32).reshape(3, 4)
    b = decode_array(encode_array(a))
    assert b.dtype == a.dtype and np.array_equal(a, b)
    assert b.flags.writeable  # decode must not hand out frozen buffers


def test_append_replay_round_trip(tmp_path):
    prog = _program()
    j = _journal(tmp_path / "wal", prog)
    j.start_segment()
    j.append({"t": "open", "sid": "a", "rows": [1, 2]})
    j.append({"t": "chunk", "sid": "a", "seq": 1,
              "x": encode_array(np.arange(5, dtype=np.int32))}, sync=True)
    j.close()
    header, records = SessionJournal.replay(tmp_path / "wal")
    assert header["program_key"] == prog.key
    assert [r["t"] for r in records] == ["open", "chunk"]
    assert np.array_equal(decode_array(records[1]["x"]), np.arange(5))


def test_corrupt_record_crc_truncates_everything_after(tmp_path):
    prog = _program()
    j = _journal(tmp_path / "wal", prog)
    j.start_segment()
    for i in range(4):
        j.append({"t": "open", "sid": f"s{i}", "rows": [i]})
    j.close()
    seg = _seg(tmp_path / "wal")
    records, _ = _read_records(seg)
    assert len(records) == 5  # header + 4
    # flip one payload byte inside the THIRD record: it and everything
    # after it are untrustworthy (framing is sequential)
    data = bytearray(open(seg, "rb").read())
    off = 0
    for _ in range(2):  # skip header + first open
        ln, _crc = struct.unpack_from("<II", data, off)
        off += 8 + ln
    data[off + 8 + 3] ^= 0xFF
    open(seg, "wb").write(bytes(data))
    header, records = SessionJournal.replay(tmp_path / "wal", repair=False)
    assert [r["sid"] for r in records] == ["s0"]


def test_torn_tail_truncated_and_physically_repaired(tmp_path):
    prog = _program()
    j = _journal(tmp_path / "wal", prog)
    j.start_segment()
    j.append({"t": "open", "sid": "a", "rows": [0]})
    j.close()
    seg = _seg(tmp_path / "wal")
    whole = os.path.getsize(seg)
    with open(seg, "ab") as f:  # a record the crash cut mid-write
        f.write(struct.pack("<II", 1000, 123) + b"only a few bytes")
    header, records = SessionJournal.replay(tmp_path / "wal")
    assert [r["t"] for r in records] == ["open"]
    # repair=True (default) physically truncates the torn bytes away
    assert os.path.getsize(seg) == whole
    # ...so a recovered server can append right where the log ends
    j2 = _journal(tmp_path / "wal", prog)
    assert j2._seg_index == 0


def test_replay_rejects_unusable_directories(tmp_path):
    with pytest.raises(JournalFormatError, match="not a journal"):
        SessionJournal.replay(tmp_path / "nope")
    empty = tmp_path / "empty"
    empty.mkdir()
    with pytest.raises(JournalFormatError, match="no journal segments"):
        SessionJournal.replay(empty)
    bad = tmp_path / "bad"
    bad.mkdir()
    (bad / "wal.000000.log").write_bytes(b"\xff" * 32)
    with pytest.raises(JournalFormatError, match="no readable header"):
        SessionJournal.replay(bad)


def test_replay_rejects_wrong_kind_and_version(tmp_path):
    prog = _program()
    for patch, match in [({"kind": "other"}, "not a session journal"),
                         ({"format_version": 99}, "version")]:
        root = tmp_path / patch["kind"] if "kind" in patch else tmp_path / "v"
        j = _journal(root, prog)
        hdr = j._header(0)
        hdr.update(patch)
        j._header = lambda index, _h=hdr: _h
        j.start_segment()
        j.close()
        with pytest.raises(JournalFormatError, match=match):
            SessionJournal.replay(root)


def test_rotation_checkpoints_and_deletes_old_segments(tmp_path):
    prog = _program(8)
    srv = BankSessionServer(prog, n_slots=2, interpret=True, auto_step=False,
                            journal=tmp_path / "wal", snapshot_every=1,
                            segment_bytes=2000)
    s = srv.open_session([0, 1])
    rng = np.random.default_rng(0)
    x = rng.integers(-128, 128, 6 * 64).astype(np.int32)
    for k in range(6):
        s.push(x[k * 64:(k + 1) * 64])
        srv.step()
        s.pull()
    assert srv.journal.rotations >= 1
    names = [n for n in os.listdir(tmp_path / "wal") if n.startswith("wal.")]
    assert len(names) == 1  # superseded segments are deleted
    srv.close()
    # the surviving segment alone rebuilds the full session
    srv2 = BankSessionServer.recover(tmp_path / "wal", prog)
    s2 = srv2.sessions[s.session_id]
    assert s2.samples_in == 6 * 64 and s2.delivered == s2.samples_out
    srv2.close()


# ---------------------------------------------------------------------------
# server-level crash recovery
# ---------------------------------------------------------------------------


def test_recover_is_bit_exact_with_queued_chunks(tmp_path):
    prog = _program()
    rng = np.random.default_rng(3)
    srv = BankSessionServer(prog, n_slots=2, interpret=True, auto_step=False,
                            journal=tmp_path / "wal", snapshot_every=2)
    sels = [[0, 3], [5, 1], [7]]
    sessions = [srv.open_session(r) for r in sels]
    streams = [[] for _ in sels]
    outs = [[] for _ in sels]
    for k in range(5):
        for i, s in enumerate(sessions):
            chunk = rng.integers(-128, 128, int(rng.integers(8, 80))
                                 ).astype(np.int32)
            streams[i].append(chunk)
            s.push(chunk)
        if k < 4:
            srv.step()
            for i, s in enumerate(sessions):
                out = s.pull()
                if out.shape[1]:
                    outs[i].append(out)
    # die here: chunk 5 queued but never stepped, no close(), no sync —
    # abandoning the object IS the SIGKILL model because appends are
    # unbuffered writes
    del srv

    srv2 = BankSessionServer.recover(tmp_path / "wal", prog)
    sessions2 = [srv2.sessions[s.session_id] for s in sessions]
    for i, s in enumerate(sessions2):
        out = s.pull()
        if out.shape[1]:
            outs[i].append(out)
        chunk = rng.integers(-128, 128, 64).astype(np.int32)
        streams[i].append(chunk)
        s.push(chunk)
    srv2.step()
    for i, s in enumerate(sessions2):
        out = s.pull()
        if out.shape[1]:
            outs[i].append(out)
        x = np.concatenate(streams[i])
        ref = fir_bit_layers_batch(x[None, :], prog.qbank)[np.asarray(sels[i]), 0]
        got = np.concatenate(outs[i], axis=1)
        assert np.array_equal(got, ref[:, :got.shape[1]]), f"session {i}"
        assert got.shape[1] == x.size - TAPS + 1  # nothing lost
    srv2.close()


def test_recover_rejects_program_digest_mismatch(tmp_path):
    prog = _program()
    srv = BankSessionServer(prog, n_slots=2, interpret=True, auto_step=False,
                            journal=tmp_path / "wal")
    srv.open_session([0])
    srv.close()
    other = _program(taps=TAPS + 2)
    with pytest.raises(JournalFormatError, match="belongs to program"):
        BankSessionServer.recover(tmp_path / "wal", other)


def test_attach_to_populated_journal_dir_is_refused(tmp_path):
    prog = _program()
    srv = BankSessionServer(prog, n_slots=2, interpret=True, auto_step=False,
                            journal=tmp_path / "wal")
    srv.close()
    with pytest.raises(ValueError, match="recover"):
        BankSessionServer(prog, n_slots=2, interpret=True, auto_step=False,
                          journal=tmp_path / "wal")


def test_sigkill_crash_then_recover_subprocess(tmp_path):
    """The real thing: a serving PROCESS is SIGKILLed mid-flight and a
    fresh process recovers every stream bit-exactly."""
    wal = tmp_path / "wal"
    victim = run_py_raw(f"""
import os, signal
import numpy as np
from repro.compiler import compile_bank
from repro.filters import spread_lowpass_qbank
from repro.serving import BankSessionServer

prog = compile_bank(spread_lowpass_qbank(16, {TAPS}))
srv = BankSessionServer(prog, n_slots=2, interpret=True, auto_step=False,
                        journal={str(wal)!r}, snapshot_every=2)
rng = np.random.default_rng(11)
ss = [srv.open_session([i, i + 8], session_id=f"t{{i}}") for i in range(3)]
for k in range(3):
    for s in ss:
        s.push(rng.integers(-128, 128, 96).astype(np.int32))
    srv.step()
    for s in ss:
        s.pull()
for s in ss:  # queued, never stepped
    s.push(rng.integers(-128, 128, 96).astype(np.int32))
os.kill(os.getpid(), signal.SIGKILL)
""", devices=1)
    assert victim.returncode == -signal.SIGKILL, victim.stderr
    prog = _program()
    srv = BankSessionServer.recover(wal, prog)
    assert sorted(srv.sessions) == ["t0", "t1", "t2"]
    # replay the victim's RNG: 4 chunks of 96 per session, round-robin
    rng = np.random.default_rng(11)
    streams = [[] for _ in range(3)]
    for _ in range(4):
        for i in range(3):
            streams[i].append(rng.integers(-128, 128, 96).astype(np.int32))
    for i in range(3):
        s = srv.sessions[f"t{i}"]
        got = s.pull()
        x = np.concatenate(streams[i])
        ref = fir_bit_layers_batch(x[None, :], prog.qbank)[[i, i + 8], 0]
        n_pre = 3 * 96 - (TAPS - 1)  # delivered before the crash
        assert np.array_equal(got, ref[:, n_pre:n_pre + got.shape[1]])
        assert s.samples_in == 4 * 96
    srv.close()


# ---------------------------------------------------------------------------
# satellite regressions: tolerant snapshot load + empty-stats guard
# ---------------------------------------------------------------------------


def test_tail_snapshot_tolerates_pre_session_field_files(tmp_path):
    """Snapshots written before the session field existed (header without
    a ``session`` key) must still load, with ``session == ""``."""
    prog = _program()
    eng = FilterBankEngine(prog, channels=1, interpret=True)
    eng.push(np.arange(TAPS + 5, dtype=np.int32)[None, :])
    snap = eng.snapshot_tail()
    path = tmp_path / "old.npz"
    snap.save(path)
    with np.load(path) as z:
        header = json.loads(str(z["header"]))
        tail = z["tail"]
    del header["session"]
    np.savez(path, header=json.dumps(header), tail=tail)
    loaded = TailSnapshot.load(path)
    assert loaded.session == ""
    assert np.array_equal(loaded.tail, snap.tail)
    # ...while a wrong-kind file still fails loudly
    np.savez(path, header=json.dumps({"kind": "x"}), tail=tail)
    with pytest.raises(SnapshotFormatError, match="not a tail-snapshot"):
        TailSnapshot.load(path)


def test_serve_stats_empty_percentiles_are_none():
    srv = BankSessionServer(_program(), n_slots=2, interpret=True,
                            auto_step=False)
    srv.open_session([0])  # registered but never served
    stats = srv.serve_stats()
    assert stats["latency_p50_ms"] is None
    assert stats["latency_p99_ms"] is None
    assert json.dumps(stats)  # stays JSON-clean
