"""pack_trits/unpack_trits round-trip edge cases (no hypothesis needed).

The 2-bit packing became load-bearing with the packed-trit bank kernel
(`repro.kernels.blmac_fir_bank` unpacks these words in-kernel), so the
corner cases get explicit deterministic coverage here.
"""
import numpy as np
import pytest

from repro.core import csd_decode, csd_digits, pack_trits, unpack_trits


def test_empty_input():
    d = np.zeros((0,), np.int8)
    w = pack_trits(d)
    assert w.shape == (0,)
    assert w.dtype == np.uint32
    assert unpack_trits(w, 0).shape == (0,)


def test_empty_last_axis_batched():
    d = np.zeros((3, 0), np.int8)
    w = pack_trits(d)
    assert w.shape == (3, 0)
    assert np.array_equal(unpack_trits(w, 0), d)


def test_exactly_16_trits():
    rng = np.random.default_rng(0)
    d = rng.integers(-1, 2, 16).astype(np.int8)
    w = pack_trits(d)
    assert w.shape == (1,)  # exactly one word, no padding word
    assert np.array_equal(unpack_trits(w, 16), d)


@pytest.mark.parametrize("n", [1, 5, 15, 17, 31, 33, 100])
def test_non_multiple_of_16(n):
    rng = np.random.default_rng(n)
    d = rng.integers(-1, 2, n).astype(np.int8)
    w = pack_trits(d)
    assert w.shape == ((n + 15) // 16,)
    assert np.array_equal(unpack_trits(w, n), d)
    # padding trits decode to zero: unpacking the full words gives zeros
    full = unpack_trits(w, w.shape[-1] * 16)
    assert not full[n:].any()


def test_all_negative_digits():
    d = np.full(40, -1, np.int8)
    w = pack_trits(d)
    assert np.array_equal(unpack_trits(w, 40), d)
    # code 0b11 in every position of full words
    assert w[0] == 0xFFFFFFFF


def test_all_positive_digits():
    d = np.ones(16, np.int8)
    assert pack_trits(d)[0] == 0x55555555


def test_batched_roundtrip_matches_decode():
    rng = np.random.default_rng(7)
    vals = rng.integers(-(2**14), 2**14, (5, 9))
    d = csd_digits(vals)  # (5, 9, L)
    w = pack_trits(d)
    assert w.shape[:2] == (5, 9)
    back = unpack_trits(w, d.shape[-1])
    assert np.array_equal(back, d)
    assert np.array_equal(csd_decode(back.astype(np.int64)), vals)
