"""FIR designer vs scipy; the paper's sweep and quantization (§3.1–§3.2)."""
import numpy as np
import pytest
import scipy.signal as ss

pytest.importorskip("hypothesis", reason="property tests need hypothesis "
                    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import po2_quantize, po2_quantize_batch, fir_blmac_additions  # noqa: E402
from repro.filters import (design_bank, fir_bit_layers, fir_direct,  # noqa: E402
                           fir_symmetric, sweep_bank, sweep_specs)


@pytest.mark.parametrize("window,swindow", [
    ("hamming", "hamming"), (("kaiser", 8.0), ("kaiser", 8.0))])
@pytest.mark.parametrize("kind,cut,kw", [
    ("lowpass", 0.3, dict(cutoff=0.3, pass_zero=True)),
    ("highpass", 0.4, dict(cutoff=0.4, pass_zero=False)),
    ("bandpass", (0.2, 0.5), dict(cutoff=[0.2, 0.5], pass_zero=False)),
    ("bandstop", (0.25, 0.6), dict(cutoff=[0.25, 0.6], pass_zero=True))])
def test_firwin_matches_scipy(window, swindow, kind, cut, kw):
    for taps in (55, 91, 255):
        ours = design_bank(taps, [(kind, cut)], window)[0]
        theirs = ss.firwin(taps, window=swindow, **kw)
        np.testing.assert_allclose(ours, theirs, atol=1e-12)


def test_sweep_population():
    specs = sweep_specs(10)
    assert len(specs) == 90  # N(N-1)
    kinds = [s.kind for s in specs]
    assert kinds.count("lowpass") == 9
    assert kinds.count("bandpass") == 9 * 8 // 2


def test_po2_quantize_fills_range():
    """§3.2: the largest coefficient must truly need 16 bits."""
    bank = sweep_bank(55, 12)
    q, k = po2_quantize_batch(bank, 16)
    assert q.max() <= 32767 and q.min() >= -32768
    assert (np.abs(q).max(axis=1) >= 16384).all()  # top bit used


def test_po2_single_matches_batch():
    bank = sweep_bank(55, 8)
    qb, kb = po2_quantize_batch(bank, 16)
    for i in range(0, len(bank), 7):
        q, k = po2_quantize(bank[i], 16)
        assert k == kb[i]
        assert np.array_equal(q, qb[i])


@given(st.integers(0, 2**32 - 1))
@settings(max_examples=25, deadline=None)
def test_application_paths_bit_exact(seed):
    rng = np.random.default_rng(seed)
    taps = int(rng.choice([7, 25, 55]))
    half = rng.integers(-32768, 32768, taps // 2 + 1)
    w = np.concatenate([half[:-1], half[-1:], half[:-1][::-1]])
    x = rng.integers(-128, 128, taps + 100)
    y = fir_direct(x, w)
    assert np.array_equal(y, fir_symmetric(x, w))
    assert np.array_equal(y, fir_bit_layers(x, w))
    assert np.array_equal(y, fir_bit_layers(x, w, symmetric=False))


def test_additions_count_matches_paper_example_scale():
    bank = sweep_bank(127, 12, "hamming")
    q, _ = po2_quantize_batch(bank, 16)
    adds = [fir_blmac_additions(row) for row in q]
    # Fig. 3 neighbourhood for N=127: ~230-320 additions on average
    assert 200 < np.mean(adds) < 350
