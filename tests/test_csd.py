"""Property tests for the CSD/NAF codec — the paper's §2 core."""
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis "
                    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import (csd_decode, csd_digits, csd_truncate, max_pulses,  # noqa: E402
                        num_pulses, pack_trits, unpack_trits)


@given(st.lists(st.integers(-2**40, 2**40), min_size=1, max_size=50))
@settings(max_examples=200, deadline=None)
def test_roundtrip(vals):
    w = np.asarray(vals, np.int64)
    assert np.array_equal(csd_decode(csd_digits(w)), w)


@given(st.integers(0, 2**24 - 1))
@settings(max_examples=300, deadline=None)
def test_pulse_bound(v):
    """NAF uses at most ⌈(n+1)/2⌉ pulses for an n-bit magnitude (Tab. 3)."""
    n = max(1, int(v).bit_length())
    assert num_pulses(np.asarray([v]))[0] <= max_pulses(n)


@given(st.integers(1, 2**30))
@settings(max_examples=200, deadline=None)
def test_nonadjacent(v):
    d = csd_digits(np.asarray([v]))[0]
    nz = d != 0
    assert not np.any(nz[:-1] & nz[1:]), "NAF must have no adjacent pulses"


@given(st.integers(-2**30, 2**30))
@settings(max_examples=200, deadline=None)
def test_sign_symmetry(v):
    assert num_pulses(np.asarray([v]))[0] == num_pulses(np.asarray([-v]))[0]


@given(st.integers(1, 2**20), st.integers(1, 6))
@settings(max_examples=200, deadline=None)
def test_truncate_bound(v, planes):
    """Keeping P pulses bounds the error by 2^(e - 2P + 2) (NAF pulses
    descend ≥ 2 positions per step)."""
    t = csd_truncate(np.asarray([v]), planes)[0]
    assert num_pulses(np.asarray([abs(t)]))[0] <= planes
    e = int(v).bit_length()
    assert abs(v - t) < 2.0 ** max(e - 2 * planes + 2, 0)


@given(st.lists(st.integers(-2**20, 2**20), min_size=1, max_size=20),
       st.integers(0, 8))
@settings(max_examples=200, deadline=None)
def test_truncate_pulse_budget_invariants(vals, planes):
    """csd_truncate is a pulse-budget quantizer: (1) the result never
    spends more than `planes` pulses, (2) weights already under budget
    pass through exactly, (3) it is idempotent."""
    w = np.asarray(vals, np.int64)
    t = csd_truncate(w, planes)
    assert (num_pulses(np.abs(t)) <= planes).all()
    under = num_pulses(np.abs(w)) <= planes
    assert np.array_equal(t[under], w[under])
    assert np.array_equal(csd_truncate(t, planes), t)


@given(st.lists(st.integers(-1, 1), min_size=1, max_size=100))
@settings(max_examples=200, deadline=None)
def test_pack_roundtrip(trits):
    t = np.asarray(trits, np.int8)
    assert np.array_equal(unpack_trits(pack_trits(t), t.shape[-1]), t)


def test_paper_table3_small():
    """Exact agreement with the paper's Tab. 3 for 1..16 bits."""
    paper_avg = [0.5, 1.0, 1.37, 1.75, 2.09, 2.44, 2.77, 3.11, 3.44, 3.77,
                 4.11, 4.44, 4.78, 5.11, 5.44, 5.77]
    paper_max = [1, 2, 2, 3, 3, 4, 4, 5, 5, 6, 6, 7, 7, 8, 8, 9]
    for n in range(1, 17):
        p = num_pulses(np.arange(1 << n))
        assert abs(p.mean() - paper_avg[n - 1]) < 0.01, n
        assert p.max() == paper_max[n - 1], n


def test_ntrits_paper_example():
    assert num_pulses(np.asarray([118]))[0] == 3  # 118 = (1,0,0,0,-1,0)
