"""Sessions × shards: `BankSessionServer` on a `ShardedFilterBankEngine`.

The composition under test: multi-tenant shared-lane batching (PR 7)
running ON TOP of the fault-tolerant sharded mesh (PR 6).  Lane
dispatches route through the sharded engine's stateless `apply_lanes`,
so a shard kill / transient / corruption mid-`step()` triggers the
engine's recovery machinery while the session layer provides per-tenant
fault isolation: only the sessions in the failed dispatch round replay,
and `fault_stats()` attributes the fault to exactly those tenants.
"""
import signal

import numpy as np
import pytest

from repro.compiler import compile_bank
from repro.distributed.faultbank import FaultInjector, TransientShardError
from repro.filters import (ShardedFilterBankEngine, fir_bit_layers_batch,
                           spread_lowpass_qbank)
from repro.serving import BankSessionServer
from tests._subproc import run_py, run_py_raw

TAPS = 31


def _program(n_filters: int = 8, taps: int = TAPS):
    return compile_bank(spread_lowpass_qbank(n_filters, taps))


def _sharded_server(prog, inj=None, n_slots=2, **engine_kw):
    eng = ShardedFilterBankEngine(
        prog, channels=n_slots, fault_injector=inj, **engine_kw
    )
    return BankSessionServer(prog, n_slots=n_slots, auto_step=False,
                             engine=eng), eng


def _stream_one(srv, session, rows, x, chunk=100):
    outs = []
    for k in range(0, x.size, chunk):
        session.push(x[k:k + chunk])
        srv.step()
        out = session.pull()
        if out.shape[1]:
            outs.append(out)
    return np.concatenate(outs, axis=1)


# ---------------------------------------------------------------------------
# engine injection contract
# ---------------------------------------------------------------------------


def test_engine_injection_validates_program_and_geometry():
    prog = _program()
    other = _program(taps=TAPS + 2)
    with pytest.raises(ValueError, match="program"):
        BankSessionServer(
            prog, n_slots=2, auto_step=False,
            engine=ShardedFilterBankEngine(other, channels=2),
        )
    with pytest.raises(ValueError, match="channel lanes"):
        BankSessionServer(
            prog, n_slots=4, auto_step=False,
            engine=ShardedFilterBankEngine(prog, channels=2),
        )


def test_swap_program_refused_on_injected_engine():
    prog = _program()
    srv, _ = _sharded_server(prog)
    with pytest.raises(ValueError, match="injected"):
        srv.swap_program(_program(taps=TAPS + 2))


def test_sessions_on_sharded_engine_bit_exact_no_faults():
    prog = _program()
    rng = np.random.default_rng(5)
    srv, eng = _sharded_server(prog)
    sels = [[0, 3], [5, 1], [7]]
    sessions = [srv.open_session(r) for r in sels]
    streams = [rng.integers(-128, 128, 4 * 100).astype(np.int32)
               for _ in sels]
    outs = [[] for _ in sels]
    for k in range(4):  # 3 tenants over 2 lanes: multi-round steps
        for i, s in enumerate(sessions):
            s.push(streams[i][k * 100:(k + 1) * 100])
        srv.step()
        for i, s in enumerate(sessions):
            out = s.pull()
            if out.shape[1]:
                outs[i].append(out)
    for i, sel in enumerate(sels):
        ref = fir_bit_layers_batch(
            streams[i][None, :], prog.qbank
        )[np.asarray(sel), 0]
        assert np.array_equal(np.concatenate(outs[i], axis=1), ref)
    # lane dispatches went through the sharded engine, statelessly
    assert eng._chunk_idx == srv.rounds
    assert eng.samples_in == 0 and not eng._inflight


# ---------------------------------------------------------------------------
# fault paths: transient retry, corruption heal, attribution, isolation
# ---------------------------------------------------------------------------


def test_transient_fault_is_retried_inside_step_and_attributed():
    prog = _program()
    inj = FaultInjector().fail_push(0, at_chunk=1, times=1)
    srv, _ = _sharded_server(prog, inj)
    s = srv.open_session([0, 3])
    x = np.random.default_rng(0).integers(-128, 128, 400).astype(np.int32)
    got = _stream_one(srv, s, [0, 3], x)
    ref = fir_bit_layers_batch(x[None, :], prog.qbank)[[0, 3], 0]
    assert np.array_equal(got, ref)
    fs = srv.fault_stats()
    assert srv.step_retries == 1 and fs["transients"] == 1
    assert fs["session_faults"] == 1 and fs["per_session"][s.session_id] == 1


def test_corruption_is_healed_in_call_and_attributed():
    prog = _program()
    inj = FaultInjector().corrupt_output(0, at_chunk=1, times=1)
    srv, _ = _sharded_server(prog, inj, integrity_check=True)
    s = srv.open_session([1, 2])
    x = np.random.default_rng(1).integers(-128, 128, 400).astype(np.int32)
    got = _stream_one(srv, s, [1, 2], x)
    ref = fir_bit_layers_batch(x[None, :], prog.qbank)[[1, 2], 0]
    assert np.array_equal(got, ref)
    fs = srv.fault_stats()
    assert fs["corruptions"] == 1 and fs["replayed_chunks"] == 1
    assert srv.step_retries == 0  # healed inside the call, not re-raised
    assert fs["per_session"][s.session_id] == 1


def test_retry_exhaustion_raises_and_leaves_queue_intact():
    prog = _program()
    # three consecutive dispatch indices armed: with max_step_retries=1
    # the second attempt exhausts the budget and step() re-raises
    inj = (FaultInjector().fail_push(0, at_chunk=1)
           .fail_push(0, at_chunk=2).fail_push(0, at_chunk=3))
    eng = ShardedFilterBankEngine(prog, channels=2, fault_injector=inj)
    srv = BankSessionServer(prog, n_slots=2, auto_step=False, engine=eng,
                            max_step_retries=1)
    s = srv.open_session([0])
    x = np.random.default_rng(2).integers(-128, 128, 300).astype(np.int32)
    s.push(x[:100])
    srv.step()
    delivered = [s.pull()]
    s.push(x[100:200])
    with pytest.raises(TransientShardError):
        srv.step()
    # nothing consumed, nothing lost: the chunk is still queued and a
    # later step (fault drained) serves it bit-exactly
    assert s.queued_samples == 100 and len(s.queue) == 1
    s.push(x[200:])
    srv.step()
    delivered.append(s.pull())
    got = np.concatenate(delivered, axis=1)
    ref = fir_bit_layers_batch(x[None, :], prog.qbank)[[0], 0]
    assert np.array_equal(got, ref)
    assert srv.step_retries == 3  # two in the failed step, one absorbed


def test_faults_attributed_only_to_sessions_in_failed_round():
    """Per-tenant isolation: 4 tenants over 2 lanes = 2 rounds/step; a
    transient in ONE round must mark exactly that round's tenants."""
    prog = _program()
    inj = FaultInjector().fail_push(0, at_chunk=1, times=1)
    srv, _ = _sharded_server(prog, inj)
    sessions = [srv.open_session([i]) for i in range(4)]
    rng = np.random.default_rng(3)
    for s in sessions:
        s.push(rng.integers(-128, 128, 100).astype(np.int32))
    srv.step()  # round 0 = chunk 0 (clean), round 1 = chunk 1 (faulted)
    faults = [s.faults for s in sessions]
    assert faults == [0, 0, 1, 1]
    assert srv.session_faults == 1


# ---------------------------------------------------------------------------
# forced-8-device legs: real meshes, kills, degradation, crash recovery
# ---------------------------------------------------------------------------


def test_sessions_survive_shard_kills_on_real_mesh():
    out = run_py(f"""
import numpy as np
from repro.compiler import compile_bank
from repro.distributed.faultbank import FaultInjector
from repro.filters import (ShardedFilterBankEngine, fir_bit_layers_batch,
                           spread_lowpass_qbank)
from repro.serving import BankSessionServer

prog = compile_bank(spread_lowpass_qbank(64, {TAPS}))
rng = np.random.default_rng(7)
N, CH = 12, 128
sels = [np.arange((i * 5) % 60, (i * 5) % 60 + 5) for i in range(N)]
inj = FaultInjector().kill_shard(1, at_chunk=2).kill_shard(0, at_chunk=5)
eng = ShardedFilterBankEngine(prog, channels=4, n_bank_shards=4,
                              fault_injector=inj)
srv = BankSessionServer(prog, n_slots=4, auto_step=False, engine=eng,
                        step_budget_us=1e9)
ss = [srv.open_session(sels[i]) for i in range(N)]
streams = [rng.integers(-128, 128, CH * 8).astype(np.int32)
           for _ in range(N)]
outs = [[] for _ in range(N)]
for k in range(8):
    for i, s in enumerate(ss):
        s.push(streams[i][k * CH:(k + 1) * CH])
    srv.step()
    for i, s in enumerate(ss):
        o = s.pull()
        if o.shape[1]:
            outs[i].append(o)
for i in range(N):
    ref = fir_bit_layers_batch(streams[i][None, :], prog.qbank)[sels[i], 0]
    assert np.array_equal(np.concatenate(outs[i], axis=1), ref), i
fs = srv.fault_stats()
assert fs["lost_shards"] == 2 and fs["recoveries"] == 2
assert fs["session_faults"] == 2
# exact attribution: 12 tenants / 4 lanes = 3 rounds per step, and both
# kills (dispatch 2 and 5) land in round 2 of their step — the SAME four
# tenants are marked twice, everyone else stays clean
assert sorted(fs["per_session"].values()) == [0] * 8 + [2] * 4
# spare forced-host devices let recovery re-partition at full width
assert eng.n_bank_shards == 4 and not srv.serve_stats()["degraded"]
print("KILLS_OK")
""", devices=8)
    assert "KILLS_OK" in out


def test_degraded_mesh_reprices_admission_and_sheds():
    out = run_py(f"""
import numpy as np
from repro.compiler import compile_bank
from repro.distributed.faultbank import FaultInjector
from repro.filters import (ShardedFilterBankEngine, fir_bit_layers_batch,
                           spread_lowpass_qbank)
from repro.serving import BankSessionServer

from repro.distributed import bank_mesh

prog = compile_bank(spread_lowpass_qbank(9, {TAPS}))
rng = np.random.default_rng(8)
# cascade: three kills degrade the 4x1 mesh to the plain 1x1 engine.
# The mesh is PINNED to 4 devices so recovery cannot re-partition onto
# spare forced-host devices — survivors shrink 4 -> 3 -> ... -> degraded
inj = (FaultInjector().kill_shard(0, at_chunk=1)
       .kill_shard(1, at_chunk=3).kill_shard(0, at_chunk=5))
eng = ShardedFilterBankEngine(prog, channels=2, mesh=bank_mesh(4, 1),
                              n_bank_shards=4, fault_injector=inj)
srv = BankSessionServer(prog, n_slots=2, auto_step=False, engine=eng,
                        step_budget_us=1e12)
s = srv.open_session([0, 4])
x = rng.integers(-128, 128, 8 * 200).astype(np.int32)
outs = []
for k in range(8):
    s.push(x[k * 200:(k + 1) * 200])
    srv.step()
    o = s.pull()
    if o.shape[1]:
        outs.append(o)
ref = fir_bit_layers_batch(x[None, :], prog.qbank)[[0, 4], 0]
assert np.array_equal(np.concatenate(outs, axis=1), ref)
st = srv.serve_stats()
assert st["degraded"] and srv._degraded()
# admission prices against the LIVE (degraded) plan, finitely
pred = srv.predicted_step_us(extra_sessions=1)
assert np.isfinite(pred) and pred > 0
assert srv.fault_stats()["lost_shards"] == 3
print("DEGRADED_OK", f"{{pred:.0f}}us")
""", devices=8)
    assert "DEGRADED_OK" in out


def test_differential_session_chaos_leg(tmp_path):
    """The harness's sessions × shards leg, journaled, on a real mesh."""
    out = run_py(f"""
from tests.differential import random_type1_bank, session_chaos_check

stats = session_chaos_check(
    random_type1_bank(12, taps={TAPS}, seed=5),
    [(1, 3), (0, 9)],
    n_bank_shards=4,
    journal_path={str(tmp_path / "wal")!r},
)
assert stats["detections"] == 2 and stats["n_bank_shards"] >= 1
print("SESSION_CHAOS_OK", stats["replayed_chunks"])
""", devices=8)
    assert "SESSION_CHAOS_OK" in out


def test_chaos_64_sessions_8_shards_kill_and_sigkill_recovery(tmp_path):
    """The acceptance chaos test: 64 tenants over an 8-shard mesh
    survive (a) a mid-step shard kill and (b) a SIGKILL of the whole
    serving process followed by `recover()` — every session's
    concatenated output bit-exact vs an uninterrupted dedicated run,
    with exact fault accounting."""
    wal = str(tmp_path / "wal")
    setup = f"""
import numpy as np
from repro.compiler import compile_bank
from repro.distributed.faultbank import FaultInjector
from repro.filters import (ShardedFilterBankEngine, fir_bit_layers_batch,
                           spread_lowpass_qbank)
from repro.serving import BankSessionServer

TAPS, N, CH, SLOTS = {TAPS}, 64, 128, 8
qbank = spread_lowpass_qbank(64, TAPS)
prog = compile_bank(qbank)
sels = [[i % 64, (i * 7 + 3) % 64] for i in range(N)]

def chunks_for(n_steps):
    rng = np.random.default_rng(21)
    out = [[] for _ in range(N)]
    for _ in range(n_steps):
        for i in range(N):
            out[i].append(rng.integers(-128, 128, CH).astype(np.int32))
    return out
"""
    victim = run_py_raw(setup + f"""
import os, signal
# 64 tenants / 8 lanes = 8 rounds per step; chunk 12 lands mid-step 2
inj = FaultInjector().kill_shard(3, at_chunk=12)
eng = ShardedFilterBankEngine(prog, channels=SLOTS, n_bank_shards=8,
                              fault_injector=inj)
srv = BankSessionServer(prog, n_slots=SLOTS, auto_step=False, engine=eng,
                        step_budget_us=1e12, journal={wal!r},
                        snapshot_every=2)
ss = [srv.open_session(sels[i], session_id=f"t{{i}}") for i in range(N)]
chunks = chunks_for(4)
for k in range(3):
    for i, s in enumerate(ss):
        s.push(chunks[i][k])
    srv.step()
    for s in ss:
        s.pull()
fs = srv.fault_stats()
assert fs["lost_shards"] == 1 and fs["recoveries"] == 1, fs
assert fs["session_faults"] == 1, fs
assert sorted(fs["per_session"].values()) == [0] * 56 + [1] * 8, fs
assert eng.n_bank_shards == 7
for i, s in enumerate(ss):   # chunk 4: journaled, queued, never stepped
    s.push(chunks[i][3])
print("VICTIM_OK", flush=True)
os.kill(os.getpid(), signal.SIGKILL)
""", devices=8, timeout=600)
    assert victim.returncode == -signal.SIGKILL, (
        victim.stdout + victim.stderr
    )
    assert "VICTIM_OK" in victim.stdout

    out = run_py(setup + f"""
eng = ShardedFilterBankEngine(prog, channels=SLOTS, n_bank_shards=8)
srv = BankSessionServer.recover({wal!r}, prog, engine=eng,
                                step_budget_us=1e12)
assert len(srv.sessions) == N
chunks = chunks_for(5)
outs = [[] for _ in range(N)]
ss = [srv.sessions[f"t{{i}}"] for i in range(N)]
for i, s in enumerate(ss):
    out = s.pull()           # regenerated, journal-trimmed
    if out.shape[1]:
        outs[i].append(out)
for i, s in enumerate(ss):   # one more chunk after recovery
    s.push(chunks[i][4])
srv.step()
for i, s in enumerate(ss):
    out = s.pull()
    if out.shape[1]:
        outs[i].append(out)
n_pre = 3 * CH - (TAPS - 1)  # delivered by the victim before the crash
for i in range(N):
    x = np.concatenate(chunks[i])
    ref = fir_bit_layers_batch(x[None, :], qbank)[np.asarray(sels[i]), 0]
    got = np.concatenate(outs[i], axis=1)
    assert got.shape[1] == 2 * CH, (i, got.shape)   # chunks 4+5, no gaps
    assert np.array_equal(got, ref[:, n_pre:n_pre + got.shape[1]]), i
    assert ss[i].samples_in == 5 * CH
srv.close()
print("CHAOS_OK")
""", devices=8, timeout=600)
    assert "CHAOS_OK" in out
