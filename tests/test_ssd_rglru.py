"""SSD chunked algorithm and RG-LRU scan vs naive step-by-step recurrences."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.nn.common import ShardCtx, init_params
from repro.nn.ssd import ssd_apply, ssd_decls, ssd_decode
from repro.nn.rglru import rglru_apply, rglru_decls, rglru_decode


def test_ssd_prefill_matches_stepwise_decode():
    """Running the chunked SSD over S tokens must equal S single-step
    recurrences (the decode path) — the state-space duality itself."""
    cfg = get_config("mamba2-370m").reduced(
        d_model=48, ssm_heads=4, ssm_head_dim=8, ssm_state=16)
    p = init_params(ssd_decls(cfg), jax.random.key(0))
    rng = np.random.default_rng(0)
    B, S = 2, 23
    x = jnp.asarray(rng.standard_normal((B, S, 48)) * 0.3, jnp.float32)
    ctx = ShardCtx(compute_dtype=jnp.float32, make_cache=True)
    y_full, cache = ssd_apply(p, x, ctx, cfg, None, chunk=8)
    # stepwise
    state = {"state": jnp.zeros_like(cache["state"]),
             "conv_tail": jnp.zeros_like(cache["conv_tail"])}
    ys = []
    ctx1 = ShardCtx(compute_dtype=jnp.float32)
    for t in range(S):
        y1, state = ssd_decode(p, x[:, t:t+1], state, ctx1, cfg, None)
        ys.append(y1)
    y_step = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_full), np.asarray(y_step),
                               rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(cache["state"]),
                               np.asarray(state["state"]), rtol=2e-3, atol=2e-3)


def test_rglru_scan_matches_loop():
    cfg = get_config("recurrentgemma-2b").reduced(
        d_model=32, rglru_width=32)
    p = init_params(rglru_decls(cfg), jax.random.key(1))
    rng = np.random.default_rng(1)
    B, S = 2, 17
    x = jnp.asarray(rng.standard_normal((B, S, 32)) * 0.5, jnp.float32)
    ctx = ShardCtx(compute_dtype=jnp.float32, make_cache=True)
    y_full, cache = rglru_apply(p, x, ctx, cfg, None)
    state = {"h": jnp.zeros_like(cache["h"]),
             "conv_tail": jnp.zeros_like(cache["conv_tail"])}
    ys = []
    ctx1 = ShardCtx(compute_dtype=jnp.float32)
    for t in range(S):
        y1, state = rglru_decode(p, x[:, t:t+1], state, ctx1, cfg, None)
        ys.append(y1)
    y_step = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_full), np.asarray(y_step),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(cache["h"]), np.asarray(state["h"]),
                               rtol=1e-4, atol=1e-5)
