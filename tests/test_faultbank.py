"""Fault tolerance of the sharded bank service: the taxonomy/injector/
watchdog substrate (`repro.distributed.faultbank`), tail-snapshot
capture/restore/persist (`repro.compiler.state`), the engine's
detect → re-partition → replay recovery, `AsyncBankServer`'s bounded
retry/deadline semantics, and the multi-device chaos legs (kill grids,
cascade to the degraded engine, time/channel mesh recovery) in a
forced-8-device subprocess."""
import json
import os

import numpy as np
import pytest

from repro.compiler import (SnapshotFormatError, TailSnapshot, compile_bank)
from repro.core import predict_recovery_us
from repro.distributed.faultbank import (FaultInjector, PendingInvalidated,
                                         ShardHealth, ShardLost,
                                         StragglerStats, TransientShardError)
from repro.filters import (FilterBankEngine, ShardedFilterBankEngine,
                           fir_bit_layers_batch, spread_lowpass_qbank)
from repro.serving import AsyncBankServer
from tests._subproc import run_py

TAPS = 31


def _qbank(n_filters: int, taps: int = TAPS) -> np.ndarray:
    return spread_lowpass_qbank(n_filters, taps)


def _stream(seed: int, n: int) -> np.ndarray:
    return np.random.default_rng(seed).integers(-128, 128, n)


# ---------------------------------------------------------------------------
# substrate: compat re-exports, watchdog, injector (no devices involved)
# ---------------------------------------------------------------------------


def test_fault_module_reexports_survive_the_move():
    # StragglerStats / SimulatedFailure moved to faultbank; the train
    # module and the package root must keep serving the same objects
    import repro.distributed as dist
    from repro.distributed import fault, faultbank

    assert fault.StragglerStats is faultbank.StragglerStats
    assert fault.SimulatedFailure is faultbank.SimulatedFailure
    assert dist.StragglerStats is faultbank.StragglerStats
    for name in ("FaultInjector", "ShardHealth", "ShardLost",
                 "TransientShardError", "RetriesExhausted"):
        assert getattr(dist, name) is getattr(faultbank, name)


def test_straggler_stats_flags_only_with_history():
    st = StragglerStats(factor=2.0)
    assert not any(st.record(100.0) for _ in range(4))  # < 5 samples: never
    st = StragglerStats(factor=2.0)
    for _ in range(4):
        st.record(1.0)
    assert st.record(100.0)  # 5th sample: median window armed, 100 > 2x1
    assert not st.record(1.0)
    assert st.slow_steps == 1


def test_shard_health_reset_and_summary():
    h = ShardHealth(3, timeout=0.5, straggler_factor=3.0)
    for _ in range(6):
        h.record(0, 0.01)
    assert h.record(0, 1.0)  # straggler on shard 0
    s = h.summary()
    assert s["n_shards"] == 3 and s["timeout_s"] == 0.5
    assert s["heartbeats"] == [7, 0, 0] and s["slow_steps"][0] == 1
    h.reset(2)  # recovery re-partition rebuilds the per-shard series
    assert h.n_shards == 2 and h.summary()["heartbeats"] == [0, 0]


def test_injector_is_deterministic_and_slot_scoped():
    inj = FaultInjector().kill_shard(1, at_chunk=2).kill_shard(1, at_chunk=5)
    inj.fail_push(0, at_chunk=1, times=2).corrupt_output(2, at_chunk=3)
    # chunks before the kill pass; the kill then fires for EVERY chunk
    # until the engine removes the shard (a dead machine stays dead)
    inj.on_dispatch(1, 0)
    inj.on_dispatch(1, 1)
    with pytest.raises(ShardLost):
        inj.on_dispatch(1, 2)
    with pytest.raises(ShardLost):
        inj.on_dispatch(1, 3)
    assert inj.faults_injected()["kills"] == 1  # one kill event, not two
    # removal retires only the FIRED kill; the second (1, 5) entry keeps
    # targeting slot 1 of the recovered mesh
    inj.on_shard_removed(1)
    inj.on_dispatch(1, 3)
    with pytest.raises(ShardLost):
        inj.on_dispatch(1, 5)
    assert inj.faults_injected()["kills"] == 2
    # transients burn a per-(shard, chunk) budget, then pass
    for _ in range(2):
        with pytest.raises(TransientShardError):
            inj.on_dispatch(0, 1)
    inj.on_dispatch(0, 1)
    # corruption damages the block exactly `times` times
    a = np.zeros((2, 1, 4), np.int32)
    assert inj.corrupt(2, 3, a).sum() == 8
    assert inj.corrupt(2, 3, a).sum() == 0
    assert inj.faults_injected() == {
        "kills": 2, "delays": 0, "transients": 2, "corruptions": 1,
    }


def test_predict_recovery_us_orders_candidates_sensibly():
    # more shards to re-plan and more samples to replay both cost more;
    # a faster steady state amortizes over the serving horizon
    base = predict_recovery_us(100.0, 2, 1000)
    assert predict_recovery_us(100.0, 4, 1000) > base
    assert predict_recovery_us(100.0, 2, 50_000) > base
    assert predict_recovery_us(50.0, 2, 1000) < base


# ---------------------------------------------------------------------------
# tail snapshots: capture / restore / persist (content-addressed)
# ---------------------------------------------------------------------------


def test_tail_snapshot_resumes_both_engines_bit_exactly():
    q = _qbank(5)
    x = _stream(0, 1200)
    ref = fir_bit_layers_batch(x, q)[:, 0, :]
    for make in (lambda: FilterBankEngine(q),
                 lambda: ShardedFilterBankEngine(q)):
        eng = make()
        eng.push(x[:700])
        snap = eng.snapshot_tail()
        assert snap.samples_in == 700
        a = eng.push(x[700:])  # the uninterrupted continuation
        fresh = make()
        fresh.restore_tail(snap)
        b = fresh.push(x[700:])  # resumed from the frozen state
        assert np.array_equal(a, b)
        assert np.array_equal(b[:, 0, :], ref[:, 700 - TAPS + 1:])


def test_tail_snapshot_rejects_foreign_program_and_channels():
    q = _qbank(4)
    other = compile_bank(_qbank(4, taps=15))
    for eng in (FilterBankEngine(q), ShardedFilterBankEngine(q)):
        eng.push(_stream(1, 400))
        snap = eng.snapshot_tail()
        with pytest.raises(ValueError, match="belongs to program"):
            FilterBankEngine(other).restore_tail(snap)
        with pytest.raises(ValueError, match="channels"):
            FilterBankEngine(q, channels=2).restore_tail(snap)


def test_tail_snapshot_file_roundtrip_and_format_errors(tmp_path):
    eng = FilterBankEngine(_qbank(3), channels=2)
    eng.push(np.stack([_stream(2, 500), _stream(3, 500)]))
    snap = eng.snapshot_tail()
    path = os.path.join(tmp_path, "tail.npz")
    snap.save(path)
    back = TailSnapshot.load(path)
    assert back.program_key == snap.program_key
    assert back.samples_in == snap.samples_in == 500
    assert back.samples_out == snap.samples_out
    assert np.array_equal(back.tail, snap.tail)
    eng2 = FilterBankEngine(_qbank(3), channels=2)
    eng2.restore_tail(back)
    assert eng2.pending == eng.pending
    # every way the file can be bad is a loud SnapshotFormatError
    bad = os.path.join(tmp_path, "bad.npz")
    with open(bad, "wb") as f:
        f.write(b"not a zipfile")
    with pytest.raises(SnapshotFormatError):
        TailSnapshot.load(bad)
    prog = os.path.join(tmp_path, "prog.npz")  # wrong kind of artifact
    eng.program.save(prog)
    with pytest.raises(SnapshotFormatError, match="not a tail-snapshot"):
        TailSnapshot.load(prog)


# ---------------------------------------------------------------------------
# engine semantics on a 1x1 mesh (fault paths that need no second device)
# ---------------------------------------------------------------------------


def test_reset_invalidates_inflight_pendings():
    eng = ShardedFilterBankEngine(_qbank(4))
    p = eng.push_async(_stream(4, 600))
    eng.reset()  # regression: used to let result() reassemble stale rows
    with pytest.raises(PendingInvalidated):
        p.result()
    # the reset stream itself is unharmed
    x = _stream(5, 600)
    assert np.array_equal(
        eng.push(x)[:, 0, :], fir_bit_layers_batch(x, _qbank(4))[:, 0, :]
    )


def test_restore_tail_invalidates_inflight_pendings():
    eng = ShardedFilterBankEngine(_qbank(4))
    snap = eng.snapshot_tail()
    p = eng.push_async(_stream(6, 500))
    eng.restore_tail(snap)
    with pytest.raises(PendingInvalidated):
        p.result()


def test_corruption_is_detected_and_replayed_bit_exactly():
    q = _qbank(5)
    inj = FaultInjector().corrupt_output(0, at_chunk=1, times=1)
    eng = ShardedFilterBankEngine(q, fault_injector=inj, integrity_check=True)
    x = _stream(7, 1024)
    a = eng.push(x[:512])
    b = eng.push(x[512:])  # corrupted once, healed by snapshot replay
    y = np.concatenate([a, b], axis=2)[:, 0, :]
    assert np.array_equal(y, fir_bit_layers_batch(x, q)[:, 0, :])
    st = eng.fault_stats()
    assert st["corruptions"] == 1 and st["replayed_chunks"] == 1
    assert st["detections"] == 1 and st["recoveries"] == 0


def test_persistent_corruption_escalates_to_loss():
    inj = FaultInjector().corrupt_output(0, at_chunk=0, times=10)
    eng = ShardedFilterBankEngine(_qbank(4), fault_injector=inj,
                                  integrity_check=True)
    with pytest.raises(ShardLost, match="no surviving devices"):
        eng.push(_stream(8, 600))
    # max_heals replays + the escalating detection, all counted
    assert eng.fault.corruptions == eng.max_heals + 1
    assert eng.fault.replayed_chunks == eng.max_heals


def test_losing_the_only_shard_is_unrecoverable_not_a_hang():
    inj = FaultInjector().kill_shard(0, at_chunk=0)
    eng = ShardedFilterBankEngine(_qbank(4), fault_injector=inj)
    p = eng.push_async(_stream(9, 500))  # dispatch does not raise
    with pytest.raises(ShardLost, match="no surviving devices"):
        p.result()
    assert eng.fault_stats()["detections"] == 1
    assert eng.fault_stats()["recoveries"] == 0


def test_watchdog_timeout_escalates_to_loss():
    inj = FaultInjector().delay_shard(0, at_chunk=0, seconds=0.6)
    eng = ShardedFilterBankEngine(_qbank(4), fault_injector=inj,
                                  shard_timeout=0.05)
    with pytest.raises(ShardLost):
        eng.push(_stream(10, 500))
    st = eng.fault_stats()
    assert st["timeouts"] == 1 and st["health"]["timeout_s"] == 0.05


# ---------------------------------------------------------------------------
# AsyncBankServer failure semantics (retry / deadline / ordering)
# ---------------------------------------------------------------------------


def test_server_retries_transients_then_succeeds():
    q = _qbank(5)
    inj = FaultInjector().fail_push(0, at_chunk=1, times=2)
    eng = ShardedFilterBankEngine(q, fault_injector=inj)
    server = AsyncBankServer(eng, depth=2, max_retries=3, backoff_s=1e-4)
    x = _stream(11, 4 * 512)
    got = []
    for k in range(4):
        got += server.submit(x[k * 512:(k + 1) * 512])
    got += server.drain()
    y = np.concatenate([g for g in got if g.shape[2]], axis=2)[:, 0, :]
    assert np.array_equal(y, fir_bit_layers_batch(x, q)[:, 0, :])
    assert server.retries == 2 and server.failed_chunks == 0
    st = server.fault_stats()
    assert st["engine"]["transients"] == 2
    assert st["engine"]["replayed_chunks"] >= 2  # each retry re-armed


def test_server_exhausts_retries_and_the_stream_survives():
    q = _qbank(5)
    inj = FaultInjector().fail_push(0, at_chunk=0, times=10)
    eng = ShardedFilterBankEngine(q, fault_injector=inj)
    server = AsyncBankServer(eng, depth=2, max_retries=2, backoff_s=1e-4)
    x = _stream(12, 2 * 500)
    from repro.distributed.faultbank import RetriesExhausted

    server.submit(x[:500])
    server.submit(x[500:])
    with pytest.raises(RetriesExhausted):
        server.drain()
    assert server.retries_exhausted == 1 and server.failed_chunks == 1
    # the failed chunk is DROPPED, not wedged: the next drain resolves
    # the younger chunk, whose outputs continue the stream bit-exactly
    # (the tail state advanced at dispatch; only chunk 0's outputs die)
    rest = server.drain()
    assert len(rest) == 1 and server.chunks_out == 1
    ref = fir_bit_layers_batch(x, q)[:, 0, :]
    assert np.array_equal(rest[0][:, 0, :], ref[:, 500 - TAPS + 1:])


def test_server_deadline_expires_before_the_retry_budget():
    inj = FaultInjector().fail_push(0, at_chunk=0, times=10)
    eng = ShardedFilterBankEngine(_qbank(4), fault_injector=inj)
    server = AsyncBankServer(eng, depth=1, max_retries=50,
                             backoff_s=0.02, deadline_s=0.01)
    from repro.distributed.faultbank import DeadlineExceeded

    server.submit(_stream(13, 500))
    with pytest.raises(DeadlineExceeded):
        server.drain()
    assert server.deadline_expired == 1 and server.retries_exhausted == 0
    assert server.inflight == 0  # dropped, never a hang


def test_server_delivers_resolved_outputs_when_a_later_chunk_fails():
    # regression: drain() used to DISCARD outputs already resolved in
    # its loop when a younger chunk then failed terminally — chunk 0's
    # outputs died with chunk 1's RetriesExhausted
    q = _qbank(5)
    inj = FaultInjector().fail_push(0, at_chunk=1, times=10)
    eng = ShardedFilterBankEngine(q, fault_injector=inj)
    server = AsyncBankServer(eng, depth=2, max_retries=1, backoff_s=1e-4)
    from repro.distributed.faultbank import RetriesExhausted

    x = _stream(15, 2 * 500)
    server.submit(x[:500])
    server.submit(x[500:])
    with pytest.raises(RetriesExhausted):
        server.drain()
    # chunk 0 resolved before chunk 1 failed: buffered, not lost
    assert server.fault_stats()["buffered"] == 1
    rest = server.drain()
    assert len(rest) == 1 and server.fault_stats()["buffered"] == 0
    ref = fir_bit_layers_batch(x, q)[:, 0, :]
    assert np.array_equal(rest[0][:, 0, :], ref[:, :500 - TAPS + 1])


def test_server_backoff_never_sleeps_past_the_deadline():
    # regression: uncapped exponential backoff could sleep an arbitrary
    # multiple of deadline_s before re-checking — a 10 s backoff against
    # a 50 ms deadline used to stall the stream for seconds
    import time

    inj = FaultInjector().fail_push(0, at_chunk=0, times=100)
    eng = ShardedFilterBankEngine(_qbank(4), fault_injector=inj)
    server = AsyncBankServer(eng, depth=1, max_retries=1000,
                             backoff_s=10.0, deadline_s=0.05)
    from repro.distributed.faultbank import DeadlineExceeded

    server.submit(_stream(16, 500))
    t0 = time.monotonic()
    with pytest.raises(DeadlineExceeded):
        server.drain()
    assert time.monotonic() - t0 < 2.0  # was ≥ 10 s before the clamp
    assert server.deadline_expired == 1 and server.inflight == 0


def test_server_backoff_is_capped(monkeypatch):
    import time

    sleeps = []
    monkeypatch.setattr(time, "sleep", lambda s: sleeps.append(s))
    inj = FaultInjector().fail_push(0, at_chunk=0, times=100)
    eng = ShardedFilterBankEngine(_qbank(4), fault_injector=inj)
    server = AsyncBankServer(eng, depth=1, max_retries=6,
                             backoff_s=1e-3, max_backoff_s=4e-3)
    from repro.distributed.faultbank import RetriesExhausted

    server.submit(_stream(17, 400))
    with pytest.raises(RetriesExhausted):
        server.drain()
    assert sleeps[:3] == [1e-3, 2e-3, 4e-3]  # doubling…
    assert max(sleeps) <= 4e-3  # …until the cap bites
    with pytest.raises(ValueError):
        AsyncBankServer(eng, max_backoff_s=0.0)


def test_server_fault_stats_are_json_ready():
    eng = ShardedFilterBankEngine(_qbank(4), fault_injector=FaultInjector())
    server = AsyncBankServer(eng)
    server.submit(_stream(14, 400))
    server.drain()
    st = server.fault_stats()
    json.dumps(st)  # the whole surface must serialize
    assert st["chunks_in"] == st["chunks_out"] == 1
    assert st["engine"]["n_bank_shards"] == 1
    assert st["engine"]["injected"]["kills"] == 0
    assert st["engine"]["health"]["heartbeats"] == [1]


# ---------------------------------------------------------------------------
# multi-device recovery legs (forced-8-device subprocess)
# ---------------------------------------------------------------------------


def test_kill_and_recover_8_devices():
    out = run_py("""
import numpy as np
from repro.distributed import bank_mesh
from repro.distributed.faultbank import FaultInjector
from repro.filters import (ShardedFilterBankEngine, fir_bit_layers_batch,
                           spread_lowpass_qbank)
from repro.serving import AsyncBankServer

taps = 31
rng = np.random.default_rng(0)

# -- kill one of four bank shards mid-stream, behind the server --------
q = spread_lowpass_qbank(13, taps)
n_chunks, chunk = 6, 512
x = rng.integers(-128, 128, n_chunks * chunk)
ref = fir_bit_layers_batch(x, q)[:, 0, :]
inj = FaultInjector().kill_shard(1, at_chunk=2)
eng = ShardedFilterBankEngine(q, mesh=bank_mesh(4, 1), n_bank_shards=4,
                              fault_injector=inj)
server = AsyncBankServer(eng, depth=2)
got = []
for k in range(n_chunks):
    got += server.submit(x[k * chunk:(k + 1) * chunk])
got += server.drain()
y = np.concatenate([g for g in got if g.shape[2]], axis=2)[:, 0, :]
assert np.array_equal(y, ref), "recovered stream != uninterrupted stream"
st = eng.fault_stats()
assert st["detections"] == 1 and st["recoveries"] == 1
assert st["lost_shards"] == 1 and st["replayed_chunks"] == 2
assert server.failed_chunks == 0 and server.chunks_out == n_chunks
assert eng.n_bank_shards == 3 and not st["degraded"]
print("KILL_RECOVER_OK", eng.describe())

# -- cascade: three kills degrade 4x1 to the plain 1x1 engine ----------
q2 = spread_lowpass_qbank(9, taps)
x2 = rng.integers(-128, 128, 8 * 400)
ref2 = fir_bit_layers_batch(x2, q2)[:, 0, :]
inj2 = (FaultInjector().kill_shard(0, at_chunk=1)
        .kill_shard(1, at_chunk=3).kill_shard(0, at_chunk=5))
eng2 = ShardedFilterBankEngine(q2, mesh=bank_mesh(4, 1), n_bank_shards=4,
                               fault_injector=inj2)
outs = [eng2.push(x2[k * 400:(k + 1) * 400]) for k in range(8)]
y2 = np.concatenate([o for o in outs if o.shape[2]], axis=2)[:, 0, :]
assert np.array_equal(y2, ref2), "degraded stream != uninterrupted stream"
st2 = eng2.fault_stats()
assert st2["detections"] == 3 and st2["recoveries"] == 3
assert st2["lost_shards"] == 3 and st2["degraded"]
assert eng2.n_bank_shards == 1 and "DEGRADED" in eng2.describe()
assert inj2.faults_injected()["kills"] == 3
print("CASCADE_OK", eng2.describe())
""", devices=8)
    assert "KILL_RECOVER_OK" in out and "CASCADE_OK" in out


def test_data_axis_meshes_recover_8_devices():
    out = run_py("""
import numpy as np
from repro.distributed import bank_mesh
from repro.distributed.faultbank import FaultInjector
from repro.filters import (ShardedFilterBankEngine, fir_bit_layers_batch,
                           spread_lowpass_qbank)
from repro.serving import AsyncBankServer

taps = 31
rng = np.random.default_rng(1)
q = spread_lowpass_qbank(8, taps)

# -- time-sharded 2x2: lose a bank row, keep the halo-exchange axis ----
x = rng.integers(-128, 128, 6 * 600)
ref = fir_bit_layers_batch(x, q)[:, 0, :]
inj = FaultInjector().kill_shard(1, at_chunk=2)
eng = ShardedFilterBankEngine(q, mesh=bank_mesh(2, 2), n_bank_shards=2,
                              data_mode="time", fault_injector=inj,
                              integrity_check=True)
assert eng.data_mode == "time"
outs = [eng.push(x[k * 600:(k + 1) * 600]) for k in range(6)]
y = np.concatenate([o for o in outs if o.shape[2]], axis=2)[:, 0, :]
assert np.array_equal(y, ref)
assert eng.n_bank_shards == 1 and eng.n_data == 2
assert eng.data_mode == "time"
print("TIME_RECOVER_OK", eng.describe())

# -- channel-sharded 2x2 behind the server: C=2 survives a bank kill ---
C = 2
xc = rng.integers(-128, 128, (C, 6 * 512))
refc = fir_bit_layers_batch(xc, q)
injc = FaultInjector().kill_shard(0, at_chunk=3)
engc = ShardedFilterBankEngine(q, channels=C, mesh=bank_mesh(2, 2),
                               n_bank_shards=2, data_mode="channels",
                               fault_injector=injc)
server = AsyncBankServer(engc, depth=2)
got = []
for k in range(6):
    got += server.submit(xc[:, k * 512:(k + 1) * 512])
got += server.drain()
yc = np.concatenate([g for g in got if g.shape[2]], axis=2)
assert np.array_equal(yc, refc)
assert server.failed_chunks == 0 and server.chunks_out == 6
assert engc.fault_stats()["recoveries"] == 1
print("CHANNELS_RECOVER_OK", engc.describe())
""", devices=8)
    assert "TIME_RECOVER_OK" in out and "CHANNELS_RECOVER_OK" in out


def test_chaos_differential_grid_8_devices():
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    out = run_py(f"""
import sys
sys.path.insert(0, {root!r})
from tests.differential import adversarial_bank, chaos_check
from tests.test_sharded_bank import _skewed_bank

# kill grids over the two nastiest banks in the harness: the mixed
# adversarial bank (empty rows, extreme-layer pulses, dense rows) and
# the occupancy-skewed bank — single kills and a two-kill cascade,
# every point bit-exact vs the Eq. 2 oracle with the integrity probe on
adv = adversarial_bank(taps=31)
for kills in ([(1, 2)], [(3, 1)], [(0, 1), (1, 3)]):
    stats = chaos_check(adv, kills, n_bank_shards=4)
    assert stats["lost_shards"] == len(kills)
print("CHAOS_ADVERSARIAL_OK")

skew = _skewed_bank(n_dense=4, n_sparse=4)
for kills in ([(2, 1)], [(0, 2), (0, 4)]):
    stats = chaos_check(skew, kills, n_bank_shards=4, seed=7)
    assert stats["lost_shards"] == len(kills)
print("CHAOS_SKEWED_OK")
""", devices=8)
    assert "CHAOS_ADVERSARIAL_OK" in out and "CHAOS_SKEWED_OK" in out
