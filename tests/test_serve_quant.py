"""BLMAC CSD-P checkpoint quantization for serving."""
import jax
import numpy as np

from repro.configs import get_config
from repro.core.serve_quant import quantize_param_tree
from repro.nn import init_params, model_decls
from repro.serving import ServeEngine


def test_error_decreases_and_engine_runs():
    cfg = get_config("qwen2.5-3b").reduced(n_layers=2, vocab_size=256)
    params = init_params(model_decls(cfg), jax.random.key(0))
    errs = {}
    for p in (1, 2, 4):
        qparams, stats = quantize_param_tree(params, p)
        assert stats["n_quantized"] > 0
        errs[p] = stats["mean_rel_err"]
    assert errs[1] > errs[2] > errs[4]
    assert errs[4] < 0.01
    # quantized model still generates
    eng = ServeEngine(cfg, qparams, cache_len=64)
    out = eng.generate(np.zeros((2, 8), np.int32), max_new_tokens=4)
    assert out.shape == (2, 4)


def test_generate_zero_new_tokens_is_empty():
    # boundary: max_new_tokens=0 must not emit the prefill argmax
    cfg = get_config("qwen2.5-3b").reduced(n_layers=2, vocab_size=256)
    params = init_params(model_decls(cfg), jax.random.key(0))
    eng = ServeEngine(cfg, params, cache_len=64)
    prompts = np.zeros((3, 8), np.int32)
    out = np.asarray(eng.generate(prompts, max_new_tokens=0))
    assert out.shape == (3, 0) and out.dtype == np.int32
    # and one token really is one token (the old off-by-one boundary)
    assert np.asarray(eng.generate(prompts, max_new_tokens=1)).shape == (3, 1)


def test_greedy_tokens_mostly_stable_at_p4():
    cfg = get_config("qwen2.5-3b").reduced(n_layers=2, vocab_size=256)
    params = init_params(model_decls(cfg), jax.random.key(1))
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, 256, (4, 16)).astype(np.int32)
    base = np.asarray(ServeEngine(cfg, params, 64).generate(prompts, 8))
    qp, _ = quantize_param_tree(params, 4)
    quant = np.asarray(ServeEngine(cfg, qp, 64).generate(prompts, 8))
    agree = (base == quant).mean()
    assert agree > 0.7, agree  # CSD-4 ≈ faithful generation
