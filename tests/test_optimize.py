"""Tests for the cross-filter CSE optimizing pass (`repro.compiler.optimize`).

The contract under test, in order of importance:

  1. **Bit-exactness** — an optimized program produces the parent's
     outputs on every backend lane (the `cse_check` differential leg:
     oracle, scheduled interpret + fused xla combine GEMM, specialized,
     vmachine, sharded, both engine modes).
  2. **Accounting** — total pulses and §3.3 adds never increase; §4
     cycles price one extra cycle per combine use.
  3. **Caching** — the pass is content-addressed on ``(parent.key,
     "cse", level)``, memoized (mines exactly once across the engine,
     the autotuner and the cycle predictor), and survives save/load
     with tamper detection.
"""
import numpy as np
import pytest

from repro.compiler import (BlmacProgram, OptimizedProgram, cache_stats,
                            clear_caches, compile_bank, cse_pass, lower)
from repro.filters import FilterBankEngine

from tests.differential import (adversarial_bank, cse_check,
                                random_type1_bank, sampled_sweep_bank)


def _toy_bank():
    bank = np.zeros((3, 15), np.int64)
    bank[:, 7] = [9, 9, 9]  # 9 = 2^0 + 2^3: one shared 2-term pattern
    return bank


# ---------------------------------------------------------------------------
# the pass itself
# ---------------------------------------------------------------------------


def test_cse_toy_shares_center_tap():
    parent = compile_bank(_toy_bank())
    opt = cse_pass(parent)
    assert isinstance(opt, OptimizedProgram)
    assert opt.n_real == 3 and opt.n_shared == 1
    # three 2-pulse rows collapse onto one shared 2-pulse virtual row
    assert int(opt.pulse_counts.sum()) == 2
    assert np.array_equal(opt.use_counts, [1, 1, 1])
    assert np.array_equal(opt.effective_qbank(), parent.qbank)
    assert opt.total_adds() < parent.total_adds()
    assert opt.out_filters == 3 and opt.n_filters == 4


def test_cse_declines_when_nothing_shared():
    bank = np.zeros((2, 15), np.int64)
    bank[0, 7] = 1  # single pulses: no 2-term patterns at all
    bank[1, 7] = 4
    parent = compile_bank(bank)
    assert cse_pass(parent) is parent


def test_cse_level_ilp_is_documented_stretch():
    parent = compile_bank(_toy_bank())
    with pytest.raises(NotImplementedError, match="1912.04210"):
        cse_pass(parent, level="ilp")
    with pytest.raises(ValueError, match="level"):
        cse_pass(parent, level=3)
    with pytest.raises(TypeError):
        cse_pass(np.zeros((2, 15)))


def test_cse_max_shared_caps_virtual_rows():
    parent = compile_bank(random_type1_bank(8, 31, seed=5))
    opt = cse_pass(parent, max_shared=3)
    assert isinstance(opt, OptimizedProgram) and opt.n_shared <= 3
    assert np.array_equal(opt.effective_qbank(), parent.qbank)


def test_cse_row_structure_hooks_point_to_bank():
    opt = cse_pass(compile_bank(_toy_bank()))
    with pytest.raises(NotImplementedError, match="combine"):
        opt.select(np.array([0]))
    with pytest.raises(NotImplementedError, match=r"\.bank"):
        opt.partition(2)
    bank = opt.bank
    assert type(bank) is BlmacProgram and bank is opt.bank  # cached
    assert np.array_equal(bank.packed, opt.packed)


# ---------------------------------------------------------------------------
# differential bit-exactness (every backend lane)
# ---------------------------------------------------------------------------


def test_cse_bit_exact_random_bank():
    report = cse_check(random_type1_bank(6, 31, seed=1), interpret=True)
    assert report["n_shared"] > 0
    assert report["adds_optimized"] <= report["adds_parent"]
    assert report["auto_cse"] in ("optimized", "declined")


def test_cse_bit_exact_sweep_bank():
    report = cse_check(
        sampled_sweep_bank(taps=127, n_filters=6, seed=2), interpret=True
    )
    assert report["n_shared"] > 0
    assert report["adds_optimized"] < report["adds_parent"]


def test_cse_bit_exact_adversarial_bank():
    # empty rows / single pulses / truncated rows — the pass may decline
    # entirely, and cse_check must hold either way
    report = cse_check(adversarial_bank(31, seed=3), interpret=True)
    assert report["adds_optimized"] <= report["adds_parent"]


def test_cse_engine_decline_executes_parent():
    parent = compile_bank(random_type1_bank(10, 31, seed=7))
    opt = cse_pass(parent)
    eng = FilterBankEngine(opt, channels=1, mode="auto", interpret=True)
    assert eng.dispatch_plan.cse in ("optimized", "declined")
    if eng.dispatch_plan.cse == "declined":
        assert eng.program is parent
        assert eng.n_filters == parent.n_filters
    else:
        assert eng.program is opt
    rng = np.random.default_rng(0)
    x = rng.integers(-128, 128, size=(1, 600), dtype=np.int32)
    assert np.array_equal(
        eng.push(x), lower(parent, "scheduled", interpret=True)(x)
    )


# ---------------------------------------------------------------------------
# memoization: CSE mines exactly once across every client
# ---------------------------------------------------------------------------


def test_cse_runs_exactly_once_across_clients():
    from repro.kernels.runtime import (autotune_bank_dispatch,
                                       autotune_sharded_dispatch)

    q = random_type1_bank(8, 31, seed=11)
    clear_caches()
    parent = compile_bank(q)
    opt = cse_pass(parent)
    c1 = cache_stats()
    assert c1["counters"]["cse_passes"] == 1
    assert c1["cse"]["misses"] == 1 and c1["cse"]["size"] == 1

    # engine construction, both autotuners and the cycle predictor all
    # consume the SAME optimized artifact: no re-mining anywhere
    eng = FilterBankEngine(opt, channels=1, mode="auto", interpret=True)
    autotune_bank_dispatch(opt, chunk_hint=2048)
    autotune_sharded_dispatch(opt, mesh_shape=(2, 1), interpret=True)
    cycles = opt.machine_cycles()
    assert cycles.shape == (opt.n_real,)
    assert cse_pass(parent) is opt
    assert cse_pass(opt) is opt  # idempotent
    c2 = cache_stats()
    assert c2["counters"]["cse_passes"] == 1
    assert c2["cse"]["hits"] >= 1
    assert eng.dispatch_plan.cse in ("optimized", "declined")


def test_cse_memo_is_bounded():
    from repro.compiler.optimize import _CSE_MEMO, CSE_MEMO_MAX

    clear_caches()
    for seed in range(CSE_MEMO_MAX + 5):
        cse_pass(compile_bank(random_type1_bank(2, 15, seed=seed)))
    assert len(_CSE_MEMO) <= CSE_MEMO_MAX


# ---------------------------------------------------------------------------
# serialization
# ---------------------------------------------------------------------------


def test_cse_save_load_roundtrip(tmp_path):
    parent = compile_bank(random_type1_bank(5, 31, seed=13))
    opt = cse_pass(parent)
    path = tmp_path / "opt.npz"
    opt.save(path)
    assert BlmacProgram.load(path) is opt  # memo hit: the same object

    clear_caches()
    loaded = BlmacProgram.load(path)
    assert isinstance(loaded, OptimizedProgram)
    assert loaded.key == opt.key and loaded.parent_key == parent.key
    assert np.array_equal(loaded.combine, opt.combine)
    assert np.array_equal(loaded.use_counts, opt.use_counts)
    assert np.array_equal(loaded.packed, opt.packed)
    assert np.array_equal(loaded.effective_qbank(), parent.qbank)
    rng = np.random.default_rng(0)
    x = rng.integers(-128, 128, 400)
    assert np.array_equal(
        lower(loaded, "scheduled", interpret=True)(x),
        lower(parent, "scheduled", interpret=True)(x),
    )


def test_cse_load_rejects_tampered_combine(tmp_path):
    import json

    from repro.compiler.program import ProgramFormatError

    opt = cse_pass(compile_bank(random_type1_bank(5, 31, seed=17)))
    path = tmp_path / "opt.npz"
    opt.save(path)
    with np.load(path, allow_pickle=False) as z:
        arrays = {k: z[k] for k in z.files}
    header = json.loads(str(arrays["header"]))
    combine = arrays["combine"].copy()
    combine[0, 0] += 2  # silently serve the wrong filters? no.
    arrays["combine"] = combine
    np.savez(tmp_path / "evil.npz", **arrays)
    with pytest.raises(ProgramFormatError, match="key"):
        BlmacProgram.load(tmp_path / "evil.npz")
    assert header["cse"]["parent_key"] == opt.parent_key


# ---------------------------------------------------------------------------
# decode round-trip properties (hypothesis leg in test_optimize_props.py)
# ---------------------------------------------------------------------------


def roundtrip_properties(bank: np.ndarray) -> None:
    parent = compile_bank(bank)
    opt = cse_pass(parent)
    assert int(opt.pulse_counts.sum()) <= int(parent.pulse_counts.sum())
    assert opt.total_adds() <= parent.total_adds()
    if not isinstance(opt, OptimizedProgram):
        return
    from repro.core.csd import csd_decode, unpack_trits

    # the packed augmented trits decode to the augmented qbank halves...
    half = bank.shape[1] // 2
    digits = np.swapaxes(unpack_trits(opt.packed, half + 1), 1, 2)
    halves = csd_decode(digits.astype(np.int64))
    assert np.array_equal(halves, opt.qbank[:, : half + 1])
    # ...and the combine folds them back onto the parent's exact bank
    assert np.array_equal(opt.effective_qbank(), parent.qbank)


def test_cse_properties_on_sweep_sample():
    roundtrip_properties(sampled_sweep_bank(taps=63, n_filters=8, seed=23))


def test_cse_properties_on_random_banks():
    for seed in range(4):
        roundtrip_properties(random_type1_bank(4, 31, seed=seed))
