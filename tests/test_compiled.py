"""The compiled execution lanes and the per-backend calibration table.

Covers: xla-lane bit-exactness against the numpy oracle (one-shot and
streamed), the compiled autotuner sweep preferring the fused XLA lowering
at wide merges, calibration fit/persist round-trips under an isolated
cache dir, and backward compatibility of the default (interpret-only)
sweep and plan layout.
"""
import json
import os

import numpy as np
import pytest

from repro.core import po2_quantize_batch
from repro.core.costmodel import (REFERENCE_CALIBRATIONS, BankDispatchPlan,
                                  calibrate_backend, calibration_path,
                                  ensure_calibration, get_calibration)
from repro.compiler import compile_bank, lower
from repro.filters import (FilterBankEngine, ShardedFilterBankEngine,
                           design_bank, fir_bit_layers_batch)
from repro.kernels import autotune_bank_dispatch
from repro.kernels.blmac_fir import LANES
from repro.kernels.runtime import (COMPILED_MERGE_CANDIDATES,
                                   MERGE_CANDIDATES, autotune_sharded_dispatch,
                                   default_lane, resolve_lane)


@pytest.fixture(scope="module", autouse=True)
def _isolated_calibration(tmp_path_factory):
    """Point the calibration cache at a module-scoped temp dir so tests
    never read or write the user's real table, while still sharing one
    fitted file across the tests in this module (fits cost seconds)."""
    prev = os.environ.get("REPRO_CACHE_DIR")
    os.environ["REPRO_CACHE_DIR"] = str(tmp_path_factory.mktemp("cal"))
    try:
        yield
    finally:
        if prev is None:
            os.environ.pop("REPRO_CACHE_DIR", None)
        else:
            os.environ["REPRO_CACHE_DIR"] = prev


def _qbank(n, taps=63):
    cuts = 0.05 + 0.9 * (np.arange(n) + 0.5) / n
    q, _ = po2_quantize_batch(
        design_bank(taps, [("lowpass", float(c)) for c in cuts]), 16
    )
    return q


# ---------------------------------------------------------------------------
# lane resolution + plan layout backward compatibility
# ---------------------------------------------------------------------------


def test_lane_resolution():
    assert default_lane() in LANES
    assert resolve_lane(None) == default_lane()
    assert resolve_lane(True) == default_lane()
    assert resolve_lane("xla") == "xla"
    with pytest.raises(ValueError):
        resolve_lane("cuda")


def test_plan_lane_defaults_to_interpret():
    # positional construction predates the lane field and must keep working
    plan = BankDispatchPlan("scheduled", 512, 128, 8, 123.0)
    assert plan.lane == "interpret"


def test_default_sweep_is_interpret_only():
    q = _qbank(64)
    plan, _ = autotune_bank_dispatch(compile_bank(q), chunk_hint=8192)
    assert plan.lane == "interpret"
    assert plan.merge in MERGE_CANDIDATES


# ---------------------------------------------------------------------------
# calibration table: fit, persist, reread, fall back
# ---------------------------------------------------------------------------


def test_calibrate_backend_fits_and_persists():
    cal = calibrate_backend("xla")
    assert cal.lane == "xla" and cal.source == "fitted"
    assert cal.cpu_model  # stamped with this host's CPU
    for field in ("call_us", "step_us", "mac_us", "unpack_us",
                  "spec_call_us", "spec_op_us"):
        assert getattr(cal, field) > 0.0, field
    # persisted next to the program cache, keyed per lane
    with open(calibration_path()) as f:
        table = json.load(f)
    assert table["xla"]["source"] == "fitted"
    # pure read returns the fitted entry; fit-at-first-use short-circuits
    assert get_calibration("xla") == cal
    assert ensure_calibration("xla") == cal


def test_get_calibration_ignores_foreign_cpu_entry():
    path = calibration_path()
    with open(path) as f:
        table = json.load(f)
    saved = json.dumps(table)
    table["xla"]["cpu_model"] = "some other machine entirely"
    with open(path, "w") as f:
        json.dump(table, f)
    try:
        assert get_calibration("xla") == REFERENCE_CALIBRATIONS["xla"]
    finally:
        with open(path, "w") as f:
            f.write(saved)


def test_get_calibration_unknown_lane_raises():
    with pytest.raises(ValueError):
        get_calibration("cuda")


# ---------------------------------------------------------------------------
# xla lane: bit-exactness
# ---------------------------------------------------------------------------


def test_xla_lane_bit_exact_vs_oracle():
    q = _qbank(24, taps=63)
    prog = compile_bank(q)
    rng = np.random.default_rng(5)
    x = rng.integers(-128, 128, (2, 1500))
    y_oracle = lower(prog, "oracle")(x)
    y_xla = lower(prog, "scheduled", lane="xla")(x)
    assert np.array_equal(np.asarray(y_xla, np.int64), y_oracle)


def test_xla_lane_matches_interpret_on_adversarial_geometry():
    from tests.differential import adversarial_bank

    q = adversarial_bank(taps=15)
    prog = compile_bank(q)
    rng = np.random.default_rng(6)
    x = rng.integers(-128, 128, (1, 700))
    a = lower(prog, "scheduled", interpret=True, tile=128)(x)
    b = lower(prog, "scheduled", lane="xla", tile=128)(x)
    assert np.array_equal(a, b)


def test_engine_compiled_streaming_bit_exact():
    q = _qbank(32, taps=31)
    rng = np.random.default_rng(7)
    x = rng.integers(-128, 128, (1, 900))
    eng = FilterBankEngine(q, channels=1, interpret=True, compiled="xla")
    assert eng.dispatch_plan.lane == "xla"
    assert eng.lane == "xla"
    cuts = [0, 130, 131, 512, 900]
    y = np.concatenate(
        [eng.push(x[:, a:b]) for a, b in zip(cuts, cuts[1:])], axis=2
    )
    expect = fir_bit_layers_batch(x, q)
    assert np.array_equal(np.asarray(y, np.int64), expect)


# ---------------------------------------------------------------------------
# compiled autotuner sweep
# ---------------------------------------------------------------------------


def test_compiled_sweep_picks_xla_wide_merge_on_wide_bank():
    q = _qbank(256)
    prog = compile_bank(q)
    plan, sched = autotune_bank_dispatch(prog, chunk_hint=16384,
                                         compiled="xla")
    # at B=256 the fused XLA lowering wins, and it wins at a wider merge
    # than the interpreter ever picks (the merge-heuristic inversion)
    assert plan.lane == "xla"
    assert plan.mode == "scheduled"
    assert plan.merge in COMPILED_MERGE_CANDIDATES
    assert plan.merge > 1
    assert sched is not None and sched.tile_size == plan.bank_tile
    # repeat dispatch is an LRU hit returning the identical plan object
    again, _ = autotune_bank_dispatch(prog, chunk_hint=16384, compiled="xla")
    assert again is plan
    # the compiled sweep never perturbs the default one
    base, _ = autotune_bank_dispatch(prog, chunk_hint=16384)
    assert base.lane == "interpret"


def test_sharded_compiled_planning_and_degraded_engine():
    q = _qbank(64)
    prog = compile_bank(q)
    plan, part, scheds = autotune_sharded_dispatch(
        prog, channels=1, mesh_shape=(8, 1), chunk_hint=16384, compiled="xla"
    )
    assert all(p.lane == "xla" for p in plan.shard_plans
               if p.mode == "scheduled")
    # single-device mesh degrades to a plain engine that keeps the
    # compiled lane — and stays bit-exact
    eng = ShardedFilterBankEngine(q, compiled="xla")
    rng = np.random.default_rng(8)
    x = rng.integers(-128, 128, (1, 600))
    y = eng.push(x)
    expect = fir_bit_layers_batch(x, q)
    assert np.array_equal(np.asarray(y, np.int64), expect)
