"""Batched filter-bank kernel and streaming engine vs the numpy oracles."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import po2_quantize_batch
from repro.filters import (FilterBankEngine, design_bank, fir_bit_layers_batch,
                           fir_direct)
from repro.kernels import blmac_fir_bank, pack_bank_trits
from repro.kernels.blmac_fir import (blmac_fir_dynamic, blmac_fir_specialized,
                                     specialized_program)


def _qbank(n_filters: int, taps: int, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    specs = []
    for i in range(n_filters):
        lo = 0.05 + 0.85 * i / max(n_filters, 2)
        if i % 3 == 2:
            specs.append(("bandpass", (lo * 0.5 + 0.01, min(lo + 0.1, 0.97))))
        else:
            specs.append(("lowpass", lo + 0.02 * rng.random()))
    q, _ = po2_quantize_batch(design_bank(taps, specs), 16)
    return q


@pytest.mark.parametrize("taps", [7, 63, 127])
@pytest.mark.parametrize("n_filters,channels", [(1, 1), (5, 2), (17, 1)])
def test_bank_matches_batch_oracle(taps, n_filters, channels):
    q = _qbank(n_filters, taps)
    rng = np.random.default_rng(taps * n_filters)
    x = rng.integers(-128, 128, (channels, 900))
    y = blmac_fir_bank(jnp.asarray(x), q, tile=256)
    assert np.array_equal(np.asarray(y), fir_bit_layers_batch(x, q))


def test_bank_16_filters_single_call_bit_exact():
    """The acceptance-criterion shape: one pallas_call, ≥16 filters,
    multi-channel, bit-exact against the batched reference."""
    q = _qbank(16, 63)
    rng = np.random.default_rng(1)
    x = rng.integers(-128, 128, (3, 2000))
    y = blmac_fir_bank(jnp.asarray(x), q, tile=512)
    assert y.shape == (16, 3, 2000 - 63 + 1)
    assert np.array_equal(np.asarray(y), fir_bit_layers_batch(x, q))


def test_bank_1d_signal_and_every_filter_matches_direct():
    q = _qbank(6, 31)
    rng = np.random.default_rng(2)
    x = rng.integers(-128, 128, 500)
    y = np.asarray(blmac_fir_bank(jnp.asarray(x), q, tile=128))
    assert y.shape == (6, 500 - 31 + 1)
    for b in range(6):
        assert np.array_equal(y[b], fir_direct(x, q[b]))


def test_bank_tile_padding_paths():
    """Bank sizes that don't divide the bank tile exercise the pad rows."""
    q = _qbank(9, 15)
    rng = np.random.default_rng(3)
    x = rng.integers(-128, 128, 400)
    ref = fir_bit_layers_batch(x, q)[:, 0, :]
    for bank_tile in (1, 4, 8, 16):
        y = blmac_fir_bank(jnp.asarray(x), q, tile=128, bank_tile=bank_tile)
        assert np.array_equal(np.asarray(y), ref), bank_tile


def test_batch_oracle_matches_direct():
    q = _qbank(4, 21)
    rng = np.random.default_rng(4)
    x = rng.integers(-1000, 1000, (2, 300))
    y = fir_bit_layers_batch(x, q)
    for b in range(4):
        for c in range(2):
            assert np.array_equal(y[b, c], fir_direct(x[c], q[b]))


def test_batch_oracle_rejects_asymmetric():
    with pytest.raises(ValueError):
        fir_bit_layers_batch(np.zeros(50, np.int64), np.arange(22).reshape(2, 11))


def test_dynamic_single_filter_is_bank_of_one():
    from repro.core.csd import csd_digits

    q = _qbank(1, 55)[0]
    rng = np.random.default_rng(5)
    x = rng.integers(-128, 128, 700)
    trits = csd_digits(q[: 55 // 2 + 1], n_digits=17).T
    y = blmac_fir_dynamic(jnp.asarray(x), trits, 55, 17, tile=256)
    assert np.array_equal(np.asarray(y), fir_direct(x, q))


def test_pack_bank_trits_rejects_bad_banks():
    with pytest.raises(ValueError):
        pack_bank_trits(np.ones((2, 4), np.int64))  # even taps
    with pytest.raises(ValueError):
        pack_bank_trits(np.arange(10).reshape(2, 5))  # asymmetric


def test_specialized_program_cache_hits():
    q = _qbank(1, 31)[0]
    from repro.kernels.blmac_fir import pulses_msb_first

    pulses = pulses_msb_first(q)
    before = specialized_program.cache_info()
    x = jnp.asarray(np.random.default_rng(6).integers(-128, 128, 400))
    a = blmac_fir_specialized(x, pulses, 31, 128)
    mid = specialized_program.cache_info()
    b = blmac_fir_specialized(x, pulses, 31, 128)
    after = specialized_program.cache_info()
    assert mid.misses == before.misses + 1  # one compile per schedule
    assert after.misses == mid.misses and after.hits == mid.hits + 1
    assert np.array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# streaming overlap-save engine
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["packed", "specialized"])
def test_engine_stream_equals_one_shot(mode):
    q = _qbank(10, 31)
    rng = np.random.default_rng(8)
    x = rng.integers(-128, 128, (2, 2100))
    eng = FilterBankEngine(q, channels=2, tile=256, mode=mode)
    cuts = [0, 13, 30, 31, 600, 601, 1500, 2100]
    outs = [eng.push(x[:, a:b]) for a, b in zip(cuts, cuts[1:])]
    y = np.concatenate(outs, axis=2)
    assert np.array_equal(y, fir_bit_layers_batch(x, q))
    assert eng.samples_in == 2100
    assert eng.samples_out == 2100 - 31 + 1
    assert eng.pending == 30


def test_engine_priming_returns_empty():
    q = _qbank(3, 15)
    eng = FilterBankEngine(q, channels=1, tile=128)
    y = eng.push(np.arange(7))
    assert y.shape == (3, 1, 0)
    y = eng.push(np.arange(7))
    assert y.shape == (3, 1, 0)
    y = eng.push(np.arange(3))  # 17 samples total -> 3 windows
    assert y.shape == (3, 1, 3)


def test_engine_auto_mode_selection():
    """auto = cost-model dispatch: narrow banks go per-filter specialized,
    banks past the compile-budget cap always go to the scheduled path."""
    from repro.kernels.runtime import SPECIALIZE_BANK_MAX

    small = FilterBankEngine(_qbank(2, 15))
    assert small.mode == "specialized"
    assert small.dispatch_plan is not None
    assert small.dispatch_plan.predicted_us > 0
    wide = FilterBankEngine(_qbank(SPECIALIZE_BANK_MAX + 1, 15))
    assert wide.mode == "packed"
    assert wide.dispatch_plan.mode == "scheduled"
    assert wide.bank_tile == wide.bank_schedule.tile_size
    # forced modes bypass the autotuner entirely
    forced = FilterBankEngine(_qbank(2, 15), mode="packed")
    assert forced.mode == "packed" and forced.dispatch_plan is None
    alias = FilterBankEngine(_qbank(2, 15), mode="scheduled")
    assert alias.mode == "packed"


def test_engine_reset_and_taps1():
    q = np.array([[3]], np.int64)  # taps=1: no tail at all
    eng = FilterBankEngine(q, channels=1)
    y1 = eng.push(np.arange(10))
    assert np.array_equal(y1[0, 0], 3 * np.arange(10))
    assert eng.pending == 0
    eng.reset()
    assert eng.samples_in == 0 and eng.samples_out == 0


def test_engine_rejects_bad_input():
    q = _qbank(2, 15)
    with pytest.raises(ValueError):
        FilterBankEngine(q, channels=0)
    with pytest.raises(ValueError):
        FilterBankEngine(q, mode="warp")
    eng = FilterBankEngine(q, channels=2)
    with pytest.raises(ValueError):
        eng.push(np.zeros((3, 10)))


@pytest.mark.parametrize("mode", ["packed", "specialized"])
def test_engine_sub_tap_chunks_after_priming(mode):
    """Chunks shorter than taps-1 — including single samples — after the
    engine is already primed must each yield exactly chunk-sized output."""
    q = _qbank(3, 15)
    rng = np.random.default_rng(20)
    x = rng.integers(-128, 128, (1, 60))
    eng = FilterBankEngine(q, channels=1, tile=128, mode=mode)
    eng.push(x[:, :14])  # exactly taps-1: still priming
    assert eng.pending == 14
    outs = [eng.push(x[:, i : i + 1]) for i in range(14, 60)]  # 1 at a time
    assert all(o.shape == (3, 1, 1) for o in outs)
    y = np.concatenate(outs, axis=2)
    assert np.array_equal(y, fir_bit_layers_batch(x, q))


def test_engine_empty_chunk_is_identity():
    q = _qbank(2, 15)
    eng = FilterBankEngine(q, channels=2, tile=128)
    rng = np.random.default_rng(21)
    x = rng.integers(-128, 128, (2, 40))
    y1 = eng.push(x)
    pend = eng.pending
    y_empty = eng.push(np.zeros((2, 0), np.int64))
    assert y_empty.shape == (2, 2, 0)
    assert eng.pending == pend and eng.samples_in == 40
    y2 = eng.push(x)  # stream continues seamlessly after the empty push
    full = fir_bit_layers_batch(np.concatenate([x, x], axis=1), q)
    assert np.array_equal(np.concatenate([y1, y2], axis=2), full)


def test_engine_empty_chunk_while_priming():
    q = _qbank(2, 15)
    eng = FilterBankEngine(q, channels=1, tile=128)
    assert eng.push(np.zeros(0, np.int64)).shape == (2, 1, 0)
    eng.push(np.arange(5))
    assert eng.push(np.zeros(0, np.int64)).shape == (2, 1, 0)
    assert eng.pending == 5


@pytest.mark.parametrize("mode", ["packed", "specialized"])
def test_engine_final_chunk_not_tile_multiple(mode):
    """A final chunk that leaves the padded buffer off the tile grid: the
    windows reaching into the zero padding must be dropped, not returned."""
    q = _qbank(4, 31)
    rng = np.random.default_rng(22)
    x = rng.integers(-128, 128, (1, 777))  # 777 = 6*128 + 9, taps 31
    eng = FilterBankEngine(q, channels=1, tile=128, mode=mode)
    y = np.concatenate(
        [eng.push(x[:, :512]), eng.push(x[:, 512:])], axis=2
    )
    assert y.shape == (4, 1, 777 - 31 + 1)
    assert np.array_equal(y, fir_bit_layers_batch(x, q))


def test_engine_tail_state_and_output_dtype():
    """The carried tail must stay int32 whatever integer dtype is pushed,
    and outputs are int32 — the serving-side contract."""
    q = _qbank(2, 15)
    eng = FilterBankEngine(q, channels=1, tile=128)
    for dtype in (np.int8, np.int16, np.int32, np.int64):
        y = eng.push(np.ones(20, dtype))
        assert y.dtype == np.int32
        assert eng._tail.dtype == np.int32
        assert eng._tail.shape == (1, 14)
    eng.reset()
    assert eng._tail.dtype == np.int32 and eng._tail.shape == (1, 0)


def test_engine_predicted_cycles_matches_cost_model():
    from repro.core import MachineSpec, machine_cycles_batch

    q = _qbank(5, 63)
    eng = FilterBankEngine(q, channels=1)
    cyc = eng.predicted_machine_cycles()
    assert np.array_equal(cyc, machine_cycles_batch(q))
    spec = MachineSpec(taps=63, fused_last_add=True, start_overhead=2)
    fused = eng.predicted_machine_cycles(spec)
    assert np.array_equal(
        fused, machine_cycles_batch(q, overhead=2, fused_last_add=True)
    )
    assert eng.predicted_mean_cycles() == pytest.approx(cyc.mean())
    # cached: same spec parameters → same array object
    assert eng.predicted_machine_cycles(spec) is fused
    with pytest.raises(ValueError):
        eng.predicted_machine_cycles(MachineSpec(taps=127))
