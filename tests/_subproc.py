"""Run a python snippet in a fresh process with N fake XLA devices.

Multi-device tests must not pollute the main pytest process (jax locks
the device count at first init), so they run here.
"""
import os
import subprocess
import sys

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_py(code: str, devices: int = 8, timeout: int = 600) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    res = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=timeout)
    if res.returncode != 0:
        raise AssertionError(
            f"subprocess failed:\nSTDOUT:\n{res.stdout}\nSTDERR:\n{res.stderr}")
    return res.stdout
