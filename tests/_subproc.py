"""Run a python snippet in a fresh process with N fake XLA devices.

Multi-device tests must not pollute the main pytest process (jax locks
the device count at first init), so they run here.

Mesh-size agnosticism: the CI multi-device leg exports
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` for the WHOLE
pytest run.  `run_py` therefore merges its device-count override into
the inherited ``XLA_FLAGS`` instead of clobbering it — any other flags
the environment carries survive, and the forced count is always the one
the test asked for, whatever the parent session was forced to.
"""
import os
import re
import subprocess
import sys

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

_DEVCOUNT_RE = re.compile(r"--xla_force_host_platform_device_count=\d+")


def device_flags(devices: int, base: str = "") -> str:
    """``base`` XLA_FLAGS with the forced host device count set to
    ``devices`` (replacing any inherited forced count)."""
    flags = _DEVCOUNT_RE.sub("", base).split()
    flags.append(f"--xla_force_host_platform_device_count={devices}")
    return " ".join(flags)


def run_py_raw(
    code: str, devices: int = 8, timeout: int = 600
) -> subprocess.CompletedProcess:
    """Like `run_py` but returns the CompletedProcess without asserting
    on the exit status.  Crash-recovery tests use this for the victim
    process, which is EXPECTED to die (``os.kill(os.getpid(),
    signal.SIGKILL)`` exits with -9, not 0)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = device_flags(devices, env.get("XLA_FLAGS", ""))
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    return subprocess.run(
        [sys.executable, "-c", code],
        env=env,
        capture_output=True,
        text=True,
        timeout=timeout,
    )


def run_py(code: str, devices: int = 8, timeout: int = 600) -> str:
    res = run_py_raw(code, devices=devices, timeout=timeout)
    if res.returncode != 0:
        raise AssertionError(
            f"subprocess failed:\nSTDOUT:\n{res.stdout}\nSTDERR:\n{res.stderr}"
        )
    return res.stdout
