"""Five-way differential verification harness.

One bank, one signal, ONE compiled program (`repro.compiler.BlmacProgram`
— shared by every leg since the one-program refactor), five independent
implementations of the BLMAC dot product — proven bit-exact against
*each other*, not just individually plausible:

  1. **oracle**   — `repro.filters.fir_bit_layers_batch` (numpy, Eq. 2),
  2. **kernel**   — `repro.kernels.blmac_fir_bank` (Pallas, packed trits,
                    sparsity-scheduled bank tiles) — exercised BOTH
                    through the one-shot wrapper and through the
                    streaming `FilterBankEngine` scheduled path
                    (occupancy grouping + order restoration),
  3. **machine**  — `repro.core.FirBlmacMachine` (scalar cycle-accurate
                    reference, per-code Python loop),
  4. **vmachine** — `repro.core.FirBlmacVMachine` (vectorized bank
                    simulator under test),
  5. **sharded**  — `repro.filters.ShardedFilterBankEngine` over a
                    (bank, data) mesh of every visible device (1×1 on a
                    plain session, 8 forced host devices in the CI
                    multi-device leg): occupancy-balanced filter
                    partition, per-shard schedules, halo exchange when
                    the mesh has a data axis, and gather-free
                    caller-order reassembly.

Beyond outputs, the harness checks what only the machines can disagree on:
per-output cycle counts (scalar vs vectorized vs the static cost model vs
`FilterBankEngine.predicted_machine_cycles`) and the weight-memory
programming decision (scalar `program` raises exactly where the vectorized
fit mask is False).  The scalar machine is slow, so its leg runs on
``scalar_samples`` filters and ``scalar_outputs`` output positions;
everything vectorized covers the whole bank.

Bank sources: `random_type1_bank` (seeded random coefficients — stress the
digit space), `sampled_sweep_bank` (real filters from the paper's §3.1
design sweep), and `adversarial_bank` (empty-layer / single-pulse /
truncated rows — the cases a layer-skip schedule can get wrong).  Used by
`tests/test_vmachine.py` and `tests/test_schedule.py`; importable from
any future test or benchmark.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.compiler import BlmacProgram, compile_bank, lower
from repro.core import (FirBlmacMachine, FirBlmacVMachine, MachineSpec,
                        machine_cycles_batch, po2_quantize_batch)
from repro.core.machine import MachineResult
from repro.filters import FilterBankEngine, sweep_bank, sweep_specs

__all__ = [
    "DifferentialReport",
    "chaos_check",
    "cse_check",
    "five_way_check",
    "four_way_check",
    "random_type1_bank",
    "sampled_sweep_bank",
    "adversarial_bank",
]


# ---------------------------------------------------------------------------
# bank sources
# ---------------------------------------------------------------------------


def random_type1_bank(
    n_filters: int,
    taps: int,
    coeff_bits: int = 16,
    seed: int = 0,
    density: float = 1.0,
) -> np.ndarray:
    """Seeded random odd-symmetric integer bank.  ``density`` < 1 zeroes a
    fraction of coefficients — sparse programs exercise long zero-runs."""
    if taps % 2 == 0:
        raise ValueError("type-I filters need an odd tap count")
    rng = np.random.default_rng(seed)
    lim = 1 << (coeff_bits - 1)
    half = rng.integers(-lim, lim, (n_filters, taps // 2 + 1))
    if density < 1.0:
        half *= rng.random(half.shape) < density
    return np.concatenate([half, half[:, :-1][:, ::-1]], axis=1)


def adversarial_bank(taps: int = 31, coeff_bits: int = 16, seed: int = 0) -> np.ndarray:
    """The cases a layer-skip schedule can get wrong, in one mixed bank:
    all-zero rows (empty schedule), single-pulse rows at the extreme
    layers, low-layer-only rows (sparse occupancy), and dense rows — in
    an order that forces the occupancy sort to permute and restore."""
    if taps % 2 == 0:
        raise ValueError("type-I filters need an odd tap count")
    rng = np.random.default_rng(seed)
    half = taps // 2
    lim = 1 << (coeff_bits - 1)
    halves = [np.zeros(half + 1, np.int64)]  # all-zero: empty schedule
    one_top = np.zeros(half + 1, np.int64)
    one_top[half] = 1 << (coeff_bits - 2)  # single pulse, MSB layer
    halves.append(one_top)
    halves.append(rng.integers(-lim, lim, half + 1))  # dense
    one_bot = np.zeros(half + 1, np.int64)
    one_bot[0] = 1  # single pulse, layer 0
    halves.append(one_bot)
    halves.append(rng.integers(-7, 8, half + 1))  # low layers only
    halves.append(np.zeros(half + 1, np.int64))  # second empty row
    halves.append(rng.integers(-lim, lim, half + 1))  # dense again
    return np.stack(
        [np.concatenate([h, h[:-1][::-1]]) for h in halves]
    )


def sampled_sweep_bank(
    taps: int = 127,
    n_div: int = 10,
    n_filters: int = 8,
    window: str = "hamming",
    coeff_bits: int = 16,
    seed: int = 0,
) -> np.ndarray:
    """Quantized filters sampled from the paper's §3.1 design sweep."""
    bank = sweep_bank(taps, n_div, window, sweep_specs(n_div))
    rng = np.random.default_rng(seed)
    rows = rng.choice(len(bank), size=min(n_filters, len(bank)), replace=False)
    q, _ = po2_quantize_batch(bank[rows], bits=coeff_bits)
    return q


# ---------------------------------------------------------------------------
# the harness
# ---------------------------------------------------------------------------


@dataclass
class DifferentialReport:
    n_filters: int
    n_out: int
    fits: np.ndarray  # (B,) bool — vectorized weight-memory verdicts
    mean_cycles: float  # over all filters, vmachine
    scalar_checked: int  # filters the scalar machine replayed
    scalar_rejected: int  # filters the scalar machine refused to program
    sharded_mesh: tuple = (0, 0)  # (n_bank_shards, n_data) of leg 5

    def __str__(self) -> str:  # pragma: no cover - debugging aid
        return (
            f"five-way OK: B={self.n_filters} n_out={self.n_out} "
            f"fits={int(self.fits.sum())}/{self.n_filters} "
            f"mean_cycles={self.mean_cycles:.1f} "
            f"scalar legs: {self.scalar_checked} replayed, "
            f"{self.scalar_rejected} reject-parity; "
            f"sharded mesh {self.sharded_mesh}"
        )


def five_way_check(
    qbank: np.ndarray | None = None,
    x: np.ndarray | None = None,
    spec: MachineSpec | None = None,
    *,
    program: BlmacProgram | None = None,
    n_out: int = 48,
    tile: int = 256,
    scalar_samples: int = 4,
    scalar_outputs: int = 8,
    seed: int = 0,
    interpret: bool | None = None,
    mesh=None,
) -> DifferentialReport:
    """Assert all five implementations agree on ``qbank``; see module doc.

    The bank is compiled ONCE (`repro.compiler.compile_bank`) and every
    leg consumes that single `BlmacProgram` — the oracle, kernel and
    sharded legs through `repro.compiler.lower`, the engines by being
    constructed from it, the machines by reading its quantized
    coefficients.  Pass a prebuilt ``program`` (e.g. one that survived a
    `save()`/`load()` round-trip) to pin the shared artifact explicitly;
    ``qbank`` may then be omitted.

    ``x`` defaults to a seeded random signal producing ``n_out`` outputs
    within the spec's sample range.  Raises AssertionError with the leg
    name on any divergence.  ``mesh`` pins the sharded leg's device mesh
    (default: every visible device on the bank axis — a 1×1 mesh on a
    single-device session, where the leg still exercises the partition,
    per-shard planning and reassembly plumbing end-to-end).
    """
    if program is None:
        if qbank is None:
            raise ValueError("five_way_check needs qbank or program")
        program = compile_bank(np.atleast_2d(np.asarray(qbank, np.int64)))
    elif qbank is not None:
        assert np.array_equal(
            np.atleast_2d(np.asarray(qbank, np.int64)), program.qbank
        ), "qbank/program mismatch"
    qbank = program.qbank
    n_filters, taps = qbank.shape
    if spec is None:
        spec = MachineSpec(taps=taps)
    assert spec.taps == taps, "spec/taps mismatch"
    rng = np.random.default_rng(seed)
    if x is None:
        lim = 1 << (spec.sample_bits - 1)
        x = rng.integers(-lim, lim, taps - 1 + n_out)
    x = np.asarray(x, np.int64)
    n_out = x.size - taps + 1

    # -- leg 1: numpy oracle -------------------------------------------------
    # lower(..., "oracle") reads ONLY program.qbank and runs the naive
    # dense Eq. 2 loop — independent of the schedule machinery under test
    oracle = lower(program, "oracle")(x)[:, 0, :]  # (B, n_out)

    # -- leg 4: vectorized machine (under test) ------------------------------
    vm = FirBlmacVMachine(spec)
    fits = vm.program_bank(qbank)
    vres = vm.run(x)
    assert np.array_equal(vres.outputs, oracle), "vmachine outputs != oracle"
    cm = machine_cycles_batch(
        qbank, spec.n_layers, spec.start_overhead, spec.fused_last_add
    )
    assert np.array_equal(vres.cycles, np.broadcast_to(cm[:, None], vres.cycles.shape)), \
        "vmachine cycles != static cost model"
    assert np.array_equal(program.machine_cycles(spec), cm), \
        "program cycle prediction != static cost model"

    # -- leg 2: Pallas bank kernel -------------------------------------------
    y = lower(program, "scheduled", tile=tile, interpret=interpret)(x)
    assert np.array_equal(np.asarray(y[:, 0, :], np.int64), oracle), \
        "pallas bank kernel != oracle"

    # -- leg 2b: streaming engine through the scheduled bank path ------------
    # (occupancy sort, tile grouping, layer-skip superlayers, order
    # restoration — everything the one-shot wrapper also uses, plus the
    # device-resident operands and the overlap-save framing)
    eng = FilterBankEngine(
        program, channels=1, tile=tile, mode="packed", interpret=interpret
    )
    assert eng.program is program, "engine did not adopt the shared program"
    y_eng = eng.push(x)[:, 0, :]
    assert np.array_equal(np.asarray(y_eng, np.int64), oracle), \
        "scheduled FilterBankEngine != oracle"
    assert np.array_equal(eng.predicted_machine_cycles(spec), vres.cycles[:, 0]), \
        "FilterBankEngine cycle prediction != vmachine"

    # -- leg 5: device-sharded engine over a (bank, data) mesh ---------------
    # occupancy-balanced partition, per-shard autotuned programs, halo
    # exchange when the mesh carries a data axis, and the gather-free
    # caller-order reassembly — checked on whatever mesh the session has
    sharded = lower(program, "sharded", mesh=mesh, interpret=interpret)
    seng = sharded.engine
    assert seng.program is program, "sharded engine did not adopt the program"
    y_sh = sharded(x)[:, 0, :]
    assert np.array_equal(np.asarray(y_sh, np.int64), oracle), (
        f"sharded engine != oracle (mesh "
        f"{seng.n_bank_shards}x{seng.n_data}, data={seng.data_mode})"
    )
    # caller-order restoration: the partition must be a true permutation
    order = np.concatenate(seng.partition.assign)
    assert np.array_equal(np.sort(order), np.arange(n_filters)), \
        "sharded partition is not a permutation of the bank"
    assert np.array_equal(order[seng.partition.inv], np.arange(n_filters)), \
        "sharded partition inverse does not restore caller order"

    # -- leg 3: scalar cycle-accurate machine (sampled) ----------------------
    n_scalar = min(scalar_samples, n_filters)
    rows = rng.choice(n_filters, size=n_scalar, replace=False)
    xs = x[: taps - 1 + min(scalar_outputs, n_out)]
    checked = rejected = 0
    for b in rows:
        m = FirBlmacMachine(spec)
        try:
            m.program(qbank[b])
        except ValueError:
            assert not fits[b], f"scalar rejected filter {b}, vmachine fit it"
            continue  # reject-parity is re-checked (and counted) below
        assert fits[b], f"vmachine rejected filter {b}, scalar programmed it"
        sres: MachineResult = m.run(xs)
        n = sres.outputs.size
        assert np.array_equal(sres.outputs, vres.outputs[b, :n]), \
            f"scalar machine outputs != vmachine (filter {b})"
        assert np.array_equal(sres.cycles, vres.cycles[b, :n]), \
            f"scalar machine cycles != vmachine (filter {b})"
        checked += 1

    # reject-parity for every filter the mask flags (cheap: program only)
    for b in np.nonzero(~fits)[0]:
        m = FirBlmacMachine(spec)
        try:
            m.program(qbank[b])
            raise AssertionError(
                f"filter {b}: vmachine says overflow, scalar programmed it"
            )
        except ValueError:
            rejected += 1

    return DifferentialReport(
        n_filters=n_filters,
        n_out=n_out,
        fits=fits,
        mean_cycles=vres.mean_cycles,
        scalar_checked=checked,
        scalar_rejected=rejected,
        sharded_mesh=(seng.n_bank_shards, seng.n_data),
    )


def cse_check(
    qbank: np.ndarray | None = None,
    x: np.ndarray | None = None,
    *,
    program: BlmacProgram | None = None,
    n_out: int = 48,
    tile: int = 256,
    seed: int = 0,
    interpret: bool | None = None,
    mesh=None,
    level=2,
    max_shared: int | None = None,
) -> dict:
    """CSE leg of the harness: optimize a compiled bank with
    `repro.compiler.cse_pass` and assert the optimized program is
    bit-exact against the PARENT's oracle on every backend —
    weight-level (``effective_qbank``), scheduled (interpret AND the
    fused xla lane with its in-kernel combine GEMM), specialized (small
    banks), vmachine (widened-spec augmented rows + exact int64 fold),
    sharded (augmented rows across the mesh, host fold after the
    gather), and both `FilterBankEngine` modes — ``mode="auto"`` also
    exercising the autotuner's optimize-vs-decline verdict.

    Also asserts the pass's accounting: the optimized program never
    increases total pulses or §3.3 adds, and its §4 cycle prediction
    equals its augmented bank's cycles plus one per combine use.
    Returns a small report dict (counts, adds, the auto verdict).
    """
    from repro.compiler import cse_pass

    if program is None:
        if qbank is None:
            raise ValueError("cse_check needs qbank or program")
        program = compile_bank(np.atleast_2d(np.asarray(qbank, np.int64)))
    opt = cse_pass(program, level, max_shared=max_shared)
    taps = program.taps
    rng = np.random.default_rng(seed)
    if x is None:
        lim = 1 << (program.spec.sample_bits - 1)
        x = rng.integers(-lim, lim, taps - 1 + n_out)
    x = np.asarray(x, np.int64)
    oracle = lower(program, "oracle")(x)[:, 0, :]

    report = {
        "n_real": program.n_filters,
        "n_shared": 0,
        "adds_parent": program.total_adds(),
        "adds_optimized": opt.total_adds(),
        "auto_cse": "",
    }
    if opt is program:  # nothing profitable: the pass declined entirely
        return report
    report["n_shared"] = opt.n_shared

    # -- accounting ----------------------------------------------------------
    assert np.array_equal(opt.effective_qbank(), program.qbank), \
        "cse: effective_qbank != parent qbank"
    assert int(opt.pulse_counts.sum()) <= int(program.pulse_counts.sum()), \
        "cse: optimized bank has MORE pulses than the parent"
    assert opt.total_adds() <= program.total_adds(), \
        "cse: optimized program has MORE §3.3 adds than the parent"
    wspec = MachineSpec(taps=taps, coeff_bits=opt.n_layers + 1)
    assert np.array_equal(
        opt.machine_cycles(),
        opt.bank.machine_cycles(wspec)[: opt.n_real] + opt.use_counts,
    ), "cse: cycle prediction != augmented cycles + combine uses"

    # -- execution legs ------------------------------------------------------
    for leg, kw in (
        ("oracle", {}),
        ("scheduled", dict(tile=tile, interpret=interpret)),
        ("scheduled", dict(tile=tile, interpret=interpret, lane="xla")),
        ("vmachine", {}),
        ("sharded", dict(mesh=mesh, interpret=interpret)),
    ):
        y = np.asarray(lower(opt, leg, **kw)(x))[:, 0, :]
        assert np.array_equal(y.astype(np.int64), oracle), \
            f"cse: optimized {leg} {kw} != parent oracle"
    if opt.n_filters <= 12:  # one compile per augmented row: small banks
        y = np.asarray(
            lower(opt, "specialized", interpret=interpret)(x)
        )[:, 0, :]
        assert np.array_equal(y.astype(np.int64), oracle), \
            "cse: optimized specialized != parent oracle"

    # -- engines -------------------------------------------------------------
    eng = FilterBankEngine(
        opt, channels=1, tile=tile, mode="packed", interpret=interpret
    )
    assert eng.n_filters == opt.out_filters
    y = eng.push(x)[:, 0, :]
    assert np.array_equal(np.asarray(y, np.int64), oracle), \
        "cse: packed FilterBankEngine != parent oracle"
    auto = FilterBankEngine(opt, channels=1, mode="auto", interpret=interpret)
    assert auto.dispatch_plan.cse in ("optimized", "declined")
    y = auto.push(x)[:, 0, :]
    assert np.array_equal(np.asarray(y, np.int64), oracle), \
        "cse: auto FilterBankEngine != parent oracle"
    report["auto_cse"] = auto.dispatch_plan.cse
    return report


def chaos_check(
    qbank: np.ndarray,
    kills,
    *,
    n_chunks: int = 6,
    chunk: int = 512,
    mesh=None,
    n_bank_shards: int | None = None,
    data_mode: str | None = None,
    depth: int = 2,
    seed: int = 0,
    interpret: bool | None = None,
    integrity_check: bool = True,
) -> dict:
    """Chaos leg of the harness: kill shards mid-stream, assert the
    recovered stream is bit-exact vs the oracle and the fault counters
    match the injected faults.

    ``kills`` is a list of ``(shard, at_chunk)`` grid points handed to
    `repro.distributed.faultbank.FaultInjector.kill_shard` — shard
    indices are bank-shard SLOTS at fire time (after a recovery the
    survivors renumber from 0), so sequential kills read the way a test
    reasons about the recovered mesh.  The stream runs through
    `AsyncBankServer` (double-buffered, strict order); every in-flight
    chunk at each kill is replayed from its tail snapshot through the
    re-partitioned mesh, and the concatenated output must equal the
    naive Eq. 2 oracle to the last bit.  The integrity probe is on by
    default so the halo/reassembly positions are host-verified too.
    Returns the engine's ``fault_stats()`` for further assertions.
    """
    from repro.distributed.faultbank import FaultInjector
    from repro.filters import ShardedFilterBankEngine
    from repro.serving import AsyncBankServer

    program = compile_bank(np.atleast_2d(np.asarray(qbank, np.int64)))
    rng = np.random.default_rng(seed)
    lim = 1 << (program.spec.sample_bits - 1)
    x = rng.integers(-lim, lim, n_chunks * chunk)
    oracle = lower(program, "oracle")(x)[:, 0, :]

    injector = FaultInjector()
    kills = list(kills)
    for shard, at_chunk in kills:
        injector.kill_shard(shard, at_chunk)
    eng = ShardedFilterBankEngine(
        program, mesh=mesh, n_bank_shards=n_bank_shards,
        data_mode=data_mode, interpret=interpret,
        fault_injector=injector, integrity_check=integrity_check,
    )
    server = AsyncBankServer(eng, depth=depth)
    got = []
    for k in range(n_chunks):
        got += server.submit(x[k * chunk: (k + 1) * chunk])
    got += server.drain()
    y = np.concatenate([g for g in got if g.shape[2]], axis=2)[:, 0, :]
    assert np.array_equal(np.asarray(y, np.int64), oracle), (
        f"chaos: recovered stream != oracle after kills {kills} "
        f"(final mesh {eng.n_bank_shards}x{eng.n_data})"
    )
    stats = eng.fault_stats()
    assert stats["injected"]["kills"] == len(kills), (
        f"chaos: {stats['injected']['kills']} of {len(kills)} kills fired "
        f"— the grid points never hit a live (shard, chunk)"
    )
    assert stats["lost_shards"] == len(kills), stats
    assert stats["recoveries"] == len(kills), stats
    assert stats["detections"] == len(kills), stats
    assert server.failed_chunks == 0 and server.chunks_out == n_chunks, (
        "chaos: the server dropped chunks — recovery must be lossless"
    )
    return stats


def session_chaos_check(
    qbank: np.ndarray,
    kills,
    *,
    n_sessions: int = 8,
    n_slots: int = 4,
    rows_per_session: int = 2,
    n_chunks: int = 6,
    chunk: int = 256,
    n_bank_shards: int | None = None,
    mesh=None,
    seed: int = 0,
    journal_path=None,
) -> dict:
    """Sessions × shards chaos leg: N tenant streams batched into the
    shared lanes of a `BankSessionServer` whose dispatches run through a
    `ShardedFilterBankEngine`, with shards killed mid-`step()`.

    Every session's concatenated stream must equal the Eq. 2 oracle for
    its own (stream, row-selection) to the last bit — shard loss is an
    arithmetic no-op — and the server must attribute each detected fault
    to exactly the ``n_slots`` sessions of the failed dispatch round
    (per-tenant isolation: everyone else's counter stays put).  With
    ``journal_path`` the run is also journaled, checking the WAL rides
    along with mesh recovery.  Returns the server's ``fault_stats()``.
    """
    from repro.distributed.faultbank import FaultInjector
    from repro.filters import ShardedFilterBankEngine
    from repro.serving import BankSessionServer

    program = compile_bank(np.atleast_2d(np.asarray(qbank, np.int64)))
    rng = np.random.default_rng(seed)
    lim = 1 << (program.spec.sample_bits - 1)
    n = program.n_filters
    sels = [
        np.sort(rng.choice(n, size=min(rows_per_session, n), replace=False))
        for _ in range(n_sessions)
    ]
    streams = [
        rng.integers(-lim, lim, n_chunks * chunk).astype(np.int32)
        for _ in range(n_sessions)
    ]
    oracle = lower(program, "oracle")

    injector = FaultInjector()
    kills = list(kills)
    for shard, at_chunk in kills:
        injector.kill_shard(shard, at_chunk)
    eng = ShardedFilterBankEngine(
        program, channels=n_slots, mesh=mesh, n_bank_shards=n_bank_shards,
        fault_injector=injector,
    )
    server = BankSessionServer(
        program, n_slots=n_slots, auto_step=False, engine=eng,
        step_budget_us=1e12, journal=journal_path,
    )
    sessions = [server.open_session(sel) for sel in sels]
    outs = [[] for _ in range(n_sessions)]
    for k in range(n_chunks):
        for i, s in enumerate(sessions):
            s.push(streams[i][k * chunk: (k + 1) * chunk])
        server.step()
        for i, s in enumerate(sessions):
            out = s.pull()
            if out.shape[1]:
                outs[i].append(out)
    for i in range(n_sessions):
        want = oracle(streams[i])[sels[i], 0, :]
        got = np.concatenate(outs[i], axis=1)
        assert np.array_equal(np.asarray(got, np.int64), want), (
            f"session chaos: tenant {i} diverged from its oracle after "
            f"kills {kills} (final mesh {eng.n_bank_shards}x{eng.n_data})"
        )
    stats = server.fault_stats()
    assert stats["injected"]["kills"] == len(kills), stats
    assert stats["lost_shards"] == len(kills), stats
    assert stats["recoveries"] == len(kills), stats
    # per-tenant isolation: each kill marked one round's tenants, and
    # only them — total attributed faults = kills × round size
    marked = sum(stats["per_session"].values())
    assert marked <= len(kills) * n_slots, stats
    assert stats["session_faults"] == len(kills), stats
    server.close()
    return stats


# The harness grew its fifth (sharded) leg in PR 4; the historical name
# stays importable for existing tests and external callers.
four_way_check = five_way_check
