"""MoE dispatch correctness against a brute-force reference."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis "
                    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.configs import get_config  # noqa: E402
from repro.nn.common import ShardCtx, init_params  # noqa: E402
from repro.nn.moe import _positions_in_expert, moe_apply, moe_decls  # noqa: E402


@given(st.lists(st.integers(0, 7), min_size=1, max_size=200))
@settings(max_examples=100, deadline=None)
def test_positions_in_expert(ids):
    e = np.asarray(ids)
    pos = np.asarray(_positions_in_expert(jnp.asarray(e), 8))
    # each expert's positions must be 0..count-1 in order of appearance
    for ex in range(8):
        got = pos[e == ex]
        assert np.array_equal(got, np.arange(len(got)))


def _dense_reference(p, x, cfg):
    """Compute routed MoE exactly: every token through its top-k experts."""
    t, d = x.shape
    logits = x @ np.asarray(p["router"])
    probs = np.exp(logits - logits.max(-1, keepdims=True))
    probs /= probs.sum(-1, keepdims=True)
    order = np.argsort(-probs, axis=-1)[:, : cfg.experts_per_token]
    w = np.take_along_axis(probs, order, axis=-1)
    w /= w.sum(-1, keepdims=True) + 1e-9
    y = np.zeros_like(x)
    for ti in range(t):
        for kk in range(cfg.experts_per_token):
            e = order[ti, kk]
            g = x[ti] @ np.asarray(p["gate"][e])
            u = x[ti] @ np.asarray(p["up"][e])
            h = (g / (1 + np.exp(-g))) * u
            y[ti] += w[ti, kk] * (h @ np.asarray(p["down"][e]))
    return y


def test_moe_matches_dense_reference():
    cfg = get_config("mixtral-8x22b").reduced(
        d_model=32, moe_d_ff=16, n_experts=4, experts_per_token=2,
        capacity_factor=64.0)
    p = init_params(moe_decls(cfg), jax.random.key(0))
    rng = np.random.default_rng(0)
    x = rng.standard_normal((1, 24, 32)).astype(np.float32)
    ctx = ShardCtx(compute_dtype=jnp.float32)
    y, aux = jax.jit(lambda p, x: moe_apply(p, x, ctx, cfg))(p, jnp.asarray(x))
    y_ref = _dense_reference(p, x[0], cfg)
    np.testing.assert_allclose(np.asarray(y)[0], y_ref, rtol=2e-4, atol=2e-4)
    assert float(aux) > 0.0


def test_moe_capacity_drops_tokens():
    cfg = get_config("mixtral-8x22b").reduced(
        d_model=32, moe_d_ff=16, n_experts=4, experts_per_token=2,
        capacity_factor=0.10)  # almost everything dropped
    p = init_params(moe_decls(cfg), jax.random.key(0))
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((1, 64, 32)), jnp.float32)
    ctx = ShardCtx(compute_dtype=jnp.float32)
    y, _ = jax.jit(lambda p, x: moe_apply(p, x, ctx, cfg))(p, x)
    # dropped tokens produce zero routed output; norm far below no-drop run
    assert float(jnp.abs(y).mean()) < 0.5
