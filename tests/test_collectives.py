"""int8-compressed gradient all-reduce: numerics + bandwidth accounting."""
from tests._subproc import run_py


def test_compressed_psum_numerics():
    out = run_py("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
try:
    from jax import shard_map
except ImportError:  # jax 0.4.x
    from jax.experimental.shard_map import shard_map
from repro.distributed.collectives import compressed_psum
mesh = jax.make_mesh((8,), ("data",))
rng = np.random.default_rng(0)
g = jnp.asarray(rng.standard_normal((8, 64)), jnp.float32)

def f(x):
    return compressed_psum(x, "data")

y = shard_map(f, mesh=mesh, in_specs=P("data"), out_specs=P("data"))(g)
# each shard returns the int8-compressed mean over shards
expect = np.broadcast_to(np.asarray(g).mean(axis=0, keepdims=True), (8, 64))
got = np.asarray(y)
rel = np.abs(got - expect).max() / (np.abs(expect).max() + 1e-9)
assert rel < 0.02, rel  # int8 quantization error bound
print("COMPRESSED_OK", rel)
""", devices=8)
    assert "COMPRESSED_OK" in out


def test_compressed_dp_grads_close_to_exact():
    out = run_py("""
import jax, jax.numpy as jnp, numpy as np
from repro.distributed.collectives import make_compressed_dp_grad_fn
mesh = jax.make_mesh((8,), ("data",))
W = jnp.asarray(np.random.default_rng(1).standard_normal((16, 4)), jnp.float32)
x = jnp.asarray(np.random.default_rng(2).standard_normal((32, 16)), jnp.float32)
y = jnp.asarray(np.random.default_rng(3).standard_normal((32, 4)), jnp.float32)

def loss(w, batch):
    xx, yy = batch
    return jnp.mean((xx @ w - yy) ** 2)

f = make_compressed_dp_grad_fn(loss, mesh, "data")
l1, g1 = f(W, (x, y))
l2, g2 = jax.value_and_grad(loss)(W, (x, y))
rel = float(jnp.abs(g1 - g2).max() / (jnp.abs(g2).max() + 1e-9))
assert rel < 0.05, rel
print("DPGRAD_OK", rel)
""", devices=8)
    assert "DPGRAD_OK" in out
