"""The HLO roofline analyzer: parser units + scanned/unrolled parity."""
import jax
import jax.numpy as jnp

from repro.roofline.hlo_analysis import (analyze_hlo, parse_def, shape_bytes,
                                         shape_dims)


def test_shape_parsing():
    assert shape_bytes("f32[16,64]{1,0}") == 16 * 64 * 4
    assert shape_bytes("bf16[2,3]") == 12
    assert shape_bytes("(f32[4], s32[2,2])") == 16 + 16
    assert shape_dims("bf16[8,128]{1,0}") == [8, 128]
    assert shape_bytes("pred[7]") == 7


def test_parse_def_tuple_type_with_comments():
    line = ('  %while.53 = (s32[], bf16[16,4096,2048]{2,1,0}, '
            '/*index=5*/f32[36]{0}) while(%tuple.4), condition=%c, body=%b, '
            'backend_config={"known_trip_count":{"n":"36"}}')
    d = parse_def(line)
    assert d.opcode == "while"
    assert shape_bytes(d.type_str) == 4 + 16*4096*2048*2 + 36*4


def test_scanned_equals_unrolled_flops():
    D, F, L = 32, 64, 7

    def layer(x, w):
        return jnp.tanh(x @ w[0]) @ w[1]

    def scanned(x, ws):
        y, _ = jax.lax.scan(lambda c, w: (layer(c, w), None), x, ws)
        return y.sum()

    def unrolled(x, ws):
        for i in range(L):
            x = layer(x, (ws[0][i], ws[1][i]))
        return x.sum()

    x = jax.ShapeDtypeStruct((16, D), jnp.float32)
    ws = (jax.ShapeDtypeStruct((L, D, F), jnp.float32),
          jax.ShapeDtypeStruct((L, F, D), jnp.float32))
    cs = analyze_hlo(jax.jit(scanned).lower(x, ws).compile().as_text())
    cu = analyze_hlo(jax.jit(unrolled).lower(x, ws).compile().as_text())
    expect = 2 * 16 * D * F * 2 * L
    assert cs.flops == expect, (cs.flops, expect)
    assert cu.flops == expect


def test_dus_counts_slice_not_buffer():
    def f(buf, upd):
        return jax.lax.dynamic_update_slice(buf, upd, (0, 0))

    buf = jax.ShapeDtypeStruct((4096, 512), jnp.float32)
    upd = jax.ShapeDtypeStruct((1, 512), jnp.float32)
    c = analyze_hlo(jax.jit(f, donate_argnums=0).lower(buf, upd).compile().as_text())
    # traffic must be ~2x the update slice, nowhere near the 8 MiB buffer
    assert c.hbm_bytes <= 4 * 512 * 4 * 2 + 1024, c.hbm_bytes
