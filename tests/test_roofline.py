"""The HLO roofline analyzer: parser units + scanned/unrolled parity."""
import jax
import jax.numpy as jnp

from repro.roofline.hlo_analysis import (analyze_hlo, parse_def, shape_bytes,
                                         shape_dims)


def test_shape_parsing():
    assert shape_bytes("f32[16,64]{1,0}") == 16 * 64 * 4
    assert shape_bytes("bf16[2,3]") == 12
    assert shape_bytes("(f32[4], s32[2,2])") == 16 + 16
    assert shape_dims("bf16[8,128]{1,0}") == [8, 128]
    assert shape_bytes("pred[7]") == 7


def test_parse_def_tuple_type_with_comments():
    line = ('  %while.53 = (s32[], bf16[16,4096,2048]{2,1,0}, '
            '/*index=5*/f32[36]{0}) while(%tuple.4), condition=%c, body=%b, '
            'backend_config={"known_trip_count":{"n":"36"}}')
    d = parse_def(line)
    assert d.opcode == "while"
    assert shape_bytes(d.type_str) == 4 + 16*4096*2048*2 + 36*4


def test_scanned_equals_unrolled_flops():
    D, F, L = 32, 64, 7

    def layer(x, w):
        return jnp.tanh(x @ w[0]) @ w[1]

    def scanned(x, ws):
        y, _ = jax.lax.scan(lambda c, w: (layer(c, w), None), x, ws)
        return y.sum()

    def unrolled(x, ws):
        for i in range(L):
            x = layer(x, (ws[0][i], ws[1][i]))
        return x.sum()

    x = jax.ShapeDtypeStruct((16, D), jnp.float32)
    ws = (jax.ShapeDtypeStruct((L, D, F), jnp.float32),
          jax.ShapeDtypeStruct((L, F, D), jnp.float32))
    cs = analyze_hlo(jax.jit(scanned).lower(x, ws).compile().as_text())
    cu = analyze_hlo(jax.jit(unrolled).lower(x, ws).compile().as_text())
    expect = 2 * 16 * D * F * 2 * L
    assert cs.flops == expect, (cs.flops, expect)
    assert cu.flops == expect


def test_dus_counts_slice_not_buffer():
    def f(buf, upd):
        return jax.lax.dynamic_update_slice(buf, upd, (0, 0))

    buf = jax.ShapeDtypeStruct((4096, 512), jnp.float32)
    upd = jax.ShapeDtypeStruct((1, 512), jnp.float32)
    c = analyze_hlo(jax.jit(f, donate_argnums=0).lower(buf, upd).compile().as_text())
    # traffic must be ~2x the update slice, nowhere near the 8 MiB buffer
    assert c.hbm_bytes <= 4 * 512 * 4 * 2 + 1024, c.hbm_bytes


# ---------------------------------------------------------------------------
# the scheduled bank kernel's compiled (xla-lane) HLO
# ---------------------------------------------------------------------------

import numpy as np

from repro.core import po2_quantize_batch
from repro.filters import design_bank
from repro.kernels import pack_bank_trits, plan_bank_schedule
from repro.kernels.blmac_fir import (TRITS_PER_WORD, _bank_call_xla,
                                     frame_signal_batch)


def _compiled_group(merge, taps=31, tile=512, n=32, chunk=2048):
    cuts = 0.05 + 0.9 * (np.arange(n) + 0.5) / n
    q, _ = po2_quantize_batch(
        design_bank(taps, [("lowpass", float(c)) for c in cuts]), 16
    )
    sched = plan_bank_schedule(pack_bank_trits(q), None, merge)
    assert len(sched.groups) == 1
    g = sched.groups[0]
    frames, _ = frame_signal_batch(jnp.zeros((1, chunk), jnp.int32), taps, tile)
    op = jnp.asarray(g.packed.view(np.int32))
    hlo = _bank_call_xla.lower(
        frames, op, taps=taps, schedule=g.schedule, tail_shift=g.tail_shift,
        tile=tile,
    ).compile().as_text()
    return g, frames, op, tile, analyze_hlo(hlo)


def test_bank_xla_hlo_dot_flops_exact():
    """One superlayer (merge=16 fully fuses a 16-bit bank) → exactly one
    (B_pad, M) @ (M, C·n_tiles·tile) contraction's worth of FLOPs."""
    g, frames, op, tile, c = _compiled_group(merge=16)
    assert len(g.schedule) == 1
    b_pad, _, n_words = op.shape
    m_pad = n_words * TRITS_PER_WORD
    s = frames.shape[0] * frames.shape[1] * tile
    assert c.flops == 2.0 * b_pad * m_pad * s * len(g.schedule), c.flops


def test_bank_xla_hlo_flops_scale_with_superlayer_count():
    """merge=8 splits the same bank into two superlayers: twice the
    contractions, twice the dot FLOPs — the schedule→HLO relation the
    compiled cost model relies on."""
    g16, _, _, _, c16 = _compiled_group(merge=16)
    g8, _, _, _, c8 = _compiled_group(merge=8)
    assert len(g16.schedule) == 1 and len(g8.schedule) == 2
    assert c8.flops == 2 * c16.flops, (c8.flops, c16.flops)


def test_bank_xla_hlo_unpack_is_fused():
    """The fused-unpack property at the HLO level: the packed trit words
    are the program operand (2 bits/trit) and the shift/mask decode lands
    inside fusions, so HBM traffic stays near the window matrix + output
    — nowhere near what per-superlayer unpacked-trit round-trips would
    add on top."""
    g, frames, op, tile, c = _compiled_group(merge=16)
    b_pad = op.shape[0]
    m_pad = op.shape[2] * TRITS_PER_WORD
    s = frames.shape[0] * frames.shape[1] * tile
    window = m_pad * s * 4  # the im2col-style u matrix, int32
    out = b_pad * s * 4
    # the dot reads packed-derived LHS + window, writes the accumulator
    assert c.hbm_by_op.get("dot", 0) >= window + out
    # fusion-optimistic total stays within a small multiple of the
    # unavoidable traffic (window + out + frames + packed operand)
    floor = window + out + frames.size * 4 + op.size * 4
    assert c.hbm_bytes <= 4 * floor, (c.hbm_bytes, floor)
    # no unpacked-int8 trit tensor ever becomes a top-level buffer: that
    # would add ≥ b_pad · m_pad · n_tiles round trips via some elementwise
    # op, and every decode op XLA emits here is in the fused set
    assert "shift-right-arithmetic" not in c.hbm_by_op
    assert "and" not in c.hbm_by_op
