"""Device-sharded filter-bank engine: partition balance, caller-order
restoration, single-device degradation, mesh-aware autotuning, and the
multi-device paths (per-shard programs, halo exchange, channel sharding)
in a forced-8-device subprocess."""
import numpy as np
import pytest

from repro.distributed import bank_mesh, partition_bank
from repro.filters import (FilterBankEngine, ShardedFilterBankEngine,
                           fir_bit_layers_batch, spread_lowpass_qbank)
from repro.kernels.blmac_fir import pack_bank_trits
from repro.kernels.runtime import autotune_sharded_dispatch
from tests._subproc import run_py
from tests.differential import adversarial_bank, five_way_check


def _qbank(n_filters: int, taps: int = 31) -> np.ndarray:
    return spread_lowpass_qbank(n_filters, taps)


def _skewed_bank(taps: int = 31, n_dense: int = 8, n_sparse: int = 8,
                 seed: int = 0) -> np.ndarray:
    """Half dense 16-bit rows, half single-pulse rows, interleaved — the
    occupancy-skew case where a naive round-robin split puts every dense
    filter on the same shard."""
    rng = np.random.default_rng(seed)
    half = taps // 2
    rows = []
    for i in range(n_dense + n_sparse):
        h = np.zeros(half + 1, np.int64)
        if i % 2 == 0:
            h[:] = rng.integers(-(1 << 15), 1 << 15, half + 1)
        else:
            h[i % (half + 1)] = 1  # single pulse, layer 0
        rows.append(np.concatenate([h, h[:-1][::-1]]))
    return np.stack(rows)


# ---------------------------------------------------------------------------
# partition specs (pure planning — no devices involved)
# ---------------------------------------------------------------------------


def test_partition_is_permutation_and_uneven_counts():
    q = _qbank(13)
    part = partition_bank(pack_bank_trits(q), 4, q.shape[1])
    sizes = [len(a) for a in part.assign]
    assert sum(sizes) == 13 and min(sizes) >= 1
    order = np.concatenate(part.assign)
    assert np.array_equal(np.sort(order), np.arange(13))
    assert np.array_equal(order[part.inv], np.arange(13))


def test_partition_balances_occupancy_skew():
    q = _skewed_bank(n_dense=8, n_sparse=8)
    packed = pack_bank_trits(q)
    part = partition_bank(packed, 4, q.shape[1])
    # dense rows carry ~3 orders of magnitude more pulses than the
    # single-pulse rows: a count-equal split would leave one shard with
    # 4 dense rows (imbalance ≈ 2); the cost-weighted cut must not
    assert part.imbalance < 1.5, part.cost
    # occupancy-sorted contiguity: no shard mixes the two populations
    # more than at one boundary (signature sort groups them)
    sigs = [packed[a].any(axis=-1).sum(axis=-1) for a in part.assign]
    assert all(s.max() - s.min() <= 16 for s in sigs)


def test_partition_clamps_shards_to_bank():
    q = _qbank(3)
    part = partition_bank(pack_bank_trits(q), 8, q.shape[1])
    assert part.n_shards == 3
    assert all(len(a) == 1 for a in part.assign)


# ---------------------------------------------------------------------------
# mesh-aware autotuning (planning is device-free)
# ---------------------------------------------------------------------------


def test_autotuner_shards_wide_bank_and_declines_narrow():
    wide = pack_bank_trits(_qbank(256, taps=63))
    plan, part, schedules = autotune_sharded_dispatch(
        wide, 63, channels=1, mesh_shape=(8, 1), chunk_hint=8192
    )
    assert plan.n_bank_shards > 1, "256-filter bank should shard on 8 devices"
    assert len(schedules) == plan.n_bank_shards == part.n_shards
    # a tiny bank on the same mesh: per-shard dispatch overhead swamps
    # the work and the autotuner must decline to shard the filter axis
    narrow = pack_bank_trits(_qbank(2, taps=31))
    plan2, _, _ = autotune_sharded_dispatch(
        narrow, 31, channels=1, mesh_shape=(8, 1), chunk_hint=512
    )
    assert plan2.n_bank_shards == 1
    assert not plan2.sharded


def test_autotuner_can_decline_the_data_axis():
    packed = pack_bank_trits(_qbank(4, taps=31))
    # short chunks on a (1, 2) mesh: the halo exchange + split overhead
    # loses to one device per shard, so the sweep leaves the axis idle
    plan, _, _ = autotune_sharded_dispatch(
        packed, 31, channels=1, mesh_shape=(1, 2), chunk_hint=256
    )
    assert plan.n_data == 1 and plan.data_mode == "none"
    # forcing an unavailable mode is an error, not a silent fallback
    with pytest.raises(ValueError):
        autotune_sharded_dispatch(
            packed, 31, channels=3, mesh_shape=(1, 2), chunk_hint=256,
            force_data="channels",
        )


def test_forced_shard_count_is_respected():
    packed = pack_bank_trits(_qbank(16, taps=31))
    plan, part, _ = autotune_sharded_dispatch(
        packed, 31, channels=1, mesh_shape=(8, 1), chunk_hint=2048,
        force_shards=4,
    )
    assert plan.n_bank_shards == 4 and part.n_shards == 4


# ---------------------------------------------------------------------------
# single-device degradation + the five-way differential
# ---------------------------------------------------------------------------


def test_single_device_mesh_degrades_to_plain_engine():
    q = _qbank(9)
    mesh = bank_mesh(1, 1)
    eng = ShardedFilterBankEngine(q, mesh=mesh)
    assert eng.n_bank_shards == 1 and eng.data_mode == "none"
    plain = FilterBankEngine(q)
    rng = np.random.default_rng(1)
    x = rng.integers(-128, 128, 700)
    a = eng.push(x)
    b = plain.push(x)
    assert np.array_equal(a, b)
    # streaming state stays in lock-step across ragged pushes
    for sz in (3, 250, 97):
        x2 = rng.integers(-128, 128, sz)
        assert np.array_equal(eng.push(x2), plain.push(x2))
    assert eng.pending == plain.pending


def test_five_way_differential_adversarial():
    rep = five_way_check(adversarial_bank(taps=31), n_out=24, tile=128)
    assert rep.sharded_mesh[0] >= 1


def test_five_way_differential_skewed():
    rep = five_way_check(_skewed_bank(n_dense=4, n_sparse=4), n_out=32)
    assert rep.n_filters == 8


# ---------------------------------------------------------------------------
# multi-device legs (forced 8-device subprocess)
# ---------------------------------------------------------------------------


def test_sharded_engine_8_devices():
    out = run_py("""
import numpy as np
from repro.distributed import bank_mesh
from repro.filters import (ShardedFilterBankEngine, fir_bit_layers_batch,
                           spread_lowpass_qbank)

taps = 31
q = spread_lowpass_qbank(13, taps)
rng = np.random.default_rng(0)
x = rng.integers(-128, 128, 4096)
ref = fir_bit_layers_batch(x, q)[:, 0, :]

# uneven B=13 over 4 bank shards x 2 time shards (halo exchange);
# data_mode forced so the halo path is exercised even where the
# autotuner would decline the data axis
eng = ShardedFilterBankEngine(q, mesh=bank_mesh(4, 2), n_bank_shards=4,
                              data_mode="time")
assert eng.data_mode == "time" and eng.n_bank_shards == 4
assert np.array_equal(eng.push(x)[:, 0, :], ref)
print("TIME_SHARDED_OK")

# streamed ragged chunks through the same mesh
eng.reset()
outs = []
i = 0
for sz in (17, 1000, 3, 2000, 1076):
    outs.append(eng.push(x[i:i + sz]))
    i += sz
y = np.concatenate([o for o in outs if o.shape[2]], axis=2)[:, 0, :]
assert np.array_equal(y, ref)
print("STREAM_OK")

# channel sharding: C=4 over the data axis, no halo needed
C = 4
xc = rng.integers(-128, 128, (C, 2048))
refc = fir_bit_layers_batch(xc, q)
engc = ShardedFilterBankEngine(q, channels=C, mesh=bank_mesh(4, 2),
                               n_bank_shards=4, data_mode="channels")
assert engc.data_mode == "channels"
assert np.array_equal(engc.push(xc), refc)
print("CHANNELS_OK")

# caller-order restoration under a shuffled bank: outputs must follow
# the CALLER's row order, not the occupancy sort
perm = rng.permutation(13)
engp = ShardedFilterBankEngine(q[perm], mesh=bank_mesh(8, 1))
assert np.array_equal(engp.push(x)[:, 0, :], ref[perm])
print("ORDER_OK")
""", devices=8)
    for marker in ("TIME_SHARDED_OK", "STREAM_OK", "CHANNELS_OK", "ORDER_OK"):
        assert marker in out


def test_five_way_differential_8_devices():
    import os

    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    out = run_py(f"""
import sys
sys.path.insert(0, {root!r})
from tests.differential import adversarial_bank, five_way_check
rep = five_way_check(adversarial_bank(taps=31), n_out=24, tile=128)
assert rep.sharded_mesh[0] >= 1
print("FIVE_WAY_8DEV_OK", rep.sharded_mesh)
""", devices=8)
    assert "FIVE_WAY_8DEV_OK" in out


def test_async_double_buffered_server():
    from repro.serving import AsyncBankServer

    q = _qbank(6)
    eng = ShardedFilterBankEngine(q)
    server = AsyncBankServer(eng, depth=2)
    rng = np.random.default_rng(2)
    x = rng.integers(-128, 128, 4 * 600)
    ref = fir_bit_layers_batch(x, q)[:, 0, :]
    got = []
    for k in range(4):
        for done in server.submit(x[k * 600: (k + 1) * 600]):
            got.append(done)
    assert server.inflight == 2  # double buffer stayed full
    got.extend(server.drain())
    assert server.inflight == 0
    y = np.concatenate([g for g in got if g.shape[2]], axis=2)[:, 0, :]
    assert np.array_equal(y, ref)
    assert server.chunks_in == server.chunks_out == 4


def test_pending_chunk_result_is_idempotent():
    q = _qbank(3)
    eng = ShardedFilterBankEngine(q)
    x = np.arange(500) % 100
    p = eng.push_async(x)
    a = p.result()
    b = p.result()
    assert a is b  # resolved once, cached


def test_all_zero_bank_sharded():
    q = np.zeros((5, 31), np.int64)
    eng = ShardedFilterBankEngine(q)
    x = np.random.default_rng(3).integers(-128, 128, 400)
    y = eng.push(x)
    assert y.shape == (5, 1, 400 - 31 + 1)
    assert not y.any()


def test_rejects_bad_inputs():
    q = _qbank(4)
    with pytest.raises(ValueError):
        ShardedFilterBankEngine(q, channels=0)
    eng = ShardedFilterBankEngine(q, channels=2)
    with pytest.raises(ValueError):
        eng.push(np.zeros((3, 100)))  # wrong channel count
