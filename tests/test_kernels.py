"""Pallas kernels vs their jnp oracles (interpret mode), shape/dtype sweeps."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import po2_quantize
from repro.filters import design_bank, fir_direct
from repro.kernels import (blmac_fir, pulse_dequantize, pulse_matmul_op,
                           pulse_quantize)
from repro.kernels.ref import blmac_fir_ref, fir_direct_ref, pulse_decode_ref


@pytest.mark.parametrize("taps", [7, 55, 127])
@pytest.mark.parametrize("n", [300, 2500])
@pytest.mark.parametrize("dtype", [np.int8, np.int16, np.int32])
@pytest.mark.parametrize("specialize", [True, False])
def test_blmac_fir_sweep(taps, n, dtype, specialize):
    rng = np.random.default_rng(taps * n)
    cut = 0.2 + 0.5 * rng.random()
    h = design_bank(taps, [("lowpass", float(cut))])[0]
    q, _ = po2_quantize(h, 16)
    # paper §2.1 regime: sample VALUES stay 8-bit (dtype is storage);
    # 16b coeffs × 8b samples × ≤255 taps fits the int32 accumulator
    x = rng.integers(-128, 128, size=n).astype(dtype)
    y = blmac_fir(jnp.asarray(x), q, specialize=specialize, tile=512)
    expect = fir_direct(x.astype(np.int64), q)
    assert np.array_equal(np.asarray(y), expect)


def test_blmac_fir_refs_agree():
    rng = np.random.default_rng(0)
    h = design_bank(63, [("bandpass", (0.25, 0.7))])[0]
    q, _ = po2_quantize(h, 16)
    x = jnp.asarray(rng.integers(-128, 128, 700), jnp.int32)
    a = blmac_fir_ref(x, q)
    b = fir_direct_ref(x, q)
    assert np.array_equal(np.asarray(a), np.asarray(b))


def test_blmac_fir_rejects_asymmetric():
    with pytest.raises(ValueError):
        blmac_fir(jnp.zeros(100, jnp.int32), np.arange(31))


@pytest.mark.parametrize("planes", [1, 2, 4])
@pytest.mark.parametrize("k,n,m", [(128, 128, 8), (512, 256, 16), (256, 384, 4)])
def test_pulse_matmul_sweep(planes, k, n, m):
    rng = np.random.default_rng(planes * k + n)
    w = rng.standard_normal((k, n)) * np.exp2(rng.integers(-8, 8, (k, n)))
    codes, ge = pulse_quantize(w, planes)
    wd = pulse_dequantize(codes, ge)
    x = rng.standard_normal((m, k)).astype(np.float32)
    y_kern = pulse_matmul_op(jnp.asarray(x), jnp.asarray(codes),
                             jnp.asarray(ge), planes, bm=max(1, m // 2),
                             bk=128, bn=128)
    y_ref = x @ wd
    scale = np.abs(y_ref).max() + 1e-9
    assert np.abs(np.asarray(y_kern) - y_ref).max() / scale < 1e-5
    # jnp decode oracle agrees with numpy decode
    wd2 = np.asarray(pulse_decode_ref(jnp.asarray(codes), jnp.asarray(ge)))
    np.testing.assert_allclose(wd2, wd, rtol=1e-6)


def test_pulse_quantize_error_decreases_with_planes():
    rng = np.random.default_rng(1)
    w = rng.standard_normal((256, 64))
    errs = []
    for p in (1, 2, 3, 4):
        codes, ge = pulse_quantize(w, p)
        errs.append(np.abs(pulse_dequantize(codes, ge) - w).mean())
    assert errs == sorted(errs, reverse=True)
    assert errs[3] < 0.01 * np.abs(w).mean()


def test_pulse_quantize_exact_for_po2_weights():
    """P=1 is exact when weights ARE signed powers of two (paper's
    variable-precision claim in its purest form)."""
    rng = np.random.default_rng(2)
    w = np.exp2(rng.integers(-6, 6, (64, 32)).astype(np.float64))
    w *= rng.choice([-1.0, 1.0], w.shape)
    codes, ge = pulse_quantize(w, 1)
    np.testing.assert_allclose(pulse_dequantize(codes, ge), w, rtol=0)


def test_zero_column_group():
    w = np.zeros((64, 8))
    codes, ge = pulse_quantize(w, 2)
    assert np.abs(pulse_dequantize(codes, ge)).max() == 0.0
