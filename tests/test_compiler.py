"""The unified compile pipeline: content addressing, exactly-once
compilation across clients, save/load round-trips, and the five lowering
backends of one `BlmacProgram`.
"""
import json

import numpy as np
import pytest

from repro.compiler import (BlmacProgram, CompileSpec, ProgramFormatError,
                            cache_stats, clear_caches, compile_bank,
                            compile_packed, lower, pack_bank_trits)
from repro.core import machine_cycles_batch, po2_quantize_batch
from repro.filters import FilterBankEngine, fir_bit_layers_batch

from differential import adversarial_bank, five_way_check, random_type1_bank


def _qbank(n=6, taps=31, seed=0, lim=12000):
    rng = np.random.default_rng(seed)
    half = rng.integers(-lim, lim, (n, taps // 2 + 1))
    return np.concatenate([half, half[:, :-1][:, ::-1]], axis=1)


# ---------------------------------------------------------------------------
# content addressing
# ---------------------------------------------------------------------------

def test_compile_bank_is_content_addressed():
    q = _qbank()
    p1 = compile_bank(q)
    p2 = compile_bank(q.copy())  # different buffer, same content
    assert p1 is p2
    # the packed route resolves to the SAME program object
    p3 = compile_packed(pack_bank_trits(q), q.shape[1])
    assert p3 is p1
    # …and in the other order: a bank first seen packed is adopted by
    # compile_bank instead of compiled into a duplicate
    q4 = _qbank(seed=11)
    clear_caches()
    p4 = compile_packed(pack_bank_trits(q4), q4.shape[1])
    p5 = compile_bank(q4)
    assert p5 is p4
    assert cache_stats()["counters"]["bank_compiles"] == 1
    # different content -> different program
    q2 = q.copy()
    q2[0, 0] += 1
    q2[0, -1] += 1  # keep symmetry
    assert compile_bank(q2) is not p1


def test_compile_does_not_adopt_caller_buffers():
    """Programs freeze their arrays — that must never leak onto a
    caller's buffer, and mutating the caller's operand afterwards must
    not alter cached program content."""
    q = _qbank(seed=13)
    packed = pack_bank_trits(q)
    prog = compile_packed(packed, q.shape[1])
    packed[0, 0, 0] ^= 1  # caller's buffer stays writable…
    assert prog.packed[0, 0, 0] == packed[0, 0, 0] ^ 1  # …and unaliased
    qi = q.copy()
    prog2 = compile_bank(qi)
    qi[0, 0] += 2  # int input: same guarantee
    assert prog2.qbank[0, 0] == qi[0, 0] - 2


def test_engines_keep_integer_truncation_for_float_banks():
    """FilterBankEngine's historical contract: float input is cast to
    int64 (truncated), NOT po2-quantized — a float bank of integer
    values filters with exactly those values."""
    w = np.array([1.0, 2.0, 1.0])
    eng = FilterBankEngine(w, mode="packed")
    assert np.array_equal(eng.qbank, [[1, 2, 1]])
    x = np.arange(10)
    y = eng.push(x)[0, 0]
    assert np.array_equal(y, fir_bit_layers_batch(x, [1, 2, 1])[0, 0])


def test_compile_bank_quantizes_float_input():
    from repro.filters import design_bank

    h = design_bank(31, [("lowpass", 0.3), ("bandpass", (0.2, 0.6))])
    prog = compile_bank(h)
    q, k = po2_quantize_batch(h, 16)
    assert np.array_equal(prog.qbank, q)
    assert np.array_equal(prog.exponents, k)
    # float and its quantization compile to one program
    assert compile_bank(q) is prog


def test_compile_rejects_bad_banks():
    with pytest.raises(ValueError):
        compile_bank(np.ones((2, 4), np.int64))  # even taps
    with pytest.raises(ValueError):
        compile_bank(np.arange(10).reshape(2, 5))  # asymmetric
    with pytest.raises(TypeError):
        compile_bank(np.ones((2, 5), complex))
    with pytest.raises(OverflowError):
        compile_bank(_qbank(), CompileSpec(sample_bits=16))


def test_select_subprogram_slices_and_memoizes():
    q = _qbank(n=8)
    p = compile_bank(q)
    rows = np.array([5, 1, 2])
    sub = p.select(rows)
    assert sub is p.select(rows)
    assert np.array_equal(sub.qbank, q[rows])
    assert np.array_equal(sub.packed, p.packed[rows])
    assert np.array_equal(sub.pulse_counts, p.pulse_counts[rows])
    # content addressing reaches the subprogram too
    assert compile_bank(q[rows]) is sub


# ---------------------------------------------------------------------------
# exactly-once compilation across engine + autotuner + cycle predictor
# ---------------------------------------------------------------------------

def test_csd_computed_exactly_once_across_clients():
    """The regression the refactor exists for: one bank used by the
    engine, the autotuner and the cycle predictor costs ONE CSD/packing
    pass and ONE program compile, however many clients touch it."""
    from repro.kernels.runtime import autotune_bank_dispatch

    q = _qbank(n=12, taps=31, seed=3)
    clear_caches()
    eng = FilterBankEngine(q, mode="auto", chunk_hint=1024)  # compiles
    c1 = cache_stats()
    assert c1["counters"]["bank_compiles"] == 1
    assert c1["counters"]["csd_packings"] == 1
    plans_after_build = c1["counters"]["schedule_plans"]

    # a second engine, a direct autotuner call and the cycle predictor
    # all resolve through the same artifact: no new compiles, packings
    # or schedule plans
    eng2 = FilterBankEngine(q, mode="auto", chunk_hint=1024)
    assert eng2.program is eng.program
    plan, _ = autotune_bank_dispatch(eng.program, chunk_hint=1024)
    assert plan == eng.dispatch_plan or eng.dispatch_plan is None
    cycles = eng.predicted_machine_cycles()
    assert np.array_equal(eng2.predicted_machine_cycles(), cycles)
    c2 = cache_stats()
    assert c2["counters"]["bank_compiles"] == 1
    assert c2["counters"]["csd_packings"] == 1
    assert c2["counters"]["schedule_plans"] == plans_after_build
    assert c2["counters"]["machine_cycle_computes"] == 1
    assert c2["program"]["hits"] >= 1
    assert c2["autotune"]["hits"] >= 1
    assert np.array_equal(
        cycles, machine_cycles_batch(q, 16, 0, False)
    )


def test_cache_stats_reports_every_domain():
    stats = cache_stats()
    for domain in ("program", "autotune", "specialized"):
        assert {"hits", "misses", "size"} <= set(stats[domain])
    assert "size" in stats["bank_call"]
    assert isinstance(stats["counters"], dict)


def test_caches_are_bounded():
    from repro.compiler.cache import PROGRAM_CACHE
    from repro.kernels.runtime import _AUTOTUNE_CACHE, _AUTOTUNE_CACHE_MAX

    clear_caches()
    for seed in range(40):
        compile_bank(_qbank(n=2, taps=15, seed=seed, lim=500))
    assert len(PROGRAM_CACHE) <= PROGRAM_CACHE.max_entries
    assert len(_AUTOTUNE_CACHE) <= _AUTOTUNE_CACHE_MAX


# ---------------------------------------------------------------------------
# save / load
# ---------------------------------------------------------------------------

def test_save_load_roundtrip_identical(tmp_path):
    q = adversarial_bank(taps=31)
    prog = compile_bank(q)
    sched = prog.schedule()
    est_spec = prog.predict_specialized_us(1, 4)
    est_sched = prog.predict_scheduled_us(1, 4, 512)
    x = np.random.default_rng(0).integers(-128, 128, 31 + 64)
    y = lower(prog, "scheduled", tile=128)(x)

    path = tmp_path / "bank.npz"
    prog.save(path)
    clear_caches()  # force the load to rebuild, not cache-hit
    loaded = BlmacProgram.load(path)
    assert loaded.key == prog.key
    assert np.array_equal(loaded.qbank, prog.qbank)
    assert np.array_equal(loaded.exponents, prog.exponents)
    assert np.array_equal(loaded.packed, prog.packed)
    assert np.array_equal(loaded.occupancy, prog.occupancy)
    assert np.array_equal(loaded.signatures, prog.signatures)
    assert np.array_equal(loaded.pulse_counts, prog.pulse_counts)
    # identical schedule structure
    sched2 = loaded.schedule()
    assert sched2.tile_size == sched.tile_size
    assert sched2.merge == sched.merge
    assert np.array_equal(sched2.perm, sched.perm)
    assert len(sched2.groups) == len(sched.groups)
    for g1, g2 in zip(sched.groups, sched2.groups):
        assert g1.schedule == g2.schedule
        assert g1.tail_shift == g2.tail_shift
        assert g1.sel_layers == g2.sel_layers
        assert np.array_equal(g1.packed, g2.packed)
    # identical cost estimates
    assert loaded.predict_specialized_us(1, 4) == est_spec
    assert loaded.predict_scheduled_us(1, 4, 512) == est_sched
    # bit-exact outputs after reload
    y2 = lower(loaded, "scheduled", tile=128)(x)
    assert np.array_equal(y, y2)
    # loading registered the program: compiling the bank is now a hit
    assert compile_bank(q) is loaded


def _rewrite_npz(path, mutate):
    with np.load(path, allow_pickle=False) as z:
        data = {k: np.array(z[k]) for k in z.files}
    mutate(data)
    with open(path, "wb") as f:
        np.savez(f, **data)


def test_load_rejects_version_mismatch(tmp_path):
    path = tmp_path / "bank.npz"
    compile_bank(_qbank(n=2)).save(path)

    def bump_version(data):
        hdr = json.loads(str(data["header"][()]))
        hdr["format_version"] = 999
        data["header"] = np.array(json.dumps(hdr))

    _rewrite_npz(path, bump_version)
    with pytest.raises(ProgramFormatError, match="version"):
        BlmacProgram.load(path)


def test_load_rejects_tampered_content(tmp_path):
    path = tmp_path / "bank.npz"
    compile_bank(_qbank(n=2)).save(path)

    def flip_trit(data):
        packed = data["packed"].copy()
        packed[0, 0, 0] ^= 1
        data["packed"] = packed

    _rewrite_npz(path, flip_trit)
    with pytest.raises(ProgramFormatError, match="digest"):
        BlmacProgram.load(path)


def test_load_rejects_tampered_coefficients(tmp_path):
    """The digest covers the trits; a corrupted qbank (which would make
    the oracle backend diverge from the kernels) must also be rejected."""
    path = tmp_path / "bank.npz"
    compile_bank(_qbank(n=2)).save(path)

    def corrupt_qbank(data):
        qbank = data["qbank"].copy()
        qbank[0, 0] += 1
        data["qbank"] = qbank

    _rewrite_npz(path, corrupt_qbank)
    with pytest.raises(ProgramFormatError, match="digest"):
        BlmacProgram.load(path)


def test_load_rejects_truncated_file(tmp_path):
    """A half-written file must fall into the ProgramFormatError path the
    serving warm-start recovers from, not an arbitrary zip exception."""
    path = tmp_path / "bank.npz"
    compile_bank(_qbank(n=2)).save(path)
    raw = path.read_bytes()
    path.write_bytes(raw[: len(raw) // 2])
    with pytest.raises(ProgramFormatError, match="unreadable"):
        BlmacProgram.load(path)


# ---------------------------------------------------------------------------
# lowering: one program, five backends
# ---------------------------------------------------------------------------

def test_lower_all_backends_agree():
    q = _qbank(n=5, taps=15, seed=7, lim=4000)
    prog = compile_bank(q)
    rng = np.random.default_rng(1)
    x = rng.integers(-128, 128, (2, 15 + 40))  # 2 channels
    ref = fir_bit_layers_batch(x, q)
    for backend in ("oracle", "specialized", "scheduled", "vmachine"):
        exe = lower(prog, backend, tile=128)
        if backend == "vmachine":
            assert exe.fits.shape == (5,)
        y = exe(x)
        assert y.shape == ref.shape, backend
        assert np.array_equal(np.asarray(y, np.int64), ref), backend
    sharded = lower(prog, "sharded", channels=2, tile=128)
    y = sharded(x)
    assert np.array_equal(np.asarray(y, np.int64), ref)
    assert sharded.engine.program is prog


def test_lower_rejects_unknowns():
    prog = compile_bank(_qbank(n=2))
    with pytest.raises(ValueError, match="backend"):
        lower(prog, "fpga")
    with pytest.raises(TypeError):
        lower(np.ones((2, 31)), "oracle")


def test_five_way_accepts_prebuilt_program(tmp_path):
    """The differential harness's five legs all consume ONE program —
    here one that survived a disk round-trip."""
    q = random_type1_bank(4, 31, coeff_bits=12, seed=5)
    prog = compile_bank(q)
    path = tmp_path / "bank.npz"
    prog.save(path)
    clear_caches()
    loaded = BlmacProgram.load(path)
    report = five_way_check(program=loaded, n_out=24)
    assert report.n_filters == 4
    # and the legacy signature still routes through one shared program
    report2 = five_way_check(q, n_out=24)
    assert report2.n_filters == 4


def test_five_way_program_qbank_mismatch_raises():
    prog = compile_bank(_qbank(n=2))
    with pytest.raises(AssertionError, match="mismatch"):
        five_way_check(_qbank(n=2, seed=9), program=prog, n_out=8)
