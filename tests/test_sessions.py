"""Multi-tenant session serving: `repro.serving.BankSessionServer`.

The load-bearing property: ANY schedule of pushes across N sessions —
independently-paced chunk sizes, arbitrary step() points, mid-stream
filter hot-swap, pause/resume, program swap — produces bit-exactly the
stream N dedicated per-session `FilterBankEngine`s of the same program
would produce.  Batching into shared lanes is a pure scheduling
decision, never an arithmetic one.
"""
import json

import numpy as np
import pytest

from repro.compiler import TailSnapshot, compile_bank
from repro.core.costmodel import predict_session_step_us, SESSION_LANE_US
from repro.filters import (FilterBankEngine, fir_bit_layers_batch,
                           spread_lowpass_qbank)
from repro.serving import AdmissionRejected, BankSessionServer
from tests._subproc import run_py

TAPS = 31


def _program(n_filters: int, taps: int = TAPS, bits: int = 16):
    return compile_bank(spread_lowpass_qbank(n_filters, taps, coeff_bits=bits))


def _push_both(session, ref, rows, chunk, ref_out):
    session.push(chunk)
    ref_out.append(ref.push(chunk[None, :])[np.asarray(rows), 0])


# ---------------------------------------------------------------------------
# the bit-exactness property: arbitrary interleavings vs dedicated engines
# ---------------------------------------------------------------------------


def test_any_interleaving_matches_dedicated_engines():
    rng = np.random.default_rng(0)
    prog = _program(16)
    srv = BankSessionServer(prog, n_slots=3, interpret=True, auto_step=False)
    sels = [[0, 3], [5], [7, 8, 9], [1, 15], [2]]
    sessions = [srv.open_session(r) for r in sels]
    refs = [FilterBankEngine(prog, channels=1, interpret=True) for _ in sels]
    ref_out = [[] for _ in sels]
    # random schedule: every iteration a random subset of sessions
    # pushes a random-sized chunk (including tiny priming chunks), and
    # the server steps at random points — more sessions than slots, so
    # steps routinely take multiple rounds
    for _ in range(12):
        for i in rng.permutation(len(sessions)):
            if rng.random() < 0.7:
                chunk = rng.integers(-128, 128, int(rng.integers(1, 50)))
                _push_both(sessions[i], refs[i], sels[i], chunk, ref_out[i])
        if rng.random() < 0.6:
            srv.step()
    srv.step()
    for i, s in enumerate(sessions):
        got = s.pull()
        want = np.concatenate(ref_out[i], axis=1)
        assert np.array_equal(got, want), f"session {i} diverged"
    st = srv.serve_stats()
    assert st["samples_out"] == sum(r.samples_out for r in refs)


def test_interleaving_with_hot_swap_and_pause_resume():
    # one session through three eras — original selection, hot-swapped
    # selection, resumed-from-snapshot — against ONE dedicated engine
    # that just keeps streaming: the tail carries across both events
    rng = np.random.default_rng(1)
    prog = _program(12)
    srv = BankSessionServer(prog, n_slots=2, interpret=True, auto_step=False)
    rows = [2, 7]
    s = srv.open_session(rows)
    ref = FilterBankEngine(prog, channels=1, interpret=True)
    ref_out = []
    for _ in range(4):
        chunk = rng.integers(-128, 128, int(rng.integers(5, 60)))
        _push_both(s, ref, rows, chunk, ref_out)
    srv.step()
    era1 = s.pull()
    assert np.array_equal(era1, np.concatenate(ref_out, axis=1))
    # mid-stream selection hot-swap: tail carries, output shape changes
    rows = [0, 4, 9]
    assert s.swap_filters(rows).shape[1] == 0  # already flushed + pulled
    ref_out = []
    for _ in range(3):
        chunk = rng.integers(-128, 128, int(rng.integers(5, 60)))
        _push_both(s, ref, rows, chunk, ref_out)
    srv.step()
    # mid-stream pause → resume (through the snapshot object)
    snap = s.pause()
    era2 = s.pull()  # pull still works on the paused handle
    assert snap.session == s.session_id
    assert np.array_equal(era2, np.concatenate(ref_out, axis=1))
    s = srv.resume_session(snap, rows)
    ref_out = []
    for _ in range(3):
        chunk = rng.integers(-128, 128, int(rng.integers(5, 60)))
        _push_both(s, ref, rows, chunk, ref_out)
    srv.step()
    era3 = s.pull()
    assert np.array_equal(era3, np.concatenate(ref_out, axis=1))


def test_program_hot_swap_is_zero_downtime_and_bit_exact():
    rng = np.random.default_rng(2)
    qb_a = spread_lowpass_qbank(8, TAPS)
    qb_b = spread_lowpass_qbank(8, TAPS, coeff_bits=12)
    srv = BankSessionServer(qb_a, n_slots=2, interpret=True, auto_step=False)
    rows = [1, 6]
    s = srv.open_session(rows)
    ref = FilterBankEngine(srv.program, channels=1, interpret=True)
    x1 = rng.integers(-128, 128, 90)
    s.push(x1)
    srv.step()
    want1 = ref.push(x1[None, :])[rows, 0]
    assert np.array_equal(s.pull(), want1)
    old_key = srv.program.key
    srv.swap_program(qb_b)
    assert srv.program.key != old_key and srv.program_swaps == 1
    # the dedicated reference for the new era inherits the same raw
    # input history — exactly what the server's per-session tails carry
    ref_b = FilterBankEngine(srv.program, channels=1, interpret=True)
    ref_b._tail = ref._tail.copy()
    x2 = rng.integers(-128, 128, 90)
    s.push(x2)
    srv.step()
    want2 = ref_b.push(x2[None, :])[rows, 0]
    assert np.array_equal(s.pull(), want2)
    # swapping identical content is a ProgramCache hit, not a recompile
    srv.swap_program(qb_b)
    assert srv.program_swaps == 2
    with pytest.raises(ValueError):
        srv.swap_program(spread_lowpass_qbank(8, TAPS + 2))  # taps differ


# ---------------------------------------------------------------------------
# session lifecycle: snapshots, admission, eviction
# ---------------------------------------------------------------------------


def test_snapshot_session_field_round_trips_through_disk(tmp_path):
    prog = _program(6)
    srv = BankSessionServer(prog, n_slots=2, interpret=True, auto_step=False)
    s = srv.open_session([0, 2], session_id="tenant-42")
    s.push(np.arange(100))
    srv.step()
    s.pull()
    snap = s.pause()
    path = tmp_path / "tenant-42.npz"
    snap.save(path)
    loaded = TailSnapshot.load(path)
    assert loaded.session == "tenant-42"
    assert loaded.program_key == prog.select([0, 2]).key
    # a resumed stream continues bit-exactly from the file
    s2 = srv.resume_session(loaded, [0, 2])
    assert s2.session_id == "tenant-42"
    ref = FilterBankEngine(prog, channels=1, interpret=True)
    ref.push(np.arange(100)[None, :])
    x = np.arange(100, 160)
    s2.push(x)
    srv.step()
    assert np.array_equal(s2.pull(), ref.push(x[None, :])[[0, 2], 0])
    # resuming under the wrong selection is a loud error
    with pytest.raises(ValueError):
        srv.resume_session(loaded, [0, 3])


def test_admission_control_rejects_over_budget():
    prog = _program(4)
    srv = BankSessionServer(
        prog, n_slots=2, interpret=True, step_budget_us=1.0
    )
    with pytest.raises(AdmissionRejected) as ei:
        srv.open_session([0])
    assert ei.value.predicted_us > ei.value.budget_us == 1.0
    assert srv.serve_stats()["admission_rejections"] == 1
    # the budget uses the cost model's round structure
    base = srv.predicted_step_us(extra_sessions=1)
    assert base == predict_session_step_us(srv._dispatch_us(), 1, 2)


def test_eviction_parks_idle_lru_and_push_readmits():
    prog = _program(4)
    srv = BankSessionServer(
        prog, n_slots=2, interpret=True, max_sessions=2, auto_step=False
    )
    a = srv.open_session([0])
    b = srv.open_session([1])
    c = srv.open_session([2])  # over the cap: parks the LRU idle (a)
    assert a.parked and not b.parked and not c.parked
    assert srv.evictions == 1
    st = srv.serve_stats()
    assert st["active"] == 2 and st["parked"] == 1
    # a parked session's stream survives parking bit-exactly: push
    # re-admits it transparently (parking someone else)
    ref = FilterBankEngine(prog, channels=1, interpret=True)
    x = np.arange(80)
    a.push(x)
    assert not a.parked and srv.evictions == 2
    srv.step()
    assert np.array_equal(a.pull(), ref.push(x[None, :])[[0], 0])
    # with every session busy, the cap is a hard rejection
    for s in srv.sessions.values():
        if not s.parked:
            s.push(np.arange(5))
    with pytest.raises(AdmissionRejected):
        srv.open_session([3])


def test_serve_stats_are_json_ready():
    prog = _program(6)
    srv = BankSessionServer(prog, n_slots=2, interpret=True)
    s = srv.open_session([0, 1])
    s.push(np.arange(64))
    s.push(np.arange(64))
    st = srv.serve_stats()
    json.dumps(st)  # the whole surface must serialize
    assert st["sessions"] == st["active"] == 1
    assert st["chunks_in"] == 2 and st["steps"] >= 1
    assert 0.0 < st["occupancy"] <= 1.0
    assert st["per_session"][s.session_id]["latency_p50_ms"] is not None
    assert st["predicted_step_us"] > 0


def test_session_validation_errors():
    prog = _program(4)
    srv = BankSessionServer(prog, n_slots=2, interpret=True)
    with pytest.raises(ValueError):
        srv.open_session([])  # empty selection
    with pytest.raises(ValueError):
        srv.open_session([4])  # out of range
    s = srv.open_session([0], session_id="dup")
    with pytest.raises(ValueError):
        srv.open_session([1], session_id="dup")
    with pytest.raises(ValueError):
        s.push(np.zeros((2, 8)))  # sessions are single-lane streams
    s.close()
    with pytest.raises(ValueError):
        s.push(np.arange(8))  # closed
    with pytest.raises(ValueError):
        BankSessionServer(prog, n_slots=0)


def test_apply_lanes_is_stateless_and_validated():
    prog = _program(4)
    eng = FilterBankEngine(prog, channels=2, interpret=True)
    rng = np.random.default_rng(3)
    buf = rng.integers(-128, 128, (2, 100)).astype(np.int32)
    y = eng.apply_lanes(buf)
    assert y.shape == (4, 2, 100 - TAPS + 1)
    assert np.array_equal(y, fir_bit_layers_batch(buf, prog.qbank))
    assert eng.samples_in == 0 and eng._tail.shape[1] == 0  # stateless
    with pytest.raises(ValueError):
        eng.apply_lanes(buf[:1])  # wrong lane count
    with pytest.raises(ValueError):
        eng.apply_lanes(buf[:, : TAPS - 1])  # shorter than one window


def test_predict_session_step_us_round_structure():
    # one slot-rounding boundary: 8 active over 8 slots is one round,
    # 9 active spills a second full dispatch
    one = predict_session_step_us(1000.0, 8, 8)
    two = predict_session_step_us(1000.0, 9, 8)
    assert one == 1000.0 + 8 * SESSION_LANE_US
    assert two == 2 * one
    assert predict_session_step_us(1000.0, 0, 8) == 0.0
    with pytest.raises(ValueError):
        predict_session_step_us(1000.0, 1, 0)


# ---------------------------------------------------------------------------
# acceptance: 64 sessions over a 256-filter bank, hot-swap + pause/resume
# ---------------------------------------------------------------------------


def test_64_sessions_over_256_filter_bank_bit_exact():
    rng = np.random.default_rng(4)
    prog = _program(256, taps=15)
    srv = BankSessionServer(
        prog, n_slots=16, tile=128, interpret=True, auto_step=False
    )
    n_sessions = 64
    sels = [np.arange(i * 4, i * 4 + 4) for i in range(n_sessions)]
    sessions = [srv.open_session(sel) for sel in sels]
    streams = [
        rng.integers(-128, 128, 96).astype(np.int32)
        for _ in range(n_sessions)
    ]
    got = [[] for _ in range(n_sessions)]
    cuts = [
        np.sort(rng.integers(1, 96, 2)).tolist() for _ in range(n_sessions)
    ]
    for k in range(3):  # three independently-sized chunks per session
        if k == 1:
            # one mid-stream hot-swap (same rows back: exercises the
            # flush-then-retarget path without changing the reference)
            got[7].append(sessions[7].swap_filters(sels[7]))
            # one mid-stream pause/resume
            snap = sessions[13].pause()
            got[13].append(sessions[13].pull())
            sessions[13] = srv.resume_session(snap, sels[13])
        for i, s in enumerate(sessions):
            lo = 0 if k == 0 else cuts[i][k - 1]
            hi = cuts[i][k] if k < 2 else 96
            if hi > lo:
                s.push(streams[i][lo:hi])
        srv.step()
        for i, s in enumerate(sessions):
            got[i].append(s.pull())
    oracle = fir_bit_layers_batch(
        np.stack(streams), prog.qbank
    )  # (256, 64, 96-15+1): filter b applied to stream c
    for i in range(n_sessions):
        out = np.concatenate([g for g in got[i] if g.shape[1]], axis=1)
        want = oracle[sels[i], i, :]
        assert out.shape == want.shape
        assert np.array_equal(out, want), f"session {i} diverged"
    st = srv.serve_stats()
    assert st["occupancy"] > 0.9  # 64 ready sessions over 16 lanes
    assert st["rounds"] >= 9  # ≈ 4 rounds/step minus priming absorptions


# ---------------------------------------------------------------------------
# forced-8-device leg: the session server composes with a forced mesh
# ---------------------------------------------------------------------------


def test_sessions_under_forced_device_count():
    out = run_py(
        """
import numpy as np
from repro.filters import FilterBankEngine, spread_lowpass_qbank
from repro.serving import BankSessionServer

qb = spread_lowpass_qbank(8, 31)
srv = BankSessionServer(qb, n_slots=4, interpret=True, auto_step=False)
sels = [[0, 1], [5], [2, 6, 7]]
sessions = [srv.open_session(r) for r in sels]
refs = [FilterBankEngine(srv.program, channels=1, interpret=True)
        for _ in sels]
rng = np.random.default_rng(0)
want = []
for s, r, sel in zip(sessions, refs, sels):
    x = rng.integers(-128, 128, 70)
    s.push(x)
    want.append(r.push(x[None, :])[np.asarray(sel), 0])
srv.step()
for s, w in zip(sessions, want):
    assert np.array_equal(s.pull(), w)
print("OK", srv.serve_stats()["rounds"])
""",
        devices=8,
    )
    assert "OK" in out
