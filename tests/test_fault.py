"""Fault tolerance: crash → auto-resume bit-exactness; data determinism."""
import numpy as np
import pytest

from repro.configs import get_config
from repro.data import DataConfig, TokenPipeline
from repro.distributed.fault import SimulatedFailure, TrainLoop
from repro.training import OptHParams, TrainHParams


def _mk(ckpt_dir, seed=1):
    cfg = get_config("qwen2.5-3b").reduced(n_layers=2, vocab_size=128,
                                           d_model=64, d_ff=128)
    pipe = TokenPipeline(DataConfig(128, 8, 32, seed=seed))
    hp = TrainHParams(opt=OptHParams(learning_rate=3e-3, warmup_steps=5,
                                     total_steps=40))
    return TrainLoop(cfg, hp, pipe, str(ckpt_dir), ckpt_every=5)


def test_pipeline_is_pure_function_of_step():
    p1 = TokenPipeline(DataConfig(100, 8, 16, seed=3))
    p2 = TokenPipeline(DataConfig(100, 8, 16, seed=3))
    b1, b2 = p1.global_batch_at(11), p2.global_batch_at(11)
    assert np.array_equal(b1["tokens"], b2["tokens"])
    sh = [p1.shard_batch_at(11, i, 4)["tokens"] for i in range(4)]
    assert np.array_equal(np.concatenate(sh), b1["tokens"])


def test_crash_resume_bit_exact(tmp_path):
    a = _mk(tmp_path / "a")
    a.run(20)
    b = _mk(tmp_path / "b")
    with pytest.raises(SimulatedFailure):
        b.run(20, fail_at=13)
    b2 = _mk(tmp_path / "b")  # auto-resumes from step 10
    assert b2.step == 10
    b2.run(20)
    import jax

    pa = jax.tree_util.tree_leaves(a.state["params"])
    pb = jax.tree_util.tree_leaves(b2.state["params"])
    assert all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(pa, pb))


def test_straggler_watchdog_counts():
    from repro.distributed.fault import StragglerStats

    s = StragglerStats(factor=2.0)
    for _ in range(10):
        s.record(0.1)
    assert s.record(0.5) is True
    assert s.slow_steps == 1
