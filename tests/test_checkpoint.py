"""Checkpoint manager: atomicity, keep-k, sharded layout, elastic re-mesh."""
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import (all_steps, latest_step, restore_checkpoint,
                              save_checkpoint)
from tests._subproc import run_py


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {"a": jnp.asarray(rng.standard_normal((8, 16)), jnp.float32),
            "b": {"c": jnp.asarray(rng.integers(0, 100, (4,)), jnp.int32),
                  "d": jnp.asarray(rng.standard_normal(()), jnp.float32)}}


def test_roundtrip_and_keep_k(tmp_path):
    root = str(tmp_path)
    trees = {}
    for s in (1, 2, 3, 4, 5):
        trees[s] = _tree(s)
        save_checkpoint(root, s, trees[s], keep=3)
    assert all_steps(root) == [3, 4, 5]
    assert latest_step(root) == 5
    restored, step = restore_checkpoint(root, _tree())
    assert step == 5
    for a, b in zip(jax.tree_util.tree_leaves(restored),
                    jax.tree_util.tree_leaves(trees[5])):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_tmp_dirs_are_not_checkpoints(tmp_path):
    root = str(tmp_path)
    save_checkpoint(root, 1, _tree())
    os.makedirs(os.path.join(root, "step_000000002.tmp"))
    assert all_steps(root) == [1]  # uncommitted write is invisible


def test_elastic_remesh_8_to_4():
    """Save sharded on 8 devices, restore under a 4-device sharding,
    then again on 8 — bit-exact (the elastic-scaling path)."""
    out = run_py("""
import jax, jax.numpy as jnp, numpy as np, tempfile, os
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.checkpoint import save_checkpoint, restore_checkpoint
mesh8 = jax.make_mesh((8,), ("data",))
mesh4 = jax.make_mesh((4,), ("data",), devices=jax.devices()[:4])
x = jnp.arange(64, dtype=jnp.float32).reshape(8, 8)
xs = jax.device_put(x, NamedSharding(mesh8, P("data", None)))
d = tempfile.mkdtemp()
save_checkpoint(d, 7, {"w": xs}, sharded=True)
like = {"w": jax.ShapeDtypeStruct((8, 8), jnp.float32)}
sh4 = {"w": NamedSharding(mesh4, P("data", None))}
r4, step = restore_checkpoint(d, like, shardings=sh4)
assert step == 7
assert len(r4["w"].sharding.device_set) == 4
assert np.array_equal(np.asarray(r4["w"]), np.asarray(x))
sh8 = {"w": NamedSharding(mesh8, P(None, "data"))}  # different layout too
r8, _ = restore_checkpoint(d, like, shardings=sh8)
assert np.array_equal(np.asarray(r8["w"]), np.asarray(x))
print("ELASTIC_OK")
""", devices=8)
    assert "ELASTIC_OK" in out
